//! Quickstart: fit a sparse additive Matérn GP, learn the scale by MLE,
//! predict with variance + gradients, then stream further observations
//! through the *incremental* `observe` path (no refit per point) — the
//! 60-second tour of the API.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use addgp::gp::model::{AdditiveGP, AdditiveGpConfig};
use addgp::gp::train::TrainCfg;
use addgp::util::Rng;

fn main() {
    let d = 3;
    let n = 500;
    let mut rng = Rng::new(7);

    // Ground truth: an additive function + N(0, 0.1²) noise.
    let truth = |x: &[f64]| x[0].sin() + 0.5 * (2.0 * x[1]).cos() + 0.3 * x[2];
    let x: Vec<Vec<f64>> =
        (0..n).map(|_| (0..d).map(|_| rng.uniform_in(0.0, 5.0)).collect()).collect();
    let y: Vec<f64> = x.iter().map(|r| truth(r) + 0.1 * rng.normal()).collect();

    // Fit with a deliberately wrong initial scale, then run MLE.
    let mut cfg = AdditiveGpConfig::default();
    cfg.omega0 = 8.0;
    cfg.sigma2_y = 0.01;
    let mut gp = AdditiveGP::new(cfg, d);
    gp.fit(&x, &y);

    println!("training ω by Adam on the sparse likelihood gradient (eq. 15)…");
    let hist = gp.optimize_hypers(&TrainCfg { steps: 25, lr: 0.15, ..Default::default() });
    println!("  ω: 8.0 → {:.3} in {} steps", gp.omegas[0], hist.len());

    // Predict on a grid line and report accuracy.
    let mut rmse = 0.0;
    let m = 50;
    for i in 0..m {
        let q = vec![0.1 + 4.8 * i as f64 / m as f64, 2.5, 2.5];
        let out = gp.predict(&q, true);
        rmse += (out.mean - truth(&q)).powi(2);
        if i % 10 == 0 {
            println!(
                "  x₀={:.2}: μ={:+.3} (truth {:+.3})  s={:.4}  ∇μ={:+.3?}",
                q[0],
                out.mean,
                truth(&q),
                out.var,
                out.mean_grad
            );
        }
    }
    rmse = (rmse / m as f64).sqrt();
    let (hits, misses, resident) = gp.cache_stats();
    println!("RMSE over the slice: {rmse:.4}");
    println!("M̃-cache: {hits} hits / {misses} misses ({resident} columns resident)");
    assert!(rmse < 0.2, "quickstart accuracy regression");

    // Stream 25 more observations incrementally: each is a window-local KP
    // patch + a warm-started Algorithm 4 solve — no full refit
    // (DESIGN.md §FitState).
    for _ in 0..25 {
        let q: Vec<f64> = (0..d).map(|_| rng.uniform_in(0.0, 5.0)).collect();
        gp.observe(&q, truth(&q) + 0.1 * rng.normal());
    }
    let out = gp.predict(&[2.5, 2.5, 2.5], false);
    let (inserted, fallbacks, refreshes) = gp.incremental_stats();
    println!(
        "after 25 incremental observes: n={} μ={:+.3} s={:.4} \
         ({inserted} inserts, {fallbacks} fallbacks, {refreshes} cache refreshes)",
        gp.n(),
        out.mean,
        out.var
    );
    assert!(out.var.is_finite() && out.var >= 0.0);
    println!("quickstart OK");
}
