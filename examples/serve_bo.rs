//! **End-to-end system driver** (DESIGN.md §E2E): boots the full
//! three-layer stack in one process —
//!
//!   L3 rust coordinator (TCP, model registry, dynamic batcher)
//!     → PJRT runtime executing the AOT-compiled
//!   L2 JAX graph wrapping the
//!   L1 Pallas window kernel
//!
//! — then drives a real workload over the wire through the typed protocol
//! v3 [`Client`]: stream observations of the 5-D Schwefel function, fit
//! hyperparameters, issue batched acquisition queries from concurrent
//! clients, and run a short sequential BO loop via `suggest`. Reports
//! latency/throughput and verifies PJRT actually served the batches (falls
//! back to native with a notice if artifacts are absent).
//!
//! ```sh
//! make artifacts && cargo run --release --example serve_bo
//! ```

use std::time::Instant;

use addgp::bo::testfns::{schwefel, NoisyObjective};
use addgp::coordinator::server::Server;
use addgp::coordinator::Client;
use addgp::util::error::Result;
use addgp::util::Rng;

fn percentile(sorted: &[f64], p: f64) -> f64 {
    let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
    sorted[idx]
}

fn main() -> Result<()> {
    let d = 5;
    let server = Server::bind("127.0.0.1:0", true, -500.0, 500.0)?;
    let addr = server.local_addr();
    std::thread::spawn(move || {
        let _ = server.serve();
    });
    println!("coordinator on {addr}");

    let mut c = Client::connect(addr)?;
    let model = c.create_model(d, 1, 0.01, 1.0)?;

    // Stream 400 noisy Schwefel observations.
    let f = schwefel;
    let obj = NoisyObjective::new(&f, 1.0);
    let mut rng = Rng::new(0x5EED);
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for _ in 0..400 {
        let x: Vec<f64> = (0..d).map(|_| rng.uniform_in(-500.0, 500.0)).collect();
        ys.push(obj.sample(&x, &mut rng));
        xs.push(x);
    }
    let t0 = Instant::now();
    let b = c.observe_batch(model, &xs, &ys)?;
    println!(
        "ingested 400 observations in {:.2}s (path: {})",
        t0.elapsed().as_secs_f64(),
        b.path
    );

    // Fit hyperparameters server-side.
    let t0 = Instant::now();
    c.fit(model, 10)?;
    println!("MLE fit (10 Adam steps) in {:.2}s", t0.elapsed().as_secs_f64());

    // Batched acquisition queries from 4 concurrent clients.
    let queries_per_client = 25;
    let batch_per_query = 16;
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for t in 0..4u64 {
        handles.push(std::thread::spawn(move || -> Vec<f64> {
            let mut c = Client::connect(addr).unwrap();
            let mut rng = Rng::new(0xC11E + t);
            let mut lat = Vec::new();
            for _ in 0..queries_per_client {
                let rows: Vec<Vec<f64>> = (0..batch_per_query)
                    .map(|_| (0..5).map(|_| rng.uniform_in(-480.0, 480.0)).collect())
                    .collect();
                let q0 = Instant::now();
                let p = c.predict(model, &rows, 2.0, true).unwrap();
                lat.push(q0.elapsed().as_secs_f64());
                assert_eq!(p.mu.len(), batch_per_query);
            }
            lat
        }));
    }
    let mut lats: Vec<f64> = Vec::new();
    for h in handles {
        lats.extend(h.join().unwrap());
    }
    let wall = t0.elapsed().as_secs_f64();
    lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let total_points = 4 * queries_per_client * batch_per_query;
    println!(
        "served {total_points} acquisition points in {wall:.2}s \
         ({:.0} pts/s); per-request latency p50={:.1}ms p95={:.1}ms p99={:.1}ms",
        total_points as f64 / wall,
        percentile(&lats, 0.50) * 1e3,
        percentile(&lats, 0.95) * 1e3,
        percentile(&lats, 0.99) * 1e3,
    );

    // Short sequential BO via suggest/observe over the wire.
    let mut best = f64::INFINITY;
    let t0 = Instant::now();
    for _ in 0..20 {
        let x = c.suggest(model, 2.0)?;
        let y = obj.sample(&x, &mut rng);
        best = best.min(y);
        c.observe(model, &x, y)?;
    }
    println!(
        "20 suggest→observe BO rounds in {:.2}s; best f = {best:.3}",
        t0.elapsed().as_secs_f64()
    );

    // Confirm which execution path served the predictions — the typed
    // stats reply carries the v3 nested sections already parsed.
    let s = c.stats(model)?;
    println!(
        "execution paths: {} PJRT batches, {} native queries \
         (cache hits {} / misses {})",
        s.solve.pjrt_batches, s.solve.native_queries, s.solve.cache_hits, s.solve.cache_misses
    );
    if s.solve.pjrt_batches == 0 {
        println!("NOTE: PJRT did not serve — run `make artifacts` for the compiled path");
    }

    let _ = c.shutdown();
    println!("serve_bo OK");
    Ok(())
}
