//! **End-to-end system driver** (DESIGN.md §E2E): boots the full
//! three-layer stack in one process —
//!
//!   L3 rust coordinator (TCP, model registry, dynamic batcher)
//!     → PJRT runtime executing the AOT-compiled
//!   L2 JAX graph wrapping the
//!   L1 Pallas window kernel
//!
//! — then drives a real workload over the wire: stream observations of the
//! 5-D Schwefel function, fit hyperparameters, issue batched acquisition
//! queries from concurrent clients, and run a short sequential BO loop via
//! `suggest`. Reports latency/throughput and verifies PJRT actually served
//! the batches (falls back to native with a notice if artifacts are absent).
//!
//! ```sh
//! make artifacts && cargo run --release --example serve_bo
//! ```

use std::time::Instant;

use addgp::bo::testfns::{schwefel, NoisyObjective};
use addgp::coordinator::server::{Client, Server};
use addgp::ensure;
use addgp::util::error::Result;
use addgp::util::Rng;

fn percentile(sorted: &[f64], p: f64) -> f64 {
    let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
    sorted[idx]
}

fn main() -> Result<()> {
    let d = 5;
    let server = Server::bind("127.0.0.1:0", true, -500.0, 500.0)?;
    let addr = server.local_addr();
    std::thread::spawn(move || {
        let _ = server.serve();
    });
    println!("coordinator on {addr}");

    let mut c = Client::connect(addr)?;
    let r = c.call(&format!(
        r#"{{"op":"create_model","d":{d},"nu2":1,"omega":0.01,"sigma2":1.0}}"#
    ))?;
    ensure!(r.get("ok").unwrap().as_bool() == Some(true), "create failed: {r}");
    let model = r.get("model").unwrap().as_usize().unwrap();

    // Stream 400 noisy Schwefel observations.
    let f = schwefel;
    let obj = NoisyObjective::new(&f, 1.0);
    let mut rng = Rng::new(0x5EED);
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for _ in 0..400 {
        let x: Vec<f64> = (0..d).map(|_| rng.uniform_in(-500.0, 500.0)).collect();
        let y = obj.sample(&x, &mut rng);
        xs.push(format!(
            "[{}]",
            x.iter().map(|v| v.to_string()).collect::<Vec<_>>().join(",")
        ));
        ys.push(y.to_string());
    }
    let t0 = Instant::now();
    let r = c.call(&format!(
        r#"{{"op":"observe_batch","model":{model},"xs":[{}],"ys":[{}]}}"#,
        xs.join(","),
        ys.join(",")
    ))?;
    ensure!(r.get("ok").unwrap().as_bool() == Some(true));
    println!("ingested 400 observations in {:.2}s", t0.elapsed().as_secs_f64());

    // Fit hyperparameters server-side.
    let t0 = Instant::now();
    let r = c.call(&format!(r#"{{"op":"fit","model":{model},"steps":10}}"#))?;
    ensure!(r.get("ok").unwrap().as_bool() == Some(true));
    println!("MLE fit (10 Adam steps) in {:.2}s", t0.elapsed().as_secs_f64());

    // Batched acquisition queries from 4 concurrent clients.
    let queries_per_client = 25;
    let batch_per_query = 16;
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for t in 0..4u64 {
        let model = model;
        handles.push(std::thread::spawn(move || -> Vec<f64> {
            let mut c = Client::connect(addr).unwrap();
            let mut rng = Rng::new(0xC11E + t);
            let mut lat = Vec::new();
            for _ in 0..queries_per_client {
                let rows: Vec<String> = (0..batch_per_query)
                    .map(|_| {
                        let x: Vec<String> = (0..5)
                            .map(|_| rng.uniform_in(-480.0, 480.0).to_string())
                            .collect();
                        format!("[{}]", x.join(","))
                    })
                    .collect();
                let req = format!(
                    r#"{{"op":"predict","model":{model},"xs":[{}],"beta":2.0,"grad":true}}"#,
                    rows.join(",")
                );
                let q0 = Instant::now();
                let r = c.call(&req).unwrap();
                lat.push(q0.elapsed().as_secs_f64());
                assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
                assert_eq!(
                    r.get("mu").unwrap().as_f64_vec().unwrap().len(),
                    batch_per_query
                );
            }
            lat
        }));
    }
    let mut lats: Vec<f64> = Vec::new();
    for h in handles {
        lats.extend(h.join().unwrap());
    }
    let wall = t0.elapsed().as_secs_f64();
    lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let total_points = 4 * queries_per_client * batch_per_query;
    println!(
        "served {total_points} acquisition points in {wall:.2}s \
         ({:.0} pts/s); per-request latency p50={:.1}ms p95={:.1}ms p99={:.1}ms",
        total_points as f64 / wall,
        percentile(&lats, 0.50) * 1e3,
        percentile(&lats, 0.95) * 1e3,
        percentile(&lats, 0.99) * 1e3,
    );

    // Short sequential BO via suggest/observe over the wire.
    let mut best = f64::INFINITY;
    let t0 = Instant::now();
    for _ in 0..20 {
        let r = c.call(&format!(r#"{{"op":"suggest","model":{model},"beta":2.0}}"#))?;
        let x = r.get("x").unwrap().as_f64_vec().unwrap();
        let y = obj.sample(&x, &mut rng);
        best = best.min(y);
        let req = format!(
            r#"{{"op":"observe","model":{model},"x":[{}],"y":{y}}}"#,
            x.iter().map(|v| v.to_string()).collect::<Vec<_>>().join(",")
        );
        let r = c.call(&req)?;
        ensure!(r.get("ok").unwrap().as_bool() == Some(true));
    }
    println!(
        "20 suggest→observe BO rounds in {:.2}s; best f = {best:.3}",
        t0.elapsed().as_secs_f64()
    );

    // Confirm which execution path served the predictions.
    let r = c.call(&format!(r#"{{"op":"stats","model":{model}}}"#))?;
    let pjrt = r.get("pjrt_batches").unwrap().as_f64().unwrap();
    let native = r.get("native_queries").unwrap().as_f64().unwrap();
    println!(
        "execution paths: {pjrt} PJRT batches, {native} native queries \
         (cache hits {} / misses {})",
        r.get("cache_hits").unwrap().as_f64().unwrap(),
        r.get("cache_misses").unwrap().as_f64().unwrap()
    );
    if pjrt == 0.0 {
        println!("NOTE: PJRT did not serve — run `make artifacts` for the compiled path");
    }

    let _ = c.call(r#"{"op":"shutdown"}"#);
    println!("serve_bo OK");
    Ok(())
}
