//! **Cluster driver** (DESIGN.md §Replication): one writer process plus N
//! stateless read replicas, wired through the protocol v3 replication
//! surface — generation-numbered snapshot ships, invalidation pushes, and
//! replica-served `predict`/`suggest` at arbitrary fan-out.
//!
//! The parent process boots the home shard (writer), seeds a model, then
//! re-executes itself `--replica` N times: each child binds its own port,
//! subscribes to the writer, imports the snapshot artifact, and serves
//! reads until it receives a `shutdown`. The parent verifies every replica
//! answers the probe grid **bit-identically** to the writer, then hammers
//! the fleet with acquisition reads and reports aggregate throughput next
//! to the single-writer baseline. CI runs this twice (2 then 4 replicas)
//! and gates on the fleet throughput scaling — see the `cluster` job.
//!
//! ```sh
//! cargo run --release --example serve_cluster           # 2 replicas
//! REPLICAS=4 cargo run --release --example serve_cluster
//! ```
//!
//! Machine-readable output lines:
//!
//! ```text
//! BIT_IDENTITY OK replicas=<n>
//! CLUSTER replicas=<n> fleet_pts_per_s=<f> writer_pts_per_s=<w> speedup=<r>
//! ```

use std::io::{BufRead, BufReader};
use std::process::{Command, Stdio};
use std::time::{Duration, Instant};

use addgp::coordinator::server::Server;
use addgp::coordinator::{Client, Replica, ReplicaConfig};
use addgp::util::error::Result;
use addgp::util::Rng;
use addgp::{anyhow, ensure};

const D: usize = 4;
const LO: f64 = 0.0;
const HI: f64 = 4.0;
const SEED_N: usize = 500;
const BATCH: usize = 16;

/// Child role: bind a replica, report its address on stdout, serve until
/// the parent sends `shutdown`, then report the serve stats.
fn replica_main(args: &[String]) -> Result<()> {
    let writer = args.get(2).cloned().ok_or_else(|| anyhow!("--replica needs <writer_addr>"))?;
    let model: u64 = args
        .get(3)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow!("--replica needs <model_id>"))?;
    let rep = Replica::bind(
        "127.0.0.1:0",
        ReplicaConfig { writer, models: vec![model], lo: LO, hi: HI, seed: 7 },
    )
    .map_err(|e| anyhow!("replica bind: {e}"))?;
    println!("REPLICA_ADDR {}", rep.local_addr());
    let stats = rep.serve();
    println!(
        "REPLICA_STATS imported={} invalidations={} refresh_failures={} reads={}",
        stats.snapshots_imported,
        stats.invalidations_seen,
        stats.refresh_failures,
        stats.reads_served
    );
    Ok(())
}

/// A fixed probe grid: the bitwise writer↔replica identity witness.
fn probe_bits(c: &mut Client, model: u64) -> Result<Vec<u64>> {
    let xs: Vec<Vec<f64>> = vec![
        vec![0.5, 3.5, 1.0, 2.0],
        vec![2.0, 2.0, 3.0, 0.5],
        vec![3.25, 0.75, 2.5, 3.75],
        vec![1.5, 1.5, 0.25, 1.25],
    ];
    let p = c.predict(model, &xs, 2.0, true)?;
    ensure!(p.path == "native", "probe must ride the native path, got {}", p.path);
    Ok(p.mu
        .iter()
        .chain(&p.svar)
        .chain(&p.acq)
        .chain(p.gacq.iter().flatten())
        .map(|v| v.to_bits())
        .collect())
}

/// One client thread per address, each issuing `requests` batched
/// acquisition reads (grad=true — server-bound work) against its own
/// target. Returns aggregate served points per second.
fn hammer(addrs: &[String], model: u64, requests: usize) -> Result<f64> {
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for (t, addr) in addrs.iter().enumerate() {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || -> Result<usize> {
            let mut c = Client::connect(&addr)?;
            let mut rng = Rng::new(0xFA2_0017 + t as u64);
            let mut served = 0;
            for _ in 0..requests {
                let xs: Vec<Vec<f64>> = (0..BATCH)
                    .map(|_| (0..D).map(|_| rng.uniform_in(LO + 0.1, HI - 0.1)).collect())
                    .collect();
                let p = c.predict(model, &xs, 2.0, true)?;
                ensure!(p.mu.len() == BATCH, "short reply: {} of {BATCH}", p.mu.len());
                served += BATCH;
            }
            Ok(served)
        }));
    }
    let mut total = 0usize;
    for h in handles {
        total += h.join().map_err(|_| anyhow!("hammer thread panicked"))??;
    }
    Ok(total as f64 / t0.elapsed().as_secs_f64())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    if args.get(1).map(String::as_str) == Some("--replica") {
        return replica_main(&args);
    }
    let replicas: usize = std::env::var("REPLICAS")
        .ok()
        .or_else(|| args.get(1).cloned())
        .and_then(|s| s.parse().ok())
        .unwrap_or(2);
    let requests: usize = std::env::var("CLUSTER_READS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(40);

    // Home shard: native path so the example runs without PJRT artifacts.
    let server = Server::bind("127.0.0.1:0", false, LO, HI)?;
    let addr = server.local_addr();
    std::thread::spawn(move || {
        let _ = server.serve();
    });
    println!("writer on {addr}");

    let mut c = Client::connect(addr)?;
    let model = c.create_model(D, 1, 1.0, 1.0)?;
    let mut rng = Rng::new(0x5EED);
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for _ in 0..SEED_N {
        let x: Vec<f64> = (0..D).map(|_| rng.uniform_in(LO, HI)).collect();
        ys.push(x[0].sin() + x[1].cos() + 0.5 * x[2].sin() + 0.1 * rng.normal());
        xs.push(x);
    }
    ensure!(c.observe_batch(model, &xs, &ys)?.n == SEED_N);
    let gen = c.snapshot(model, None)?.gen;
    println!("seeded model {model} with {SEED_N} observations (generation {gen})");

    // Fan out: re-exec self as N replica processes, collect their ports.
    let exe = std::env::current_exe()?;
    let mut children = Vec::new();
    let mut outs = Vec::new();
    let mut raddrs = Vec::new();
    for _ in 0..replicas {
        let mut child = Command::new(&exe)
            .args(["--replica", &addr.to_string(), &model.to_string()])
            .stdout(Stdio::piped())
            .spawn()?;
        let mut out = BufReader::new(
            child.stdout.take().ok_or_else(|| anyhow!("child stdout not captured"))?,
        );
        let mut line = String::new();
        out.read_line(&mut line)?;
        let raddr = line
            .trim()
            .strip_prefix("REPLICA_ADDR ")
            .ok_or_else(|| anyhow!("bad child hello: {line:?}"))?
            .to_string();
        println!("replica on {raddr}");
        raddrs.push(raddr);
        outs.push(out);
        children.push(child);
    }

    // Wait for every replica to import the writer's generation. The
    // `have_gen` form doubles as a cheap generation query: a matching
    // replica elides the payload.
    let deadline = Instant::now() + Duration::from_secs(30);
    let mut rclients = Vec::new();
    for raddr in &raddrs {
        let mut cr = loop {
            match Client::connect(raddr) {
                Ok(cr) => break cr,
                Err(e) => {
                    ensure!(Instant::now() < deadline, "replica {raddr} unreachable: {e}");
                    std::thread::sleep(Duration::from_millis(25));
                }
            }
        };
        while cr.snapshot(model, Some(gen))?.gen != gen {
            ensure!(Instant::now() < deadline, "replica {raddr} never reached gen {gen}");
            std::thread::sleep(Duration::from_millis(25));
        }
        rclients.push(cr);
    }

    // The replication contract: every replica serves the probe grid
    // bit-for-bit identically to the writer it mirrors.
    let writer_bits = probe_bits(&mut c, model)?;
    for (cr, raddr) in rclients.iter_mut().zip(&raddrs) {
        ensure!(
            probe_bits(cr, model)? == writer_bits,
            "replica {raddr} diverged from the writer on the probe grid"
        );
        let x = cr.suggest(model, 2.0)?;
        ensure!(x.len() == D && x.iter().all(|v| (LO..=HI).contains(v)));
    }
    println!("BIT_IDENTITY OK replicas={replicas}");

    // Throughput: single-writer baseline, then the replica fleet with one
    // client thread per replica.
    let writer_pts = hammer(&[addr.to_string()], model, requests)?;
    let fleet_pts = hammer(&raddrs, model, requests)?;
    println!(
        "CLUSTER replicas={replicas} fleet_pts_per_s={fleet_pts:.0} \
         writer_pts_per_s={writer_pts:.0} speedup={:.2}",
        fleet_pts / writer_pts
    );

    // Orderly teardown: shut each replica down over the wire, collect its
    // serve stats, then stop the writer.
    for (mut cr, (mut out, mut child)) in
        rclients.into_iter().zip(outs.into_iter().zip(children.into_iter()))
    {
        cr.shutdown()?;
        let mut line = String::new();
        out.read_line(&mut line)?;
        print!("{line}");
        child.wait()?;
    }
    let _ = c.shutdown();
    println!("serve_cluster OK");
    Ok(())
}
