//! Regenerates paper **Figure 5**: prediction RMSE (± STD) and training+
//! prediction time for the Schwefel and Rastrigin surfaces, D ∈ {10, 20},
//! comparing GKP (ours) vs FGP / IP / state-space ("VBEM" stand-in).
//!
//! Scaled-down defaults (documented in DESIGN.md §4): n sweeps to 12000 by
//! default (30000 with `--full`), 10 macro-reps instead of 100, and FGP is
//! capped at n ≤ 2000 (its O(n³) fit dominates all wall-clock otherwise).
//!
//! ```sh
//! cargo run --release --example figure5 [-- --full]
//! ```
//! CSV columns: fn,d,n,method,rmse,std,fit_time_s,pred_time_s

use std::io::Write;
use std::time::Instant;

use addgp::baselines::full_gp::FullGP;
use addgp::baselines::inducing::InducingGP;
use addgp::baselines::statespace::StateSpaceBackfit;
use addgp::bo::testfns::{rastrigin_classic, schwefel};
use addgp::gp::model::{AdditiveGP, AdditiveGpConfig};
use addgp::gp::train::TrainCfg;
use addgp::util::Rng;

const N_TEST: usize = 100;
const FGP_CAP: usize = 2000;

struct Series {
    rmse_mean: f64,
    rmse_std: f64,
    fit_s: f64,
    pred_s: f64,
}

fn rmse(pred: &[f64], truth: &[f64]) -> f64 {
    (pred.iter().zip(truth).map(|(a, b)| (a - b) * (a - b)).sum::<f64>() / pred.len() as f64)
        .sqrt()
}

#[allow(clippy::too_many_arguments)]
fn eval_method(
    method: &str,
    f: &dyn Fn(&[f64]) -> f64,
    d: usize,
    n: usize,
    lo: f64,
    hi: f64,
    reps: usize,
    seed0: u64,
) -> Option<Series> {
    if method == "FGP" && n > FGP_CAP {
        return None;
    }
    let mut rmses = Vec::with_capacity(reps);
    let mut fit_s = 0.0;
    let mut pred_s = 0.0;
    for rep in 0..reps {
        let mut rng = Rng::new(seed0 + rep as u64);
        let x: Vec<Vec<f64>> =
            (0..n).map(|_| (0..d).map(|_| rng.uniform_in(lo, hi)).collect()).collect();
        let y: Vec<f64> = x.iter().map(|r| f(r) + rng.normal()).collect();
        let xt: Vec<Vec<f64>> =
            (0..N_TEST).map(|_| (0..d).map(|_| rng.uniform_in(lo, hi)).collect()).collect();
        let truth: Vec<f64> = xt.iter().map(|r| f(r)).collect();
        let omega0 = 10.0 / (hi - lo);

        let mut pred = vec![0.0; N_TEST];
        match method {
            "GKP" => {
                let mut cfg = AdditiveGpConfig::default();
                cfg.omega0 = omega0;
                cfg.stochastic.trace_probes = 8; // MLE gradient probes
                let mut gp = AdditiveGP::new(cfg, d);
                let t0 = Instant::now();
                gp.fit(&x, &y);
                gp.optimize_hypers(&TrainCfg { steps: 6, lr: 0.25, ..Default::default() });
                fit_s += t0.elapsed().as_secs_f64();
                let t0 = Instant::now();
                for (i, q) in xt.iter().enumerate() {
                    pred[i] = gp.mean(q);
                }
                pred_s += t0.elapsed().as_secs_f64();
            }
            "FGP" => {
                let mut gp = FullGP::new(addgp::Nu::Half, omega0, 1.0, d);
                let t0 = Instant::now();
                gp.fit(&x, &y);
                gp.optimize_shared_omega(omega0 * 0.1, omega0 * 10.0, 8);
                fit_s += t0.elapsed().as_secs_f64();
                let t0 = Instant::now();
                for (i, q) in xt.iter().enumerate() {
                    pred[i] = gp.predict(q).0;
                }
                pred_s += t0.elapsed().as_secs_f64();
            }
            "IP" => {
                let mut gp = InducingGP::new(addgp::Nu::Half, omega0, 1.0, d, seed0);
                let t0 = Instant::now();
                gp.fit(&x, &y);
                fit_s += t0.elapsed().as_secs_f64();
                let t0 = Instant::now();
                for (i, q) in xt.iter().enumerate() {
                    pred[i] = gp.predict(q).0;
                }
                pred_s += t0.elapsed().as_secs_f64();
            }
            "SS" => {
                let omegas = vec![omega0; d];
                let t0 = Instant::now();
                let gp = StateSpaceBackfit::fit(&x, &y, &omegas, 1.0, 8);
                fit_s += t0.elapsed().as_secs_f64();
                let t0 = Instant::now();
                for (i, q) in xt.iter().enumerate() {
                    pred[i] = gp.predict_mean(q);
                }
                pred_s += t0.elapsed().as_secs_f64();
            }
            _ => unreachable!(),
        }
        rmses.push(rmse(&pred, &truth));
    }
    let mean = rmses.iter().sum::<f64>() / reps as f64;
    let var = rmses.iter().map(|r| (r - mean) * (r - mean)).sum::<f64>() / reps as f64;
    Some(Series {
        rmse_mean: mean,
        rmse_std: var.sqrt(),
        fit_s: fit_s / reps as f64,
        pred_s: pred_s / reps as f64,
    })
}

fn main() -> std::io::Result<()> {
    let full = std::env::args().any(|a| a == "--full");
    let reps = if full { 20 } else { 5 };
    let ns: Vec<usize> = if full {
        vec![3000, 6000, 12000, 30000]
    } else {
        vec![1000, 2000, 4000, 8000]
    };
    let out_dir = "target/figures";
    std::fs::create_dir_all(out_dir)?;
    let mut w = std::fs::File::create(format!("{out_dir}/figure5.csv"))?;
    writeln!(w, "fn,d,n,method,rmse,std,fit_time_s,pred_time_s")?;

    for (fname, f, lo, hi) in [
        ("schwefel", schwefel as fn(&[f64]) -> f64, -500.0, 500.0),
        ("rastrigin", rastrigin_classic as fn(&[f64]) -> f64, -5.12, 5.12),
    ] {
        for d in [10usize, 20] {
            for &n in &ns {
                for method in ["GKP", "FGP", "IP", "SS"] {
                    let seed = 0xF5 + d as u64 * 1000 + n as u64;
                    let Some(s) = eval_method(method, &f, d, n, lo, hi, reps, seed) else {
                        continue;
                    };
                    println!(
                        "{fname} D={d} n={n} {method:>4}: RMSE {:.3} ± {:.3}  fit {:.2}s pred {:.3}s",
                        s.rmse_mean, s.rmse_std, s.fit_s, s.pred_s
                    );
                    writeln!(
                        w,
                        "{fname},{d},{n},{method},{},{},{},{}",
                        s.rmse_mean, s.rmse_std, s.fit_s, s.pred_s
                    )?;
                }
            }
        }
    }
    println!("wrote {out_dir}/figure5.csv");
    Ok(())
}
