//! Empirical complexity study — verifies the paper's headline claims
//! (Table 1 and §5/§6) by fitting log–log slopes over an n-sweep:
//!
//! * KP factorization + posterior (`b_Y`) build:        ~O(n log n)  (slope ≈ 1)
//! * log-likelihood + gradient:                         ~O(n log n)
//! * acquisition value+gradient at a *new* point:        ~O(log n)   (slope ≈ 0)
//! * acquisition step after a tiny move (cache warm):    ~O(1)
//! * dense FGP fit:                                      ~O(n³)      (slope ≈ 3)
//!
//! ```sh
//! cargo run --release --example scaling
//! ```

use std::time::Instant;

use addgp::baselines::full_gp::FullGP;
use addgp::gp::model::{AdditiveGP, AdditiveGpConfig};
use addgp::util::timer::loglog_slope;
use addgp::util::Rng;

fn main() {
    let d = 5;
    let ns = [1000usize, 2000, 4000, 8000, 16000];
    let mut fit_t = Vec::new();
    let mut nllgrad_t = Vec::new();
    let mut query_cold_t = Vec::new();
    let mut query_warm_t = Vec::new();

    println!("n-sweep (D={d}, Matérn-1/2):");
    for &n in &ns {
        let mut rng = Rng::new(n as u64);
        let x: Vec<Vec<f64>> =
            (0..n).map(|_| (0..d).map(|_| rng.uniform_in(0.0, 10.0)).collect()).collect();
        let y: Vec<f64> =
            x.iter().map(|r| r.iter().map(|v| v.sin()).sum::<f64>() + rng.normal()).collect();
        let mut cfg = AdditiveGpConfig::default();
        cfg.omega0 = 1.0;
        let mut gp = AdditiveGP::new(cfg, d);

        let t0 = Instant::now();
        gp.fit(&x, &y);
        gp.ensure_posterior();
        let t_fit = t0.elapsed().as_secs_f64();

        let t0 = Instant::now();
        let _ = gp.nll_grad();
        let t_grad = t0.elapsed().as_secs_f64();

        // Cold query: fresh point, cache must be built for its windows.
        let q = vec![5.0; d];
        let t0 = Instant::now();
        let _ = gp.predict(&q, true);
        let t_cold = t0.elapsed().as_secs_f64();

        // Warm queries: tiny moves around q (the paper's O(1) step).
        let reps = 2000;
        let mut qq = q.clone();
        let t0 = Instant::now();
        for i in 0..reps {
            qq[i % d] += 1e-7;
            let _ = std::hint::black_box(gp.predict(&qq, true));
        }
        let t_warm = t0.elapsed().as_secs_f64() / reps as f64;

        println!(
            "  n={n:6}: fit+posterior {t_fit:8.3}s  ∇NLL {t_grad:8.3}s  \
             cold query {:.3}ms  warm step {:.1}µs",
            t_cold * 1e3,
            t_warm * 1e6
        );
        fit_t.push(t_fit);
        nllgrad_t.push(t_grad);
        query_cold_t.push(t_cold);
        query_warm_t.push(t_warm);
    }
    let nsf: Vec<f64> = ns.iter().map(|&n| n as f64).collect();
    println!("log–log slopes vs n:");
    println!("  fit+posterior : {:+.2}  (paper: ~1, O(n log n))", loglog_slope(&nsf, &fit_t));
    println!("  NLL gradient  : {:+.2}  (paper: ~1, O(n log n))", loglog_slope(&nsf, &nllgrad_t));
    println!(
        "  cold query    : {:+.2}  (paper: ~0, O(log n) + window build)",
        loglog_slope(&nsf, &query_cold_t)
    );
    println!(
        "  warm step     : {:+.2}  (paper: ~0, O(1))",
        loglog_slope(&nsf, &query_warm_t)
    );

    // Dense baseline for contrast (small ns only).
    let ns_fgp = [250usize, 500, 1000, 2000];
    let mut fgp_t = Vec::new();
    for &n in &ns_fgp {
        let mut rng = Rng::new(n as u64);
        let x: Vec<Vec<f64>> =
            (0..n).map(|_| (0..d).map(|_| rng.uniform_in(0.0, 10.0)).collect()).collect();
        let y: Vec<f64> =
            x.iter().map(|r| r.iter().map(|v| v.sin()).sum::<f64>() + rng.normal()).collect();
        let mut gp = FullGP::new(addgp::Nu::Half, 1.0, 1.0, d);
        let t0 = Instant::now();
        gp.fit(&x, &y);
        fgp_t.push(t0.elapsed().as_secs_f64());
        println!("  FGP n={n:5}: fit {:.3}s", fgp_t.last().unwrap());
    }
    let nsf: Vec<f64> = ns_fgp.iter().map(|&n| n as f64).collect();
    println!("  FGP fit slope : {:+.2}  (theory: ~3, O(n³))", loglog_slope(&nsf, &fgp_t));
}
