//! Regenerates paper **Figure 6**: Bayesian optimization of the Schwefel
//! function — searched minimum vs samples, computational time, and the
//! distribution of sampled points, GKP (ours) vs FGP.
//!
//! Scaled-down defaults (DESIGN.md §4): D ∈ {5, 10}, budget 400 (vs the
//! paper's thousands), FGP capped at total n ≤ 600 by its O(n³)/O(n⁴)
//! sequential refits. Pass `--full` for D=10/20 and budget 1000.
//!
//! ```sh
//! cargo run --release --example figure6 [-- --full]
//! ```
//! CSV: d,method,iter,best,model_time_s  +  samples CSV for the right panel.

use std::io::Write;

use addgp::baselines::full_gp::FullGP;
use addgp::bo::run::{run_bo, BoConfig, BoResult};
use addgp::bo::testfns::{schwefel, NoisyObjective, SCHWEFEL_ARGMIN};
use addgp::gp::model::{AdditiveGP, AdditiveGpConfig};

fn run(d: usize, budget: usize, engine: &str) -> BoResult {
    let f = schwefel;
    let obj = NoisyObjective::new(&f, 1.0);
    let mut cfg = BoConfig {
        budget,
        warmup: 100,
        lo: -500.0,
        hi: 500.0,
        hyper_every: 0, // fixed sensible ω, as hyper refits dominate FGP
        beta: 2.0,
        seed: 0xF6 + d as u64,
        ..Default::default()
    };
    cfg.search.restarts = 6;
    cfg.search.steps = 50;
    match engine {
        "GKP" => {
            let mut gpcfg = AdditiveGpConfig::default();
            gpcfg.omega0 = 0.01;
            let mut e = AdditiveGP::new(gpcfg, d);
            run_bo(&mut e, &obj, d, &cfg)
        }
        _ => {
            let mut e = FullGP::new(addgp::Nu::Half, 0.01, 1.0, d);
            run_bo(&mut e, &obj, d, &cfg)
        }
    }
}

fn main() -> std::io::Result<()> {
    let full = std::env::args().any(|a| a == "--full");
    let (dims, budget, fgp_budget): (Vec<usize>, usize, usize) =
        if full { (vec![10, 20], 1000, 500) } else { (vec![5, 10], 300, 150) };

    let out_dir = "target/figures";
    std::fs::create_dir_all(out_dir)?;
    let mut w = std::fs::File::create(format!("{out_dir}/figure6_traces.csv"))?;
    writeln!(w, "d,method,iter,best,model_time_s")?;
    let mut ws = std::fs::File::create(format!("{out_dir}/figure6_samples.csv"))?;
    writeln!(ws, "d,method,x0,x1")?;

    for &d in &dims {
        for (method, b) in [("GKP", budget), ("FGP", fgp_budget)] {
            let t0 = std::time::Instant::now();
            let res = run(d, b, method);
            let wall = t0.elapsed().as_secs_f64();
            for (i, best) in res.best_trace.iter().enumerate() {
                writeln!(w, "{d},{method},{i},{best},{}", res.model_time_s)?;
            }
            // 2-D projection of sampled points (right panels of Fig 6).
            for s in &res.samples {
                writeln!(ws, "{d},{method},{},{}", s[0], s[1])?;
            }
            let dist: f64 = res
                .best_x
                .iter()
                .map(|&v| (v - SCHWEFEL_ARGMIN).powi(2))
                .sum::<f64>()
                .sqrt();
            println!(
                "Schwefel D={d} {method}: budget {b}, best {:.3}, |x−x*| {:.1}, \
                 model time {:.1}s (wall {:.1}s)",
                res.best_y, dist, res.model_time_s, wall
            );
        }
    }
    println!("wrote {out_dir}/figure6_traces.csv and figure6_samples.csv");
    Ok(())
}
