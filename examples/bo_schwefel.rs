//! Bayesian optimization of the 10-D Schwefel function with GP-LCB on the
//! sparse additive engine — the paper's §7.2 workload at example scale.
//!
//! ```sh
//! cargo run --release --example bo_schwefel [-- <budget> <d>]
//! ```

use addgp::bo::run::{run_bo, BoConfig};
use addgp::bo::testfns::{schwefel, NoisyObjective, SCHWEFEL_ARGMIN};
use addgp::gp::model::{AdditiveGP, AdditiveGpConfig};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let budget: usize = args.first().and_then(|v| v.parse().ok()).unwrap_or(200);
    let d: usize = args.get(1).and_then(|v| v.parse().ok()).unwrap_or(10);

    let f = schwefel;
    let obj = NoisyObjective::new(&f, 1.0);
    let mut gpcfg = AdditiveGpConfig::default();
    gpcfg.omega0 = 0.01; // ~10 length-scales across (−500, 500)
    let mut engine = AdditiveGP::new(gpcfg, d);

    let mut cfg = BoConfig {
        budget,
        warmup: 100,
        lo: -500.0,
        hi: 500.0,
        hyper_every: 100,
        beta: 2.0,
        seed: 0xBEEF,
        ..Default::default()
    };
    cfg.search.restarts = 8;
    cfg.search.steps = 60;

    println!("GP-LCB on Schwefel, D={d}, warmup=100, budget={budget}");
    let t0 = std::time::Instant::now();
    let res = run_bo(&mut engine, &obj, d, &cfg);
    let wall = t0.elapsed().as_secs_f64();

    for (i, b) in res.best_trace.iter().enumerate() {
        if i % (budget / 10).max(1) == 0 {
            println!("  iter {i:4}: best = {b:.3}");
        }
    }
    let dist: f64 = res
        .best_x
        .iter()
        .map(|&v| (v - SCHWEFEL_ARGMIN).powi(2))
        .sum::<f64>()
        .sqrt();
    println!(
        "best f = {:.3} after {} evals ({} warmup); |x − x*| = {:.1}",
        res.best_y,
        res.samples.len(),
        100,
        dist
    );
    println!("model+search time: {:.2}s of {wall:.2}s wall", res.model_time_s);
    let (hits, misses, _) = engine.cache_stats();
    println!("M̃-cache hits/misses: {hits}/{misses}");
}
