//! Regenerates the data behind paper **Figures 1 and 2** as CSV:
//!
//! * Figure 1 (left): five Matérn-3/2 kernels `a_j k(·, x_j)` whose sum is a
//!   compactly-supported KP; (right) the ten KPs obtained from ten kernels.
//! * Figure 2: the generalized KPs of `∂ω k` for Matérn-1/2, ω = 1,
//!   X = {0.1, …, 1.0}.
//!
//! ```sh
//! cargo run --release --example figures_kp [-- out_dir]
//! ```

use addgp::kernels::gkp::GkpFactorization;
use addgp::kernels::kp::KpFactorization;
use addgp::kernels::matern::{Matern, Nu};
use std::io::Write;

fn main() -> std::io::Result<()> {
    let out_dir = std::env::args().nth(1).unwrap_or_else(|| "target/figures".into());
    std::fs::create_dir_all(&out_dir)?;

    // ---- Figure 1: Matérn-3/2 KPs on 10 equispaced points -------------
    let xs: Vec<f64> = (1..=10).map(|i| i as f64 / 10.0).collect();
    let kernel = Matern::new(Nu::ThreeHalves, 1.0);
    let f = KpFactorization::new(&xs, kernel);
    let grid: Vec<f64> = (0..=600).map(|i| -0.2 + 1.4 * i as f64 / 600.0).collect();

    // Left panel: the central packet at row 5 and its five scaled kernels.
    let mut w = std::fs::File::create(format!("{out_dir}/figure1_left.csv"))?;
    writeln!(w, "x,kp,term1,term2,term3,term4,term5")?;
    let row = 5usize;
    let (lo, hi) = f.a.row_range(row);
    for &x in &grid {
        let mut terms = Vec::new();
        let mut kp = 0.0;
        for s in lo..hi {
            let t = f.a.get(row, s) * kernel.k(f.xs[s], x);
            terms.push(t);
            kp += t;
        }
        while terms.len() < 5 {
            terms.push(0.0);
        }
        writeln!(
            w,
            "{x},{kp},{},{},{},{},{}",
            terms[0], terms[1], terms[2], terms[3], terms[4]
        )?;
    }

    // Right panel: all ten KPs.
    let mut w = std::fs::File::create(format!("{out_dir}/figure1_right.csv"))?;
    let header: Vec<String> = (0..10).map(|i| format!("kp{i}")).collect();
    writeln!(w, "x,{}", header.join(","))?;
    for &x in &grid {
        let mut row = vec![x.to_string()];
        for i in 0..10 {
            let (lo, hi) = f.a.row_range(i);
            let v: f64 = (lo..hi).map(|s| f.a.get(i, s) * kernel.k(f.xs[s], x)).sum();
            row.push(format!("{v}"));
        }
        writeln!(w, "{}", row.join(","))?;
    }

    // Numeric verification of the compact-support claim (Fig 1's point):
    let mut max_out: f64 = 0.0;
    for i in f.w()..10 - f.w() {
        for &x in &grid {
            let (plo, phi_) = (f.xs[i - f.w()], f.xs[i + f.w()]);
            if x < plo - 1e-9 || x > phi_ + 1e-9 {
                let (lo, hi) = f.a.row_range(i);
                let v: f64 = (lo..hi).map(|s| f.a.get(i, s) * kernel.k(f.xs[s], x)).sum();
                max_out = max_out.max(v.abs());
            }
        }
    }
    println!("figure1: max |KP| outside support = {max_out:.3e} (should be ~0)");

    // ---- Figure 2: generalized KPs of ∂ωk, Matérn-1/2 ------------------
    let kernel2 = Matern::new(Nu::Half, 1.0);
    let g = GkpFactorization::new_sorted(&xs, kernel2);
    let mut w = std::fs::File::create(format!("{out_dir}/figure2.csv"))?;
    let header: Vec<String> = (0..10).map(|i| format!("gkp{i}")).collect();
    writeln!(w, "x,dk_example,{}", header.join(","))?;
    let mut max_out2: f64 = 0.0;
    for &x in &grid {
        let mut row = vec![x.to_string(), format!("{}", kernel2.dk_domega(0.5, x))];
        for i in 0..10 {
            let (lo, hi) = g.b.row_range(i);
            let v: f64 = (lo..hi).map(|s| g.b.get(i, s) * kernel2.dk_domega(g.xs[s], x)).sum();
            row.push(format!("{v}"));
            let wb = 2; // ν+3/2 for ν=1/2
            if i >= wb && i + wb < 10 {
                let (plo, phi_) = (g.xs[i - wb], g.xs[i + wb]);
                if x < plo - 1e-9 || x > phi_ + 1e-9 {
                    max_out2 = max_out2.max(v.abs());
                }
            }
        }
        writeln!(w, "{}", row.join(","))?;
    }
    println!("figure2: max |GKP| outside support = {max_out2:.3e} (should be ~0)");
    println!("CSV written to {out_dir}/figure1_left.csv, figure1_right.csv, figure2.csv");
    Ok(())
}
