//! Offline-build substrates: the environment ships no general-purpose crates
//! (no `rand`, `serde_json`, `clap`, `criterion`, `anyhow`), so the small
//! pieces this library needs are implemented here from scratch.

pub mod codec;
pub mod error;
pub mod fault;
pub mod json;
pub mod pool;
pub mod rng;
pub mod timer;

pub use json::Json;
pub use rng::Rng;
