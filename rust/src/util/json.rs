//! Minimal JSON value, parser and serializer — the coordinator wire format
//! and the artifact-manifest format (`serde_json` is unavailable offline).
//!
//! Supports the full JSON grammar except `\u` surrogate pairs beyond the BMP;
//! numbers are parsed as `f64`.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Decode an array of numbers.
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr().map(|a| a.iter().filter_map(|v| v.as_f64()).collect())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        write!(f, "{}", *x as i64)
                    } else {
                        write!(f, "{x}")
                    }
                } else {
                    write!(f, "null") // JSON has no inf/nan
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let cp =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf-8".to_string())?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let src = r#"{"op":"predict","x":[1.5,-2.0,3],"id":7,"ok":true,"note":null}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("op").unwrap().as_str().unwrap(), "predict");
        assert_eq!(v.get("x").unwrap().as_f64_vec().unwrap(), vec![1.5, -2.0, 3.0]);
        assert_eq!(v.get("id").unwrap().as_usize().unwrap(), 7);
        assert_eq!(v.get("ok").unwrap().as_bool().unwrap(), true);
        // Re-parse the serialization.
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn nested_and_escapes() {
        let src = r#"{"a":[[1,2],[3,4]],"s":"line\nbreak \"q\" é"}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("s").unwrap().as_str().unwrap(), "line\nbreak \"q\" é");
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("hello").is_err());
        assert!(Json::parse("{\"a\":1} x").is_err());
    }

    #[test]
    fn scientific_numbers() {
        let v = Json::parse("[1e-3, -2.5E+2, 0.0]").unwrap();
        assert_eq!(v.as_f64_vec().unwrap(), vec![1e-3, -250.0, 0.0]);
    }
}
