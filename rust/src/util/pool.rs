//! A zero-dependency scoped fork-join helper for the per-dimension shards
//! of the incremental batch path (DESIGN.md §FitState, "Batched inserts &
//! dimension sharding").
//!
//! Back-fitting treats the `D` additive dimensions as independent blocks, so
//! a batch insert decomposes into `D` embarrassingly parallel jobs (one band
//! splice + window re-solve + factor sweep each). The offline image ships no
//! rayon; [`std::thread::scope`] (fork-join with borrowed data, no `'static`
//! bound) is all that's needed: jobs are coarse — milliseconds at serving
//! sizes — so per-call spawn cost is noise and a persistent pool would add
//! state for no measurable win.

/// Number of worker threads the host offers (≥ 1).
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1)
}

/// Apply `f` to every item of `items` (with its index), spreading the items
/// over at most `max_threads` scoped threads, and return the results in item
/// order. Falls back to a plain sequential loop when only one thread is
/// requested or there is at most one item, so callers need no special case.
///
/// Items are split into contiguous chunks (one per thread); `f` must be
/// deterministic per item for results to be independent of the thread count,
/// which every caller in this crate relies on.
pub fn par_map_mut<T, R, F>(items: &mut [T], max_threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, &mut T) -> R + Sync,
{
    let n = items.len();
    let threads = max_threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        return items.iter_mut().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let chunk = (n + threads - 1) / threads;
    let mut out: Vec<Option<R>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    let fref = &f;
    std::thread::scope(|s| {
        let mut it_rest: &mut [T] = items;
        let mut out_rest: &mut [Option<R>] = &mut out;
        let mut base = 0usize;
        while !it_rest.is_empty() {
            let take = chunk.min(it_rest.len());
            let (it_chunk, it_tail) = std::mem::take(&mut it_rest).split_at_mut(take);
            let (o_chunk, o_tail) = std::mem::take(&mut out_rest).split_at_mut(take);
            it_rest = it_tail;
            out_rest = o_tail;
            let b = base;
            base += take;
            s.spawn(move || {
                for (off, (t, o)) in
                    it_chunk.iter_mut().zip(o_chunk.iter_mut()).enumerate()
                {
                    *o = Some(fref(b + off, t));
                }
            });
        }
    });
    out.into_iter().map(|o| o.expect("worker filled every slot")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order_and_mutates() {
        for threads in [1usize, 2, 3, 8] {
            let mut items: Vec<u64> = (0..13).collect();
            let out = par_map_mut(&mut items, threads, |i, v| {
                *v += 100;
                (i as u64) * 2 + *v
            });
            assert_eq!(items, (100..113).collect::<Vec<u64>>());
            let want: Vec<u64> = (0..13u64).map(|i| i * 2 + 100 + i).collect();
            assert_eq!(out, want, "threads={threads}");
        }
    }

    #[test]
    fn empty_and_single() {
        let mut none: Vec<u32> = Vec::new();
        let out = par_map_mut(&mut none, 4, |_, v| *v);
        assert!(out.is_empty());
        let mut one = vec![7u32];
        let out = par_map_mut(&mut one, 4, |i, v| (i, *v));
        assert_eq!(out, vec![(0, 7)]);
    }
}
