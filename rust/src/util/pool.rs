//! Thread-pool substrates for the two concurrency shapes in this crate
//! (DESIGN.md §FitState "Batched inserts & dimension sharding" and
//! §Coordinator "Shared worker pool").
//!
//! * [`par_map_mut`] — a zero-dependency *scoped* fork-join helper for the
//!   per-dimension shards of the incremental batch path. Back-fitting treats
//!   the `D` additive dimensions as independent blocks, so a batch insert
//!   decomposes into `D` embarrassingly parallel jobs (one band splice +
//!   window re-solve + factor sweep each). Jobs borrow the caller's data, so
//!   [`std::thread::scope`] is the right tool: no `'static` bound, and the
//!   jobs are coarse enough (milliseconds at serving sizes) that per-call
//!   spawn cost is noise.
//!
//! * [`WorkerPool`] — the *persistent* generalization that the serving
//!   coordinator runs on: a fixed set of named workers serving `'static`
//!   jobs from per-worker queues with work stealing. One pool serves every
//!   model in the process (cross-model sharding), so a fleet of small models
//!   shares cores and one giant model overlaps ingest with predict batching.
//!   Jobs that must run on a specific worker — PJRT executables are pinned
//!   to the thread that compiled them — are submitted with
//!   [`WorkerPool::spawn_pinned`] and are never stolen.
//!
//! The offline image ships no rayon/tokio; both substrates are std-only —
//! and deliberately `unsafe`-free: scoped threads plus `split_at_mut` give
//! the borrow splits that would otherwise tempt raw-pointer chunking (any
//! future `unsafe` must carry a `// SAFETY:` comment; `cargo xtask lint`
//! enforces that repo-wide).

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};

use crate::check::{Audit, AuditError};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Number of worker threads the host offers (≥ 1).
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1)
}

/// Apply `f` to every item of `items` (with its index), spreading the items
/// over at most `max_threads` scoped threads, and return the results in item
/// order. Falls back to a plain sequential loop when only one thread is
/// requested or there is at most one item, so callers need no special case.
///
/// Items are split into contiguous chunks (one per thread); `f` must be
/// deterministic per item for results to be independent of the thread count,
/// which every caller in this crate relies on.
pub fn par_map_mut<T, R, F>(items: &mut [T], max_threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, &mut T) -> R + Sync,
{
    let n = items.len();
    let threads = max_threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        return items.iter_mut().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let chunk = n.div_ceil(threads);
    let mut out: Vec<Option<R>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    let fref = &f;
    std::thread::scope(|s| {
        let mut it_rest: &mut [T] = items;
        let mut out_rest: &mut [Option<R>] = &mut out;
        let mut base = 0usize;
        while !it_rest.is_empty() {
            let take = chunk.min(it_rest.len());
            let (it_chunk, it_tail) = std::mem::take(&mut it_rest).split_at_mut(take);
            let (o_chunk, o_tail) = std::mem::take(&mut out_rest).split_at_mut(take);
            it_rest = it_tail;
            out_rest = o_tail;
            let b = base;
            base += take;
            s.spawn(move || {
                for (off, (t, o)) in
                    it_chunk.iter_mut().zip(o_chunk.iter_mut()).enumerate()
                {
                    *o = Some(fref(b + off, t));
                }
            });
        }
    });
    out.into_iter().map(|o| o.expect("worker filled every slot")).collect()
}

/// A job for the persistent pool. The argument is the index of the worker
/// executing it (0-based) — affinity-sensitive callers use it to key
/// worker-local state (e.g. the coordinator's per-worker PJRT executables).
pub type Job = Box<dyn FnOnce(usize) + Send + 'static>;

/// Aggregate pool observability, surfaced through the coordinator's `stats`
/// op (`pool_*` fields) and the serving-metrics report.
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolStats {
    /// Fixed number of workers.
    pub workers: usize,
    /// Jobs sitting in queues (pinned + unpinned) right now.
    pub queued: u64,
    /// Workers currently executing a job (pool occupancy).
    pub running: u64,
    /// Jobs completed over the pool's lifetime.
    pub executed: u64,
    /// Unpinned jobs a worker took from another worker's queue.
    pub steals: u64,
    /// Jobs that panicked (caught; the worker survives).
    pub panics: u64,
}

struct Queues {
    /// Per-worker pinned jobs; only worker `i` may run `pinned[i]`.
    pinned: Vec<VecDeque<Job>>,
    /// Per-worker queues for unpinned jobs; any idle worker may steal.
    local: Vec<VecDeque<Job>>,
    /// Round-robin cursor for unpinned submission.
    next: usize,
    shutdown: bool,
}

struct PoolShared {
    q: Mutex<Queues>,
    cv: Condvar,
    /// Jobs accepted into the queues over the pool's lifetime — bumped under
    /// the queue lock so the audit's accounting identity
    /// (`enqueued == executed + queued + in-flight`) is exactly checkable.
    enqueued: AtomicU64,
    running: AtomicU64,
    executed: AtomicU64,
    steals: AtomicU64,
    panics: AtomicU64,
}

/// A persistent fixed-size worker pool with per-worker queues, work
/// stealing, worker-affinity submission and deterministic shutdown.
///
/// * Unpinned jobs are placed round-robin on the workers' local queues; an
///   idle worker first drains its own queues, then steals from its peers
///   (counted in [`PoolStats::steals`]).
/// * Pinned jobs run only on their target worker — the affinity hint the
///   coordinator uses to keep PJRT executables on the thread that compiled
///   them (the handles are not `Send`).
/// * [`WorkerPool::shutdown`] drains every queued job, then joins all
///   workers; it is idempotent and also runs on `Drop`.
/// * A panicking job is caught and counted; the worker survives. Callers
///   that share state with jobs decide their own quarantine policy (the
///   coordinator marks the model dead).
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    handles: Mutex<Vec<JoinHandle<()>>>,
    workers: usize,
}

impl WorkerPool {
    /// Spawn `workers.max(1)` named worker threads.
    pub fn new(workers: usize) -> Self {
        let workers = workers.max(1);
        let shared = Arc::new(PoolShared {
            q: Mutex::new(Queues {
                pinned: (0..workers).map(|_| VecDeque::new()).collect(),
                local: (0..workers).map(|_| VecDeque::new()).collect(),
                next: 0,
                shutdown: false,
            }),
            cv: Condvar::new(),
            enqueued: AtomicU64::new(0),
            running: AtomicU64::new(0),
            executed: AtomicU64::new(0),
            steals: AtomicU64::new(0),
            panics: AtomicU64::new(0),
        });
        let handles = (0..workers)
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("addgp-pool-{i}"))
                    .spawn(move || worker_loop(i, sh))
                    .expect("spawn pool worker")
            })
            .collect();
        WorkerPool { shared, handles: Mutex::new(handles), workers }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Submit an unpinned job (any worker may run or steal it). Returns
    /// `false` — and drops the job — if the pool is shutting down.
    pub fn spawn(&self, job: Job) -> bool {
        {
            let mut q = self.shared.q.lock().unwrap();
            if q.shutdown {
                return false;
            }
            let slot = q.next % self.workers;
            q.next = q.next.wrapping_add(1);
            q.local[slot].push_back(job);
            self.shared.enqueued.fetch_add(1, Ordering::Relaxed);
        }
        self.shared.cv.notify_all();
        true
    }

    /// Submit a job pinned to `worker % workers` (never stolen). Returns
    /// `false` — and drops the job — if the pool is shutting down.
    pub fn spawn_pinned(&self, worker: usize, job: Job) -> bool {
        let w = worker % self.workers;
        {
            let mut q = self.shared.q.lock().unwrap();
            if q.shutdown {
                return false;
            }
            q.pinned[w].push_back(job);
            self.shared.enqueued.fetch_add(1, Ordering::Relaxed);
        }
        self.shared.cv.notify_all();
        true
    }

    pub fn stats(&self) -> PoolStats {
        let queued = {
            let q = self.shared.q.lock().unwrap();
            (q.pinned.iter().map(|d| d.len()).sum::<usize>()
                + q.local.iter().map(|d| d.len()).sum::<usize>()) as u64
        };
        PoolStats {
            workers: self.workers,
            queued,
            running: self.shared.running.load(Ordering::Relaxed),
            executed: self.shared.executed.load(Ordering::Relaxed),
            steals: self.shared.steals.load(Ordering::Relaxed),
            panics: self.shared.panics.load(Ordering::Relaxed),
        }
    }

    /// Stop accepting jobs, let the workers drain everything already queued,
    /// then join them all. Returns the number of workers joined (0 on a
    /// repeat call — shutdown is idempotent).
    pub fn shutdown(&self) -> usize {
        {
            let mut q = self.shared.q.lock().unwrap();
            q.shutdown = true;
        }
        self.shared.cv.notify_all();
        let mut handles = self.handles.lock().unwrap();
        let mut joined = 0;
        for h in handles.drain(..) {
            let _ = h.join();
            joined += 1;
        }
        joined
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

impl Audit for WorkerPool {
    /// Queue accounting, checked under the queue lock so the counters are a
    /// consistent snapshot: holding the lock freezes both admissions
    /// (`enqueued` bumps) and removals (worker pops), leaving only
    /// completions racing — and those only shrink the in-flight residue.
    /// The invariants are therefore exact, not heuristics:
    ///
    /// * `executed + queued ≤ enqueued` — nothing executes or waits that was
    ///   never admitted;
    /// * `enqueued − queued − executed ≤ workers` — at most one popped-but-
    ///   uncounted job per worker;
    /// * `running ≤ workers`, and the per-worker queue vectors match the
    ///   fixed worker count.
    fn audit(&self) -> Result<(), AuditError> {
        let q = match self.shared.q.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        if q.pinned.len() != self.workers || q.local.len() != self.workers {
            return Err(AuditError::new(
                "WorkerPool",
                "q",
                None,
                format!(
                    "queue vectors (pinned {}, local {}) disagree with {} workers",
                    q.pinned.len(),
                    q.local.len(),
                    self.workers
                ),
            ));
        }
        let queued = (q.pinned.iter().map(|d| d.len()).sum::<usize>()
            + q.local.iter().map(|d| d.len()).sum::<usize>()) as u64;
        let enqueued = self.shared.enqueued.load(Ordering::Relaxed);
        let executed = self.shared.executed.load(Ordering::Relaxed);
        if executed + queued > enqueued {
            return Err(AuditError::new(
                "WorkerPool",
                "enqueued",
                None,
                format!(
                    "accounting broken: executed {executed} + queued {queued} > enqueued {enqueued}"
                ),
            ));
        }
        let in_flight = enqueued - queued - executed;
        if in_flight > self.workers as u64 {
            return Err(AuditError::new(
                "WorkerPool",
                "enqueued",
                None,
                format!(
                    "{in_flight} in-flight jobs exceed the {} workers that could hold them",
                    self.workers
                ),
            ));
        }
        let running = self.shared.running.load(Ordering::Relaxed);
        if running > self.workers as u64 {
            return Err(AuditError::new(
                "WorkerPool",
                "running",
                None,
                format!("{running} running jobs on {} workers", self.workers),
            ));
        }
        Ok(())
    }
}

fn worker_loop(me: usize, sh: Arc<PoolShared>) {
    loop {
        let job: Option<Job> = {
            let mut q = sh.q.lock().unwrap();
            loop {
                if let Some(j) = q.pinned[me].pop_front() {
                    break Some(j);
                }
                if let Some(j) = q.local[me].pop_front() {
                    break Some(j);
                }
                // Steal scan, round-robin starting after this worker.
                let n = q.local.len();
                let mut stolen = None;
                for off in 1..n {
                    let v = (me + off) % n;
                    if let Some(j) = q.local[v].pop_front() {
                        stolen = Some(j);
                        break;
                    }
                }
                if let Some(j) = stolen {
                    sh.steals.fetch_add(1, Ordering::Relaxed);
                    break Some(j);
                }
                if q.shutdown {
                    break None;
                }
                q = sh.cv.wait(q).unwrap();
            }
        };
        let Some(job) = job else { return };
        sh.running.fetch_add(1, Ordering::Relaxed);
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            // Chaos hook: lets the suite kill an arbitrary pool job inside
            // the same containment the real payload runs under.
            if let Some(act) = crate::util::fault::point!("pool.job") {
                if act == crate::util::fault::FaultAction::Panic {
                    panic!("injected fault: pool.job");
                }
            }
            job(me)
        }));
        sh.running.fetch_sub(1, Ordering::Relaxed);
        sh.executed.fetch_add(1, Ordering::Relaxed);
        if outcome.is_err() {
            sh.panics.fetch_add(1, Ordering::Relaxed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::sync::mpsc::channel;

    #[test]
    fn maps_in_order_and_mutates() {
        for threads in [1usize, 2, 3, 8] {
            let mut items: Vec<u64> = (0..13).collect();
            let out = par_map_mut(&mut items, threads, |i, v| {
                *v += 100;
                (i as u64) * 2 + *v
            });
            assert_eq!(items, (100..113).collect::<Vec<u64>>());
            let want: Vec<u64> = (0..13u64).map(|i| i * 2 + 100 + i).collect();
            assert_eq!(out, want, "threads={threads}");
        }
    }

    #[test]
    fn empty_and_single() {
        let mut none: Vec<u32> = Vec::new();
        let out = par_map_mut(&mut none, 4, |_, v| *v);
        assert!(out.is_empty());
        let mut one = vec![7u32];
        let out = par_map_mut(&mut one, 4, |i, v| (i, *v));
        assert_eq!(out, vec![(0, 7)]);
    }

    #[test]
    fn pool_runs_all_jobs_and_joins() {
        let pool = WorkerPool::new(3);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..50 {
            let c = Arc::clone(&counter);
            assert!(pool.spawn(Box::new(move |_| {
                c.fetch_add(1, Ordering::SeqCst);
            })));
        }
        let joined = pool.shutdown();
        assert_eq!(joined, 3);
        // Shutdown drains the queues before joining.
        assert_eq!(counter.load(Ordering::SeqCst), 50);
        assert_eq!(pool.stats().executed, 50);
        assert_eq!(pool.shutdown(), 0, "idempotent");
        assert!(!pool.spawn(Box::new(|_| {})), "rejects jobs after shutdown");
    }

    #[test]
    fn pinned_jobs_run_on_their_worker() {
        let pool = WorkerPool::new(4);
        let (tx, rx) = channel();
        for want in [0usize, 1, 2, 3, 2, 1] {
            let tx = tx.clone();
            assert!(pool.spawn_pinned(want, Box::new(move |me| {
                tx.send((want, me)).unwrap();
            })));
        }
        for _ in 0..6 {
            let (want, got) = rx.recv().unwrap();
            assert_eq!(want, got, "pinned job ran on the wrong worker");
        }
        pool.shutdown();
    }

    #[test]
    fn work_stealing_spreads_load() {
        // Many unpinned jobs with uneven durations: with > 1 worker some
        // must be stolen once a worker runs dry.
        let pool = WorkerPool::new(2);
        let seen: Arc<Mutex<Vec<usize>>> = Arc::new(Mutex::new(Vec::new()));
        for i in 0..40 {
            let seen = Arc::clone(&seen);
            pool.spawn(Box::new(move |me| {
                if i % 7 == 0 {
                    std::thread::sleep(std::time::Duration::from_millis(2));
                }
                seen.lock().unwrap().push(me);
            }));
        }
        pool.shutdown();
        let seen = seen.lock().unwrap();
        assert_eq!(seen.len(), 40);
        // Both workers participated (stealing or round-robin placement).
        assert!(seen.contains(&0) && seen.contains(&1));
    }

    /// The queue-accounting audit holds while jobs are in flight and after
    /// a drain-and-join shutdown.
    #[test]
    fn audit_holds_under_load_and_after_shutdown() {
        let pool = WorkerPool::new(3);
        for i in 0..60 {
            pool.spawn(Box::new(move |_| {
                if i % 9 == 0 {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                }
            }));
            if i % 10 == 0 {
                assert!(pool.audit().is_ok(), "audit mid-flight (i={i})");
            }
        }
        pool.shutdown();
        assert!(pool.audit().is_ok(), "audit after shutdown");
        assert_eq!(pool.stats().executed, 60);
    }

    /// Tampering with the admission counter breaks the accounting identity
    /// and is named as such.
    #[test]
    fn audit_flags_broken_queue_accounting() {
        let pool = WorkerPool::new(2);
        for _ in 0..10 {
            pool.spawn(Box::new(|_| {}));
        }
        pool.shutdown(); // drains: queued = 0, executed = enqueued = 10
        pool.shared.enqueued.store(3, Ordering::Relaxed); // executed > enqueued
        let e = pool.audit().unwrap_err();
        assert_eq!(e.structure, "WorkerPool");
        assert_eq!(e.field, "enqueued");
    }

    #[test]
    fn panicking_job_does_not_kill_the_worker() {
        let pool = WorkerPool::new(1);
        pool.spawn(Box::new(|_| panic!("job boom")));
        let (tx, rx) = channel();
        pool.spawn(Box::new(move |_| {
            tx.send(7u32).unwrap();
        }));
        assert_eq!(rx.recv_timeout(std::time::Duration::from_secs(10)), Ok(7));
        let stats = pool.stats();
        assert_eq!(stats.panics, 1);
        assert_eq!(pool.shutdown(), 1);
    }
}
