//! Deterministic PRNG: xoshiro256++ with a SplitMix64 seeder, plus the
//! distributions the paper's algorithms need (uniform, standard normal via
//! Box–Muller, Rademacher probes for Hutchinson trace estimation).

/// xoshiro256++ pseudo-random generator (Blackman & Vigna).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    cached_normal: Option<f64>,
}

impl Rng {
    /// Seed deterministically via SplitMix64.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()], cached_normal: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> [0, 1).
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)`.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        (self.uniform() * n as f64) as usize % n.max(1)
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.cached_normal.take() {
            return z;
        }
        let u1 = loop {
            let u = self.uniform();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.cached_normal = Some(r * s);
        r * c
    }

    /// ±1 with equal probability (Hutchinson probe entries, Algorithm 6/7).
    #[inline]
    pub fn rademacher(&mut self) -> f64 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Vector of Rademacher ±1.
    pub fn rademacher_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.rademacher()).collect()
    }

    /// Vector of uniforms in `[lo, hi)`.
    pub fn uniform_vec(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.uniform_in(lo, hi)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Rng::new(7);
        let n = 20000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50000;
        let (mut m1, mut m2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            m1 += z;
            m2 += z * z;
        }
        m1 /= n as f64;
        m2 /= n as f64;
        assert!(m1.abs() < 0.02, "mean {m1}");
        assert!((m2 - 1.0).abs() < 0.03, "var {m2}");
    }

    #[test]
    fn rademacher_is_pm1() {
        let mut r = Rng::new(3);
        let mut pos = 0;
        for _ in 0..1000 {
            let v = r.rademacher();
            assert!(v == 1.0 || v == -1.0);
            if v > 0.0 {
                pos += 1;
            }
        }
        assert!((400..600).contains(&pos));
    }
}
