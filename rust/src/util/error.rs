//! Minimal `anyhow`-compatible error handling (the offline image ships no
//! crates, so the few ergonomics the runtime/server layers need are vendored
//! here): a string-backed [`Error`], a defaulted [`Result`], the [`anyhow!`]
//! and [`ensure!`] macros, and a [`Context`] trait with
//! `context`/`with_context`.
//!
//! Deliberately *not* implemented: downcasting, backtraces and error chains —
//! nothing in this crate needs them, and keeping [`Error`] free of a
//! `std::error::Error` impl is what allows the blanket `From<E>` conversion
//! (the same trick `anyhow` itself uses).

use std::fmt;

/// A boxed, display-only error value.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg(m: impl fmt::Display) -> Self {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// `Result` defaulted to [`Error`], as in `anyhow`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)+) => {
        $crate::util::error::Error::msg(format!($($arg)+))
    };
}

/// Early-return an `Err` when the condition fails.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::util::error::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::util::error::Error::msg(format!($($arg)+)));
        }
    };
}

/// Attach context to a failing `Result`, `anyhow`-style.
pub trait Context<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T>;
    fn with_context<D: fmt::Display>(self, f: impl FnOnce() -> D) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{msg}: {e}")))
    }

    fn with_context<D: fmt::Display>(self, f: impl FnOnce() -> D) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        Err(anyhow!("bad {}", 42))
    }

    fn guarded(v: i32) -> Result<i32> {
        ensure!(v > 0, "v must be positive, got {v}");
        Ok(v)
    }

    #[test]
    fn macros_and_context() {
        assert_eq!(fails().unwrap_err().to_string(), "bad 42");
        assert!(guarded(1).is_ok());
        assert_eq!(
            guarded(-1).unwrap_err().to_string(),
            "v must be positive, got -1"
        );
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.with_context(|| "while formatting").unwrap_err();
        assert!(e.to_string().starts_with("while formatting: "));
    }

    #[test]
    fn from_std_error() {
        let io = std::io::Error::new(std::io::ErrorKind::Other, "boom");
        let e: Error = io.into();
        assert!(e.to_string().contains("boom"));
    }
}
