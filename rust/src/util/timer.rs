//! Tiny timing/benchmark helpers (criterion is unavailable offline).
//! `cargo bench` targets use [`bench`] directly from their `main()`.

use std::time::Instant;

/// Time a closure once, returning `(result, seconds)`.
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed().as_secs_f64())
}

/// Benchmark statistics over repeated runs.
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl BenchStats {
    /// Criterion-like one-line report.
    pub fn report(&self) -> String {
        format!(
            "{:<44} time: [{} {} {}]  ({} iters)",
            self.name,
            fmt_time(self.min_s),
            fmt_time(self.median_s),
            fmt_time(self.max_s),
            self.iters
        )
    }
}

/// Human-readable duration.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Run `f` with warmup, then measure `iters` runs and report stats.
/// A `std::hint::black_box` is applied to the closure result.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchStats {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let stats = BenchStats {
        name: name.to_string(),
        iters,
        mean_s: mean,
        median_s: times[times.len() / 2],
        min_s: times[0],
        max_s: *times.last().unwrap(),
    };
    println!("{}", stats.report());
    stats
}

/// Least-squares slope of `log(y)` against `log(x)` — used by the scaling
/// study to verify the paper's empirical complexity exponents.
pub fn loglog_slope(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    let lx: Vec<f64> = xs.iter().map(|x| x.ln()).collect();
    let ly: Vec<f64> = ys.iter().map(|y| y.ln()).collect();
    let mx = lx.iter().sum::<f64>() / n;
    let my = ly.iter().sum::<f64>() / n;
    let cov: f64 = lx.iter().zip(&ly).map(|(x, y)| (x - mx) * (y - my)).sum();
    let var: f64 = lx.iter().map(|x| (x - mx) * (x - mx)).sum();
    cov / var
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slope_of_quadratic_is_two() {
        let xs = vec![10.0, 20.0, 40.0, 80.0];
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 * x * x).collect();
        assert!((loglog_slope(&xs, &ys) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn bench_runs() {
        let s = bench("noop", 1, 5, || 1 + 1);
        assert_eq!(s.iters, 5);
        assert!(s.min_s <= s.median_s && s.median_s <= s.max_s);
    }
}
