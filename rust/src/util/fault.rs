//! Seeded fault injection for the chaos suite (`tests/chaos.rs`).
//!
//! A *fault plan* is a set of [`Rule`]s armed by a test: "on the Nth hit of
//! injection point `journal.append`, return [`FaultAction::IoError`]".
//! Production code marks its injectable sites with
//! [`point!`](crate::util::fault::point) — a macro that expands to a plan
//! lookup when the `fault-inject` feature is on, and to a literal `None`
//! when it is off, so release builds carry no branch, no atomic, and no
//! plan state on any hot path.
//!
//! Every site name must be registered in [`POINTS`]; `cargo xtask lint`
//! cross-checks the call sites against this inventory in both directions
//! (an unregistered site and a stale inventory entry both fail the gate)
//! and bans calling [`check`] directly, so the feature gate cannot be
//! bypassed by accident.
//!
//! The plan is process-global (the sites it serves are reached from pool
//! workers, reader threads and the test thread alike), so tests that arm it
//! must serialize on a lock of their own — see `chaos.rs`'s `fault_lock()`.

use std::collections::HashMap;
use std::sync::{Mutex, OnceLock};

/// Inventory of every fault-injection point compiled into the crate, in
/// dispatch order (engine → journal → solver → substrate). `cargo xtask
/// lint` fails if a `fault::point!` site uses a name missing here or if an
/// entry here has no remaining call site.
pub const POINTS: &[&str] = &[
    "engine.mutate",
    "journal.append",
    "journal.fsync",
    "journal.checkpoint",
    "lu.factor",
    "pcg.converge",
    "pool.job",
    "snapshot.encode",
];

/// What an armed rule makes the injection point do.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultAction {
    /// Panic at the site (exercises quarantine + journal resurrection).
    Panic,
    /// Surface an I/O error from the site (journal degradation paths).
    IoError,
    /// Write only the first `n` bytes of the record, then fail — a torn
    /// tail, as left by a crash mid-`write`.
    TornWrite(usize),
    /// Report the operation as failed without side effects (e.g. force the
    /// PCG convergence check to read "did not converge").
    ForceFail,
}

/// One armed fault: fire `action` on the `nth` hit (1-based) of `point`
/// since [`arm`]; `nth == 0` fires on every hit.
#[derive(Clone, Copy, Debug)]
pub struct Rule {
    pub point: &'static str,
    pub nth: u64,
    pub action: FaultAction,
}

struct Plan {
    rules: Vec<Rule>,
    /// Hits per point since the last [`arm`] — the counter the `nth`
    /// trigger is measured against.
    hits: HashMap<&'static str, u64>,
}

fn plan() -> &'static Mutex<Plan> {
    static PLAN: OnceLock<Mutex<Plan>> = OnceLock::new();
    PLAN.get_or_init(|| Mutex::new(Plan { rules: Vec::new(), hits: HashMap::new() }))
}

fn plan_lock() -> std::sync::MutexGuard<'static, Plan> {
    match plan().lock() {
        Ok(g) => g,
        // A panic *while armed* is the expected outcome of a Panic rule;
        // the plan itself is only mutated under short straight-line
        // sections, so the poisoned state is intact.
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// Install a fault plan, resetting all hit counters. Replaces any plan
/// already armed.
pub fn arm(rules: &[Rule]) {
    for r in rules {
        assert!(
            POINTS.contains(&r.point),
            "fault rule targets unregistered point '{}'",
            r.point
        );
    }
    let mut p = plan_lock();
    p.rules = rules.to_vec();
    p.hits.clear();
}

/// Remove every armed rule (hit counters are kept until the next [`arm`]).
pub fn disarm() {
    plan_lock().rules.clear();
}

/// Number of times `point` has been hit since the last [`arm`].
pub fn hits(point: &str) -> u64 {
    *plan_lock().hits.get(point).unwrap_or(&0)
}

/// Record a hit of `point` and return the action to inject, if any rule
/// matches. Call through [`point!`](crate::util::fault::point), never
/// directly — the macro is what the `fault-inject` feature gates out.
pub fn check(point: &'static str) -> Option<FaultAction> {
    debug_assert!(POINTS.contains(&point), "unregistered fault point '{point}'");
    let mut p = plan_lock();
    if p.rules.is_empty() {
        // Fast path for armed-capable but idle builds (the chaos suite
        // between tests): count nothing, fire nothing.
        return None;
    }
    let n = p.hits.entry(point).or_insert(0);
    *n += 1;
    let n = *n;
    p.rules
        .iter()
        .find(|r| r.point == point && (r.nth == 0 || r.nth == n))
        .map(|r| r.action)
}

/// The injection-point marker. Expands to [`check`]`(name)` under the
/// `fault-inject` feature and to a constant `None` otherwise, so release
/// builds compile every site to nothing.
#[cfg(feature = "fault-inject")]
#[macro_export]
macro_rules! fault_point {
    ($name:literal) => {
        $crate::util::fault::check($name)
    };
}

/// The injection-point marker (fault injection compiled out).
#[cfg(not(feature = "fault-inject"))]
#[macro_export]
macro_rules! fault_point {
    ($name:literal) => {{
        None::<$crate::util::fault::FaultAction>
    }};
}

pub use crate::fault_point as point;

#[cfg(test)]
mod tests {
    use super::*;

    // The plan is process-global; these tests mutate it and so must not
    // interleave. cargo runs tests in threads — serialize on a local lock.
    fn serial() -> std::sync::MutexGuard<'static, ()> {
        static LOCK: Mutex<()> = Mutex::new(());
        match LOCK.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        }
    }

    #[test]
    fn nth_rule_fires_exactly_once() {
        let _g = serial();
        arm(&[Rule { point: "journal.append", nth: 3, action: FaultAction::IoError }]);
        assert_eq!(check("journal.append"), None);
        assert_eq!(check("journal.append"), None);
        assert_eq!(check("journal.append"), Some(FaultAction::IoError));
        assert_eq!(check("journal.append"), None, "nth is exact, not >=");
        assert_eq!(hits("journal.append"), 4);
        disarm();
    }

    #[test]
    fn every_hit_rule_and_disarm() {
        let _g = serial();
        arm(&[Rule { point: "pool.job", nth: 0, action: FaultAction::Panic }]);
        assert_eq!(check("pool.job"), Some(FaultAction::Panic));
        assert_eq!(check("pool.job"), Some(FaultAction::Panic));
        disarm();
        assert_eq!(check("pool.job"), None);
    }

    #[test]
    fn points_are_independent_and_rearm_resets() {
        let _g = serial();
        arm(&[Rule { point: "lu.factor", nth: 1, action: FaultAction::ForceFail }]);
        assert_eq!(check("pcg.converge"), None, "other points unaffected");
        assert_eq!(check("lu.factor"), Some(FaultAction::ForceFail));
        arm(&[Rule { point: "lu.factor", nth: 1, action: FaultAction::ForceFail }]);
        assert_eq!(check("lu.factor"), Some(FaultAction::ForceFail), "counters reset on arm");
        disarm();
    }

    #[test]
    #[should_panic(expected = "unregistered point")]
    fn arming_an_unknown_point_is_a_test_bug() {
        // No serial(): arm panics before touching rules used by others.
        arm(&[Rule { point: "no.such.point", nth: 1, action: FaultAction::Panic }]);
    }

    #[test]
    fn macro_matches_feature_gate() {
        let _g = serial();
        disarm();
        let got: Option<FaultAction> = crate::util::fault::point!("engine.mutate");
        assert_eq!(got, None, "idle plan injects nothing in either build");
    }
}
