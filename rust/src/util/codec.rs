//! Minimal binary codec for the durability layer (`coordinator/journal.rs`
//! and the checkpoint serializer in `gp::persist`).
//!
//! The offline image ships no serde, so records are hand-framed: fixed-width
//! little-endian integers, `f64` shipped as raw IEEE-754 bits
//! (`f64::to_bits`) so a decode → encode round trip is the identity on every
//! value including `-0.0`, NaN payloads and subnormals — the property the
//! crash-recovery bit-identity argument (DESIGN.md §Durability) rests on —
//! and a table-driven CRC-32 (IEEE/zlib polynomial) for frame checksums.
//!
//! [`ByteReader`] is panic-free: every read is bounds-checked and returns
//! `Err` on truncation, so a torn journal tail can never take the decoder
//! down.

/// Append-only byte sink with fixed-width little-endian encoders.
#[derive(Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> Self {
        ByteWriter { buf: Vec::new() }
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// `usize` travels as `u64` so the format is identical across hosts.
    pub fn put_usize(&mut self, v: usize) {
        self.put_u64(v as u64);
    }

    pub fn put_bool(&mut self, v: bool) {
        self.put_u8(v as u8);
    }

    /// Raw IEEE bits — bit-exact round trip, no formatting/parsing.
    pub fn put_f64(&mut self, v: f64) {
        self.put_u64(v.to_bits());
    }

    pub fn put_f64s(&mut self, vs: &[f64]) {
        self.put_usize(vs.len());
        for &v in vs {
            self.put_f64(v);
        }
    }

    pub fn put_usizes(&mut self, vs: &[usize]) {
        self.put_usize(vs.len());
        for &v in vs {
            self.put_usize(v);
        }
    }

    pub fn put_bytes(&mut self, vs: &[u8]) {
        self.put_usize(vs.len());
        self.buf.extend_from_slice(vs);
    }
}

/// Bounds-checked reader over an encoded byte slice. Errors name the field
/// being decoded so a corrupt checkpoint is diagnosable.
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        ByteReader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn is_done(&self) -> bool {
        self.pos == self.buf.len()
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8], String> {
        if self.remaining() < n {
            return Err(format!(
                "truncated while decoding {what}: need {n} bytes, have {}",
                self.remaining()
            ));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn get_u8(&mut self, what: &str) -> Result<u8, String> {
        Ok(self.take(1, what)?[0])
    }

    pub fn get_u32(&mut self, what: &str) -> Result<u32, String> {
        let b = self.take(4, what)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn get_u64(&mut self, what: &str) -> Result<u64, String> {
        let b = self.take(8, what)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(b);
        Ok(u64::from_le_bytes(a))
    }

    pub fn get_usize(&mut self, what: &str) -> Result<usize, String> {
        let v = self.get_u64(what)?;
        usize::try_from(v).map_err(|_| format!("{what} {v} overflows usize"))
    }

    pub fn get_bool(&mut self, what: &str) -> Result<bool, String> {
        match self.get_u8(what)? {
            0 => Ok(false),
            1 => Ok(true),
            v => Err(format!("{what}: invalid bool byte {v}")),
        }
    }

    pub fn get_f64(&mut self, what: &str) -> Result<f64, String> {
        Ok(f64::from_bits(self.get_u64(what)?))
    }

    /// Length-prefixed `f64` vector. The length is sanity-checked against
    /// the bytes actually remaining, so a corrupt prefix cannot trigger a
    /// huge allocation.
    pub fn get_f64s(&mut self, what: &str) -> Result<Vec<f64>, String> {
        let n = self.get_usize(what)?;
        if n > self.remaining() / 8 {
            return Err(format!("{what}: claimed length {n} exceeds remaining bytes"));
        }
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.get_f64(what)?);
        }
        Ok(v)
    }

    pub fn get_usizes(&mut self, what: &str) -> Result<Vec<usize>, String> {
        let n = self.get_usize(what)?;
        if n > self.remaining() / 8 {
            return Err(format!("{what}: claimed length {n} exceeds remaining bytes"));
        }
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.get_usize(what)?);
        }
        Ok(v)
    }

    pub fn get_bytes(&mut self, what: &str) -> Result<&'a [u8], String> {
        let n = self.get_usize(what)?;
        if n > self.remaining() {
            return Err(format!("{what}: claimed length {n} exceeds remaining bytes"));
        }
        self.take(n, what)
    }
}

/// CRC-32 (IEEE 802.3 / zlib polynomial, reflected), table-driven.
pub fn crc32(data: &[u8]) -> u32 {
    static TABLE: std::sync::OnceLock<[u32; 256]> = std::sync::OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    });
    let mut c = !0u32;
    for &b in data {
        c = table[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_primitives() {
        let mut w = ByteWriter::new();
        w.put_u8(0xAB);
        w.put_u32(0xDEAD_BEEF);
        w.put_u64(u64::MAX - 7);
        w.put_usize(123_456);
        w.put_bool(true);
        w.put_bool(false);
        w.put_f64(-0.0);
        w.put_f64(f64::from_bits(0x7FF8_0000_0000_1234)); // NaN w/ payload
        w.put_f64s(&[1.5, f64::MIN_POSITIVE, -3.25e300]);
        w.put_usizes(&[0, 9, 42]);
        w.put_bytes(b"tail");
        let bytes = w.into_bytes();

        let mut r = ByteReader::new(&bytes);
        assert_eq!(r.get_u8("a").unwrap(), 0xAB);
        assert_eq!(r.get_u32("b").unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.get_u64("c").unwrap(), u64::MAX - 7);
        assert_eq!(r.get_usize("d").unwrap(), 123_456);
        assert!(r.get_bool("e").unwrap());
        assert!(!r.get_bool("f").unwrap());
        let z = r.get_f64("g").unwrap();
        assert_eq!(z.to_bits(), (-0.0f64).to_bits(), "signed zero preserved");
        assert_eq!(r.get_f64("h").unwrap().to_bits(), 0x7FF8_0000_0000_1234, "NaN bits preserved");
        assert_eq!(r.get_f64s("i").unwrap(), vec![1.5, f64::MIN_POSITIVE, -3.25e300]);
        assert_eq!(r.get_usizes("j").unwrap(), vec![0, 9, 42]);
        assert_eq!(r.get_bytes("k").unwrap(), b"tail");
        assert!(r.is_done());
    }

    #[test]
    fn truncation_errors_not_panics() {
        let mut w = ByteWriter::new();
        w.put_f64s(&[1.0, 2.0, 3.0]);
        let bytes = w.into_bytes();
        for cut in 0..bytes.len() {
            let mut r = ByteReader::new(&bytes[..cut]);
            assert!(r.get_f64s("v").is_err(), "cut at {cut} must error");
        }
        // Absurd claimed length: rejected before allocating.
        let mut w = ByteWriter::new();
        w.put_u64(u64::MAX);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        assert!(r.get_f64s("v").unwrap_err().contains("exceeds remaining"));
    }

    #[test]
    fn crc32_known_vectors() {
        // Standard check values for the IEEE polynomial.
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn crc32_detects_bit_flips() {
        let data = b"journal record payload";
        let base = crc32(data);
        let mut copy = data.to_vec();
        for byte in 0..copy.len() {
            for bit in 0..8 {
                copy[byte] ^= 1 << bit;
                assert_ne!(crc32(&copy), base, "flip at {byte}:{bit} undetected");
                copy[byte] ^= 1 << bit;
            }
        }
    }
}
