//! MLE of the Matérn scale hyperparameters — §5.1 "Training".
//!
//! Minimizes the NLL by Adam on `θ_d = log ω_d` (positivity by
//! reparameterization), with the stochastic gradient of eq. (15). Each step
//! rebuilds the per-dimension factorizations (`O(Dn)`) and computes the
//! gradient in `O(Q·Dn)` — the paper's `O(n log n)` per-iteration claim.

use crate::gp::dim::DimFactor;
use crate::gp::likelihood::{nll_grad, StochasticCfg};
use crate::kernels::matern::{Matern, Nu};

/// Options for the hyperparameter optimizer.
#[derive(Clone, Copy, Debug)]
pub struct TrainCfg {
    pub steps: usize,
    pub lr: f64,
    /// Tie all dimensions to one shared ω (the paper's experimental setup).
    pub shared_omega: bool,
    /// Adam moments.
    pub beta1: f64,
    pub beta2: f64,
    /// Clamp on log-ω to keep factorizations well-posed.
    pub log_omega_min: f64,
    pub log_omega_max: f64,
}

impl Default for TrainCfg {
    fn default() -> Self {
        TrainCfg {
            steps: 30,
            lr: 0.1,
            shared_omega: true,
            beta1: 0.9,
            beta2: 0.999,
            log_omega_min: -9.0,
            log_omega_max: 6.0,
        }
    }
}

/// One record of the optimization trajectory.
#[derive(Clone, Debug)]
pub struct TrainStep {
    pub step: usize,
    pub omegas: Vec<f64>,
    pub grad_norm: f64,
}

/// Run Adam on `log ω` and return the trajectory. `x_cols` is the per-dim
/// column view of the data; the factorizations are rebuilt each step and the
/// final ones are returned.
pub fn optimize_omegas(
    x_cols: &[Vec<f64>],
    y: &[f64],
    nu: Nu,
    omegas0: &[f64],
    sigma2_y: f64,
    cfg: &TrainCfg,
    scfg: &StochasticCfg,
) -> (Vec<f64>, Vec<DimFactor>, Vec<TrainStep>) {
    let dd = x_cols.len();
    let mut theta: Vec<f64> = omegas0.iter().map(|o| o.ln()).collect();
    let mut m = vec![0.0; dd];
    let mut v = vec![0.0; dd];
    let mut history = Vec::with_capacity(cfg.steps);
    let mut scfg_step = *scfg;

    let build = |theta: &[f64]| -> Vec<DimFactor> {
        x_cols
            .iter()
            .zip(theta)
            .map(|(col, &t)| DimFactor::new(col, Matern::new(nu, t.exp()), sigma2_y))
            .collect()
    };

    let mut dims = build(&theta);
    for step in 0..cfg.steps {
        // Fresh probe seed each step keeps the stochastic gradient unbiased
        // across the trajectory.
        scfg_step.seed = scfg.seed.wrapping_add(step as u64 * 0x9E37);
        let g = nll_grad(&mut dims, sigma2_y, y, &scfg_step);
        // Chain rule: ∂/∂θ = ω · ∂/∂ω.
        let mut gtheta: Vec<f64> = (0..dd).map(|d| g.omega[d] * theta[d].exp()).collect();
        if cfg.shared_omega {
            let mean = gtheta.iter().sum::<f64>() / dd as f64;
            gtheta = vec![mean; dd];
        }
        let gnorm = gtheta.iter().map(|x| x * x).sum::<f64>().sqrt();
        for d in 0..dd {
            m[d] = cfg.beta1 * m[d] + (1.0 - cfg.beta1) * gtheta[d];
            v[d] = cfg.beta2 * v[d] + (1.0 - cfg.beta2) * gtheta[d] * gtheta[d];
            let mh = m[d] / (1.0 - cfg.beta1.powi(step as i32 + 1));
            let vh = v[d] / (1.0 - cfg.beta2.powi(step as i32 + 1));
            theta[d] = (theta[d] - cfg.lr * mh / (vh.sqrt() + 1e-8))
                .clamp(cfg.log_omega_min, cfg.log_omega_max);
        }
        if cfg.shared_omega {
            let t0 = theta[0];
            theta.iter_mut().for_each(|t| *t = t0);
        }
        dims = build(&theta);
        history.push(TrainStep {
            step,
            omegas: theta.iter().map(|t| t.exp()).collect(),
            grad_norm: gnorm,
        });
    }
    let omegas: Vec<f64> = theta.iter().map(|t| t.exp()).collect();
    (omegas, dims, history)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::likelihood::nll_exact;
    use crate::util::Rng;

    /// Training must reduce the exact NLL from a deliberately bad start.
    #[test]
    fn training_improves_nll() {
        let n = 40;
        let dd = 2;
        let sigma2 = 0.25;
        let mut rng = Rng::new(11);
        let x_cols: Vec<Vec<f64>> = (0..dd).map(|_| rng.uniform_vec(n, 0.0, 6.0)).collect();
        // Data generated from a smooth additive function → ω ≈ O(1) optimal.
        let y: Vec<f64> = (0..n)
            .map(|i| {
                (x_cols[0][i]).sin() + 0.6 * (1.3 * x_cols[1][i]).cos() + 0.3 * rng.normal()
            })
            .collect();
        let nu = Nu::Half;
        let omega_bad = vec![30.0, 30.0]; // far too rough
        let dims0: Vec<DimFactor> = x_cols
            .iter()
            .map(|c| DimFactor::new(c, Matern::new(nu, 30.0), sigma2))
            .collect();
        let nll0 = nll_exact(&dims0, sigma2, &y);

        let tcfg = TrainCfg { steps: 40, lr: 0.15, ..Default::default() };
        let scfg = StochasticCfg { trace_probes: 64, ..Default::default() };
        let (omegas, dims, hist) =
            optimize_omegas(&x_cols, &y, nu, &omega_bad, sigma2, &tcfg, &scfg);
        let nll1 = nll_exact(&dims, sigma2, &y);
        assert!(
            nll1 < nll0 - 1.0,
            "training did not improve NLL: {nll0} -> {nll1} (ω = {omegas:?})"
        );
        assert!(omegas[0] < 25.0, "ω should move off the bad start: {omegas:?}");
        assert_eq!(hist.len(), 40);
    }

    /// Shared-ω mode keeps all dimensions tied.
    #[test]
    fn shared_omega_stays_shared() {
        let n = 30;
        let mut rng = Rng::new(12);
        let x_cols: Vec<Vec<f64>> = (0..3).map(|_| rng.uniform_vec(n, 0.0, 4.0)).collect();
        let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let tcfg = TrainCfg { steps: 5, ..Default::default() };
        let scfg = StochasticCfg { trace_probes: 8, ..Default::default() };
        let (omegas, _, _) =
            optimize_omegas(&x_cols, &y, Nu::Half, &[1.0, 1.0, 1.0], 1.0, &tcfg, &scfg);
        assert!((omegas[0] - omegas[1]).abs() < 1e-12);
        assert!((omegas[0] - omegas[2]).abs() < 1e-12);
    }
}
