//! The incremental fit state — the crate's core trained-model object
//! (DESIGN.md §FitState).
//!
//! [`FitState`] owns everything a trained additive GP carries between
//! observations: the per-dimension [`DimFactor`] factorizations, the
//! posterior `b` vectors of eq. (12), and the last Algorithm 4 solution ṽ.
//! Its defining operations are [`FitState::observe`], which absorbs one new
//! data point *without* refitting, and [`FitState::observe_batch`], which
//! absorbs `m` points for one sweep/splice/solve each and shards the
//! per-dimension work across a scoped thread pool. Per observation:
//!
//! * each dimension patches its KP factorization in place —
//!   `O(log n)` position search, `O(2ν+1)` packet re-solves, one band-storage
//!   splice, and a prefix-reuse banded-LU patch per factor (`O(ν³)`
//!   arithmetic for append-ordered inserts; full `O(ν²n)` re-sweeps only
//!   when the patch preconditions fail — [`DimFactor::insert_point`],
//!   DESIGN.md §FitState "Sublinear LU patching");
//! * the stored ṽ is extended by one entry and reused as the PCG warm start
//!   for the next posterior solve, which then converges in a handful of
//!   iterations instead of a cold Algorithm 4 run;
//! * degenerate insertions (duplicate clusters that defeat the coordinate
//!   nudge) fall back to a full [`DimFactor::new`] rebuild of that dimension
//!   only — exactness is never traded away.
//!
//! Everything the state computes is *exact* relative to a from-scratch
//! refit (to solver tolerance): the packet windows outside the insertion
//! neighborhood are bit-identical, and warm starts change iteration counts,
//! not fixed points. The equivalence is enforced by
//! `tests/incremental.rs` against both a full refit and the dense
//! `baselines::full_gp` oracle.

use std::sync::{Arc, Mutex};

use crate::check::{enforce, Audit, AuditError};
use crate::gp::backfit::{BlockVec, GaussSeidel, GsStats};
use crate::gp::dim::{DimFactor, PatchTimings};
use crate::gp::posterior::{self, MTildeCache, Posterior, PredictOut};
use crate::kernels::matern::Matern;
use crate::linalg::banded::PatchPolicy;
use crate::linalg::StorageStats;
use crate::util::pool;

/// Result of one [`FitState::observe_batch`].
pub struct BatchPositions {
    /// `positions[d][t]` = final sorted position of batch point `t` in
    /// dimension `d`. Empty for a dimension that went through the
    /// sequential-replay fallback (its intermediate rebuilds make per-point
    /// final positions meaningless — callers must invalidate coarsely).
    pub positions: Vec<Vec<usize>>,
    /// Whether any dimension fell back to the sequential replay.
    pub fallback: bool,
}

/// One state mutation — the single vocabulary every ingest/forget path
/// speaks (DESIGN.md §FitState, "Downdates & rolling windows"). All
/// mutation plumbing flows through [`FitState::apply`]; `observe`,
/// `observe_batch`, `forget` and `forget_batch` are thin wrappers over
/// these variants, so layers above (model, BO engine, coordinator) never
/// touch per-dimension insert/remove machinery directly — the xtask
/// `mutation plumbing` lint enforces exactly that.
///
/// Data-order contract (mirrors the old `observe` contract):
/// * insertions — the caller has already **pushed** the new rows onto
///   `x_cols`;
/// * removals — the caller has already **compacted** `x_cols` (and its `y`),
///   and `index`/`indices` are *pre-removal* data-order indices.
#[derive(Clone, Copy, Debug)]
pub enum Mutation<'a> {
    /// Absorb one observation; `x` is the new point's coordinates.
    Insert { x: &'a [f64] },
    /// Absorb `m` observations in one sweep/splice/solve per dimension.
    InsertBatch { xs: &'a [Vec<f64>] },
    /// Release the observation at data-order `index`.
    Remove { index: usize },
    /// Release the observations at strictly increasing data-order `indices`.
    RemoveBatch { indices: &'a [usize] },
}

/// What a [`FitState::apply`] did, in cache-invalidation vocabulary.
pub struct MutationOutcome {
    /// `positions[d][t]` = sorted position of mutated point `t` in dimension
    /// `d` — *final post-insert* positions for insertions, *pre-removal*
    /// positions for removals (exactly what [`MTildeCache::on_insert_batch`]
    /// / [`MTildeCache::on_remove_batch`] consume). Empty for a dimension
    /// that went through a fallback rebuild mid-batch.
    pub positions: Vec<Vec<usize>>,
    /// Whether any dimension fell back to a full rebuild; callers must then
    /// invalidate caches coarsely.
    pub fallback: bool,
}

/// Trained per-dimension factorizations + updatable posterior vectors.
pub struct FitState {
    dims: Vec<DimFactor>,
    post: Option<Arc<Posterior>>,
    /// Last Algorithm 4 solution ṽ (data order) — the next solve's warm
    /// start.
    tilde: Option<BlockVec>,
    pub sigma2_y: f64,
    pub gs_max_sweeps: usize,
    pub gs_tol: f64,
    /// Observations absorbed through the incremental path.
    pub incremental_inserts: u64,
    /// Observations released through the incremental downdate path.
    pub incremental_removes: u64,
    /// Per-dimension full rebuilds forced by degenerate mutations.
    pub fallback_rebuilds: u64,
    /// How inserts update the banded LU factors (DESIGN.md §FitState,
    /// "Sublinear LU patching"); applied to every dimension, including
    /// fallback rebuilds.
    patch_policy: PatchPolicy,
    /// Cumulative count of band-storage chunks handed to snapshots by
    /// reference (Arc bump) rather than deep copy.
    snapshot_chunks_shared: u64,
}

impl FitState {
    /// Wrap freshly-built factorizations (posterior computed lazily).
    pub fn new(
        dims: Vec<DimFactor>,
        sigma2_y: f64,
        gs_max_sweeps: usize,
        gs_tol: f64,
    ) -> Self {
        assert!(!dims.is_empty(), "FitState needs at least one dimension");
        FitState {
            dims,
            post: None,
            tilde: None,
            sigma2_y,
            gs_max_sweeps,
            gs_tol,
            incremental_inserts: 0,
            incremental_removes: 0,
            fallback_rebuilds: 0,
            patch_policy: PatchPolicy::Exact,
            snapshot_chunks_shared: 0,
        }
    }

    /// Set the factor-patching policy on this state and every dimension
    /// (future fallback rebuilds inherit it too).
    pub fn set_patch_policy(&mut self, policy: PatchPolicy) {
        self.patch_policy = policy;
        for dim in &mut self.dims {
            dim.patch_policy = policy;
        }
    }

    /// The active factor-patching policy.
    pub fn patch_policy(&self) -> PatchPolicy {
        self.patch_policy
    }

    /// LU updates served by the prefix-reuse patch, summed over dimensions
    /// (up to 4 per dimension per insert — one per factor).
    pub fn factor_patches(&self) -> u64 {
        self.dims.iter().map(|d| d.factor_patches).sum()
    }

    /// LU updates that fell back to the full `O(ν²n)` re-sweep, summed over
    /// dimensions.
    pub fn factor_resweeps(&self) -> u64 {
        self.dims.iter().map(|d| d.factor_resweeps).sum()
    }

    /// Accumulated KP-patch vs factor-update wall-clock split, summed over
    /// dimensions.
    pub fn patch_timings(&self) -> PatchTimings {
        let mut out = PatchTimings::default();
        for d in &self.dims {
            out.accumulate(&d.timings);
        }
        out
    }

    /// Replace `dim` with a from-scratch rebuild over `pts` (the degenerate
    /// duplicate-cluster fallback), carrying the policy and the cumulative
    /// patch counters/timings across so the per-state totals stay monotone.
    fn rebuild_dim(dim: &mut DimFactor, pts: &[f64], sigma2_y: f64) {
        let kern: Matern = *dim.kernel();
        let mut fresh = DimFactor::new(pts, kern, sigma2_y);
        fresh.patch_policy = dim.patch_policy;
        fresh.factor_patches = dim.factor_patches;
        fresh.factor_resweeps = dim.factor_resweeps;
        fresh.timings = dim.timings;
        *dim = fresh;
    }

    pub fn n(&self) -> usize {
        self.dims[0].n()
    }

    pub fn input_dim(&self) -> usize {
        self.dims.len()
    }

    pub fn dims(&self) -> &[DimFactor] {
        &self.dims
    }

    pub fn dims_mut(&mut self) -> &mut [DimFactor] {
        &mut self.dims
    }

    /// The posterior, if [`FitState::ensure_posterior`] has run since the
    /// last observation.
    pub fn posterior(&self) -> Option<&Posterior> {
        self.post.as_deref()
    }

    /// Split borrow for the cached-predict path: mutable factorizations
    /// (lazy GKP / band-of-inverse builds) alongside the posterior.
    /// Panics if the posterior has not been ensured.
    pub fn parts_mut(&mut self) -> (&mut [DimFactor], &Posterior) {
        (
            &mut self.dims,
            self.post.as_deref().expect("ensure_posterior() before parts_mut()"),
        )
    }

    /// Apply one [`Mutation`] — the **sole** entry point for changing the
    /// trained state's point set. Inserts absorb observations
    /// incrementally (KP patch + prefix-reuse LU patch + warm-start growth);
    /// removals run the exact mirror downdate ([`DimFactor::remove_point`] /
    /// [`DimFactor::remove_points`]), shrinking the stored ṽ at the removed
    /// data indices so `observe(x)` followed by `forget` of that point is
    /// bit-identical (under [`PatchPolicy::Exact`]) to never observing it.
    ///
    /// The posterior is invalidated in every case; removals panic if they
    /// would drop `n` below the packet minimum `2w + 1` (callers deactivate
    /// the incremental state instead — see `AdditiveGP::forget`).
    pub fn apply(&mut self, mutation: Mutation<'_>, x_cols: &[Vec<f64>]) -> MutationOutcome {
        assert_eq!(x_cols.len(), self.dims.len());
        let out = match mutation {
            Mutation::Insert { x } => self.insert_one(x, x_cols),
            Mutation::InsertBatch { xs } => self.insert_many(xs, x_cols),
            Mutation::Remove { index } => self.remove_one(index, x_cols),
            Mutation::RemoveBatch { indices } => self.remove_many(indices, x_cols),
        };
        self.post = None;
        enforce(self, "FitState::apply");
        out
    }

    /// Absorb one observation (already appended to `x_cols` in data order)
    /// incrementally. Returns each dimension's sorted insertion position —
    /// the cache layer needs them for windowed invalidation.
    ///
    /// Thin wrapper over [`FitState::apply`] with [`Mutation::Insert`]; the
    /// posterior is invalidated (recomputed warm on next
    /// [`FitState::ensure_posterior`]), the stored ṽ survives, extended by a
    /// zero entry for the new point.
    pub fn observe(&mut self, x: &[f64], x_cols: &[Vec<f64>]) -> Vec<usize> {
        let out = self.apply(Mutation::Insert { x }, x_cols);
        out.positions.iter().map(|p| p[0]).collect()
    }

    /// Absorb a whole batch of observations (already appended to `x_cols`
    /// in data order) incrementally, sharding the per-dimension work across
    /// a scoped thread pool (DESIGN.md §FitState, "Batched inserts &
    /// dimension sharding"). Thin wrapper over [`FitState::apply`] with
    /// [`Mutation::InsertBatch`].
    ///
    /// Per dimension the batch costs **one** band splice, **one**
    /// union-of-windows KP re-solve, **one** prefix-reuse LU patch per factor
    /// ([`DimFactor::insert_points`]) — instead of `m` of each — and the
    /// posterior is invalidated once, so the next
    /// [`FitState::ensure_posterior`] runs a single warm PCG solve for the
    /// whole batch. A dimension whose batch hits a degenerate duplicate
    /// cluster replays the exact sequential [`FitState::observe`] semantics
    /// for itself (per-point insert, full [`DimFactor::new`] rebuild on
    /// failure), so batch and sequential ingest stay bit-identical at the
    /// factor level in every case.
    pub fn observe_batch(
        &mut self,
        xs: &[Vec<f64>],
        x_cols: &[Vec<f64>],
    ) -> BatchPositions {
        let out = self.apply(Mutation::InsertBatch { xs }, x_cols);
        BatchPositions { positions: out.positions, fallback: out.fallback }
    }

    /// Release the observation at data-order `index` (`x_cols` already
    /// compacted) — the sliding-window downdate. Returns each dimension's
    /// *pre-removal* sorted position, the cache layer's windowed-invalidation
    /// vocabulary ([`MTildeCache::on_remove`]). Thin wrapper over
    /// [`FitState::apply`] with [`Mutation::Remove`].
    pub fn forget(&mut self, index: usize, x_cols: &[Vec<f64>]) -> Vec<usize> {
        let out = self.apply(Mutation::Remove { index }, x_cols);
        out.positions.iter().map(|p| p[0]).collect()
    }

    /// Release a whole batch of observations at strictly increasing
    /// data-order `indices` (`x_cols` already compacted), one union-window
    /// downdate per dimension. Thin wrapper over [`FitState::apply`] with
    /// [`Mutation::RemoveBatch`]; positions in the result are *pre-removal*
    /// sorted positions in batch order.
    pub fn forget_batch(
        &mut self,
        indices: &[usize],
        x_cols: &[Vec<f64>],
    ) -> BatchPositions {
        let out = self.apply(Mutation::RemoveBatch { indices }, x_cols);
        BatchPositions { positions: out.positions, fallback: out.fallback }
    }

    fn insert_one(&mut self, x: &[f64], x_cols: &[Vec<f64>]) -> MutationOutcome {
        let dd = self.dims.len();
        assert_eq!(x.len(), dd);
        let n_new = self.n() + 1;
        assert_eq!(x_cols[0].len(), n_new, "push the new point before observe()");
        let mut positions = Vec::with_capacity(dd);
        let mut fallback = false;
        for d in 0..dd {
            let pos = match self.dims[d].insert_point(x[d]) {
                Some(pos) => {
                    self.incremental_inserts += 1;
                    pos
                }
                None => {
                    // Degenerate cluster: rebuild this dimension with the
                    // full nudge cascade (identical to the refit path).
                    self.fallback_rebuilds += 1;
                    fallback = true;
                    Self::rebuild_dim(&mut self.dims[d], &x_cols[d], self.sigma2_y);
                    self.dims[d].kp.perm.sorted_pos(n_new - 1)
                }
            };
            positions.push(vec![pos]);
        }
        if let Some(t) = self.tilde.as_mut() {
            for td in t.iter_mut() {
                td.push(0.0);
            }
        }
        MutationOutcome { positions, fallback }
    }

    fn insert_many(&mut self, xs: &[Vec<f64>], x_cols: &[Vec<f64>]) -> MutationOutcome {
        let dd = self.dims.len();
        let m = xs.len();
        if m == 0 {
            return MutationOutcome { positions: vec![Vec::new(); dd], fallback: false };
        }
        let n0 = self.n();
        assert_eq!(
            x_cols[0].len(),
            n0 + m,
            "push the batch before observe_batch()"
        );
        for x in xs {
            assert_eq!(x.len(), dd);
        }
        // Column-major batch values, one independent job per dimension.
        let vals: Vec<Vec<f64>> =
            (0..dd).map(|d| xs.iter().map(|x| x[d]).collect()).collect();
        let sigma2 = self.sigma2_y;

        struct DimOutcome {
            positions: Vec<usize>,
            fallback: bool,
            inserts: u64,
            rebuilds: u64,
        }
        let threads = pool::default_threads().min(dd);
        let outcomes: Vec<DimOutcome> =
            pool::par_map_mut(&mut self.dims, threads, |d, dim| {
                match dim.insert_points(&vals[d]) {
                    Some(positions) => DimOutcome {
                        positions,
                        fallback: false,
                        inserts: m as u64,
                        rebuilds: 0,
                    },
                    None => {
                        // Degenerate batch: replay the sequential-observe
                        // semantics for this dimension only, including the
                        // mid-stream full rebuilds.
                        let mut inserts = 0u64;
                        let mut rebuilds = 0u64;
                        for (t, &v) in vals[d].iter().enumerate() {
                            match dim.insert_point(v) {
                                Some(_) => inserts += 1,
                                None => {
                                    rebuilds += 1;
                                    Self::rebuild_dim(dim, &x_cols[d][..n0 + t + 1], sigma2);
                                }
                            }
                        }
                        DimOutcome {
                            positions: Vec::new(),
                            fallback: true,
                            inserts,
                            rebuilds,
                        }
                    }
                }
            });

        let mut positions = Vec::with_capacity(dd);
        let mut fallback = false;
        for o in outcomes {
            self.incremental_inserts += o.inserts;
            self.fallback_rebuilds += o.rebuilds;
            fallback |= o.fallback;
            positions.push(o.positions);
        }
        if let Some(t) = self.tilde.as_mut() {
            for td in t.iter_mut() {
                td.extend(std::iter::repeat(0.0).take(m));
            }
        }
        MutationOutcome { positions, fallback }
    }

    fn remove_one(&mut self, index: usize, x_cols: &[Vec<f64>]) -> MutationOutcome {
        let dd = self.dims.len();
        let n_old = self.n();
        assert!(index < n_old, "forget index {index} out of range (n = {n_old})");
        assert_eq!(x_cols[0].len(), n_old - 1, "compact the data before forget()");
        self.assert_above_packet_minimum(n_old - 1);
        let mut positions = Vec::with_capacity(dd);
        let mut fallback = false;
        for d in 0..dd {
            let pos = self.dims[d].kp.perm.sorted_pos(index);
            match self.dims[d].remove_point(pos) {
                Some(orig) => {
                    debug_assert_eq!(orig, index);
                    self.incremental_removes += 1;
                }
                None => {
                    // Degenerate dimension: rebuild from the compacted data
                    // (identical to the refit path).
                    self.fallback_rebuilds += 1;
                    fallback = true;
                    Self::rebuild_dim(&mut self.dims[d], &x_cols[d], self.sigma2_y);
                }
            }
            positions.push(vec![pos]);
        }
        if let Some(t) = self.tilde.as_mut() {
            for td in t.iter_mut() {
                td.remove(index);
            }
        }
        MutationOutcome { positions, fallback }
    }

    fn remove_many(&mut self, indices: &[usize], x_cols: &[Vec<f64>]) -> MutationOutcome {
        let dd = self.dims.len();
        let m = indices.len();
        if m == 0 {
            return MutationOutcome { positions: vec![Vec::new(); dd], fallback: false };
        }
        let n_old = self.n();
        assert!(
            indices.windows(2).all(|p| p[0] < p[1]),
            "forget_batch indices must be strictly increasing"
        );
        assert!(indices[m - 1] < n_old, "forget index out of range (n = {n_old})");
        assert_eq!(x_cols[0].len(), n_old - m, "compact the data before forget_batch()");
        self.assert_above_packet_minimum(n_old - m);
        // Per-dim pre-removal sorted positions: batch order for the outcome,
        // ascending for the per-dimension union-window downdate.
        let batch_pos: Vec<Vec<usize>> = (0..dd)
            .map(|d| indices.iter().map(|&i| self.dims[d].kp.perm.sorted_pos(i)).collect())
            .collect();
        let sorted_pos: Vec<Vec<usize>> = batch_pos
            .iter()
            .map(|p| {
                // lint: cow-ok (Vec<usize> of batch positions, not band storage)
                let mut q = p.clone();
                q.sort_unstable();
                q
            })
            .collect();
        let sigma2 = self.sigma2_y;

        struct DimOutcome {
            fallback: bool,
            removes: u64,
            rebuilds: u64,
        }
        let threads = pool::default_threads().min(dd);
        let outcomes: Vec<DimOutcome> =
            pool::par_map_mut(&mut self.dims, threads, |d, dim| {
                match dim.remove_points(&sorted_pos[d]) {
                    Some(origs) => {
                        debug_assert_eq!(
                            {
                                let mut o = origs;
                                o.sort_unstable();
                                o
                            },
                            indices
                        );
                        DimOutcome { fallback: false, removes: m as u64, rebuilds: 0 }
                    }
                    None => {
                        // Degenerate dimension: rebuild from the compacted
                        // data (identical to the refit path).
                        Self::rebuild_dim(dim, &x_cols[d], sigma2);
                        DimOutcome { fallback: true, removes: 0, rebuilds: 1 }
                    }
                }
            });

        let mut positions = Vec::with_capacity(dd);
        let mut fallback = false;
        for (d, o) in outcomes.into_iter().enumerate() {
            self.incremental_removes += o.removes;
            self.fallback_rebuilds += o.rebuilds;
            fallback |= o.fallback;
            // lint: cow-ok (Vec<usize> of batch positions, not band storage)
            positions.push(if o.fallback { Vec::new() } else { batch_pos[d].clone() });
        }
        if let Some(t) = self.tilde.as_mut() {
            for td in t.iter_mut() {
                for &i in indices.iter().rev() {
                    td.remove(i);
                }
            }
        }
        MutationOutcome { positions, fallback }
    }

    /// Removals must leave every dimension at or above its KP packet
    /// minimum `2w + 1`; callers that want to shrink further deactivate the
    /// incremental state instead of forgetting through it.
    fn assert_above_packet_minimum(&self, n_new: usize) {
        for dim in &self.dims {
            assert!(
                n_new >= 2 * dim.kp.w() + 1,
                "forget would shrink n below the packet minimum {} (deactivate instead)",
                2 * dim.kp.w() + 1
            );
        }
    }

    /// Ensure the posterior (`b` vectors) exists — one warm-started
    /// Algorithm 4 solve when observations arrived since the last call.
    pub fn ensure_posterior(&mut self, y: &[f64]) {
        if self.post.is_some() {
            return;
        }
        assert_eq!(y.len(), self.n());
        let guess = self.tilde.take();
        let gs = self.solver();
        let (post, tilde) =
            posterior::compute_posterior_warm(&self.dims, y, &gs, guess.as_ref());
        self.post = Some(Arc::new(post));
        self.tilde = Some(tilde);
        enforce(self, "FitState::ensure_posterior");
    }

    /// Build an immutable, shareable [`PosteriorSnapshot`] for the
    /// coordinator's concurrent read path (DESIGN.md §Coordinator,
    /// "Snapshot semantics").
    ///
    /// Deliberately **non-perturbing**: when the posterior is stale the
    /// solve runs *warm from the stored ṽ but is not written back*, so a
    /// read arriving at any point between two mutations observes exactly
    /// the state the mutation stream produced and leaves the engine's
    /// numeric trajectory bit-identical to a read-free replay — the
    /// property the multi-model determinism stress test pins. The lazy
    /// band-of-inverse *is* materialized on `self` (it is a pure function
    /// of the factors, so building it early changes nothing downstream).
    ///
    /// The build itself is a **reference bump**: every band rope is settled
    /// (`mark_storage_clean`) so the `dims` clone below Arc-shares all of
    /// its chunks, and the posterior travels as a shared `Arc`. Chunks the
    /// engine dirties after this call are deep-copied on first write, so a
    /// snapshot generation costs O(dirtied chunks), not O(Dnν).
    pub fn read_snapshot(&mut self, y: &[f64], cache_capacity: usize) -> PosteriorSnapshot {
        for dim in self.dims.iter_mut() {
            let _ = dim.c_band();
        }
        let post = match &self.post {
            Some(p) => Arc::clone(p),
            None => {
                assert_eq!(y.len(), self.n());
                let gs = self.solver();
                let (post, _tilde) =
                    posterior::compute_posterior_warm(&self.dims, y, &gs, self.tilde.as_ref());
                Arc::new(post)
            }
        };
        let mut shared = 0u64;
        for dim in self.dims.iter_mut() {
            let (_dirtied, total) = dim.mark_storage_clean();
            shared += total;
        }
        self.snapshot_chunks_shared += shared;
        PosteriorSnapshot {
            // lint: cow-ok (reference-bump clone: chunks settled above)
            dims: self.dims.clone(),
            post,
            sigma2_y: self.sigma2_y,
            cache_capacity,
            cache: Mutex::new(MTildeCache::new(cache_capacity)),
        }
    }

    /// Cumulative band-storage counters, summed over dimensions:
    /// `(memmove_bytes, chunks_copied, chunks_shared)` — bytes shifted by
    /// mid-matrix splices, chunks deep-copied by copy-on-write, and chunks
    /// handed to snapshots by reference.
    pub fn storage_stats(&self) -> (u64, u64, u64) {
        let mut s = StorageStats::default();
        for d in &self.dims {
            s.accumulate(d.storage_stats());
        }
        (s.memmove_bytes, s.chunks_copied, self.snapshot_chunks_shared)
    }

    /// Stats of the last posterior solve, if one has run.
    pub fn gs_stats(&self) -> Option<GsStats> {
        self.post.as_ref().map(|p| p.gs_stats)
    }

    /// A solver borrowing the current factorizations, with this state's
    /// iteration controls.
    pub fn solver(&self) -> GaussSeidel<'_> {
        let mut gs = GaussSeidel::new(&self.dims, self.sigma2_y);
        gs.max_sweeps = self.gs_max_sweeps;
        gs.tol = self.gs_tol;
        gs
    }

    /// The stored warm-start ṽ, if any — checkpoint serialization surface.
    /// Both ṽ and the posterior must travel through checkpoints: whether a
    /// posterior is present decides if the next
    /// [`FitState::ensure_posterior`] solves at all, and ṽ seeds that
    /// solve, so dropping either would fork the recovered engine's numeric
    /// trajectory from the live one.
    pub fn tilde(&self) -> Option<&BlockVec> {
        self.tilde.as_ref()
    }

    /// Reassemble a trained state from checkpoint-decoded parts (journal
    /// recovery).
    #[allow(clippy::too_many_arguments)]
    pub fn from_parts(
        dims: Vec<DimFactor>,
        post: Option<Posterior>,
        tilde: Option<BlockVec>,
        sigma2_y: f64,
        gs_max_sweeps: usize,
        gs_tol: f64,
        patch_policy: PatchPolicy,
        counters: (u64, u64, u64, u64),
    ) -> Self {
        assert!(!dims.is_empty(), "FitState needs at least one dimension");
        let (incremental_inserts, incremental_removes, fallback_rebuilds, snapshot_chunks_shared) =
            counters;
        FitState {
            dims,
            post: post.map(Arc::new),
            tilde,
            sigma2_y,
            gs_max_sweeps,
            gs_tol,
            incremental_inserts,
            incremental_removes,
            fallback_rebuilds,
            patch_policy,
            snapshot_chunks_shared,
        }
    }

    /// Drop the stored posterior *and* warm start, then re-solve cold — the
    /// second rung of the non-convergence escalation ladder
    /// (`AdditiveGP::ensure_posterior`): a warm start that steered PCG into
    /// stagnation is discarded rather than reused.
    pub fn resolve_cold(&mut self, y: &[f64]) {
        self.post = None;
        self.tilde = None;
        self.ensure_posterior(y);
    }
}

impl Audit for FitState {
    /// Cross-dimension agreement: every dimension holds the same `n` and the
    /// same noise variance as the state, and the two carried solve artifacts
    /// (the warm-start ṽ and the posterior `b`) have exactly `D` blocks of
    /// length `n`. Child [`DimFactor`] audits run first so a deeper break is
    /// pinpointed at its own structure.
    fn audit(&self) -> Result<(), AuditError> {
        if self.dims.is_empty() {
            return Err(AuditError::new(
                "FitState",
                "dims",
                None,
                "no dimensions".to_string(),
            ));
        }
        let n = self.dims[0].n();
        for (d, dim) in self.dims.iter().enumerate() {
            dim.audit()?;
            if dim.n() != n {
                return Err(AuditError::new(
                    "FitState",
                    "dims",
                    Some(d),
                    format!("dimension holds n = {} but dimension 0 holds {n}", dim.n()),
                ));
            }
            if dim.sigma2_y != self.sigma2_y {
                return Err(AuditError::new(
                    "FitState",
                    "dims",
                    Some(d),
                    format!(
                        "dimension noise {} desynced from state noise {}",
                        dim.sigma2_y, self.sigma2_y
                    ),
                ));
            }
        }
        if let Some(t) = &self.tilde {
            if t.len() != self.dims.len() {
                return Err(AuditError::new(
                    "FitState",
                    "tilde",
                    None,
                    format!("ṽ has {} blocks for {} dimensions", t.len(), self.dims.len()),
                ));
            }
            for (d, td) in t.iter().enumerate() {
                if td.len() != n {
                    return Err(AuditError::new(
                        "FitState",
                        "tilde",
                        Some(d),
                        format!("ṽ block length {} != n = {n}", td.len()),
                    ));
                }
            }
        }
        if let Some(p) = &self.post {
            if p.b.len() != self.dims.len() {
                return Err(AuditError::new(
                    "FitState",
                    "post",
                    None,
                    format!("posterior has {} blocks for {} dimensions", p.b.len(), self.dims.len()),
                ));
            }
            for (d, bd) in p.b.iter().enumerate() {
                if bd.len() != n {
                    return Err(AuditError::new(
                        "FitState",
                        "post",
                        Some(d),
                        format!("posterior block length {} != n = {n}", bd.len()),
                    ));
                }
            }
        }
        Ok(())
    }
}

/// An immutable, shareable view of a trained model — everything the
/// concurrent read path (`predict`/`suggest` in the coordinator's shared
/// worker pool) needs, decoupled from the mutable [`FitState`]:
///
/// * cloned per-dimension factorizations with the band-of-inverse already
///   materialized, so prediction is pure `&`-access
///   ([`posterior::predict_prebuilt`]);
/// * the posterior `b` vectors as of the snapshot's generation;
/// * its own `M̃` column cache behind a [`Mutex`] (columns warm up across
///   the reads that share this snapshot; the engine's cache is untouched).
///
/// Readers on different models never contend; readers on one model contend
/// only on the column-cache mutex, never with ingest. A fresh snapshot is
/// built per mutation generation; since band chunks are copy-on-write
/// ropes and the posterior travels as an `Arc`, that per-write cost is a
/// reference bump plus deep copies of only the chunks dirtied since the
/// previous generation.
pub struct PosteriorSnapshot {
    dims: Vec<DimFactor>,
    post: Arc<Posterior>,
    sigma2_y: f64,
    cache_capacity: usize,
    cache: Mutex<MTildeCache>,
}

impl PosteriorSnapshot {
    pub fn n(&self) -> usize {
        self.dims[0].n()
    }

    pub fn input_dim(&self) -> usize {
        self.dims.len()
    }

    /// Posterior mean/variance (and gradients) at `x` through the shared
    /// snapshot cache — the coordinator's native `predict` read path.
    pub fn predict(&self, x: &[f64], want_grad: bool) -> PredictOut {
        let mut cache = match self.cache.lock() {
            Ok(g) => g,
            // A reader that panicked mid-insert left the cache usable
            // (worst case: a missing column recomputed later).
            Err(poisoned) => poisoned.into_inner(),
        };
        posterior::predict_prebuilt(&self.dims, self.sigma2_y, &self.post, &mut cache, x, want_grad)
    }

    /// [`PosteriorSnapshot::predict`] through a caller-owned cache — the
    /// `suggest` path gives each gradient-ascent search its own cache so a
    /// long search never blocks concurrent predicts on the shared one.
    pub fn predict_with_cache(
        &self,
        cache: &mut MTildeCache,
        x: &[f64],
        want_grad: bool,
    ) -> PredictOut {
        posterior::predict_prebuilt(&self.dims, self.sigma2_y, &self.post, cache, x, want_grad)
    }

    /// An empty cache with this snapshot's configured capacity.
    pub fn fresh_cache(&self) -> MTildeCache {
        MTildeCache::new(self.cache_capacity)
    }

    /// `(hits, misses)` of the shared snapshot cache.
    pub fn cache_stats(&self) -> (u64, u64) {
        let cache = match self.cache.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        (cache.hits, cache.misses)
    }

    /// Reassemble a snapshot from decoded parts — the replica import path
    /// (`gp/persist.rs::decode_snapshot`). The caller is responsible for
    /// materializing each dimension's band-of-inverse before serving; run
    /// the [`Audit`] to prove it (the replica always does).
    pub fn from_parts(
        dims: Vec<DimFactor>,
        post: Posterior,
        sigma2_y: f64,
        cache_capacity: usize,
    ) -> Self {
        PosteriorSnapshot {
            dims,
            post: Arc::new(post),
            sigma2_y,
            cache_capacity,
            cache: Mutex::new(MTildeCache::new(cache_capacity)),
        }
    }

    /// The cloned per-dimension factorizations — snapshot export surface.
    pub fn dims(&self) -> &[DimFactor] {
        &self.dims
    }

    /// The posterior `b` vectors at this snapshot's generation.
    pub fn posterior(&self) -> &Posterior {
        &self.post
    }

    /// The snapshot's noise variance.
    pub fn sigma2_y(&self) -> f64 {
        self.sigma2_y
    }

    /// Configured capacity of the shared `M̃` column cache.
    pub fn cache_capacity(&self) -> usize {
        self.cache_capacity
    }
}

impl Audit for PosteriorSnapshot {
    /// The snapshot's construction guarantees beyond [`FitState`]'s: every
    /// cloned dimension must have its band-of-inverse **already
    /// materialized** (the `&`-only predict path panics otherwise), the
    /// posterior blocks must match the snapshot's `n`, and every key in the
    /// shared column cache must reference a live `(dimension, sorted index)`
    /// pair — the cache-key vs `n` agreement check.
    fn audit(&self) -> Result<(), AuditError> {
        if self.dims.is_empty() {
            return Err(AuditError::new(
                "PosteriorSnapshot",
                "dims",
                None,
                "no dimensions".to_string(),
            ));
        }
        let n = self.dims[0].n();
        for (d, dim) in self.dims.iter().enumerate() {
            dim.audit()?;
            if dim.n() != n {
                return Err(AuditError::new(
                    "PosteriorSnapshot",
                    "dims",
                    Some(d),
                    format!("dimension holds n = {} but dimension 0 holds {n}", dim.n()),
                ));
            }
            if !dim.has_c_band() {
                return Err(AuditError::new(
                    "PosteriorSnapshot",
                    "dims",
                    Some(d),
                    "band-of-inverse not materialized (predict would panic)".to_string(),
                ));
            }
        }
        if self.post.b.len() != self.dims.len() {
            return Err(AuditError::new(
                "PosteriorSnapshot",
                "post",
                None,
                format!(
                    "posterior has {} blocks for {} dimensions",
                    self.post.b.len(),
                    self.dims.len()
                ),
            ));
        }
        for (d, bd) in self.post.b.iter().enumerate() {
            if bd.len() != n {
                return Err(AuditError::new(
                    "PosteriorSnapshot",
                    "post",
                    Some(d),
                    format!("posterior block length {} != n = {n}", bd.len()),
                ));
            }
        }
        let cache = match self.cache.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        cache.audit_with(self.dims.len(), n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::matern::{Matern, Nu};
    use crate::util::Rng;
    use std::sync::Arc;

    fn build_state(
        x_cols: &[Vec<f64>],
        nu: Nu,
        omega: f64,
        sigma2: f64,
    ) -> FitState {
        let dims: Vec<DimFactor> = x_cols
            .iter()
            .map(|col| DimFactor::new(col, Matern::new(nu, omega), sigma2))
            .collect();
        FitState::new(dims, sigma2, 200, 1e-10)
    }

    /// Incremental observes + warm posterior equal a cold posterior on
    /// freshly-built factorizations over the same data.
    #[test]
    fn warm_posterior_matches_cold_rebuild() {
        let mut rng = Rng::new(41);
        let sigma2 = 0.8;
        let mut x_cols: Vec<Vec<f64>> =
            (0..2).map(|_| rng.uniform_vec(30, 0.0, 5.0)).collect();
        let mut y: Vec<f64> =
            (0..30).map(|i| x_cols[0][i].sin() + x_cols[1][i].cos()).collect();

        let mut state = build_state(&x_cols, Nu::ThreeHalves, 1.0, sigma2);
        state.ensure_posterior(&y);

        for step in 0..6 {
            let x = vec![
                rng.uniform_in(-0.5, 5.5),
                rng.uniform_in(-0.5, 5.5),
            ];
            for (d, &v) in x.iter().enumerate() {
                x_cols[d].push(v);
            }
            y.push(x[0].sin() + x[1].cos() + 0.01 * rng.normal());
            let positions = state.observe(&x, &x_cols);
            assert_eq!(positions.len(), 2);
            state.ensure_posterior(&y);

            let cold = build_state(&x_cols, Nu::ThreeHalves, 1.0, sigma2);
            let gs = cold.solver();
            let cold_post = posterior::compute_posterior(cold.dims(), &y, &gs);
            let warm_post = state.posterior().unwrap();
            for d in 0..2 {
                let scale = cold_post.b[d]
                    .iter()
                    .fold(0.0f64, |m, &v| m.max(v.abs()))
                    .max(1.0);
                for i in 0..y.len() {
                    assert!(
                        (warm_post.b[d][i] - cold_post.b[d][i]).abs() < 1e-6 * scale,
                        "step {step} d={d} i={i}: {} vs {}",
                        warm_post.b[d][i],
                        cold_post.b[d][i]
                    );
                }
            }
        }
        assert_eq!(state.incremental_inserts, 12);
        assert_eq!(state.fallback_rebuilds, 0);
    }

    /// One `observe_batch` produces the same factors and (warm) posterior
    /// as the equivalent sequence of `observe` calls.
    #[test]
    fn observe_batch_matches_sequential_observes() {
        let mut rng = Rng::new(71);
        let sigma2 = 0.9;
        let mut x_cols: Vec<Vec<f64>> =
            (0..3).map(|_| rng.uniform_vec(28, 0.0, 5.0)).collect();
        let mut y: Vec<f64> = (0..28)
            .map(|i| x_cols[0][i].sin() + x_cols[1][i].cos() + 0.1 * x_cols[2][i])
            .collect();
        let mut batched = build_state(&x_cols, Nu::ThreeHalves, 1.0, sigma2);
        let mut seq = build_state(&x_cols, Nu::ThreeHalves, 1.0, sigma2);
        batched.ensure_posterior(&y);
        seq.ensure_posterior(&y);

        let m = 7;
        let batch: Vec<Vec<f64>> = (0..m)
            .map(|_| (0..3).map(|_| rng.uniform_in(-0.5, 5.5)).collect::<Vec<f64>>())
            .collect();
        // The batched state sees all points at once; the sequential state's
        // column view must grow point by point (the `observe` contract).
        let mut x_cols_seq = x_cols.clone();
        for x in &batch {
            for (d, &v) in x.iter().enumerate() {
                x_cols[d].push(v);
            }
            y.push(x[0].sin() + x[1].cos() + 0.1 * x[2]);
        }
        let out = batched.observe_batch(&batch, &x_cols);
        assert!(!out.fallback);
        assert_eq!(out.positions.len(), 3);
        for x in &batch {
            for (d, &v) in x.iter().enumerate() {
                x_cols_seq[d].push(v);
            }
            let _ = seq.observe(x, &x_cols_seq);
        }
        assert_eq!(batched.incremental_inserts, seq.incremental_inserts);
        assert_eq!(batched.fallback_rebuilds, 0);

        // Factors bit-identical across the two ingest orders.
        for d in 0..3 {
            let (bd, sd) = (&batched.dims[d], &seq.dims[d]);
            assert_eq!(bd.n(), sd.n());
            for i in 0..bd.n() {
                assert_eq!(bd.kp.xs[i], sd.kp.xs[i], "d={d} xs[{i}]");
                assert_eq!(bd.kp.perm.orig(i), sd.kp.perm.orig(i), "d={d} perm[{i}]");
                let (lo, hi) = bd.kp.a.row_range(i);
                for j in lo..hi {
                    assert_eq!(bd.kp.a.get(i, j), sd.kp.a.get(i, j), "d={d} A[{i},{j}]");
                }
            }
        }

        // Posteriors agree to solver tolerance.
        batched.ensure_posterior(&y);
        seq.ensure_posterior(&y);
        let (bp, sp) = (batched.posterior().unwrap(), seq.posterior().unwrap());
        for d in 0..3 {
            let scale = sp.b[d]
                .iter()
                .fold(0.0f64, |mx, &v| mx.max(v.abs()))
                .max(1.0);
            for i in 0..y.len() {
                assert!(
                    (bp.b[d][i] - sp.b[d][i]).abs() < 1e-8 * scale,
                    "d={d} i={i}: {} vs {}",
                    bp.b[d][i],
                    sp.b[d][i]
                );
            }
        }
    }

    /// Desyncing the carried warm-start ṽ from the model size is pinpointed
    /// at the offending block.
    #[test]
    fn audit_flags_desynced_tilde_block() {
        let mut rng = Rng::new(81);
        let x_cols: Vec<Vec<f64>> = (0..2).map(|_| rng.uniform_vec(20, 0.0, 5.0)).collect();
        let y: Vec<f64> = (0..20).map(|i| x_cols[0][i].sin()).collect();
        let mut state = build_state(&x_cols, Nu::Half, 1.0, 1.0);
        state.ensure_posterior(&y);
        assert!(state.audit().is_ok());
        state.tilde.as_mut().unwrap()[1].pop(); // block 1 now one entry short
        let e = state.audit().unwrap_err();
        assert_eq!(e.structure, "FitState");
        assert_eq!(e.field, "tilde");
        assert_eq!(e.index, Some(1));
    }

    /// A snapshot audit verifies the prebuilt band-of-inverse guarantee and
    /// the cache-key/n agreement.
    #[test]
    fn snapshot_audit_checks_construction_guarantees() {
        let mut rng = Rng::new(82);
        let x_cols: Vec<Vec<f64>> = (0..2).map(|_| rng.uniform_vec(22, 0.0, 5.0)).collect();
        let y: Vec<f64> = (0..22).map(|i| x_cols[0][i].cos()).collect();
        let mut state = build_state(&x_cols, Nu::ThreeHalves, 1.0, 0.9);
        state.ensure_posterior(&y);
        let mut snap = state.read_snapshot(&y, 0);
        assert!(snap.audit().is_ok());
        let _ = snap.predict(&[2.0, 2.5], false);
        assert!(snap.audit().is_ok(), "a served predict must keep the cache consistent");
        Arc::make_mut(&mut snap.post).b[0].push(0.0); // posterior block desynced from n
        let e = snap.audit().unwrap_err();
        assert_eq!(e.structure, "PosteriorSnapshot");
        assert_eq!(e.field, "post");
        assert_eq!(e.index, Some(0));
    }

    fn drop_rows(cols: &[Vec<f64>], gone: &[usize]) -> Vec<Vec<f64>> {
        cols.iter()
            .map(|c| {
                c.iter()
                    .enumerate()
                    .filter(|(i, _)| !gone.contains(i))
                    .map(|(_, &v)| v)
                    .collect()
            })
            .collect()
    }

    /// The tentpole property at the state level: `observe(x)` followed by
    /// `forget` of that point is **bit-identical** to never observing it —
    /// factors, carried warm-start ṽ, and the next posterior solve all
    /// restore exactly (default `PatchPolicy::Exact`).
    #[test]
    fn observe_then_forget_is_bit_identical_to_never_observing() {
        let mut rng = Rng::new(91);
        let sigma2 = 0.8;
        let x_cols: Vec<Vec<f64>> =
            (0..2).map(|_| rng.uniform_vec(26, 0.0, 5.0)).collect();
        let y: Vec<f64> =
            (0..26).map(|i| x_cols[0][i].sin() + x_cols[1][i].cos()).collect();
        let mut state = build_state(&x_cols, Nu::ThreeHalves, 1.0, sigma2);
        state.ensure_posterior(&y);
        let mut control = build_state(&x_cols, Nu::ThreeHalves, 1.0, sigma2);
        control.ensure_posterior(&y);

        // Round trip: push → observe → compact → forget.
        let x = vec![2.31, 1.07];
        let mut grown = x_cols.clone();
        for (d, &v) in x.iter().enumerate() {
            grown[d].push(v);
        }
        let _ = state.observe(&x, &grown);
        let removed_pos = state.forget(26, &x_cols);
        assert_eq!(removed_pos.len(), 2);
        assert_eq!(state.n(), 26);
        assert_eq!(state.incremental_removes, 2);

        // Factor level: every maintained band and LU bitwise equal.
        for d in 0..2 {
            let (sd, cd) = (&state.dims[d], &control.dims[d]);
            assert_eq!(sd.kp.xs, cd.kp.xs, "d={d} xs");
            assert_eq!(sd.kp.a.to_flat(), cd.kp.a.to_flat(), "d={d} A");
            assert_eq!(sd.kp.phi.to_flat(), cd.kp.phi.to_flat(), "d={d} Φ");
            assert_eq!(sd.t.to_flat(), cd.t.to_flat(), "d={d} T");
            assert_eq!(
                sd.t_lu.fac_band().to_flat(),
                cd.t_lu.fac_band().to_flat(),
                "d={d} T LU"
            );
            assert_eq!(
                sd.phit_lu.fac_band().to_flat(),
                cd.phit_lu.fac_band().to_flat(),
                "d={d} Φᵀ LU"
            );
        }
        // The carried warm start is restored exactly (the pushed zero left
        // with the forgotten point), so the next posterior solve runs the
        // identical warm PCG trajectory.
        assert_eq!(state.tilde, control.tilde);
        state.ensure_posterior(&y);
        control.post = None;
        control.ensure_posterior(&y);
        let (sp, cp) = (state.posterior().unwrap(), control.posterior().unwrap());
        for d in 0..2 {
            assert_eq!(sp.b[d], cp.b[d], "d={d} posterior b");
        }
    }

    /// One `forget_batch` equals the corresponding descending sequence of
    /// single `forget` calls bit-for-bit (factors and warm start).
    #[test]
    fn forget_batch_matches_sequential_forgets() {
        let mut rng = Rng::new(93);
        let sigma2 = 0.9;
        let x_cols: Vec<Vec<f64>> =
            (0..2).map(|_| rng.uniform_vec(30, 0.0, 5.0)).collect();
        let y: Vec<f64> = (0..30).map(|i| x_cols[0][i].cos()).collect();
        let mut batched = build_state(&x_cols, Nu::ThreeHalves, 1.0, sigma2);
        let mut seq = build_state(&x_cols, Nu::ThreeHalves, 1.0, sigma2);
        batched.ensure_posterior(&y);
        seq.ensure_posterior(&y);

        let indices = [3usize, 11, 12, 29];
        let compacted = drop_rows(&x_cols, &indices);
        let out = batched.forget_batch(&indices, &compacted);
        assert!(!out.fallback);
        assert_eq!(out.positions.len(), 2);
        assert_eq!(out.positions[0].len(), indices.len());
        // Descending singles keep earlier data indices valid.
        let mut gone: Vec<usize> = Vec::new();
        for &i in indices.iter().rev() {
            gone.push(i);
            let cols = drop_rows(&x_cols, &gone);
            let _ = seq.forget(i, &cols);
        }
        assert_eq!(batched.n(), seq.n());
        assert_eq!(batched.incremental_removes, seq.incremental_removes);
        assert_eq!(batched.tilde, seq.tilde);
        for d in 0..2 {
            let (bd, sd) = (&batched.dims[d], &seq.dims[d]);
            assert_eq!(bd.kp.xs, sd.kp.xs, "d={d} xs");
            assert_eq!(bd.t.to_flat(), sd.t.to_flat(), "d={d} T");
            assert_eq!(
                bd.t_lu.fac_band().to_flat(),
                sd.t_lu.fac_band().to_flat(),
                "d={d} T LU"
            );
        }
    }

    /// Duplicate-heavy streams route through the per-dimension rebuild
    /// fallback without corrupting the state.
    #[test]
    fn degenerate_duplicates_fall_back() {
        let mut rng = Rng::new(42);
        let base: Vec<f64> = (0..12).map(|i| i as f64 * 0.25).collect();
        let mut x_cols = vec![base.clone(), base.clone()];
        let mut y: Vec<f64> = (0..12).map(|_| rng.normal()).collect();
        let mut state = build_state(&x_cols, Nu::Half, 1.0, 1.0);
        state.ensure_posterior(&y);
        // Hammer one coordinate value repeatedly.
        for _ in 0..5 {
            let x = vec![1.0, 1.0];
            for (d, &v) in x.iter().enumerate() {
                x_cols[d].push(v);
            }
            y.push(0.5);
            let _ = state.observe(&x, &x_cols);
            state.ensure_posterior(&y);
            let p = state.posterior().unwrap();
            for d in 0..2 {
                assert!(p.b[d].iter().all(|v| v.is_finite()));
            }
        }
    }
}
