//! The sparse additive-GP engine — paper §3 and §5.
//!
//! * [`dim`] — per-dimension factorization state (KP, GKP, the banded LUs).
//! * [`backfit`] — block Gauss–Seidel for `[K^{-1}+σ⁻²SS^T]^{-1}v`
//!   (**Algorithm 4**).
//! * [`posterior`] — posterior mean (12) / variance (13), sparse windows,
//!   band-of-inverse (via **Algorithm 5**) and the lazy `M̃`-column cache.
//! * [`likelihood`] — log-likelihood (14), its gradient (15), power method
//!   (**Algorithm 6**), Hutchinson trace (**Algorithm 7**) and the stochastic
//!   log-determinant (**Algorithm 8**).
//! * [`train`] — MLE of the scale hyperparameters by Adam on ∇l.
//! * [`model`] — the [`model::AdditiveGP`] façade tying it together.

pub mod backfit;
pub mod dim;
pub mod likelihood;
pub mod model;
pub mod posterior;
pub mod train;

pub use backfit::{BlockVec, GaussSeidel};
pub use dim::DimFactor;
pub use model::{AdditiveGP, AdditiveGpConfig};
