//! The sparse additive-GP engine — paper §3 and §5.
//!
//! * [`dim`] — per-dimension factorization state (KP, GKP, the banded LUs),
//!   incrementally updatable via `DimFactor::insert_point`.
//! * [`backfit`] — block Gauss–Seidel for `[K^{-1}+σ⁻²SS^T]^{-1}v`
//!   (**Algorithm 4**), with warm-started PCG (`solve_from`).
//! * [`posterior`] — posterior mean (12) / variance (13), sparse windows,
//!   band-of-inverse (via **Algorithm 5**) and the lazy `M̃`-column cache
//!   with windowed invalidation.
//! * [`fit_state`] — the [`fit_state::FitState`] layer owning the trained
//!   factorizations + posterior vectors, with `observe` as a first-class
//!   incremental operation (DESIGN.md §FitState).
//! * [`likelihood`] — log-likelihood (14), its gradient (15), power method
//!   (**Algorithm 6**), Hutchinson trace (**Algorithm 7**) and the stochastic
//!   log-determinant (**Algorithm 8**).
//! * [`train`] — MLE of the scale hyperparameters by Adam on ∇l.
//! * [`model`] — the [`model::AdditiveGP`] façade tying it together.
//! * [`persist`] — bit-exact checkpoint encode/decode of a trained model,
//!   the compaction payload of the coordinator's mutation journal.

pub mod backfit;
pub mod dim;
pub mod fit_state;
pub mod likelihood;
pub mod model;
pub mod persist;
pub mod posterior;
pub mod train;

pub use backfit::{BlockVec, GaussSeidel, GsScratch};
pub use dim::{DimFactor, PatchTimings};
pub use fit_state::{BatchPositions, FitState, PosteriorSnapshot};
pub use model::{AdditiveGP, AdditiveGpConfig, BatchPath};
