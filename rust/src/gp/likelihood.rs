//! Log-likelihood (eq. 14) and its gradient (eq. 15) — paper §5.1.2.
//!
//! The negative log marginal likelihood of the additive model is
//!
//! ```text
//! NLL(ω, σ_y) = ½ [ Yᵀ R Y + log|Σ| + n log 2π ],   Σ = Σ_d K_d + σ_y² I
//! R = Σ^{-1} = σ⁻² I − σ⁻⁴ Sᵀ [K^{-1}+σ⁻²SSᵀ]^{-1} S          (Woodbury)
//! log|Σ| = 2n log σ_y + Σ_d (log|Φ_d| − log|A_d|) + log|K^{-1}+σ⁻²SSᵀ|
//! ```
//!
//! * `R·v` costs one Algorithm 4 solve (`O(Dn)` per Gauss–Seidel sweep).
//! * The banded log-dets come from the banded LU (`O(ν²n)`).
//! * `log|K^{-1}+σ⁻²SSᵀ|` uses the **power method (Algorithm 6)** for
//!   `λ_max`, then the truncated-Taylor + **Hutchinson (Algorithm 7)**
//!   stochastic estimator (**Algorithm 8**).
//! * The gradient `∂NLL/∂ω_d = ½[tr(R ∂K_d) − YᵀR (∂K_d) R Y]` applies
//!   `∂K_d = B_d^{-1}Ψ_d` via the generalized-KP factorization (eq. 15) and
//!   estimates the trace with shared Hutchinson probes (eq. 24).

use crate::gp::backfit::{BlockVec, GaussSeidel};
use crate::gp::dim::DimFactor;
use crate::util::Rng;

/// Tunables for the stochastic estimators.
#[derive(Clone, Copy, Debug)]
pub struct StochasticCfg {
    /// Hutchinson probes for traces (paper's `Q`).
    pub trace_probes: usize,
    /// Probes for the log-det estimator (Algorithm 8's outer loop `Q`).
    pub logdet_probes: usize,
    /// Taylor truncation order (Algorithm 8's inner loop `S`); `0` → use
    /// `⌈4 log₂ n⌉`.
    pub logdet_terms: usize,
    /// Power-method restarts / iterations (Algorithm 6's `Q` and `S`).
    pub power_restarts: usize,
    pub power_iters: usize,
    pub seed: u64,
}

impl Default for StochasticCfg {
    fn default() -> Self {
        StochasticCfg {
            trace_probes: 24,
            logdet_probes: 24,
            logdet_terms: 0,
            power_restarts: 3,
            power_iters: 30,
            seed: 0xADD6,
        }
    }
}

/// Apply `R = [Σ_d K_d + σ²I]^{-1}` to an `n`-vector (data order).
pub fn r_matvec(dims: &[DimFactor], sigma2_y: f64, gs: &GaussSeidel, v: &[f64]) -> Vec<f64> {
    let mut blocks: BlockVec = vec![vec![0.0; v.len()]; dims.len()];
    let mut out = vec![0.0; v.len()];
    r_matvec_into(dims, sigma2_y, gs, v, &mut blocks, &mut out);
    out
}

/// [`r_matvec`] with caller-owned buffers — the Hutchinson probe loops
/// reuse `blocks`/`out` across probes instead of allocating a fresh
/// `BlockVec` per solve (the per-iteration solver work inside is already
/// allocation-free through `GaussSeidel`'s scratch; DESIGN.md §Perf).
pub fn r_matvec_into(
    dims: &[DimFactor],
    sigma2_y: f64,
    gs: &GaussSeidel,
    v: &[f64],
    blocks: &mut BlockVec,
    out: &mut [f64],
) {
    let n = v.len();
    assert_eq!(blocks.len(), dims.len());
    assert_eq!(out.len(), n);
    let inv2 = 1.0 / sigma2_y;
    // S v: every block gets v. Solve [K^{-1}+σ⁻²SSᵀ]u = S v.
    for b in blocks.iter_mut() {
        b.copy_from_slice(v);
    }
    let (u, _) = gs.solve(blocks);
    out.fill(0.0);
    for b in &u {
        for i in 0..n {
            out[i] += b[i];
        }
    }
    for i in 0..n {
        out[i] = inv2 * v[i] - inv2 * inv2 * out[i];
    }
}

/// `Σ_d (log|Φ_d| − log|A_d|) = log|K|` — the banded log-det terms of (14).
pub fn logdet_k(dims: &[DimFactor]) -> f64 {
    dims.iter()
        .map(|d| {
            let (lphi, _) = d.phi_lu.logdet();
            let (la, _) = d.a_lu.logdet();
            lphi - la
        })
        .sum()
}

/// **Algorithm 6** (power method): estimate `λ_max` of
/// `M = K^{-1} + σ⁻²SSᵀ` using the `O(n)` operator.
pub fn lambda_max(dims: &[DimFactor], gs: &GaussSeidel, cfg: &StochasticCfg, rng: &mut Rng) -> f64 {
    let n = dims[0].n();
    let dd = dims.len();
    let mut best = 0.0f64;
    // One scratch + one iterate buffer for the whole power iteration — the
    // inner loop allocates nothing.
    let mut scratch = gs.scratch();
    let mut w: BlockVec = vec![vec![0.0; n]; dd];
    for _ in 0..cfg.power_restarts.max(1) {
        let mut v: BlockVec = (0..dd).map(|_| rng.rademacher_vec(n)).collect();
        for _ in 0..cfg.power_iters {
            gs.apply_into(&v, &mut w, &mut scratch);
            let norm = w
                .iter()
                .flat_map(|b| b.iter())
                .map(|x| x * x)
                .sum::<f64>()
                .sqrt()
                .max(1e-300);
            for b in &mut w {
                for x in b.iter_mut() {
                    *x /= norm;
                }
            }
            std::mem::swap(&mut v, &mut w);
        }
        gs.apply_into(&v, &mut w, &mut scratch);
        let num: f64 = v
            .iter()
            .zip(&w)
            .flat_map(|(a, b)| a.iter().zip(b.iter()))
            .map(|(a, b)| a * b)
            .sum();
        let den: f64 = v.iter().flat_map(|b| b.iter()).map(|x| x * x).sum();
        best = best.max(num / den);
    }
    best
}

/// **Algorithm 8**: stochastic `log|K^{-1} + σ⁻²SSᵀ|` via power method,
/// Taylor expansion of `log det`, and Hutchinson traces (**Algorithm 7**).
pub fn logdet_m_stochastic(dims: &[DimFactor], gs: &GaussSeidel, cfg: &StochasticCfg) -> f64 {
    let n = dims[0].n();
    let dd = dims.len();
    let mut rng = Rng::new(cfg.seed ^ 0x10adde7);
    // Slight over-estimate of λ_max keeps all normalized eigenvalues < 1.
    let lam = lambda_max(dims, gs, cfg, &mut rng) * 1.05;
    let terms = if cfg.logdet_terms > 0 {
        cfg.logdet_terms
    } else {
        (4.0 * (n as f64).log2()).ceil() as usize
    };
    let mut gamma = 0.0;
    let mut scratch = gs.scratch();
    let mut mu: BlockVec = vec![vec![0.0; n]; dd];
    for _ in 0..cfg.logdet_probes {
        let v0: BlockVec = (0..dd).map(|_| rng.rademacher_vec(n)).collect();
        let mut u = v0.clone();
        let mut acc = 0.0;
        for s in 1..=terms {
            // u ← (I − M/λ) u
            gs.apply_into(&u, &mut mu, &mut scratch);
            for (ub, mb) in u.iter_mut().zip(&mu) {
                for (x, m) in ub.iter_mut().zip(mb) {
                    *x -= m / lam;
                }
            }
            let dot: f64 = v0
                .iter()
                .zip(&u)
                .flat_map(|(a, b)| a.iter().zip(b.iter()))
                .map(|(a, b)| a * b)
                .sum();
            acc += dot / s as f64;
        }
        gamma += acc;
    }
    gamma /= cfg.logdet_probes as f64;
    (dd * n) as f64 * lam.ln() - gamma
}

/// Exact dense `log|K^{-1}+σ⁻²SSᵀ|` (tests / tiny n).
pub fn logdet_m_dense(dims: &[DimFactor], sigma2_y: f64) -> f64 {
    let n = dims[0].n();
    let dd = dims.len();
    let mut m = crate::linalg::Dense::zeros(dd * n, dd * n);
    for (d, dim) in dims.iter().enumerate() {
        let kinv = dim.kernel().gram(&dim.kp.xs).inverse();
        for i in 0..n {
            for j in 0..n {
                let io = dim.kp.perm.orig(i);
                let jo = dim.kp.perm.orig(j);
                m.add(d * n + io, d * n + jo, kinv.get(i, j));
            }
        }
    }
    for d1 in 0..dd {
        for d2 in 0..dd {
            for i in 0..n {
                m.add(d1 * n + i, d2 * n + i, 1.0 / sigma2_y);
            }
        }
    }
    m.lu_logdet().0
}

/// Full negative log marginal likelihood (up to the `n log 2π / 2` constant
/// included), with the stochastic log-det.
pub fn nll(dims: &[DimFactor], sigma2_y: f64, y: &[f64], cfg: &StochasticCfg) -> f64 {
    let gs = GaussSeidel::new(dims, sigma2_y);
    let ry = r_matvec(dims, sigma2_y, &gs, y);
    let quad: f64 = y.iter().zip(&ry).map(|(a, b)| a * b).sum();
    let n = y.len() as f64;
    let logdet_sigma = n * sigma2_y.ln()
        + logdet_k(dims)
        + logdet_m_stochastic(dims, &gs, cfg);
    0.5 * (quad + logdet_sigma + n * (2.0 * std::f64::consts::PI).ln())
}

/// Exact NLL with the dense log-det (tests / small n).
pub fn nll_exact(dims: &[DimFactor], sigma2_y: f64, y: &[f64]) -> f64 {
    let gs = GaussSeidel::new(dims, sigma2_y);
    let ry = r_matvec(dims, sigma2_y, &gs, y);
    let quad: f64 = y.iter().zip(&ry).map(|(a, b)| a * b).sum();
    let n = y.len() as f64;
    let logdet_sigma =
        n * sigma2_y.ln() + logdet_k(dims) + logdet_m_dense(dims, sigma2_y);
    0.5 * (quad + logdet_sigma + n * (2.0 * std::f64::consts::PI).ln())
}

/// Gradient of the NLL.
#[derive(Clone, Debug)]
pub struct NllGrad {
    /// `∂NLL/∂ω_d`.
    pub omega: Vec<f64>,
    /// `∂NLL/∂σ_y²`.
    pub sigma2: f64,
}

/// `∂NLL/∂ω_d = ½ [tr(R ∂K_d) − YᵀR (∂K_d) R Y]` (eq. 15 up to sign — the
/// paper writes the gradient of `l = −2·NLL + const`), and
/// `∂NLL/∂σ² = ½ [tr(R) − ‖R Y‖²]`.
///
/// Traces use `Q` shared Hutchinson probes (Algorithm 7 / eq. 24): for each
/// probe `v`, one Algorithm 4 solve yields `Rv`, then each dimension costs
/// only a generalized-KP matvec — `O(Q·Dn)` total.
pub fn nll_grad(dims: &mut [DimFactor], sigma2_y: f64, y: &[f64], cfg: &StochasticCfg) -> NllGrad {
    let n = y.len();
    let dd = dims.len();
    // Ensure GKPs exist (mutable phase), then borrow immutably.
    for dim in dims.iter_mut() {
        dim.gkp();
    }
    let dims = &*dims;
    let gs = GaussSeidel::new(dims, sigma2_y);
    let ry = r_matvec(dims, sigma2_y, &gs, y);
    // Probe solves feed a Monte-Carlo trace with O(1/sqrt(Q)) error - a
    // loose solver tolerance is statistically free (DESIGN.md §Perf).
    let mut gs_probe = GaussSeidel::new(dims, sigma2_y);
    gs_probe.tol = 1e-6;

    // Quadratic parts.
    let dk_ry: Vec<Vec<f64>> = dims
        .iter()
        .map(|dim| {
            let s = dim.kp.perm.to_sorted(&ry);
            let out = dim
                .gkp_cached()
                .expect("gkp built above")
                .dk_matvec(&s);
            dim.kp.perm.to_original(&out)
        })
        .collect();
    let mut quad_omega = vec![0.0; dd];
    for d in 0..dd {
        quad_omega[d] = ry.iter().zip(&dk_ry[d]).map(|(a, b)| a * b).sum();
    }
    let quad_sigma: f64 = ry.iter().map(|x| x * x).sum();

    // Hutchinson traces with shared probes; the probe solves reuse one set
    // of RHS/output buffers across the whole loop.
    let mut rng = Rng::new(cfg.seed ^ 0x7eace);
    let mut tr_omega = vec![0.0; dd];
    let mut tr_sigma = 0.0;
    let mut probe_blocks: BlockVec = vec![vec![0.0; n]; dd];
    let mut rv = vec![0.0; n];
    for _ in 0..cfg.trace_probes {
        let v = rng.rademacher_vec(n);
        r_matvec_into(dims, sigma2_y, &gs_probe, &v, &mut probe_blocks, &mut rv);
        tr_sigma += v.iter().zip(&rv).map(|(a, b)| a * b).sum::<f64>();
        for (d, dim) in dims.iter().enumerate() {
            let vs = dim.kp.perm.to_sorted(&v);
            let dkv = dim.gkp_cached().unwrap().dk_matvec(&vs);
            let dkv_o = dim.kp.perm.to_original(&dkv);
            tr_omega[d] += rv.iter().zip(&dkv_o).map(|(a, b)| a * b).sum::<f64>();
        }
    }
    let q = cfg.trace_probes as f64;
    NllGrad {
        omega: (0..dd).map(|d| 0.5 * (tr_omega[d] / q - quad_omega[d])).collect(),
        sigma2: 0.5 * (tr_sigma / q - quad_sigma),
    }
}

/// Exact gradient via dense algebra (tests / small n).
pub fn nll_grad_exact(dims: &[DimFactor], sigma2_y: f64, y: &[f64]) -> NllGrad {
    let n = y.len();
    let dd = dims.len();
    let mut sigma = crate::linalg::Dense::zeros(n, n);
    let mut dks = Vec::with_capacity(dd);
    for dim in dims {
        let xs_orig: Vec<f64> = (0..n).map(|i| dim.kp.xs[dim.kp.perm.sorted_pos(i)]).collect();
        let k = dim.kernel().gram(&xs_orig);
        let dk = dim.kernel().gram_domega(&xs_orig);
        for i in 0..n {
            for j in 0..n {
                sigma.add(i, j, k.get(i, j));
            }
        }
        dks.push(dk);
    }
    for i in 0..n {
        sigma.add(i, i, sigma2_y);
    }
    let r = sigma.inverse();
    let ry = r.matvec(y);
    let mut omega = vec![0.0; dd];
    for d in 0..dd {
        let quad: f64 = ry.iter().zip(dks[d].matvec(&ry)).map(|(a, b)| a * b).sum();
        // tr(R dK)
        let rdk = r.matmul(&dks[d]);
        let mut tr = 0.0;
        for i in 0..n {
            tr += rdk.get(i, i);
        }
        omega[d] = 0.5 * (tr - quad);
    }
    let mut tr_r = 0.0;
    for i in 0..n {
        tr_r += r.get(i, i);
    }
    let quad_s: f64 = ry.iter().map(|x| x * x).sum();
    NllGrad { omega, sigma2: 0.5 * (tr_r - quad_s) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::matern::{Matern, Nu};
    use crate::util::Rng;

    fn setup(n: usize, dd: usize, nu: Nu, sigma2: f64, seed: u64) -> (Vec<DimFactor>, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let dims: Vec<DimFactor> = (0..dd)
            .map(|d| {
                let pts = rng.uniform_vec(n, 0.0, 5.0);
                DimFactor::new(&pts, Matern::new(nu, 0.7 + 0.2 * d as f64), sigma2)
            })
            .collect();
        let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        (dims, y)
    }

    /// `R` really is `Σ^{-1}`: `Σ (R y) = y`.
    #[test]
    fn r_matvec_is_sigma_inverse() {
        let sigma2 = 0.9;
        let (dims, y) = setup(20, 3, Nu::Half, sigma2, 1);
        let gs = GaussSeidel::new(&dims, sigma2);
        let ry = r_matvec(&dims, sigma2, &gs, &y);
        // Build Σ densely.
        let n = 20;
        let mut sig = crate::linalg::Dense::zeros(n, n);
        for dim in &dims {
            let xs_orig: Vec<f64> =
                (0..n).map(|i| dim.kp.xs[dim.kp.perm.sorted_pos(i)]).collect();
            let k = dim.kernel().gram(&xs_orig);
            for i in 0..n {
                for j in 0..n {
                    sig.add(i, j, k.get(i, j));
                }
            }
        }
        for i in 0..n {
            sig.add(i, i, sigma2);
        }
        let back = sig.matvec(&ry);
        for i in 0..n {
            assert!((back[i] - y[i]).abs() < 1e-6, "i={i}: {} vs {}", back[i], y[i]);
        }
    }

    /// Banded `log|K|` matches the dense log-det of the per-dim grams.
    #[test]
    fn logdet_k_matches_dense() {
        let (dims, _) = setup(18, 2, Nu::ThreeHalves, 1.0, 2);
        let got = logdet_k(&dims);
        let want: f64 = dims
            .iter()
            .map(|dim| dim.kernel().gram(&dim.kp.xs).lu_logdet().0)
            .sum();
        assert!((got - want).abs() < 1e-7, "{got} vs {want}");
    }

    /// Algorithm 8 approaches the dense log-det. The Taylor series converges
    /// at rate `1 − λ_min/λ_max`, so the test uses a well-conditioned
    /// instance (spread-out points, rough kernel) — the regime the paper's
    /// `S = O(log n)` claim assumes; see DESIGN.md for the caveat.
    #[test]
    fn stochastic_logdet_close_to_dense() {
        let sigma2 = 1.0;
        let mut rng = Rng::new(3);
        let dims: Vec<DimFactor> = (0..2)
            .map(|_| {
                let pts: Vec<f64> = (0..16)
                    .map(|i| (i as f64 + 0.3 * rng.uniform()) * 1.5)
                    .collect();
                DimFactor::new(&pts, Matern::new(Nu::Half, 3.0), sigma2)
            })
            .collect();
        let gs = GaussSeidel::new(&dims, sigma2);
        let cfg = StochasticCfg {
            logdet_probes: 400,
            logdet_terms: 600,
            power_iters: 80,
            ..Default::default()
        };
        let got = logdet_m_stochastic(&dims, &gs, &cfg);
        let want = logdet_m_dense(&dims, sigma2);
        let rel = (got - want).abs() / want.abs().max(1.0);
        assert!(rel < 0.05, "stochastic {got} vs dense {want} (rel {rel})");
    }

    /// λ_max from Algorithm 6 matches the dense spectrum (upper end).
    #[test]
    fn power_method_lambda_max() {
        let sigma2 = 0.8;
        let (dims, _) = setup(14, 2, Nu::Half, sigma2, 4);
        let gs = GaussSeidel::new(&dims, sigma2);
        let cfg = StochasticCfg { power_iters: 80, power_restarts: 4, ..Default::default() };
        let mut rng = Rng::new(9);
        let lam = lambda_max(&dims, &gs, &cfg, &mut rng);
        // Dense check: λ_max via many power iterations on the dense matrix.
        let n = 14;
        let dd = 2;
        let mut m = crate::linalg::Dense::zeros(dd * n, dd * n);
        for (d, dim) in dims.iter().enumerate() {
            let kinv = dim.kernel().gram(&dim.kp.xs).inverse();
            for i in 0..n {
                for j in 0..n {
                    let io = dim.kp.perm.orig(i);
                    let jo = dim.kp.perm.orig(j);
                    m.add(d * n + io, d * n + jo, kinv.get(i, j));
                }
            }
        }
        for d1 in 0..dd {
            for d2 in 0..dd {
                for i in 0..n {
                    m.add(d1 * n + i, d2 * n + i, 1.0 / sigma2);
                }
            }
        }
        let mut v = vec![1.0; dd * n];
        for _ in 0..500 {
            let w = m.matvec(&v);
            let norm = w.iter().map(|x| x * x).sum::<f64>().sqrt();
            v = w.into_iter().map(|x| x / norm).collect();
        }
        let lam_dense: f64 =
            v.iter().zip(m.matvec(&v)).map(|(a, b)| a * b).sum::<f64>();
        assert!(
            (lam - lam_dense).abs() < 0.05 * lam_dense,
            "power {lam} vs dense {lam_dense}"
        );
    }

    /// Exact sparse NLL (quad + banded dets + dense logdet-M) equals the
    /// classic dense GP NLL.
    #[test]
    fn nll_exact_matches_classic_formula() {
        let sigma2 = 1.1;
        let (dims, y) = setup(15, 2, Nu::ThreeHalves, sigma2, 5);
        let got = nll_exact(&dims, sigma2, &y);
        // Classic: ½ [yᵀΣ⁻¹y + log|Σ| + n log 2π].
        let n = 15;
        let mut sig = crate::linalg::Dense::zeros(n, n);
        for dim in &dims {
            let xs_orig: Vec<f64> =
                (0..n).map(|i| dim.kp.xs[dim.kp.perm.sorted_pos(i)]).collect();
            let k = dim.kernel().gram(&xs_orig);
            for i in 0..n {
                for j in 0..n {
                    sig.add(i, j, k.get(i, j));
                }
            }
        }
        for i in 0..n {
            sig.add(i, i, sigma2);
        }
        let quad: f64 = y.iter().zip(sig.solve(&y)).map(|(a, b)| a * b).sum();
        let want = 0.5
            * (quad + sig.lu_logdet().0 + n as f64 * (2.0 * std::f64::consts::PI).ln());
        assert!((got - want).abs() < 1e-5 * want.abs(), "{got} vs {want}");
    }

    /// Stochastic gradient ≈ exact dense gradient.
    #[test]
    fn grad_matches_dense() {
        let sigma2 = 1.0;
        let (mut dims, y) = setup(18, 2, Nu::Half, sigma2, 6);
        let cfg = StochasticCfg { trace_probes: 4000, ..Default::default() };
        let got = nll_grad(&mut dims, sigma2, &y, &cfg);
        let want = nll_grad_exact(&dims, sigma2, &y);
        for d in 0..2 {
            let tol = 0.05 * want.omega[d].abs().max(1.0);
            assert!(
                (got.omega[d] - want.omega[d]).abs() < tol,
                "ω_{d}: {} vs {}",
                got.omega[d],
                want.omega[d]
            );
        }
        assert!(
            (got.sigma2 - want.sigma2).abs() < 0.05 * want.sigma2.abs().max(1.0),
            "σ²: {} vs {}",
            got.sigma2,
            want.sigma2
        );
    }

    /// The exact dense gradient itself matches finite differences of the
    /// exact NLL — guards the eq. (15) sign conventions end to end.
    #[test]
    fn dense_grad_matches_fd() {
        let sigma2 = 1.0;
        let n = 14;
        let mut rng = Rng::new(7);
        let pts: Vec<Vec<f64>> = (0..2).map(|_| rng.uniform_vec(n, 0.0, 5.0)).collect();
        let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let build = |omegas: [f64; 2]| -> Vec<DimFactor> {
            (0..2)
                .map(|d| DimFactor::new(&pts[d], Matern::new(Nu::Half, omegas[d]), sigma2))
                .collect()
        };
        let base = [0.9, 1.3];
        let dims = build(base);
        let g = nll_grad_exact(&dims, sigma2, &y);
        let h = 1e-5;
        for d in 0..2 {
            let mut up = base;
            up[d] += h;
            let mut dn = base;
            dn[d] -= h;
            let fp = nll_exact(&build(up), sigma2, &y);
            let fm = nll_exact(&build(dn), sigma2, &y);
            let fd = (fp - fm) / (2.0 * h);
            assert!(
                (fd - g.omega[d]).abs() < 1e-3 * fd.abs().max(1.0),
                "ω_{d}: fd {fd} vs exact {}",
                g.omega[d]
            );
        }
    }
}
