//! Posterior mean (eq. 12) and variance (eq. 13) in sparse-window form,
//! plus their x-gradients (eq. 30) — paper §5.2 and §6.
//!
//! After training, the mean is an `O(1)` window dot against the vector
//! `b = Φ^{-T} P^T [K^{-1}+σ⁻²SS^T]^{-1} S Y/σ²`. The variance combines a
//! `2ν`-band of `C_d = Φ_d^{-T}A_d^{-1}` (Algorithm 5) with a quadratic form
//! in `M̃ = Φ^{-T}P^T M P Φ^{-1}`; `M̃` is never materialized — its columns
//! are computed on demand with Algorithm 4 and memoized in [`MTildeCache`],
//! which is what makes small-step acquisition ascent `O(1)` amortized (§6).

use std::collections::{HashMap, HashSet};

use crate::check::{Audit, AuditError};
use crate::gp::backfit::{BlockVec, GaussSeidel, GsStats};
use crate::gp::dim::DimFactor;

/// Trained posterior state: the `b` vectors of eq. (12), per dimension, in
/// sorted coordinates.
#[derive(Clone, Debug)]
pub struct Posterior {
    /// `b_d = Φ_d^{-T} (P_d^T ṽ_d)`, sorted coordinates.
    pub b: Vec<Vec<f64>>,
    pub gs_stats: GsStats,
}

/// Compute the posterior state (`O(n log n)`): one Algorithm 4 solve with the
/// shared right-hand side `S Y/σ²`, then one banded `Φ^T`-solve per dim.
/// (The noise variance enters through the solver, which owns `σ_y²`.)
pub fn compute_posterior(dims: &[DimFactor], y: &[f64], gs: &GaussSeidel) -> Posterior {
    compute_posterior_warm(dims, y, gs, None).0
}

/// [`compute_posterior`] with an optional warm start for the Algorithm 4
/// solve, returning the raw solution ṽ alongside so the caller
/// (`FitState`) can seed the *next* solve with it.
pub fn compute_posterior_warm(
    dims: &[DimFactor],
    y: &[f64],
    gs: &GaussSeidel,
    guess: Option<&BlockVec>,
) -> (Posterior, BlockVec) {
    let (tilde, gs_stats) = gs.solve_shared_from(y, guess);
    let b = dims
        .iter()
        .zip(&tilde)
        .map(|(dim, t)| {
            let ts = dim.kp.perm.to_sorted(t);
            dim.phit_lu.solve(&ts)
        })
        .collect();
    (Posterior { b, gs_stats }, tilde)
}

/// Posterior mean `μ_n(x*) = Σ_d φ_d(x*_d)·b_d` — `O(D log n)`.
pub fn mean(dims: &[DimFactor], post: &Posterior, x: &[f64]) -> f64 {
    let mut acc = 0.0;
    for (d, dim) in dims.iter().enumerate() {
        let (start, vals) = dim.kp.phi_window(x[d]);
        for (r, &v) in vals.iter().enumerate() {
            acc += v * post.b[d][start + r];
        }
    }
    acc
}

/// Gradient of the posterior mean, `∂μ/∂x_d = ∂φ_d(x_d)·b_d` — `O(D log n)`.
pub fn mean_grad(dims: &[DimFactor], post: &Posterior, x: &[f64]) -> Vec<f64> {
    dims.iter()
        .enumerate()
        .map(|(d, dim)| {
            let (start, dvals) = dim.kp.dphi_window(x[d]);
            dvals.iter().enumerate().map(|(r, &v)| v * post.b[d][start + r]).sum()
        })
        .collect()
}

/// Memoized columns of `M̃ = Φ^{-T} P^T [K^{-1}+σ⁻²SS^T]^{-1} P Φ^{-1}`,
/// keyed by `(dim, sorted index)`. Each miss costs one Algorithm 4 solve
/// (`O(Dn)`); hits are free — consecutive small acquisition steps touch the
/// same window columns, giving the paper's `O(1)` per-step claim.
#[derive(Default)]
pub struct MTildeCache {
    cols: HashMap<(u32, u32), Vec<Vec<f64>>>,
    /// Columns carried across an incremental observe: values predate the
    /// insertion, so they serve only as PCG warm starts until refreshed.
    stale: HashSet<(u32, u32)>,
    pub hits: u64,
    pub misses: u64,
    /// Stale columns recomputed with a warm start after an observe.
    pub refreshes: u64,
    /// Queries answered by the one-shot single-solve path (see
    /// [`predict_cached`]'s cold-start policy).
    pub single_solves: u64,
    /// Size-triggered wholesale drops: invalidation passes that *truncated*
    /// the cache (too many resident columns, or a batch larger than
    /// [`MTildeCache::REMAP_MAX_BATCH`]) instead of remapping it. Previously
    /// silent; surfaced through `Response::Stats` so operators can see when
    /// locality is being thrown away.
    pub truncation_clears: u64,
    /// Soft cap on resident columns (FIFO-ish eviction by generation).
    pub capacity: usize,
    order: Vec<(u32, u32)>,
    /// Visit counts per window signature — columns are only materialized on
    /// the second visit, when locality makes them pay off.
    visits: HashMap<Vec<u32>, u32>,
}

impl MTildeCache {
    /// Above this many resident columns an insert-time remap sweep costs
    /// more than letting columns rebuild on demand — both invalidation
    /// paths ([`MTildeCache::on_insert`], [`MTildeCache::on_insert_batch`])
    /// drop everything instead.
    const REMAP_MAX_COLS: usize = 64;
    /// Batches larger than this clear rather than remap — the zero-splice
    /// sweep scales with `m·resident·D·n` and most windows overlap an
    /// insertion anyway.
    const REMAP_MAX_BATCH: usize = 16;

    pub fn new(capacity: usize) -> Self {
        MTildeCache { capacity, ..Default::default() }
    }

    pub fn clear(&mut self) {
        self.cols.clear();
        self.stale.clear();
        self.order.clear();
        self.visits.clear();
    }

    /// [`MTildeCache::clear`], counted as a size-triggered truncation.
    /// Deliberately *not* called from plain `clear()` so refit-driven full
    /// rebuilds (where dropping the cache is inherent, not a shortcut) don't
    /// inflate the counter.
    fn clear_truncated(&mut self) {
        self.truncation_clears += 1;
        self.clear();
    }

    /// Windowed invalidation after an incremental observe at sorted position
    /// `positions[d]` in each dimension (KP half-bandwidth `w = ν+1/2`).
    ///
    /// Columns whose `2ν`-window overlaps the insertion are *evicted* — their
    /// Φ-window structure changed, so the old values are a poor basis.
    /// Every surviving column is re-keyed (sorted indices at or above the
    /// insertion shift by one), gets a zero entry spliced in at each
    /// dimension's insertion position, and is marked **stale**: it is served
    /// again only after an exact warm-started re-solve in
    /// [`MTildeCache::column`]. Staleness therefore never leaks into
    /// results — it only converts cold `O(Dn)`-solve misses into a few
    /// warm PCG iterations.
    pub fn on_insert(&mut self, positions: &[usize], w: usize) {
        // Re-keying splices a zero into every dim of every surviving column
        // (`O(resident·D·n)`). That's a win for the handful of columns a
        // local acquisition ascent holds, but a near-full cache would make
        // this dwarf the factor sweep itself — there, dropping everything
        // and letting columns rebuild on demand is strictly cheaper.
        if self.cols.len() > Self::REMAP_MAX_COLS {
            self.clear_truncated();
            return;
        }
        let reach = (2 * w) as isize;
        // Column remapping is order-independent (each column re-keys and
        // splices on its own). lint: hashmap-order-ok
        let old: Vec<((u32, u32), Vec<Vec<f64>>)> = self.cols.drain().collect();
        self.stale.clear();
        let mut remap: HashMap<(u32, u32), (u32, u32)> = HashMap::new();
        for ((dcol, j), mut col) in old {
            let p = positions[dcol as usize];
            if (j as isize - p as isize).abs() <= reach {
                continue; // evict: window overlaps the inserted point
            }
            let nj = if j as usize >= p { j + 1 } else { j };
            for (d, v) in col.iter_mut().enumerate() {
                v.insert(positions[d], 0.0);
            }
            self.stale.insert((dcol, nj));
            remap.insert((dcol, j), (dcol, nj));
            self.cols.insert((dcol, nj), col);
        }
        let order: Vec<(u32, u32)> =
            self.order.iter().filter_map(|k| remap.get(k).copied()).collect();
        self.order = order;
        self.visits.clear();
    }

    /// Batched form of [`MTildeCache::on_insert`]: one invalidation pass for
    /// a whole `observe_batch`, instead of one re-key/splice sweep per
    /// point. `positions[d]` holds dimension `d`'s final sorted insertion
    /// positions (batch data order).
    ///
    /// The exactness story is unchanged — every surviving column is re-keyed
    /// through the batch index shift, zero-spliced at each dimension's
    /// insertion positions, and marked stale, so it is served only after an
    /// exact warm re-solve. Large batches (or near-full caches) drop
    /// everything instead: with `m` insertions the splice work scales as
    /// `O(resident·D·(n+m))` while most windows overlap an insertion anyway.
    pub fn on_insert_batch(&mut self, positions: &[Vec<usize>], w: usize) {
        let m = positions.first().map(|p| p.len()).unwrap_or(0);
        if m == 0 {
            return;
        }
        if m == 1 {
            let pos: Vec<usize> = positions.iter().map(|p| p[0]).collect();
            self.on_insert(&pos, w);
            return;
        }
        if self.cols.len() > Self::REMAP_MAX_COLS || m > Self::REMAP_MAX_BATCH {
            self.clear_truncated();
            return;
        }
        let sorted: Vec<Vec<usize>> = positions
            .iter()
            .map(|p| {
                let mut q = p.clone();
                q.sort_unstable();
                q
            })
            .collect();
        let reach = (2 * w) as isize;
        // Column remapping is order-independent (see on_insert).
        // lint: hashmap-order-ok
        let old: Vec<((u32, u32), Vec<Vec<f64>>)> = self.cols.drain().collect();
        self.stale.clear();
        let mut remap: HashMap<(u32, u32), (u32, u32)> = HashMap::new();
        'cols: for ((dcol, j), mut col) in old {
            // Old sorted index → final coordinate in the column's own dim.
            let qs = &sorted[dcol as usize];
            let mut shift = 0usize;
            for &q in qs {
                if q <= j as usize + shift {
                    shift += 1;
                } else {
                    break;
                }
            }
            let nj = j as usize + shift;
            for &q in qs {
                if (nj as isize - q as isize).abs() <= reach {
                    continue 'cols; // evict: some insertion hit its window
                }
            }
            // Ascending final positions splice exactly (earlier splices
            // leave later final indices correct).
            for (d, v) in col.iter_mut().enumerate() {
                for &q in &sorted[d] {
                    v.insert(q, 0.0);
                }
            }
            let key = (dcol, nj as u32);
            self.stale.insert(key);
            remap.insert((dcol, j), key);
            self.cols.insert(key, col);
        }
        let order: Vec<(u32, u32)> =
            self.order.iter().filter_map(|k| remap.get(k).copied()).collect();
        self.order = order;
        self.visits.clear();
    }

    /// Windowed invalidation after an incremental forget at sorted position
    /// `positions[d]` in each dimension — the deletion mirror of
    /// [`MTildeCache::on_insert`].
    ///
    /// The removed column itself and every column whose `2ν`-window overlaps
    /// the closing gap are evicted. Every surviving column is re-keyed
    /// (sorted indices above the removal shift down by one), has the removed
    /// entry spliced *out* of each dimension's block (keeping vector shapes
    /// aligned with the shrunk `n`), and is marked **stale** — served again
    /// only after an exact warm-started re-solve in [`MTildeCache::column`],
    /// so pre-removal values never leak into results.
    ///
    /// Truncation parity: an over-full cache routes through
    /// [`MTildeCache::clear_truncated`] exactly like the insert path, so
    /// `truncation_clears` counts thrown-away locality symmetrically for
    /// observes and forgets.
    pub fn on_remove(&mut self, positions: &[usize], w: usize) {
        if self.cols.len() > Self::REMAP_MAX_COLS {
            self.clear_truncated();
            return;
        }
        let reach = (2 * w) as isize;
        // Column remapping is order-independent (see on_insert).
        // lint: hashmap-order-ok
        let old: Vec<((u32, u32), Vec<Vec<f64>>)> = self.cols.drain().collect();
        self.stale.clear();
        let mut remap: HashMap<(u32, u32), (u32, u32)> = HashMap::new();
        for ((dcol, j), mut col) in old {
            let p = positions[dcol as usize];
            if (j as isize - p as isize).abs() <= reach {
                continue; // evict: window overlaps the closing gap (or j == p)
            }
            let nj = if j as usize > p { j - 1 } else { j };
            for (d, v) in col.iter_mut().enumerate() {
                v.remove(positions[d]);
            }
            self.stale.insert((dcol, nj));
            remap.insert((dcol, j), (dcol, nj));
            self.cols.insert((dcol, nj), col);
        }
        let order: Vec<(u32, u32)> =
            self.order.iter().filter_map(|k| remap.get(k).copied()).collect();
        self.order = order;
        self.visits.clear();
    }

    /// Batched form of [`MTildeCache::on_remove`]: one invalidation pass for
    /// a whole `forget_batch`. `positions[d]` holds dimension `d`'s
    /// *pre-removal* sorted positions of the forgotten points (batch data
    /// order). Overlap tests and splice-outs run in pre-removal coordinates
    /// (descending splice order keeps earlier indices valid); surviving keys
    /// shift down by the number of removals below them. Large batches and
    /// near-full caches truncate, mirroring
    /// [`MTildeCache::on_insert_batch`]'s counter behaviour.
    pub fn on_remove_batch(&mut self, positions: &[Vec<usize>], w: usize) {
        let m = positions.first().map(|p| p.len()).unwrap_or(0);
        if m == 0 {
            return;
        }
        if m == 1 {
            let pos: Vec<usize> = positions.iter().map(|p| p[0]).collect();
            self.on_remove(&pos, w);
            return;
        }
        if self.cols.len() > Self::REMAP_MAX_COLS || m > Self::REMAP_MAX_BATCH {
            self.clear_truncated();
            return;
        }
        let sorted: Vec<Vec<usize>> = positions
            .iter()
            .map(|p| {
                let mut q = p.clone();
                q.sort_unstable();
                q
            })
            .collect();
        let reach = (2 * w) as isize;
        // Column remapping is order-independent (see on_insert).
        // lint: hashmap-order-ok
        let old: Vec<((u32, u32), Vec<Vec<f64>>)> = self.cols.drain().collect();
        self.stale.clear();
        let mut remap: HashMap<(u32, u32), (u32, u32)> = HashMap::new();
        'cols: for ((dcol, j), mut col) in old {
            let qs = &sorted[dcol as usize];
            let mut shift = 0usize;
            for &q in qs {
                if (j as isize - q as isize).abs() <= reach {
                    continue 'cols; // evict: a removal hit its window
                }
                if q < j as usize {
                    shift += 1;
                }
            }
            let nj = j as usize - shift;
            for (d, v) in col.iter_mut().enumerate() {
                for &q in sorted[d].iter().rev() {
                    v.remove(q);
                }
            }
            let key = (dcol, nj as u32);
            self.stale.insert(key);
            remap.insert((dcol, j), key);
            self.cols.insert(key, col);
        }
        let order: Vec<(u32, u32)> =
            self.order.iter().filter_map(|k| remap.get(k).copied()).collect();
        self.order = order;
        self.visits.clear();
    }

    /// Count a visit to a window signature; returns the previous count.
    fn visit(&mut self, starts: &[usize]) -> u32 {
        let key: Vec<u32> = starts.iter().map(|&s| s as u32).collect();
        let c = self.visits.entry(key).or_insert(0);
        let prev = *c;
        *c += 1;
        prev
    }

    /// How many of the window columns for `(dcol, j)` are resident and
    /// fresh (stale columns still cost a solve, so they don't count).
    fn cached_count(&self, needs: &[(usize, usize)]) -> usize {
        needs
            .iter()
            .filter(|&&(d, j)| {
                let key = (d as u32, j as u32);
                self.cols.contains_key(&key) && !self.stale.contains(&key)
            })
            .count()
    }

    pub fn len(&self) -> usize {
        self.cols.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cols.is_empty()
    }

    /// Column `(d', j)` of `M̃` (all `D × n` sorted-coordinate entries).
    ///
    /// A stale column (carried across an incremental observe) is re-solved
    /// before being served, using the stale values as the PCG warm start —
    /// exact results at a fraction of a cold miss.
    fn column<'c>(
        &'c mut self,
        dims: &[DimFactor],
        gs: &GaussSeidel,
        dcol: usize,
        j: usize,
    ) -> &'c Vec<Vec<f64>> {
        let key = (dcol as u32, j as u32);
        let resident = self.cols.contains_key(&key);
        let is_stale = resident && self.stale.contains(&key);
        if resident && !is_stale {
            self.hits += 1;
        } else {
            if is_stale {
                self.refreshes += 1;
            } else {
                self.misses += 1;
                if self.capacity > 0 && self.cols.len() >= self.capacity {
                    // Evict the oldest half to amortize.
                    let drop = self.order.len() / 2;
                    for k in self.order.drain(..drop) {
                        self.cols.remove(&k);
                        self.stale.remove(&k);
                    }
                }
            }
            // Warm start: recover u from the stale column via u_d = P_d Φ_d^T col_d.
            let guess: Option<BlockVec> = if is_stale {
                let colv = self.cols.get(&key).unwrap();
                Some(
                    dims.iter()
                        .zip(colv)
                        .map(|(dim, cd)| dim.kp.perm.to_original(&dim.kp.phi.matvec_t(cd)))
                        .collect(),
                )
            } else {
                None
            };
            let n = dims[0].n();
            // z = P Φ^{-1} e_j  (block d' only), data order.
            let mut e = vec![0.0; n];
            e[j] = 1.0;
            let z_s = dims[dcol].phi_lu.solve(&e);
            let z = dims[dcol].kp.perm.to_original(&z_s);
            let mut rhs: BlockVec = vec![vec![0.0; n]; dims.len()];
            rhs[dcol] = z;
            let (u, _) = gs.solve_from(&rhs, guess.as_ref());
            // col_d = Φ_d^{-T} (P_d^T u_d), sorted coordinates.
            let col: Vec<Vec<f64>> = dims
                .iter()
                .zip(&u)
                .map(|(dim, ud)| dim.phit_lu.solve(&dim.kp.perm.to_sorted(ud)))
                .collect();
            self.stale.remove(&key);
            if !resident {
                self.order.push(key);
            }
            self.cols.insert(key, col);
        }
        self.cols.get(&key).unwrap()
    }

    /// [`Audit`] plus the context the cache cannot know by itself: every key
    /// must reference a live dimension (`dcol < d`) and sorted index
    /// (`j < n`), and every resident column must hold `d` blocks of length
    /// `n`. Called by `PosteriorSnapshot::audit`, which owns that context.
    pub fn audit_with(&self, d: usize, n: usize) -> Result<(), AuditError> {
        self.audit()?;
        for (&(dcol, j), col) in &self.cols {
            if dcol as usize >= d || j as usize >= n {
                return Err(AuditError::new(
                    "MTildeCache",
                    "cols",
                    Some(j as usize),
                    format!("key ({dcol}, {j}) outside model shape D = {d}, n = {n}"),
                ));
            }
            if col.len() != d || col.iter().any(|v| v.len() != n) {
                return Err(AuditError::new(
                    "MTildeCache",
                    "cols",
                    Some(j as usize),
                    format!("column ({dcol}, {j}) shape disagrees with D = {d}, n = {n}"),
                ));
            }
        }
        Ok(())
    }
}

impl Audit for MTildeCache {
    /// Context-free structural checks: the stale set only marks resident
    /// columns, and the eviction `order` log is exactly the resident keyset
    /// (no duplicates, nothing dangling) — `column()`'s amortized eviction
    /// relies on that bijection.
    fn audit(&self) -> Result<(), AuditError> {
        for key in &self.stale {
            if !self.cols.contains_key(key) {
                return Err(AuditError::new(
                    "MTildeCache",
                    "stale",
                    Some(key.1 as usize),
                    format!("stale mark ({}, {}) has no resident column", key.0, key.1),
                ));
            }
        }
        if self.order.len() != self.cols.len() {
            return Err(AuditError::new(
                "MTildeCache",
                "order",
                None,
                format!(
                    "eviction order tracks {} keys but {} columns are resident",
                    self.order.len(),
                    self.cols.len()
                ),
            ));
        }
        let mut seen: HashSet<(u32, u32)> = HashSet::with_capacity(self.order.len());
        for (i, key) in self.order.iter().enumerate() {
            if !seen.insert(*key) {
                return Err(AuditError::new(
                    "MTildeCache",
                    "order",
                    Some(i),
                    format!("duplicate eviction entry ({}, {})", key.0, key.1),
                ));
            }
            if !self.cols.contains_key(key) {
                return Err(AuditError::new(
                    "MTildeCache",
                    "order",
                    Some(i),
                    format!("eviction entry ({}, {}) has no resident column", key.0, key.1),
                ));
            }
        }
        Ok(())
    }
}

/// Output of a full posterior evaluation at one point.
#[derive(Clone, Debug)]
pub struct PredictOut {
    pub mean: f64,
    pub var: f64,
    /// `∇μ` (empty unless gradients were requested).
    pub mean_grad: Vec<f64>,
    /// `∇s` (empty unless gradients were requested).
    pub var_grad: Vec<f64>,
}

/// Posterior variance (and optionally its gradient) at `x`, using the
/// `M̃`-column cache: `O(1)` amortized when the window columns are cached,
/// one `O(Dn)` Algorithm 4 solve per uncached column otherwise. Builds the
/// lazy band-of-inverse on first use, then delegates to
/// [`predict_prebuilt`].
pub fn predict_cached(
    dims: &mut [DimFactor],
    sigma2_y: f64,
    post: &Posterior,
    cache: &mut MTildeCache,
    x: &[f64],
    want_grad: bool,
) -> PredictOut {
    for dim in dims.iter_mut() {
        let _ = dim.c_band();
    }
    predict_prebuilt(dims, sigma2_y, post, cache, x, want_grad)
}

/// [`predict_cached`] over *immutable* factorizations — the concurrent
/// read path of the coordinator's
/// [`crate::gp::fit_state::PosteriorSnapshot`]. Identical math; the only
/// difference is that every dimension's band-of-inverse must already be
/// materialized (panics otherwise — snapshot construction guarantees it).
pub fn predict_prebuilt(
    dims: &[DimFactor],
    sigma2_y: f64,
    post: &Posterior,
    cache: &mut MTildeCache,
    x: &[f64],
    want_grad: bool,
) -> PredictOut {
    let ddim = dims.len();
    // Gather windows first.
    let mut windows = Vec::with_capacity(ddim);
    for (d, dim) in dims.iter().enumerate() {
        let (start, vals) = dim.kp.phi_window(x[d]);
        let dvals = if want_grad { dim.kp.dphi_window(x[d]).1 } else { Vec::new() };
        debug_assert!(dim.has_c_band(), "c_band must be prebuilt");
        windows.push((start, vals, dvals));
        let _ = d;
    }

    let mut mean_acc = 0.0;
    let mut term1 = 0.0;
    let mut term2 = 0.0;
    let mut mean_grad = vec![0.0; if want_grad { ddim } else { 0 }];
    // dφ_d^T C_d φ_d per dim (for the variance gradient).
    let mut dterm2 = vec![0.0; if want_grad { ddim } else { 0 }];
    for (d, dim) in dims.iter().enumerate() {
        let (start, vals, dvals) = &windows[d];
        term1 += dim.kernel().k(x[d], x[d]);
        let c = dim.c_band_cached().expect("c_band prebuilt for predict");
        for (r, &vr) in vals.iter().enumerate() {
            mean_acc += vr * post.b[d][start + r];
            for (s, &vs) in vals.iter().enumerate() {
                term2 += vr * vs * c.get(start + r, start + s);
            }
        }
        if want_grad {
            for (r, &dv) in dvals.iter().enumerate() {
                mean_grad[d] += dv * post.b[d][start + r];
                for (s, &vs) in vals.iter().enumerate() {
                    dterm2[d] += dv * vs * c.get(start + r, start + s);
                }
            }
        }
    }

    // term3 = Σ_{d,d'} φ_d^T M̃_{d,d'} φ_{d'}.
    //
    // Cold-start policy (perf; DESIGN.md §Perf): the column cache only
    // pays off when a window region is revisited (gradient-ascent steps).
    // On the *first* visit to a window signature with mostly-cold columns we
    // answer with ONE Algorithm 4 solve (`u = M^{-1} P Φ^{-1} φ`), which
    // also yields the gradient via `M̃φ = Φ^{-T} P^T u`; columns are only
    // materialized from the second visit on.
    let gs = GaussSeidel::new(dims, sigma2_y);
    let n = dims[0].n();
    let needs: Vec<(usize, usize)> = windows
        .iter()
        .enumerate()
        .flat_map(|(d, (start, vals, _))| (0..vals.len()).map(move |s| (d, start + s)))
        .collect();
    let prev_visits = cache.visit(&windows.iter().map(|w| w.0).collect::<Vec<_>>());
    let mostly_cold = cache.cached_count(&needs) * 2 < needs.len();
    let mut term3 = 0.0;
    let mut dterm3 = vec![0.0; if want_grad { ddim } else { 0 }];
    if prev_visits == 0 && mostly_cold {
        cache.single_solves += 1;
        // z = P Φ^{-1} φ (all dims at once), one backfit solve.
        let mut z: BlockVec = vec![vec![0.0; n]; ddim];
        for (d, dim) in dims.iter().enumerate() {
            let (start, vals, _) = &windows[d];
            let mut phi_sparse = vec![0.0; n];
            for (r, &vr) in vals.iter().enumerate() {
                phi_sparse[start + r] = vr;
            }
            let z_s = dim.phi_lu.solve(&phi_sparse);
            z[d] = dim.kp.perm.to_original(&z_s);
        }
        let (u, _) = gs.solve(&z);
        term3 = z
            .iter()
            .zip(&u)
            .map(|(zd, ud)| zd.iter().zip(ud).map(|(a, b)| a * b).sum::<f64>())
            .sum();
        if want_grad {
            for (d, dim) in dims.iter().enumerate() {
                let mphi = dim.phit_lu.solve(&dim.kp.perm.to_sorted(&u[d]));
                let (start, _, dvals) = &windows[d];
                for (r, &dv) in dvals.iter().enumerate() {
                    dterm3[d] += dv * mphi[start + r];
                }
            }
        }
    } else {
        for dcol in 0..ddim {
            let (start_c, vals_c, _) = windows[dcol].clone();
            for (s, &vs) in vals_c.iter().enumerate() {
                if vs == 0.0 {
                    continue;
                }
                let col = cache.column(dims, &gs, dcol, start_c + s);
                for (d, (start, vals, dvals)) in windows.iter().enumerate() {
                    for (r, &vr) in vals.iter().enumerate() {
                        term3 += vr * vs * col[d][start + r];
                    }
                    if want_grad {
                        for (r, &dv) in dvals.iter().enumerate() {
                            dterm3[d] += dv * vs * col[d][start + r];
                        }
                    }
                }
            }
        }
    }

    let var = (term1 - term2 + term3).max(0.0);
    let var_grad = if want_grad {
        (0..ddim).map(|d| -2.0 * dterm2[d] + 2.0 * dterm3[d]).collect()
    } else {
        Vec::new()
    };
    PredictOut { mean: mean_acc, var, mean_grad, var_grad }
}

/// Fixed-shape window payload for one query — the exact input row of the
/// AOT-compiled `window_acq` kernel (`python/compile/model.py`). Windows
/// shorter than `w_max` are left-aligned and zero-padded (padded slots
/// contribute nothing to any contraction).
#[derive(Clone, Debug)]
pub struct QueryWindows {
    pub w_max: usize,
    /// Per-dim window start (sorted index).
    pub starts: Vec<usize>,
    pub lens: Vec<usize>,
    /// `[D, W]` row-major.
    pub phi: Vec<f64>,
    pub dphi: Vec<f64>,
    pub bwin: Vec<f64>,
    /// `[D, W, W]` — C_d window blocks.
    pub cwin: Vec<f64>,
    /// `[D, W, D, W]` — M̃ window blocks.
    pub mwin: Vec<f64>,
    pub kdiag: f64,
}

/// Gather the full window payload at `x` (mean/variance/gradients become
/// pure contractions — executed either natively or by the PJRT kernel).
/// Costs `O(D log n)` searches plus cache misses as in [`predict_cached`].
pub fn gather_windows(
    dims: &mut [DimFactor],
    sigma2_y: f64,
    post: &Posterior,
    cache: &mut MTildeCache,
    x: &[f64],
) -> QueryWindows {
    let ddim = dims.len();
    let w_max = 2 * dims[0].kp.w();
    let mut windows = Vec::with_capacity(ddim);
    for (d, dim) in dims.iter_mut().enumerate() {
        let (start, vals) = dim.kp.phi_window(x[d]);
        let dvals = dim.kp.dphi_window(x[d]).1;
        dim.c_band();
        windows.push((start, vals, dvals));
        let _ = d;
    }
    let mut out = QueryWindows {
        w_max,
        starts: windows.iter().map(|w| w.0).collect(),
        lens: windows.iter().map(|w| w.1.len()).collect(),
        phi: vec![0.0; ddim * w_max],
        dphi: vec![0.0; ddim * w_max],
        bwin: vec![0.0; ddim * w_max],
        cwin: vec![0.0; ddim * w_max * w_max],
        mwin: vec![0.0; ddim * w_max * ddim * w_max],
        kdiag: 0.0,
    };
    for (d, dim) in dims.iter().enumerate() {
        let (start, vals, dvals) = &windows[d];
        out.kdiag += dim.kernel().k(x[d], x[d]);
        let c = dim.c_band_cached().unwrap();
        for (r, &v) in vals.iter().enumerate() {
            out.phi[d * w_max + r] = v;
            out.dphi[d * w_max + r] = dvals[r];
            out.bwin[d * w_max + r] = post.b[d][start + r];
            for s in 0..vals.len() {
                out.cwin[(d * w_max + r) * w_max + s] = c.get(start + r, start + s);
            }
        }
    }
    // M̃ blocks via cached columns.
    let gs = GaussSeidel::new(dims, sigma2_y);
    for dcol in 0..ddim {
        let (start_c, len_c) = (windows[dcol].0, windows[dcol].1.len());
        for s in 0..len_c {
            let col = cache.column(dims, &gs, dcol, start_c + s);
            for (d, (start, vals, _)) in windows.iter().enumerate() {
                for r in 0..vals.len() {
                    // mwin[d, r, dcol, s]
                    let idx = ((d * w_max + r) * ddim + dcol) * w_max + s;
                    out.mwin[idx] = col[d][start + r];
                }
            }
        }
    }
    out
}

/// Posterior variance at `x` *without* the cache — one Algorithm 4 solve
/// (`O(Dn)`) per query; the "predetermined predictive point" path of §5.2.
pub fn variance_direct(dims: &mut [DimFactor], sigma2_y: f64, x: &[f64]) -> f64 {
    let ddim = dims.len();
    let n = dims[0].n();
    let mut windows = Vec::with_capacity(ddim);
    for (d, dim) in dims.iter_mut().enumerate() {
        let w = dim.kp.phi_window(x[d]);
        dim.c_band();
        windows.push(w);
        let _ = d;
    }
    let mut term1 = 0.0;
    let mut term2 = 0.0;
    let mut z: BlockVec = vec![vec![0.0; n]; ddim];
    for (d, dim) in dims.iter().enumerate() {
        let (start, vals) = &windows[d];
        term1 += dim.kernel().k(x[d], x[d]);
        let c = dim.c_band_cached().unwrap();
        let mut phi_sparse = vec![0.0; n];
        for (r, &vr) in vals.iter().enumerate() {
            phi_sparse[start + r] = vr;
            for (s, &vs) in vals.iter().enumerate() {
                term2 += vr * vs * c.get(start + r, start + s);
            }
        }
        let z_s = dim.phi_lu.solve(&phi_sparse);
        z[d] = dim.kp.perm.to_original(&z_s);
    }
    let gs = GaussSeidel::new(dims, sigma2_y);
    let (u, _) = gs.solve(&z);
    let term3: f64 = z
        .iter()
        .zip(&u)
        .map(|(zd, ud)| zd.iter().zip(ud).map(|(a, b)| a * b).sum::<f64>())
        .sum();
    (term1 - term2 + term3).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::matern::{Matern, Nu};
    use crate::linalg::Dense;
    use crate::util::Rng;

    /// Dense-oracle additive GP posterior (standard eq. 1 with the summed
    /// kernel) for verification.
    struct DenseOracle {
        x_cols: Vec<Vec<f64>>, // D × n
        kernels: Vec<Matern>,
        sigma2: f64,
        kinv: Dense, // (Σ_d K_d + σ²I)^{-1}
        alpha: Vec<f64>,
    }

    impl DenseOracle {
        fn new(x_cols: &[Vec<f64>], kernels: &[Matern], sigma2: f64, y: &[f64]) -> Self {
            let n = y.len();
            let mut sig = Dense::zeros(n, n);
            for (d, k) in kernels.iter().enumerate() {
                for i in 0..n {
                    for j in 0..n {
                        sig.add(i, j, k.k(x_cols[d][i], x_cols[d][j]));
                    }
                }
            }
            for i in 0..n {
                sig.add(i, i, sigma2);
            }
            let kinv = sig.inverse();
            let alpha = kinv.matvec(y);
            DenseOracle { x_cols: x_cols.to_vec(), kernels: kernels.to_vec(), sigma2, kinv, alpha }
        }

        fn kvec(&self, x: &[f64]) -> Vec<f64> {
            let n = self.alpha.len();
            (0..n)
                .map(|i| {
                    self.kernels
                        .iter()
                        .enumerate()
                        .map(|(d, k)| k.k(self.x_cols[d][i], x[d]))
                        .sum()
                })
                .collect()
        }

        fn mean(&self, x: &[f64]) -> f64 {
            self.kvec(x).iter().zip(&self.alpha).map(|(a, b)| a * b).sum()
        }

        fn var(&self, x: &[f64]) -> f64 {
            let kv = self.kvec(x);
            let kk: f64 = self.kernels.iter().map(|k| k.k(0.0, 0.0)).sum();
            let quad: f64 = kv.iter().zip(self.kinv.matvec(&kv)).map(|(a, b)| a * b).sum();
            let _ = self.sigma2;
            kk - quad
        }
    }

    fn setup(
        n: usize,
        ddim: usize,
        nu: Nu,
        sigma2: f64,
        seed: u64,
    ) -> (Vec<Vec<f64>>, Vec<Matern>, Vec<f64>, Vec<DimFactor>) {
        let mut rng = Rng::new(seed);
        let x_cols: Vec<Vec<f64>> = (0..ddim).map(|_| rng.uniform_vec(n, 0.0, 4.0)).collect();
        let kernels: Vec<Matern> =
            (0..ddim).map(|d| Matern::new(nu, 0.8 + 0.15 * d as f64)).collect();
        let y: Vec<f64> = (0..n)
            .map(|i| {
                (0..ddim).map(|d| (x_cols[d][i] * 1.3).sin()).sum::<f64>() + 0.1 * rng.normal()
            })
            .collect();
        let dims: Vec<DimFactor> = (0..ddim)
            .map(|d| DimFactor::new(&x_cols[d], kernels[d], sigma2))
            .collect();
        (x_cols, kernels, y, dims)
    }

    #[test]
    fn posterior_mean_matches_dense() {
        let sigma2 = 1.0;
        for (nu, ddim) in [(Nu::Half, 2), (Nu::ThreeHalves, 3)] {
            let (x_cols, kernels, y, dims) = setup(25, ddim, nu, sigma2, 10);
            let gs = GaussSeidel::new(&dims, sigma2);
            let post = compute_posterior(&dims, &y, &gs);
            let oracle = DenseOracle::new(&x_cols, &kernels, sigma2, &y);
            let mut rng = Rng::new(20);
            for _ in 0..8 {
                let x: Vec<f64> = (0..ddim).map(|_| rng.uniform_in(0.2, 3.8)).collect();
                let got = mean(&dims, &post, &x);
                let want = oracle.mean(&x);
                assert!(
                    (got - want).abs() < 1e-6 * want.abs().max(1.0),
                    "{nu:?} D={ddim}: {got} vs {want}"
                );
            }
        }
    }

    #[test]
    fn posterior_variance_matches_dense_direct() {
        let sigma2 = 0.8;
        for (nu, ddim) in [(Nu::Half, 2), (Nu::ThreeHalves, 2)] {
            let (x_cols, kernels, y, mut dims) = setup(22, ddim, nu, sigma2, 30);
            let oracle = DenseOracle::new(&x_cols, &kernels, sigma2, &y);
            let mut rng = Rng::new(31);
            for _ in 0..6 {
                let x: Vec<f64> = (0..ddim).map(|_| rng.uniform_in(0.2, 3.8)).collect();
                let got = variance_direct(&mut dims, sigma2, &x);
                let want = oracle.var(&x);
                assert!(
                    (got - want).abs() < 1e-5 * want.abs().max(1.0),
                    "{nu:?}: var {got} vs {want}"
                );
            }
        }
    }

    #[test]
    fn cached_predict_matches_direct() {
        let sigma2 = 1.0;
        let (_xc, _k, y, mut dims) = setup(20, 3, Nu::Half, sigma2, 40);
        let gs_post = {
            let gs = GaussSeidel::new(&dims, sigma2);
            compute_posterior(&dims, &y, &gs)
        };
        let mut cache = MTildeCache::new(0);
        let mut rng = Rng::new(41);
        for _ in 0..5 {
            let x: Vec<f64> = (0..3).map(|_| rng.uniform_in(0.3, 3.7)).collect();
            let out = predict_cached(&mut dims, sigma2, &gs_post, &mut cache, &x, false);
            let direct = variance_direct(&mut dims, sigma2, &x);
            assert!(
                (out.var - direct).abs() < 1e-6 * direct.max(1.0),
                "var {} vs {}",
                out.var,
                direct
            );
            let m = mean(&dims, &gs_post, &x);
            assert!((out.mean - m).abs() < 1e-12);
        }
        // Every point was fresh, so all went through the single-solve path.
        assert!(cache.single_solves > 0);
    }

    #[test]
    fn cache_hits_on_nearby_points() {
        let sigma2 = 1.0;
        let (_xc, _k, y, mut dims) = setup(30, 2, Nu::Half, sigma2, 50);
        let post = {
            let gs = GaussSeidel::new(&dims, sigma2);
            compute_posterior(&dims, &y, &gs)
        };
        let mut cache = MTildeCache::new(0);
        let x = vec![1.5, 2.0];
        // 1st visit: answered by the one-shot single-solve path.
        let _ = predict_cached(&mut dims, sigma2, &post, &mut cache, &x, true);
        assert_eq!(cache.single_solves, 1);
        // 2nd visit (tiny step, same windows): columns get materialized.
        let x2 = vec![1.5 + 1e-6, 2.0 - 1e-6];
        let _ = predict_cached(&mut dims, sigma2, &post, &mut cache, &x2, true);
        let misses_second = cache.misses;
        assert!(misses_second > 0);
        // 3rd+ visits: pure cache hits — the paper's O(1) step.
        let x3 = vec![1.5 + 2e-6, 2.0 - 2e-6];
        let _ = predict_cached(&mut dims, sigma2, &post, &mut cache, &x3, true);
        assert_eq!(cache.misses, misses_second, "warm step should not miss");
        assert!(cache.hits > 0);
    }

    /// A stale mark without a resident column breaks the cache's structural
    /// story and is pinpointed by key.
    #[test]
    fn audit_flags_dangling_stale_mark() {
        let sigma2 = 1.0;
        let (_xc, _k, y, mut dims) = setup(20, 2, Nu::Half, sigma2, 70);
        let post = {
            let gs = GaussSeidel::new(&dims, sigma2);
            compute_posterior(&dims, &y, &gs)
        };
        let mut cache = MTildeCache::new(0);
        let x = vec![1.5, 2.0];
        let _ = predict_cached(&mut dims, sigma2, &post, &mut cache, &x, false);
        let _ = predict_cached(&mut dims, sigma2, &post, &mut cache, &x, false);
        assert!(cache.audit().is_ok());
        assert!(cache.audit_with(2, 20).is_ok());
        // x = [1.5, 2.0] touches mid-array windows only, so the extreme
        // sorted index 19 is never resident: a guaranteed-dangling mark.
        assert!(!cache.cols.contains_key(&(0, 19)));
        cache.stale.insert((0, 19));
        let e = cache.audit().unwrap_err();
        assert_eq!(e.structure, "MTildeCache");
        assert_eq!(e.field, "stale");
        assert_eq!(e.index, Some(19));
    }

    /// Keys referencing rows beyond the model's `n` fail the contextual
    /// audit (the shape check snapshots rely on).
    #[test]
    fn audit_with_flags_out_of_range_key() {
        let sigma2 = 1.0;
        let (_xc, _k, y, mut dims) = setup(20, 2, Nu::Half, sigma2, 71);
        let post = {
            let gs = GaussSeidel::new(&dims, sigma2);
            compute_posterior(&dims, &y, &gs)
        };
        let mut cache = MTildeCache::new(0);
        let x = vec![1.2, 2.6];
        let _ = predict_cached(&mut dims, sigma2, &post, &mut cache, &x, false);
        let _ = predict_cached(&mut dims, sigma2, &post, &mut cache, &x, false);
        assert!(cache.len() > 0);
        // Same columns, judged against a *smaller* claimed n: out of range.
        assert!(cache.audit_with(2, 1).is_err());
    }

    /// The size-triggered truncation paths count themselves; plain clears
    /// (refits) do not.
    #[test]
    fn truncation_clears_are_counted() {
        let mut cache = MTildeCache::new(0);
        cache.clear();
        assert_eq!(cache.truncation_clears, 0);
        // A batch wider than REMAP_MAX_BATCH forces the truncating clear
        // even with nothing resident... except the m==0/resident==0 path
        // still enters the clear branch. Seed one fake column first.
        cache.cols.insert((0, 0), vec![vec![0.0; 4]]);
        cache.order.push((0, 0));
        let positions = vec![(0..MTildeCache::REMAP_MAX_BATCH + 1).collect::<Vec<usize>>()];
        cache.on_insert_batch(&positions, 1);
        assert_eq!(cache.truncation_clears, 1);
        assert!(cache.is_empty());
        // Removal parity: the forget paths count truncations through the
        // same counter, so operators see thrown-away locality symmetrically.
        cache.cols.insert((0, 40), vec![vec![0.0; 40]]);
        cache.order.push((0, 40));
        let wide = vec![(0..MTildeCache::REMAP_MAX_BATCH + 1).collect::<Vec<usize>>()];
        cache.on_remove_batch(&wide, 1);
        assert_eq!(cache.truncation_clears, 2);
        assert!(cache.is_empty());
        // A plain clear still doesn't count.
        cache.clear();
        assert_eq!(cache.truncation_clears, 2);
    }

    /// `on_remove` evicts gap-overlapping columns, re-keys the survivors one
    /// slot down, splices the removed entry out of every block, and leaves a
    /// structurally valid (auditable) cache at the shrunk `n`.
    #[test]
    fn on_remove_rekeys_and_splices_out() {
        let mut cache = MTildeCache::new(0);
        let n = 12;
        let col = |tag: f64| vec![(0..n).map(|i| tag + i as f64).collect::<Vec<f64>>()];
        for j in [2u32, 4, 10] {
            cache.cols.insert((0, j), col(j as f64 * 100.0));
            cache.order.push((0, j));
        }
        // Remove sorted position 5 with w = 1 (reach 2): column 4 overlaps
        // the gap and is evicted; 2 keeps its key; 10 shifts to 9.
        cache.on_remove(&[5], 1);
        assert_eq!(cache.len(), 2);
        assert!(cache.cols.contains_key(&(0, 2)));
        assert!(cache.cols.contains_key(&(0, 9)));
        assert!(cache.stale.contains(&(0, 2)) && cache.stale.contains(&(0, 9)));
        // Entry 5 spliced out: survivors hold n-1 values with index 5 gone.
        let c2 = &cache.cols[&(0, 2)][0];
        assert_eq!(c2.len(), n - 1);
        assert_eq!(c2[4], 204.0);
        assert_eq!(c2[5], 206.0);
        assert!(cache.audit_with(1, n - 1).is_ok());
    }

    /// `on_remove_batch` matches the sequential single-remove story: same
    /// survivors, same re-keyed positions, same spliced-out blocks.
    #[test]
    fn on_remove_batch_matches_sequential() {
        let n = 20;
        let seed = |cache: &mut MTildeCache| {
            for j in [1u32, 8, 15, 18] {
                cache.cols.insert((0, j), vec![(0..n).map(|i| i as f64).collect()]);
                cache.order.push((0, j));
            }
        };
        let mut batched = MTildeCache::new(0);
        seed(&mut batched);
        batched.on_remove_batch(&[vec![5, 11]], 1);
        let mut seq = MTildeCache::new(0);
        seed(&mut seq);
        // Descending single removes keep pre-removal coordinates valid.
        seq.on_remove(&[11], 1);
        seq.on_remove(&[5], 1);
        assert_eq!(batched.len(), seq.len());
        for (key, col) in &batched.cols {
            assert_eq!(seq.cols.get(key), Some(col), "key {key:?}");
            assert!(seq.stale.contains(key));
        }
        assert!(batched.audit_with(1, n - 2).is_ok());
    }

    #[test]
    fn gradients_match_finite_difference() {
        let sigma2 = 1.0;
        let (_xc, _k, y, mut dims) = setup(24, 2, Nu::ThreeHalves, sigma2, 60);
        let post = {
            let gs = GaussSeidel::new(&dims, sigma2);
            compute_posterior(&dims, &y, &gs)
        };
        let mut cache = MTildeCache::new(0);
        let x = vec![1.7, 2.3];
        let out = predict_cached(&mut dims, sigma2, &post, &mut cache, &x, true);
        let h = 1e-6;
        for d in 0..2 {
            let mut xp = x.clone();
            xp[d] += h;
            let mut xm = x.clone();
            xm[d] -= h;
            let op = predict_cached(&mut dims, sigma2, &post, &mut cache, &xp, false);
            let om = predict_cached(&mut dims, sigma2, &post, &mut cache, &xm, false);
            let fd_mean = (op.mean - om.mean) / (2.0 * h);
            let fd_var = (op.var - om.var) / (2.0 * h);
            assert!(
                (fd_mean - out.mean_grad[d]).abs() < 1e-4 * fd_mean.abs().max(1.0),
                "d={d} mean grad {} vs fd {}",
                out.mean_grad[d],
                fd_mean
            );
            assert!(
                (fd_var - out.var_grad[d]).abs() < 1e-4 * fd_var.abs().max(1.0),
                "d={d} var grad {} vs fd {}",
                out.var_grad[d],
                fd_var
            );
        }
    }
}
