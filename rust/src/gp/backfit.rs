//! Paper **Algorithm 4**: block Gauss–Seidel ("back-fitting") solution of
//!
//! ```text
//! [K^{-1} + σ_y^{-2} S S^T] ṽ = v
//! ```
//!
//! where `K = diag(K_1, …, K_D)` and `S = [I; …; I]`. The system is SPD, so
//! Gauss–Seidel converges; each block-`d` update solves
//! `(K_d^{-1} + σ⁻²I) u = rhs`, which in sorted coordinates is the *banded*
//! system `(A_d + σ⁻²Φ_d) u = Φ_d · rhs` — `O(n)` per block per sweep.
//!
//! **Optimization over the paper** (see DESIGN.md §Perf): plain block GS
//! stalls when smooth components are shared between dimensions (classic
//! back-fitting concurvity — hundreds of sweeps at D=10). [`GaussSeidel::solve`]
//! therefore runs *conjugate gradients preconditioned by one symmetric block
//! GS (SSOR) sweep*, built from exactly the same banded block solves; the
//! paper-faithful plain iteration remains available as
//! [`GaussSeidel::solve_gs`]. Both are `O(Dn)` per iteration.

use crate::gp::dim::DimFactor;

/// A block vector in `ℝ^{Dn}`: one length-`n` vector per dimension, in
/// *data order* (original point indices, not sorted).
pub type BlockVec = Vec<Vec<f64>>;

/// Statistics from a solve.
#[derive(Clone, Copy, Debug)]
pub struct GsStats {
    /// Iterations used (PCG iterations or GS sweeps).
    pub sweeps: usize,
    pub rel_residual: f64,
}

/// The Algorithm 4 solver, borrowing the per-dimension factorizations.
pub struct GaussSeidel<'a> {
    pub dims: &'a [DimFactor],
    pub sigma2_y: f64,
    pub max_sweeps: usize,
    pub tol: f64,
}

/// Reusable buffers for the hot solve loops — one set per solve (or per
/// probe loop), so the per-iteration PCG / preconditioner work runs through
/// `BandedLU::solve_in_place` and the `_into` matvec/permutation forms
/// without allocating a single `Vec` (DESIGN.md §Perf).
pub struct GsScratch {
    /// Data-order accumulator (`Σ_d` running sums of both SSOR half-sweeps).
    acc: Vec<f64>,
    /// Data-order right-hand side under construction.
    rhs: Vec<f64>,
    /// Sorted-order staging buffer (solver inputs).
    sorted: Vec<f64>,
    /// Sorted-order output buffer (in-place banded solves).
    sorted2: Vec<f64>,
    /// Forward half-sweep results `t_d` of the SSOR preconditioner.
    t: BlockVec,
}

fn dot_blocks(a: &BlockVec, b: &BlockVec) -> f64 {
    a.iter()
        .zip(b)
        .flat_map(|(x, y)| x.iter().zip(y.iter()))
        .map(|(x, y)| x * y)
        .sum()
}

fn norm_blocks(a: &BlockVec) -> f64 {
    dot_blocks(a, a).sqrt()
}

impl<'a> GaussSeidel<'a> {
    pub fn new(dims: &'a [DimFactor], sigma2_y: f64) -> Self {
        GaussSeidel { dims, sigma2_y, max_sweeps: 200, tol: 1e-10 }
    }

    /// Solve `[K^{-1}+σ⁻²SS^T] ṽ = v` — PCG with a symmetric block-GS
    /// preconditioner (the production path).
    pub fn solve(&self, v: &BlockVec) -> (BlockVec, GsStats) {
        self.solve_from(v, None)
    }

    /// Fresh scratch buffers sized for this solver's dimensions. Create one
    /// per solve — or once per probe loop — and feed it to the `_into`
    /// methods; the per-iteration work then allocates nothing.
    pub fn scratch(&self) -> GsScratch {
        let n = self.dims[0].n();
        let dd = self.dims.len();
        GsScratch {
            acc: vec![0.0; n],
            rhs: vec![0.0; n],
            sorted: vec![0.0; n],
            sorted2: vec![0.0; n],
            t: vec![vec![0.0; n]; dd],
        }
    }

    /// [`GaussSeidel::solve`] with an optional warm start `x0`: the
    /// incremental-observe path seeds the iteration with the previous
    /// solution ṽ (extended by one entry), turning the posterior update into
    /// a handful of PCG iterations instead of a cold solve (DESIGN.md
    /// §FitState). Convergence is judged against `‖v‖` exactly as in the
    /// cold solve, so a warm start changes cost, never accuracy.
    ///
    /// All per-iteration work (operator + preconditioner applications) runs
    /// through reused scratch buffers — the only allocations are the
    /// once-per-solve result/direction vectors.
    pub fn solve_from(&self, v: &BlockVec, x0: Option<&BlockVec>) -> (BlockVec, GsStats) {
        let dd = self.dims.len();
        assert_eq!(v.len(), dd);
        let n = self.dims[0].n();
        let vnorm = norm_blocks(v).max(1e-300);
        let mut scratch = self.scratch();

        let (mut x, mut r) = match x0 {
            Some(x0) => {
                assert_eq!(x0.len(), dd);
                assert_eq!(x0[0].len(), n);
                let mut mx: BlockVec = vec![vec![0.0; n]; dd];
                self.apply_into(x0, &mut mx, &mut scratch);
                let r: BlockVec = v
                    .iter()
                    .zip(&mx)
                    .map(|(vb, mb)| vb.iter().zip(mb).map(|(a, b)| a - b).collect())
                    .collect();
                (x0.clone(), r)
            }
            None => (vec![vec![0.0; n]; dd], v.clone()),
        };
        let mut stats = GsStats { sweeps: 0, rel_residual: norm_blocks(&r) / vnorm };
        if stats.rel_residual < self.tol {
            return (x, stats); // warm start already converged
        }
        let mut z: BlockVec = vec![vec![0.0; n]; dd];
        self.precond_into(&r, &mut z, &mut scratch);
        let mut p = z.clone();
        let mut mp: BlockVec = vec![vec![0.0; n]; dd];
        let mut rz = dot_blocks(&r, &z);
        for it in 0..self.max_sweeps {
            self.apply_into(&p, &mut mp, &mut scratch);
            let pmp = dot_blocks(&p, &mp);
            if pmp <= 0.0 {
                break; // numerical breakdown; return best effort
            }
            let alpha = rz / pmp;
            for d in 0..dd {
                for i in 0..n {
                    x[d][i] += alpha * p[d][i];
                    r[d][i] -= alpha * mp[d][i];
                }
            }
            stats.sweeps = it + 1;
            stats.rel_residual = norm_blocks(&r) / vnorm;
            if stats.rel_residual < self.tol {
                break;
            }
            self.precond_into(&r, &mut z, &mut scratch);
            let rz_new = dot_blocks(&r, &z);
            let beta = rz_new / rz;
            rz = rz_new;
            for d in 0..dd {
                for i in 0..n {
                    p[d][i] = z[d][i] + beta * p[d][i];
                }
            }
        }
        (x, stats)
    }

    /// Paper-faithful **Algorithm 4**: plain block Gauss–Seidel sweeps.
    pub fn solve_gs(&self, v: &BlockVec) -> (BlockVec, GsStats) {
        let dd = self.dims.len();
        assert_eq!(v.len(), dd);
        let n = self.dims[0].n();
        let inv_s2 = 1.0 / self.sigma2_y;
        let mut tilde: BlockVec = vec![vec![0.0; n]; dd];
        let mut sum = vec![0.0; n];
        let vnorm = norm_blocks(v).max(1e-300);
        let mut stats = GsStats { sweeps: 0, rel_residual: f64::INFINITY };
        for sweep in 0..self.max_sweeps {
            for d in 0..dd {
                let dim = &self.dims[d];
                let mut rhs = vec![0.0; n];
                for i in 0..n {
                    rhs[i] = v[d][i] - inv_s2 * (sum[i] - tilde[d][i]);
                }
                let rhs_s = dim.kp.perm.to_sorted(&rhs);
                let u_s = dim.gs_block_solve_sorted(&rhs_s);
                let u = dim.kp.perm.to_original(&u_s);
                for i in 0..n {
                    sum[i] += u[i] - tilde[d][i];
                }
                tilde[d] = u;
            }
            stats.sweeps = sweep + 1;
            let r = self.residual_norm(v, &tilde, &sum);
            stats.rel_residual = r / vnorm;
            if stats.rel_residual < self.tol {
                break;
            }
        }
        (tilde, stats)
    }

    /// Symmetric block-GS (SSOR) preconditioner application
    /// `z = (D+U)^{-1} D (D+L)^{-1} r`, where `D` holds the diagonal blocks
    /// `K_d^{-1}+σ⁻²I` and `L = U^T` the `σ⁻²I` couplings. Runs entirely in
    /// the caller's scratch buffers — zero allocations.
    fn precond_into(&self, r: &BlockVec, z: &mut BlockVec, s: &mut GsScratch) {
        let dd = self.dims.len();
        let n = self.dims[0].n();
        debug_assert_eq!(r.len(), dd);
        debug_assert_eq!(z.len(), dd);
        debug_assert!(s.acc.len() == n && s.rhs.len() == n && s.t.len() == dd);
        let inv_s2 = 1.0 / self.sigma2_y;
        // Forward: t_d = D_d^{-1}(r_d − σ⁻² Σ_{d'<d} t_{d'}).
        s.acc.fill(0.0);
        for d in 0..dd {
            let dim = &self.dims[d];
            for i in 0..n {
                s.rhs[i] = r[d][i] - inv_s2 * s.acc[i];
            }
            dim.kp.perm.to_sorted_into(&s.rhs, &mut s.sorted);
            dim.gs_block_solve_sorted_into(&s.sorted, &mut s.sorted2);
            dim.kp.perm.to_original_into(&s.sorted2, &mut s.t[d]);
            for i in 0..n {
                s.acc[i] += s.t[d][i];
            }
        }
        // Middle: u_d = D_d t_d  (apply the diagonal block).
        // Backward: z_d = D_d^{-1}(u_d − σ⁻² Σ_{d'>d} z_{d'}).
        s.acc.fill(0.0); // now the backward accumulator
        for d in (0..dd).rev() {
            let dim = &self.dims[d];
            // u_d = D_d t_d = K_d^{-1} t_d + σ⁻² t_d
            dim.kp.perm.to_sorted_into(&s.t[d], &mut s.sorted);
            dim.kinv_sorted_into(&s.sorted, &mut s.sorted2);
            dim.kp.perm.to_original_into(&s.sorted2, &mut s.rhs);
            for i in 0..n {
                let u = s.rhs[i] + inv_s2 * s.t[d][i];
                s.rhs[i] = u - inv_s2 * s.acc[i];
            }
            dim.kp.perm.to_sorted_into(&s.rhs, &mut s.sorted);
            dim.gs_block_solve_sorted_into(&s.sorted, &mut s.sorted2);
            dim.kp.perm.to_original_into(&s.sorted2, &mut z[d]);
            for i in 0..n {
                s.acc[i] += z[d][i];
            }
        }
    }

    /// Apply the system operator `M = K^{-1} + σ⁻²SS^T` to a block vector.
    pub fn apply(&self, x: &BlockVec) -> BlockVec {
        let n = self.dims[0].n();
        let mut out: BlockVec = vec![vec![0.0; n]; self.dims.len()];
        let mut s = self.scratch();
        self.apply_into(x, &mut out, &mut s);
        out
    }

    /// [`GaussSeidel::apply`] into caller-owned output and scratch — the
    /// allocation-free form the PCG loop and the stochastic estimators use.
    pub fn apply_into(&self, x: &BlockVec, out: &mut BlockVec, s: &mut GsScratch) {
        let n = self.dims[0].n();
        debug_assert_eq!(x.len(), self.dims.len());
        debug_assert_eq!(out.len(), self.dims.len());
        debug_assert!(s.acc.len() == n && s.sorted.len() == n && s.sorted2.len() == n);
        let inv_s2 = 1.0 / self.sigma2_y;
        s.acc.fill(0.0);
        for b in x {
            for i in 0..n {
                s.acc[i] += b[i];
            }
        }
        for (d, dim) in self.dims.iter().enumerate() {
            dim.kp.perm.to_sorted_into(&x[d], &mut s.sorted);
            dim.kinv_sorted_into(&s.sorted, &mut s.sorted2);
            dim.kp.perm.to_original_into(&s.sorted2, &mut out[d]);
            for i in 0..n {
                out[d][i] += inv_s2 * s.acc[i];
            }
        }
    }

    fn residual_norm(&self, v: &BlockVec, tilde: &BlockVec, sum: &[f64]) -> f64 {
        let n = self.dims[0].n();
        let inv_s2 = 1.0 / self.sigma2_y;
        let mut acc = 0.0;
        for (d, dim) in self.dims.iter().enumerate() {
            let ts = dim.kp.perm.to_sorted(&tilde[d]);
            let kinv = dim.kinv_sorted(&ts);
            let kinv_o = dim.kp.perm.to_original(&kinv);
            for i in 0..n {
                let r = kinv_o[i] + inv_s2 * sum[i] - v[d][i];
                acc += r * r;
            }
        }
        acc.sqrt()
    }

    /// Convenience: solve with the *shared* right-hand side `S w / σ²`
    /// (every block gets `w/σ²`) — the `b_Y` path of eq. (12).
    pub fn solve_shared(&self, w: &[f64]) -> (BlockVec, GsStats) {
        self.solve_shared_from(w, None)
    }

    /// [`GaussSeidel::solve_shared`] with an optional warm start.
    pub fn solve_shared_from(
        &self,
        w: &[f64],
        x0: Option<&BlockVec>,
    ) -> (BlockVec, GsStats) {
        let inv_s2 = 1.0 / self.sigma2_y;
        let v: BlockVec = (0..self.dims.len())
            .map(|_| w.iter().map(|&x| x * inv_s2).collect())
            .collect();
        self.solve_from(&v, x0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::matern::{Matern, Nu};
    use crate::linalg::Dense;
    use crate::util::Rng;

    fn make_dims(n: usize, d: usize, nu: Nu, sigma2: f64, seed: u64) -> Vec<DimFactor> {
        let mut rng = Rng::new(seed);
        (0..d)
            .map(|i| {
                let pts = rng.uniform_vec(n, 0.0, 3.0 + i as f64);
                DimFactor::new(&pts, Matern::new(nu, 0.9 + 0.2 * i as f64), sigma2)
            })
            .collect()
    }

    /// Build the dense `K^{-1}+σ⁻²SS^T` in data order for verification.
    fn dense_system(dims: &[DimFactor], sigma2: f64) -> Dense {
        let n = dims[0].n();
        let dd = dims.len();
        let mut m = Dense::zeros(dd * n, dd * n);
        for (d, dim) in dims.iter().enumerate() {
            let k = dim.kernel().gram(&dim.kp.xs);
            let kinv_sorted = k.inverse();
            for i in 0..n {
                for j in 0..n {
                    let io = dim.kp.perm.orig(i);
                    let jo = dim.kp.perm.orig(j);
                    m.add(d * n + io, d * n + jo, kinv_sorted.get(i, j));
                }
            }
        }
        for d1 in 0..dd {
            for d2 in 0..dd {
                for i in 0..n {
                    m.add(d1 * n + i, d2 * n + i, 1.0 / sigma2);
                }
            }
        }
        m
    }

    #[test]
    fn matches_dense_solve_d1() {
        let sigma2 = 0.7;
        let dims = make_dims(20, 1, Nu::ThreeHalves, sigma2, 1);
        let gs = GaussSeidel::new(&dims, sigma2);
        let mut rng = Rng::new(2);
        let v: BlockVec = vec![rng.normal_vec(20)];
        let (tilde, stats) = gs.solve(&v);
        assert!(stats.rel_residual < 1e-9, "residual {}", stats.rel_residual);

        let m = dense_system(&dims, sigma2);
        let want = m.solve(&v[0]);
        // Both solutions carry cond(M)·ε error; compare via residuals in M.
        let scale = want.iter().fold(0.0f64, |a, &b| a.max(b.abs()));
        for i in 0..20 {
            assert!(
                (tilde[0][i] - want[i]).abs() < 1e-5 * scale.max(1.0),
                "i={i}: {} vs {}",
                tilde[0][i],
                want[i]
            );
        }
    }

    #[test]
    fn matches_dense_solve_d3() {
        let sigma2 = 1.0;
        let dims = make_dims(15, 3, Nu::Half, sigma2, 3);
        let gs = GaussSeidel::new(&dims, sigma2);
        let mut rng = Rng::new(4);
        let v: BlockVec = (0..3).map(|_| rng.normal_vec(15)).collect();
        let (tilde, stats) = gs.solve(&v);
        assert!(stats.rel_residual < 1e-8, "residual {}", stats.rel_residual);

        let m = dense_system(&dims, sigma2);
        let vflat: Vec<f64> = v.iter().flat_map(|b| b.iter().copied()).collect();
        let want = m.solve(&vflat);
        let scale = want.iter().fold(0.0f64, |a, &b| a.max(b.abs()));
        for d in 0..3 {
            for i in 0..15 {
                assert!(
                    (tilde[d][i] - want[d * 15 + i]).abs() < 1e-6 * scale.max(1.0),
                    "d={d} i={i}: {} vs {}",
                    tilde[d][i],
                    want[d * 15 + i]
                );
            }
        }
    }

    /// The paper-faithful plain GS agrees with PCG (to its residual).
    #[test]
    fn plain_gs_agrees_with_pcg() {
        let sigma2 = 1.0;
        let dims = make_dims(25, 2, Nu::Half, sigma2, 9);
        let mut gs = GaussSeidel::new(&dims, sigma2);
        gs.max_sweeps = 2000;
        let mut rng = Rng::new(10);
        let v: BlockVec = (0..2).map(|_| rng.normal_vec(25)).collect();
        let (a, sa) = gs.solve(&v);
        let (b, sb) = gs.solve_gs(&v);
        assert!(sa.rel_residual < 1e-9);
        assert!(sb.rel_residual < 1e-8, "plain GS residual {}", sb.rel_residual);
        let scale = a.iter().flat_map(|x| x.iter()).fold(0.0f64, |m, &x| m.max(x.abs()));
        for d in 0..2 {
            for i in 0..25 {
                assert!((a[d][i] - b[d][i]).abs() < 1e-5 * scale.max(1.0));
            }
        }
    }

    /// A warm start at the exact solution returns immediately; a perturbed
    /// warm start converges to the same answer as the cold solve.
    #[test]
    fn warm_start_is_exact_and_cheap() {
        let sigma2 = 0.8;
        let dims = make_dims(22, 3, Nu::Half, sigma2, 12);
        let gs = GaussSeidel::new(&dims, sigma2);
        let mut rng = Rng::new(13);
        let v: BlockVec = (0..3).map(|_| rng.normal_vec(22)).collect();
        let (cold, cold_stats) = gs.solve(&v);
        assert!(cold_stats.rel_residual < 1e-9);

        let (warm, warm_stats) = gs.solve_from(&v, Some(&cold));
        assert_eq!(warm_stats.sweeps, 0, "exact guess must exit immediately");
        for d in 0..3 {
            for i in 0..22 {
                assert_eq!(warm[d][i], cold[d][i]);
            }
        }

        let mut guess = cold.clone();
        for b in &mut guess {
            for x in b.iter_mut() {
                *x += 0.01 * rng.normal();
            }
        }
        let (re, re_stats) = gs.solve_from(&v, Some(&guess));
        assert!(re_stats.rel_residual < 1e-9);
        assert!(
            re_stats.sweeps <= cold_stats.sweeps,
            "warm {} vs cold {}",
            re_stats.sweeps,
            cold_stats.sweeps
        );
        let scale = cold.iter().flat_map(|x| x.iter()).fold(0.0f64, |m, &x| m.max(x.abs()));
        for d in 0..3 {
            for i in 0..22 {
                assert!((re[d][i] - cold[d][i]).abs() < 1e-6 * scale.max(1.0));
            }
        }
    }

    #[test]
    fn apply_is_inverse_of_solve() {
        let sigma2 = 0.5;
        let dims = make_dims(18, 2, Nu::ThreeHalves, sigma2, 5);
        let gs = GaussSeidel::new(&dims, sigma2);
        let mut rng = Rng::new(6);
        let v: BlockVec = (0..2).map(|_| rng.normal_vec(18)).collect();
        let (tilde, _) = gs.solve(&v);
        let back = gs.apply(&tilde);
        let scale = v.iter().flat_map(|x| x.iter()).fold(0.0f64, |m, &x| m.max(x.abs()));
        for d in 0..2 {
            for i in 0..18 {
                assert!((back[d][i] - v[d][i]).abs() < 1e-5 * scale);
            }
        }
    }

    /// PCG must reach tight residuals fast even at D=10 where plain GS
    /// stalls (the concurvity regime).
    #[test]
    fn pcg_converges_fast_at_high_d() {
        let sigma2 = 1.0;
        let dims = make_dims(80, 10, Nu::Half, sigma2, 7);
        let gs = GaussSeidel::new(&dims, sigma2);
        let mut rng = Rng::new(8);
        let v: BlockVec = (0..10).map(|_| rng.normal_vec(80)).collect();
        let (_, stats) = gs.solve(&v);
        assert!(stats.rel_residual < 1e-10, "residual {}", stats.rel_residual);
        assert!(stats.sweeps <= 60, "PCG took {} iterations", stats.sweeps);
    }
}
