//! [`AdditiveGP`] — the user-facing façade over the sparse engine: fit,
//! sequentially observe *incrementally* (no refit per point), learn
//! hyperparameters, and predict mean/variance (with gradients) at
//! `O(log n)`→`O(1)` per query. The trained state lives in
//! [`crate::gp::fit_state::FitState`]; this façade adds data bookkeeping,
//! the `M̃` cache, and hyperparameter training on top.

use crate::check::{enforce, Audit, AuditError};
use crate::gp::dim::{DimFactor, PatchTimings};
use crate::gp::fit_state::{FitState, PosteriorSnapshot};
use crate::gp::likelihood::{self, StochasticCfg};
use crate::gp::posterior::{self, MTildeCache, PredictOut};
use crate::gp::train::{self, TrainCfg};
use crate::kernels::matern::{Matern, Nu};
use crate::linalg::banded::PatchPolicy;

/// Configuration of an additive Matérn GP.
#[derive(Clone, Copy, Debug)]
pub struct AdditiveGpConfig {
    pub nu: Nu,
    /// Initial (or fixed) scale ω for every dimension.
    pub omega0: f64,
    /// Observation noise variance σ_y².
    pub sigma2_y: f64,
    /// Gauss–Seidel controls (Algorithm 4).
    pub gs_max_sweeps: usize,
    pub gs_tol: f64,
    /// Stochastic-estimator controls (Algorithms 6–8).
    pub stochastic: StochasticCfg,
    /// `M̃` cache capacity (columns); 0 = unbounded.
    pub cache_capacity: usize,
    /// How `observe`/`observe_batch` update the banded LU factors
    /// (DESIGN.md §FitState, "Sublinear LU patching"). The default
    /// [`PatchPolicy::Exact`] reuses the elimination prefix and stays
    /// bit-identical to a full refit; [`PatchPolicy::EarlyExit`] additionally
    /// truncates mid-matrix sweeps at a tolerance;
    /// [`PatchPolicy::Resweep`] restores the pre-patch full sweep (kill
    /// switch / bench baseline).
    pub patch_policy: PatchPolicy,
}

impl Default for AdditiveGpConfig {
    fn default() -> Self {
        AdditiveGpConfig {
            nu: Nu::Half,
            omega0: 1.0,
            sigma2_y: 1.0,
            gs_max_sweeps: 60,
            gs_tol: 1e-10,
            stochastic: StochasticCfg::default(),
            cache_capacity: 8192,
            patch_policy: PatchPolicy::Exact,
        }
    }
}

/// Which execution path one [`AdditiveGP::observe_batch`] call took —
/// reported through the coordinator's `observe_batch` reply and the serving
/// metrics.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatchPath {
    /// No factor work ran: the model has not reached `min_points` yet, or
    /// the batch was empty.
    Buffered,
    /// One batched incremental insert: per dimension one band splice, one
    /// union-of-windows KP re-solve and one factor sweep, dimensions sharded
    /// across threads, the M̃ cache invalidated once.
    Incremental,
    /// Full refit — first activation, or a batch at/above the crossover.
    Refit,
}

impl BatchPath {
    /// Wire label used by the coordinator reply and metrics.
    pub fn as_str(&self) -> &'static str {
        match self {
            BatchPath::Buffered => "buffered",
            BatchPath::Incremental => "incremental",
            BatchPath::Refit => "refit",
        }
    }
}

/// An additive Matérn GP `y = Σ_d 𝒢_d(x_d) + ε` backed by the sparse
/// KP representation (paper §3–§6).
pub struct AdditiveGP {
    pub cfg: AdditiveGpConfig,
    /// Current per-dimension scales.
    pub omegas: Vec<f64>,
    /// Column-major data: `x_cols[d][i]`.
    x_cols: Vec<Vec<f64>>,
    y: Vec<f64>,
    /// Trained factorizations + posterior (None until `min_points`).
    state: Option<FitState>,
    cache: MTildeCache,
    /// Warm posterior solves whose residual missed `gs_tol` and were
    /// retried cold (escalation rung 1; see
    /// [`AdditiveGP::ensure_posterior`]). Lives on the façade, not the
    /// [`FitState`], so the count survives refits.
    pub solve_cold_retries: u64,
    /// Cold retries that still missed `gs_tol` and forced a full refit
    /// (escalation rung 2).
    pub solve_refit_escalations: u64,
}

impl AdditiveGP {
    /// Empty model over `d` input dimensions.
    pub fn new(cfg: AdditiveGpConfig, d: usize) -> Self {
        AdditiveGP {
            omegas: vec![cfg.omega0; d],
            x_cols: vec![Vec::new(); d],
            y: Vec::new(),
            state: None,
            cache: MTildeCache::new(cfg.cache_capacity),
            cfg,
            solve_cold_retries: 0,
            solve_refit_escalations: 0,
        }
    }

    pub fn input_dim(&self) -> usize {
        self.x_cols.len()
    }

    pub fn n(&self) -> usize {
        self.y.len()
    }

    /// Minimum number of observations before the KP factorization is valid.
    pub fn min_points(&self) -> usize {
        2 * (self.cfg.nu.q() + 2) + 1 // n ≥ 2ν+4 (GKP is the binding one)
    }

    /// Replace the data set (rows of `x`) and refit the factorizations.
    pub fn fit(&mut self, x: &[Vec<f64>], y: &[f64]) {
        assert_eq!(x.len(), y.len());
        let d = self.input_dim();
        self.x_cols = vec![Vec::with_capacity(x.len()); d];
        for row in x {
            assert_eq!(row.len(), d);
            for (dd, &v) in row.iter().enumerate() {
                self.x_cols[dd].push(v);
            }
        }
        self.y = y.to_vec();
        self.refit();
    }

    /// Append one observation (sequential sampling) **incrementally**: once
    /// the model is active, each dimension patches its KP factorization in
    /// place (`O(log n)` search + `O(2ν+1)` packet re-solves + a
    /// prefix-reuse banded-LU patch — `O(ν³)` arithmetic for append-ordered
    /// points, `O(ν²(n − pos))` for a mid-matrix insert at sorted position
    /// `pos`), the `M̃` cache is
    /// invalidated only in the `2ν` window around the insertion, and the
    /// next posterior solve warm-starts from the previous ṽ — no full refit
    /// (DESIGN.md §FitState, "Sublinear LU patching").
    pub fn observe(&mut self, x: &[f64], y: f64) {
        assert_eq!(x.len(), self.input_dim());
        for (d, &v) in x.iter().enumerate() {
            self.x_cols[d].push(v);
        }
        self.y.push(y);
        if self.n() < self.min_points() {
            return;
        }
        if self.state.is_none() {
            self.refit(); // crossing min_points: first full build
            return;
        }
        let state = self.state.as_mut().unwrap();
        let positions = state.observe(x, &self.x_cols);
        self.cache.on_insert(&positions, self.cfg.nu.q() + 1);
        enforce(self, "AdditiveGP::observe");
    }

    /// Append a batch of observations through the *batched* incremental
    /// path: per dimension one band splice, one union-of-windows KP
    /// re-solve, one prefix-reuse LU patch per factor — instead of `m` of
    /// each — with the dimensions sharded across a scoped thread pool, the
    /// M̃ cache invalidated once, and one warm posterior solve on the next
    /// predict ([`crate::gp::fit_state::FitState::observe_batch`]).
    ///
    /// Crossover policy (measured by `cargo bench --bench incremental --
    /// --crossover`; DESIGN.md §FitState "Batched inserts"): because the
    /// batch pays its `O(n)` costs once rather than once per point, the
    /// incremental path beats a refit until the batch rivals the existing
    /// data in size — so the old `m < n/4 → point-by-point, else refit`
    /// heuristic is replaced by `m ≤ n → one batched insert, else refit`.
    /// Exactness is unaffected by the choice: both paths agree with a
    /// from-scratch fit to solver tolerance (`tests/incremental.rs`).
    pub fn observe_batch(&mut self, xs: &[Vec<f64>], ys: &[f64]) -> BatchPath {
        assert_eq!(xs.len(), ys.len());
        if xs.is_empty() {
            // Nothing absorbed — report the no-work path so the per-path
            // serving counters stay honest.
            return BatchPath::Buffered;
        }
        for x in xs {
            assert_eq!(x.len(), self.input_dim());
        }
        let m = xs.len();
        let n_before = self.n();
        for (x, &y) in xs.iter().zip(ys) {
            for (d, &v) in x.iter().enumerate() {
                self.x_cols[d].push(v);
            }
            self.y.push(y);
        }
        if self.n() < self.min_points() {
            return BatchPath::Buffered;
        }
        let incremental = self.state.is_some() && m <= n_before;
        if !incremental {
            self.refit();
            return BatchPath::Refit;
        }
        let state = self.state.as_mut().unwrap();
        let out = state.observe_batch(xs, &self.x_cols);
        if out.fallback {
            // A sequential-replay dimension rebuilt mid-batch: its final
            // positions are unknown here, so invalidate coarsely. Columns
            // rebuild on demand; exactness is untouched.
            self.cache.clear();
        } else {
            self.cache.on_insert_batch(&out.positions, self.cfg.nu.q() + 1);
        }
        enforce(self, "AdditiveGP::observe_batch");
        BatchPath::Incremental
    }

    /// Release the observation at data-order `index` — the sliding-window
    /// downdate (DESIGN.md §FitState, "Downdates & rolling windows"). On an
    /// active model this is the exact mirror of [`AdditiveGP::observe`]:
    /// each dimension runs a windowed KP removal plus a prefix-reuse LU
    /// patch from the lowest removed row, the `M̃` cache is invalidated only
    /// in the `2ν` window around the closing gap, and the carried warm
    /// start shrinks at the removed entry — no refit, and under the default
    /// [`PatchPolicy::Exact`] the factors are bit-identical to never having
    /// observed the point. Shrinking below `min_points` deactivates the
    /// trained state instead (it rebuilds on the next activation crossing,
    /// mirroring the observe-side boundary).
    pub fn forget_index(&mut self, index: usize) {
        let n = self.n();
        assert!(index < n, "forget index {index} out of range (n = {n})");
        for col in self.x_cols.iter_mut() {
            col.remove(index);
        }
        self.y.remove(index);
        if self.state.is_none() {
            return;
        }
        if self.n() < self.min_points() {
            self.state = None;
            self.cache.clear();
            return;
        }
        let state = self.state.as_mut().unwrap();
        let positions = state.forget(index, &self.x_cols);
        self.cache.on_remove(&positions, self.cfg.nu.q() + 1);
        enforce(self, "AdditiveGP::forget_index");
    }

    /// Release the most recent observation whose coordinates equal `x`
    /// exactly (the protocol's forget-by-value form). Returns `false` when
    /// no stored row matches — nothing changes. Ties (duplicate rows)
    /// resolve to the latest, matching a sliding window's arrival order.
    pub fn forget(&mut self, x: &[f64]) -> bool {
        assert_eq!(x.len(), self.input_dim());
        let found = (0..self.n())
            .rev()
            .find(|&i| x.iter().enumerate().all(|(d, &v)| self.x_cols[d][i] == v));
        match found {
            Some(i) => {
                self.forget_index(i);
                true
            }
            None => false,
        }
    }

    /// Release a whole batch of observations at strictly increasing
    /// data-order `indices` — one union-window downdate per dimension
    /// ([`FitState::forget_batch`]) and one cache invalidation pass, the
    /// deletion mirror of [`AdditiveGP::observe_batch`].
    pub fn forget_batch(&mut self, indices: &[usize]) {
        if indices.is_empty() {
            return;
        }
        let n = self.n();
        assert!(
            indices.windows(2).all(|p| p[0] < p[1]),
            "forget_batch indices must be strictly increasing"
        );
        assert!(indices[indices.len() - 1] < n, "forget index out of range (n = {n})");
        let mut keep = vec![true; n];
        for &i in indices {
            keep[i] = false;
        }
        for col in self.x_cols.iter_mut() {
            let mut it = keep.iter();
            col.retain(|_| *it.next().unwrap());
        }
        let mut it = keep.iter();
        self.y.retain(|_| *it.next().unwrap());
        if self.state.is_none() {
            return;
        }
        if self.n() < self.min_points() {
            self.state = None;
            self.cache.clear();
            return;
        }
        let state = self.state.as_mut().unwrap();
        let out = state.forget_batch(indices, &self.x_cols);
        if out.fallback {
            // A degenerate dimension rebuilt from the compacted data: its
            // sorted order is unknown here, so invalidate coarsely (columns
            // rebuild on demand; exactness is untouched).
            self.cache.clear();
        } else {
            self.cache.on_remove_batch(&out.positions, self.cfg.nu.q() + 1);
        }
        enforce(self, "AdditiveGP::forget_batch");
    }

    /// Rebuild per-dimension factorizations with the current hyperparameters
    /// (hyperparameter changes and large batches; the per-point path is
    /// [`AdditiveGP::observe`]).
    pub fn refit(&mut self) {
        self.cache.clear();
        if self.n() < self.min_points() {
            self.state = None;
            return;
        }
        let sigma2 = self.cfg.sigma2_y;
        let nu = self.cfg.nu;
        let dims: Vec<DimFactor> = self
            .x_cols
            .iter()
            .zip(&self.omegas)
            .map(|(col, &om)| DimFactor::new(col, Matern::new(nu, om), sigma2))
            .collect();
        let mut state = FitState::new(dims, sigma2, self.cfg.gs_max_sweeps, self.cfg.gs_tol);
        state.set_patch_policy(self.cfg.patch_policy);
        self.state = Some(state);
        enforce(self, "AdditiveGP::refit");
    }

    /// Ensure the posterior state (`b_Y`) exists — one (warm-started)
    /// Algorithm 4 solve, escalated on non-convergence.
    ///
    /// Escalation ladder: a warm solve whose final relative residual misses
    /// `gs_tol` is retried **cold** (the stale ṽ that steered PCG into
    /// stagnation is discarded — [`FitState::resolve_cold`]); if the cold
    /// solve also misses, the factorizations themselves are rebuilt by a
    /// full [`AdditiveGP::refit`] and solved once more. Each rung is
    /// counted ([`AdditiveGP::solve_cold_retries`] /
    /// [`AdditiveGP::solve_refit_escalations`], surfaced through the
    /// coordinator's `stats` reply), replacing the old behavior of silently
    /// serving whatever the stagnated sweep left behind. The ladder is a
    /// deterministic function of the solve result, so journal replay walks
    /// the same rungs and recovery stays bit-identical. Only this
    /// *perturbing* path escalates — the non-perturbing
    /// [`AdditiveGP::read_snapshot`] never writes back, preserving the
    /// read-path determinism contract.
    pub fn ensure_posterior(&mut self) {
        let state = self.state.as_mut().expect("fit() with enough points first");
        if state.posterior().is_some() {
            return;
        }
        state.ensure_posterior(&self.y);
        if self.solve_converged() {
            return;
        }
        self.solve_cold_retries += 1;
        self.state.as_mut().unwrap().resolve_cold(&self.y);
        if self.solve_converged() {
            return;
        }
        self.solve_refit_escalations += 1;
        self.refit();
        self.state.as_mut().expect("refit keeps an active model active").ensure_posterior(&self.y);
    }

    /// Did the last posterior solve reach `gs_tol`? (The fault plan can
    /// force a "no" here — chaos tests drive the escalation ladder through
    /// the `pcg.converge` point.)
    fn solve_converged(&self) -> bool {
        if let Some(act) = crate::util::fault::point!("pcg.converge") {
            if act == crate::util::fault::FaultAction::ForceFail {
                return false;
            }
        }
        match self.state.as_ref() {
            Some(s) => match s.gs_stats() {
                // Mirror the solver's own stopping rule (strict `< tol`,
                // `backfit.rs`) against the state's live tolerance.
                Some(g) => g.rel_residual.is_finite() && g.rel_residual < s.gs_tol,
                None => true, // nothing was solved; nothing to escalate
            },
            None => true,
        }
    }

    /// Posterior mean at `x` — `O(D log n)` given the posterior.
    pub fn mean(&mut self, x: &[f64]) -> f64 {
        self.ensure_posterior();
        let state = self.state.as_ref().unwrap();
        posterior::mean(state.dims(), state.posterior().unwrap(), x)
    }

    /// Posterior mean and variance (plus gradients if requested).
    pub fn predict(&mut self, x: &[f64], want_grad: bool) -> PredictOut {
        self.ensure_posterior();
        let sigma2 = self.cfg.sigma2_y;
        let state = self.state.as_mut().unwrap();
        let (dims, post) = state.parts_mut();
        posterior::predict_cached(dims, sigma2, post, &mut self.cache, x, want_grad)
    }

    /// Negative log marginal likelihood (stochastic log-det).
    pub fn nll(&self) -> f64 {
        let state = self.state.as_ref().expect("fit first");
        likelihood::nll(state.dims(), self.cfg.sigma2_y, &self.y, &self.cfg.stochastic)
    }

    /// Gradient of the NLL w.r.t. each ω_d (and σ²).
    pub fn nll_grad(&mut self) -> likelihood::NllGrad {
        let sigma2 = self.cfg.sigma2_y;
        let scfg = self.cfg.stochastic;
        let state = self.state.as_mut().expect("fit first");
        likelihood::nll_grad(state.dims_mut(), sigma2, &self.y, &scfg)
    }

    /// Learn the scales by Adam (paper §5.1); updates `self.omegas` and
    /// rebuilds the fit state (full refit — the `hyper_every` boundary of
    /// the BO loop).
    pub fn optimize_hypers(&mut self, tcfg: &TrainCfg) -> Vec<train::TrainStep> {
        let (omegas, dims, hist) = train::optimize_omegas(
            &self.x_cols,
            &self.y,
            self.cfg.nu,
            &self.omegas.clone(),
            self.cfg.sigma2_y,
            tcfg,
            &self.cfg.stochastic,
        );
        self.omegas = omegas;
        let mut state =
            FitState::new(dims, self.cfg.sigma2_y, self.cfg.gs_max_sweeps, self.cfg.gs_tol);
        state.set_patch_policy(self.cfg.patch_policy);
        self.state = Some(state);
        self.cache.clear();
        enforce(self, "AdditiveGP::optimize_hypers");
        hist
    }

    /// Gather the fixed-shape window payload for one query (the PJRT
    /// batcher's input row; see [`posterior::gather_windows`]).
    pub fn gather_windows(&mut self, x: &[f64]) -> posterior::QueryWindows {
        self.ensure_posterior();
        let sigma2 = self.cfg.sigma2_y;
        let state = self.state.as_mut().unwrap();
        let (dims, post) = state.parts_mut();
        posterior::gather_windows(dims, sigma2, post, &mut self.cache, x)
    }

    /// Cache statistics `(hits, misses, resident columns)`.
    pub fn cache_stats(&self) -> (u64, u64, usize) {
        (self.cache.hits, self.cache.misses, self.cache.len())
    }

    /// How many times the `M̃` cache was wholesale-cleared because an insert
    /// exceeded its remap limits (too many resident columns, or a batch too
    /// large to remap) — the formerly *silent* truncation path, surfaced in
    /// the coordinator's `stats` reply as `cache_truncations`. Refit-driven
    /// clears are deliberate invalidations and are not counted.
    pub fn cache_truncations(&self) -> u64 {
        self.cache.truncation_clears
    }

    /// Incremental-path statistics `(incremental inserts, fallback
    /// rebuilds, stale-column refreshes)` — zero before activation.
    pub fn incremental_stats(&self) -> (u64, u64, u64) {
        match &self.state {
            Some(s) => (s.incremental_inserts, s.fallback_rebuilds, self.cache.refreshes),
            None => (0, 0, self.cache.refreshes),
        }
    }

    /// Observations released through the incremental downdate path (zero
    /// before activation; resets when the state deactivates or refits, like
    /// the insert counters).
    pub fn incremental_removes(&self) -> u64 {
        self.state.as_ref().map(|s| s.incremental_removes).unwrap_or(0)
    }

    /// Factor-update statistics `(prefix-reuse patches, full re-sweeps)`,
    /// counted per banded LU (up to 4 per dimension per insert) — the
    /// production observability for the DESIGN.md "Sublinear LU patching"
    /// crossover. Zero before activation.
    pub fn factor_stats(&self) -> (u64, u64) {
        match &self.state {
            Some(s) => (s.factor_patches(), s.factor_resweeps()),
            None => (0, 0),
        }
    }

    /// Band-storage statistics `(memmove_bytes, chunks_copied,
    /// chunks_shared)` — bytes shifted by mid-matrix splices, chunks
    /// deep-copied by copy-on-write, and chunks handed to snapshots by
    /// reference (DESIGN.md "Chunked COW band storage"). Zero before
    /// activation.
    pub fn storage_stats(&self) -> (u64, u64, u64) {
        match &self.state {
            Some(s) => s.storage_stats(),
            None => (0, 0, 0),
        }
    }

    /// Accumulated wall-clock split of the incremental insert path (KP
    /// window patch vs factor update), summed over dimensions.
    pub fn patch_timings(&self) -> PatchTimings {
        match &self.state {
            Some(s) => s.patch_timings(),
            None => PatchTimings::default(),
        }
    }

    /// Build an immutable [`PosteriorSnapshot`] for the coordinator's
    /// concurrent read path, or `None` before the model is active
    /// (`n < min_points`). Non-perturbing: a stale posterior is solved warm
    /// from the stored ṽ *without* writing it back, so reads at arbitrary
    /// times leave the engine's numeric trajectory bit-identical to a
    /// read-free replay (see [`FitState::read_snapshot`]).
    pub fn read_snapshot(&mut self) -> Option<PosteriorSnapshot> {
        let cap = self.cfg.cache_capacity;
        let state = self.state.as_mut()?;
        Some(state.read_snapshot(&self.y, cap))
    }

    /// Data access for baselines/benchmarks.
    pub fn data(&self) -> (&[Vec<f64>], &[f64]) {
        (&self.x_cols, &self.y)
    }

    /// Reinstall checkpoint-decoded parts (journal recovery): data columns,
    /// targets, per-dimension scales, escalation counters and the trained
    /// state. The `M̃` cache restarts cold — cached columns are
    /// bit-identical to recomputation (pinned by the snapshot-vs-predict
    /// equivalence tests), so a cold cache changes latency, never
    /// prediction bits.
    pub fn restore_parts(
        &mut self,
        omegas: Vec<f64>,
        x_cols: Vec<Vec<f64>>,
        y: Vec<f64>,
        state: Option<FitState>,
        solve_counters: (u64, u64),
    ) -> Result<(), String> {
        if omegas.len() != self.input_dim() || x_cols.len() != self.input_dim() {
            return Err(format!(
                "checkpoint carries {} dims, model built with {}",
                x_cols.len(),
                self.input_dim()
            ));
        }
        if x_cols.iter().any(|c| c.len() != y.len()) {
            return Err("checkpoint data columns disagree with y length".to_string());
        }
        self.omegas = omegas;
        self.x_cols = x_cols;
        self.y = y;
        self.state = state;
        self.cache = MTildeCache::new(self.cfg.cache_capacity);
        self.solve_cold_retries = solve_counters.0;
        self.solve_refit_escalations = solve_counters.1;
        enforce(self, "AdditiveGP::restore_parts");
        Ok(())
    }

    /// Immutable access to the factorizations (None before `fit`).
    pub fn dims(&self) -> Option<&[DimFactor]> {
        self.state.as_ref().map(|s| s.dims())
    }

    /// Immutable access to the trained fit state (None before `fit`).
    pub fn fit_state(&self) -> Option<&FitState> {
        self.state.as_ref()
    }

    /// On-demand audit entry point (the coordinator's `audit` request):
    /// walk every stateful structure in the model and return
    /// `(structures_checked, result)`. The count is deterministic for a
    /// given model shape: 2 for the façade (data bookkeeping + `M̃` cache),
    /// and when the model is active 1 for the [`FitState`] plus, per
    /// dimension, the [`DimFactor`] and its 10 children (KP factorization,
    /// permutation, the A/Φ/T/Φᵀ bands, and the four banded LUs), plus one
    /// more for each dimension that has materialized its band-of-inverse.
    pub fn run_audit(&self) -> (u64, Result<(), AuditError>) {
        let mut structures = 2u64;
        if let Some(state) = &self.state {
            structures += 1;
            for dim in state.dims() {
                structures += 11;
                if dim.has_c_band() {
                    structures += 1;
                }
            }
        }
        (structures, self.audit())
    }
}

impl Audit for AdditiveGP {
    fn audit(&self) -> Result<(), AuditError> {
        let n = self.y.len();
        let d = self.x_cols.len();
        if self.omegas.len() != d {
            return Err(AuditError::new(
                "AdditiveGP",
                "omegas",
                None,
                format!("{} scales for {d} dimensions", self.omegas.len()),
            ));
        }
        for (dd, &om) in self.omegas.iter().enumerate() {
            if !(om.is_finite() && om > 0.0) {
                return Err(AuditError::new(
                    "AdditiveGP",
                    "omegas",
                    Some(dd),
                    format!("scale {om} not finite-positive"),
                ));
            }
        }
        for (dd, col) in self.x_cols.iter().enumerate() {
            if col.len() != n {
                return Err(AuditError::new(
                    "AdditiveGP",
                    "x_cols",
                    Some(dd),
                    format!("column holds {} points but y holds {n}", col.len()),
                ));
            }
        }
        if let Some(state) = &self.state {
            state.audit()?;
            if state.dims().len() != d {
                return Err(AuditError::new(
                    "AdditiveGP",
                    "state",
                    None,
                    format!("{} trained dimensions for {d} data columns", state.dims().len()),
                ));
            }
            if state.dims()[0].n() != n {
                return Err(AuditError::new(
                    "AdditiveGP",
                    "state",
                    None,
                    format!("trained on {} points but {n} observed", state.dims()[0].n()),
                ));
            }
        }
        self.cache.audit_with(d, n)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn toy_data(n: usize, d: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let x: Vec<Vec<f64>> =
            (0..n).map(|_| (0..d).map(|_| rng.uniform_in(0.0, 5.0)).collect()).collect();
        let y: Vec<f64> = x
            .iter()
            .map(|row| row.iter().map(|v| (1.2 * v).sin()).sum::<f64>() + 0.1 * rng.normal())
            .collect();
        (x, y)
    }

    #[test]
    fn fit_predict_roundtrip() {
        let (x, y) = toy_data(60, 3, 1);
        let mut gp = AdditiveGP::new(AdditiveGpConfig::default(), 3);
        gp.fit(&x, &y);
        let out = gp.predict(&[2.0, 3.0, 1.0], true);
        assert!(out.var > 0.0);
        assert!(out.mean.is_finite());
        assert_eq!(out.mean_grad.len(), 3);
        assert_eq!(out.var_grad.len(), 3);
    }

    /// Interpolation sanity: at a data point with small noise the posterior
    /// mean is close to the observed value.
    #[test]
    fn approaches_data_with_small_noise() {
        let (x, y) = toy_data(80, 2, 2);
        let mut cfg = AdditiveGpConfig::default();
        cfg.sigma2_y = 1e-3;
        cfg.omega0 = 1.0;
        let mut gp = AdditiveGP::new(cfg, 2);
        gp.fit(&x, &y);
        let mut err = 0.0;
        for i in 0..10 {
            let m = gp.mean(&x[i]);
            err += (m - y[i]).abs();
        }
        err /= 10.0;
        assert!(err < 0.15, "mean abs error at data points: {err}");
    }

    #[test]
    fn observe_accumulates_then_activates() {
        let mut gp = AdditiveGP::new(AdditiveGpConfig::default(), 2);
        let (x, y) = toy_data(30, 2, 3);
        for i in 0..30 {
            gp.observe(&x[i], y[i]);
        }
        assert_eq!(gp.n(), 30);
        let out = gp.predict(&[1.0, 1.0], false);
        assert!(out.var.is_finite());
    }

    /// The batch path chooses buffered → refit → incremental as the model
    /// grows, and the result always matches a from-scratch fit.
    #[test]
    fn observe_batch_paths_and_equivalence() {
        let (x, y) = toy_data(50, 2, 6);
        let mut gp = AdditiveGP::new(AdditiveGpConfig::default(), 2);
        assert_eq!(gp.observe_batch(&x[..3], &y[..3]), BatchPath::Buffered);
        // Crossing min_points (and m > n before) → one full refit.
        assert_eq!(gp.observe_batch(&x[3..40], &y[3..40]), BatchPath::Refit);
        // Small batch on an active model → batched incremental insert.
        assert_eq!(gp.observe_batch(&x[40..], &y[40..]), BatchPath::Incremental);
        let (inc, fall, _) = gp.incremental_stats();
        assert_eq!(inc, 20, "10 points × 2 dims through the batch insert");
        assert_eq!(fall, 0);

        let mut full = AdditiveGP::new(AdditiveGpConfig::default(), 2);
        full.fit(&x, &y);
        for q in [[2.0, 2.5], [0.5, 4.0]] {
            let a = gp.predict(&q, false);
            let b = full.predict(&q, false);
            assert!(
                (a.mean - b.mean).abs() < 1e-7 * b.mean.abs().max(1.0),
                "mean {} vs {}",
                a.mean,
                b.mean
            );
            assert!(
                (a.var - b.var).abs() < 1e-6 * b.var.max(1e-3),
                "var {} vs {}",
                a.var,
                b.var
            );
        }
    }

    /// Observe-then-forget at the façade level is bit-identical to never
    /// observing: factors restore exactly, both models run the same cold
    /// posterior solve, and predictions agree to the last bit.
    #[test]
    fn forget_roundtrip_is_bitwise_never_observed() {
        let (x, y) = toy_data(41, 2, 14);
        let mut never = AdditiveGP::new(AdditiveGpConfig::default(), 2);
        never.fit(&x[..40], &y[..40]);
        let mut gp = AdditiveGP::new(AdditiveGpConfig::default(), 2);
        gp.fit(&x[..40], &y[..40]);
        gp.observe(&x[40], y[40]);
        assert_eq!(gp.n(), 41);
        assert!(gp.forget(&x[40]), "the observed row must be found by value");
        assert_eq!(gp.n(), 40);
        assert_eq!(gp.incremental_removes(), 2, "one downdate per dimension");
        assert!(!gp.forget(&x[40]), "already forgotten");
        for q in [[2.0, 2.5], [0.5, 4.0], [4.4, 0.1]] {
            let a = gp.predict(&q, true);
            let b = never.predict(&q, true);
            assert_eq!(a.mean.to_bits(), b.mean.to_bits(), "mean at {q:?}");
            assert_eq!(a.var.to_bits(), b.var.to_bits(), "var at {q:?}");
            assert_eq!(a.var_grad[0].to_bits(), b.var_grad[0].to_bits());
        }
        assert!(gp.run_audit().1.is_ok());
    }

    /// Shrinking below `min_points` deactivates the trained state; crossing
    /// back up reactivates it with a clean refit.
    #[test]
    fn forget_below_min_points_deactivates_and_reactivates() {
        let mut gp = AdditiveGP::new(AdditiveGpConfig::default(), 2);
        let (x, y) = toy_data(30, 2, 15);
        let min = gp.min_points();
        for i in 0..min {
            gp.observe(&x[i], y[i]);
        }
        assert!(gp.dims().is_some(), "activated at min_points");
        gp.forget_index(0);
        assert!(gp.dims().is_none(), "shrunk below min_points");
        assert_eq!(gp.n(), min - 1);
        assert!(gp.fit_state().is_none(), "trained state must be dropped");
        gp.observe(&x[min], y[min]);
        assert!(gp.dims().is_some(), "re-crossed min_points");
        assert!(gp.run_audit().1.is_ok());
    }

    /// `forget_batch` compacts data and state together and keeps the model
    /// consistent with a from-scratch fit on the surviving rows.
    #[test]
    fn forget_batch_matches_fresh_fit_on_survivors() {
        let (x, y) = toy_data(46, 2, 16);
        let mut gp = AdditiveGP::new(AdditiveGpConfig::default(), 2);
        gp.fit(&x, &y);
        let gone = [0usize, 7, 8, 22, 45];
        gp.forget_batch(&gone);
        assert_eq!(gp.n(), 41);
        let survivors: Vec<usize> = (0..46).filter(|i| !gone.contains(i)).collect();
        let xs: Vec<Vec<f64>> = survivors.iter().map(|&i| x[i].clone()).collect();
        let ys: Vec<f64> = survivors.iter().map(|&i| y[i]).collect();
        let mut fresh = AdditiveGP::new(AdditiveGpConfig::default(), 2);
        fresh.fit(&xs, &ys);
        for q in [[1.0, 3.0], [3.3, 1.8]] {
            let a = gp.predict(&q, false);
            let b = fresh.predict(&q, false);
            assert_eq!(a.mean.to_bits(), b.mean.to_bits(), "mean at {q:?}");
            assert_eq!(a.var.to_bits(), b.var.to_bits(), "var at {q:?}");
        }
        assert!(gp.run_audit().1.is_ok());
    }

    /// The coordinator's read snapshot agrees with the engine's own predict
    /// path, and building it leaves the engine bit-for-bit untouched (the
    /// invariant the multi-model determinism stress test relies on).
    #[test]
    fn read_snapshot_matches_predict_and_does_not_perturb() {
        let (x, y) = toy_data(70, 2, 9);
        let mut gp = AdditiveGP::new(AdditiveGpConfig::default(), 2);
        gp.fit(&x[..60], &y[..60]);
        // Incremental observes leave the posterior stale, so the snapshot
        // has to run its own (non-perturbing) warm solve.
        for i in 60..70 {
            gp.observe(&x[i], y[i]);
        }
        let probe = [1.3, 2.1];
        let snap = gp.read_snapshot().unwrap();
        let a = snap.predict(&probe, true);
        let snap2 = gp.read_snapshot().unwrap();
        let b = snap2.predict(&probe, true);
        assert_eq!(a.mean.to_bits(), b.mean.to_bits(), "snapshot build perturbed the engine");
        assert_eq!(a.var.to_bits(), b.var.to_bits());
        let c = gp.predict(&probe, true);
        assert!(
            (a.mean - c.mean).abs() < 1e-8 * c.mean.abs().max(1.0),
            "snapshot mean {} vs engine {}",
            a.mean,
            c.mean
        );
        assert!(
            (a.var - c.var).abs() < 1e-6 * c.var.max(1e-6),
            "snapshot var {} vs engine {}",
            a.var,
            c.var
        );
        assert_eq!(snap.n(), 70);
        assert_eq!(snap.input_dim(), 2);
    }

    #[test]
    fn variance_shrinks_near_data() {
        let (x, y) = toy_data(100, 2, 4);
        let mut gp = AdditiveGP::new(AdditiveGpConfig::default(), 2);
        gp.fit(&x, &y);
        let near = gp.predict(&x[0], false).var;
        let far = gp.predict(&[50.0, -40.0], false).var;
        assert!(near < far, "near {near} !< far {far}");
    }

    /// `run_audit` reports the documented deterministic structure count and
    /// pins corruption to `AdditiveGP.omegas[1]`.
    #[test]
    fn run_audit_counts_structures_and_flags_bad_scale() {
        let (x, y) = toy_data(40, 2, 21);
        let mut gp = AdditiveGP::new(AdditiveGpConfig::default(), 2);
        let (count, ok) = gp.run_audit();
        assert_eq!(count, 2, "inactive model audits only the façade");
        assert!(ok.is_ok());
        gp.fit(&x, &y);
        let (count, ok) = gp.run_audit();
        assert_eq!(count, 2 + 1 + 2 * 11);
        assert!(ok.is_ok(), "healthy model: {ok:?}");
        gp.predict(&[1.0, 1.0], false);
        let with_c = gp.dims().unwrap().iter().filter(|d| d.has_c_band()).count() as u64;
        let (count, ok) = gp.run_audit();
        assert_eq!(count, 2 + 1 + 2 * 11 + with_c);
        assert!(ok.is_ok());
        gp.omegas[1] = f64::NAN;
        let err = gp.run_audit().1.unwrap_err();
        assert_eq!(err.structure, "AdditiveGP");
        assert_eq!(err.field, "omegas");
        assert_eq!(err.index, Some(1));
    }

    #[test]
    fn nll_finite_and_grad_shaped() {
        let (x, y) = toy_data(40, 2, 5);
        let mut gp = AdditiveGP::new(AdditiveGpConfig::default(), 2);
        gp.fit(&x, &y);
        assert!(gp.nll().is_finite());
        let g = gp.nll_grad();
        assert_eq!(g.omega.len(), 2);
        assert!(g.sigma2.is_finite());
    }
}
