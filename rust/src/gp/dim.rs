//! Per-dimension factorization state: one [`KpFactorization`] plus the
//! banded LU factors every downstream algorithm reuses, and (lazily) the
//! generalized-KP factorization for gradients.

use crate::kernels::gkp::GkpFactorization;
use crate::kernels::kp::KpFactorization;
use crate::kernels::matern::Matern;
use crate::linalg::banded::BandedLU;
use crate::linalg::block_tridiag::selected_inverse_band;
use crate::linalg::Banded;

/// Everything the engine needs about one additive dimension `d`:
/// `P_d^T K_d P_d = A_d^{-1} Φ_d`, the Gauss–Seidel block matrix
/// `T_d = A_d + σ⁻²Φ_d`, and LU factors of `Φ_d`, `Φ_d^T`, `T_d`.
pub struct DimFactor {
    pub kp: KpFactorization,
    /// LU of `T_d = A_d + σ_y^{-2} Φ_d` (the Algorithm 4 block solve).
    pub t_lu: BandedLU,
    /// LU of `Φ_d`.
    pub phi_lu: BandedLU,
    /// LU of `Φ_d^T`.
    pub phit_lu: BandedLU,
    /// LU of `A_d` (log-det term of eq. 14 and `K_d`-matvecs).
    pub a_lu: BandedLU,
    /// Lazily-built generalized KP (Algorithm 3) for `∂_ω K_d`.
    gkp: Option<GkpFactorization>,
    /// Lazily-built `2ν`-band of `Φ_d^{-T} A_d^{-1}` (Algorithm 5).
    c_band: Option<Banded>,
    pub sigma2_y: f64,
    /// Whether `xs` is strictly increasing. Degenerate (duplicate-cluster)
    /// states disable the incremental path — every insert falls back to a
    /// full rebuild until a rebuild separates the points again.
    monotone: bool,
}

impl DimFactor {
    /// Factorize dimension `d`'s covariance for scattered `points`.
    pub fn new(points: &[f64], kernel: Matern, sigma2_y: f64) -> Self {
        let kp = KpFactorization::new(points, kernel);
        let monotone = kp.xs.windows(2).all(|p| p[1] > p[0]);
        let (t_lu, phi_lu, phit_lu, a_lu) = factor_lus(&kp, sigma2_y);
        DimFactor {
            kp,
            t_lu,
            phi_lu,
            phit_lu,
            a_lu,
            gkp: None,
            c_band: None,
            sigma2_y,
            monotone,
        }
    }

    /// Incrementally absorb one new point (appended in data order):
    /// `O(2ν+1)` packet re-solves via [`KpFactorization::insert`], then an
    /// `O(ν²n)` banded LU sweep per factor — no `O(n)` moment-system rebuild
    /// and no dense work (DESIGN.md §FitState). The lazy GKP and
    /// band-of-inverse are invalidated and rebuilt on next use.
    ///
    /// Returns the sorted insertion position, or `None` when the point
    /// cannot be inserted incrementally (degenerate duplicate cluster) — the
    /// caller should rebuild this dimension with [`DimFactor::new`].
    pub fn insert_point(&mut self, x: f64) -> Option<usize> {
        if !self.monotone {
            return None;
        }
        let pos = self.kp.insert(x)?;
        let (t_lu, phi_lu, phit_lu, a_lu) = factor_lus(&self.kp, self.sigma2_y);
        self.t_lu = t_lu;
        self.phi_lu = phi_lu;
        self.phit_lu = phit_lu;
        self.a_lu = a_lu;
        self.gkp = None;
        self.c_band = None;
        Some(pos)
    }

    /// Batched form of [`DimFactor::insert_point`]: absorb `values` (in data
    /// order) with **one** union-of-windows KP patch
    /// ([`KpFactorization::insert_batch`]) and **one** `O(ν²n)` sweep per LU
    /// factor for the whole batch — the m-fold sweep amortization behind
    /// `FitState::observe_batch`. Returns each value's final sorted
    /// position.
    ///
    /// Returns `None` with the factor state untouched when the batch hits a
    /// degenerate duplicate cluster (or the dimension is already
    /// non-monotone); the caller replays the sequential path for this
    /// dimension so batch semantics stay bit-identical to per-point
    /// observes.
    pub fn insert_points(&mut self, values: &[f64]) -> Option<Vec<usize>> {
        if !self.monotone {
            return None;
        }
        let positions = self.kp.insert_batch(values)?;
        let (t_lu, phi_lu, phit_lu, a_lu) = factor_lus(&self.kp, self.sigma2_y);
        self.t_lu = t_lu;
        self.phi_lu = phi_lu;
        self.phit_lu = phit_lu;
        self.a_lu = a_lu;
        self.gkp = None;
        self.c_band = None;
        Some(positions)
    }

    pub fn n(&self) -> usize {
        self.kp.n()
    }

    pub fn kernel(&self) -> &Matern {
        &self.kp.kernel
    }

    /// Apply `K_d^{-1} = Φ_d^{-1} A_d` to a vector in sorted coordinates.
    pub fn kinv_sorted(&self, v: &[f64]) -> Vec<f64> {
        self.phi_lu.solve(&self.kp.a.matvec(v))
    }

    /// Apply `K_d = A_d^{-1} Φ_d` to a vector in sorted coordinates.
    pub fn k_sorted(&self, v: &[f64]) -> Vec<f64> {
        self.a_lu.solve(&self.kp.phi.matvec(v))
    }

    /// Solve the Algorithm 4 block system in sorted coordinates:
    /// `(K_d^{-1} + σ⁻²I) u = w  ⟺  (A_d + σ⁻²Φ_d) u = Φ_d w`.
    pub fn gs_block_solve_sorted(&self, w: &[f64]) -> Vec<f64> {
        self.t_lu.solve(&self.kp.phi.matvec(w))
    }

    /// The generalized-KP factorization (built on first use).
    pub fn gkp(&mut self) -> &GkpFactorization {
        if self.gkp.is_none() {
            self.gkp = Some(GkpFactorization::new_sorted(&self.kp.xs, *self.kernel()));
        }
        self.gkp.as_ref().unwrap()
    }

    /// The central band of `C_d = Φ_d^{-T} A_d^{-1}` (paper Algorithm 5;
    /// built on first use). `H = A_d Φ_d^T = A_d K_d A_d^T` is symmetric
    /// positive definite and `2ν`-banded; the needed band of its inverse
    /// comes from the selected block-tridiagonal inverse in `O(ν² n)`.
    ///
    /// Note: the paper's summary table says the `(ν+1/2)`-band, but its own
    /// eq. (25) pairs window entries up to `2ν` apart, so we store the
    /// `2ν`-band — the asymptotic cost is identical.
    pub fn c_band(&mut self) -> &Banded {
        if self.c_band.is_none() {
            let h = self.kp.a.matmul(&self.kp.phi.transpose());
            // Symmetrize against round-off before inverting.
            let mut hs = h.clone();
            for i in 0..hs.n() {
                let (lo, hi) = hs.row_range(i);
                for j in lo..hi {
                    if j > i {
                        let v = 0.5 * (h.get(i, j) + h.get(j, i));
                        hs.set(i, j, v);
                        hs.set(j, i, v);
                    }
                }
            }
            self.c_band = Some(selected_inverse_band(&hs, 2 * self.kp.w() - 1));
        }
        self.c_band.as_ref().unwrap()
    }

    /// Whether the band-of-inverse has been materialized yet.
    pub fn has_c_band(&self) -> bool {
        self.c_band.is_some()
    }

    /// Immutable access to the band-of-inverse if already built.
    pub fn c_band_cached(&self) -> Option<&Banded> {
        self.c_band.as_ref()
    }

    /// Immutable access to the generalized-KP factorization if already built.
    pub fn gkp_cached(&self) -> Option<&GkpFactorization> {
        self.gkp.as_ref()
    }
}

/// The four banded LUs every consumer reuses, from one KP factorization —
/// shared by the fresh build and the incremental insert so both paths stay
/// factor-for-factor identical.
fn factor_lus(
    kp: &KpFactorization,
    sigma2_y: f64,
) -> (BandedLU, BandedLU, BandedLU, BandedLU) {
    let t = kp.a.add_scaled(&kp.phi, 1.0 / sigma2_y);
    (t.lu(), kp.phi.lu(), kp.phi.transpose().lu(), kp.a.lu())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::matern::Nu;
    use crate::util::Rng;

    fn factor(n: usize, nu: Nu, omega: f64, seed: u64) -> DimFactor {
        let mut rng = Rng::new(seed);
        let pts = rng.uniform_vec(n, 0.0, 4.0);
        DimFactor::new(&pts, Matern::new(nu, omega), 0.5)
    }

    #[test]
    fn kinv_is_inverse_of_k() {
        // Round-trip error scales with cond(K): machine precision for ν=1/2
        // (tridiagonal Markov inverse), growing with smoothness — Matérn-5/2
        // grams over clustered random points are within a few digits of
        // singular in f64, so the tolerance is graded.
        for (nu, tol) in
            [(Nu::Half, 1e-9), (Nu::ThreeHalves, 1e-6), (Nu::FiveHalves, 5e-3)]
        {
            let f = factor(30, nu, 1.2, 3);
            let mut rng = Rng::new(4);
            let v = rng.normal_vec(30);
            let w = f.kinv_sorted(&f.k_sorted(&v));
            for i in 0..30 {
                assert!((w[i] - v[i]).abs() < tol, "{nu:?} i={i}: {} vs {}", w[i], v[i]);
            }
        }
    }

    #[test]
    fn gs_block_solve_is_consistent() {
        let f = factor(25, Nu::ThreeHalves, 0.8, 5);
        let mut rng = Rng::new(6);
        let w = rng.normal_vec(25);
        let u = f.gs_block_solve_sorted(&w);
        // Check (K^{-1} + σ⁻²I) u = w.
        let r = f.kinv_sorted(&u);
        for i in 0..25 {
            assert!((r[i] + u[i] / 0.5 - w[i]).abs() < 1e-7, "i={i}");
        }
    }

    /// `insert_point` produces factors that act identically to a
    /// from-scratch build on the extended point set.
    #[test]
    fn insert_point_matches_fresh_build() {
        for nu in [Nu::Half, Nu::ThreeHalves] {
            let mut rng = Rng::new(31);
            let mut pts = rng.uniform_vec(24, 0.0, 4.0);
            let kern = Matern::new(nu, 1.1);
            let mut inc = DimFactor::new(&pts, kern, 0.7);
            for &x in &[1.234, -0.4, 4.6] {
                let pos = inc.insert_point(x).expect("distinct point");
                pts.push(x);
                let fresh = DimFactor::new(&pts, kern, 0.7);
                assert_eq!(inc.kp.xs[pos], x);
                let n = pts.len();
                let v = rng.normal_vec(n);
                let (ki, kf) = (inc.k_sorted(&v), fresh.k_sorted(&v));
                let (gi, gf) = (inc.gs_block_solve_sorted(&v), fresh.gs_block_solve_sorted(&v));
                for i in 0..n {
                    assert!((ki[i] - kf[i]).abs() < 1e-9, "{nu:?} K i={i}");
                    assert!((gi[i] - gf[i]).abs() < 1e-9, "{nu:?} T i={i}");
                }
            }
        }
    }

    /// `insert_points` (one sweep per batch) acts identically to a
    /// from-scratch build on the extended point set.
    #[test]
    fn insert_points_matches_fresh_build() {
        for nu in [Nu::Half, Nu::ThreeHalves] {
            let mut rng = Rng::new(33);
            let mut pts = rng.uniform_vec(26, 0.0, 4.0);
            let kern = Matern::new(nu, 1.05);
            let mut inc = DimFactor::new(&pts, kern, 0.6);
            let batch = [1.91, -0.3, 4.4, 2.6, 0.44];
            let positions = inc.insert_points(&batch).expect("distinct batch");
            pts.extend_from_slice(&batch);
            let fresh = DimFactor::new(&pts, kern, 0.6);
            assert_eq!(positions.len(), batch.len());
            for (t, &x) in batch.iter().enumerate() {
                assert_eq!(inc.kp.xs[positions[t]], x);
            }
            let n = pts.len();
            let v = rng.normal_vec(n);
            let (ki, kf) = (inc.k_sorted(&v), fresh.k_sorted(&v));
            let (gi, gf) =
                (inc.gs_block_solve_sorted(&v), fresh.gs_block_solve_sorted(&v));
            for i in 0..n {
                assert!((ki[i] - kf[i]).abs() < 1e-9, "{nu:?} K i={i}");
                assert!((gi[i] - gf[i]).abs() < 1e-9, "{nu:?} T i={i}");
            }
        }
    }

    #[test]
    fn c_band_matches_dense_inverse() {
        for nu in [Nu::Half, Nu::ThreeHalves] {
            let mut f = factor(30, nu, 1.0, 7);
            let w = f.kp.w();
            let c = f.c_band().clone();
            // Dense Φ^{-T} A^{-1} = (A Φ^T)^{-1}.
            let h = f.kp.a.to_dense().matmul(&f.kp.phi.to_dense().transpose());
            let hinv = h.inverse();
            for i in 0..30 {
                let (lo, hi) = c.row_range(i);
                for j in lo..hi {
                    assert!(
                        (c.get(i, j) - hinv.get(i, j)).abs()
                            < 1e-7 * hinv.get(i, j).abs().max(1.0),
                        "{nu:?} ({i},{j}) band={} dense={}",
                        c.get(i, j),
                        hinv.get(i, j)
                    );
                }
                let _ = w;
            }
        }
    }

    /// `φ_d(x*)^T C_d φ_d(x*)` must equal `k_d(x*,X) K_d^{-1} k_d(X,x*)` —
    /// the second posterior-variance term of eq. (13) vs its dense form.
    #[test]
    fn variance_term2_matches_dense() {
        let mut f = factor(35, Nu::ThreeHalves, 1.5, 11);
        let c = f.c_band().clone();
        let kern = *f.kernel();
        let mut rng = Rng::new(12);
        let kd = kern.gram(&f.kp.xs);
        let kinv = kd.inverse();
        for _ in 0..10 {
            let x = rng.uniform_in(-0.2, 4.2);
            let (start, vals) = f.kp.phi_window(x);
            let mut sparse = 0.0;
            for (r, &vi) in vals.iter().enumerate() {
                for (s, &vj) in vals.iter().enumerate() {
                    sparse += vi * vj * c.get(start + r, start + s);
                }
            }
            let gamma: Vec<f64> = f.kp.xs.iter().map(|&p| kern.k(p, x)).collect();
            let dense = gamma
                .iter()
                .zip(kinv.matvec(&gamma))
                .map(|(a, b)| a * b)
                .sum::<f64>();
            assert!(
                (sparse - dense).abs() < 1e-6 * dense.abs().max(1.0),
                "x={x}: sparse={sparse} dense={dense}"
            );
        }
    }
}
