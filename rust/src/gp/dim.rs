//! Per-dimension factorization state: one [`KpFactorization`] plus the
//! banded LU factors every downstream algorithm reuses, and (lazily) the
//! generalized-KP factorization for gradients.

use std::sync::Arc;
use std::time::Instant;

use crate::check::{enforce, Audit, AuditError};
use crate::kernels::gkp::GkpFactorization;
use crate::kernels::kp::KpFactorization;
use crate::kernels::matern::Matern;
use crate::linalg::banded::{BandedLU, PatchOutcome, PatchPolicy, SpliceInfo};
use crate::linalg::block_tridiag::selected_inverse_band;
use crate::linalg::{Banded, StorageStats};

/// Wall-clock split of the incremental insert path, accumulated per
/// dimension — lets benches (and operators) separate the `O(log n)` KP
/// window patch from the factor-LU update (DESIGN.md §FitState, "Sublinear
/// LU patching").
#[derive(Clone, Copy, Debug, Default)]
pub struct PatchTimings {
    /// Seconds spent in `KpFactorization::insert{,_batch}` (position search,
    /// band splice, packet re-solves).
    pub kp_patch_s: f64,
    /// Seconds spent updating `T`/`Φᵀ` and the four banded LUs
    /// (`BandedLU::refactor_from` — patched or re-swept).
    pub factor_s: f64,
}

impl PatchTimings {
    /// Elementwise accumulate (used when summing over dimensions).
    pub fn accumulate(&mut self, other: &PatchTimings) {
        self.kp_patch_s += other.kp_patch_s;
        self.factor_s += other.factor_s;
    }
}

/// Everything the engine needs about one additive dimension `d`:
/// `P_d^T K_d P_d = A_d^{-1} Φ_d`, the Gauss–Seidel block matrix
/// `T_d = A_d + σ⁻²Φ_d`, and LU factors of `Φ_d`, `Φ_d^T`, `T_d`.
/// `Clone` supports the coordinator's immutable read snapshots
/// ([`crate::gp::fit_state::PosteriorSnapshot`]).
#[derive(Clone)]
pub struct DimFactor {
    pub kp: KpFactorization,
    /// `T_d = A_d + σ_y^{-2} Φ_d`, maintained incrementally through inserts
    /// (band splice + window rewrite) so the LU patch never pays an `O(νn)`
    /// rebuild. Invariant: bit-identical to
    /// `kp.a.add_scaled(&kp.phi, 1/σ_y²)`.
    pub t: Banded,
    /// `Φ_d^T`, maintained incrementally. Invariant: bit-identical to
    /// `kp.phi.transpose()`.
    pub phit: Banded,
    /// LU of `T_d = A_d + σ_y^{-2} Φ_d` (the Algorithm 4 block solve).
    pub t_lu: BandedLU,
    /// LU of `Φ_d`.
    pub phi_lu: BandedLU,
    /// LU of `Φ_d^T`.
    pub phit_lu: BandedLU,
    /// LU of `A_d` (log-det term of eq. 14 and `K_d`-matvecs).
    pub a_lu: BandedLU,
    /// Lazily-built generalized KP (Algorithm 3) for `∂_ω K_d`.
    /// `Arc`-shared: immutable once built (inserts reset it to `None`), so
    /// snapshot clones bump a reference instead of deep-copying its bands.
    gkp: Option<Arc<GkpFactorization>>,
    /// Lazily-built `2ν`-band of `Φ_d^{-T} A_d^{-1}` (Algorithm 5).
    c_band: Option<Banded>,
    pub sigma2_y: f64,
    /// How inserts update the four LUs (DESIGN.md §FitState, "Sublinear LU
    /// patching"). `Exact` (the default) reuses the elimination prefix and
    /// stays bit-identical to a from-scratch factorization.
    pub patch_policy: PatchPolicy,
    /// LU updates served by the prefix-reuse patch (per factor, so up to 4
    /// per insert).
    pub factor_patches: u64,
    /// LU updates that fell back to the full `O(ν²n)` re-sweep
    /// ([`PatchPolicy::Resweep`], or an insertion so close to the front that
    /// no clean resume boundary exists above row 0).
    pub factor_resweeps: u64,
    /// Accumulated wall-clock split of the insert path.
    pub timings: PatchTimings,
    /// Whether `xs` is strictly increasing. Degenerate (duplicate-cluster)
    /// states disable the incremental path — every insert falls back to a
    /// full rebuild until a rebuild separates the points again.
    monotone: bool,
}

impl DimFactor {
    /// Factorize dimension `d`'s covariance for scattered `points`.
    pub fn new(points: &[f64], kernel: Matern, sigma2_y: f64) -> Self {
        let kp = KpFactorization::new(points, kernel);
        let monotone = kp.xs.windows(2).all(|p| p[1] > p[0]);
        let t = kp.a.add_scaled(&kp.phi, 1.0 / sigma2_y);
        let phit = kp.phi.transpose();
        let t_lu = t.lu();
        let phi_lu = kp.phi.lu();
        let phit_lu = phit.lu();
        let a_lu = kp.a.lu();
        DimFactor {
            kp,
            t,
            phit,
            t_lu,
            phi_lu,
            phit_lu,
            a_lu,
            gkp: None,
            c_band: None,
            sigma2_y,
            patch_policy: PatchPolicy::Exact,
            factor_patches: 0,
            factor_resweeps: 0,
            timings: PatchTimings::default(),
            monotone,
        }
    }

    /// Reassemble a dimension from checkpoint-decoded parts (journal
    /// recovery). The lazy GKP and band-of-inverse stay unmaterialized —
    /// both are pure functions of the factors, rebuilt on demand, and never
    /// affect prediction bits — and `monotone` is restored verbatim (it is
    /// sticky state, not derivable: after a remove it can lag the grid
    /// until the next rebuild, and recomputing it would steer the recovered
    /// engine onto a different insert path than the live one).
    #[allow(clippy::too_many_arguments)]
    pub fn from_parts(
        kp: KpFactorization,
        t: Banded,
        phit: Banded,
        t_lu: BandedLU,
        phi_lu: BandedLU,
        phit_lu: BandedLU,
        a_lu: BandedLU,
        sigma2_y: f64,
        patch_policy: PatchPolicy,
        factor_patches: u64,
        factor_resweeps: u64,
        monotone: bool,
    ) -> Self {
        DimFactor {
            kp,
            t,
            phit,
            t_lu,
            phi_lu,
            phit_lu,
            a_lu,
            gkp: None,
            c_band: None,
            sigma2_y,
            patch_policy,
            factor_patches,
            factor_resweeps,
            timings: PatchTimings::default(),
            monotone,
        }
    }

    /// Whether `xs` is strictly increasing (see the field docs) — travels
    /// through checkpoints via [`DimFactor::from_parts`].
    pub fn monotone(&self) -> bool {
        self.monotone
    }

    /// Incrementally absorb one new point (appended in data order):
    /// `O(2ν+1)` packet re-solves via [`KpFactorization::insert`], then a
    /// *patched* update of all four banded LUs via
    /// [`BandedLU::refactor_from`] — the untouched elimination prefix is
    /// reused verbatim and only rows from the lowest touched row on are
    /// re-eliminated. For an append-ordered insert (new maximum) that is
    /// `O(ν²(w+ν))` arithmetic per factor — no `O(ν²n)` sweep; a mid-matrix
    /// insert re-eliminates `O(n − pos)` rows (with an optional
    /// tolerance-gated early-exit under [`PatchPolicy::EarlyExit`]), and a
    /// full re-sweep runs only when no clean resume boundary exists above
    /// row 0 — the split is counted in [`DimFactor::factor_patches`] /
    /// [`DimFactor::factor_resweeps`]. Under the default
    /// [`PatchPolicy::Exact`] every path is bit-identical to a from-scratch
    /// build. The lazy GKP and band-of-inverse are invalidated and rebuilt
    /// on next use.
    ///
    /// Returns the sorted insertion position, or `None` when the point
    /// cannot be inserted incrementally (degenerate duplicate cluster) — the
    /// caller should rebuild this dimension with [`DimFactor::new`].
    pub fn insert_point(&mut self, x: f64) -> Option<usize> {
        if !self.monotone {
            return None;
        }
        let t0 = Instant::now();
        let pos = self.kp.insert(x)?;
        let t1 = Instant::now();
        self.patch_factors(&[pos]);
        self.timings.kp_patch_s += (t1 - t0).as_secs_f64();
        self.timings.factor_s += t1.elapsed().as_secs_f64();
        self.gkp = None;
        self.c_band = None;
        enforce(self, "DimFactor::insert_point");
        Some(pos)
    }

    /// Batched form of [`DimFactor::insert_point`]: absorb `values` (in data
    /// order) with **one** union-of-windows KP patch
    /// ([`KpFactorization::insert_batch`]) and **one** LU update per factor
    /// for the whole batch. The factor update is *not* an unconditional
    /// `O(ν²n)` sweep: [`BandedLU::refactor_from`] reuses the elimination
    /// prefix `[0, p_min − 2ν)` verbatim and re-eliminates only from the
    /// lowest touched row, so an append-ordered batch costs
    /// `O(ν²(m + w + ν))` per factor while a batch spanning the whole index
    /// range degrades gracefully toward the old full sweep (patched vs
    /// re-swept updates are counted in [`DimFactor::factor_patches`] /
    /// [`DimFactor::factor_resweeps`]; a re-sweep triggers only on the
    /// [`PatchPolicy::Resweep`] kill switch or a batch touching the very
    /// first rows). Returns each value's final sorted position.
    ///
    /// Returns `None` with the factor state untouched when the batch hits a
    /// degenerate duplicate cluster (or the dimension is already
    /// non-monotone); the caller replays the sequential path for this
    /// dimension so batch semantics stay bit-identical to per-point
    /// observes.
    pub fn insert_points(&mut self, values: &[f64]) -> Option<Vec<usize>> {
        if !self.monotone {
            return None;
        }
        let t0 = Instant::now();
        let positions = self.kp.insert_batch(values)?;
        let t1 = Instant::now();
        if !positions.is_empty() {
            // lint: cow-ok (Vec<usize> of batch positions, not band storage)
            let mut sorted = positions.clone();
            sorted.sort_unstable();
            self.patch_factors(&sorted);
        }
        self.timings.kp_patch_s += (t1 - t0).as_secs_f64();
        self.timings.factor_s += t1.elapsed().as_secs_f64();
        self.gkp = None;
        self.c_band = None;
        enforce(self, "DimFactor::insert_points");
        Some(positions)
    }

    /// Incrementally release the point at sorted position `pos` — the
    /// deletion mirror of [`DimFactor::insert_point`], behind
    /// `FitState::forget` (DESIGN.md §FitState, "Downdates & rolling
    /// windows"): `O(2ν+1)` packet re-solves via
    /// [`KpFactorization::remove`], then a patched update of all four
    /// banded LUs from the lowest removed row via
    /// [`BandedLU::refactor_from`]. The tail of a removal shifts *up*, which
    /// the early-exit's downward-shift replay cannot describe, so the
    /// splice carries no tail — under [`PatchPolicy::EarlyExit`] a removal
    /// simply re-eliminates to the end (i.e. behaves like
    /// [`PatchPolicy::Exact`], staying bit-identical to a from-scratch
    /// build). The lazy GKP and band-of-inverse are invalidated.
    ///
    /// Returns the removed point's *original* (data-order) index, or `None`
    /// when the dimension is degenerate (non-monotone) — the caller rebuilds
    /// from the compacted data instead. Panics if the removal would drop `n`
    /// below the packet minimum `2w+1`; the caller deactivates first.
    pub fn remove_point(&mut self, pos: usize) -> Option<usize> {
        if !self.monotone {
            return None;
        }
        let t0 = Instant::now();
        let orig = self.kp.remove(pos);
        let t1 = Instant::now();
        self.unpatch_factors(&[pos]);
        self.timings.kp_patch_s += (t1 - t0).as_secs_f64();
        self.timings.factor_s += t1.elapsed().as_secs_f64();
        self.gkp = None;
        self.c_band = None;
        enforce(self, "DimFactor::remove_point");
        Some(orig)
    }

    /// Batched form of [`DimFactor::remove_point`]: release the points at
    /// `sorted_positions` (current sorted indices, strictly increasing) with
    /// **one** union-of-windows KP patch ([`KpFactorization::remove_batch`])
    /// and **one** LU update per factor for the whole batch. Returns the
    /// removed points' *original* indices (pre-compaction, in the order of
    /// `sorted_positions`), or `None` when the dimension is degenerate.
    pub fn remove_points(&mut self, sorted_positions: &[usize]) -> Option<Vec<usize>> {
        if !self.monotone {
            return None;
        }
        if sorted_positions.is_empty() {
            return Some(Vec::new());
        }
        let t0 = Instant::now();
        let origs = self.kp.remove_batch(sorted_positions);
        let t1 = Instant::now();
        self.unpatch_factors(sorted_positions);
        self.timings.kp_patch_s += (t1 - t0).as_secs_f64();
        self.timings.factor_s += t1.elapsed().as_secs_f64();
        self.gkp = None;
        self.c_band = None;
        enforce(self, "DimFactor::remove_points");
        Some(origs)
    }

    /// Update `T`, `Φᵀ` and the four banded LUs after the KP factorization
    /// released the points at `sorted_positions` (pre-removal sorted
    /// indices, strictly increasing) — the deletion mirror of
    /// [`DimFactor::patch_factors`]. `T`/`Φᵀ` get one band deletion plus a
    /// window rewrite from the freshly patched `A`/`Φ` (bit-identical to a
    /// from-scratch `add_scaled`/`transpose`); each LU is then patched from
    /// its lowest touched row. `SpliceInfo.tail` stays `None`: a removal
    /// shifts the tail *up*, outside the early-exit's downward-shift replay,
    /// so every policy re-eliminates `[low − kl, n)` exactly.
    fn unpatch_factors(&mut self, sorted_positions: &[usize]) {
        let w = self.kp.w();
        let pmin = sorted_positions[0];
        self.t.remove_rows_cols(sorted_positions);
        self.phit.remove_rows_cols(sorted_positions);
        let n = self.kp.n();
        let inv_s2 = 1.0 / self.sigma2_y;
        // Post-removal coordinates where the gaps closed: position t of the
        // batch lost t earlier neighbors. (Adjacent removals can collapse to
        // equal coordinates; the union walk tolerates that.)
        let post: Vec<usize> =
            sorted_positions.iter().enumerate().map(|(t, &q)| q - t).collect();
        {
            let DimFactor { t, phit, kp, .. } = self;
            // T rows: the KP rewrite windows [q′−w, q′+w] (covers the band
            // deletion straddle, max(kl, ku) = w).
            for_union_rows(n, &post, w, |i| {
                let (lo, hi) = t.row_range(i);
                for j in lo..hi {
                    t.set(i, j, kp.a.get(i, j) + inv_s2 * kp.phi.get(i, j));
                }
            });
            // Φᵀ rows: every Φ column a rewritten Φ row covers,
            // [q′−(2w−1), q′+(2w−1)].
            for_union_rows(n, &post, 2 * w - 1, |i| {
                let (lo, hi) = phit.row_range(i);
                for j in lo..hi {
                    phit.set(i, j, kp.phi.get(j, i));
                }
            });
        }
        let policy = self.patch_policy;
        let outcomes = [
            self.t_lu.refactor_from(
                &self.t,
                &SpliceInfo { low: pmin.saturating_sub(w), tail: None },
                policy,
            ),
            self.phi_lu.refactor_from(
                &self.kp.phi,
                &SpliceInfo { low: pmin.saturating_sub(w), tail: None },
                policy,
            ),
            self.phit_lu.refactor_from(
                &self.phit,
                &SpliceInfo { low: pmin.saturating_sub(2 * w - 1), tail: None },
                policy,
            ),
            self.a_lu.refactor_from(
                &self.kp.a,
                &SpliceInfo { low: pmin.saturating_sub(w), tail: None },
                policy,
            ),
        ];
        for o in outcomes {
            match o {
                PatchOutcome::Patched { .. } => self.factor_patches += 1,
                PatchOutcome::Resweep => self.factor_resweeps += 1,
            }
        }
    }

    /// Update `T`, `Φᵀ` and the four banded LUs after the KP factorization
    /// absorbed inserts at `sorted_positions` (final sorted indices,
    /// strictly increasing). `T`/`Φᵀ` get one zero row/col splice plus a
    /// window rewrite from the freshly patched `A`/`Φ` (bit-identical to a
    /// from-scratch `add_scaled`/`transpose`); each LU is then patched by
    /// [`BandedLU::refactor_from`] with its own lowest-touched row and
    /// uniform-shift tail.
    fn patch_factors(&mut self, sorted_positions: &[usize]) {
        let w = self.kp.w();
        let m = sorted_positions.len();
        let pmin = sorted_positions[0];
        let pmax = *sorted_positions.last().unwrap();
        self.t.insert_rows_cols(sorted_positions);
        self.phit.insert_rows_cols(sorted_positions);
        let n = self.kp.n();
        let inv_s2 = 1.0 / self.sigma2_y;
        {
            let DimFactor { t, phit, kp, .. } = self;
            // T rows: the KP rewrite windows [p−w, p+w] (covers the splice
            // straddle, max(kl, ku) = w).
            for_union_rows(n, sorted_positions, w, |i| {
                let (lo, hi) = t.row_range(i);
                for j in lo..hi {
                    t.set(i, j, kp.a.get(i, j) + inv_s2 * kp.phi.get(i, j));
                }
            });
            // Φᵀ rows: every Φ column a rewritten Φ row covers,
            // [p−(2w−1), p+(2w−1)].
            for_union_rows(n, sorted_positions, 2 * w - 1, |i| {
                let (lo, hi) = phit.row_range(i);
                for j in lo..hi {
                    phit.set(i, j, kp.phi.get(j, i));
                }
            });
        }
        let policy = self.patch_policy;
        let tail = |h: usize| {
            let from = pmax + h + 1;
            if from < n {
                Some((from, m))
            } else {
                None
            }
        };
        let outcomes = [
            self.t_lu.refactor_from(
                &self.t,
                &SpliceInfo { low: pmin.saturating_sub(w), tail: tail(w) },
                policy,
            ),
            self.phi_lu.refactor_from(
                &self.kp.phi,
                &SpliceInfo { low: pmin.saturating_sub(w), tail: tail(w) },
                policy,
            ),
            self.phit_lu.refactor_from(
                &self.phit,
                &SpliceInfo { low: pmin.saturating_sub(2 * w - 1), tail: tail(2 * w - 1) },
                policy,
            ),
            self.a_lu.refactor_from(
                &self.kp.a,
                &SpliceInfo { low: pmin.saturating_sub(w), tail: tail(w) },
                policy,
            ),
        ];
        for o in outcomes {
            match o {
                PatchOutcome::Patched { .. } => self.factor_patches += 1,
                PatchOutcome::Resweep => self.factor_resweeps += 1,
            }
        }
    }

    pub fn n(&self) -> usize {
        self.kp.n()
    }

    pub fn kernel(&self) -> &Matern {
        &self.kp.kernel
    }

    /// Apply `K_d^{-1} = Φ_d^{-1} A_d` to a vector in sorted coordinates.
    pub fn kinv_sorted(&self, v: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; v.len()];
        self.kinv_sorted_into(v, &mut out);
        out
    }

    /// [`DimFactor::kinv_sorted`] into a caller-owned buffer: one banded
    /// matvec plus one in-place banded solve, no allocation (the hot-loop
    /// form; DESIGN.md §Perf).
    pub fn kinv_sorted_into(&self, v: &[f64], out: &mut [f64]) {
        self.kp.a.matvec_into(v, out);
        self.phi_lu.solve_in_place(out);
    }

    /// Apply `K_d = A_d^{-1} Φ_d` to a vector in sorted coordinates.
    pub fn k_sorted(&self, v: &[f64]) -> Vec<f64> {
        self.a_lu.solve(&self.kp.phi.matvec(v))
    }

    /// Solve the Algorithm 4 block system in sorted coordinates:
    /// `(K_d^{-1} + σ⁻²I) u = w  ⟺  (A_d + σ⁻²Φ_d) u = Φ_d w`.
    pub fn gs_block_solve_sorted(&self, w: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; w.len()];
        self.gs_block_solve_sorted_into(w, &mut out);
        out
    }

    /// [`DimFactor::gs_block_solve_sorted`] into a caller-owned buffer, no
    /// allocation.
    pub fn gs_block_solve_sorted_into(&self, w: &[f64], out: &mut [f64]) {
        self.kp.phi.matvec_into(w, out);
        self.t_lu.solve_in_place(out);
    }

    /// The generalized-KP factorization (built on first use).
    pub fn gkp(&mut self) -> &GkpFactorization {
        if self.gkp.is_none() {
            self.gkp =
                Some(Arc::new(GkpFactorization::new_sorted(&self.kp.xs, *self.kernel())));
        }
        self.gkp.as_deref().unwrap()
    }

    /// The central band of `C_d = Φ_d^{-T} A_d^{-1}` (paper Algorithm 5;
    /// built on first use). `H = A_d Φ_d^T = A_d K_d A_d^T` is symmetric
    /// positive definite and `2ν`-banded; the needed band of its inverse
    /// comes from the selected block-tridiagonal inverse in `O(ν² n)`.
    ///
    /// Note: the paper's summary table says the `(ν+1/2)`-band, but its own
    /// eq. (25) pairs window entries up to `2ν` apart, so we store the
    /// `2ν`-band — the asymptotic cost is identical.
    pub fn c_band(&mut self) -> &Banded {
        if self.c_band.is_none() {
            let h = self.kp.a.matmul(&self.kp.phi.transpose());
            // Symmetrize against round-off before inverting.
            // lint: cow-ok (reference-bump clone; writes below COW per chunk)
            let mut hs = h.clone();
            for i in 0..hs.n() {
                let (lo, hi) = hs.row_range(i);
                for j in lo..hi {
                    if j > i {
                        let v = 0.5 * (h.get(i, j) + h.get(j, i));
                        hs.set(i, j, v);
                        hs.set(j, i, v);
                    }
                }
            }
            self.c_band = Some(selected_inverse_band(&hs, 2 * self.kp.w() - 1));
        }
        self.c_band.as_ref().unwrap()
    }

    /// Whether the band-of-inverse has been materialized yet.
    pub fn has_c_band(&self) -> bool {
        self.c_band.is_some()
    }

    /// Immutable access to the band-of-inverse if already built.
    pub fn c_band_cached(&self) -> Option<&Banded> {
        self.c_band.as_ref()
    }

    /// Immutable access to the generalized-KP factorization if already built.
    pub fn gkp_cached(&self) -> Option<&GkpFactorization> {
        self.gkp.as_deref()
    }

    /// Summed storage counters over every band rope this dimension owns:
    /// the raw `A`/`Φ`, the maintained `T`/`Φᵀ`, the four packed LU
    /// factors, and the lazy band-of-inverse when built.
    pub fn storage_stats(&self) -> StorageStats {
        let mut s = StorageStats::default();
        s.accumulate(self.kp.a.storage_stats());
        s.accumulate(self.kp.phi.storage_stats());
        s.accumulate(self.t.storage_stats());
        s.accumulate(self.phit.storage_stats());
        s.accumulate(self.t_lu.storage_stats());
        s.accumulate(self.phi_lu.storage_stats());
        s.accumulate(self.phit_lu.storage_stats());
        s.accumulate(self.a_lu.storage_stats());
        if let Some(c) = &self.c_band {
            s.accumulate(c.storage_stats());
        }
        s
    }

    /// Settle every band rope before a snapshot clone (see
    /// [`Banded::mark_storage_clean`]): clears the dirty flags so the clone
    /// is a pure reference bump. Returns summed `(dirtied, total)` chunk
    /// counts — `total − dirtied` chunks are shared with the previous
    /// generation unchanged.
    pub fn mark_storage_clean(&mut self) -> (u64, u64) {
        let mut dirtied = 0u64;
        let mut total = 0u64;
        for (d, t) in [
            self.kp.a.mark_storage_clean(),
            self.kp.phi.mark_storage_clean(),
            self.t.mark_storage_clean(),
            self.phit.mark_storage_clean(),
            self.t_lu.mark_storage_clean(),
            self.phi_lu.mark_storage_clean(),
            self.phit_lu.mark_storage_clean(),
            self.a_lu.mark_storage_clean(),
        ] {
            dirtied += d;
            total += t;
        }
        if let Some(c) = self.c_band.as_mut() {
            let (d, t) = c.mark_storage_clean();
            dirtied += d;
            total += t;
        }
        (dirtied, total)
    }
}

impl Audit for DimFactor {
    /// Verifies the two *materialization* invariants documented on the
    /// fields — `T` is **bit-identical** to `A + σ_y^{-2}Φ` over its band and
    /// `Φᵀ` bit-identical to `Φ` transposed (both maintenance paths compute
    /// exactly these expressions, so equality is `==`, not a tolerance) —
    /// plus shape agreement between the four banded LUs and the matrices
    /// they factor. Child audits (`kp`, each LU) propagate their own
    /// structure names; failures here name the desynced row.
    fn audit(&self) -> Result<(), AuditError> {
        self.kp.audit()?;
        let n = self.kp.n();
        let w = self.kp.w();
        if self.monotone {
            // The incremental path is only sound over strictly increasing
            // points; the KP audit alone tolerates the degenerate equal-
            // adjacent case that sets `monotone = false`.
            for i in 1..n {
                if self.kp.xs[i] <= self.kp.xs[i - 1] {
                    return Err(AuditError::new(
                        "DimFactor",
                        "monotone",
                        Some(i),
                        format!(
                            "monotone flag set but xs[{}] = {} ≥ xs[{i}] = {}",
                            i - 1,
                            self.kp.xs[i - 1],
                            self.kp.xs[i]
                        ),
                    ));
                }
            }
        }
        if !(self.sigma2_y.is_finite() && self.sigma2_y > 0.0) {
            return Err(AuditError::new(
                "DimFactor",
                "sigma2_y",
                None,
                format!("noise variance {} not positive/finite", self.sigma2_y),
            ));
        }
        self.t.audit()?;
        if self.t.n() != n || self.t.kl() != w || self.t.ku() != w {
            return Err(AuditError::new(
                "DimFactor",
                "t",
                None,
                format!(
                    "T shape (n={}, kl={}, ku={}) != (n={n}, w={w}, w={w})",
                    self.t.n(),
                    self.t.kl(),
                    self.t.ku()
                ),
            ));
        }
        self.phit.audit()?;
        if self.phit.n() != n || self.phit.kl() != w - 1 || self.phit.ku() != w - 1 {
            return Err(AuditError::new(
                "DimFactor",
                "phit",
                None,
                format!(
                    "Φᵀ shape (n={}, kl={}, ku={}) != (n={n}, w−1={}, w−1={})",
                    self.phit.n(),
                    self.phit.kl(),
                    self.phit.ku(),
                    w - 1,
                    w - 1
                ),
            ));
        }
        let inv_s2 = 1.0 / self.sigma2_y;
        for i in 0..n {
            let (lo, hi) = self.t.row_range(i);
            for j in lo..hi {
                let want = self.kp.a.get(i, j) + inv_s2 * self.kp.phi.get(i, j);
                if self.t.get(i, j) != want {
                    return Err(AuditError::new(
                        "DimFactor",
                        "t",
                        Some(i),
                        format!(
                            "T[{i},{j}] = {} desynced from A + σ⁻²Φ = {want}",
                            self.t.get(i, j)
                        ),
                    ));
                }
            }
            let (lo, hi) = self.phit.row_range(i);
            for j in lo..hi {
                if self.phit.get(i, j) != self.kp.phi.get(j, i) {
                    return Err(AuditError::new(
                        "DimFactor",
                        "phit",
                        Some(i),
                        format!(
                            "Φᵀ[{i},{j}] = {} desynced from Φ[{j},{i}] = {}",
                            self.phit.get(i, j),
                            self.kp.phi.get(j, i)
                        ),
                    ));
                }
            }
        }
        for (name, lu) in [
            ("t_lu", &self.t_lu),
            ("phi_lu", &self.phi_lu),
            ("phit_lu", &self.phit_lu),
            ("a_lu", &self.a_lu),
        ] {
            lu.audit()?;
            if lu.n() != n {
                return Err(AuditError::new(
                    "DimFactor",
                    name,
                    None,
                    format!("LU size {} disagrees with n = {n}", lu.n()),
                ));
            }
        }
        if self.t_lu.kl() != w || self.phi_lu.kl() != w - 1 || self.a_lu.kl() != w {
            return Err(AuditError::new(
                "DimFactor",
                "t_lu",
                None,
                format!(
                    "LU bandwidths (t={}, phi={}, a={}) disagree with w = {w}",
                    self.t_lu.kl(),
                    self.phi_lu.kl(),
                    self.a_lu.kl()
                ),
            ));
        }
        if let Some(c) = &self.c_band {
            c.audit()?;
            if c.n() != n {
                return Err(AuditError::new(
                    "DimFactor",
                    "c_band",
                    None,
                    format!("band-of-inverse size {} disagrees with n = {n}", c.n()),
                ));
            }
        }
        Ok(())
    }
}

/// Visit each row in the union of the windows `[q−h, q+h]` over the
/// strictly-increasing `sorted_positions` exactly once (the same coverage
/// walk as `KpFactorization::insert_batch`).
fn for_union_rows(n: usize, sorted_positions: &[usize], h: usize, mut f: impl FnMut(usize)) {
    let mut next = 0usize;
    for &q in sorted_positions {
        let lo = q.saturating_sub(h).max(next);
        let hi = (q + h).min(n - 1);
        if lo > hi {
            continue;
        }
        for i in lo..=hi {
            f(i);
        }
        next = hi + 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::matern::Nu;
    use crate::util::Rng;

    fn factor(n: usize, nu: Nu, omega: f64, seed: u64) -> DimFactor {
        let mut rng = Rng::new(seed);
        let pts = rng.uniform_vec(n, 0.0, 4.0);
        DimFactor::new(&pts, Matern::new(nu, omega), 0.5)
    }

    #[test]
    fn kinv_is_inverse_of_k() {
        // Round-trip error scales with cond(K): machine precision for ν=1/2
        // (tridiagonal Markov inverse), growing with smoothness — Matérn-5/2
        // grams over clustered random points are within a few digits of
        // singular in f64, so the tolerance is graded.
        for (nu, tol) in
            [(Nu::Half, 1e-9), (Nu::ThreeHalves, 1e-6), (Nu::FiveHalves, 5e-3)]
        {
            let f = factor(30, nu, 1.2, 3);
            let mut rng = Rng::new(4);
            let v = rng.normal_vec(30);
            let w = f.kinv_sorted(&f.k_sorted(&v));
            for i in 0..30 {
                assert!((w[i] - v[i]).abs() < tol, "{nu:?} i={i}: {} vs {}", w[i], v[i]);
            }
        }
    }

    #[test]
    fn gs_block_solve_is_consistent() {
        let f = factor(25, Nu::ThreeHalves, 0.8, 5);
        let mut rng = Rng::new(6);
        let w = rng.normal_vec(25);
        let u = f.gs_block_solve_sorted(&w);
        // Check (K^{-1} + σ⁻²I) u = w.
        let r = f.kinv_sorted(&u);
        for i in 0..25 {
            assert!((r[i] + u[i] / 0.5 - w[i]).abs() < 1e-7, "i={i}");
        }
    }

    /// `insert_point` produces factors that act identically to a
    /// from-scratch build on the extended point set.
    #[test]
    fn insert_point_matches_fresh_build() {
        for nu in [Nu::Half, Nu::ThreeHalves] {
            let mut rng = Rng::new(31);
            let mut pts = rng.uniform_vec(24, 0.0, 4.0);
            let kern = Matern::new(nu, 1.1);
            let mut inc = DimFactor::new(&pts, kern, 0.7);
            for &x in &[1.234, -0.4, 4.6] {
                let pos = inc.insert_point(x).expect("distinct point");
                pts.push(x);
                let fresh = DimFactor::new(&pts, kern, 0.7);
                assert_eq!(inc.kp.xs[pos], x);
                let n = pts.len();
                let v = rng.normal_vec(n);
                let (ki, kf) = (inc.k_sorted(&v), fresh.k_sorted(&v));
                let (gi, gf) = (inc.gs_block_solve_sorted(&v), fresh.gs_block_solve_sorted(&v));
                for i in 0..n {
                    assert!((ki[i] - kf[i]).abs() < 1e-9, "{nu:?} K i={i}");
                    assert!((gi[i] - gf[i]).abs() < 1e-9, "{nu:?} T i={i}");
                }
            }
        }
    }

    /// `insert_points` (one sweep per batch) acts identically to a
    /// from-scratch build on the extended point set.
    #[test]
    fn insert_points_matches_fresh_build() {
        for nu in [Nu::Half, Nu::ThreeHalves] {
            let mut rng = Rng::new(33);
            let mut pts = rng.uniform_vec(26, 0.0, 4.0);
            let kern = Matern::new(nu, 1.05);
            let mut inc = DimFactor::new(&pts, kern, 0.6);
            let batch = [1.91, -0.3, 4.4, 2.6, 0.44];
            let positions = inc.insert_points(&batch).expect("distinct batch");
            pts.extend_from_slice(&batch);
            let fresh = DimFactor::new(&pts, kern, 0.6);
            assert_eq!(positions.len(), batch.len());
            for (t, &x) in batch.iter().enumerate() {
                assert_eq!(inc.kp.xs[positions[t]], x);
            }
            let n = pts.len();
            let v = rng.normal_vec(n);
            let (ki, kf) = (inc.k_sorted(&v), fresh.k_sorted(&v));
            let (gi, gf) =
                (inc.gs_block_solve_sorted(&v), fresh.gs_block_solve_sorted(&v));
            for i in 0..n {
                assert!((ki[i] - kf[i]).abs() < 1e-9, "{nu:?} K i={i}");
                assert!((gi[i] - gf[i]).abs() < 1e-9, "{nu:?} T i={i}");
            }
        }
    }

    /// `remove_point` produces factors that act identically to a
    /// from-scratch build on the compacted point set — and an
    /// `insert_point` + `remove_point` round trip is bit-identical to never
    /// inserting (all four LUs included) under the default `Exact` policy.
    #[test]
    fn remove_point_matches_fresh_build() {
        for nu in [Nu::Half, Nu::ThreeHalves] {
            let mut rng = Rng::new(41);
            let mut pts = rng.uniform_vec(24, 0.0, 4.0);
            let kern = Matern::new(nu, 1.1);
            let mut inc = DimFactor::new(&pts, kern, 0.7);
            for &pos in &[11usize, 0, 20, 1] {
                let orig = inc.remove_point(pos).expect("monotone dim removes");
                pts.remove(orig);
                let fresh = DimFactor::new(&pts, kern, 0.7);
                let n = pts.len();
                let v = rng.normal_vec(n);
                let (ki, kf) = (inc.k_sorted(&v), fresh.k_sorted(&v));
                let (gi, gf) =
                    (inc.gs_block_solve_sorted(&v), fresh.gs_block_solve_sorted(&v));
                for i in 0..n {
                    assert!((ki[i] - kf[i]).abs() < 1e-9, "{nu:?} K i={i}");
                    assert!((gi[i] - gf[i]).abs() < 1e-9, "{nu:?} T i={i}");
                }
            }
        }
    }

    /// Factor-level half of the forget property: insert then remove of the
    /// same point leaves every maintained band AND all four packed LU
    /// factors bit-identical to the untouched state (`PatchPolicy::Exact`).
    #[test]
    fn insert_then_remove_restores_factors_bitwise() {
        for nu in [Nu::Half, Nu::ThreeHalves] {
            let mut rng = Rng::new(43);
            let pts = rng.uniform_vec(26, 0.0, 4.0);
            let kern = Matern::new(nu, 0.9);
            let base = DimFactor::new(&pts, kern, 0.6);
            for &x in &[1.77, -0.2, 4.5] {
                // lint: cow-ok (test clone of the whole factor state)
                let mut f = base.clone();
                let pos = f.insert_point(x).expect("distinct point");
                f.remove_point(pos).expect("monotone dim removes");
                assert_eq!(f.n(), base.n());
                let n = f.n();
                let bands = |d: &DimFactor| {
                    [
                        d.kp.a.to_flat(),
                        d.kp.phi.to_flat(),
                        d.t.to_flat(),
                        d.phit.to_flat(),
                        d.t_lu.fac_band().to_flat(),
                        d.phi_lu.fac_band().to_flat(),
                        d.phit_lu.fac_band().to_flat(),
                        d.a_lu.fac_band().to_flat(),
                    ]
                };
                for (bi, (got, want)) in
                    bands(&f).iter().zip(bands(&base).iter()).enumerate()
                {
                    assert_eq!(got, want, "{nu:?} x={x} band #{bi} diverged (n={n})");
                }
            }
        }
    }

    /// `remove_points` (one sweep per batch) equals the corresponding
    /// descending sequence of single `remove_point` calls bit-for-bit.
    #[test]
    fn remove_points_matches_sequential_removes() {
        for nu in [Nu::Half, Nu::ThreeHalves] {
            let mut rng = Rng::new(47);
            let pts = rng.uniform_vec(28, 0.0, 4.0);
            let kern = Matern::new(nu, 1.0);
            let mut batched = DimFactor::new(&pts, kern, 0.5);
            let mut seq = DimFactor::new(&pts, kern, 0.5);
            let positions = [2usize, 9, 10, 27];
            batched.remove_points(&positions).expect("monotone dim removes");
            for &p in positions.iter().rev() {
                seq.remove_point(p).expect("monotone dim removes");
            }
            assert_eq!(batched.n(), seq.n());
            assert_eq!(batched.t.to_flat(), seq.t.to_flat(), "{nu:?} T");
            assert_eq!(
                batched.t_lu.fac_band().to_flat(),
                seq.t_lu.fac_band().to_flat(),
                "{nu:?} T LU"
            );
            assert_eq!(
                batched.phit_lu.fac_band().to_flat(),
                seq.phit_lu.fac_band().to_flat(),
                "{nu:?} Φᵀ LU"
            );
        }
    }

    #[test]
    fn c_band_matches_dense_inverse() {
        for nu in [Nu::Half, Nu::ThreeHalves] {
            let mut f = factor(30, nu, 1.0, 7);
            let w = f.kp.w();
            let c = f.c_band().clone();
            // Dense Φ^{-T} A^{-1} = (A Φ^T)^{-1}.
            let h = f.kp.a.to_dense().matmul(&f.kp.phi.to_dense().transpose());
            let hinv = h.inverse();
            for i in 0..30 {
                let (lo, hi) = c.row_range(i);
                for j in lo..hi {
                    assert!(
                        (c.get(i, j) - hinv.get(i, j)).abs()
                            < 1e-7 * hinv.get(i, j).abs().max(1.0),
                        "{nu:?} ({i},{j}) band={} dense={}",
                        c.get(i, j),
                        hinv.get(i, j)
                    );
                }
                let _ = w;
            }
        }
    }

    /// Desyncing the incrementally-maintained `T = A + σ⁻²Φ` from its
    /// defining expression is pinpointed at the desynced row.
    #[test]
    fn audit_flags_desynced_t_materialization() {
        let mut f = factor(25, Nu::ThreeHalves, 1.0, 21);
        assert!(f.audit().is_ok());
        let v = f.t.get(9, 9);
        f.t.set(9, 9, v * 2.0 + 0.125); // any bit flip breaks the == invariant
        let e = f.audit().unwrap_err();
        assert_eq!(e.structure, "DimFactor");
        assert_eq!(e.field, "t");
        assert_eq!(e.index, Some(9));
    }

    /// Desyncing the maintained transpose `Φᵀ` is pinpointed likewise.
    #[test]
    fn audit_flags_desynced_phit_materialization() {
        let mut f = factor(25, Nu::Half, 1.0, 22);
        let v = f.phit.get(4, 4);
        f.phit.set(4, 4, v * 2.0 + 0.125);
        let e = f.audit().unwrap_err();
        assert_eq!(e.structure, "DimFactor");
        assert_eq!(e.field, "phit");
        assert_eq!(e.index, Some(4));
    }

    /// `φ_d(x*)^T C_d φ_d(x*)` must equal `k_d(x*,X) K_d^{-1} k_d(X,x*)` —
    /// the second posterior-variance term of eq. (13) vs its dense form.
    #[test]
    fn variance_term2_matches_dense() {
        let mut f = factor(35, Nu::ThreeHalves, 1.5, 11);
        let c = f.c_band().clone();
        let kern = *f.kernel();
        let mut rng = Rng::new(12);
        let kd = kern.gram(&f.kp.xs);
        let kinv = kd.inverse();
        for _ in 0..10 {
            let x = rng.uniform_in(-0.2, 4.2);
            let (start, vals) = f.kp.phi_window(x);
            let mut sparse = 0.0;
            for (r, &vi) in vals.iter().enumerate() {
                for (s, &vj) in vals.iter().enumerate() {
                    sparse += vi * vj * c.get(start + r, start + s);
                }
            }
            let gamma: Vec<f64> = f.kp.xs.iter().map(|&p| kern.k(p, x)).collect();
            let dense = gamma
                .iter()
                .zip(kinv.matvec(&gamma))
                .map(|(a, b)| a * b)
                .sum::<f64>();
            assert!(
                (sparse - dense).abs() < 1e-6 * dense.abs().max(1.0),
                "x={x}: sparse={sparse} dense={dense}"
            );
        }
    }
}
