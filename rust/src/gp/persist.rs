//! Bit-exact checkpoint serialization of a trained model — the payload the
//! coordinator's mutation journal compacts to (DESIGN.md §Durability).
//!
//! Why serialize *state* instead of refitting from the raw data: the
//! incremental insert/remove paths are bit-identical to a refit only under
//! [`PatchPolicy::Exact`]; under `EarlyExit` (and after mixed mutation
//! histories) the live factors can differ from a cold rebuild in the last
//! bits, and the crash-recovery contract is *bit-identity with the
//! pre-crash engine*, not merely numerical agreement. So everything that
//! influences future numeric trajectories travels verbatim:
//!
//! * the per-dimension factors and LUs (`f64` as raw IEEE bits);
//! * the posterior **and** the warm-start ṽ — presence of a posterior
//!   decides whether the next `ensure_posterior` solves at all, and ṽ seeds
//!   that solve;
//! * sticky flags (`DimFactor::monotone`) and the mutation counters.
//!
//! Deliberately *not* serialized, because they are pure functions of the
//! above (rebuilt on demand, never affecting prediction bits): the lazy
//! GKP and band-of-inverse, the `M̃` cache, and the band ropes' chunk
//! boundaries (decode re-chunks canonically; chunk layout is storage
//! bookkeeping — the soak property in `linalg/chunks.rs`). Wall-clock
//! patch timings are skipped too: they are non-deterministic observability,
//! not state.

use crate::check::Audit;
use crate::gp::backfit::{BlockVec, GsStats};
use crate::gp::dim::DimFactor;
use crate::gp::fit_state::{FitState, PosteriorSnapshot};
use crate::gp::model::{AdditiveGP, AdditiveGpConfig};
use crate::gp::posterior::Posterior;
use crate::kernels::kp::KpFactorization;
use crate::kernels::matern::{Matern, Nu};
use crate::linalg::banded::{BandedLU, PatchPolicy};
use crate::linalg::{Banded, Permutation};
use crate::util::codec::{crc32, ByteReader, ByteWriter};
use crate::util::fault;

fn put_banded(w: &mut ByteWriter, b: &Banded) {
    w.put_usize(b.n());
    w.put_usize(b.kl());
    w.put_usize(b.ku());
    // lint: cow-ok (checkpoint serialization: materialization is the point)
    w.put_f64s(&b.to_flat());
}

fn get_banded(r: &mut ByteReader<'_>, what: &str) -> Result<Banded, String> {
    let n = r.get_usize(what)?;
    let kl = r.get_usize(what)?;
    let ku = r.get_usize(what)?;
    let flat = r.get_f64s(what)?;
    Banded::from_flat(n, kl, ku, &flat).map_err(|e| format!("{what}: {e}"))
}

fn put_lu(w: &mut ByteWriter, lu: &BandedLU) {
    w.put_usize(lu.n());
    w.put_usize(lu.kl());
    w.put_usize(lu.kuf());
    put_banded(w, lu.fac_band());
    w.put_usizes(lu.piv());
    w.put_f64(lu.sign());
}

fn get_lu(r: &mut ByteReader<'_>, what: &str) -> Result<BandedLU, String> {
    let n = r.get_usize(what)?;
    let kl = r.get_usize(what)?;
    let kuf = r.get_usize(what)?;
    let fac = get_banded(r, what)?;
    let piv = r.get_usizes(what)?;
    let sign = r.get_f64(what)?;
    BandedLU::from_parts(n, kl, kuf, fac, piv, sign).map_err(|e| format!("{what}: {e}"))
}

fn put_policy(w: &mut ByteWriter, p: PatchPolicy) {
    match p {
        PatchPolicy::Resweep => w.put_u8(0),
        PatchPolicy::Exact => w.put_u8(1),
        PatchPolicy::EarlyExit { rel_tol } => {
            w.put_u8(2);
            w.put_f64(rel_tol);
        }
    }
}

fn get_policy(r: &mut ByteReader<'_>) -> Result<PatchPolicy, String> {
    match r.get_u8("patch policy")? {
        0 => Ok(PatchPolicy::Resweep),
        1 => Ok(PatchPolicy::Exact),
        2 => Ok(PatchPolicy::EarlyExit { rel_tol: r.get_f64("patch policy rel_tol")? }),
        v => Err(format!("unknown patch policy tag {v}")),
    }
}

fn put_dim(w: &mut ByteWriter, d: &DimFactor) {
    let kp = &d.kp;
    w.put_u8(kp.kernel.nu.two_nu() as u8);
    w.put_f64(kp.kernel.omega);
    w.put_f64(kp.kernel.sigma2);
    w.put_usizes(kp.perm.fwd());
    w.put_f64s(&kp.xs);
    put_banded(w, &kp.a);
    put_banded(w, &kp.phi);
    put_banded(w, &d.t);
    put_banded(w, &d.phit);
    put_lu(w, &d.t_lu);
    put_lu(w, &d.phi_lu);
    put_lu(w, &d.phit_lu);
    put_lu(w, &d.a_lu);
    w.put_f64(d.sigma2_y);
    put_policy(w, d.patch_policy);
    w.put_u64(d.factor_patches);
    w.put_u64(d.factor_resweeps);
    w.put_bool(d.monotone());
}

fn get_dim(r: &mut ByteReader<'_>) -> Result<DimFactor, String> {
    let two_nu = r.get_u8("kernel nu")? as usize;
    let nu = Nu::from_two_nu(two_nu).ok_or(format!("bad kernel 2ν = {two_nu}"))?;
    let omega = r.get_f64("kernel omega")?;
    let sigma2 = r.get_f64("kernel sigma2")?;
    let kernel = Matern { nu, omega, sigma2 };
    let fwd = r.get_usizes("perm")?;
    let perm = Permutation::from_fwd(fwd)?;
    let xs = r.get_f64s("xs")?;
    let a = get_banded(r, "kp.a")?;
    let phi = get_banded(r, "kp.phi")?;
    let kp = KpFactorization { kernel, perm, xs, a, phi };
    let t = get_banded(r, "t")?;
    let phit = get_banded(r, "phit")?;
    let t_lu = get_lu(r, "t_lu")?;
    let phi_lu = get_lu(r, "phi_lu")?;
    let phit_lu = get_lu(r, "phit_lu")?;
    let a_lu = get_lu(r, "a_lu")?;
    let sigma2_y = r.get_f64("sigma2_y")?;
    let policy = get_policy(r)?;
    let factor_patches = r.get_u64("factor_patches")?;
    let factor_resweeps = r.get_u64("factor_resweeps")?;
    let monotone = r.get_bool("monotone")?;
    Ok(DimFactor::from_parts(
        kp,
        t,
        phit,
        t_lu,
        phi_lu,
        phit_lu,
        a_lu,
        sigma2_y,
        policy,
        factor_patches,
        factor_resweeps,
        monotone,
    ))
}

fn put_blocks(w: &mut ByteWriter, blocks: &BlockVec) {
    w.put_usize(blocks.len());
    for b in blocks {
        w.put_f64s(b);
    }
}

fn get_blocks(r: &mut ByteReader<'_>, what: &str) -> Result<BlockVec, String> {
    let d = r.get_usize(what)?;
    if d > r.remaining() / 8 {
        return Err(format!("{what}: claimed {d} blocks exceed remaining bytes"));
    }
    let mut out = Vec::with_capacity(d);
    for _ in 0..d {
        out.push(r.get_f64s(what)?);
    }
    Ok(out)
}

fn put_fit_state(w: &mut ByteWriter, s: &FitState) {
    w.put_usize(s.dims().len());
    for d in s.dims() {
        put_dim(w, d);
    }
    match s.posterior() {
        Some(p) => {
            w.put_bool(true);
            put_blocks(w, &p.b);
            w.put_usize(p.gs_stats.sweeps);
            w.put_f64(p.gs_stats.rel_residual);
        }
        None => w.put_bool(false),
    }
    match s.tilde() {
        Some(t) => {
            w.put_bool(true);
            put_blocks(w, t);
        }
        None => w.put_bool(false),
    }
    w.put_f64(s.sigma2_y);
    w.put_usize(s.gs_max_sweeps);
    w.put_f64(s.gs_tol);
    put_policy(w, s.patch_policy());
    w.put_u64(s.incremental_inserts);
    w.put_u64(s.incremental_removes);
    w.put_u64(s.fallback_rebuilds);
    w.put_u64(s.storage_stats().2); // snapshot_chunks_shared
}

fn get_fit_state(r: &mut ByteReader<'_>) -> Result<FitState, String> {
    let dd = r.get_usize("dims")?;
    if dd == 0 || dd > 1 << 20 {
        return Err(format!("implausible dimension count {dd}"));
    }
    let mut dims = Vec::with_capacity(dd);
    for _ in 0..dd {
        dims.push(get_dim(r)?);
    }
    let post = if r.get_bool("post present")? {
        let b = get_blocks(r, "posterior b")?;
        let sweeps = r.get_usize("gs sweeps")?;
        let rel_residual = r.get_f64("gs rel_residual")?;
        Some(Posterior { b, gs_stats: GsStats { sweeps, rel_residual } })
    } else {
        None
    };
    let tilde = if r.get_bool("tilde present")? {
        Some(get_blocks(r, "tilde")?)
    } else {
        None
    };
    let sigma2_y = r.get_f64("sigma2_y")?;
    let gs_max_sweeps = r.get_usize("gs_max_sweeps")?;
    let gs_tol = r.get_f64("gs_tol")?;
    let policy = get_policy(r)?;
    let ii = r.get_u64("incremental_inserts")?;
    let ir = r.get_u64("incremental_removes")?;
    let fr = r.get_u64("fallback_rebuilds")?;
    let scs = r.get_u64("snapshot_chunks_shared")?;
    Ok(FitState::from_parts(
        dims,
        post,
        tilde,
        sigma2_y,
        gs_max_sweeps,
        gs_tol,
        policy,
        (ii, ir, fr, scs),
    ))
}

/// Magic prefix of a snapshot artifact (`b"AGSN"`, little-endian).
pub const SNAPSHOT_MAGIC: u32 = u32::from_le_bytes(*b"AGSN");

/// Format version of the snapshot artifact. Bump on layout changes; a
/// replica refuses artifacts it does not speak instead of mis-decoding.
pub const SNAPSHOT_VERSION: u8 = 1;

fn put_snapshot_payload(w: &mut ByteWriter, snap: &PosteriorSnapshot) {
    let dims = snap.dims();
    w.put_usize(dims.len());
    for d in dims {
        put_dim(w, d);
    }
    let p = snap.posterior();
    put_blocks(w, &p.b);
    w.put_usize(p.gs_stats.sweeps);
    w.put_f64(p.gs_stats.rel_residual);
    w.put_f64(snap.sigma2_y());
    w.put_usize(snap.cache_capacity());
}

/// Serialize a [`PosteriorSnapshot`] into a self-verifying, generation-
/// numbered artifact — the unit the writer ships to read replicas
/// (DESIGN.md §Replication).
///
/// Layout (all little-endian):
///
/// ```text
/// magic u32 ("AGSN") | format version u8 | generation u64
/// | crc32(payload) u32 | payload length u64 | payload
/// ```
///
/// The payload reuses the checkpoint encoders ([`put_dim`]-level framing):
/// per-dimension factors + LUs, the posterior `b` blocks with solve stats,
/// the noise variance and the cache capacity. Like checkpoints, the lazy
/// band-of-inverse is *not* serialized — [`decode_snapshot`] rebuilds it —
/// and the `M̃` cache starts cold on the importer.
pub fn encode_snapshot(snap: &PosteriorSnapshot, generation: u64) -> Vec<u8> {
    let mut inner = ByteWriter::new();
    put_snapshot_payload(&mut inner, snap);
    let payload = inner.into_bytes();
    let mut w = ByteWriter::new();
    w.put_u32(SNAPSHOT_MAGIC);
    w.put_u8(SNAPSHOT_VERSION);
    w.put_u64(generation);
    w.put_u32(crc32(&payload));
    w.put_bytes(&payload);
    let mut bytes = w.into_bytes();
    if let Some(action) = fault::point!("snapshot.encode") {
        match action {
            fault::FaultAction::TornWrite(keep) => bytes.truncate(keep.min(bytes.len())),
            fault::FaultAction::Panic => panic!("injected fault: snapshot.encode"),
            // IoError/ForceFail have no meaning for an in-memory encode.
            _ => {}
        }
    }
    bytes
}

/// The generation stamped on an artifact, without decoding the payload —
/// what a replica checks before spending the full import.
pub fn snapshot_generation(bytes: &[u8]) -> Result<u64, String> {
    let mut r = ByteReader::new(bytes);
    let magic = r.get_u32("snapshot magic")?;
    if magic != SNAPSHOT_MAGIC {
        return Err(format!("bad snapshot magic {magic:#010x}"));
    }
    let ver = r.get_u8("snapshot version")?;
    if ver != SNAPSHOT_VERSION {
        return Err(format!("unsupported snapshot format v{ver} (this build speaks v{SNAPSHOT_VERSION})"));
    }
    r.get_u64("snapshot generation")
}

/// Decode and verify an [`encode_snapshot`] artifact into a servable
/// snapshot. Returns `(generation, snapshot)`.
///
/// Every failure mode surfaces as `Err`, never a panic or a silently wrong
/// posterior: bad magic / version, truncation anywhere, CRC mismatch on the
/// payload, and structural inconsistency. The imported snapshot has its
/// band-of-inverse materialized and has passed the full structural
/// [`Audit`] before this returns — the guarantee that a replica serving it
/// can never produce a mixed-generation posterior.
pub fn decode_snapshot(bytes: &[u8]) -> Result<(u64, PosteriorSnapshot), String> {
    let mut r = ByteReader::new(bytes);
    let magic = r.get_u32("snapshot magic")?;
    if magic != SNAPSHOT_MAGIC {
        return Err(format!("bad snapshot magic {magic:#010x}"));
    }
    let ver = r.get_u8("snapshot version")?;
    if ver != SNAPSHOT_VERSION {
        return Err(format!("unsupported snapshot format v{ver} (this build speaks v{SNAPSHOT_VERSION})"));
    }
    let generation = r.get_u64("snapshot generation")?;
    let crc = r.get_u32("snapshot crc")?;
    let payload = r.get_bytes("snapshot payload")?;
    if !r.is_done() {
        return Err("trailing bytes after snapshot payload".to_string());
    }
    let actual = crc32(payload);
    if actual != crc {
        return Err(format!("snapshot crc mismatch: stored {crc:#010x}, computed {actual:#010x}"));
    }
    let mut pr = ByteReader::new(payload);
    let dd = pr.get_usize("snapshot dims")?;
    if dd == 0 || dd > 1 << 20 {
        return Err(format!("implausible snapshot dimension count {dd}"));
    }
    let mut dims = Vec::with_capacity(dd);
    for _ in 0..dd {
        dims.push(get_dim(&mut pr)?);
    }
    // The band-of-inverse is a pure function of the factors and is not
    // shipped; materialize it here so the replica's predict path (pure
    // `&`-access) never panics.
    for d in dims.iter_mut() {
        let _ = d.c_band();
    }
    let b = get_blocks(&mut pr, "snapshot posterior")?;
    let sweeps = pr.get_usize("snapshot gs sweeps")?;
    let rel_residual = pr.get_f64("snapshot gs rel_residual")?;
    let sigma2_y = pr.get_f64("snapshot sigma2_y")?;
    let cache_capacity = pr.get_usize("snapshot cache_capacity")?;
    if !pr.is_done() {
        return Err("trailing bytes inside snapshot payload".to_string());
    }
    let snap = PosteriorSnapshot::from_parts(
        dims,
        Posterior { b, gs_stats: GsStats { sweeps, rel_residual } },
        sigma2_y,
        cache_capacity,
    );
    snap.audit().map_err(|e| format!("imported snapshot failed audit: {e}"))?;
    Ok((generation, snap))
}

/// Serialize the mutable contents of a model — data, scales, trained state
/// and escalation counters. The config is *not* included: the journal's
/// own config record (the engine's `EngineConfig`) reconstructs it, so a
/// checkpoint can never disagree with the model's declared shape.
pub fn encode_gp(gp: &AdditiveGP, w: &mut ByteWriter) {
    let (x_cols, y) = gp.data();
    w.put_f64s(&gp.omegas);
    w.put_usize(x_cols.len());
    for c in x_cols {
        w.put_f64s(c);
    }
    w.put_f64s(y);
    w.put_u64(gp.solve_cold_retries);
    w.put_u64(gp.solve_refit_escalations);
    match gp.fit_state() {
        Some(s) => {
            w.put_bool(true);
            put_fit_state(w, s);
        }
        None => w.put_bool(false),
    }
}

/// Rebuild a model from [`encode_gp`] bytes onto a freshly-configured
/// façade. Errors (never panics) on truncated or structurally inconsistent
/// payloads, so a corrupt checkpoint surfaces as a recovery error.
pub fn decode_gp(
    r: &mut ByteReader<'_>,
    cfg: AdditiveGpConfig,
    d: usize,
) -> Result<AdditiveGP, String> {
    let omegas = r.get_f64s("omegas")?;
    let dd = r.get_usize("x_cols")?;
    if dd != d {
        return Err(format!("checkpoint carries {dd} data columns, model declares {d}"));
    }
    let mut x_cols = Vec::with_capacity(dd);
    for _ in 0..dd {
        x_cols.push(r.get_f64s("x_col")?);
    }
    let y = r.get_f64s("y")?;
    let cold = r.get_u64("solve_cold_retries")?;
    let refits = r.get_u64("solve_refit_escalations")?;
    let state = if r.get_bool("state present")? {
        Some(get_fit_state(r)?)
    } else {
        None
    };
    let mut gp = AdditiveGP::new(cfg, d);
    gp.restore_parts(omegas, x_cols, y, state, (cold, refits))?;
    Ok(gp)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn toy(n: usize, d: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let x: Vec<Vec<f64>> =
            (0..n).map(|_| (0..d).map(|_| rng.uniform_in(0.0, 5.0)).collect()).collect();
        let y: Vec<f64> =
            x.iter().map(|r| r.iter().map(|v| (1.1 * v).sin()).sum::<f64>()).collect();
        (x, y)
    }

    fn roundtrip(gp: &AdditiveGP, d: usize) -> AdditiveGP {
        let mut w = ByteWriter::new();
        encode_gp(gp, &mut w);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        let back = decode_gp(&mut r, gp.cfg, d).expect("decode");
        assert!(r.is_done(), "decoder consumed every byte");
        back
    }

    /// encode → decode → encode is the identity on the bytes — the exact
    /// property the recovery bit-identity argument needs.
    #[test]
    fn encode_decode_encode_is_identity() {
        let (x, y) = toy(50, 2, 3);
        let mut gp = AdditiveGP::new(AdditiveGpConfig::default(), 2);
        gp.fit(&x[..40], &y[..40]);
        // Leave a carried ṽ *and* a live posterior in place.
        gp.predict(&[1.0, 2.0], false);
        for i in 40..50 {
            gp.observe(&x[i], y[i]);
        }
        gp.predict(&[2.0, 1.0], false);
        let mut w = ByteWriter::new();
        encode_gp(&gp, &mut w);
        let first = w.into_bytes();
        let back = roundtrip(&gp, 2);
        let mut w2 = ByteWriter::new();
        encode_gp(&back, &mut w2);
        assert_eq!(first, w2.into_bytes(), "re-encode must be byte-identical");
    }

    /// A decoded model predicts bit-identically to the original, and its
    /// *next* mutation + solve follows the same trajectory.
    #[test]
    fn decoded_model_is_bitwise_equivalent_forward() {
        let (x, y) = toy(60, 3, 7);
        let mut gp = AdditiveGP::new(AdditiveGpConfig::default(), 3);
        gp.fit(&x[..52], &y[..52]);
        for i in 52..58 {
            gp.observe(&x[i], y[i]);
        }
        let mut back = roundtrip(&gp, 3);
        // Same next mutations on both sides...
        for i in 58..60 {
            gp.observe(&x[i], y[i]);
            back.observe(&x[i], y[i]);
        }
        // ...must land on bit-identical posteriors and predictions.
        for q in [[1.0, 2.0, 3.0], [4.0, 0.5, 2.5]] {
            let a = gp.predict(&q, true);
            let b = back.predict(&q, true);
            assert_eq!(a.mean.to_bits(), b.mean.to_bits(), "mean at {q:?}");
            assert_eq!(a.var.to_bits(), b.var.to_bits(), "var at {q:?}");
            for dd in 0..3 {
                assert_eq!(a.mean_grad[dd].to_bits(), b.mean_grad[dd].to_bits());
            }
        }
        assert!(back.run_audit().1.is_ok());
    }

    /// An inactive (pre-`min_points`) model round-trips too: raw data only.
    #[test]
    fn inactive_model_roundtrips() {
        let (x, y) = toy(3, 2, 11);
        let mut gp = AdditiveGP::new(AdditiveGpConfig::default(), 2);
        for i in 0..3 {
            gp.observe(&x[i], y[i]);
        }
        let back = roundtrip(&gp, 2);
        assert_eq!(back.n(), 3);
        assert!(back.fit_state().is_none());
        assert_eq!(back.data().1, gp.data().1);
    }

    /// An exported-then-imported snapshot serves bit-identical predictions
    /// and passes the structural audit (the replica's coherence guard).
    #[test]
    fn snapshot_artifact_roundtrips_bitwise() {
        let (x, y) = toy(55, 2, 13);
        let mut gp = AdditiveGP::new(AdditiveGpConfig::default(), 2);
        gp.fit(&x[..48], &y[..48]);
        for i in 48..55 {
            gp.observe(&x[i], y[i]);
        }
        let snap = gp.read_snapshot().expect("active model");
        let bytes = encode_snapshot(&snap, 7);
        assert_eq!(snapshot_generation(&bytes), Ok(7));
        let (generation, back) = decode_snapshot(&bytes).expect("decode");
        assert_eq!(generation, 7);
        assert_eq!(back.n(), snap.n());
        assert_eq!(back.input_dim(), 2);
        for q in [[0.5, 3.5], [2.0, 2.0], [4.5, 1.0]] {
            let a = snap.predict(&q, true);
            let b = back.predict(&q, true);
            assert_eq!(a.mean.to_bits(), b.mean.to_bits(), "mean at {q:?}");
            assert_eq!(a.var.to_bits(), b.var.to_bits(), "var at {q:?}");
            for d in 0..2 {
                assert_eq!(a.mean_grad[d].to_bits(), b.mean_grad[d].to_bits());
                assert_eq!(a.var_grad[d].to_bits(), b.var_grad[d].to_bits());
            }
        }
        // And re-encoding the import reproduces the artifact bytes.
        assert_eq!(bytes, encode_snapshot(&back, 7), "re-encode must be byte-identical");
    }

    /// Torn, bit-flipped and mislabeled artifacts all fail loudly — no
    /// panic, no silently-wrong posterior on the replica.
    #[test]
    fn corrupt_snapshot_artifacts_error_cleanly() {
        let (x, y) = toy(45, 2, 17);
        let mut gp = AdditiveGP::new(AdditiveGpConfig::default(), 2);
        gp.fit(&x, &y);
        let snap = gp.read_snapshot().expect("active model");
        let bytes = encode_snapshot(&snap, 3);
        // Torn tails at every stride: decode errors, never panics.
        for cut in (0..bytes.len()).step_by(131) {
            assert!(decode_snapshot(&bytes[..cut]).is_err(), "cut at {cut}");
        }
        // A single bit flip anywhere in the payload trips the CRC.
        let mut flipped = bytes.clone();
        let pos = bytes.len() - 9;
        flipped[pos] ^= 0x10;
        assert!(decode_snapshot(&flipped).unwrap_err().contains("crc mismatch"));
        // Wrong magic and unknown format version are refused up front.
        let mut bad_magic = bytes.clone();
        bad_magic[0] ^= 0xFF;
        assert!(decode_snapshot(&bad_magic).unwrap_err().contains("magic"));
        let mut bad_ver = bytes.clone();
        bad_ver[4] = SNAPSHOT_VERSION + 1;
        assert!(snapshot_generation(&bad_ver).unwrap_err().contains("unsupported"));
        assert!(decode_snapshot(&bad_ver).unwrap_err().contains("unsupported"));
    }

    /// Corrupt payloads error with a diagnostic instead of panicking.
    #[test]
    fn corrupt_payloads_error_cleanly() {
        let (x, y) = toy(45, 2, 5);
        let mut gp = AdditiveGP::new(AdditiveGpConfig::default(), 2);
        gp.fit(&x, &y);
        gp.predict(&[1.0, 1.0], false);
        let mut w = ByteWriter::new();
        encode_gp(&gp, &mut w);
        let bytes = w.into_bytes();
        // Every truncation point must fail cleanly (or succeed only at the
        // full length).
        for cut in (0..bytes.len()).step_by(97) {
            let mut r = ByteReader::new(&bytes[..cut]);
            assert!(decode_gp(&mut r, gp.cfg, 2).is_err(), "cut at {cut}");
        }
        // Wrong dimension count is rejected up front.
        let mut r = ByteReader::new(&bytes);
        assert!(decode_gp(&mut r, gp.cfg, 3).unwrap_err().contains("columns"));
    }
}
