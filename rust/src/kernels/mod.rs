//! Matérn kernels (half-integer smoothness) and their sparse Kernel-Packet
//! factorizations — paper §4, Algorithms 2 and 3.

pub mod gkp;
pub mod kp;
pub mod matern;

pub use gkp::GkpFactorization;
pub use kp::KpFactorization;
pub use matern::Matern;
