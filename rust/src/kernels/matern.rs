//! One-dimensional Matérn kernels with half-integer smoothness, in the
//! paper's eq. (37) parameterization:
//!
//! ```text
//! k(x, x' | ω) = σ² · exp(-ω r) · P_q(ω r),   r = |x - x'|,  q = ν - 1/2
//! P_0(t) = 1                      (ν = 1/2, exponential / OU kernel)
//! P_1(t) = 1 + t                  (ν = 3/2)
//! P_2(t) = 1 + t + t²/3           (ν = 5/2)
//! ```
//!
//! `ω` is the *rate* hyperparameter (the paper's scale; the experiments use
//! `k = exp(-θ|x-x'|)`). Closed forms for `∂k/∂ω` and `∂k/∂x` are provided —
//! both are needed for eq. (15) (likelihood gradient via generalized KPs) and
//! eq. (29)–(30) (acquisition gradients).

/// Half-integer Matérn smoothness ν ∈ {1/2, 3/2, 5/2}.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Nu {
    Half,
    ThreeHalves,
    FiveHalves,
}

impl Nu {
    /// `2ν` as an integer.
    pub fn two_nu(self) -> usize {
        match self {
            Nu::Half => 1,
            Nu::ThreeHalves => 3,
            Nu::FiveHalves => 5,
        }
    }

    /// Polynomial order `q = ν − 1/2`.
    pub fn q(self) -> usize {
        match self {
            Nu::Half => 0,
            Nu::ThreeHalves => 1,
            Nu::FiveHalves => 2,
        }
    }

    /// Half-bandwidth of the KP coefficient matrix `A`: `ν + 1/2`.
    pub fn band_a(self) -> usize {
        self.q() + 1
    }

    /// Half-bandwidth of the Gram matrix `Φ`: `ν − 1/2`.
    pub fn band_phi(self) -> usize {
        self.q()
    }

    /// Number of points in a central KP: `2ν + 2`.
    pub fn kp_points(self) -> usize {
        self.two_nu() + 2
    }

    /// Window width of nonzero `φ(x*)` entries: `2ν + 1`.
    pub fn window(self) -> usize {
        self.two_nu() + 1
    }

    pub fn from_two_nu(two_nu: usize) -> Option<Nu> {
        match two_nu {
            1 => Some(Nu::Half),
            3 => Some(Nu::ThreeHalves),
            5 => Some(Nu::FiveHalves),
            _ => None,
        }
    }
}

/// A one-dimensional Matérn kernel `σ² e^{-ωr} P_q(ωr)`.
#[derive(Clone, Copy, Debug)]
pub struct Matern {
    pub nu: Nu,
    /// Rate (inverse length-scale) ω > 0.
    pub omega: f64,
    /// Signal variance σ².
    pub sigma2: f64,
}

impl Matern {
    pub fn new(nu: Nu, omega: f64) -> Self {
        Matern { nu, omega, sigma2: 1.0 }
    }

    pub fn with_sigma2(nu: Nu, omega: f64, sigma2: f64) -> Self {
        Matern { nu, omega, sigma2 }
    }

    /// Kernel value `k(x, y)`.
    #[inline]
    pub fn k(&self, x: f64, y: f64) -> f64 {
        let t = self.omega * (x - y).abs();
        let p = match self.nu {
            Nu::Half => 1.0,
            Nu::ThreeHalves => 1.0 + t,
            Nu::FiveHalves => 1.0 + t + t * t / 3.0,
        };
        self.sigma2 * (-t).exp() * p
    }

    /// `∂k/∂ω` at `(x, y)`:  `σ² r e^{-t} (P'_q - P_q)(t)`, `t = ωr`.
    #[inline]
    pub fn dk_domega(&self, x: f64, y: f64) -> f64 {
        let r = (x - y).abs();
        let t = self.omega * r;
        let f = match self.nu {
            // P' − P:  ν=1/2: −1 ; ν=3/2: −t ; ν=5/2: −t(1+t)/3
            Nu::Half => -1.0,
            Nu::ThreeHalves => -t,
            Nu::FiveHalves => -t * (1.0 + t) / 3.0,
        };
        self.sigma2 * r * (-t).exp() * f
    }

    /// `∂k(y, x)/∂x` — derivative in the *second* argument (the prediction
    /// point). For ν = 1/2 this is the a.e. derivative (kink at `x = y`).
    #[inline]
    pub fn dk_dx(&self, y: f64, x: f64) -> f64 {
        let d = x - y;
        let t = self.omega * d.abs();
        let e = (-t).exp();
        self.sigma2
            * match self.nu {
                Nu::Half => -self.omega * d.signum() * e,
                Nu::ThreeHalves => -self.omega * self.omega * d * e,
                Nu::FiveHalves => -self.omega * self.omega * d * e * (1.0 + t) / 3.0,
            }
    }

    /// `∂²k(y, x)/∂x∂ω` — needed for the gradient of `∂φ/∂x` windows when
    /// hyperparameters move; exposed for completeness of the sparse calculus.
    #[inline]
    pub fn d2k_dx_domega(&self, y: f64, x: f64) -> f64 {
        let d = x - y;
        let r = d.abs();
        let t = self.omega * r;
        let e = (-t).exp();
        self.sigma2
            * match self.nu {
                // d/dω [−ω sgn e^{-ωr}] = sgn e^{-t} (ωr − 1)
                Nu::Half => d.signum() * e * (t - 1.0),
                // d/dω [−ω² d e^{-ωr}] = d e^{-t} ω (ωr − 2)
                Nu::ThreeHalves => d * e * self.omega * (t - 2.0),
                // d/dω [−ω² d e^{-t}(1+t)/3]
                //  = −d/3 · e^{-t} (2ω(1+t) + ω²r − ωr·ω(1+t))... expanded below
                Nu::FiveHalves => {
                    -d / 3.0 * e * (2.0 * self.omega * (1.0 + t) + self.omega * self.omega * r
                        - self.omega * r * self.omega * (1.0 + t))
                }
            }
    }

    /// Covariance matrix `k(X, X)` (dense; tests/baselines only).
    pub fn gram(&self, xs: &[f64]) -> crate::linalg::Dense {
        let n = xs.len();
        let mut g = crate::linalg::Dense::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                g.set(i, j, self.k(xs[i], xs[j]));
            }
        }
        g
    }

    /// Dense `∂K/∂ω` (tests only).
    pub fn gram_domega(&self, xs: &[f64]) -> crate::linalg::Dense {
        let n = xs.len();
        let mut g = crate::linalg::Dense::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                g.set(i, j, self.dk_domega(xs[i], xs[j]));
            }
        }
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_domega_fd(nu: Nu) {
        let omega = 0.8;
        let h = 1e-6;
        for &(x, y) in &[(0.3, 1.7), (2.0, 2.0), (-1.0, 4.0)] {
            let kp = Matern::new(nu, omega + h).k(x, y);
            let km = Matern::new(nu, omega - h).k(x, y);
            let fd = (kp - km) / (2.0 * h);
            let an = Matern::new(nu, omega).dk_domega(x, y);
            assert!((fd - an).abs() < 1e-6, "{nu:?} ({x},{y}): fd={fd} an={an}");
        }
    }

    #[test]
    fn domega_matches_finite_difference() {
        check_domega_fd(Nu::Half);
        check_domega_fd(Nu::ThreeHalves);
        check_domega_fd(Nu::FiveHalves);
    }

    fn check_dx_fd(nu: Nu) {
        let k = Matern::new(nu, 1.3);
        let h = 1e-6;
        for &(y, x) in &[(0.3, 1.7), (2.0, -0.5), (-1.0, 4.0)] {
            let fd = (k.k(y, x + h) - k.k(y, x - h)) / (2.0 * h);
            let an = k.dk_dx(y, x);
            assert!((fd - an).abs() < 1e-5, "{nu:?} ({y},{x}): fd={fd} an={an}");
        }
    }

    #[test]
    fn dx_matches_finite_difference() {
        check_dx_fd(Nu::Half);
        check_dx_fd(Nu::ThreeHalves);
        check_dx_fd(Nu::FiveHalves);
    }

    #[test]
    fn d2_dx_domega_matches_fd() {
        for nu in [Nu::Half, Nu::ThreeHalves, Nu::FiveHalves] {
            let omega = 0.9;
            let h = 1e-6;
            for &(y, x) in &[(0.3, 1.7), (-2.0, 0.4)] {
                let fp = Matern::new(nu, omega + h).dk_dx(y, x);
                let fm = Matern::new(nu, omega - h).dk_dx(y, x);
                let fd = (fp - fm) / (2.0 * h);
                let an = Matern::new(nu, omega).d2k_dx_domega(y, x);
                assert!((fd - an).abs() < 1e-5, "{nu:?}: fd={fd} an={an}");
            }
        }
    }

    #[test]
    fn kernel_basic_properties() {
        for nu in [Nu::Half, Nu::ThreeHalves, Nu::FiveHalves] {
            let k = Matern::new(nu, 2.0);
            assert!((k.k(1.0, 1.0) - 1.0).abs() < 1e-15); // k(x,x) = σ²
            assert_eq!(k.k(0.0, 3.0), k.k(3.0, 0.0)); // symmetry
            assert!(k.k(0.0, 1.0) > k.k(0.0, 2.0)); // decay
            assert!(k.k(0.0, 100.0) < 1e-10);
        }
    }

    #[test]
    fn gram_is_spd() {
        let xs = [0.1, 0.5, 0.9, 1.4, 2.0];
        for nu in [Nu::Half, Nu::ThreeHalves, Nu::FiveHalves] {
            let g = Matern::new(nu, 1.0).gram(&xs);
            assert!(g.cholesky().is_some(), "{nu:?} gram not SPD");
        }
    }
}
