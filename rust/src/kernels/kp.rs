//! Kernel Packets — paper §4.1, Theorem 3 and **Algorithm 2**.
//!
//! For a Matérn-ν kernel with half-integer ν and sorted points
//! `x_1 < … < x_n`, there exist banded matrices `A` (half-bandwidth
//! `w = ν+1/2`) and `Φ` (half-bandwidth `w−1`) such that
//!
//! ```text
//! P^T K P = A^{-1} Φ        (paper eq. 8)
//! ```
//!
//! Row `i` of `A` holds the coefficients of the *i-th kernel packet*
//! `φ_i(·) = Σ_s A[i,s] k(·, x_s)`, which is non-zero only on
//! `(x_{i−w}, x_{i+w})` (central), `(−∞, x_{i+w})` (left boundary) or
//! `(x_{i−w}, ∞)` (right boundary); `Φ[i,j] = φ_i(x_j)` is its Gram matrix.
//!
//! The coefficients span the 1-dimensional nullspace of tiny "exponential
//! moment" systems (paper eqs. 9–10). For numerical robustness the window is
//! centered (`t_i = ω(x_i − c)` — the nullspace is invariant under this
//! affine change) and the central system is expressed in the equivalent
//! `cosh/sinh` row basis, which is far better conditioned when `ω·spacing`
//! is small.

use crate::check::{enforce, Audit, AuditError};
use crate::kernels::matern::Matern;
use crate::linalg::perm::lower_index;
use crate::linalg::{Banded, Dense, Permutation};

/// Which kind of packet (paper Theorem 3 cases).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Side {
    /// `p = 2q+3` points, support `(x_1, x_p)`.
    Central,
    /// Boundary packet with support `(−∞, x_p)` (paper's `h = +1`).
    Left,
    /// Boundary packet with support `(x_1, ∞)` (paper's `h = −1`).
    Right,
}

/// Solve the exponential-moment system for one packet.
///
/// `ts` are the *pre-scaled, centered* points `t_i = ω(x_i − c)`, sorted
/// increasing; `q` is the polynomial order (`ν−1/2` for KPs of Matérn-ν,
/// `ν+1/2` for generalized KPs). Returns the `‖·‖∞ = 1` nullspace vector.
///
/// System shapes (all `(p−1) × p`, nullspace dimension 1):
/// * Central: `p = 2q+3`, rows `t^l cosh(t)` and `t^l sinh(t)`, `l = 0..=q`
///   (equivalent to paper eq. 9's `e^{±t}` rows).
/// * Left (`h=+1`): rows `t^l e^{+t}`, `l = 0..=q`, plus auxiliary rows
///   `t^r e^{−t}`, `r = 0..=p−q−3` (paper eq. 10) — valid for
///   `q+2 ≤ p ≤ 2q+2`.
/// * Right (`h=−1`): mirror of Left.
pub fn packet_coeffs(ts: &[f64], side: Side, q: usize) -> Vec<f64> {
    let p = ts.len();
    let mut rows: Vec<Vec<f64>> = Vec::with_capacity(p - 1);
    match side {
        Side::Central => {
            assert_eq!(p, 2 * q + 3, "central packet needs 2q+3 points");
            for l in 0..=q {
                let mut rc = Vec::with_capacity(p);
                let mut rs = Vec::with_capacity(p);
                for &t in ts {
                    let tl = t.powi(l as i32);
                    rc.push(tl * t.cosh());
                    rs.push(tl * t.sinh());
                }
                rows.push(rc);
                // The last sinh row is dropped to keep p−1 rows; with
                // l=0..=q that is 2(q+1) = p−1 rows exactly — keep both.
                rows.push(rs);
            }
            // 2(q+1) = 2q+2 = p−1 rows. ✓
        }
        Side::Left | Side::Right => {
            assert!(
                (q + 2..=2 * q + 2).contains(&p),
                "one-sided packet needs q+2..=2q+2 points, got {p} (q={q})"
            );
            let h = if side == Side::Left { 1.0 } else { -1.0 };
            for l in 0..=q {
                rows.push(ts.iter().map(|&t| t.powi(l as i32) * (h * t).exp()).collect());
            }
            if p >= q + 3 {
                for r in 0..=(p - q - 3) {
                    rows.push(
                        ts.iter().map(|&t| t.powi(r as i32) * (-h * t).exp()).collect(),
                    );
                }
            }
        }
    }
    debug_assert_eq!(rows.len(), p - 1);
    Dense::from_rows(&rows).nullspace_vector()
}

/// The KP factorization `P^T K P = A^{-1} Φ` of one dimension's covariance
/// matrix (paper **Algorithm 2**), plus the `O(log n)` sparse-window
/// evaluations of `φ(x*)` and `∂φ(x*)/∂x*` used throughout §5.2 and §6.
#[derive(Clone, Debug)]
pub struct KpFactorization {
    pub kernel: Matern,
    /// Sorting permutation of the original points.
    pub perm: Permutation,
    /// Sorted points.
    pub xs: Vec<f64>,
    /// Packet-coefficient matrix, half-bandwidth `w = ν+1/2`.
    pub a: Banded,
    /// Packet Gram matrix `Φ[i,j] = φ_i(x_j)`, half-bandwidth `w−1`.
    pub phi: Banded,
}

impl KpFactorization {
    /// Factorize `k(X, X)` for scattered (unsorted) `points`.
    ///
    /// Requires `points.len() ≥ 2ν+2` (paper's `Ensure`) and strictly
    /// distinct sorted points.
    pub fn new(points: &[f64], kernel: Matern) -> Self {
        let q = kernel.nu.q();
        let w = q + 1; // ν + 1/2
        let n = points.len();
        assert!(n >= 2 * w + 1, "need n ≥ 2ν+2 = {} points, got {n}", 2 * w + 1);
        let perm = Permutation::sorting(points);
        let mut xs = perm.apply_sort(points);
        // The factorization needs strictly increasing points. Coincident
        // coordinates (common in BO once the box boundary is hit) are nudged
        // apart by a deterministic ~1e-10·span offset — far below any
        // kernel length scale of interest and equivalent to an infinitesimal
        // design perturbation.
        let span = (xs[n - 1] - xs[0]).abs().max(1e-9);
        let gap = 1e-10 * span;
        for i in 1..n {
            if xs[i] <= xs[i - 1] {
                xs[i] = xs[i - 1] + gap;
            }
        }
        let a = build_packet_matrix(&xs, kernel.omega, q);
        let phi = build_gram(&a, &xs, &kernel, w - 1);
        KpFactorization { kernel, perm, xs, a, phi }
    }

    pub fn n(&self) -> usize {
        self.xs.len()
    }

    /// Packet half-bandwidth `w = ν+1/2`.
    pub fn w(&self) -> usize {
        self.kernel.nu.q() + 1
    }

    /// Sparse evaluation of `φ(x*) = A k(X, x*)`: returns `(start, vals)`
    /// where `vals[r] = φ_{start+r}(x*)` and all other entries are zero.
    /// `O(log n)` search + `O(w²)` arithmetic; at most `2w = 2ν+1` entries.
    pub fn phi_window(&self, x: f64) -> (usize, Vec<f64>) {
        self.window_impl(x, |s, xstar| self.kernel.k(s, xstar))
    }

    /// Sparse evaluation of `∂φ(x*)/∂x*` (same support as `φ`).
    pub fn dphi_window(&self, x: f64) -> (usize, Vec<f64>) {
        self.window_impl(x, |s, xstar| self.kernel.dk_dx(s, xstar))
    }

    fn window_impl(&self, x: f64, kfun: impl Fn(f64, f64) -> f64) -> (usize, Vec<f64>) {
        let n = self.n();
        let w = self.w();
        // j = index with xs[j] <= x < xs[j+1]; -1 when x < xs[0].
        let j = lower_index(&self.xs, x).map(|v| v as isize).unwrap_or(-1);
        let start = (j + 1 - w as isize).max(0) as usize;
        let end = ((j + w as isize) as usize).min(n - 1); // inclusive
        let mut vals = Vec::with_capacity(end + 1 - start);
        for i in start..=end {
            let (lo, hi) = self.a.row_range(i);
            let mut acc = 0.0;
            for s in lo..hi {
                acc += self.a.get(i, s) * kfun(self.xs[s], x);
            }
            vals.push(acc);
        }
        (start, vals)
    }

    /// Incrementally insert one new point (appended in *data* order, landing
    /// at the returned *sorted* position): the `O(log n)` structural update
    /// behind `FitState::observe` (see DESIGN.md §FitState).
    ///
    /// Only the packets whose point window contains the insertion position
    /// change — rows `i ∈ [pos−w, pos+w]` — so the update splices one zero
    /// row/col into `A` and `Φ` (a band-storage `memmove`) and re-solves
    /// `O(2ν+1)` small moment systems instead of `n` of them. All other rows
    /// keep bit-identical coefficients, which is what makes the
    /// incremental-vs-refit equivalence exact rather than approximate.
    ///
    /// Returns `None` (caller must rebuild from scratch) when the new point
    /// cannot be separated from its neighbors by the deterministic nudge —
    /// the degenerate duplicate-cluster case where the full-rebuild nudge
    /// cascade is the correct tool.
    pub fn insert(&mut self, x: f64) -> Option<usize> {
        let n = self.n();
        let w = self.w();
        let (pos, xv) = place_point(&self.xs, x)?;
        self.xs.insert(pos, xv);
        self.perm.insert(pos);
        self.a.insert_row_col(pos);
        self.phi.insert_row_col(pos);
        let n = n + 1;
        // Rebuild every packet whose point window changed. This range also
        // covers the rows whose boundary/central type flips when n grows and
        // the rows whose band storage straddles the spliced column.
        let lo = pos.saturating_sub(w);
        let hi = (pos + w).min(n - 1);
        for i in lo..=hi {
            self.rebuild_row(i);
        }
        enforce(self, "KpFactorization::insert");
        Some(pos)
    }

    /// Incrementally insert a whole batch of points (appended in *data*
    /// order), returning each point's final sorted position. The batched
    /// form of [`KpFactorization::insert`]: one strictly-sequential position
    /// / nudge simulation (so the result is bit-identical to `k` single
    /// inserts), then **one** band splice per matrix for all `k` sorted
    /// positions and one packet re-solve pass over the *union* of the
    /// insertion windows — rows covered by several windows are rebuilt once,
    /// not once per point (DESIGN.md §FitState, "Batched inserts").
    ///
    /// Returns `None` — with the factorization untouched — when any point of
    /// the batch cannot be separated from its neighbors by the deterministic
    /// nudge (degenerate duplicate cluster). The caller decides between a
    /// full rebuild and a sequential replay; failing *before* mutating is
    /// what makes that choice safe.
    pub fn insert_batch(&mut self, values: &[f64]) -> Option<Vec<usize>> {
        if values.is_empty() {
            return Some(Vec::new());
        }
        if values.len() == 1 {
            return self.insert(values[0]).map(|p| vec![p]);
        }
        let w = self.w();
        // --- Simulate the sequential inserts (positions + nudges) on a
        // scratch copy so a mid-batch degenerate failure leaves `self`
        // untouched. `place_point` is evaluated against the *growing*
        // array, exactly as repeated `insert` calls would.
        // lint: cow-ok (scratch Vec<f64> of sorted inputs, not band storage)
        let mut scratch = self.xs.clone();
        let mut final_pos: Vec<usize> = Vec::with_capacity(values.len());
        for &x in values {
            let (pos, xv) = place_point(&scratch, x)?;
            scratch.insert(pos, xv);
            for p in final_pos.iter_mut() {
                if *p >= pos {
                    *p += 1;
                }
            }
            final_pos.push(pos);
        }
        // --- Commit: one merge / splice per structure.
        // lint: cow-ok (Vec<usize> of batch positions, not band storage)
        let mut sorted_pos = final_pos.clone();
        sorted_pos.sort_unstable();
        self.xs = scratch;
        self.perm.insert_batch(&final_pos);
        self.a.insert_rows_cols(&sorted_pos);
        self.phi.insert_rows_cols(&sorted_pos);
        let n = self.n();
        // Rebuild the union of windows [q−w, q+w] (final coordinates). The
        // per-insertion coverage argument of `insert` applies unchanged: a
        // row outside every window has no inserted point in its point
        // window, no straddled band splice, and no boundary/central type
        // flip, so its stored coefficients are already the from-scratch
        // values.
        let mut next = 0usize;
        for &q in &sorted_pos {
            let lo = q.saturating_sub(w).max(next);
            let hi = (q + w).min(n - 1);
            if lo > hi {
                continue;
            }
            for i in lo..=hi {
                self.rebuild_row(i);
            }
            next = hi + 1;
        }
        enforce(self, "KpFactorization::insert_batch");
        Some(final_pos)
    }

    /// Incrementally remove the point at sorted position `pos` — the
    /// deletion mirror of [`KpFactorization::insert`], behind
    /// `FitState::forget` (DESIGN.md §FitState, "Downdates").
    ///
    /// Only the packets whose point window contained the removed point
    /// change: in post-removal indices those are rows `i ∈ [pos−w, pos+w−1]`
    /// (a surviving row `i ≥ pos+w` had old index `i+1` and old point window
    /// `[i+1−w, i+1+w]`, which the band deletion shifts onto exactly the new
    /// window `[i−w, i+w]`, so its stored coefficients are already the
    /// from-scratch values; a row `i < pos−w` is untouched outright). The
    /// rebuilt range below also absorbs every boundary/central type flip —
    /// a row `i < pos−w` cannot become a right-boundary row because
    /// `i + w < n_new` there.
    ///
    /// Returns the removed point's *original* (data-order) index; surviving
    /// original indices above it shift down by one. Panics if the removal
    /// would drop `n` below the packet minimum `2w+1` — the caller decides
    /// between refusing and deactivating the model before calling.
    pub fn remove(&mut self, pos: usize) -> usize {
        let n = self.n();
        let w = self.w();
        assert!(pos < n, "remove: sorted position {pos} out of range {n}");
        assert!(
            n - 1 >= 2 * w + 1,
            "remove: n = {} would drop below the packet minimum {}",
            n - 1,
            2 * w + 1
        );
        self.xs.remove(pos);
        let orig = self.perm.remove(pos);
        self.a.remove_row_col(pos);
        self.phi.remove_row_col(pos);
        let n = n - 1;
        let lo = pos.saturating_sub(w);
        let hi = (pos + w).min(n - 1);
        for i in lo..=hi {
            self.rebuild_row(i);
        }
        enforce(self, "KpFactorization::remove");
        orig
    }

    /// Remove a whole batch of points in one pass — the batched form of
    /// [`KpFactorization::remove`]: one band deletion per matrix plus one
    /// packet re-solve over the *union* of the removal windows.
    /// `sorted_positions` are current sorted positions, strictly increasing.
    /// Returns the removed points' *original* indices (pre-compaction, in
    /// the order of `sorted_positions`). Panics if the batch would drop `n`
    /// below the packet minimum `2w+1`.
    pub fn remove_batch(&mut self, sorted_positions: &[usize]) -> Vec<usize> {
        let k = sorted_positions.len();
        if k == 0 {
            return Vec::new();
        }
        if k == 1 {
            return vec![self.remove(sorted_positions[0])];
        }
        let w = self.w();
        assert!(
            self.n() - k >= 2 * w + 1,
            "remove_batch: n = {} would drop below the packet minimum {}",
            self.n() - k,
            2 * w + 1
        );
        for &p in sorted_positions.iter().rev() {
            self.xs.remove(p);
        }
        let origs = self.perm.remove_batch(sorted_positions);
        self.a.remove_rows_cols(sorted_positions);
        self.phi.remove_rows_cols(sorted_positions);
        let n = self.n();
        // Rebuild the union of windows [q′−w, q′+w] where q′ = q − t is the
        // post-removal coordinate the t-th gap closed at (the per-removal
        // coverage argument of `remove` applies unchanged).
        let mut next = 0usize;
        for (t, &q) in sorted_positions.iter().enumerate() {
            let qq = q - t;
            let lo = qq.saturating_sub(w).max(next);
            let hi = (qq + w).min(n - 1);
            if lo > hi {
                continue;
            }
            for i in lo..=hi {
                self.rebuild_row(i);
            }
            next = hi + 1;
        }
        enforce(self, "KpFactorization::remove_batch");
        origs
    }

    /// Recompute packet row `i` of `A` and the matching row of `Φ` from the
    /// current `xs` (used by [`KpFactorization::insert`]).
    fn rebuild_row(&mut self, i: usize) {
        let n = self.n();
        let w = self.w();
        let q = self.kernel.nu.q();
        let omega = self.kernel.omega;
        let scaled = |lo: usize, hi: usize| -> Vec<f64> {
            let c = 0.5 * (self.xs[lo] + self.xs[hi]);
            self.xs[lo..=hi].iter().map(|&p| omega * (p - c)).collect()
        };
        let (alo, ahi) = self.a.row_range(i);
        for s in alo..ahi {
            self.a.set(i, s, 0.0);
        }
        if i < w {
            let coef = packet_coeffs(&scaled(0, i + w), Side::Left, q);
            for (s, &c) in coef.iter().enumerate() {
                self.a.set(i, s, c);
            }
        } else if i >= n - w {
            let lo = i - w;
            let coef = packet_coeffs(&scaled(lo, n - 1), Side::Right, q);
            for (s, &c) in coef.iter().enumerate() {
                self.a.set(i, lo + s, c);
            }
        } else {
            let (lo, hi) = (i - w, i + w);
            let coef = packet_coeffs(&scaled(lo, hi), Side::Central, q);
            for (s, &c) in coef.iter().enumerate() {
                self.a.set(i, lo + s, c);
            }
        }
        // Refresh the Gram row Φ[i, ·] = φ_i(x_·) over its band.
        let (jlo, jhi) = self.phi.row_range(i);
        let (slo, shi) = self.a.row_range(i);
        for j in jlo..jhi {
            let mut acc = 0.0;
            for s in slo..shi {
                acc += self.a.get(i, s) * self.kernel.k(self.xs[s], self.xs[j]);
            }
            self.phi.set(i, j, acc);
        }
    }

    /// Dense `φ(x*)` (tests only).
    pub fn phi_full(&self, x: f64) -> Vec<f64> {
        let kv: Vec<f64> = self.xs.iter().map(|&s| self.kernel.k(s, x)).collect();
        self.a.matvec(&kv)
    }

    /// `log|det Φ|` and `log|det A|` — the banded log-det terms of eq. (14).
    pub fn logdets(&self) -> (f64, f64) {
        (self.phi.lu().logdet().0, self.a.lu().logdet().0)
    }
}

impl Audit for KpFactorization {
    /// The factorization's structural story: sorted points are in
    /// non-decreasing order and finite (failures name the offending sorted
    /// index — equal *adjacent* points are tolerated here because a
    /// degenerate duplicate-cluster rebuild can legitimately produce them;
    /// [`crate::gp::dim::DimFactor`]'s audit upgrades this to strict
    /// inequality whenever its `monotone` flag claims the incremental path
    /// is usable), there are enough of them for the packet construction
    /// (`n ≥ 2w+1`), the permutation is a valid bijection of the same
    /// length, and the `A` / `Φ` band matrices have exactly the Theorem-3
    /// half-bandwidths (`w` and `w−1`) at size `n`. Child audits (`perm`,
    /// `a`, `phi`) propagate their own structure names.
    fn audit(&self) -> Result<(), AuditError> {
        let n = self.xs.len();
        let w = self.w();
        if n < 2 * w + 1 {
            return Err(AuditError::new(
                "KpFactorization",
                "xs",
                None,
                format!("n = {n} below the packet minimum 2w+1 = {}", 2 * w + 1),
            ));
        }
        for (i, &x) in self.xs.iter().enumerate() {
            if !x.is_finite() {
                return Err(AuditError::new(
                    "KpFactorization",
                    "xs",
                    Some(i),
                    format!("non-finite sorted point {x}"),
                ));
            }
            if i > 0 && x < self.xs[i - 1] {
                return Err(AuditError::new(
                    "KpFactorization",
                    "xs",
                    Some(i),
                    format!("sorted order broken: xs[{}] = {} > xs[{i}] = {x}",
                        i - 1, self.xs[i - 1]),
                ));
            }
        }
        self.perm.audit()?;
        if self.perm.len() != n {
            return Err(AuditError::new(
                "KpFactorization",
                "perm",
                None,
                format!("permutation length {} != n = {n}", self.perm.len()),
            ));
        }
        self.a.audit()?;
        if self.a.n() != n || self.a.kl() != w || self.a.ku() != w {
            return Err(AuditError::new(
                "KpFactorization",
                "a",
                None,
                format!(
                    "packet matrix shape (n={}, kl={}, ku={}) != (n={n}, w={w}, w={w})",
                    self.a.n(),
                    self.a.kl(),
                    self.a.ku()
                ),
            ));
        }
        self.phi.audit()?;
        if self.phi.n() != n || self.phi.kl() != w - 1 || self.phi.ku() != w - 1 {
            return Err(AuditError::new(
                "KpFactorization",
                "phi",
                None,
                format!(
                    "Gram matrix shape (n={}, kl={}, ku={}) != (n={n}, w−1={}, w−1={})",
                    self.phi.n(),
                    self.phi.kl(),
                    self.phi.ku(),
                    w - 1,
                    w - 1
                ),
            ));
        }
        Ok(())
    }
}

/// Insertion slot and (possibly nudged) value for placing `x` into the
/// strictly-increasing `xs` — the single nudge rule shared by
/// [`KpFactorization::insert`] and the batch simulation in
/// [`KpFactorization::insert_batch`], mirroring `new()`'s cascade:
/// coincident coordinates move up by ~`1e-10·span`, far below any kernel
/// length scale of interest. `None` when the nudge cannot separate the
/// point (gap below f64 resolution, or overshooting the successor in a
/// duplicate cluster).
fn place_point(xs: &[f64], x: f64) -> Option<(usize, f64)> {
    let n = xs.len();
    let span = (xs[n - 1] - xs[0]).abs().max(1e-9);
    let gap = 1e-10 * span;
    let pos = match lower_index(xs, x) {
        None => 0,
        Some(i) => i + 1,
    };
    let mut xv = x;
    if pos > 0 && xv <= xs[pos - 1] {
        xv = xs[pos - 1] + gap;
    }
    if pos > 0 && xv <= xs[pos - 1] {
        return None; // gap below f64 resolution at this magnitude
    }
    if pos < n && xv >= xs[pos] {
        return None; // nudge overshot the successor (duplicate cluster)
    }
    Some((pos, xv))
}

/// Build the packet-coefficient matrix `A` (rows = packets) for sorted `xs`
/// with polynomial order `q` (half-bandwidth `w = q+1`). Shared by
/// Algorithm 2 (`q = ν−1/2`) and Algorithm 3 (`q = ν+1/2`, same rate ω).
pub fn build_packet_matrix(xs: &[f64], omega: f64, q: usize) -> Banded {
    let n = xs.len();
    let w = q + 1;
    assert!(n >= 2 * w + 1);
    let mut a = Banded::zeros(n, w, w);
    let scaled = |lo: usize, hi: usize| -> Vec<f64> {
        // t_i = ω (x_i − c), centered at the window midpoint.
        let c = 0.5 * (xs[lo] + xs[hi]);
        xs[lo..=hi].iter().map(|&x| omega * (x - c)).collect()
    };
    // Left boundary packets: rows 0..w use points 0..=i+w.
    for i in 0..w {
        let hi = i + w;
        let coef = packet_coeffs(&scaled(0, hi), Side::Left, q);
        for (s, &c) in coef.iter().enumerate() {
            a.set(i, s, c);
        }
    }
    // Central packets.
    for i in w..n - w {
        let (lo, hi) = (i - w, i + w);
        let coef = packet_coeffs(&scaled(lo, hi), Side::Central, q);
        for (s, &c) in coef.iter().enumerate() {
            a.set(i, lo + s, c);
        }
    }
    // Right boundary packets: rows n−w..n use points i−w..n−1.
    for i in n - w..n {
        let lo = i - w;
        let coef = packet_coeffs(&scaled(lo, n - 1), Side::Right, q);
        for (s, &c) in coef.iter().enumerate() {
            a.set(i, lo + s, c);
        }
    }
    a
}

/// Gram matrix `Φ[i,j] = Σ_s A[i,s] k(x_s, x_j)` restricted to the
/// `band`-band (entries outside are exact zeros by the packet property).
fn build_gram(a: &Banded, xs: &[f64], kernel: &Matern, band: usize) -> Banded {
    let n = xs.len();
    let mut phi = Banded::zeros(n, band, band);
    for i in 0..n {
        let (jlo, jhi) = phi.row_range(i);
        let (slo, shi) = a.row_range(i);
        for j in jlo..jhi {
            let mut acc = 0.0;
            for s in slo..shi {
                acc += a.get(i, s) * kernel.k(xs[s], xs[j]);
            }
            phi.set(i, j, acc);
        }
    }
    phi
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::matern::Nu;
    use crate::util::Rng;

    fn random_points(n: usize, lo: f64, hi: f64, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        let mut pts = rng.uniform_vec(n, lo, hi);
        // ensure distinct
        pts.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for i in 1..n {
            if pts[i] - pts[i - 1] < 1e-9 {
                pts[i] = pts[i - 1] + 1e-6;
            }
        }
        // shuffle back to scattered order
        for i in (1..n).rev() {
            let j = rng.below(i + 1);
            pts.swap(i, j);
        }
        pts
    }

    /// `A · K_sorted` must be banded with half-bandwidth `w−1` — the core
    /// compact-support claim of Theorem 3 / Figure 1.
    fn check_banded(nu: Nu, omega: f64, n: usize, seed: u64) {
        let pts = random_points(n, -2.0, 3.0, seed);
        let kernel = Matern::new(nu, omega);
        let f = KpFactorization::new(&pts, kernel);
        let kd = kernel.gram(&f.xs);
        let ad = f.a.to_dense();
        let prod = ad.matmul(&kd);
        let w = f.w();
        let mut max_out: f64 = 0.0;
        let mut max_in: f64 = 0.0;
        for i in 0..n {
            for j in 0..n {
                let v = prod.get(i, j).abs();
                if j + w > i && j < i + w {
                    max_in = max_in.max(v);
                } else {
                    max_out = max_out.max(v);
                }
            }
        }
        assert!(
            max_out < 1e-8 * max_in.max(1.0),
            "{nu:?} ω={omega}: outside-band {max_out:.3e} vs inside {max_in:.3e}"
        );
        // And Φ must equal the band of A·K.
        for i in 0..n {
            let (lo, hi) = f.phi.row_range(i);
            for j in lo..hi {
                assert!(
                    (f.phi.get(i, j) - prod.get(i, j)).abs() < 1e-9 * max_in.max(1.0),
                    "Φ[{i},{j}]"
                );
            }
        }
    }

    #[test]
    fn kp_compact_support_matern12() {
        check_banded(Nu::Half, 1.0, 30, 1);
        check_banded(Nu::Half, 0.05, 30, 2); // small ω·spacing stress
        check_banded(Nu::Half, 20.0, 30, 3);
    }

    #[test]
    fn kp_compact_support_matern32() {
        check_banded(Nu::ThreeHalves, 1.0, 30, 4);
        check_banded(Nu::ThreeHalves, 0.1, 30, 5);
        check_banded(Nu::ThreeHalves, 8.0, 30, 6);
    }

    #[test]
    fn kp_compact_support_matern52() {
        check_banded(Nu::FiveHalves, 1.0, 30, 7);
        check_banded(Nu::FiveHalves, 0.3, 30, 8);
    }

    /// Full factorization identity: `A (P^T K P) = Φ`, i.e.
    /// `P^T K P = A^{-1} Φ` (paper eq. 8).
    #[test]
    fn factorization_identity() {
        for nu in [Nu::Half, Nu::ThreeHalves, Nu::FiveHalves] {
            let pts = random_points(25, 0.0, 10.0, 42);
            let kernel = Matern::new(nu, 0.7);
            let f = KpFactorization::new(&pts, kernel);
            // Reconstruct K_sorted = A^{-1} Φ and compare to the true gram.
            let kd = kernel.gram(&f.xs);
            let alu = f.a.lu();
            for j in 0..25 {
                let col: Vec<f64> = (0..25).map(|i| f.phi.get(i, j)).collect();
                let kcol = alu.solve(&col);
                for i in 0..25 {
                    assert!(
                        (kcol[i] - kd.get(i, j)).abs() < 1e-8,
                        "{nu:?} K[{i},{j}]: {} vs {}",
                        kcol[i],
                        kd.get(i, j)
                    );
                }
            }
        }
    }

    /// `φ_i` evaluated at data points outside its support must vanish
    /// (Figure 1's right panel).
    #[test]
    fn packet_vanishes_outside_support() {
        let pts: Vec<f64> = (1..=10).map(|i| i as f64 / 10.0).collect();
        let f = KpFactorization::new(&pts, Matern::new(Nu::ThreeHalves, 1.0));
        let w = f.w(); // 2
        for i in w..10 - w {
            // central packet i: support (xs[i-2], xs[i+2])
            for (j, &xj) in f.xs.iter().enumerate() {
                let val: f64 = {
                    let (lo, hi) = f.a.row_range(i);
                    (lo..hi).map(|s| f.a.get(i, s) * f.kernel.k(f.xs[s], xj)).sum()
                };
                if j + w <= i || j >= i + w {
                    assert!(val.abs() < 1e-10, "φ_{i}(x_{j}) = {val}");
                }
            }
        }
    }

    /// Sparse window evaluation matches the dense `A k(X, x*)`.
    #[test]
    fn phi_window_matches_dense() {
        for nu in [Nu::Half, Nu::ThreeHalves, Nu::FiveHalves] {
            let pts = random_points(40, -1.0, 1.0, 9);
            let f = KpFactorization::new(&pts, Matern::new(nu, 2.0));
            let mut rng = Rng::new(100);
            for _ in 0..30 {
                let x = rng.uniform_in(-1.3, 1.3);
                let dense = f.phi_full(x);
                let (start, vals) = f.phi_window(x);
                assert!(vals.len() <= 2 * f.w());
                for (i, &d) in dense.iter().enumerate() {
                    let wv = if i >= start && i < start + vals.len() {
                        vals[i - start]
                    } else {
                        0.0
                    };
                    assert!(
                        (d - wv).abs() < 1e-10,
                        "{nu:?} x={x}: φ_{i} dense={d} window={wv}"
                    );
                }
            }
        }
    }

    /// Derivative windows match finite differences of the φ windows.
    #[test]
    fn dphi_window_matches_fd() {
        let pts = random_points(30, 0.0, 5.0, 13);
        let f = KpFactorization::new(&pts, Matern::new(Nu::ThreeHalves, 1.1));
        let h = 1e-6;
        let mut rng = Rng::new(5);
        for _ in 0..20 {
            // avoid evaluating across a data point (φ has kinks there)
            let x = rng.uniform_in(0.1, 4.9);
            if f.xs.iter().any(|&p| (p - x).abs() < 1e-3) {
                continue;
            }
            let dense_p = f.phi_full(x + h);
            let dense_m = f.phi_full(x - h);
            let (start, dvals) = f.dphi_window(x);
            for (r, &dv) in dvals.iter().enumerate() {
                let fd = (dense_p[start + r] - dense_m[start + r]) / (2.0 * h);
                assert!((fd - dv).abs() < 1e-5, "i={} fd={fd} dv={dv}", start + r);
            }
        }
    }

    /// Incremental `insert` reproduces the from-scratch factorization
    /// exactly (same moment systems ⇒ bit-identical coefficients) for
    /// interior, new-minimum and new-maximum insertions.
    #[test]
    fn insert_matches_fresh_factorization() {
        for nu in [Nu::Half, Nu::ThreeHalves, Nu::FiveHalves] {
            let pts = random_points(20, 0.0, 4.0, 51);
            let kernel = Matern::new(nu, 1.3);
            let mut inc = KpFactorization::new(&pts, kernel);
            let mut all = pts.clone();
            // Interior, below-range, above-range, near-boundary inserts.
            for &x in &[2.17, -0.5, 4.9, 0.01, 3.99] {
                let pos = inc.insert(x).expect("distinct point must insert");
                all.push(x);
                let fresh = KpFactorization::new(&all, kernel);
                assert_eq!(inc.xs[pos], x);
                assert_eq!(inc.n(), fresh.n());
                for (a, b) in inc.xs.iter().zip(&fresh.xs) {
                    assert_eq!(a, b, "{nu:?} xs mismatch after insert {x}");
                }
                for i in 0..inc.n() {
                    assert_eq!(
                        inc.perm.orig(i),
                        fresh.perm.orig(i),
                        "{nu:?} perm mismatch at {i}"
                    );
                }
                let (ai, af) = (inc.a.to_dense(), fresh.a.to_dense());
                let (pi, pf) = (inc.phi.to_dense(), fresh.phi.to_dense());
                for i in 0..inc.n() {
                    for j in 0..inc.n() {
                        assert!(
                            (ai.get(i, j) - af.get(i, j)).abs() < 1e-13,
                            "{nu:?} x={x} A[{i},{j}]: {} vs {}",
                            ai.get(i, j),
                            af.get(i, j)
                        );
                        assert!(
                            (pi.get(i, j) - pf.get(i, j)).abs() < 1e-12,
                            "{nu:?} x={x} Φ[{i},{j}]: {} vs {}",
                            pi.get(i, j),
                            pf.get(i, j)
                        );
                    }
                }
            }
        }
    }

    /// `insert_batch` is bit-identical to the equivalent sequence of single
    /// `insert` calls (positions, permutation, and every packet
    /// coefficient), across smoothness and with out-of-range points mixed
    /// in.
    #[test]
    fn insert_batch_matches_sequential_inserts_bitwise() {
        for nu in [Nu::Half, Nu::ThreeHalves, Nu::FiveHalves] {
            let pts = random_points(22, 0.0, 4.0, 61);
            let kernel = Matern::new(nu, 1.2);
            let mut batched = KpFactorization::new(&pts, kernel);
            let mut seq = KpFactorization::new(&pts, kernel);
            // Interior, below-range, above-range, adjacent insertions.
            let batch = [2.17, -0.6, 4.8, 2.18, 0.02, 3.97];
            let got = batched.insert_batch(&batch).expect("distinct points insert");
            let mut seq_final: Vec<usize> = Vec::new();
            for &x in &batch {
                let pos = seq.insert(x).expect("distinct points insert");
                for p in seq_final.iter_mut() {
                    if *p >= pos {
                        *p += 1;
                    }
                }
                seq_final.push(pos);
            }
            assert_eq!(got, seq_final, "{nu:?} final positions");
            assert_eq!(batched.n(), seq.n());
            for i in 0..batched.n() {
                assert_eq!(batched.xs[i], seq.xs[i], "{nu:?} xs[{i}]");
                assert_eq!(batched.perm.orig(i), seq.perm.orig(i), "{nu:?} perm[{i}]");
                for j in 0..batched.n() {
                    assert_eq!(
                        batched.a.get(i, j),
                        seq.a.get(i, j),
                        "{nu:?} A[{i},{j}]"
                    );
                    assert_eq!(
                        batched.phi.get(i, j),
                        seq.phi.get(i, j),
                        "{nu:?} Φ[{i},{j}]"
                    );
                }
            }
        }
    }

    /// Incremental `remove` reproduces the from-scratch factorization of the
    /// surviving points exactly (same moment systems ⇒ bit-identical
    /// coefficients) for interior, minimum and maximum removals — and
    /// `insert` followed by `remove` of the same point is bit-identical to
    /// never having inserted it.
    #[test]
    fn remove_matches_fresh_factorization() {
        for nu in [Nu::Half, Nu::ThreeHalves, Nu::FiveHalves] {
            let pts = random_points(20, 0.0, 4.0, 52);
            let kernel = Matern::new(nu, 1.3);
            let mut inc = KpFactorization::new(&pts, kernel);
            let mut all = pts.clone();
            // Interior, minimum, maximum, near-boundary removals (sorted
            // positions evaluated against the shrinking set).
            for &pos in &[7usize, 0, 17, 1, 15] {
                let orig = inc.remove(pos);
                assert_eq!(all[orig], {
                    let mut s = all.clone();
                    s.sort_by(f64::total_cmp);
                    s[pos]
                });
                all.remove(orig);
                let fresh = KpFactorization::new(&all, kernel);
                assert_eq!(inc.n(), fresh.n());
                for (a, b) in inc.xs.iter().zip(&fresh.xs) {
                    assert_eq!(a, b, "{nu:?} xs mismatch after remove {pos}");
                }
                for i in 0..inc.n() {
                    assert_eq!(
                        inc.perm.orig(i),
                        fresh.perm.orig(i),
                        "{nu:?} perm mismatch at {i}"
                    );
                }
                for i in 0..inc.n() {
                    for j in 0..inc.n() {
                        assert!(
                            (inc.a.get(i, j) - fresh.a.get(i, j)).abs() < 1e-13,
                            "{nu:?} pos={pos} A[{i},{j}]: {} vs {}",
                            inc.a.get(i, j),
                            fresh.a.get(i, j)
                        );
                        assert!(
                            (inc.phi.get(i, j) - fresh.phi.get(i, j)).abs() < 1e-12,
                            "{nu:?} pos={pos} Φ[{i},{j}]: {} vs {}",
                            inc.phi.get(i, j),
                            fresh.phi.get(i, j)
                        );
                    }
                }
            }
        }
    }

    /// `insert(x)` then `remove` of the same point restores every structure
    /// bit-for-bit (the packet-level half of the forget property).
    #[test]
    fn insert_then_remove_is_identity_bitwise() {
        for nu in [Nu::Half, Nu::ThreeHalves, Nu::FiveHalves] {
            let pts = random_points(18, 0.0, 4.0, 53);
            let kernel = Matern::new(nu, 1.1);
            let base = KpFactorization::new(&pts, kernel);
            for &x in &[2.17, -0.5, 4.9, 0.01] {
                let mut f = base.clone();
                let pos = f.insert(x).expect("distinct point must insert");
                f.remove(pos);
                assert_eq!(f.xs, base.xs, "{nu:?} x={x}");
                for i in 0..f.n() {
                    assert_eq!(f.perm.orig(i), base.perm.orig(i), "{nu:?} x={x}");
                    for j in 0..f.n() {
                        assert_eq!(f.a.get(i, j), base.a.get(i, j), "{nu:?} A[{i},{j}]");
                        assert_eq!(
                            f.phi.get(i, j),
                            base.phi.get(i, j),
                            "{nu:?} Φ[{i},{j}]"
                        );
                    }
                }
            }
        }
    }

    /// `remove_batch` is bit-identical to the equivalent sequence of single
    /// `remove` calls (walked in descending order).
    #[test]
    fn remove_batch_matches_sequential_removes_bitwise() {
        for nu in [Nu::Half, Nu::ThreeHalves, Nu::FiveHalves] {
            let pts = random_points(24, 0.0, 4.0, 62);
            let kernel = Matern::new(nu, 1.2);
            let mut batched = KpFactorization::new(&pts, kernel);
            let mut seq = KpFactorization::new(&pts, kernel);
            let positions = [0usize, 5, 6, 11, 23];
            let origs = batched.remove_batch(&positions);
            assert_eq!(origs.len(), positions.len());
            for &p in positions.iter().rev() {
                seq.remove(p);
            }
            assert_eq!(batched.n(), seq.n());
            for i in 0..batched.n() {
                assert_eq!(batched.xs[i], seq.xs[i], "{nu:?} xs[{i}]");
                assert_eq!(batched.perm.orig(i), seq.perm.orig(i), "{nu:?} perm[{i}]");
                for j in 0..batched.n() {
                    assert_eq!(batched.a.get(i, j), seq.a.get(i, j), "{nu:?} A[{i},{j}]");
                    assert_eq!(
                        batched.phi.get(i, j),
                        seq.phi.get(i, j),
                        "{nu:?} Φ[{i},{j}]"
                    );
                }
            }
        }
    }

    /// Removing below the packet minimum is refused by panic — the caller
    /// must deactivate instead.
    #[test]
    #[should_panic(expected = "packet minimum")]
    fn remove_below_packet_minimum_panics() {
        let pts: Vec<f64> = (0..3).map(|i| i as f64).collect();
        let mut f = KpFactorization::new(&pts, Matern::new(Nu::Half, 1.0));
        f.remove(0); // n = 3 = 2w+1 is already the floor for ν = 1/2
    }

    /// A batch containing an inseparable duplicate fails atomically: the
    /// factorization is left exactly as it was.
    #[test]
    fn insert_batch_degenerate_fails_without_mutating() {
        let pts: Vec<f64> = (0..12).map(|i| i as f64).collect();
        let mut f = KpFactorization::new(&pts, Matern::new(Nu::Half, 1.0));
        let before_xs = f.xs.clone();
        let before_a = f.a.to_dense();
        // Two equal values: the second cannot be separated (the first takes
        // the only nudge slot), so the whole batch must be refused.
        assert!(f.insert_batch(&[5.0, 5.0]).is_none());
        assert_eq!(f.xs, before_xs);
        let after_a = f.a.to_dense();
        for i in 0..12 {
            for j in 0..12 {
                assert_eq!(before_a.get(i, j), after_a.get(i, j));
            }
        }
        // And a clean batch still goes through afterwards.
        let pos = f.insert_batch(&[3.5, 7.25]).expect("distinct batch inserts");
        assert_eq!(pos.len(), 2);
        for w in f.xs.windows(2) {
            assert!(w[1] > w[0]);
        }
    }

    /// Duplicate insertions either nudge apart or signal a rebuild — never
    /// corrupt the factorization.
    #[test]
    fn insert_duplicate_nudges_or_falls_back() {
        let pts: Vec<f64> = (0..12).map(|i| i as f64).collect();
        let mut f = KpFactorization::new(&pts, Matern::new(Nu::Half, 1.0));
        match f.insert(5.0) {
            Some(pos) => {
                // Nudged just above the existing 5.0, strictly increasing.
                assert_eq!(pos, 6);
                for w in f.xs.windows(2) {
                    assert!(w[1] > w[0]);
                }
            }
            None => panic!("span is large; the nudge must succeed here"),
        }
        // A second duplicate may land exactly on the first nudge's offset —
        // then `insert` must refuse (rebuild signal) rather than corrupt the
        // ordering. Either way the points stay strictly increasing.
        let _ = f.insert(5.0);
        for w in f.xs.windows(2) {
            assert!(w[1] > w[0]);
        }
    }

    /// The permutation round-trips scattered order.
    #[test]
    fn permutation_consistency() {
        let pts = random_points(20, 0.0, 1.0, 77);
        let f = KpFactorization::new(&pts, Matern::new(Nu::Half, 3.0));
        for (orig, &p) in pts.iter().enumerate() {
            assert_eq!(f.xs[f.perm.sorted_pos(orig)], p);
        }
    }

    /// Desynchronizing the sorted-point array (breaking the strict order the
    /// packet windows rely on) is pinpointed at the offending sorted index.
    #[test]
    fn audit_flags_desynced_sorted_points() {
        let pts = random_points(20, 0.0, 1.0, 78);
        let mut f = KpFactorization::new(&pts, Matern::new(Nu::ThreeHalves, 1.0));
        assert!(f.audit().is_ok());
        f.xs[7] = f.xs[5]; // xs[7] ≤ xs[6]: window ordering is broken
        let e = f.audit().unwrap_err();
        assert_eq!(e.structure, "KpFactorization");
        assert_eq!(e.field, "xs");
        assert_eq!(e.index, Some(7));
    }

    /// A child-structure break (the permutation) propagates with the child's
    /// structure name, so the report still pinpoints the real culprit.
    #[test]
    fn audit_propagates_child_structure_names() {
        let pts = random_points(20, 0.0, 1.0, 79);
        let mut f = KpFactorization::new(&pts, Matern::new(Nu::Half, 1.0));
        f.perm = Permutation::identity(3); // wrong length AND detached from xs
        let e = f.audit().unwrap_err();
        assert_eq!(e.structure, "KpFactorization");
        assert_eq!(e.field, "perm");
    }
}
