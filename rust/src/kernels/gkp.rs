//! Generalized Kernel Packets — paper §4.2, Theorems 4–6 and **Algorithm 3**.
//!
//! The ω-derivative of a Matérn-ν covariance matrix also factors as
//!
//! ```text
//! P^T [∂_ω K] P = B^{-1} Ψ        (paper eq. 11)
//! ```
//!
//! where `B` is `ν+3/2`-banded and `Ψ` is `ν+1/2`-banded. The coefficients of
//! the generalized packets are exactly the KP coefficients *of order ν+1 at
//! the same rate ω* (Theorems 5–6): `∂_ω k` is `e^{-ωr}` times a polynomial
//! one degree higher, so the moment systems gain one more power `l` but keep
//! the same exponential rate. Algorithm 3 therefore reuses
//! [`build_packet_matrix`] with `q+1` and evaluates the Gram of `∂_ω k`.

use crate::kernels::kp::build_packet_matrix;
use crate::kernels::matern::Matern;
use crate::linalg::Banded;

/// The generalized-KP factorization `P^T ∂_ω K P = B^{-1} Ψ` of one
/// dimension (paper **Algorithm 3**). Shares the sorted points of the parent
/// [`crate::kernels::KpFactorization`].
#[derive(Clone, Debug)]
pub struct GkpFactorization {
    pub kernel: Matern,
    /// Sorted points (copied from the KP factorization).
    pub xs: Vec<f64>,
    /// Generalized-packet coefficients, half-bandwidth `ν+3/2`.
    pub b: Banded,
    /// Gram of the ω-derivative `Ψ[i,j] = ψ_i(x_j)`, half-bandwidth `ν+1/2`.
    pub psi: Banded,
}

impl GkpFactorization {
    /// Factorize `∂_ω k(X, X)` for *sorted* `xs` (requires `n ≥ 2ν+4`).
    pub fn new_sorted(xs: &[f64], kernel: Matern) -> Self {
        let q = kernel.nu.q();
        let wb = q + 2; // ν + 3/2
        let n = xs.len();
        assert!(n >= 2 * wb + 1, "need n ≥ 2ν+4 = {} points, got {n}", 2 * wb + 1);
        let b = build_packet_matrix(xs, kernel.omega, q + 1);
        // Ψ = band_{ν+1/2}(B ∂ωK).
        let band = q + 1;
        let mut psi = Banded::zeros(n, band, band);
        for i in 0..n {
            let (jlo, jhi) = psi.row_range(i);
            let (slo, shi) = b.row_range(i);
            for j in jlo..jhi {
                let mut acc = 0.0;
                for s in slo..shi {
                    acc += b.get(i, s) * kernel.dk_domega(xs[s], xs[j]);
                }
                psi.set(i, j, acc);
            }
        }
        GkpFactorization { kernel, xs: xs.to_vec(), b, psi }
    }

    pub fn n(&self) -> usize {
        self.xs.len()
    }

    /// Apply `∂_ω K = B^{-1} Ψ` to a vector in sorted coordinates: `O(n)`.
    pub fn dk_matvec(&self, v: &[f64]) -> Vec<f64> {
        let t = self.psi.matvec(v);
        self.b.solve(&t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::matern::Nu;
    use crate::util::Rng;

    fn sorted_points(n: usize, lo: f64, hi: f64, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        let mut pts = rng.uniform_vec(n, lo, hi);
        pts.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for i in 1..n {
            if pts[i] - pts[i - 1] < 1e-9 {
                pts[i] = pts[i - 1] + 1e-6;
            }
        }
        pts
    }

    /// `B · ∂ωK` must be `ν+1/2`-banded (Theorem 4 / Figure 2), and `Ψ`
    /// must equal its band.
    fn check_gkp(nu: Nu, omega: f64, n: usize, seed: u64) {
        let xs = sorted_points(n, -1.0, 4.0, seed);
        let kernel = Matern::new(nu, omega);
        let g = GkpFactorization::new_sorted(&xs, kernel);
        let dk = kernel.gram_domega(&xs);
        let prod = g.b.to_dense().matmul(&dk);
        let band = nu.q() + 1;
        let mut max_in: f64 = 0.0;
        let mut max_out: f64 = 0.0;
        for i in 0..n {
            for j in 0..n {
                let v = prod.get(i, j).abs();
                if j + band >= i && j <= i + band {
                    max_in = max_in.max(v);
                } else {
                    max_out = max_out.max(v);
                }
            }
        }
        assert!(
            max_out < 1e-8 * max_in.max(1.0),
            "{nu:?} ω={omega}: GKP outside-band {max_out:.3e} vs {max_in:.3e}"
        );
        for i in 0..n {
            let (lo, hi) = g.psi.row_range(i);
            for j in lo..hi {
                assert!((g.psi.get(i, j) - prod.get(i, j)).abs() < 1e-9 * max_in.max(1.0));
            }
        }
    }

    #[test]
    fn gkp_banded_matern12() {
        check_gkp(Nu::Half, 1.0, 30, 21);
        check_gkp(Nu::Half, 0.07, 30, 22);
        check_gkp(Nu::Half, 10.0, 30, 23);
    }

    #[test]
    fn gkp_banded_matern32() {
        check_gkp(Nu::ThreeHalves, 1.0, 32, 24);
        check_gkp(Nu::ThreeHalves, 0.2, 32, 25);
    }

    #[test]
    fn gkp_banded_matern52() {
        check_gkp(Nu::FiveHalves, 0.9, 36, 26);
    }

    /// Figure 2's explicit example: Matérn-1/2, ω=1, X = {0.1, …, 1.0}.
    #[test]
    fn gkp_figure2_example() {
        let xs: Vec<f64> = (1..=10).map(|i| i as f64 / 10.0).collect();
        let kernel = Matern::new(Nu::Half, 1.0);
        let g = GkpFactorization::new_sorted(&xs, kernel);
        // ∂ωk(ω|x−x'|) = −|x−x'| e^{−|x−x'|} (paper §4.2 text).
        let d = kernel.dk_domega(0.3, 0.7);
        assert!((d - (-0.4 * (-0.4f64).exp())).abs() < 1e-12);
        // Ψ is (ν+1/2)=1-banded: entries |i−j| ≥ 2 of B·∂ωK vanish.
        let dk = kernel.gram_domega(&xs);
        let prod = g.b.to_dense().matmul(&dk);
        for i in 0..10 {
            for j in 0..10 {
                if (i as isize - j as isize).abs() >= 2 {
                    assert!(prod.get(i, j).abs() < 1e-9, "Ψ[{i},{j}]={}", prod.get(i, j));
                }
            }
        }
    }

    /// `dk_matvec` reproduces the dense `∂ωK v`.
    #[test]
    fn dk_matvec_matches_dense() {
        for nu in [Nu::Half, Nu::ThreeHalves] {
            let xs = sorted_points(25, 0.0, 2.0, 31);
            let kernel = Matern::new(nu, 1.4);
            let g = GkpFactorization::new_sorted(&xs, kernel);
            let mut rng = Rng::new(8);
            let v = rng.normal_vec(25);
            let got = g.dk_matvec(&v);
            let want = kernel.gram_domega(&xs).matvec(&v);
            let scale = want.iter().fold(1.0f64, |m, v| m.max(v.abs()));
            for i in 0..25 {
                // packet coefficients carry ~1e-8 relative conditioning error;
                // n of them accumulate in a matvec.
                assert!((got[i] - want[i]).abs() < 1e-6 * scale, "{nu:?} i={i}");
            }
        }
    }

    /// B must be invertible for scattered points (Theorem 4).
    #[test]
    fn b_invertible() {
        let xs = sorted_points(40, -3.0, 3.0, 99);
        let g = GkpFactorization::new_sorted(&xs, Matern::new(Nu::Half, 0.8));
        let (ld, _) = g.b.lu().logdet();
        assert!(ld.is_finite());
        // Solve and verify residual.
        let v: Vec<f64> = (0..40).map(|i| (i as f64 * 0.3).sin()).collect();
        let x = g.b.solve(&v);
        let r = g.b.matvec(&x);
        for i in 0..40 {
            assert!((r[i] - v[i]).abs() < 1e-8);
        }
    }
}
