//! Structural invariant audits (DESIGN.md §Invariants).
//!
//! Every guarantee the crate ships — window-local KP patches, prefix-reuse
//! LU updates, batch == sequential bit-identity, non-perturbing snapshots —
//! rests on a handful of *structural invariants* (strictly-increasing
//! points, bijective permutations, band-storage/shape agreement, queue
//! accounting, …). The end-to-end equivalence tests catch a broken
//! invariant long after the mutation that introduced it; the [`Audit`]
//! trait localizes it to the mutating call.
//!
//! Each stateful structure implements [`Audit`] and reports the first
//! violated invariant as a structured [`AuditError`] naming the structure,
//! the field, and (when localized) the offending index. Mutating operations
//! call [`enforce`] on their way out; under the `strict-invariants` cargo
//! feature that runs the full audit and panics with the violation report,
//! while without the feature it compiles to nothing — release hot paths are
//! untouched (the bench smoke gate asserts the feature is off).
//!
//! On-demand audits are also reachable over the wire: the coordinator's
//! `audit` op walks a model's whole structure tree and reports the outcome
//! through the normal response/metrics path.

use std::fmt;

/// A structured invariant-violation report: which structure broke, which
/// field/invariant inside it, and where.
#[derive(Clone, Debug, PartialEq)]
pub struct AuditError {
    /// Type name of the violating structure (e.g. `"Banded"`, `"BandedLU"`).
    pub structure: &'static str,
    /// The field or named invariant that failed (e.g. `"piv"`, `"xs"`).
    pub field: &'static str,
    /// Offending index, when the violation is localized to one entry.
    pub index: Option<usize>,
    /// Human-readable detail (the values involved).
    pub detail: String,
}

impl AuditError {
    pub fn new(
        structure: &'static str,
        field: &'static str,
        index: Option<usize>,
        detail: impl Into<String>,
    ) -> Self {
        AuditError { structure, field, index, detail: detail.into() }
    }
}

impl fmt::Display for AuditError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}.{}", self.structure, self.field)?;
        if let Some(i) = self.index {
            write!(f, "[{i}]")?;
        }
        write!(f, ": {}", self.detail)
    }
}

impl std::error::Error for AuditError {}

/// A stateful structure whose well-formedness can be checked in full.
///
/// `audit` walks every invariant the structure promises (including its
/// children's, so a [`crate::gp::fit_state::FitState`] audit covers the
/// banded factors underneath it) and returns the *first* violation found —
/// structure, field, index — rather than a bare panic deep in a solve.
pub trait Audit {
    fn audit(&self) -> Result<(), AuditError>;
}

/// Post-mutation audit hook. With the `strict-invariants` feature the full
/// audit runs and a violation panics with `context` (the mutating call) in
/// the message; without it this is an empty `#[inline(always)]` stub that
/// the optimizer erases — zero release overhead by construction.
#[cfg(feature = "strict-invariants")]
pub fn enforce<T: Audit + ?Sized>(value: &T, context: &str) {
    if let Err(e) = value.audit() {
        panic!("strict-invariants: violation after {context}: {e}");
    }
}

/// No-feature variant of [`enforce`]: does nothing, inlines to nothing.
#[cfg(not(feature = "strict-invariants"))]
#[inline(always)]
pub fn enforce<T: Audit + ?Sized>(_value: &T, _context: &str) {}

#[cfg(test)]
mod tests {
    use super::*;

    struct AlwaysOk;
    impl Audit for AlwaysOk {
        fn audit(&self) -> Result<(), AuditError> {
            Ok(())
        }
    }

    struct AlwaysBad;
    impl Audit for AlwaysBad {
        fn audit(&self) -> Result<(), AuditError> {
            Err(AuditError::new("AlwaysBad", "flag", Some(3), "forced"))
        }
    }

    #[test]
    fn display_names_structure_field_index() {
        let e = AuditError::new("Banded", "data", Some(7), "non-finite entry");
        assert_eq!(e.to_string(), "Banded.data[7]: non-finite entry");
        let e = AuditError::new("FitState", "dims", None, "empty");
        assert_eq!(e.to_string(), "FitState.dims: empty");
    }

    #[test]
    fn enforce_passes_ok_values() {
        enforce(&AlwaysOk, "test");
    }

    #[cfg(feature = "strict-invariants")]
    #[test]
    fn enforce_panics_on_violation_with_context() {
        let err = std::panic::catch_unwind(|| enforce(&AlwaysBad, "tests::mutate"))
            .expect_err("must panic under strict-invariants");
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("tests::mutate"), "context missing: {msg}");
        assert!(msg.contains("AlwaysBad.flag[3]"), "violation missing: {msg}");
    }

    #[cfg(not(feature = "strict-invariants"))]
    #[test]
    fn enforce_is_a_no_op_without_the_feature() {
        enforce(&AlwaysBad, "tests::mutate"); // must not panic
    }
}
