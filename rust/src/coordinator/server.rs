//! TCP server: JSON lines in, JSON lines out. One reader thread per
//! connection; a registry routes requests to per-model engine workers.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};

use crate::anyhow;
use crate::coordinator::engine::{Command, EngineConfig, ModelEngine};
use crate::coordinator::metrics::ServerMetrics;
use crate::coordinator::protocol::{Request, Response};
use crate::kernels::matern::Nu;
use crate::util::error::Result;

/// Shared server state.
struct Shared {
    engines: Mutex<HashMap<u64, Sender<Command>>>,
    next_id: AtomicU64,
    shutting_down: AtomicBool,
    /// Engines create their own PJRT clients on their worker threads (the
    /// xla handles are not Send); this only gates whether they try.
    use_pjrt: bool,
    /// Box bounds handed to each engine's `suggest`.
    lo: f64,
    hi: f64,
    metrics: ServerMetrics,
}

/// The coordinator server.
pub struct Server {
    listener: TcpListener,
    shared: Arc<Shared>,
}

impl Server {
    /// Bind to `addr` (e.g. `127.0.0.1:0`). `use_pjrt=false` skips the PJRT
    /// client entirely (native-only engines).
    pub fn bind(addr: &str, use_pjrt: bool, lo: f64, hi: f64) -> Result<Self> {
        let listener = TcpListener::bind(addr)?;
        Ok(Server {
            listener,
            shared: Arc::new(Shared {
                engines: Mutex::new(HashMap::new()),
                next_id: AtomicU64::new(1),
                shutting_down: AtomicBool::new(false),
                use_pjrt,
                lo,
                hi,
                metrics: ServerMetrics::default(),
            }),
        })
    }

    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.listener.local_addr().unwrap()
    }

    /// One-line serving-metrics report (also printed at shutdown).
    pub fn metrics_report(&self) -> String {
        self.shared.metrics.report()
    }

    /// Accept-loop. Returns when a client sends `shutdown`.
    pub fn serve(&self) -> Result<()> {
        for stream in self.listener.incoming() {
            if self.shared.shutting_down.load(Ordering::SeqCst) {
                break;
            }
            let stream = stream?;
            let shared = Arc::clone(&self.shared);
            std::thread::spawn(move || handle_conn(stream, shared));
        }
        println!("coordinator metrics: {}", self.shared.metrics.report());
        Ok(())
    }
}

fn handle_conn(stream: TcpStream, shared: Arc<Shared>) {
    let peer = stream.peer_addr().ok();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        if line.trim().is_empty() {
            continue;
        }
        let (resp, id) = dispatch(&line, &shared);
        let out = format!("{}\n", resp.to_json(id));
        if writer.write_all(out.as_bytes()).is_err() {
            break;
        }
        if shared.shutting_down.load(Ordering::SeqCst) {
            // Poke the accept loop so `serve` can exit.
            let addr = writer.local_addr().ok();
            if let Some(addr) = addr {
                let _ = TcpStream::connect(addr);
            }
            break;
        }
    }
    let _ = peer;
}

fn dispatch(line: &str, shared: &Arc<Shared>) -> (Response, Option<f64>) {
    shared.metrics.inc_requests();
    let t0 = std::time::Instant::now();
    let (req, id) = match Request::parse(line) {
        Ok(v) => v,
        Err(e) => {
            shared.metrics.inc_errors();
            return (Response::Error(e), None);
        }
    };
    let is_predict = matches!(req, Request::Predict { .. });
    let is_suggest = matches!(req, Request::Suggest { .. });
    let is_ingest =
        matches!(req, Request::Observe { .. } | Request::ObserveBatch { .. });
    match &req {
        Request::Predict { xs, .. } => shared.metrics.add_predict_points(xs.len()),
        Request::Observe { .. } => shared.metrics.add_observe_points(1),
        Request::ObserveBatch { ys, .. } => shared.metrics.add_observe_points(ys.len()),
        _ => {}
    }
    let resp = match req {
        Request::CreateModel { d, nu2, omega, sigma2 } => {
            let nu = match Nu::from_two_nu(nu2) {
                Some(nu) => nu,
                None => return (Response::Error(format!("bad nu2 {nu2}")), id),
            };
            let cfg = EngineConfig {
                d,
                nu,
                omega0: omega,
                sigma2,
                lo: shared.lo,
                hi: shared.hi,
                use_pjrt: shared.use_pjrt,
                seed: 0xC0FE ^ d as u64,
            };
            let (tx, rx) = channel();
            // Construct on the worker thread: PJRT handles are not Send.
            std::thread::spawn(move || ModelEngine::new(cfg).run(rx));
            let idx = shared.next_id.fetch_add(1, Ordering::SeqCst);
            shared.engines.lock().unwrap().insert(idx, tx);
            Response::ModelCreated { model: idx }
        }
        Request::Shutdown => {
            shared.shutting_down.store(true, Ordering::SeqCst);
            let engines = shared.engines.lock().unwrap();
            for tx in engines.values() {
                let _ = tx.send(Command::Stop);
            }
            Response::Ok
        }
        other => {
            let model = match &other {
                Request::Observe { model, .. }
                | Request::ObserveBatch { model, .. }
                | Request::Fit { model, .. }
                | Request::Predict { model, .. }
                | Request::Suggest { model, .. }
                | Request::Stats { model } => *model,
                _ => unreachable!(),
            };
            let tx = {
                let engines = shared.engines.lock().unwrap();
                engines.get(&model).cloned()
            };
            let Some(tx) = tx else {
                return (Response::Error(format!("unknown model {model}")), id);
            };
            let (rtx, rrx) = channel();
            let cmd = match other {
                Request::Observe { x, y, .. } => Command::Observe { x, y, reply: rtx },
                Request::ObserveBatch { xs, ys, .. } => {
                    Command::ObserveBatch { xs, ys, reply: rtx }
                }
                Request::Fit { steps, .. } => Command::Fit { steps, reply: rtx },
                Request::Predict { xs, beta, grad, .. } => {
                    Command::Predict { xs, beta, grad, reply: rtx }
                }
                Request::Suggest { beta, .. } => Command::Suggest { beta, reply: rtx },
                Request::Stats { .. } => Command::Stats { reply: rtx },
                _ => unreachable!(),
            };
            if tx.send(cmd).is_err() {
                return (Response::Error("engine stopped".into()), id);
            }
            match rrx.recv() {
                Ok(r) => r,
                Err(_) => Response::Error("engine dropped reply".into()),
            }
        }
    };
    if matches!(resp, Response::Error(_)) {
        shared.metrics.inc_errors();
    }
    match &resp {
        Response::BatchObserved { path, factor_patched, factor_resweep, .. } => {
            shared.metrics.count_batch_path(path);
            shared.metrics.add_factor_outcomes(*factor_patched, *factor_resweep);
        }
        Response::Observed { factor_patched, factor_resweep, .. } => {
            shared.metrics.add_factor_outcomes(*factor_patched, *factor_resweep);
        }
        _ => {}
    }
    if is_predict {
        shared.metrics.predict_latency.record(t0.elapsed().as_secs_f64());
    } else if is_suggest {
        shared.metrics.suggest_latency.record(t0.elapsed().as_secs_f64());
    } else if is_ingest {
        shared.metrics.ingest_latency.record(t0.elapsed().as_secs_f64());
    }
    (resp, id)
}

/// Minimal blocking client for tests, examples and benches.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: std::net::SocketAddr) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        let writer = stream.try_clone()?;
        Ok(Client { reader: BufReader::new(stream), writer })
    }

    /// Send one JSON line and read one JSON-line reply.
    pub fn call(&mut self, req: &str) -> Result<crate::util::Json> {
        self.writer.write_all(req.as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        crate::util::Json::parse(&line).map_err(|e| anyhow!("bad reply: {e}"))
    }
}
