//! TCP server: JSON lines in, JSON lines out. One reader thread per
//! connection; requests route into the shared worker-pool [`Scheduler`]
//! (cross-model sharding — no thread per model).
//!
//! Shutdown is deterministic (and asserted by `tests/concurrency.rs`):
//! `shutdown` stops the accept loop, then [`Server::serve`] closes every
//! connection socket (unblocking its reader), joins every reader thread,
//! and finally drains + joins the scheduler's pool workers — in that order,
//! so an in-flight request can still get its reply from a live pool.
//!
//! Wire-input hardening (pinned by `tests/protocol_compat.rs`): lines are
//! read through a bounded reader ([`MAX_LINE`]) — an overlong line is
//! discarded up to its newline and answered with a structured error, and
//! invalid UTF-8 is decoded lossily into an ordinary parse error, so no
//! input byte sequence can panic a reader or silently close a connection.
//! Requests may carry a `deadline_ms` budget (expired waits return a
//! `retryable:` error and drop the late reply), and model-routed ops are
//! load-shed with the same `retryable:` marker once the in-flight count
//! passes the queue limit (default `workers * 256`,
//! [`Server::set_queue_limit`]). A peer that vanishes mid-request is
//! detected when its reply fails to write; the reader thread is freed and
//! the disconnect counted in [`ServerMetrics`].
//!
//! Protocol v3 adds the replication surface (DESIGN.md §Replication): a
//! `snapshot` request ships the model's generation-numbered posterior
//! artifact, and a `subscribe` request converts its connection into a
//! one-way invalidation stream — after the `subscribed` ack the reader
//! thread forwards one `invalidate` line per generation bump and reads no
//! further requests (a replica keeps a separate request/response
//! connection for its snapshot fetches).

use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::anyhow;
use crate::coordinator::engine::{Command, EngineConfig};
use crate::coordinator::journal::JournalConfig;
use crate::coordinator::lock_clean;
use crate::coordinator::metrics::ServerMetrics;
use crate::coordinator::protocol::{Request, Response, PROTOCOL_VERSION};
use crate::coordinator::scheduler::{RecoveryReport, Scheduler};
use crate::kernels::matern::Nu;
use crate::util::error::Result;
use crate::util::pool;

/// Hard cap on one request line. The biggest legitimate frames (dense
/// `observe_batch` payloads) sit far below it; anything larger is a
/// protocol violation or garbage, answered with a structured error while
/// the connection stays usable.
pub const MAX_LINE: usize = 1 << 20;

/// How long a reader blocks before re-checking the shutdown flag (also the
/// poll cadence for a no-deadline reply wait).
const READ_POLL: Duration = Duration::from_millis(250);

/// What a clean [`Server::serve`] exit joined — the deterministic-shutdown
/// receipt (no leaked reader threads, no leaked pool workers).
#[derive(Clone, Copy, Debug)]
pub struct ShutdownStats {
    /// Connection reader threads joined at shutdown (readers that finished
    /// earlier are pruned from the registry as new connections arrive).
    pub connections_joined: usize,
    /// Pool workers joined by the scheduler.
    pub workers_joined: usize,
}

/// Shared server state.
struct Shared {
    scheduler: Scheduler,
    shutting_down: AtomicBool,
    /// Whether `create_model` asks the scheduler to compile a PJRT
    /// executable (pinned to a pool worker; handles are not `Send`).
    use_pjrt: bool,
    /// Box bounds handed to each model's `suggest`.
    lo: f64,
    hi: f64,
    metrics: ServerMetrics,
    /// Model-routed requests currently between dispatch and reply, across
    /// all connections — the load-shedding signal.
    inflight: AtomicU64,
    /// Shed model-routed requests once `inflight` reaches this.
    queue_limit: AtomicU64,
    /// Live connections: a socket handle (to force readers off a blocking
    /// read at shutdown) plus the reader thread's join handle.
    conns: Mutex<Vec<(TcpStream, JoinHandle<()>)>>,
}

/// The coordinator server.
pub struct Server {
    listener: TcpListener,
    /// Resolved bind address, captured once at bind time (so `local_addr`
    /// never has to re-interrogate — and unwrap — the socket).
    addr: std::net::SocketAddr,
    shared: Arc<Shared>,
}

impl Server {
    /// Bind to `addr` (e.g. `127.0.0.1:0`) with a pool of
    /// [`pool::default_threads`] workers. `use_pjrt=false` skips PJRT
    /// compilation entirely (native-only models).
    pub fn bind(addr: &str, use_pjrt: bool, lo: f64, hi: f64) -> Result<Self> {
        Self::bind_with(addr, use_pjrt, lo, hi, pool::default_threads())
    }

    /// [`Server::bind`] with an explicit worker-pool size.
    pub fn bind_with(
        addr: &str,
        use_pjrt: bool,
        lo: f64,
        hi: f64,
        workers: usize,
    ) -> Result<Self> {
        Self::bind_scheduler(addr, use_pjrt, lo, hi, workers, Scheduler::new(workers))
    }

    /// [`Server::bind_with`] with durable mutations: every model created
    /// over the wire appends to a per-model journal under `jcfg`, so a
    /// crashed or cleanly-stopped writer can be rebooted onto the same
    /// fleet with [`Server::bind_recovered`] (DESIGN.md §Durability,
    /// §Replication — this is the home-shard half of writer failover).
    pub fn bind_journaled(
        addr: &str,
        use_pjrt: bool,
        lo: f64,
        hi: f64,
        workers: usize,
        jcfg: JournalConfig,
    ) -> Result<Self> {
        Self::bind_scheduler(addr, use_pjrt, lo, hi, workers, Scheduler::with_journal(workers, jcfg))
    }

    /// Bind a *restarted* writer: recover every journaled model from `jcfg`
    /// (same model ids, bit-identical state, generations preserved), then
    /// serve. The report rides along so callers can surface partial
    /// recoveries; replicas reconnect and resync without re-registration.
    pub fn bind_recovered(
        addr: &str,
        use_pjrt: bool,
        lo: f64,
        hi: f64,
        workers: usize,
        jcfg: JournalConfig,
    ) -> Result<(Self, RecoveryReport)> {
        let (scheduler, report) = Scheduler::recover(workers, jcfg);
        let server = Self::bind_scheduler(addr, use_pjrt, lo, hi, workers, scheduler)?;
        Ok((server, report))
    }

    fn bind_scheduler(
        addr: &str,
        use_pjrt: bool,
        lo: f64,
        hi: f64,
        workers: usize,
        scheduler: Scheduler,
    ) -> Result<Self> {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        Ok(Server {
            listener,
            addr,
            shared: Arc::new(Shared {
                scheduler,
                shutting_down: AtomicBool::new(false),
                use_pjrt,
                lo,
                hi,
                metrics: ServerMetrics::default(),
                inflight: AtomicU64::new(0),
                queue_limit: AtomicU64::new((workers.max(1) as u64) * 256),
                conns: Mutex::new(Vec::new()),
            }),
        })
    }

    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Override the load-shedding threshold (model-routed requests allowed
    /// in flight before new ones are refused with a `retryable:` error).
    pub fn set_queue_limit(&self, limit: u64) {
        self.shared.queue_limit.store(limit.max(1), Ordering::SeqCst);
    }

    /// Serving-metrics report — pool-wide counters/histograms plus one line
    /// per model (also printed at shutdown).
    pub fn metrics_report(&self) -> String {
        self.shared.metrics.report()
    }

    /// Accept-loop. Returns — after joining every connection reader and
    /// every pool worker — when a client sends `shutdown`.
    pub fn serve(&self) -> Result<ShutdownStats> {
        for stream in self.listener.incoming() {
            if self.shared.shutting_down.load(Ordering::SeqCst) {
                break;
            }
            let stream = match stream {
                Ok(s) => s,
                // A transient accept failure (ECONNABORTED, EMFILE, …) must
                // not abort serving — that would skip the deterministic
                // shutdown drain below and leak every parked reader.
                Err(_) => {
                    std::thread::sleep(std::time::Duration::from_millis(10));
                    continue;
                }
            };
            let Ok(sock) = stream.try_clone() else { continue };
            let shared = Arc::clone(&self.shared);
            let handle = std::thread::spawn(move || handle_conn(stream, shared));
            let mut conns = lock_clean(&self.shared.conns);
            // Prune finished readers so connection churn doesn't accumulate
            // cloned fds/handles for the server's whole lifetime.
            conns.retain(|(_, h)| !h.is_finished());
            conns.push((sock, handle));
        }
        // Deterministic drain: close every connection socket (readers
        // blocked in `read_line` see EOF), join the readers, then join the
        // pool — in this order an in-flight dispatch still gets its reply.
        let conns: Vec<(TcpStream, JoinHandle<()>)> =
            lock_clean(&self.shared.conns).drain(..).collect();
        let mut connections_joined = 0;
        for (sock, _) in &conns {
            let _ = sock.shutdown(Shutdown::Both);
        }
        for (_, handle) in conns {
            let _ = handle.join();
            connections_joined += 1;
        }
        let workers_joined = self.shared.scheduler.shutdown();
        println!("coordinator metrics: {}", self.shared.metrics.report());
        Ok(ShutdownStats { connections_joined, workers_joined })
    }
}

fn handle_conn(stream: TcpStream, shared: Arc<Shared>) {
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    // Wake periodically so a reader parked on a quiet connection still
    // notices shutdown even if the socket close races its blocking read.
    let _ = stream.set_read_timeout(Some(READ_POLL));
    let mut reader = BufReader::new(stream);
    loop {
        let line = match read_bounded_line(&mut reader, &shared) {
            LineRead::Line(l) => l,
            LineRead::Overlong(n) => {
                // The oversized frame was discarded up to its newline; the
                // connection stays usable for the next request.
                shared.metrics.inc_errors();
                let resp = Response::Error(format!(
                    "line too long ({n} bytes; limit {MAX_LINE}) — request discarded"
                ));
                let out = format!("{}\n", resp.to_json(None));
                if writer.write_all(out.as_bytes()).is_err() {
                    shared.metrics.inc_client_disconnects();
                    return;
                }
                continue;
            }
            LineRead::Eof => return,
        };
        if line.trim().is_empty() {
            continue;
        }
        let (resp, id, version, events) = dispatch(&line, &shared);
        let out = format!("{}\n", resp.to_json_v(id, version));
        if writer.write_all(out.as_bytes()).is_err() {
            // The peer vanished mid-request: count it and free this
            // reader thread (the computed reply is dropped).
            shared.metrics.inc_client_disconnects();
            return;
        }
        if let Some(events) = events {
            // A successful `subscribe` converts this connection into a
            // one-way invalidation stream; the reader thread becomes its
            // forwarder and reads no further requests.
            forward_events(&mut writer, events, &shared, version);
            return;
        }
        if shared.shutting_down.load(Ordering::SeqCst) {
            // Poke the accept loop so `serve` can exit.
            let addr = writer.local_addr().ok();
            if let Some(addr) = addr {
                let _ = TcpStream::connect(addr);
            }
            return;
        }
    }
}

/// Forward scheduler invalidation events to a subscribed connection until
/// the peer vanishes (a failed write), the model's subscriber entry is
/// dropped (scheduler quarantine), or the server shuts down. The receive
/// poll re-checks the shutdown flag on the same cadence as the bounded
/// reader, so subscribed connections join the deterministic drain.
fn forward_events(
    writer: &mut TcpStream,
    events: Receiver<Response>,
    shared: &Shared,
    version: u64,
) {
    loop {
        match events.recv_timeout(READ_POLL) {
            Ok(ev) => {
                let out = format!("{}\n", ev.to_json_v(None, version));
                if writer.write_all(out.as_bytes()).is_err() {
                    shared.metrics.inc_client_disconnects();
                    return;
                }
            }
            Err(RecvTimeoutError::Timeout) => {
                if shared.shutting_down.load(Ordering::SeqCst) {
                    return;
                }
            }
            Err(RecvTimeoutError::Disconnected) => return,
        }
    }
}

/// One bounded line read.
enum LineRead {
    Line(String),
    /// The line exceeded [`MAX_LINE`]; this many bytes were discarded up to
    /// (not including) its newline.
    Overlong(usize),
    Eof,
}

/// Read one `\n`-terminated line of at most [`MAX_LINE`] bytes. Longer
/// lines are consumed and discarded to their newline and reported as
/// [`LineRead::Overlong`] — the connection stays framed. Invalid UTF-8 is
/// decoded lossily (the parser then rejects it as a structured error).
/// Read timeouts re-check the shutdown flag and keep waiting, preserving
/// any partial line already buffered.
fn read_bounded_line(reader: &mut BufReader<TcpStream>, shared: &Shared) -> LineRead {
    let mut buf: Vec<u8> = Vec::new();
    let mut overlong = false;
    let mut dropped = 0usize;
    loop {
        let (done, used) = {
            let chunk = match reader.fill_buf() {
                Ok(c) => c,
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock
                            | std::io::ErrorKind::TimedOut
                            | std::io::ErrorKind::Interrupted
                    ) =>
                {
                    if shared.shutting_down.load(Ordering::SeqCst) {
                        return LineRead::Eof;
                    }
                    continue;
                }
                Err(_) => return LineRead::Eof,
            };
            if chunk.is_empty() {
                // EOF. A torn final line (bytes but no newline) means the
                // peer vanished mid-request.
                if overlong {
                    return LineRead::Overlong(dropped);
                }
                if !buf.is_empty() {
                    shared.metrics.inc_client_disconnects();
                }
                return LineRead::Eof;
            }
            match chunk.iter().position(|&b| b == b'\n') {
                Some(pos) => {
                    if overlong || buf.len() + pos > MAX_LINE {
                        dropped += if overlong { pos } else { buf.len() + pos };
                        overlong = true;
                        buf.clear();
                    } else {
                        buf.extend_from_slice(&chunk[..pos]);
                    }
                    (true, pos + 1)
                }
                None => {
                    let len = chunk.len();
                    if overlong || buf.len() + len > MAX_LINE {
                        dropped += if overlong { len } else { buf.len() + len };
                        overlong = true;
                        buf.clear();
                    } else {
                        buf.extend_from_slice(chunk);
                    }
                    (false, len)
                }
            }
        };
        reader.consume(used);
        if done {
            return if overlong {
                LineRead::Overlong(dropped)
            } else {
                LineRead::Line(String::from_utf8_lossy(&buf).into_owned())
            };
        }
    }
}

/// Parse and serve one request line. Returns the reply, its echoed `id`,
/// the request's declared protocol version (driving the reply shape via
/// [`Response::to_json_v`]), and — for a successful `subscribe` — the
/// event stream the connection must start forwarding.
fn dispatch(
    line: &str,
    shared: &Arc<Shared>,
) -> (Response, Option<f64>, u64, Option<Receiver<Response>>) {
    shared.metrics.inc_requests();
    let t0 = std::time::Instant::now();
    let (req, meta) = match Request::parse_wire(line) {
        Ok(v) => v,
        Err(e) => {
            shared.metrics.inc_errors();
            return (Response::Error(e), None, 1, None);
        }
    };
    let (id, deadline_ms, version) = (meta.id, meta.deadline_ms, meta.version);
    let mut events_rx: Option<Receiver<Response>> = None;
    let is_predict = matches!(req, Request::Predict { .. });
    let is_suggest = matches!(req, Request::Suggest { .. });
    let is_ingest =
        matches!(req, Request::Observe { .. } | Request::ObserveBatch { .. });
    match &req {
        Request::Predict { xs, .. } => shared.metrics.add_predict_points(xs.len()),
        Request::Observe { .. } => shared.metrics.add_observe_points(1),
        Request::ObserveBatch { ys, .. } => shared.metrics.add_observe_points(ys.len()),
        _ => {}
    }
    let mut routed_model: Option<u64> = None;
    let resp = match req {
        Request::CreateModel { d, nu2, omega, sigma2 } => {
            let nu = match Nu::from_two_nu(nu2) {
                Some(nu) => nu,
                None => return (Response::Error(format!("bad nu2 {nu2}")), id, version, None),
            };
            let cfg = EngineConfig {
                d,
                nu,
                omega0: omega,
                sigma2,
                lo: shared.lo,
                hi: shared.hi,
                use_pjrt: shared.use_pjrt,
                seed: 0xC0FE ^ d as u64,
            };
            let idx = shared.scheduler.create_model(cfg);
            Response::ModelCreated { model: idx }
        }
        Request::Shutdown => {
            shared.shutting_down.store(true, Ordering::SeqCst);
            Response::Ok
        }
        Request::Ping => Response::Hello { version: PROTOCOL_VERSION },
        other => {
            let model = match &other {
                Request::Observe { model, .. }
                | Request::ObserveBatch { model, .. }
                | Request::Forget { model, .. }
                | Request::ForgetBatch { model, .. }
                | Request::RollingWindow { model, .. }
                | Request::Fit { model, .. }
                | Request::Predict { model, .. }
                | Request::Suggest { model, .. }
                | Request::Stats { model }
                | Request::Audit { model }
                | Request::Snapshot { model, .. }
                | Request::Subscribe { model } => *model,
                _ => unreachable!(),
            };
            routed_model = Some(model);
            // Queue-depth load shedding: once too many model-routed
            // requests sit between dispatch and reply, refuse at the door
            // with a retry-able error instead of queueing without bound.
            let limit = shared.queue_limit.load(Ordering::SeqCst);
            let inflight = shared.inflight.fetch_add(1, Ordering::SeqCst) + 1;
            if inflight > limit {
                shared.inflight.fetch_sub(1, Ordering::SeqCst);
                shared.metrics.inc_shed_requests();
                shared.metrics.inc_errors();
                return (
                    Response::Error(format!(
                        "retryable: server overloaded ({inflight} requests in flight, \
                         limit {limit})"
                    )),
                    id,
                    version,
                    None,
                );
            }
            let (rtx, rrx) = channel();
            let cmd = match other {
                Request::Observe { x, y, .. } => Command::Observe { x, y, reply: rtx },
                Request::ObserveBatch { xs, ys, .. } => {
                    Command::ObserveBatch { xs, ys, reply: rtx }
                }
                Request::Forget { x, .. } => Command::Forget { x, reply: rtx },
                Request::ForgetBatch { xs, .. } => Command::ForgetBatch { xs, reply: rtx },
                Request::RollingWindow { max_n, max_age, .. } => {
                    Command::RollingWindow { max_n, max_age, reply: rtx }
                }
                Request::Fit { steps, .. } => Command::Fit { steps, reply: rtx },
                Request::Predict { xs, beta, grad, .. } => {
                    Command::Predict { xs, beta, grad, reply: rtx }
                }
                Request::Suggest { beta, .. } => Command::Suggest { beta, reply: rtx },
                Request::Stats { .. } => Command::Stats { reply: rtx },
                Request::Audit { .. } => Command::Audit { reply: rtx },
                Request::Snapshot { have_gen, .. } => {
                    shared.metrics.inc_snapshot_requests();
                    Command::Snapshot { have_gen, reply: rtx }
                }
                Request::Subscribe { .. } => {
                    shared.metrics.inc_subscribe_requests();
                    let (etx, erx) = channel();
                    events_rx = Some(erx);
                    Command::Subscribe { events: etx, reply: rtx }
                }
                _ => unreachable!(),
            };
            shared.scheduler.dispatch(model, cmd);
            let resp = match deadline_ms {
                // Per-request deadline: give up waiting when the budget
                // expires (the late reply is dropped with its sender) and
                // tell the client it may retry.
                Some(ms) => match rrx.recv_timeout(Duration::from_millis(ms)) {
                    Ok(r) => r,
                    Err(RecvTimeoutError::Timeout) => {
                        shared.metrics.inc_deadline_timeouts();
                        Response::Error(format!("retryable: deadline exceeded after {ms}ms"))
                    }
                    Err(RecvTimeoutError::Disconnected) => {
                        Response::Error("engine dropped reply".into())
                    }
                },
                None => match rrx.recv() {
                    Ok(r) => r,
                    Err(_) => Response::Error("engine dropped reply".into()),
                },
            };
            shared.inflight.fetch_sub(1, Ordering::SeqCst);
            resp
        }
    };
    if matches!(resp, Response::Error(_)) {
        shared.metrics.inc_errors();
        // A refused subscribe (dead/unknown model, shed) must not leave the
        // connection half-converted into an event stream.
        events_rx = None;
    }
    match &resp {
        Response::BatchObserved { path, factor_patched, factor_resweep, .. } => {
            shared.metrics.count_batch_path(path);
            shared.metrics.add_factor_outcomes(*factor_patched, *factor_resweep);
        }
        Response::Observed { factor_patched, factor_resweep, .. } => {
            shared.metrics.add_factor_outcomes(*factor_patched, *factor_resweep);
        }
        Response::Forgotten { removed, factor_patched, factor_resweep, .. } => {
            shared.metrics.add_forgotten_points(*removed);
            shared.metrics.add_factor_outcomes(*factor_patched, *factor_resweep);
        }
        Response::Stats {
            memmove_bytes, chunks_copied, chunks_shared, window_evictions, ..
        } => {
            // The reply carries the model's *cumulative* storage counters;
            // the metrics layer folds in only the delta since the model's
            // last report.
            if let Some(m) = routed_model {
                shared.metrics.record_storage_stats(
                    m,
                    *memmove_bytes,
                    *chunks_copied,
                    *chunks_shared,
                );
                shared.metrics.record_window_evictions(m, *window_evictions);
            }
        }
        _ => {}
    }
    // Pool-wide and per-model latency. Per-model histograms only for
    // successfully routed ops — errors (above all "unknown model") must not
    // mint unbounded phantom entries in the per-model map.
    let elapsed = t0.elapsed().as_secs_f64();
    let per_model = match &resp {
        Response::Error(_) => None,
        _ => routed_model.map(|m| shared.metrics.model(m)),
    };
    if is_predict {
        shared.metrics.predict_latency.record(elapsed);
        if let Some(m) = &per_model {
            m.predict_latency.record(elapsed);
        }
    } else if is_suggest {
        shared.metrics.suggest_latency.record(elapsed);
        if let Some(m) = &per_model {
            m.suggest_latency.record(elapsed);
        }
    } else if is_ingest {
        shared.metrics.ingest_latency.record(elapsed);
        if let Some(m) = &per_model {
            m.ingest_latency.record(elapsed);
        }
    }
    (resp, id, version, events_rx)
}

/// Minimal blocking client for tests, examples and benches.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: std::net::SocketAddr) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        let writer = stream.try_clone()?;
        Ok(Client { reader: BufReader::new(stream), writer })
    }

    /// Send one JSON line and read one JSON-line reply.
    pub fn call(&mut self, req: &str) -> Result<crate::util::Json> {
        self.writer.write_all(req.as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        crate::util::Json::parse(&line).map_err(|e| anyhow!("bad reply: {e}"))
    }
}
