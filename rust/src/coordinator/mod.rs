//! The L3 serving layer: BO-as-a-service on a **shared worker pool**
//! (DESIGN.md §Coordinator; quickstart in `coordinator/README.md`).
//!
//! * [`protocol`] — the JSON-line wire protocol (create / observe / fit /
//!   predict / suggest / stats; `stats` carries the `pool_*` fields).
//! * [`engine`] — per-model state (sparse GP + command handlers); pure
//!   `Send` data with no thread of its own.
//! * [`scheduler`] — the work-stealing pool serving *all* models: per-model
//!   FIFO mutual exclusion for mutating commands, concurrent
//!   snapshot-backed reads, dynamic predict batching with PJRT
//!   worker-affinity (executable handles are not `Send`).
//! * [`server`] — TCP accept loop, one reader thread per connection,
//!   requests routed into the scheduler; deterministic shutdown joins
//!   every reader and every pool worker.
//! * [`metrics`] — pool-wide and per-model latency histograms + counters.
//!
//! The offline image has no tokio/rayon, so concurrency is std threads,
//! mutexes and mpsc — the architecture (registry → per-model queues →
//! shared pool → batch → fan out) is the same one an async version would
//! use.

pub mod engine;
pub mod metrics;
pub mod protocol;
pub mod scheduler;
pub mod server;

pub use engine::{Command, EngineConfig, ModelEngine};
pub use protocol::{Request, Response};
pub use scheduler::Scheduler;
pub use server::{Server, ShutdownStats};
