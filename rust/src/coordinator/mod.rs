//! The L3 serving layer: a threaded BO-as-a-service coordinator.
//!
//! * [`protocol`] — the JSON-line wire protocol (create / observe / fit /
//!   predict / suggest / stats).
//! * [`engine`] — one worker thread per model, owning the sparse GP and the
//!   compiled PJRT `window_acq` executable; drains its queue as dynamic
//!   batches and fans results back out.
//! * [`server`] — TCP accept loop, one reader thread per connection,
//!   model registry routing requests to engine queues.
//!
//! The offline image has no tokio, so concurrency is std threads + mpsc —
//! the batching architecture (queue → drain ≤ B → PJRT execute → fan out)
//! is the same one a tokio version would use.

pub mod engine;
pub mod metrics;
pub mod protocol;
pub mod server;

pub use engine::{EngineConfig, ModelEngine};
pub use protocol::{Request, Response};
pub use server::Server;
