//! The L3 serving layer: BO-as-a-service on a **shared worker pool**
//! (DESIGN.md §Coordinator; quickstart in `coordinator/README.md`).
//!
//! * [`protocol`] — the JSON-line wire protocol (create / observe / fit /
//!   predict / suggest / stats; `stats` carries the `pool_*` fields).
//! * [`engine`] — per-model state (sparse GP + command handlers); pure
//!   `Send` data with no thread of its own.
//! * [`scheduler`] — the work-stealing pool serving *all* models: per-model
//!   FIFO mutual exclusion for mutating commands, concurrent
//!   snapshot-backed reads, dynamic predict batching with PJRT
//!   worker-affinity (executable handles are not `Send`).
//! * [`server`] — TCP accept loop, one reader thread per connection,
//!   requests routed into the scheduler; deterministic shutdown joins
//!   every reader and every pool worker.
//! * [`client`] — the typed protocol v3 client: builder-style connect with
//!   a versioned hello, typed `predict`/`observe`/`suggest`/`stats`
//!   methods returning `Result<T, ProtocolError>`. The one sanctioned
//!   place (besides [`protocol`] itself) that constructs wire JSON.
//! * [`replica`] — stateless read replica: imports generation-numbered
//!   posterior snapshots from a writer and serves `predict`/`suggest` at
//!   any fan-out (DESIGN.md §Replication).
//! * [`metrics`] — pool-wide and per-model latency histograms + counters.
//! * [`journal`] — per-model durable mutation log + checkpoint compaction;
//!   `Scheduler::recover` rebuilds a bit-identical engine fleet from it
//!   after a crash (DESIGN.md §Durability).
//!
//! The offline image has no tokio/rayon, so concurrency is std threads,
//! mutexes and mpsc — the architecture (registry → per-model queues →
//! shared pool → batch → fan out) is the same one an async version would
//! use.

pub mod client;
pub mod engine;
pub mod journal;
pub mod metrics;
pub mod protocol;
pub mod replica;
pub mod scheduler;
pub mod server;

pub use client::{Client, ProtocolError, Subscription};
pub use engine::{Command, EngineConfig, ModelEngine};
pub use journal::{FsyncPolicy, JournalConfig, MutationOp};
pub use protocol::{Request, Response};
pub use replica::{Replica, ReplicaConfig, ReplicaStats};
pub use scheduler::{RecoveryReport, Scheduler};
pub use server::{Server, ShutdownStats};

/// Lock a mutex, recovering the guard from a poisoned lock. The serving
/// layer's shared maps and queues stay structurally valid across a payload
/// panic (each command body is wrapped in `catch_unwind`, and panicked
/// models are quarantined via their `dead` flag), so the right response to
/// poison here is to keep serving — not to propagate the panic with
/// `unwrap()`, which `cargo xtask lint` bans in `coordinator/`.
pub(crate) fn lock_clean<T>(m: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}
