//! Per-model durable mutation journal (DESIGN.md §Durability).
//!
//! Every *successful* v2 mutating command is appended, after it applied and
//! before its reply is sent, as one checksummed record:
//!
//! ```text
//! [u32 LE len][u32 LE crc32(payload)][payload]
//! payload = [u8 record type][u64 generation][body]
//! ```
//!
//! Record type 1 carries the model's [`EngineConfig`] (written once, at
//! generation 0, when the model is created); type 2 carries a
//! [`MutationOp`]. Journaling *after* the apply is the crash-loop guard: a
//! command that panics the engine is never written, so replay can never
//! re-panic on it. The price is one-command amnesia — a crash between
//! apply and append loses that mutation, which is exactly the durability
//! point a client learns from the missing reply.
//!
//! Periodically ([`JournalConfig::checkpoint_every`]) the journal is
//! *compacted*: the engine's bit-exact state
//! ([`ModelEngine::encode_state`]) is written to `model-<id>.ckpt` via
//! temp-file + fsync + rename, then the journal is truncated. Recovery
//! ([`recover_model`]) decodes the checkpoint (if present), replays the
//! journal tail — skipping records at or below the checkpoint generation,
//! which makes a crash *between* the rename and the truncate harmless —
//! and stops cleanly at the first torn or corrupt record, repairing the
//! file back to its valid prefix.
//!
//! Bit-identity argument: the engine is a deterministic function of its
//! mutation history (rolling-window evictions included — they depend only
//! on state and the logical ingest clock, never wall time), the checkpoint
//! is bit-exact, and replay routes through the same [`apply_op`] used by
//! live dispatch. So checkpoint + tail replay lands on an engine whose
//! every future output is bit-identical to the uninterrupted run — the
//! property `tests/chaos.rs` asserts per seed.

use std::fs::{self, File, OpenOptions};
use std::io::{self, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use crate::coordinator::engine::{EngineConfig, ModelEngine};
use crate::coordinator::protocol::Response;
use crate::util::codec::{crc32, ByteReader, ByteWriter};
use crate::util::fault::FaultAction;

/// Record carrying the model's [`EngineConfig`] (generation 0).
const REC_CONFIG: u8 = 1;
/// Record carrying one applied [`MutationOp`].
const REC_OP: u8 = 2;
/// Sanity bound on a single record: op payloads are bounded by the server's
/// line limit, so anything bigger is framing corruption, not data.
const MAX_OP_RECORD: u32 = 64 << 20;
/// Checkpoints hold a full serialized model; bound them far looser.
const MAX_CKPT_RECORD: u32 = 1 << 31;

/// A v2 mutating command, shorn of its reply channel — the journal's unit
/// of durability and replay's unit of work.
#[derive(Clone, Debug, PartialEq)]
pub enum MutationOp {
    Observe { x: Vec<f64>, y: f64 },
    ObserveBatch { xs: Vec<Vec<f64>>, ys: Vec<f64> },
    Forget { x: Vec<f64> },
    ForgetBatch { xs: Vec<Vec<f64>> },
    RollingWindow { max_n: usize, max_age: Option<u64> },
    Fit { steps: usize },
}

/// Apply one mutation to an engine — the single entry point shared by live
/// dispatch ([`crate::coordinator::scheduler`]) and journal replay, so the
/// two cannot drift. The `engine.mutate` fault point fires *before* the
/// handler: an injected panic leaves the engine untouched, modeling a
/// command that dies mid-dispatch.
pub fn apply_op(eng: &mut ModelEngine, op: &MutationOp) -> Response {
    if let Some(act) = crate::util::fault::point!("engine.mutate") {
        if act == FaultAction::Panic {
            panic!("injected fault: engine.mutate");
        }
    }
    match op {
        MutationOp::Observe { x, y } => eng.observe(x, *y),
        MutationOp::ObserveBatch { xs, ys } => eng.observe_batch(xs, ys),
        MutationOp::Forget { x } => eng.forget(x),
        MutationOp::ForgetBatch { xs } => eng.forget_batch(xs),
        MutationOp::RollingWindow { max_n, max_age } => eng.rolling_window(*max_n, *max_age),
        MutationOp::Fit { steps } => eng.fit(*steps),
    }
}

fn encode_op(op: &MutationOp, w: &mut ByteWriter) {
    match op {
        MutationOp::Observe { x, y } => {
            w.put_u8(1);
            w.put_f64s(x);
            w.put_f64(*y);
        }
        MutationOp::ObserveBatch { xs, ys } => {
            w.put_u8(2);
            w.put_usize(xs.len());
            for x in xs {
                w.put_f64s(x);
            }
            w.put_f64s(ys);
        }
        MutationOp::Forget { x } => {
            w.put_u8(3);
            w.put_f64s(x);
        }
        MutationOp::ForgetBatch { xs } => {
            w.put_u8(4);
            w.put_usize(xs.len());
            for x in xs {
                w.put_f64s(x);
            }
        }
        MutationOp::RollingWindow { max_n, max_age } => {
            w.put_u8(5);
            w.put_usize(*max_n);
            match max_age {
                Some(a) => {
                    w.put_bool(true);
                    w.put_u64(*a);
                }
                None => w.put_bool(false),
            }
        }
        MutationOp::Fit { steps } => {
            w.put_u8(6);
            w.put_usize(*steps);
        }
    }
}

fn decode_op(r: &mut ByteReader<'_>) -> Result<MutationOp, String> {
    match r.get_u8("op tag")? {
        1 => Ok(MutationOp::Observe { x: r.get_f64s("observe x")?, y: r.get_f64("observe y")? }),
        2 => {
            let m = r.get_usize("batch len")?;
            if m > r.remaining() / 8 {
                return Err(format!("claimed batch of {m} rows exceeds record bytes"));
            }
            let mut xs = Vec::with_capacity(m);
            for _ in 0..m {
                xs.push(r.get_f64s("batch x")?);
            }
            Ok(MutationOp::ObserveBatch { xs, ys: r.get_f64s("batch ys")? })
        }
        3 => Ok(MutationOp::Forget { x: r.get_f64s("forget x")? }),
        4 => {
            let m = r.get_usize("forget batch len")?;
            if m > r.remaining() / 8 {
                return Err(format!("claimed batch of {m} rows exceeds record bytes"));
            }
            let mut xs = Vec::with_capacity(m);
            for _ in 0..m {
                xs.push(r.get_f64s("forget batch x")?);
            }
            Ok(MutationOp::ForgetBatch { xs })
        }
        5 => {
            let max_n = r.get_usize("rolling max_n")?;
            let max_age = if r.get_bool("rolling max_age present")? {
                Some(r.get_u64("rolling max_age")?)
            } else {
                None
            };
            Ok(MutationOp::RollingWindow { max_n, max_age })
        }
        6 => Ok(MutationOp::Fit { steps: r.get_usize("fit steps")? }),
        t => Err(format!("unknown mutation op tag {t}")),
    }
}

/// When appended records reach the disk.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FsyncPolicy {
    /// `fsync` after every record: no acknowledged mutation is ever lost,
    /// at the cost of one disk sync per op.
    EveryOp,
    /// `fsync` after every k-th record (and at every checkpoint): bounds
    /// loss to the last < k acknowledged mutations.
    EveryK(u32),
    /// Never `fsync` the tail (checkpoints still sync): crash durability
    /// degrades to whatever the page cache flushed.
    Off,
}

/// Scheduler-level journal configuration (one directory for all models).
#[derive(Clone, Debug)]
pub struct JournalConfig {
    pub dir: PathBuf,
    pub fsync: FsyncPolicy,
    /// Compact after this many appended ops (0 disables checkpointing).
    pub checkpoint_every: u64,
}

impl JournalConfig {
    pub fn new(dir: impl Into<PathBuf>) -> Self {
        JournalConfig { dir: dir.into(), fsync: FsyncPolicy::EveryK(64), checkpoint_every: 1024 }
    }
}

fn journal_path(dir: &Path, id: u64) -> PathBuf {
    dir.join(format!("model-{id}.journal"))
}

fn ckpt_path(dir: &Path, id: u64) -> PathBuf {
    dir.join(format!("model-{id}.ckpt"))
}

/// `[len][crc][payload]` framing for one record.
fn frame(payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 + payload.len());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&crc32(payload).to_le_bytes());
    out.extend_from_slice(payload);
    out
}

/// The append half: one open journal file per live model.
pub struct ModelJournal {
    file: File,
    ckpt: PathBuf,
    fsync: FsyncPolicy,
    checkpoint_every: u64,
    /// Records appended since the last sync (EveryK accounting).
    unsynced: u32,
    /// Ops appended since the last checkpoint.
    ops_since_ckpt: u64,
    /// Lifetime observability counters (surfaced through `Stats`).
    pub appends: u64,
    pub syncs: u64,
    pub checkpoints: u64,
    pub bytes: u64,
}

impl ModelJournal {
    /// Start a fresh journal for a newly created model: truncates any stale
    /// files left by a previous process using the same id, then writes the
    /// durable config record at generation 0.
    pub fn create(jcfg: &JournalConfig, id: u64, cfg: &EngineConfig) -> io::Result<ModelJournal> {
        fs::create_dir_all(&jcfg.dir)?;
        let file = OpenOptions::new()
            .create(true)
            .write(true)
            .truncate(true)
            .open(journal_path(&jcfg.dir, id))?;
        let ckpt = ckpt_path(&jcfg.dir, id);
        match fs::remove_file(&ckpt) {
            Ok(()) => {}
            Err(e) if e.kind() == io::ErrorKind::NotFound => {}
            Err(e) => return Err(e),
        }
        let mut j = ModelJournal {
            file,
            ckpt,
            fsync: jcfg.fsync,
            checkpoint_every: jcfg.checkpoint_every,
            unsynced: 0,
            ops_since_ckpt: 0,
            appends: 0,
            syncs: 0,
            checkpoints: 0,
            bytes: 0,
        };
        let mut w = ByteWriter::new();
        w.put_u8(REC_CONFIG);
        w.put_u64(0);
        cfg.encode(&mut w);
        j.write_record(&w.into_bytes())?;
        j.sync_now()?; // the config record is always durable
        Ok(j)
    }

    /// Reattach to a recovered model's journal (after [`recover_model`]
    /// repaired it to its valid prefix), positioned to append.
    pub fn open_recovered(
        jcfg: &JournalConfig,
        id: u64,
        ops_in_tail: u64,
    ) -> io::Result<ModelJournal> {
        let mut file =
            OpenOptions::new().create(true).write(true).open(journal_path(&jcfg.dir, id))?;
        file.seek(SeekFrom::End(0))?;
        Ok(ModelJournal {
            file,
            ckpt: ckpt_path(&jcfg.dir, id),
            fsync: jcfg.fsync,
            checkpoint_every: jcfg.checkpoint_every,
            unsynced: 0,
            ops_since_ckpt: ops_in_tail,
            appends: 0,
            syncs: 0,
            checkpoints: 0,
            bytes: 0,
        })
    }

    fn write_record(&mut self, payload: &[u8]) -> io::Result<()> {
        let framed = frame(payload);
        if let Some(act) = crate::util::fault::point!("journal.append") {
            match act {
                FaultAction::TornWrite(k) => {
                    // Model a crash mid-write: a prefix of the frame lands
                    // on disk, then the write "fails".
                    let cut = k.min(framed.len().saturating_sub(1)).max(1);
                    self.file.write_all(&framed[..cut])?;
                    let _ = self.file.sync_data();
                    return Err(io::Error::new(
                        io::ErrorKind::Other,
                        "injected fault: torn journal append",
                    ));
                }
                FaultAction::Panic => panic!("injected fault: journal.append"),
                FaultAction::IoError | FaultAction::ForceFail => {
                    return Err(io::Error::new(
                        io::ErrorKind::Other,
                        "injected fault: journal.append",
                    ));
                }
            }
        }
        self.file.write_all(&framed)?;
        self.bytes += framed.len() as u64;
        Ok(())
    }

    fn sync_now(&mut self) -> io::Result<()> {
        if let Some(act) = crate::util::fault::point!("journal.fsync") {
            if act == FaultAction::Panic {
                panic!("injected fault: journal.fsync");
            }
            return Err(io::Error::new(io::ErrorKind::Other, "injected fault: journal.fsync"));
        }
        self.file.sync_data()?;
        self.unsynced = 0;
        self.syncs += 1;
        Ok(())
    }

    fn maybe_sync(&mut self) -> io::Result<()> {
        match self.fsync {
            FsyncPolicy::Off => Ok(()),
            FsyncPolicy::EveryOp => self.sync_now(),
            FsyncPolicy::EveryK(k) => {
                self.unsynced += 1;
                if self.unsynced >= k.max(1) {
                    self.sync_now()
                } else {
                    Ok(())
                }
            }
        }
    }

    /// Append one applied op at its post-apply generation.
    pub fn append_op(&mut self, gen: u64, op: &MutationOp) -> io::Result<()> {
        let mut w = ByteWriter::new();
        w.put_u8(REC_OP);
        w.put_u64(gen);
        encode_op(op, &mut w);
        self.write_record(&w.into_bytes())?;
        self.appends += 1;
        self.ops_since_ckpt += 1;
        self.maybe_sync()
    }

    /// Whether the compaction threshold has been reached.
    pub fn due_for_checkpoint(&self) -> bool {
        self.checkpoint_every > 0 && self.ops_since_ckpt >= self.checkpoint_every
    }

    /// Compact: write the serialized engine to `model-<id>.ckpt` via
    /// temp + fsync + rename (the rename is the commit point), then
    /// truncate the journal. A crash between the two leaves op records at
    /// or below the checkpoint generation in the journal; recovery skips
    /// them by generation.
    pub fn write_checkpoint(&mut self, gen: u64, state: &[u8]) -> io::Result<()> {
        if let Some(act) = crate::util::fault::point!("journal.checkpoint") {
            if act == FaultAction::Panic {
                panic!("injected fault: journal.checkpoint");
            }
            return Err(io::Error::new(io::ErrorKind::Other, "injected fault: journal.checkpoint"));
        }
        let mut payload = Vec::with_capacity(8 + state.len());
        payload.extend_from_slice(&gen.to_le_bytes());
        payload.extend_from_slice(state);
        let framed = frame(&payload);
        let tmp = self.ckpt.with_extension("ckpt.tmp");
        {
            let mut f = File::create(&tmp)?;
            f.write_all(&framed)?;
            f.sync_data()?;
        }
        fs::rename(&tmp, &self.ckpt)?;
        self.file.set_len(0)?;
        self.file.seek(SeekFrom::Start(0))?;
        self.file.sync_data()?;
        self.ops_since_ckpt = 0;
        self.unsynced = 0;
        self.checkpoints += 1;
        self.bytes += framed.len() as u64;
        Ok(())
    }
}

/// What one frame-parse step found.
enum Frame<'a> {
    /// Clean end of file exactly at the offset.
    Eof,
    /// A valid record: payload + offset of the next frame.
    Ok(&'a [u8], usize),
    /// Structurally complete frame whose checksum mismatches; skippable.
    BadCrc(usize),
    /// Torn tail: not enough bytes for the claimed (or any) frame.
    Torn,
}

fn parse_frame(data: &[u8], off: usize, max_len: u32) -> Frame<'_> {
    if off == data.len() {
        return Frame::Eof;
    }
    if data.len() - off < 8 {
        return Frame::Torn;
    }
    let mut b4 = [0u8; 4];
    b4.copy_from_slice(&data[off..off + 4]);
    let len = u32::from_le_bytes(b4) as usize;
    if len as u64 > max_len as u64 || data.len() - off - 8 < len {
        return Frame::Torn;
    }
    b4.copy_from_slice(&data[off + 4..off + 8]);
    let want = u32::from_le_bytes(b4);
    let payload = &data[off + 8..off + 8 + len];
    let next = off + 8 + len;
    if crc32(payload) != want {
        return Frame::BadCrc(next);
    }
    Frame::Ok(payload, next)
}

/// Replay one valid journal record onto the engine under reconstruction.
/// Config records only seed an engine when no checkpoint did; op records at
/// or below the current generation are checkpoint-covered and skipped, and
/// a generation gap is corruption (the chain past it cannot be trusted).
fn replay_record(
    payload: &[u8],
    engine: &mut Option<ModelEngine>,
    gen: &mut u64,
    replayed: &mut u64,
) -> Result<(), String> {
    let mut r = ByteReader::new(payload);
    match r.get_u8("record type")? {
        REC_CONFIG => {
            let g = r.get_u64("record gen")?;
            let cfg = EngineConfig::decode(&mut r)?;
            if engine.is_none() {
                *engine = Some(ModelEngine::new(cfg));
                *gen = g;
            }
            Ok(())
        }
        REC_OP => {
            let g = r.get_u64("record gen")?;
            let op = decode_op(&mut r)?;
            if g <= *gen {
                return Ok(()); // already inside the checkpoint
            }
            if g != *gen + 1 {
                return Err(format!("generation gap: {g} after {gen}"));
            }
            let Some(eng) = engine.as_mut() else {
                return Err("op record before any config/checkpoint".into());
            };
            apply_op(eng, &op);
            *gen = g;
            *replayed += 1;
            Ok(())
        }
        t => Err(format!("unknown record type {t}")),
    }
}

/// One model rebuilt from its checkpoint + journal tail.
pub struct RecoveredModel {
    pub engine: ModelEngine,
    /// Post-replay generation (the scheduler seeds the cell's gen with it).
    pub gen: u64,
    /// Op records re-applied from the journal tail.
    pub replayed_ops: u64,
    /// Records dropped at the torn/corrupt tail (0 on a clean journal).
    pub dropped_records: u64,
    /// Bytes discarded with them (the file is repaired to its valid prefix).
    pub dropped_bytes: u64,
}

/// Rebuild one model from disk. Never panics: torn tails stop the replay at
/// the last valid record (and repair the file), while a corrupt *checkpoint*
/// is unrecoverable for that model and returns `Err`.
pub fn recover_model(jcfg: &JournalConfig, id: u64) -> Result<RecoveredModel, String> {
    let jp = journal_path(&jcfg.dir, id);
    let cp = ckpt_path(&jcfg.dir, id);
    let mut engine: Option<ModelEngine> = None;
    let mut gen = 0u64;
    match fs::read(&cp) {
        Ok(bytes) => match parse_frame(&bytes, 0, MAX_CKPT_RECORD) {
            Frame::Ok(payload, _) => {
                if payload.len() < 8 {
                    return Err(format!("model {id}: checkpoint payload too short"));
                }
                let mut b8 = [0u8; 8];
                b8.copy_from_slice(&payload[..8]);
                gen = u64::from_le_bytes(b8);
                let eng = ModelEngine::decode_state(&payload[8..])
                    .map_err(|e| format!("model {id}: checkpoint: {e}"))?;
                engine = Some(eng);
            }
            Frame::Eof => return Err(format!("model {id}: empty checkpoint file")),
            Frame::BadCrc(_) | Frame::Torn => {
                return Err(format!("model {id}: checkpoint fails its checksum"));
            }
        },
        Err(e) if e.kind() == io::ErrorKind::NotFound => {}
        Err(e) => return Err(format!("model {id}: reading checkpoint: {e}")),
    }
    let data = match fs::read(&jp) {
        Ok(d) => d,
        Err(e) if e.kind() == io::ErrorKind::NotFound && engine.is_some() => Vec::new(),
        Err(e) => return Err(format!("model {id}: reading journal: {e}")),
    };
    let mut off = 0usize;
    let mut valid_end = 0usize;
    let mut replayed = 0u64;
    let mut corrupt = false;
    while !corrupt {
        match parse_frame(&data, off, MAX_OP_RECORD) {
            Frame::Eof => break,
            Frame::Torn | Frame::BadCrc(_) => corrupt = true,
            Frame::Ok(payload, next) => {
                match replay_record(payload, &mut engine, &mut gen, &mut replayed) {
                    Ok(()) => {
                        off = next;
                        valid_end = next;
                    }
                    Err(_) => corrupt = true,
                }
            }
        }
    }
    // Count what the corruption cost: the record we stopped on, plus any
    // structurally complete frames stranded behind it (their contents can
    // no longer be applied — the generation chain is broken).
    let mut dropped_records = 0u64;
    if corrupt {
        let mut o = valid_end;
        loop {
            match parse_frame(&data, o, MAX_OP_RECORD) {
                Frame::Eof => break,
                Frame::Torn => {
                    dropped_records += 1;
                    break;
                }
                Frame::Ok(_, next) | Frame::BadCrc(next) => {
                    dropped_records += 1;
                    o = next;
                }
            }
        }
        dropped_records = dropped_records.max(1);
    }
    let dropped_bytes = (data.len() - valid_end) as u64;
    if corrupt && dropped_bytes > 0 {
        // Repair: truncate back to the valid prefix so future appends are
        // framed cleanly.
        let repaired = OpenOptions::new()
            .write(true)
            .open(&jp)
            .and_then(|f| f.set_len(valid_end as u64));
        if let Err(e) = repaired {
            return Err(format!("model {id}: repairing torn journal: {e}"));
        }
    }
    let Some(engine) = engine else {
        return Err(format!("model {id}: no checkpoint and no config record — nothing to rebuild"));
    };
    Ok(RecoveredModel { engine, gen, replayed_ops: replayed, dropped_records, dropped_bytes })
}

/// Model ids present in a journal directory (sorted; union of `.journal`
/// and `.ckpt` files).
pub fn list_model_ids(dir: &Path) -> Vec<u64> {
    let mut ids = std::collections::BTreeSet::new();
    if let Ok(rd) = fs::read_dir(dir) {
        for e in rd.flatten() {
            let name = e.file_name();
            let name = name.to_string_lossy();
            let Some(rest) = name.strip_prefix("model-") else { continue };
            let stem = rest.strip_suffix(".journal").or_else(|| rest.strip_suffix(".ckpt"));
            if let Some(stem) = stem {
                if let Ok(v) = stem.parse::<u64>() {
                    ids.insert(v);
                }
            }
        }
    }
    ids.into_iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "addgp-journal-{tag}-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).expect("create temp dir");
        dir
    }

    fn test_cfg(d: usize) -> EngineConfig {
        EngineConfig { d, use_pjrt: false, lo: 0.0, hi: 4.0, seed: 11, ..Default::default() }
    }

    fn ops_script(n: usize, d: usize, seed: u64) -> Vec<MutationOp> {
        let mut rng = Rng::new(seed);
        let mut ops = Vec::new();
        let xs: Vec<Vec<f64>> =
            (0..20).map(|_| (0..d).map(|_| rng.uniform_in(0.0, 4.0)).collect()).collect();
        let ys: Vec<f64> = xs.iter().map(|x| x[0].sin() + x[1].cos()).collect();
        ops.push(MutationOp::ObserveBatch { xs, ys });
        for _ in 0..n {
            let x: Vec<f64> = (0..d).map(|_| rng.uniform_in(0.0, 4.0)).collect();
            let y = x[0].sin() + x[1].cos();
            ops.push(MutationOp::Observe { x, y });
        }
        ops
    }

    /// Write a journal through the real append path, then recover and
    /// compare engines bit-for-bit.
    #[test]
    fn journal_roundtrip_is_bit_identical() {
        let dir = tmp_dir("roundtrip");
        let jcfg = JournalConfig::new(&dir);
        let cfg = test_cfg(2);
        let mut eng = ModelEngine::new(cfg.clone());
        let mut j = ModelJournal::create(&jcfg, 1, &cfg).expect("create");
        let mut gen = 0u64;
        for op in ops_script(12, 2, 3) {
            let resp = apply_op(&mut eng, &op);
            assert!(!matches!(resp, Response::Error(_)), "{resp:?}");
            gen += 1;
            j.append_op(gen, &op).expect("append");
        }
        let rec = recover_model(&jcfg, 1).expect("recover");
        assert_eq!(rec.gen, gen);
        assert_eq!(rec.replayed_ops, gen);
        assert_eq!((rec.dropped_records, rec.dropped_bytes), (0, 0));
        assert_eq!(rec.engine.encode_state(), eng.encode_state(), "bitwise state");
        let _ = fs::remove_dir_all(&dir);
    }

    /// Real compaction: `write_checkpoint` truncates the journal, and
    /// recovery rebuilds from the checkpoint plus whatever appended after.
    #[test]
    fn checkpoint_compacts_and_recovery_replays_the_tail() {
        let dir = tmp_dir("ckpt");
        let jcfg = JournalConfig::new(&dir);
        let cfg = test_cfg(2);
        let mut eng = ModelEngine::new(cfg.clone());
        let mut j = ModelJournal::create(&jcfg, 4, &cfg).expect("create");
        let mut gen = 0u64;
        let ops = ops_script(10, 2, 7);
        for (i, op) in ops.iter().enumerate() {
            apply_op(&mut eng, op);
            gen += 1;
            j.append_op(gen, op).expect("append");
            if i == 6 {
                j.write_checkpoint(gen, &eng.encode_state()).expect("ckpt");
                let jsize = fs::metadata(journal_path(&dir, 4)).expect("meta").len();
                assert_eq!(jsize, 0, "compaction truncates the journal");
            }
        }
        assert_eq!(j.checkpoints, 1);
        let rec = recover_model(&jcfg, 4).expect("recover");
        assert_eq!(rec.gen, gen);
        assert_eq!(rec.replayed_ops, gen - 7, "only the post-checkpoint tail replays");
        assert_eq!(rec.engine.encode_state(), eng.encode_state(), "bitwise state");
        let _ = fs::remove_dir_all(&dir);
    }

    /// The crash window between the checkpoint rename and the journal
    /// truncate: records at or below the checkpoint generation linger in
    /// the journal and must be skipped, not double-applied.
    #[test]
    fn checkpoint_rename_crash_window_skips_covered_ops() {
        let dir = tmp_dir("ckptwin");
        let jcfg = JournalConfig::new(&dir);
        let cfg = test_cfg(2);
        let mut eng = ModelEngine::new(cfg.clone());
        let mut j = ModelJournal::create(&jcfg, 5, &cfg).expect("create");
        let mut gen = 0u64;
        let ops = ops_script(9, 2, 13);
        for (i, op) in ops.iter().enumerate() {
            apply_op(&mut eng, op);
            gen += 1;
            j.append_op(gen, op).expect("append");
            if i == 4 {
                // Write the checkpoint file by hand WITHOUT truncating the
                // journal — exactly the state a crash between rename and
                // truncate leaves behind.
                let mut payload = Vec::new();
                payload.extend_from_slice(&gen.to_le_bytes());
                payload.extend_from_slice(&eng.encode_state());
                fs::write(ckpt_path(&dir, 5), frame(&payload)).expect("raw ckpt");
            }
        }
        let rec = recover_model(&jcfg, 5).expect("recover");
        assert_eq!(rec.gen, gen);
        assert_eq!(rec.replayed_ops, gen - 5, "covered ops are skipped by generation");
        assert_eq!(rec.engine.encode_state(), eng.encode_state(), "bitwise state");
        let _ = fs::remove_dir_all(&dir);
    }

    /// Truncating the journal at *every* byte offset recovers the longest
    /// valid prefix — never panics, reports the torn tail.
    #[test]
    fn torn_tails_recover_prefix_at_every_cut() {
        let dir = tmp_dir("torn");
        let jcfg = JournalConfig::new(&dir);
        let cfg = test_cfg(2);
        let mut eng = ModelEngine::new(cfg.clone());
        let mut j = ModelJournal::create(&jcfg, 9, &cfg).expect("create");
        let mut gen = 0u64;
        for op in ops_script(6, 2, 5) {
            apply_op(&mut eng, &op);
            gen += 1;
            j.append_op(gen, &op).expect("append");
        }
        let jp = journal_path(&dir, 9);
        let full = fs::read(&jp).expect("read journal");
        // Cut only past the config record — a journal torn inside its very
        // first record legitimately has nothing to rebuild from.
        let mut b4 = [0u8; 4];
        b4.copy_from_slice(&full[..4]);
        let first = 8 + u32::from_le_bytes(b4) as usize;
        let mut rng = Rng::new(41);
        for _ in 0..25 {
            let cut = (rng.uniform_in(first as f64, full.len() as f64 - 1.0)) as usize;
            fs::write(&jp, &full[..cut]).expect("truncate");
            let rec = recover_model(&jcfg, 9).expect("torn tail must still recover");
            assert!(rec.gen <= gen);
            if cut < full.len() {
                // Unless the cut landed exactly on a frame boundary, the
                // tail is reported.
                assert!(rec.replayed_ops <= gen);
            }
            // Repair happened: a second recovery sees a clean journal.
            let again = recover_model(&jcfg, 9).expect("recover repaired");
            assert_eq!(again.gen, rec.gen);
            assert_eq!((again.dropped_records, again.dropped_bytes), (0, 0));
            assert_eq!(again.engine.encode_state(), rec.engine.encode_state());
        }
        let _ = fs::remove_dir_all(&dir);
    }

    /// A flipped bit anywhere in the body stops replay at the last valid
    /// record with `dropped_records ≥ 1`; flips in the still-valid prefix
    /// replay that prefix only.
    #[test]
    fn bit_flips_are_detected_and_reported() {
        let dir = tmp_dir("flip");
        let jcfg = JournalConfig::new(&dir);
        let cfg = test_cfg(2);
        let mut eng = ModelEngine::new(cfg.clone());
        let mut j = ModelJournal::create(&jcfg, 2, &cfg).expect("create");
        let mut gen = 0u64;
        for op in ops_script(6, 2, 9) {
            apply_op(&mut eng, &op);
            gen += 1;
            j.append_op(gen, &op).expect("append");
        }
        let jp = journal_path(&dir, 2);
        let full = fs::read(&jp).expect("read journal");
        let mut rng = Rng::new(53);
        for _ in 0..25 {
            let pos = (rng.uniform_in(0.0, full.len() as f64)) as usize % full.len();
            let bit = (rng.uniform_in(0.0, 8.0)) as u32 % 8;
            let mut bad = full.clone();
            bad[pos] ^= 1 << bit;
            fs::write(&jp, &bad).expect("write corrupted");
            match recover_model(&jcfg, 2) {
                Ok(rec) => {
                    assert!(rec.dropped_records >= 1, "flip at byte {pos} bit {bit} undetected");
                    assert!(rec.gen < gen || rec.dropped_bytes > 0);
                }
                // A flip inside the config record leaves nothing to rebuild
                // from — a structured error, never a panic.
                Err(e) => assert!(e.contains("nothing to rebuild"), "{e}"),
            }
        }
        let _ = fs::remove_dir_all(&dir);
    }

    /// Ops and the config record survive an encode/decode roundtrip.
    #[test]
    fn op_codec_roundtrips() {
        let ops = vec![
            MutationOp::Observe { x: vec![1.5, -0.25], y: 3.75 },
            MutationOp::ObserveBatch {
                xs: vec![vec![1.0, 2.0], vec![3.0, 4.0]],
                ys: vec![0.5, -0.5],
            },
            MutationOp::Forget { x: vec![1.0, 2.0] },
            MutationOp::ForgetBatch { xs: vec![vec![0.0, 0.0]] },
            MutationOp::RollingWindow { max_n: 30, max_age: Some(100) },
            MutationOp::RollingWindow { max_n: 0, max_age: None },
            MutationOp::Fit { steps: 5 },
        ];
        for op in &ops {
            let mut w = ByteWriter::new();
            encode_op(op, &mut w);
            let bytes = w.into_bytes();
            let mut r = ByteReader::new(&bytes);
            let back = decode_op(&mut r).expect("decode");
            assert!(r.is_done());
            assert_eq!(&back, op);
        }
    }

    #[test]
    fn list_model_ids_unions_journals_and_ckpts() {
        let dir = tmp_dir("list");
        fs::write(dir.join("model-3.journal"), b"").expect("w");
        fs::write(dir.join("model-7.ckpt"), b"").expect("w");
        fs::write(dir.join("model-3.ckpt"), b"").expect("w");
        fs::write(dir.join("not-a-model.txt"), b"").expect("w");
        fs::write(dir.join("model-x.journal"), b"").expect("w");
        assert_eq!(list_model_ids(&dir), vec![3, 7]);
        let _ = fs::remove_dir_all(&dir);
    }
}
