//! Per-model worker: owns the sparse [`AdditiveGP`] and (when an artifact
//! matches) the compiled PJRT `window_acq` executable. Requests arrive on an
//! mpsc queue; `Predict` requests are *dynamically batched* — the worker
//! drains whatever is queued (up to the artifact batch size), gathers
//! windows in rust (`O(log n)` per query), runs one PJRT execution, and
//! fans the rows back out to their callers.

use std::sync::mpsc::{Receiver, Sender};

use crate::bo::acquisition::Acquisition;
use crate::bo::search::{search_next, SearchCfg};
use crate::coordinator::protocol::Response;
use crate::gp::model::{AdditiveGP, AdditiveGpConfig};
use crate::gp::train::TrainCfg;
use crate::kernels::matern::Nu;
use crate::runtime::xla;
use crate::runtime::{ArtifactManifest, WindowBatch, WindowExecutable};
use crate::util::Rng;

/// Engine construction options.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub d: usize,
    pub nu: Nu,
    pub omega0: f64,
    pub sigma2: f64,
    /// Box bounds used by `suggest`.
    pub lo: f64,
    pub hi: f64,
    /// Try to load a matching PJRT artifact (otherwise native-only).
    pub use_pjrt: bool,
    pub seed: u64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            d: 2,
            nu: Nu::Half,
            omega0: 1.0,
            sigma2: 1.0,
            lo: -500.0,
            hi: 500.0,
            use_pjrt: true,
            seed: 7,
        }
    }
}

/// A command sent to the worker. `reply` receives exactly one [`Response`].
pub enum Command {
    Observe { x: Vec<f64>, y: f64, reply: Sender<Response> },
    ObserveBatch { xs: Vec<Vec<f64>>, ys: Vec<f64>, reply: Sender<Response> },
    Fit { steps: usize, reply: Sender<Response> },
    Predict { xs: Vec<Vec<f64>>, beta: f64, grad: bool, reply: Sender<Response> },
    Suggest { beta: f64, reply: Sender<Response> },
    Stats { reply: Sender<Response> },
    Stop,
}

/// The worker state. PJRT handles are not `Send`, so the engine (and its
/// own `PjRtClient`) must be constructed *on the worker thread* — see
/// [`crate::coordinator::server`].
pub struct ModelEngine {
    pub cfg: EngineConfig,
    gp: AdditiveGP,
    /// Keeps the client alive for the executable's lifetime.
    _client: Option<xla::PjRtClient>,
    exe: Option<WindowExecutable>,
    rng: Rng,
    pub pjrt_batches: u64,
    pub native_queries: u64,
}

impl ModelEngine {
    /// Build the engine, creating a PJRT CPU client and compiling the
    /// matching `(D, W)` artifact when `cfg.use_pjrt` and one exists.
    pub fn new(cfg: EngineConfig) -> Self {
        let mut gpcfg = AdditiveGpConfig::default();
        gpcfg.nu = cfg.nu;
        gpcfg.omega0 = cfg.omega0;
        gpcfg.sigma2_y = cfg.sigma2;
        let gp = AdditiveGP::new(gpcfg, cfg.d);
        let client = if cfg.use_pjrt { xla::PjRtClient::cpu().ok() } else { None };
        let exe = client.as_ref().and_then(|cl| {
            let manifest = ArtifactManifest::load(ArtifactManifest::default_dir()).ok()?;
            let w = 2 * (cfg.nu.q() + 1); // window width 2ν+1 (even form)
            let spec = manifest.select("window_acq", cfg.d, w, 64)?;
            WindowExecutable::load(cl, spec).ok()
        });
        ModelEngine {
            rng: Rng::new(cfg.seed),
            cfg,
            gp,
            _client: client,
            exe,
            pjrt_batches: 0,
            native_queries: 0,
        }
    }

    pub fn has_pjrt(&self) -> bool {
        self.exe.is_some()
    }

    /// Blocking worker loop: drain the queue, batching Predicts.
    pub fn run(mut self, rx: Receiver<Command>) {
        // Pending predict rows: (x, beta, grad, reply, row index base).
        loop {
            let cmd = match rx.recv() {
                Ok(c) => c,
                Err(_) => return,
            };
            match cmd {
                Command::Stop => return,
                Command::Predict { xs, beta, grad, reply } => {
                    // Dynamic batching: opportunistically drain more queued
                    // Predicts with the same β/grad before executing.
                    let mut batch: Vec<(Vec<Vec<f64>>, Sender<Response>)> = vec![(xs, reply)];
                    let mut deferred: Vec<Command> = Vec::new();
                    while let Ok(next) = rx.try_recv() {
                        match next {
                            Command::Predict { xs, beta: b2, grad: g2, reply }
                                if b2 == beta && g2 == grad =>
                            {
                                batch.push((xs, reply))
                            }
                            other => {
                                deferred.push(other);
                                break;
                            }
                        }
                    }
                    self.serve_predicts(batch, beta, grad);
                    for cmd in deferred {
                        if !self.handle_simple(cmd) {
                            return;
                        }
                    }
                }
                other => {
                    if !self.handle_simple(other) {
                        return;
                    }
                }
            }
        }
    }

    /// Handle a non-batchable command; returns `false` on Stop.
    fn handle_simple(&mut self, cmd: Command) -> bool {
        match cmd {
            Command::Stop => return false,
            Command::Observe { x, y, reply } => {
                // Incremental path: O(log n) window work + a prefix-reuse
                // factor patch per point — serving no longer pays O(n log n)
                // (or even a linear factor sweep) per append ingest. The
                // patched-vs-resweep delta rides the reply so the
                // coordinator metrics can watch the crossover.
                let (p0, r0) = self.gp.factor_stats();
                self.gp.observe(&x, y);
                // saturating: a refit (first activation) replaces the fit
                // state and resets the cumulative counters.
                let (p1, r1) = self.gp.factor_stats();
                let _ = reply.send(Response::Observed {
                    n: self.gp.n(),
                    factor_patched: p1.saturating_sub(p0),
                    factor_resweep: r1.saturating_sub(r0),
                });
            }
            Command::ObserveBatch { xs, ys, reply } => {
                if xs.len() != ys.len() {
                    let _ = reply.send(Response::Error("xs/ys length mismatch".into()));
                } else {
                    // Batched incremental ingest: one splice/patch/solve per
                    // dimension for the whole batch, dimensions sharded
                    // across threads; a refit only at/above the crossover.
                    let (p0, r0) = self.gp.factor_stats();
                    let path = self.gp.observe_batch(&xs, &ys);
                    // Refresh the posterior *before* replying, so a client
                    // that issues predict right after the reply (or another
                    // client racing it) sees the post-batch state instead of
                    // paying the solve inside its own predict.
                    if self.gp.fit_state().is_some() {
                        self.gp.ensure_posterior();
                    }
                    let (p1, r1) = self.gp.factor_stats();
                    let _ = reply.send(Response::BatchObserved {
                        n: self.gp.n(),
                        path: path.as_str(),
                        factor_patched: p1.saturating_sub(p0),
                        factor_resweep: r1.saturating_sub(r0),
                    });
                }
            }
            Command::Fit { steps, reply } => {
                let tcfg = TrainCfg { steps, ..Default::default() };
                self.gp.optimize_hypers(&tcfg);
                let _ = reply.send(Response::Ok);
            }
            Command::Predict { xs, beta, grad, reply } => {
                self.serve_predicts(vec![(xs, reply)], beta, grad);
            }
            Command::Suggest { beta, reply } => {
                let acq = Acquisition::LcbMin { beta };
                let scfg = SearchCfg::default();
                let x = search_next(
                    &mut self.gp,
                    &acq,
                    self.cfg.d,
                    self.cfg.lo,
                    self.cfg.hi,
                    &scfg,
                    &mut self.rng,
                );
                let _ = reply.send(Response::Suggestion { x });
            }
            Command::Stats { reply } => {
                let (hits, misses, _) = self.gp.cache_stats();
                let (patches, resweeps) = self.gp.factor_stats();
                let _ = reply.send(Response::Stats {
                    n: self.gp.n(),
                    d: self.gp.input_dim(),
                    omegas: self.gp.omegas.clone(),
                    cache_hits: hits,
                    cache_misses: misses,
                    pjrt_batches: self.pjrt_batches,
                    native_queries: self.native_queries,
                    factor_patches: patches,
                    factor_resweeps: resweeps,
                });
            }
        }
        true
    }

    /// Serve a set of predict requests, through PJRT when possible.
    fn serve_predicts(
        &mut self,
        requests: Vec<(Vec<Vec<f64>>, Sender<Response>)>,
        beta: f64,
        grad: bool,
    ) {
        // Flatten rows, remembering per-request extents.
        let mut rows: Vec<Vec<f64>> = Vec::new();
        let mut extents = Vec::with_capacity(requests.len());
        for (xs, _) in &requests {
            extents.push((rows.len(), xs.len()));
            rows.extend(xs.iter().cloned());
        }
        let results = if self.gp.n() >= self.gp.min_points() {
            self.predict_rows(&rows, beta, grad)
        } else {
            Err("not enough observations".to_string())
        };
        match results {
            Err(e) => {
                for (_, reply) in requests {
                    let _ = reply.send(Response::Error(e.clone()));
                }
            }
            Ok((mu, svar, acq, gacq, path)) => {
                for ((start, len), (_, reply)) in extents.into_iter().zip(requests) {
                    let _ = reply.send(Response::Prediction {
                        mu: mu[start..start + len].to_vec(),
                        svar: svar[start..start + len].to_vec(),
                        acq: acq[start..start + len].to_vec(),
                        gacq: if grad {
                            gacq[start..start + len].to_vec()
                        } else {
                            Vec::new()
                        },
                        path,
                    });
                }
            }
        }
    }

    /// Evaluate all rows; PJRT path when an executable exists.
    #[allow(clippy::type_complexity)]
    fn predict_rows(
        &mut self,
        rows: &[Vec<f64>],
        beta: f64,
        grad: bool,
    ) -> Result<(Vec<f64>, Vec<f64>, Vec<f64>, Vec<Vec<f64>>, &'static str), String> {
        let d = self.cfg.d;
        for r in rows {
            if r.len() != d {
                return Err(format!("expected {d}-dim points"));
            }
        }
        if let Some(exe) = &self.exe {
            let spec_b = exe.spec.b;
            let (sd, sw) = (exe.spec.d, exe.spec.w);
            let mut mu = Vec::with_capacity(rows.len());
            let mut svar = Vec::with_capacity(rows.len());
            let mut acq = Vec::with_capacity(rows.len());
            let mut gacq = Vec::with_capacity(rows.len());
            for chunk in rows.chunks(spec_b) {
                let mut batch = WindowBatch::zeros(&exe.spec, beta as f32);
                batch.rows = chunk.len();
                for (bi, x) in chunk.iter().enumerate() {
                    let qw = self.gp.gather_windows(x);
                    debug_assert_eq!(qw.w_max, sw);
                    for di in 0..sd {
                        for wi in 0..sw {
                            let src = di * sw + wi;
                            let dst = (bi * sd + di) * sw + wi;
                            batch.phi[dst] = qw.phi[src] as f32;
                            batch.dphi[dst] = qw.dphi[src] as f32;
                            batch.bwin[dst] = qw.bwin[src] as f32;
                            for wj in 0..sw {
                                batch.cwin[dst * sw + wj] =
                                    qw.cwin[src * sw + wj] as f32;
                            }
                            for dj in 0..sd {
                                for wj in 0..sw {
                                    let srcm = (src * sd + dj) * sw + wj;
                                    let dstm = ((bi * sd + di) * sw + wi) * sd * sw
                                        + dj * sw
                                        + wj;
                                    batch.mwin[dstm] = qw.mwin[srcm] as f32;
                                }
                            }
                        }
                    }
                    batch.kdiag[bi] = qw.kdiag as f32;
                }
                let out = exe.execute(&batch).map_err(|e| e.to_string())?;
                self.pjrt_batches += 1;
                for bi in 0..chunk.len() {
                    mu.push(out.mu[bi] as f64);
                    svar.push(out.svar[bi] as f64);
                    acq.push(out.acq[bi] as f64);
                    gacq.push(
                        (0..sd).map(|di| out.gacq[bi * sd + di] as f64).collect(),
                    );
                }
            }
            return Ok((mu, svar, acq, gacq, "pjrt"));
        }
        // Native fallback: identical math through the sparse engine.
        let a = Acquisition::LcbMin { beta };
        let mut mu = Vec::new();
        let mut svar = Vec::new();
        let mut acqv = Vec::new();
        let mut gacq = Vec::new();
        for x in rows {
            let out = self.gp.predict(x, grad);
            self.native_queries += 1;
            let (v, g) = if grad {
                a.value_grad(out.mean, out.var, &out.mean_grad, &out.var_grad)
            } else {
                (a.value(out.mean, out.var), Vec::new())
            };
            mu.push(out.mean);
            svar.push(out.var);
            acqv.push(v);
            gacq.push(g);
        }
        Ok((mu, svar, acqv, gacq, "native"))
    }

    /// Direct (in-process, non-threaded) access for tests and examples.
    pub fn gp_mut(&mut self) -> &mut AdditiveGP {
        &mut self.gp
    }

    /// In-process predict used by integration tests.
    #[allow(clippy::type_complexity)]
    pub fn predict_inline(
        &mut self,
        rows: &[Vec<f64>],
        beta: f64,
        grad: bool,
    ) -> Result<(Vec<f64>, Vec<f64>, Vec<f64>, Vec<Vec<f64>>, &'static str), String> {
        self.predict_rows(rows, beta, grad)
    }
}
