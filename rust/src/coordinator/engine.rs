//! Per-model engine state: owns the sparse [`AdditiveGP`] and the command
//! handlers. Since the shared worker-pool rewrite (DESIGN.md §Coordinator)
//! the engine no longer runs its own thread or owns PJRT handles: any pool
//! worker may execute a command against it under the model's mutual
//! exclusion, and the compiled `window_acq` executable — whose handles are
//! not `Send` — lives in the thread-local registry of the worker that
//! compiled it and is *passed in* by the scheduler's worker-affinity predict
//! jobs ([`crate::coordinator::scheduler`]).

use std::sync::mpsc::Sender;

use crate::bo::acquisition::Acquisition;
use crate::coordinator::protocol::Response;
use crate::gp::fit_state::PosteriorSnapshot;
use crate::gp::model::{AdditiveGP, AdditiveGpConfig};
use crate::gp::persist;
use crate::gp::train::TrainCfg;
use crate::kernels::matern::Nu;
use crate::runtime::{WindowBatch, WindowExecutable};
use crate::util::codec::{ByteReader, ByteWriter};

/// Version byte leading every [`ModelEngine::encode_state`] payload, bumped
/// on any layout change so a stale checkpoint errors instead of misparsing.
const STATE_VERSION: u8 = 1;

/// Engine construction options.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub d: usize,
    pub nu: Nu,
    pub omega0: f64,
    pub sigma2: f64,
    /// Box bounds used by `suggest`.
    pub lo: f64,
    pub hi: f64,
    /// Try to load a matching PJRT artifact (otherwise native-only).
    pub use_pjrt: bool,
    pub seed: u64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            d: 2,
            nu: Nu::Half,
            omega0: 1.0,
            sigma2: 1.0,
            lo: -500.0,
            hi: 500.0,
            use_pjrt: true,
            seed: 7,
        }
    }
}

impl EngineConfig {
    /// Append the config to a checkpoint / journal record (bit-exact; the
    /// `f64` fields travel as raw IEEE bits).
    pub fn encode(&self, w: &mut ByteWriter) {
        w.put_usize(self.d);
        w.put_u8(self.nu.two_nu() as u8);
        w.put_f64(self.omega0);
        w.put_f64(self.sigma2);
        w.put_f64(self.lo);
        w.put_f64(self.hi);
        w.put_bool(self.use_pjrt);
        w.put_u64(self.seed);
    }

    /// Inverse of [`Self::encode`]; errors on truncated or invalid bytes.
    pub fn decode(r: &mut ByteReader<'_>) -> Result<Self, String> {
        let d = r.get_usize("cfg.d")?;
        let two_nu = r.get_u8("cfg.nu")? as usize;
        let nu = Nu::from_two_nu(two_nu).ok_or(format!("bad cfg 2ν = {two_nu}"))?;
        Ok(EngineConfig {
            d,
            nu,
            omega0: r.get_f64("cfg.omega0")?,
            sigma2: r.get_f64("cfg.sigma2")?,
            lo: r.get_f64("cfg.lo")?,
            hi: r.get_f64("cfg.hi")?,
            use_pjrt: r.get_bool("cfg.use_pjrt")?,
            seed: r.get_u64("cfg.seed")?,
        })
    }
}

/// A command routed to a model by the scheduler. `reply` receives exactly
/// one [`Response`]. `Observe`/`ObserveBatch`/`Forget`/`ForgetBatch`/
/// `RollingWindow`/`Fit` are *mutating* (per-model FIFO under mutual
/// exclusion); `Predict`/`Suggest`/`Stats`/`Snapshot` are *reads* (served
/// concurrently — see DESIGN.md §Coordinator, "Command classes").
pub enum Command {
    Observe { x: Vec<f64>, y: f64, reply: Sender<Response> },
    ObserveBatch { xs: Vec<Vec<f64>>, ys: Vec<f64>, reply: Sender<Response> },
    /// Release the latest observation matching `x` by value (protocol v2).
    Forget { x: Vec<f64>, reply: Sender<Response> },
    /// Release a batch of observations by value (protocol v2).
    ForgetBatch { xs: Vec<Vec<f64>>, reply: Sender<Response> },
    /// Configure (or, with `max_n = 0`, disable) the sliding-window policy.
    RollingWindow { max_n: usize, max_age: Option<u64>, reply: Sender<Response> },
    Fit { steps: usize, reply: Sender<Response> },
    Predict { xs: Vec<Vec<f64>>, beta: f64, grad: bool, reply: Sender<Response> },
    Suggest { beta: f64, reply: Sender<Response> },
    Stats { reply: Sender<Response> },
    /// On-demand structural invariant audit (a *read*: briefly locks the
    /// engine, walks every structure, never mutates).
    Audit { reply: Sender<Response> },
    /// Export the model's read snapshot as a generation-numbered artifact
    /// (protocol v3 — the replica feed). A `have_gen` matching the served
    /// generation elides the payload (the cheap "unchanged" delta). A
    /// *read*: rides the snapshot path, never perturbs the engine.
    Snapshot { have_gen: Option<u64>, reply: Sender<Response> },
    /// Register `events` for push invalidations: one
    /// [`Response::Invalidate`] per generation bump until the receiver
    /// hangs up (protocol v3).
    Subscribe { events: Sender<Response>, reply: Sender<Response> },
}

impl Command {
    /// Consume the command, answering its caller with an error (unknown
    /// model, dead engine, coordinator shutdown).
    pub fn fail(self, msg: String) {
        let reply = match self {
            Command::Observe { reply, .. }
            | Command::ObserveBatch { reply, .. }
            | Command::Forget { reply, .. }
            | Command::ForgetBatch { reply, .. }
            | Command::RollingWindow { reply, .. }
            | Command::Fit { reply, .. }
            | Command::Predict { reply, .. }
            | Command::Suggest { reply, .. }
            | Command::Stats { reply }
            | Command::Audit { reply }
            | Command::Snapshot { reply, .. }
            | Command::Subscribe { reply, .. } => reply,
        };
        let _ = reply.send(Response::Error(msg));
    }
}

/// Sliding-window policy: after each ingest the engine evicts oldest-first
/// until at most `max_n` observations remain and (when `max_age` is set)
/// none is older than `max_age` ingest ticks. Evictions never shrink the
/// model below its activation minimum — a window configured tighter than
/// `min_points` floats there until arrivals resume.
#[derive(Clone, Copy, Debug)]
pub struct RollingCfg {
    pub max_n: usize,
    pub max_age: Option<u64>,
}

/// The per-model state (pure data — `Send`, shared behind the scheduler's
/// per-model mutex). PJRT executables are deliberately *not* stored here:
/// their handles are not `Send`, so they stay in the worker-local registry
/// of the pool worker that compiled them.
pub struct ModelEngine {
    pub cfg: EngineConfig,
    gp: AdditiveGP,
    pub pjrt_batches: u64,
    pub native_queries: u64,
    /// Active sliding-window policy (None = keep everything).
    rolling: Option<RollingCfg>,
    /// Ingest tick of each live observation, data order (parallel to the
    /// model's rows; stays nondecreasing because ingest only appends).
    /// Only commands keep this in sync — tests poking `gp_mut()` directly
    /// bypass it.
    arrival: Vec<u64>,
    /// Monotone ingest clock: one tick per observed point.
    ingest_ticks: u64,
    /// Observations evicted by the rolling-window policy (lifetime total).
    pub window_evictions: u64,
}

impl ModelEngine {
    /// Build the native engine state. PJRT compilation happens separately,
    /// on the pool worker the model is pinned to (see
    /// [`crate::coordinator::scheduler::Scheduler::create_model`]).
    pub fn new(cfg: EngineConfig) -> Self {
        let mut gpcfg = AdditiveGpConfig::default();
        gpcfg.nu = cfg.nu;
        gpcfg.omega0 = cfg.omega0;
        gpcfg.sigma2_y = cfg.sigma2;
        let gp = AdditiveGP::new(gpcfg, cfg.d);
        ModelEngine {
            cfg,
            gp,
            pjrt_batches: 0,
            native_queries: 0,
            rolling: None,
            arrival: Vec::new(),
            ingest_ticks: 0,
            window_evictions: 0,
        }
    }

    pub fn gp(&self) -> &AdditiveGP {
        &self.gp
    }

    /// Absorb one observation. Incremental path: O(log n) window work + a
    /// prefix-reuse factor patch per point — serving no longer pays
    /// O(n log n) (or even a linear factor sweep) per append ingest. The
    /// patched-vs-resweep delta rides the reply so the coordinator metrics
    /// can watch the crossover.
    pub fn observe(&mut self, x: &[f64], y: f64) -> Response {
        if x.len() != self.gp.input_dim() {
            return Response::Error(format!("expected {}-dim points", self.gp.input_dim()));
        }
        let (p0, r0) = self.gp.factor_stats();
        self.gp.observe(x, y);
        self.ingest_ticks += 1;
        self.arrival.push(self.ingest_ticks);
        self.enforce_window();
        // saturating: a refit (first activation) replaces the fit state and
        // resets the cumulative counters.
        let (p1, r1) = self.gp.factor_stats();
        Response::Observed {
            n: self.gp.n(),
            factor_patched: p1.saturating_sub(p0),
            factor_resweep: r1.saturating_sub(r0),
        }
    }

    /// Absorb a batch: one splice/patch/solve per dimension for the whole
    /// batch, dimensions sharded across threads; a refit only at/above the
    /// crossover. Replies *after* the posterior refresh, so a client that
    /// predicts right after the reply (or another client racing it) sees the
    /// post-batch state instead of paying the solve inside its own predict.
    pub fn observe_batch(&mut self, xs: &[Vec<f64>], ys: &[f64]) -> Response {
        if xs.len() != ys.len() {
            return Response::Error("xs/ys length mismatch".into());
        }
        if xs.iter().any(|x| x.len() != self.gp.input_dim()) {
            return Response::Error(format!("expected {}-dim points", self.gp.input_dim()));
        }
        let (p0, r0) = self.gp.factor_stats();
        let path = self.gp.observe_batch(xs, ys);
        for _ in 0..xs.len() {
            self.ingest_ticks += 1;
            self.arrival.push(self.ingest_ticks);
        }
        self.enforce_window();
        if self.gp.fit_state().is_some() {
            self.gp.ensure_posterior();
        }
        let (p1, r1) = self.gp.factor_stats();
        Response::BatchObserved {
            n: self.gp.n(),
            path: path.as_str(),
            factor_patched: p1.saturating_sub(p0),
            factor_resweep: r1.saturating_sub(r0),
        }
    }

    /// Release the latest observation equal to `x` by value — the protocol
    /// v2 `forget` op. Matching nothing is not an error: the reply reports
    /// `removed: 0` so idempotent retraction scripts stay simple.
    pub fn forget(&mut self, x: &[f64]) -> Response {
        if x.len() != self.gp.input_dim() {
            return Response::Error(format!("expected {}-dim points", self.gp.input_dim()));
        }
        let (p0, r0) = self.gp.factor_stats();
        // Resolve the index here (latest match, same rule as the facade) so
        // the arrival clock can be spliced at the same spot.
        let hit = {
            let (cols, _) = self.gp.data();
            let n = cols.first().map(|c| c.len()).unwrap_or(0);
            (0..n)
                .rev()
                .find(|&i| x.iter().enumerate().all(|(d, &v)| cols[d][i] == v))
        };
        let removed = if let Some(i) = hit {
            self.gp.forget_index(i);
            self.arrival.remove(i);
            1
        } else {
            0
        };
        let (p1, r1) = self.gp.factor_stats();
        Response::Forgotten {
            n: self.gp.n(),
            removed,
            factor_patched: p1.saturating_sub(p0),
            factor_resweep: r1.saturating_sub(r0),
        }
    }

    /// Release a batch of observations by value — the protocol v2
    /// `forget_batch` op. Each row retires the latest still-unclaimed
    /// matching observation; rows that match nothing are skipped and the
    /// reply's `removed` reports how many were actually released.
    pub fn forget_batch(&mut self, xs: &[Vec<f64>]) -> Response {
        if xs.iter().any(|x| x.len() != self.gp.input_dim()) {
            return Response::Error(format!("expected {}-dim points", self.gp.input_dim()));
        }
        let (p0, r0) = self.gp.factor_stats();
        let (cols, _) = self.gp.data();
        let n = cols.first().map(|c| c.len()).unwrap_or(0);
        let mut claimed = vec![false; n];
        let mut indices: Vec<usize> = Vec::new();
        for x in xs {
            let hit = (0..n).rev().find(|&i| {
                !claimed[i] && x.iter().enumerate().all(|(d, &v)| cols[d][i] == v)
            });
            if let Some(i) = hit {
                claimed[i] = true;
                indices.push(i);
            }
        }
        indices.sort_unstable();
        let removed = indices.len();
        if removed > 0 {
            self.gp.forget_batch(&indices);
            for &i in indices.iter().rev() {
                self.arrival.remove(i);
            }
        }
        let (p1, r1) = self.gp.factor_stats();
        Response::Forgotten {
            n: self.gp.n(),
            removed,
            factor_patched: p1.saturating_sub(p0),
            factor_resweep: r1.saturating_sub(r0),
        }
    }

    /// Configure (or disable, with `max_n = 0`) the sliding-window policy
    /// and apply it immediately — the protocol v2 `rolling_window` op.
    pub fn rolling_window(&mut self, max_n: usize, max_age: Option<u64>) -> Response {
        if max_n == 0 {
            self.rolling = None;
            return Response::Ok;
        }
        self.rolling = Some(RollingCfg { max_n, max_age });
        self.enforce_window();
        Response::Ok
    }

    /// Current occupancy of the sliding window (= live observations).
    pub fn window_occupancy(&self) -> usize {
        self.gp.n()
    }

    /// Evict oldest-first until the rolling-window policy is satisfied,
    /// never shrinking the model below `min_points` (a tighter window
    /// floats at the activation minimum). Data order is arrival order —
    /// ingest only appends — so "oldest" is always a prefix and one
    /// batched union-window downdate retires it.
    fn enforce_window(&mut self) -> usize {
        let Some(rc) = self.rolling else { return 0 };
        let n = self.gp.n();
        let mut k = n.saturating_sub(rc.max_n);
        if let Some(age) = rc.max_age {
            let now = self.ingest_ticks;
            while k < n && now.saturating_sub(self.arrival[k]) > age {
                k += 1;
            }
        }
        let floor = self.gp.min_points();
        if n.saturating_sub(k) < floor {
            k = n.saturating_sub(floor);
        }
        if k == 0 {
            return 0;
        }
        let indices: Vec<usize> = (0..k).collect();
        self.gp.forget_batch(&indices);
        self.arrival.drain(..k);
        self.window_evictions += k as u64;
        k
    }

    /// Re-learn hyperparameters (full refit — a mutating command).
    pub fn fit(&mut self, steps: usize) -> Response {
        if self.gp.n() < self.gp.min_points() {
            return Response::Error("not enough observations".into());
        }
        let tcfg = TrainCfg { steps, ..Default::default() };
        self.gp.optimize_hypers(&tcfg);
        Response::Ok
    }

    /// Build the concurrent-read snapshot, or an error before activation.
    pub fn read_snapshot(&mut self) -> Result<PosteriorSnapshot, String> {
        self.gp.read_snapshot().ok_or_else(|| "not enough observations".to_string())
    }

    /// Walk every stateful structure's invariants
    /// ([`AdditiveGP::run_audit`]) and report the first violation, if any,
    /// as `Structure.field[index]: detail`. Valid at any model age —
    /// before activation only the façade structures are walked.
    pub fn audit(&self) -> Response {
        let (structures, result) = self.gp.run_audit();
        match result {
            Ok(()) => Response::AuditReport { passed: true, structures, violation: String::new() },
            Err(e) => Response::AuditReport {
                passed: false,
                structures,
                violation: e.to_string(),
            },
        }
    }

    /// Serve a set of predict requests sharing one `(β, grad)`, through the
    /// given PJRT executable when present (the scheduler's dynamic batching
    /// drains a model's queued predicts into one call).
    pub fn serve_predicts(
        &mut self,
        exe: Option<&WindowExecutable>,
        requests: Vec<(Vec<Vec<f64>>, Sender<Response>)>,
        beta: f64,
        grad: bool,
    ) {
        // Flatten rows, remembering per-request extents.
        let mut rows: Vec<Vec<f64>> = Vec::new();
        let mut extents = Vec::with_capacity(requests.len());
        for (xs, _) in &requests {
            extents.push((rows.len(), xs.len()));
            rows.extend(xs.iter().cloned());
        }
        let results = if self.gp.n() >= self.gp.min_points() {
            self.predict_rows(exe, &rows, beta, grad)
        } else {
            Err("not enough observations".to_string())
        };
        match results {
            Err(e) => {
                for (_, reply) in requests {
                    let _ = reply.send(Response::Error(e.clone()));
                }
            }
            Ok((mu, svar, acq, gacq, path)) => {
                for ((start, len), (_, reply)) in extents.into_iter().zip(requests) {
                    let _ = reply.send(Response::Prediction {
                        mu: mu[start..start + len].to_vec(),
                        svar: svar[start..start + len].to_vec(),
                        acq: acq[start..start + len].to_vec(),
                        gacq: if grad {
                            gacq[start..start + len].to_vec()
                        } else {
                            Vec::new()
                        },
                        path,
                    });
                }
            }
        }
    }

    /// Evaluate all rows; PJRT path when an executable is supplied.
    #[allow(clippy::type_complexity)]
    fn predict_rows(
        &mut self,
        exe: Option<&WindowExecutable>,
        rows: &[Vec<f64>],
        beta: f64,
        grad: bool,
    ) -> Result<(Vec<f64>, Vec<f64>, Vec<f64>, Vec<Vec<f64>>, &'static str), String> {
        let d = self.cfg.d;
        for r in rows {
            if r.len() != d {
                return Err(format!("expected {d}-dim points"));
            }
        }
        if let Some(exe) = exe {
            let spec_b = exe.spec.b;
            let (sd, sw) = (exe.spec.d, exe.spec.w);
            let mut mu = Vec::with_capacity(rows.len());
            let mut svar = Vec::with_capacity(rows.len());
            let mut acq = Vec::with_capacity(rows.len());
            let mut gacq = Vec::with_capacity(rows.len());
            // Double-buffered dispatch: chunk g executes on the device
            // while chunk g+1's windows are gathered on the host; the
            // blocking host sync (`wait`) runs only once the next batch is
            // fully staged. An error on either side drops the in-flight
            // handle and propagates — the caller's native fallback and
            // error paths are unchanged.
            let mut pending: Option<(usize, crate::runtime::PendingWindow)> = None;
            let mut drain = |rows_in_flight: usize,
                             out: &crate::runtime::WindowOutputs| {
                for bi in 0..rows_in_flight {
                    mu.push(out.mu[bi] as f64);
                    svar.push(out.svar[bi] as f64);
                    acq.push(out.acq[bi] as f64);
                    gacq.push(
                        (0..sd).map(|di| out.gacq[bi * sd + di] as f64).collect(),
                    );
                }
            };
            for chunk in rows.chunks(spec_b) {
                let mut batch = WindowBatch::zeros(&exe.spec, beta as f32);
                batch.rows = chunk.len();
                for (bi, x) in chunk.iter().enumerate() {
                    let qw = self.gp.gather_windows(x);
                    debug_assert_eq!(qw.w_max, sw);
                    for di in 0..sd {
                        for wi in 0..sw {
                            let src = di * sw + wi;
                            let dst = (bi * sd + di) * sw + wi;
                            batch.phi[dst] = qw.phi[src] as f32;
                            batch.dphi[dst] = qw.dphi[src] as f32;
                            batch.bwin[dst] = qw.bwin[src] as f32;
                            for wj in 0..sw {
                                batch.cwin[dst * sw + wj] =
                                    qw.cwin[src * sw + wj] as f32;
                            }
                            for dj in 0..sd {
                                for wj in 0..sw {
                                    let srcm = (src * sd + dj) * sw + wj;
                                    let dstm = ((bi * sd + di) * sw + wi) * sd * sw
                                        + dj * sw
                                        + wj;
                                    batch.mwin[dstm] = qw.mwin[srcm] as f32;
                                }
                            }
                        }
                    }
                    batch.kdiag[bi] = qw.kdiag as f32;
                }
                if let Some((rows_in_flight, p)) = pending.take() {
                    let out = p.wait().map_err(|e| e.to_string())?;
                    self.pjrt_batches += 1;
                    drain(rows_in_flight, &out);
                }
                let p = exe.submit(&batch).map_err(|e| e.to_string())?;
                pending = Some((chunk.len(), p));
            }
            if let Some((rows_in_flight, p)) = pending.take() {
                let out = p.wait().map_err(|e| e.to_string())?;
                self.pjrt_batches += 1;
                drain(rows_in_flight, &out);
            }
            return Ok((mu, svar, acq, gacq, "pjrt"));
        }
        // Native fallback: identical math through the sparse engine.
        let a = Acquisition::LcbMin { beta };
        let mut mu = Vec::new();
        let mut svar = Vec::new();
        let mut acqv = Vec::new();
        let mut gacq = Vec::new();
        for x in rows {
            let out = self.gp.predict(x, grad);
            self.native_queries += 1;
            let (v, g) = if grad {
                a.value_grad(out.mean, out.var, &out.mean_grad, &out.var_grad)
            } else {
                (a.value(out.mean, out.var), Vec::new())
            };
            mu.push(out.mean);
            svar.push(out.var);
            acqv.push(v);
            gacq.push(g);
        }
        Ok((mu, svar, acqv, gacq, "native"))
    }

    /// Direct (in-process, non-threaded) access for tests and examples.
    pub fn gp_mut(&mut self) -> &mut AdditiveGP {
        &mut self.gp
    }

    /// Serialize the engine bit-exactly — config, arrival clock, counters
    /// and the full trained model ([`persist::encode_gp`]). This is the
    /// journal's checkpoint payload: `decode_state(encode_state())` is an
    /// engine whose every future command follows the same bit trajectory
    /// (the chaos suite's recovery property). PJRT executables are *not*
    /// state — they live in worker-local registries and are recompiled on
    /// demand after recovery.
    pub fn encode_state(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.put_u8(STATE_VERSION);
        self.cfg.encode(&mut w);
        w.put_u64(self.pjrt_batches);
        w.put_u64(self.native_queries);
        match self.rolling {
            Some(rc) => {
                w.put_bool(true);
                w.put_usize(rc.max_n);
                match rc.max_age {
                    Some(a) => {
                        w.put_bool(true);
                        w.put_u64(a);
                    }
                    None => w.put_bool(false),
                }
            }
            None => w.put_bool(false),
        }
        w.put_usize(self.arrival.len());
        for &t in &self.arrival {
            w.put_u64(t);
        }
        w.put_u64(self.ingest_ticks);
        w.put_u64(self.window_evictions);
        persist::encode_gp(&self.gp, &mut w);
        w.into_bytes()
    }

    /// Rebuild an engine from [`Self::encode_state`] bytes. Errors (never
    /// panics) on any truncated, corrupt or version-skewed payload, so a
    /// damaged checkpoint degrades into a recovery error the scheduler can
    /// report.
    pub fn decode_state(bytes: &[u8]) -> Result<ModelEngine, String> {
        let mut r = ByteReader::new(bytes);
        let ver = r.get_u8("state version")?;
        if ver != STATE_VERSION {
            return Err(format!("checkpoint state version {ver}, expected {STATE_VERSION}"));
        }
        let cfg = EngineConfig::decode(&mut r)?;
        let pjrt_batches = r.get_u64("pjrt_batches")?;
        let native_queries = r.get_u64("native_queries")?;
        let rolling = if r.get_bool("rolling present")? {
            let max_n = r.get_usize("rolling.max_n")?;
            let max_age = if r.get_bool("rolling.max_age present")? {
                Some(r.get_u64("rolling.max_age")?)
            } else {
                None
            };
            Some(RollingCfg { max_n, max_age })
        } else {
            None
        };
        let n_arrival = r.get_usize("arrival len")?;
        if n_arrival > r.remaining() / 8 {
            return Err(format!("claimed {n_arrival} arrival ticks exceed remaining bytes"));
        }
        let mut arrival = Vec::with_capacity(n_arrival);
        for _ in 0..n_arrival {
            arrival.push(r.get_u64("arrival tick")?);
        }
        let ingest_ticks = r.get_u64("ingest_ticks")?;
        let window_evictions = r.get_u64("window_evictions")?;
        // Same config derivation as `ModelEngine::new`, so the checkpoint
        // can never disagree with the declared engine shape.
        let mut gpcfg = AdditiveGpConfig::default();
        gpcfg.nu = cfg.nu;
        gpcfg.omega0 = cfg.omega0;
        gpcfg.sigma2_y = cfg.sigma2;
        let gp = persist::decode_gp(&mut r, gpcfg, cfg.d)?;
        if !r.is_done() {
            return Err(format!("{} trailing bytes after checkpoint payload", r.remaining()));
        }
        if arrival.len() != gp.n() {
            return Err(format!(
                "arrival clock carries {} ticks for {} observations",
                arrival.len(),
                gp.n()
            ));
        }
        Ok(ModelEngine {
            cfg,
            gp,
            pjrt_batches,
            native_queries,
            rolling,
            arrival,
            ingest_ticks,
            window_evictions,
        })
    }

    /// In-process predict used by integration tests (native path; pass an
    /// executable to exercise PJRT).
    #[allow(clippy::type_complexity)]
    pub fn predict_inline(
        &mut self,
        exe: Option<&WindowExecutable>,
        rows: &[Vec<f64>],
        beta: f64,
        grad: bool,
    ) -> Result<(Vec<f64>, Vec<f64>, Vec<f64>, Vec<Vec<f64>>, &'static str), String> {
        self.predict_rows(exe, rows, beta, grad)
    }
}
