//! The typed protocol v3 client (DESIGN.md §Coordinator, §Replication).
//!
//! Everything that talks to a coordinator from Rust goes through
//! [`Client`]: builder-style connect with a versioned hello (`ping`),
//! typed `predict`/`observe`/`suggest`/`stats` methods returning
//! `Result<T, ProtocolError>`, and a [`Subscription`] handle for the v3
//! invalidation push stream. Together with [`protocol`] this module is the
//! one sanctioned place that constructs request JSON — `cargo xtask lint`
//! bans raw `"op":...` literals everywhere else outside tests.
//!
//! ```no_run
//! use addgp::coordinator::Client;
//!
//! let mut c = Client::connect("127.0.0.1:9000")?;
//! let model = c.create_model(4, 5, 1.0, 1.0)?;
//! c.observe(model, &[0.1, 0.2, 0.3, 0.4], 1.5)?;
//! let pred = c.predict(model, &[vec![0.5; 4]], 2.0, false)?;
//! println!("mu = {:?}", pred.mu);
//! # Ok::<(), addgp::coordinator::ProtocolError>(())
//! ```
//!
//! The client is version-transparent: `Client::builder(addr).version(2)`
//! speaks the flat v2 wire format (and refuses v3-only methods locally
//! with a structured error instead of a confusing server reject), while
//! the default v3 client parses the nested `stats` sections. Both shapes
//! are golden-pinned in `tests/protocol_compat.rs`.
//!
//! [`protocol`]: crate::coordinator::protocol

use std::fmt;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use crate::coordinator::protocol::{hex_decode, PROTOCOL_VERSION};
use crate::util::Json;

/// A structured client-side error: transport, server-reported, or a reply
/// the client could not make sense of.
#[derive(Clone, Debug, PartialEq)]
pub enum ProtocolError {
    /// Socket-level failure (connect, read, write, peer hangup).
    Io(String),
    /// The server answered with `{"ok":false,"error":...}`; carries the
    /// server's error string verbatim.
    Remote(String),
    /// The server's reply parsed but did not have the promised shape
    /// (missing field, id mismatch) — or a v3-only method was called on a
    /// client pinned to an older protocol version.
    Malformed(String),
}

impl ProtocolError {
    /// True for load-shed rejections the caller should back off and retry
    /// (the server prefixes those with `retryable:`).
    pub fn is_retryable(&self) -> bool {
        matches!(self, ProtocolError::Remote(e) if e.starts_with("retryable:"))
    }

    /// True when the server refused the request over protocol versioning —
    /// either the declared version is newer than the server speaks, or the
    /// op needs a newer version than was declared.
    pub fn is_version_rejection(&self) -> bool {
        match self {
            ProtocolError::Remote(e) => {
                e.starts_with("unsupported protocol version")
                    || e.contains("requires protocol v")
            }
            _ => false,
        }
    }
}

impl fmt::Display for ProtocolError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ProtocolError::Io(e) => write!(f, "io: {e}"),
            ProtocolError::Remote(e) => write!(f, "server: {e}"),
            ProtocolError::Malformed(e) => write!(f, "malformed reply: {e}"),
        }
    }
}

impl std::error::Error for ProtocolError {}

fn io_err(e: std::io::Error) -> ProtocolError {
    ProtocolError::Io(e.to_string())
}

/// `observe` acknowledgment: post-observe data size and this call's
/// patched vs re-swept factor-update counts.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Observed {
    pub n: usize,
    pub factor_patched: u64,
    pub factor_resweep: u64,
}

/// `observe_batch` acknowledgment; `path` is which ingest path ran
/// ("incremental", "refit" or "buffered").
#[derive(Clone, Debug, Default, PartialEq)]
pub struct BatchObserved {
    pub n: usize,
    pub path: String,
    pub factor_patched: u64,
    pub factor_resweep: u64,
}

/// `forget`/`forget_batch` acknowledgment — the downdate mirror of
/// [`Observed`]; `removed` counts observations actually released.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Forgotten {
    pub n: usize,
    pub removed: usize,
    pub factor_patched: u64,
    pub factor_resweep: u64,
}

/// A `predict` reply: per-row posterior mean, additive variance, LCB
/// acquisition, optional acquisition gradients (`[B, D]`, empty unless
/// requested), and which execution path served it ("pjrt" or "native").
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Prediction {
    pub mu: Vec<f64>,
    pub svar: Vec<f64>,
    pub acq: Vec<f64>,
    pub gacq: Vec<Vec<f64>>,
    pub path: String,
}

/// An `audit` reply: whether every structural invariant held, how many
/// structures were walked, and the first violation (empty on pass).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct AuditReport {
    pub passed: bool,
    pub structures: u64,
    pub violation: String,
}

/// A `snapshot` reply: the served generation and — unless the server
/// short-circuited on a matching `have_gen` — the decoded artifact bytes
/// (feed them to [`crate::gp::persist::decode_snapshot`]).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SnapshotFetch {
    pub gen: u64,
    pub artifact: Option<Vec<u8>>,
}

/// The `solve` stats section: posterior cache + factor-update counters.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SolveStats {
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub pjrt_batches: u64,
    pub native_queries: u64,
    pub factor_patches: u64,
    pub factor_resweeps: u64,
    pub cache_truncations: u64,
    pub fallback_rebuilds: u64,
    pub cold_retries: u64,
    pub refit_escalations: u64,
}

/// The `storage` stats section: chunked COW band-storage counters.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StorageStats {
    pub memmove_bytes: u64,
    pub chunks_copied: u64,
    pub chunks_shared: u64,
}

/// The `journal` stats section: durability counters + degradation flag.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct JournalStats {
    pub appends: u64,
    pub bytes: u64,
    pub checkpoints: u64,
    pub recoveries: u64,
    pub degraded: bool,
}

/// The `pool` stats section: shared worker-pool occupancy (pool-wide).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PoolSection {
    pub workers: u64,
    pub busy: u64,
    pub queue_depth: u64,
    pub steals: u64,
}

/// The `window` stats section: sliding-window eviction counters.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct WindowStats {
    pub evictions: u64,
    pub occupancy: u64,
}

/// The `replication` stats section (v3-only; zero when the client speaks
/// v1/v2, whose flat wire shape predates replication).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ReplicationStats {
    pub snapshots_exported: u64,
    pub invalidations_sent: u64,
    pub subscribers: u64,
}

/// A typed `stats` reply. Parsed from the nested v3 sections, or — when
/// the client is pinned to v1/v2 — assembled from the flat legacy shape,
/// so callers never see the wire difference.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Stats {
    pub n: usize,
    pub d: usize,
    pub omegas: Vec<f64>,
    pub solve: SolveStats,
    pub storage: StorageStats,
    pub journal: JournalStats,
    pub pool: PoolSection,
    pub window: WindowStats,
    pub replication: ReplicationStats,
}

/// One invalidation push event: `model` advanced to `gen`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Invalidation {
    pub model: u64,
    pub gen: u64,
}

/// Builder for [`Client`]: pin a protocol version, attach a per-request
/// deadline, or skip the connect-time hello.
#[derive(Clone, Debug)]
pub struct ClientBuilder {
    addr: String,
    version: u64,
    deadline_ms: Option<u64>,
    hello: bool,
}

impl ClientBuilder {
    /// Speak an older protocol version (1 or 2): requests carry that `v`
    /// (v1 omits the field — the legacy wire format), replies are parsed
    /// in the matching shape, and v3-only methods fail locally.
    pub fn version(mut self, v: u64) -> Self {
        self.version = v;
        self
    }

    /// Attach `deadline_ms` to every request sent by this client.
    pub fn deadline_ms(mut self, ms: u64) -> Self {
        self.deadline_ms = Some(ms);
        self
    }

    /// Skip the connect-time versioned hello (v3 clients send a `ping` by
    /// default so a version mismatch surfaces before any real traffic).
    pub fn no_hello(mut self) -> Self {
        self.hello = false;
        self
    }

    /// Connect and (for v3 with the hello enabled) verify the server
    /// speaks this client's protocol version.
    pub fn connect(self) -> Result<Client, ProtocolError> {
        let stream = TcpStream::connect(&self.addr).map_err(io_err)?;
        let _ = stream.set_nodelay(true);
        let writer = stream.try_clone().map_err(io_err)?;
        let mut c = Client {
            reader: BufReader::new(stream),
            writer,
            version: self.version,
            deadline_ms: self.deadline_ms,
            next_id: 0,
        };
        if self.hello && self.version >= 3 {
            let server = c.ping()?;
            if server < c.version {
                return Err(ProtocolError::Remote(format!(
                    "server speaks v{server}, client requires v{}",
                    c.version
                )));
            }
        }
        Ok(c)
    }
}

/// A typed, blocking JSON-line client for the coordinator protocol.
///
/// One request in flight at a time (the protocol is strictly
/// request/reply per connection); open one client per thread for
/// concurrent load. Every request carries a monotonically increasing `id`
/// and the reply's echo is checked, so a desynchronized connection
/// surfaces as [`ProtocolError::Malformed`] instead of silently pairing
/// replies with the wrong calls.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    version: u64,
    deadline_ms: Option<u64>,
    next_id: u64,
}

impl Client {
    /// Start building a client for `addr` (anything that formats as
    /// `host:port` — a `&str` or a `SocketAddr`).
    pub fn builder(addr: impl fmt::Display) -> ClientBuilder {
        ClientBuilder {
            addr: addr.to_string(),
            version: PROTOCOL_VERSION,
            deadline_ms: None,
            hello: true,
        }
    }

    /// Connect with the defaults: current protocol version, no deadline,
    /// versioned hello on.
    pub fn connect(addr: impl fmt::Display) -> Result<Client, ProtocolError> {
        Client::builder(addr).connect()
    }

    /// The protocol version this client speaks (and declares on the wire).
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Send one request line, read one reply line, and run the shared
    /// reply checks (transport, `error`, `ok`, id echo). All typed methods
    /// bottom out here — the only place request JSON is built.
    fn request(
        &mut self,
        op: &str,
        fields: Vec<(&str, Json)>,
    ) -> Result<Json, ProtocolError> {
        self.next_id += 1;
        let id = self.next_id as f64;
        let mut pairs: Vec<(&str, Json)> = vec![("op", Json::Str(op.to_string()))];
        if self.version >= 2 {
            // A missing `v` *is* the v1 wire format, pinned forever.
            pairs.push(("v", Json::Num(self.version as f64)));
        }
        pairs.push(("id", Json::Num(id)));
        if let Some(ms) = self.deadline_ms {
            pairs.push(("deadline_ms", Json::Num(ms as f64)));
        }
        pairs.extend(fields);
        let line = Json::obj(pairs).to_string();
        self.writer.write_all(line.as_bytes()).map_err(io_err)?;
        self.writer.write_all(b"\n").map_err(io_err)?;

        let mut reply = String::new();
        let n = self.reader.read_line(&mut reply).map_err(io_err)?;
        if n == 0 {
            return Err(ProtocolError::Io("server closed the connection".into()));
        }
        let v = Json::parse(reply.trim_end())
            .map_err(|e| ProtocolError::Malformed(format!("bad reply JSON: {e}")))?;
        if let Some(e) = v.get("error").and_then(|x| x.as_str()) {
            return Err(ProtocolError::Remote(e.to_string()));
        }
        if v.get("ok").and_then(|x| x.as_bool()) != Some(true) {
            return Err(ProtocolError::Malformed(format!(
                "reply missing ok:true: {}",
                reply.trim_end()
            )));
        }
        // Parse errors can't echo the id; every ok reply must.
        match v.get("id").and_then(|x| x.as_f64()) {
            Some(echo) if echo == id => Ok(v),
            other => Err(ProtocolError::Malformed(format!(
                "reply id {other:?} does not echo request id {id}"
            ))),
        }
    }

    /// Versioned hello (v3): returns the server's protocol version.
    pub fn ping(&mut self) -> Result<u64, ProtocolError> {
        need_v3(self.version, "ping")?;
        let v = self.request("ping", Vec::new())?;
        get_u64(&v, "server_version")
    }

    /// Create a model; returns its id. `nu2` is 2ν (1, 3 or 5).
    pub fn create_model(
        &mut self,
        d: usize,
        nu2: usize,
        omega: f64,
        sigma2: f64,
    ) -> Result<u64, ProtocolError> {
        let v = self.request(
            "create_model",
            vec![
                ("d", Json::Num(d as f64)),
                ("nu2", Json::Num(nu2 as f64)),
                ("omega", Json::Num(omega)),
                ("sigma2", Json::Num(sigma2)),
            ],
        )?;
        get_u64(&v, "model")
    }

    /// Ingest one observation.
    pub fn observe(
        &mut self,
        model: u64,
        x: &[f64],
        y: f64,
    ) -> Result<Observed, ProtocolError> {
        let v = self.request(
            "observe",
            vec![
                ("model", Json::Num(model as f64)),
                ("x", Json::arr_f64(x)),
                ("y", Json::Num(y)),
            ],
        )?;
        Ok(Observed {
            n: get_usize(&v, "n")?,
            factor_patched: get_u64(&v, "factor_patched")?,
            factor_resweep: get_u64(&v, "factor_resweep")?,
        })
    }

    /// Ingest a batch of observations in one posterior refresh.
    pub fn observe_batch(
        &mut self,
        model: u64,
        xs: &[Vec<f64>],
        ys: &[f64],
    ) -> Result<BatchObserved, ProtocolError> {
        let v = self.request(
            "observe_batch",
            vec![
                ("model", Json::Num(model as f64)),
                ("xs", rows(xs)),
                ("ys", Json::arr_f64(ys)),
            ],
        )?;
        Ok(BatchObserved {
            n: get_usize(&v, "n")?,
            path: get_str(&v, "path")?,
            factor_patched: get_u64(&v, "factor_patched")?,
            factor_resweep: get_u64(&v, "factor_resweep")?,
        })
    }

    /// Release the most recent observation equal to `x` (v2).
    pub fn forget(&mut self, model: u64, x: &[f64]) -> Result<Forgotten, ProtocolError> {
        let v = self.request(
            "forget",
            vec![("model", Json::Num(model as f64)), ("x", Json::arr_f64(x))],
        )?;
        parse_forgotten(&v)
    }

    /// Release a batch of observations by value (v2).
    pub fn forget_batch(
        &mut self,
        model: u64,
        xs: &[Vec<f64>],
    ) -> Result<Forgotten, ProtocolError> {
        let v = self.request(
            "forget_batch",
            vec![("model", Json::Num(model as f64)), ("xs", rows(xs))],
        )?;
        parse_forgotten(&v)
    }

    /// Put the model into sliding-window mode (v2); `max_n = 0` turns it
    /// off.
    pub fn rolling_window(
        &mut self,
        model: u64,
        max_n: usize,
        max_age: Option<u64>,
    ) -> Result<(), ProtocolError> {
        let mut fields = vec![
            ("model", Json::Num(model as f64)),
            ("max_n", Json::Num(max_n as f64)),
        ];
        if let Some(age) = max_age {
            fields.push(("max_age", Json::Num(age as f64)));
        }
        self.request("rolling_window", fields).map(|_| ())
    }

    /// Run `steps` hyper-parameter fit steps.
    pub fn fit(&mut self, model: u64, steps: usize) -> Result<(), ProtocolError> {
        self.request(
            "fit",
            vec![
                ("model", Json::Num(model as f64)),
                ("steps", Json::Num(steps as f64)),
            ],
        )
        .map(|_| ())
    }

    /// Batched posterior query at `xs` with LCB parameter `beta`.
    pub fn predict(
        &mut self,
        model: u64,
        xs: &[Vec<f64>],
        beta: f64,
        grad: bool,
    ) -> Result<Prediction, ProtocolError> {
        let v = self.request(
            "predict",
            vec![
                ("model", Json::Num(model as f64)),
                ("xs", rows(xs)),
                ("beta", Json::Num(beta)),
                ("grad", Json::Bool(grad)),
            ],
        )?;
        let gacq = match v.get("gacq").and_then(|x| x.as_arr()) {
            Some(arr) => arr
                .iter()
                .map(|row| {
                    row.as_f64_vec().ok_or_else(|| {
                        ProtocolError::Malformed("bad gacq row".into())
                    })
                })
                .collect::<Result<Vec<_>, _>>()?,
            None => Vec::new(),
        };
        Ok(Prediction {
            mu: get_f64_vec(&v, "mu")?,
            svar: get_f64_vec(&v, "svar")?,
            acq: get_f64_vec(&v, "acq")?,
            gacq,
            path: get_str(&v, "path")?,
        })
    }

    /// Ask for the next point to evaluate (multi-start LCB descent).
    pub fn suggest(&mut self, model: u64, beta: f64) -> Result<Vec<f64>, ProtocolError> {
        let v = self.request(
            "suggest",
            vec![("model", Json::Num(model as f64)), ("beta", Json::Num(beta))],
        )?;
        get_f64_vec(&v, "x")
    }

    /// Typed model + pool statistics (see [`Stats`]).
    pub fn stats(&mut self, model: u64) -> Result<Stats, ProtocolError> {
        let v = self.request("stats", vec![("model", Json::Num(model as f64))])?;
        if self.version >= 3 {
            parse_stats_nested(&v)
        } else {
            parse_stats_flat(&v)
        }
    }

    /// Run the structural invariant audit on demand.
    pub fn audit(&mut self, model: u64) -> Result<AuditReport, ProtocolError> {
        let v = self.request("audit", vec![("model", Json::Num(model as f64))])?;
        Ok(AuditReport {
            passed: get_bool(&v, "passed")?,
            structures: get_u64(&v, "structures")?,
            violation: get_str(&v, "violation")?,
        })
    }

    /// Fetch the model's posterior as a generation-numbered snapshot
    /// artifact (v3). With `have_gen` matching the served generation the
    /// reply is a payload-free `unchanged` ack (`artifact: None`).
    pub fn snapshot(
        &mut self,
        model: u64,
        have_gen: Option<u64>,
    ) -> Result<SnapshotFetch, ProtocolError> {
        need_v3(self.version, "snapshot")?;
        let mut fields = vec![("model", Json::Num(model as f64))];
        if let Some(g) = have_gen {
            fields.push(("have_gen", Json::Num(g as f64)));
        }
        let v = self.request("snapshot", fields)?;
        let gen = get_u64(&v, "gen")?;
        let artifact = match v.get("snapshot").and_then(|x| x.as_str()) {
            Some(hex) => Some(
                hex_decode(hex)
                    .map_err(|e| ProtocolError::Malformed(format!("bad artifact: {e}")))?,
            ),
            None => {
                if v.get("unchanged").and_then(|x| x.as_bool()) != Some(true) {
                    return Err(ProtocolError::Malformed(
                        "snapshot reply has neither payload nor unchanged ack".into(),
                    ));
                }
                None
            }
        };
        Ok(SnapshotFetch { gen, artifact })
    }

    /// Ask the server to shut down (acknowledged before the listener
    /// stops accepting).
    pub fn shutdown(&mut self) -> Result<(), ProtocolError> {
        self.request("shutdown", Vec::new()).map(|_| ())
    }

    /// Convert this connection into an invalidation push stream (v3).
    /// Consumes the client: after the `subscribed` ack the server writes
    /// only event lines here, so request/reply traffic needs its own
    /// connection.
    pub fn subscribe(mut self, model: u64) -> Result<Subscription, ProtocolError> {
        need_v3(self.version, "subscribe")?;
        let v = self.request("subscribe", vec![("model", Json::Num(model as f64))])?;
        if v.get("subscribed").and_then(|x| x.as_bool()) != Some(true) {
            return Err(ProtocolError::Malformed("subscribe reply not acked".into()));
        }
        let gen = get_u64(&v, "gen")?;
        Ok(Subscription {
            stream: self.writer,
            reader: self.reader,
            partial: String::new(),
            gen,
        })
    }
}

/// A live invalidation stream: the consumed connection of a successful
/// [`Client::subscribe`]. Dropping it disconnects, which is how the
/// server learns to drop the subscriber.
pub struct Subscription {
    /// Kept for `set_read_timeout`; never written after the subscribe ack.
    stream: TcpStream,
    reader: BufReader<TcpStream>,
    /// Partial line carried across a read timeout, so a timeout that
    /// lands mid-line never corrupts the stream.
    partial: String,
    gen: u64,
}

impl Subscription {
    /// The model generation at subscription time — events only arrive for
    /// generations after this one.
    pub fn starting_gen(&self) -> u64 {
        self.gen
    }

    /// Block up to `timeout` (forever when `None`) for the next
    /// invalidation. `Ok(None)` means the timeout elapsed; the stream is
    /// still live.
    pub fn next_event(
        &mut self,
        timeout: Option<Duration>,
    ) -> Result<Option<Invalidation>, ProtocolError> {
        self.stream.set_read_timeout(timeout).map_err(io_err)?;
        match self.reader.read_line(&mut self.partial) {
            Ok(0) => {
                return Err(ProtocolError::Io("subscription closed by server".into()))
            }
            Ok(_) if !self.partial.ends_with('\n') => {
                return Err(ProtocolError::Io(
                    "subscription closed mid-event".into(),
                ))
            }
            Ok(_) => {}
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                return Ok(None)
            }
            Err(e) => return Err(io_err(e)),
        }
        let line = std::mem::take(&mut self.partial);
        let v = Json::parse(line.trim_end())
            .map_err(|e| ProtocolError::Malformed(format!("bad event JSON: {e}")))?;
        if v.get("event").and_then(|x| x.as_str()) != Some("invalidate") {
            return Err(ProtocolError::Malformed(format!(
                "unexpected event line: {}",
                line.trim_end()
            )));
        }
        let inv = Invalidation {
            model: get_u64(&v, "model")?,
            gen: get_u64(&v, "gen")?,
        };
        self.gen = inv.gen;
        Ok(Some(inv))
    }
}

/// Refuse a v3-only method locally when the client is pinned older — a
/// clearer failure than shipping an op the server will reject.
fn need_v3(version: u64, op: &str) -> Result<(), ProtocolError> {
    if version < 3 {
        return Err(ProtocolError::Malformed(format!(
            "op '{op}' requires protocol v3 but this client speaks v{version}"
        )));
    }
    Ok(())
}

fn rows(xs: &[Vec<f64>]) -> Json {
    Json::Arr(xs.iter().map(|row| Json::arr_f64(row)).collect())
}

fn missing(key: &str) -> ProtocolError {
    ProtocolError::Malformed(format!("reply missing '{key}'"))
}

fn get_u64(v: &Json, key: &str) -> Result<u64, ProtocolError> {
    v.get(key)
        .and_then(|x| x.as_f64())
        .map(|f| f as u64)
        .ok_or_else(|| missing(key))
}

fn get_usize(v: &Json, key: &str) -> Result<usize, ProtocolError> {
    v.get(key).and_then(|x| x.as_usize()).ok_or_else(|| missing(key))
}

fn get_bool(v: &Json, key: &str) -> Result<bool, ProtocolError> {
    v.get(key).and_then(|x| x.as_bool()).ok_or_else(|| missing(key))
}

fn get_str(v: &Json, key: &str) -> Result<String, ProtocolError> {
    v.get(key)
        .and_then(|x| x.as_str())
        .map(|s| s.to_string())
        .ok_or_else(|| missing(key))
}

fn get_f64_vec(v: &Json, key: &str) -> Result<Vec<f64>, ProtocolError> {
    v.get(key).and_then(|x| x.as_f64_vec()).ok_or_else(|| missing(key))
}

fn parse_forgotten(v: &Json) -> Result<Forgotten, ProtocolError> {
    Ok(Forgotten {
        n: get_usize(v, "n")?,
        removed: get_usize(v, "removed")?,
        factor_patched: get_u64(v, "factor_patched")?,
        factor_resweep: get_u64(v, "factor_resweep")?,
    })
}

/// Parse the nested v3 `stats` shape.
fn parse_stats_nested(v: &Json) -> Result<Stats, ProtocolError> {
    let section = |key: &str| -> Result<&Json, ProtocolError> {
        v.get(key).ok_or_else(|| missing(key))
    };
    let solve = section("solve")?;
    let storage = section("storage")?;
    let journal = section("journal")?;
    let pool = section("pool")?;
    let window = section("window")?;
    let replication = section("replication")?;
    Ok(Stats {
        n: get_usize(v, "n")?,
        d: get_usize(v, "d")?,
        omegas: get_f64_vec(v, "omegas")?,
        solve: SolveStats {
            cache_hits: get_u64(solve, "cache_hits")?,
            cache_misses: get_u64(solve, "cache_misses")?,
            pjrt_batches: get_u64(solve, "pjrt_batches")?,
            native_queries: get_u64(solve, "native_queries")?,
            factor_patches: get_u64(solve, "factor_patches")?,
            factor_resweeps: get_u64(solve, "factor_resweeps")?,
            cache_truncations: get_u64(solve, "cache_truncations")?,
            fallback_rebuilds: get_u64(solve, "fallback_rebuilds")?,
            cold_retries: get_u64(solve, "cold_retries")?,
            refit_escalations: get_u64(solve, "refit_escalations")?,
        },
        storage: StorageStats {
            memmove_bytes: get_u64(storage, "memmove_bytes")?,
            chunks_copied: get_u64(storage, "chunks_copied")?,
            chunks_shared: get_u64(storage, "chunks_shared")?,
        },
        journal: JournalStats {
            appends: get_u64(journal, "appends")?,
            bytes: get_u64(journal, "bytes")?,
            checkpoints: get_u64(journal, "checkpoints")?,
            recoveries: get_u64(journal, "recoveries")?,
            degraded: get_bool(journal, "degraded")?,
        },
        pool: PoolSection {
            workers: get_u64(pool, "workers")?,
            busy: get_u64(pool, "busy")?,
            queue_depth: get_u64(pool, "queue_depth")?,
            steals: get_u64(pool, "steals")?,
        },
        window: WindowStats {
            evictions: get_u64(window, "evictions")?,
            occupancy: get_u64(window, "occupancy")?,
        },
        replication: ReplicationStats {
            snapshots_exported: get_u64(replication, "snapshots_exported")?,
            invalidations_sent: get_u64(replication, "invalidations_sent")?,
            subscribers: get_u64(replication, "subscribers")?,
        },
    })
}

/// Parse the flat v1/v2 `stats` shape into the same typed struct (the
/// replication section predates v3 on the wire, so it stays zero).
fn parse_stats_flat(v: &Json) -> Result<Stats, ProtocolError> {
    Ok(Stats {
        n: get_usize(v, "n")?,
        d: get_usize(v, "d")?,
        omegas: get_f64_vec(v, "omegas")?,
        solve: SolveStats {
            cache_hits: get_u64(v, "cache_hits")?,
            cache_misses: get_u64(v, "cache_misses")?,
            pjrt_batches: get_u64(v, "pjrt_batches")?,
            native_queries: get_u64(v, "native_queries")?,
            factor_patches: get_u64(v, "factor_patches")?,
            factor_resweeps: get_u64(v, "factor_resweeps")?,
            cache_truncations: get_u64(v, "cache_truncations")?,
            fallback_rebuilds: get_u64(v, "fallback_rebuilds")?,
            cold_retries: get_u64(v, "solve_cold_retries")?,
            refit_escalations: get_u64(v, "solve_refit_escalations")?,
        },
        storage: StorageStats {
            memmove_bytes: get_u64(v, "memmove_bytes")?,
            chunks_copied: get_u64(v, "chunks_copied")?,
            chunks_shared: get_u64(v, "chunks_shared")?,
        },
        journal: JournalStats {
            appends: get_u64(v, "journal_appends")?,
            bytes: get_u64(v, "journal_bytes")?,
            checkpoints: get_u64(v, "journal_checkpoints")?,
            recoveries: get_u64(v, "recoveries")?,
            degraded: get_bool(v, "degraded")?,
        },
        pool: PoolSection {
            workers: get_u64(v, "pool_workers")?,
            busy: get_u64(v, "pool_busy")?,
            queue_depth: get_u64(v, "pool_queue_depth")?,
            steals: get_u64(v, "pool_steals")?,
        },
        window: WindowStats {
            evictions: get_u64(v, "window_evictions")?,
            occupancy: get_u64(v, "window_occupancy")?,
        },
        replication: ReplicationStats::default(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn protocol_error_classification() {
        let shed = ProtocolError::Remote("retryable: server overloaded".into());
        assert!(shed.is_retryable());
        assert!(!shed.is_version_rejection());
        let v = ProtocolError::Remote(
            "unsupported protocol version 9 (server speaks <= 3)".into(),
        );
        assert!(v.is_version_rejection());
        assert!(!v.is_retryable());
        let gate = ProtocolError::Remote(
            "op 'snapshot' requires protocol v3 (request declared v2)".into(),
        );
        assert!(gate.is_version_rejection());
        assert!(!ProtocolError::Io("eof".into()).is_version_rejection());
        assert!(!ProtocolError::Malformed("x".into()).is_retryable());
    }

    #[test]
    fn flat_and_nested_stats_parse_to_the_same_struct() {
        let flat = r#"{"ok":true,"n":3,"d":2,"omegas":[1.0,2.0],
            "cache_hits":1,"cache_misses":2,"pjrt_batches":0,"native_queries":4,
            "factor_patches":5,"factor_resweeps":6,"cache_truncations":0,
            "fallback_rebuilds":0,"pool_workers":4,"pool_busy":1,
            "pool_queue_depth":0,"pool_steals":7,"memmove_bytes":8,
            "chunks_copied":9,"chunks_shared":10,"window_evictions":0,
            "window_occupancy":3,"recoveries":1,"degraded":false,
            "journal_appends":11,"journal_bytes":12,"journal_checkpoints":1,
            "solve_cold_retries":0,"solve_refit_escalations":0}"#;
        let nested = r#"{"ok":true,"n":3,"d":2,"omegas":[1.0,2.0],
            "solve":{"cache_hits":1,"cache_misses":2,"pjrt_batches":0,
                "native_queries":4,"factor_patches":5,"factor_resweeps":6,
                "cache_truncations":0,"fallback_rebuilds":0,"cold_retries":0,
                "refit_escalations":0},
            "storage":{"memmove_bytes":8,"chunks_copied":9,"chunks_shared":10},
            "journal":{"appends":11,"bytes":12,"checkpoints":1,"recoveries":1,
                "degraded":false},
            "pool":{"workers":4,"busy":1,"queue_depth":0,"steals":7},
            "window":{"evictions":0,"occupancy":3},
            "replication":{"snapshots_exported":0,"invalidations_sent":0,
                "subscribers":0}}"#;
        let f = parse_stats_flat(&Json::parse(flat).unwrap()).unwrap();
        let n = parse_stats_nested(&Json::parse(nested).unwrap()).unwrap();
        assert_eq!(f, n);
        assert_eq!(f.pool.steals, 7);
        assert_eq!(f.journal.recoveries, 1);
        assert_eq!(f.replication, ReplicationStats::default());
    }

    #[test]
    fn v3_methods_fail_locally_on_old_clients() {
        assert!(need_v3(3, "snapshot").is_ok());
        let err = need_v3(2, "snapshot").unwrap_err();
        assert!(matches!(err, ProtocolError::Malformed(_)));
        assert!(err.to_string().contains("requires protocol v3"));
    }
}
