//! Serving metrics: lock-free counters and log₂-bucketed latency
//! histograms (p50/p95/p99), exposed through the `stats` op and printed by
//! the server on shutdown. Since the shared worker-pool rewrite the server
//! keeps both *pool-wide* histograms (all models mixed — the fleet view)
//! and *per-model* histograms (one [`ModelMetrics`] per model id — the
//! noisy-neighbour view). (No external metrics crate offline.)

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::coordinator::lock_clean;

/// Log₂-bucketed latency histogram over microseconds: bucket `i` holds
/// latencies in `[2^i, 2^{i+1})` µs, 0..=31.
#[derive(Default)]
pub struct LatencyHistogram {
    buckets: [AtomicU64; 32],
    count: AtomicU64,
    sum_us: AtomicU64,
}

impl LatencyHistogram {
    pub fn record(&self, seconds: f64) {
        let us = (seconds * 1e6).max(0.0) as u64;
        let bucket = (64 - us.max(1).leading_zeros() as usize - 1).min(31);
        self.buckets[bucket].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean latency in seconds.
    pub fn mean_s(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        self.sum_us.load(Ordering::Relaxed) as f64 / c as f64 / 1e6
    }

    /// Approximate quantile (upper bucket edge), seconds.
    pub fn quantile_s(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * total as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            if acc >= target {
                return (1u64 << (i + 1)) as f64 / 1e6;
            }
        }
        (1u64 << 31) as f64 / 1e6
    }

    /// One-line report.
    pub fn report(&self) -> String {
        format!(
            "count={} mean={:.2}ms p50≤{:.2}ms p95≤{:.2}ms p99≤{:.2}ms",
            self.count(),
            self.mean_s() * 1e3,
            self.quantile_s(0.50) * 1e3,
            self.quantile_s(0.95) * 1e3,
            self.quantile_s(0.99) * 1e3
        )
    }
}

/// Per-model latency histograms, keyed by model id in
/// [`ServerMetrics::model`]. Same bucketing as the pool-wide histograms, so
/// a model's line is directly comparable against the fleet line.
#[derive(Default)]
pub struct ModelMetrics {
    pub predict_latency: LatencyHistogram,
    pub suggest_latency: LatencyHistogram,
    pub ingest_latency: LatencyHistogram,
}

impl ModelMetrics {
    /// One-line report (only non-empty histograms are printed).
    pub fn report(&self) -> String {
        let mut parts = Vec::new();
        if self.predict_latency.count() > 0 {
            parts.push(format!("predict: {}", self.predict_latency.report()));
        }
        if self.suggest_latency.count() > 0 {
            parts.push(format!("suggest: {}", self.suggest_latency.report()));
        }
        if self.ingest_latency.count() > 0 {
            parts.push(format!("ingest: {}", self.ingest_latency.report()));
        }
        if parts.is_empty() {
            "idle".to_string()
        } else {
            parts.join(" | ")
        }
    }
}

/// Per-server request counters.
#[derive(Default)]
pub struct ServerMetrics {
    pub requests: AtomicU64,
    pub errors: AtomicU64,
    /// Peers that vanished mid-request: the reply was computed but could
    /// not be written back (or the line arrived torn at EOF). Each one also
    /// frees its reader thread — pinned by `tests/coordinator_e2e.rs`.
    pub client_disconnects: AtomicU64,
    /// Requests whose `deadline_ms` budget expired before the scheduler
    /// replied (the reply is dropped when it eventually arrives).
    pub deadline_timeouts: AtomicU64,
    /// Requests refused at the door by queue-depth load shedding.
    pub shed_requests: AtomicU64,
    pub predict_points: AtomicU64,
    /// Points ingested through `observe` + `observe_batch`.
    pub observe_points: AtomicU64,
    /// Points released through `forget` + `forget_batch` (client-driven
    /// retractions; rolling-window evictions are counted separately).
    pub points_forgotten: AtomicU64,
    /// Rolling-window evictions across all models, folded in as deltas from
    /// each model's cumulative `stats` counter.
    pub window_evictions: AtomicU64,
    /// `observe_batch` calls served by the batched incremental path.
    pub batches_incremental: AtomicU64,
    /// `observe_batch` calls served by a full refit (crossover or first
    /// activation).
    pub batches_refit: AtomicU64,
    /// `observe_batch` calls that only buffered (below `min_points`).
    pub batches_buffered: AtomicU64,
    /// Protocol v3 `snapshot` requests served (replica snapshot fetches,
    /// including `have_gen` short-circuits that shipped no payload).
    pub snapshot_requests: AtomicU64,
    /// Protocol v3 `subscribe` registrations accepted.
    pub subscribe_requests: AtomicU64,
    /// Banded-LU factor updates served by the prefix-reuse patch
    /// (`BandedLU::refactor_from`), summed over `observe`/`observe_batch`
    /// replies — with `factor_resweeps`, the production view of the
    /// DESIGN.md "Sublinear LU patching" crossover.
    pub factor_patches: AtomicU64,
    /// Factor updates that fell back to the full `O(ν²n)` re-sweep.
    pub factor_resweeps: AtomicU64,
    pub predict_latency: LatencyHistogram,
    pub suggest_latency: LatencyHistogram,
    /// `observe` / `observe_batch` round-trip latency. `observe_batch`
    /// replies *after* the posterior refresh (full ingest cost);
    /// single-point `observe` stays lazy — its samples cover the factor
    /// patch only, with the solve deferred to the next predict.
    pub ingest_latency: LatencyHistogram,
    /// Cumulative chunked-band-storage counters across all models (DESIGN.md
    /// "Chunked COW band storage"): bytes shifted by mid-matrix splices,
    /// chunks deep-copied by copy-on-write, and chunks handed to snapshots
    /// by reference.
    pub storage_memmove_bytes: AtomicU64,
    pub storage_chunks_copied: AtomicU64,
    pub storage_chunks_shared: AtomicU64,
    /// Per-model histograms, created on first touch.
    per_model: Mutex<HashMap<u64, Arc<ModelMetrics>>>,
    /// Last-seen cumulative `(memmove_bytes, chunks_copied, chunks_shared)`
    /// per model, so repeated `stats` replies fold into the totals as
    /// deltas rather than re-adding the whole lifetime counter.
    storage_seen: Mutex<HashMap<u64, (u64, u64, u64)>>,
    /// Last-seen cumulative window-eviction count per model (same delta
    /// discipline as `storage_seen`).
    window_seen: Mutex<HashMap<u64, u64>>,
}

impl ServerMetrics {
    pub fn inc_requests(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    pub fn inc_errors(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    pub fn inc_client_disconnects(&self) {
        self.client_disconnects.fetch_add(1, Ordering::Relaxed);
    }

    pub fn inc_deadline_timeouts(&self) {
        self.deadline_timeouts.fetch_add(1, Ordering::Relaxed);
    }

    pub fn inc_shed_requests(&self) {
        self.shed_requests.fetch_add(1, Ordering::Relaxed);
    }

    pub fn inc_snapshot_requests(&self) {
        self.snapshot_requests.fetch_add(1, Ordering::Relaxed);
    }

    pub fn inc_subscribe_requests(&self) {
        self.subscribe_requests.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add_predict_points(&self, n: usize) {
        self.predict_points.fetch_add(n as u64, Ordering::Relaxed);
    }

    pub fn add_observe_points(&self, n: usize) {
        self.observe_points.fetch_add(n as u64, Ordering::Relaxed);
    }

    pub fn add_forgotten_points(&self, n: usize) {
        self.points_forgotten.fetch_add(n as u64, Ordering::Relaxed);
    }

    /// Fold one model's cumulative window-eviction counter (from a `stats`
    /// reply) into the server-wide total, as a delta since its last report.
    pub fn record_window_evictions(&self, model: u64, evictions: u64) {
        let delta = {
            let mut seen = lock_clean(&self.window_seen);
            let prev = seen.insert(model, evictions).unwrap_or(0);
            evictions.saturating_sub(prev)
        };
        self.window_evictions.fetch_add(delta, Ordering::Relaxed);
    }

    /// Count one `observe_batch` under its ingest path ("incremental",
    /// "refit", "buffered" — the `BatchPath` wire labels). Unknown labels
    /// are ignored rather than misfiled, so a future path can't silently
    /// inflate an existing counter.
    pub fn count_batch_path(&self, path: &str) {
        let c = match path {
            "incremental" => &self.batches_incremental,
            "refit" => &self.batches_refit,
            "buffered" => &self.batches_buffered,
            _ => return,
        };
        c.fetch_add(1, Ordering::Relaxed);
    }

    /// Accumulate one ingest reply's patched vs re-swept factor-update
    /// counts.
    pub fn add_factor_outcomes(&self, patched: u64, resweeps: u64) {
        self.factor_patches.fetch_add(patched, Ordering::Relaxed);
        self.factor_resweeps.fetch_add(resweeps, Ordering::Relaxed);
    }

    /// Fold one model's cumulative storage counters (from a `stats` reply)
    /// into the server-wide totals. Only the delta since the model's last
    /// report is added; a counter that went *backwards* (model re-created
    /// under the same id) contributes nothing until it catches back up.
    /// Panic resurrection is *not* such a regression: the scheduler lifts
    /// its wire counters by a per-recovery baseline, so a recovered model's
    /// stats stay monotone and this fold never under-counts across a
    /// resurrection (regression-tested in `tests/chaos.rs`).
    pub fn record_storage_stats(&self, model: u64, memmove: u64, copied: u64, shared: u64) {
        let (dm, dc, ds) = {
            let mut seen = lock_clean(&self.storage_seen);
            let prev = seen.insert(model, (memmove, copied, shared)).unwrap_or((0, 0, 0));
            (
                memmove.saturating_sub(prev.0),
                copied.saturating_sub(prev.1),
                shared.saturating_sub(prev.2),
            )
        };
        self.storage_memmove_bytes.fetch_add(dm, Ordering::Relaxed);
        self.storage_chunks_copied.fetch_add(dc, Ordering::Relaxed);
        self.storage_chunks_shared.fetch_add(ds, Ordering::Relaxed);
    }

    /// The per-model histogram set for `id`, created on first touch. The
    /// returned handle is lock-free to record into.
    pub fn model(&self, id: u64) -> Arc<ModelMetrics> {
        let mut map = lock_clean(&self.per_model);
        Arc::clone(map.entry(id).or_default())
    }

    pub fn report(&self) -> String {
        let mut out = format!(
            "requests={} errors={} disconnects={} deadline_timeouts={} shed={} \
             predict_points={} observe_points={} \
             forgotten_points={} window_evictions={} \
             batches(incremental={} refit={} buffered={}) \
             factor(patched={} resweep={}) \
             storage(memmove_bytes={} chunks_copied={} chunks_shared={}) \
             replication(snapshots={} subscribes={}) | \
             predict: {} | suggest: {} | ingest: {}",
            self.requests.load(Ordering::Relaxed),
            self.errors.load(Ordering::Relaxed),
            self.client_disconnects.load(Ordering::Relaxed),
            self.deadline_timeouts.load(Ordering::Relaxed),
            self.shed_requests.load(Ordering::Relaxed),
            self.predict_points.load(Ordering::Relaxed),
            self.observe_points.load(Ordering::Relaxed),
            self.points_forgotten.load(Ordering::Relaxed),
            self.window_evictions.load(Ordering::Relaxed),
            self.batches_incremental.load(Ordering::Relaxed),
            self.batches_refit.load(Ordering::Relaxed),
            self.batches_buffered.load(Ordering::Relaxed),
            self.factor_patches.load(Ordering::Relaxed),
            self.factor_resweeps.load(Ordering::Relaxed),
            self.storage_memmove_bytes.load(Ordering::Relaxed),
            self.storage_chunks_copied.load(Ordering::Relaxed),
            self.storage_chunks_shared.load(Ordering::Relaxed),
            self.snapshot_requests.load(Ordering::Relaxed),
            self.subscribe_requests.load(Ordering::Relaxed),
            self.predict_latency.report(),
            self.suggest_latency.report(),
            self.ingest_latency.report()
        );
        let models = {
            let map = lock_clean(&self.per_model);
            // Sorted by model id right below, so the nondeterministic
            // HashMap walk never reaches the report. lint: hashmap-order-ok
            let mut v: Vec<(u64, Arc<ModelMetrics>)> =
                map.iter().map(|(k, m)| (*k, Arc::clone(m))).collect();
            v.sort_by_key(|(k, _)| *k);
            v
        };
        for (id, m) in models {
            out.push_str(&format!("\n  model {id}: {}", m.report()));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_ordered() {
        let h = LatencyHistogram::default();
        for i in 1..=1000u64 {
            h.record(i as f64 * 1e-5); // 10µs .. 10ms
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile_s(0.5);
        let p95 = h.quantile_s(0.95);
        let p99 = h.quantile_s(0.99);
        assert!(p50 <= p95 && p95 <= p99);
        // p50 of uniform 10µs..10ms ≈ 5ms; bucket edge ≤ 8.4ms.
        assert!(p50 > 2e-3 && p50 < 1.7e-2, "p50 {p50}");
        assert!(h.mean_s() > 3e-3 && h.mean_s() < 7e-3);
    }

    #[test]
    fn histogram_empty() {
        let h = LatencyHistogram::default();
        assert_eq!(h.quantile_s(0.5), 0.0);
        assert_eq!(h.mean_s(), 0.0);
    }

    #[test]
    fn counters() {
        let m = ServerMetrics::default();
        m.inc_requests();
        m.inc_requests();
        m.inc_errors();
        m.add_predict_points(64);
        m.add_observe_points(128);
        m.count_batch_path("incremental");
        m.count_batch_path("incremental");
        m.count_batch_path("refit");
        m.count_batch_path("buffered");
        m.add_factor_outcomes(8, 0);
        m.add_factor_outcomes(0, 4);
        m.add_forgotten_points(3);
        // Window evictions fold in as deltas from each model's cumulative
        // counter; a regressed counter (model re-created) adds nothing.
        m.record_window_evictions(9, 10);
        m.record_window_evictions(9, 15);
        m.record_window_evictions(4, 7);
        m.record_window_evictions(4, 2);
        // Cumulative per-model storage counters fold in as deltas: the
        // second report of model 9 adds only its growth, and a counter
        // that regressed (model re-created) adds nothing.
        m.record_storage_stats(9, 1000, 3, 20);
        m.record_storage_stats(9, 1500, 5, 26);
        m.record_storage_stats(4, 100, 1, 2);
        m.record_storage_stats(4, 50, 0, 1);
        m.inc_client_disconnects();
        m.inc_deadline_timeouts();
        m.inc_deadline_timeouts();
        m.inc_shed_requests();
        m.inc_snapshot_requests();
        m.inc_snapshot_requests();
        m.inc_subscribe_requests();
        let r = m.report();
        assert!(r.contains("requests=2"));
        assert!(r.contains("errors=1"));
        assert!(r.contains("disconnects=1"), "{r}");
        assert!(r.contains("deadline_timeouts=2"), "{r}");
        assert!(r.contains("shed=1"), "{r}");
        assert!(r.contains("predict_points=64"));
        assert!(r.contains("observe_points=128"));
        assert!(r.contains("incremental=2"));
        assert!(r.contains("refit=1"));
        assert!(r.contains("buffered=1"));
        assert!(r.contains("patched=8"));
        assert!(r.contains("resweep=4"));
        assert!(r.contains("forgotten_points=3"), "{r}");
        assert!(r.contains("window_evictions=22"), "{r}");
        assert!(r.contains("memmove_bytes=1600"), "{r}");
        assert!(r.contains("chunks_copied=6"), "{r}");
        assert!(r.contains("chunks_shared=28"), "{r}");
        assert!(r.contains("snapshots=2"), "{r}");
        assert!(r.contains("subscribes=1"), "{r}");
    }

    #[test]
    fn per_model_histograms() {
        let m = ServerMetrics::default();
        m.model(2).predict_latency.record(1e-3);
        m.model(1).ingest_latency.record(2e-3);
        m.model(2).predict_latency.record(1e-3);
        let r = m.report();
        let i1 = r.find("model 1:").expect("model 1 line");
        let i2 = r.find("model 2:").expect("model 2 line");
        assert!(i1 < i2, "per-model lines sorted by id:\n{r}");
        assert!(r.contains("ingest: count=1"), "{r}");
        assert!(r.contains("predict: count=2"), "{r}");
        assert_eq!(m.model(3).report(), "idle");
    }
}
