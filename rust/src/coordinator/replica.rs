//! Stateless snapshot-shipping read replica (DESIGN.md §Replication).
//!
//! A [`Replica`] holds **no model state of its own**: it imports
//! generation-numbered [`PosteriorSnapshot`] artifacts from its home shard
//! (the writer) and serves `predict`/`suggest` from the last coherent
//! import, through the *same* read-path math the writer's native path uses
//! ([`scheduler::predict_on_snapshot`]) — so a replica's predictions are
//! bit-identical to the writer's at the same generation.
//!
//! Freshness rides the v3 push protocol: one `subscribe` connection per
//! model delivers invalidation events, each answered with a `snapshot`
//! fetch carrying `have_gen` (the writer elides the payload when nothing
//! changed — the cheap delta). Every import re-runs the full structural
//! audit inside [`persist::decode_snapshot`], so a torn or corrupt ship
//! can never install a mixed-generation posterior: the replica keeps
//! serving its **last coherent generation** and retries. Writer restarts
//! (journal recovery) are absorbed by the reconnect loop, which refetches
//! unconditionally and installs whatever the writer now serves.
//!
//! Mutations are refused with a structured "read-only" error; route them
//! to the home shard. Scale reads by running any number of replicas — see
//! `examples/serve_cluster.rs` for a 1-writer + N-replica process fleet.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::Duration;

use crate::check::Audit;
use crate::coordinator::client::Client;
use crate::coordinator::lock_clean;
use crate::coordinator::protocol::{hex_encode, Request, Response, PROTOCOL_VERSION};
use crate::coordinator::scheduler::{predict_on_snapshot, suggest_on_snapshot};
use crate::gp::fit_state::PosteriorSnapshot;
use crate::gp::persist;

/// How often blocked reads and the accept loop re-check the shutdown flag.
const POLL: Duration = Duration::from_millis(250);

/// Configuration for a [`Replica`].
#[derive(Clone, Debug)]
pub struct ReplicaConfig {
    /// The home shard's `host:port`.
    pub writer: String,
    /// Model ids to replicate. Each must already be *active* on the writer
    /// (enough observations to build a read snapshot) when the replica
    /// binds — the initial sync is a blocking full fetch.
    pub models: Vec<u64>,
    /// Suggest search bounds; must match the writer's engine config.
    pub lo: f64,
    pub hi: f64,
    /// Base seed for this replica's suggest rng streams.
    pub seed: u64,
}

/// Counters returned by [`Replica::serve`] after shutdown, summed over all
/// replicated models.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ReplicaStats {
    /// Snapshot artifacts decoded, audited and installed.
    pub snapshots_imported: u64,
    /// Invalidation events received on subscription connections.
    pub invalidations_seen: u64,
    /// Refresh attempts that failed (connect/fetch error or an artifact
    /// that did not decode cleanly) — each one left the previous coherent
    /// generation serving.
    pub refresh_failures: u64,
    /// Rows served by this replica's read path.
    pub reads_served: u64,
}

/// A generation-tagged imported snapshot.
struct TaggedSnap {
    gen: u64,
    snap: PosteriorSnapshot,
}

/// Per-model replica state.
struct RepModel {
    /// The serving snapshot. Always present (the initial sync happens in
    /// [`Replica::bind`]); swapped atomically under a short lock so reads
    /// never block on an import.
    current: Mutex<Arc<TaggedSnap>>,
    suggest_seq: AtomicU64,
    snapshots_imported: AtomicU64,
    invalidations_seen: AtomicU64,
    refresh_failures: AtomicU64,
    reads_served: AtomicU64,
}

impl RepModel {
    /// Decode, audit and install an artifact. Installs unconditionally —
    /// imports are serialized by the model's one sync thread, and after a
    /// writer restart the authoritative generation may legitimately be
    /// *lower* than what the replica holds. A decode failure (torn write,
    /// bad CRC, failed audit) leaves the current snapshot serving.
    fn install(&self, bytes: &[u8]) -> Result<u64, String> {
        match persist::decode_snapshot(bytes) {
            Ok((gen, snap)) => {
                *lock_clean(&self.current) = Arc::new(TaggedSnap { gen, snap });
                self.snapshots_imported.fetch_add(1, Ordering::Relaxed);
                Ok(gen)
            }
            Err(e) => {
                self.refresh_failures.fetch_add(1, Ordering::Relaxed);
                Err(e)
            }
        }
    }

    fn cur(&self) -> Arc<TaggedSnap> {
        Arc::clone(&lock_clean(&self.current))
    }
}

struct RepShared {
    cfg: ReplicaConfig,
    models: HashMap<u64, RepModel>,
    shutting_down: AtomicBool,
}

/// A running read replica: bind, then [`serve`](Replica::serve).
pub struct Replica {
    listener: TcpListener,
    local: SocketAddr,
    shared: Arc<RepShared>,
}

impl Replica {
    /// Bind the serving socket and run the blocking initial sync: one full
    /// snapshot fetch + audit per replicated model. Errors if the writer
    /// is unreachable or any model cannot ship a coherent snapshot.
    pub fn bind(addr: &str, cfg: ReplicaConfig) -> Result<Replica, String> {
        let listener =
            TcpListener::bind(addr).map_err(|e| format!("replica bind {addr}: {e}"))?;
        let local = listener
            .local_addr()
            .map_err(|e| format!("replica local_addr: {e}"))?;
        let mut client = Client::connect(&cfg.writer)
            .map_err(|e| format!("writer {} connect: {e}", cfg.writer))?;
        let mut models = HashMap::new();
        for &m in &cfg.models {
            let fetch = client
                .snapshot(m, None)
                .map_err(|e| format!("initial snapshot for model {m}: {e}"))?;
            let bytes = fetch
                .artifact
                .ok_or_else(|| format!("writer sent no artifact for model {m}"))?;
            let (gen, snap) = persist::decode_snapshot(&bytes)
                .map_err(|e| format!("model {m} artifact: {e}"))?;
            let cell = RepModel {
                current: Mutex::new(Arc::new(TaggedSnap { gen, snap })),
                suggest_seq: AtomicU64::new(0),
                snapshots_imported: AtomicU64::new(1),
                invalidations_seen: AtomicU64::new(0),
                refresh_failures: AtomicU64::new(0),
                reads_served: AtomicU64::new(0),
            };
            models.insert(m, cell);
        }
        Ok(Replica {
            listener,
            local,
            shared: Arc::new(RepShared {
                cfg,
                models,
                shutting_down: AtomicBool::new(false),
            }),
        })
    }

    /// The bound serving address.
    pub fn local_addr(&self) -> SocketAddr {
        self.local
    }

    /// The generation currently served for `model` (`None` if the model is
    /// not replicated here).
    pub fn generation(&self, model: u64) -> Option<u64> {
        self.shared.models.get(&model).map(|m| m.cur().gen)
    }

    /// Run the replica until a `shutdown` request arrives: one sync thread
    /// per model (subscribe → invalidate → delta fetch, with reconnect
    /// backoff), plus the accept loop. Joins every thread before
    /// returning the accumulated counters.
    pub fn serve(self) -> ReplicaStats {
        let shared = self.shared;
        let mut syncers: Vec<JoinHandle<()>> = Vec::new();
        for &m in &shared.cfg.models {
            let s = Arc::clone(&shared);
            syncers.push(thread::spawn(move || sync_model(&s, m)));
        }
        let mut conns: Vec<JoinHandle<()>> = Vec::new();
        let _ = self.listener.set_nonblocking(true);
        while !shared.shutting_down.load(Ordering::SeqCst) {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    let _ = stream.set_nonblocking(false);
                    let s = Arc::clone(&shared);
                    conns.push(thread::spawn(move || handle_conn(&s, stream)));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    thread::sleep(Duration::from_millis(10));
                }
                Err(_) => break,
            }
            conns.retain(|h| !h.is_finished());
        }
        for h in conns {
            let _ = h.join();
        }
        for h in syncers {
            let _ = h.join();
        }
        let mut out = ReplicaStats::default();
        for m in shared.models.values() {
            out.snapshots_imported += m.snapshots_imported.load(Ordering::Relaxed);
            out.invalidations_seen += m.invalidations_seen.load(Ordering::Relaxed);
            out.refresh_failures += m.refresh_failures.load(Ordering::Relaxed);
            out.reads_served += m.reads_served.load(Ordering::Relaxed);
        }
        out
    }
}

/// One model's freshness loop: subscribe to the writer, answer each
/// invalidation with a `have_gen` delta fetch, reconnect with backoff on
/// any failure — serving continues from the last coherent import
/// throughout.
fn sync_model(shared: &Arc<RepShared>, model: u64) {
    let cell = match shared.models.get(&model) {
        Some(c) => c,
        None => return,
    };
    let mut backoff_ms = 50u64;
    while !shared.shutting_down.load(Ordering::SeqCst) {
        let attempt = || -> Result<(), String> {
            let mut sub = Client::connect(&shared.cfg.writer)
                .and_then(|c| c.subscribe(model))
                .map_err(|e| e.to_string())?;
            let mut req =
                Client::connect(&shared.cfg.writer).map_err(|e| e.to_string())?;
            // Catch-up fetch: covers mutations that landed between the
            // last import and the subscription ack (and a writer restart,
            // where the authoritative generation may have moved backward).
            refresh(cell, model, &mut req)?;
            loop {
                if shared.shutting_down.load(Ordering::SeqCst) {
                    return Ok(());
                }
                match sub.next_event(Some(POLL)) {
                    Ok(Some(_inv)) => {
                        cell.invalidations_seen.fetch_add(1, Ordering::Relaxed);
                        refresh(cell, model, &mut req)?;
                    }
                    Ok(None) => continue,
                    Err(e) => return Err(e.to_string()),
                }
            }
        };
        match attempt() {
            Ok(()) => return, // clean shutdown
            Err(_) => {
                cell.refresh_failures.fetch_add(1, Ordering::Relaxed);
                thread::sleep(Duration::from_millis(backoff_ms));
                backoff_ms = (backoff_ms * 2).min(500);
            }
        }
    }
}

/// Fetch the writer's current artifact for `model` (eliding the payload
/// via `have_gen` when the replica is already coherent) and install it. A
/// transport error propagates (caller reconnects); a decode failure is
/// absorbed by [`RepModel::install`] — last coherent generation keeps
/// serving.
fn refresh(cell: &RepModel, model: u64, req: &mut Client) -> Result<(), String> {
    let have = cell.cur().gen;
    let fetch = req.snapshot(model, Some(have)).map_err(|e| e.to_string())?;
    if let Some(bytes) = fetch.artifact {
        let _ = cell.install(&bytes);
    }
    Ok(())
}

/// One connection: JSON-line request/reply, bounded by the shutdown flag.
fn handle_conn(shared: &Arc<RepShared>, stream: TcpStream) {
    let _ = stream.set_read_timeout(Some(POLL));
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        match reader.read_line(&mut line) {
            Ok(0) => return,
            Ok(_) if !line.ends_with('\n') => return, // EOF mid-line
            Ok(_) => {}
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                // A timeout mid-line leaves the partial in `line`; keep it.
                if shared.shutting_down.load(Ordering::SeqCst) {
                    return;
                }
                continue;
            }
            Err(_) => return,
        }
        let text = std::mem::take(&mut line);
        if text.trim().is_empty() {
            continue;
        }
        let (resp, id, version) = dispatch(shared, text.trim());
        let out = format!("{}\n", resp.to_json_v(id, version));
        if writer.write_all(out.as_bytes()).is_err() {
            return;
        }
    }
}

/// Serve one request from the imported snapshots. Reads come through the
/// same helpers as the writer's native path; everything mutating is
/// refused with a structured read-only error.
fn dispatch(shared: &RepShared, line: &str) -> (Response, Option<f64>, u64) {
    let (req, meta) = match Request::parse_wire(line) {
        Ok(v) => v,
        Err(e) => return (Response::Error(e), None, 1),
    };
    let (id, version) = (meta.id, meta.version);
    let model_of = |m: u64| -> Result<&RepModel, Response> {
        shared
            .models
            .get(&m)
            .ok_or_else(|| Response::Error(format!("model {m} is not replicated here")))
    };
    let resp = match req {
        Request::Ping => Response::Hello { version: PROTOCOL_VERSION },
        Request::Shutdown => {
            shared.shutting_down.store(true, Ordering::SeqCst);
            Response::Ok
        }
        Request::Predict { model, xs, beta, grad } => match model_of(model) {
            Err(e) => e,
            Ok(cell) => {
                let cur = cell.cur();
                let d = cur.snap.input_dim();
                if xs.iter().any(|r| r.len() != d) {
                    Response::Error(format!("expected {d}-dim points"))
                } else {
                    cell.reads_served.fetch_add(xs.len() as u64, Ordering::Relaxed);
                    predict_on_snapshot(&cur.snap, &xs, beta, grad)
                }
            }
        },
        Request::Suggest { model, beta } => match model_of(model) {
            Err(e) => e,
            Ok(cell) => {
                let cur = cell.cur();
                let seq = cell.suggest_seq.fetch_add(1, Ordering::SeqCst);
                let x = suggest_on_snapshot(
                    &cur.snap,
                    cur.snap.input_dim(),
                    shared.cfg.lo,
                    shared.cfg.hi,
                    shared.cfg.seed ^ model,
                    seq,
                    beta,
                );
                cell.reads_served.fetch_add(1, Ordering::Relaxed);
                Response::Suggestion { x }
            }
        },
        Request::Snapshot { model, have_gen } => match model_of(model) {
            Err(e) => e,
            Ok(cell) => {
                // Re-export: a replica can feed another reader (or the CI
                // bit-identity check) the exact artifact it serves from.
                let cur = cell.cur();
                if have_gen == Some(cur.gen) {
                    Response::Snapshot { gen: cur.gen, artifact: None }
                } else {
                    let bytes = persist::encode_snapshot(&cur.snap, cur.gen);
                    Response::Snapshot {
                        gen: cur.gen,
                        artifact: Some(hex_encode(&bytes)),
                    }
                }
            }
        },
        Request::Audit { model } => match model_of(model) {
            Err(e) => e,
            Ok(cell) => match cell.cur().snap.audit() {
                Ok(()) => Response::AuditReport {
                    passed: true,
                    structures: 1,
                    violation: String::new(),
                },
                Err(e) => Response::AuditReport {
                    passed: false,
                    structures: 1,
                    violation: e.to_string(),
                },
            },
        },
        Request::Subscribe { .. } => Response::Error(
            "replica does not push invalidations; subscribe to the home shard".into(),
        ),
        Request::CreateModel { .. }
        | Request::Observe { .. }
        | Request::ObserveBatch { .. }
        | Request::Forget { .. }
        | Request::ForgetBatch { .. }
        | Request::RollingWindow { .. }
        | Request::Fit { .. }
        | Request::Stats { .. } => Response::Error(
            "replica is read-only: route this op to the home shard".into(),
        ),
    };
    (resp, id, version)
}
