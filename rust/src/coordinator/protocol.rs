//! JSON-line wire protocol for the coordinator.
//!
//! One JSON object per line in both directions. Requests carry an `op` and
//! (except `create_model`) a `model` id; responses always carry `ok` and
//! echo the request's `id` when present.
//!
//! ## Versioning
//!
//! Requests may declare a protocol version in an explicit `v` field. A
//! missing `v` means **v1** — the pre-forget wire format, kept parseable
//! forever so existing clients never break (`tests/protocol_compat.rs`
//! pins both paths). The sliding-window ops (`forget`, `forget_batch`,
//! `rolling_window`) were introduced in **v2**: a frame naming one of them
//! under a declared `v: 1` is rejected with a structured error rather than
//! silently accepted, and any `v` above [`PROTOCOL_VERSION`] is rejected
//! outright so future clients fail loudly against old servers.
//!
//! **v3** is the replication + redesign generation: the `snapshot` and
//! `subscribe` ops (read replicas pull generation-numbered
//! [`crate::gp::persist::encode_snapshot`] artifacts and receive
//! invalidation pushes), and a restructured `stats` reply — requests
//! declaring `v >= 3` receive the counters grouped into nested `solve` /
//! `storage` / `journal` / `pool` / `window` / `replication` sections,
//! while v1/v2 requests keep receiving the flat accreted form byte-for-byte
//! (both shapes pinned in `tests/protocol_compat.rs`). Prefer the typed
//! [`crate::coordinator::client::Client`] over hand-rolled frames.

use crate::util::Json;

/// Highest protocol version this server speaks. History:
/// * **1** — create/observe/fit/predict/suggest/stats/audit/shutdown.
/// * **2** — adds `forget`, `forget_batch`, `rolling_window`, the
///   `Forgotten` response, and the `window_evictions`/`window_occupancy`
///   stats fields.
/// * **3** — adds `snapshot`/`subscribe` (snapshot-shipping read replicas),
///   the `ping` versioned hello, the `Snapshot`/`Subscribed`/`Invalidate`/
///   `Hello` responses, and the nested `stats` sections (flat form still
///   served to v1/v2 requests).
pub const PROTOCOL_VERSION: u64 = 3;

/// Encode bytes as lowercase hex — how binary snapshot artifacts travel
/// inside the JSON-line wire format (the image ships no base64 either; hex
/// keeps decode trivially panic-free).
pub fn hex_encode(bytes: &[u8]) -> String {
    let mut s = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        s.push(char::from_digit((b >> 4) as u32, 16).unwrap_or('0'));
        s.push(char::from_digit((b & 0xF) as u32, 16).unwrap_or('0'));
    }
    s
}

/// Decode [`hex_encode`] output. Errors (never panics) on odd length or
/// non-hex bytes, so a corrupt wire frame surfaces as a structured error.
pub fn hex_decode(s: &str) -> Result<Vec<u8>, String> {
    let b = s.as_bytes();
    if b.len() % 2 != 0 {
        return Err(format!("hex payload has odd length {}", b.len()));
    }
    let nibble = |c: u8| -> Result<u8, String> {
        match c {
            b'0'..=b'9' => Ok(c - b'0'),
            b'a'..=b'f' => Ok(c - b'a' + 10),
            b'A'..=b'F' => Ok(c - b'A' + 10),
            _ => Err(format!("bad hex byte {:?}", c as char)),
        }
    };
    let mut out = Vec::with_capacity(b.len() / 2);
    for pair in b.chunks_exact(2) {
        out.push((nibble(pair[0])? << 4) | nibble(pair[1])?);
    }
    Ok(out)
}

/// A parsed client request.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    CreateModel {
        d: usize,
        /// 2ν (1, 3 or 5).
        nu2: usize,
        omega: f64,
        sigma2: f64,
    },
    Observe {
        model: u64,
        x: Vec<f64>,
        y: f64,
    },
    ObserveBatch {
        model: u64,
        xs: Vec<Vec<f64>>,
        ys: Vec<f64>,
    },
    Fit {
        model: u64,
        steps: usize,
    },
    Predict {
        model: u64,
        xs: Vec<Vec<f64>>,
        beta: f64,
        grad: bool,
    },
    Suggest {
        model: u64,
        beta: f64,
    },
    /// Release the most recent observation whose coordinates equal `x`
    /// (v2; the deletion mirror of `observe`).
    Forget {
        model: u64,
        x: Vec<f64>,
    },
    /// Release a batch of observations by value (v2; one union-window
    /// downdate per dimension, the mirror of `observe_batch`).
    ForgetBatch {
        model: u64,
        xs: Vec<Vec<f64>>,
    },
    /// Put the model into sliding-window mode (v2): after each ingest the
    /// engine evicts oldest-first until at most `max_n` observations remain
    /// and (when `max_age` is set) none is older than `max_age` ingest
    /// ticks. `max_n = 0` switches rolling mode off.
    RollingWindow {
        model: u64,
        max_n: usize,
        max_age: Option<u64>,
    },
    Stats {
        model: u64,
    },
    /// Fetch the model's current posterior as a generation-numbered
    /// snapshot artifact (v3; the replica pull path). When `have_gen`
    /// matches the model's current generation the reply is a payload-free
    /// `unchanged` ack — the cheap no-op "delta" — otherwise the full
    /// artifact ships.
    Snapshot {
        model: u64,
        have_gen: Option<u64>,
    },
    /// Convert this connection into an invalidation push stream (v3): the
    /// server acks with the model's current generation, then writes one
    /// `Invalidate` event line per mutation generation until the client
    /// disconnects. A replica holds one subscription plus a separate
    /// request connection for `snapshot` fetches.
    Subscribe {
        model: u64,
    },
    /// The versioned hello (v3): a model-free no-op whose reply reports the
    /// server's [`PROTOCOL_VERSION`]. The typed client sends one at connect
    /// time, so a version mismatch surfaces as a structured error before
    /// any real traffic.
    Ping,
    /// Run the structural invariant audit (`AdditiveGP::run_audit`) on
    /// demand — every stateful structure in the model walks its own
    /// invariants and the first violation is reported with its
    /// structure/field/index coordinates. Served on the concurrent read
    /// path; works with or without the `strict-invariants` build feature.
    Audit {
        model: u64,
    },
    Shutdown,
}

impl Request {
    /// Parse one request line. Returns `(request, client id echo)`.
    pub fn parse(line: &str) -> Result<(Request, Option<f64>), String> {
        let (req, id, _) = Request::parse_meta(line)?;
        Ok((req, id))
    }

    /// Parse one request line, also extracting the optional per-request
    /// `deadline_ms` budget (additive field, no version bump: old servers
    /// ignore it, old clients never send it). Returns
    /// `(request, client id echo, deadline_ms)`. A non-positive or
    /// non-integral `deadline_ms` is a structured parse error rather than a
    /// silently unbounded request.
    pub fn parse_meta(line: &str) -> Result<(Request, Option<f64>, Option<u64>), String> {
        let (req, meta) = Request::parse_wire(line)?;
        Ok((req, meta.id, meta.deadline_ms))
    }

    /// Parse one request line keeping *all* frame metadata, including the
    /// declared protocol version — the server threads it through to
    /// response serialization so v1/v2 clients keep the flat `stats` shape
    /// while v3 clients get the nested sections.
    pub fn parse_wire(line: &str) -> Result<(Request, RequestMeta), String> {
        let v = Json::parse(line)?;
        let deadline_ms = match v.get("deadline_ms") {
            None => None,
            Some(x) => Some(
                x.as_f64()
                    .filter(|f| f.fract() == 0.0 && *f >= 1.0)
                    .map(|f| f as u64)
                    .ok_or("bad deadline_ms (want positive integer milliseconds)")?,
            ),
        };
        let id = v.get("id").and_then(|x| x.as_f64());
        let op = v.get("op").and_then(|x| x.as_str()).ok_or("missing op")?;
        // Explicit protocol version; a missing `v` is the legacy v1 wire
        // format (pinned compatible forever).
        let version = match v.get("v") {
            None => 1,
            Some(x) => x
                .as_f64()
                .filter(|f| f.fract() == 0.0 && *f >= 1.0)
                .map(|f| f as u64)
                .ok_or("bad protocol version 'v'")?,
        };
        if version > PROTOCOL_VERSION {
            return Err(format!(
                "unsupported protocol version {version} (server speaks <= {PROTOCOL_VERSION})"
            ));
        }
        if matches!(op, "forget" | "forget_batch" | "rolling_window") && version < 2 {
            return Err(format!(
                "op '{op}' requires protocol v2 (request declared v{version})"
            ));
        }
        if matches!(op, "snapshot" | "subscribe" | "ping") && version < 3 {
            return Err(format!(
                "op '{op}' requires protocol v3 (request declared v{version})"
            ));
        }
        let model = || -> Result<u64, String> {
            v.get("model")
                .and_then(|x| x.as_f64())
                .map(|x| x as u64)
                .ok_or_else(|| "missing model".into())
        };
        let xs_field = |key: &str| -> Result<Vec<Vec<f64>>, String> {
            v.get(key)
                .and_then(|x| x.as_arr())
                .ok_or_else(|| format!("missing {key}"))?
                .iter()
                .map(|row| row.as_f64_vec().ok_or_else(|| "bad row".to_string()))
                .collect()
        };
        let req = match op {
            "create_model" => Request::CreateModel {
                d: v.get("d").and_then(|x| x.as_usize()).ok_or("missing d")?,
                nu2: v.get("nu2").and_then(|x| x.as_usize()).unwrap_or(1),
                omega: v.get("omega").and_then(|x| x.as_f64()).unwrap_or(1.0),
                sigma2: v.get("sigma2").and_then(|x| x.as_f64()).unwrap_or(1.0),
            },
            "observe" => Request::Observe {
                model: model()?,
                x: v.get("x").and_then(|x| x.as_f64_vec()).ok_or("missing x")?,
                y: v.get("y").and_then(|x| x.as_f64()).ok_or("missing y")?,
            },
            "observe_batch" => Request::ObserveBatch {
                model: model()?,
                xs: xs_field("xs")?,
                ys: v.get("ys").and_then(|x| x.as_f64_vec()).ok_or("missing ys")?,
            },
            "fit" => Request::Fit {
                model: model()?,
                steps: v.get("steps").and_then(|x| x.as_usize()).unwrap_or(10),
            },
            "predict" => Request::Predict {
                model: model()?,
                xs: xs_field("xs")?,
                beta: v.get("beta").and_then(|x| x.as_f64()).unwrap_or(2.0),
                grad: v.get("grad").and_then(|x| x.as_bool()).unwrap_or(false),
            },
            "suggest" => Request::Suggest {
                model: model()?,
                beta: v.get("beta").and_then(|x| x.as_f64()).unwrap_or(2.0),
            },
            "forget" => Request::Forget {
                model: model()?,
                x: v.get("x").and_then(|x| x.as_f64_vec()).ok_or("missing x")?,
            },
            "forget_batch" => Request::ForgetBatch {
                model: model()?,
                xs: xs_field("xs")?,
            },
            "rolling_window" => Request::RollingWindow {
                model: model()?,
                max_n: v.get("max_n").and_then(|x| x.as_usize()).ok_or("missing max_n")?,
                max_age: v.get("max_age").and_then(|x| x.as_usize()).map(|x| x as u64),
            },
            "stats" => Request::Stats { model: model()? },
            "snapshot" => Request::Snapshot {
                model: model()?,
                have_gen: v
                    .get("have_gen")
                    .and_then(|x| x.as_f64())
                    .map(|f| f as u64),
            },
            "subscribe" => Request::Subscribe { model: model()? },
            "ping" => Request::Ping,
            "audit" => Request::Audit { model: model()? },
            "shutdown" => Request::Shutdown,
            other => return Err(format!("unknown op '{other}'")),
        };
        Ok((req, RequestMeta { id, deadline_ms, version }))
    }
}

/// Frame metadata alongside a parsed [`Request`]: the client's `id` echo,
/// the optional `deadline_ms` budget, and the declared protocol version
/// (missing `v` = 1).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RequestMeta {
    pub id: Option<f64>,
    pub deadline_ms: Option<u64>,
    pub version: u64,
}

/// A server response.
#[derive(Clone, Debug)]
pub enum Response {
    Ok,
    Error(String),
    ModelCreated {
        model: u64,
    },
    /// Acknowledges a single-point `observe` (factor patch done, posterior
    /// still lazy), reporting the post-observe data size and how many of the
    /// banded-LU factor updates were served by the prefix-reuse patch vs a
    /// full re-sweep (this call's delta — the production signal for the
    /// DESIGN.md "Sublinear LU patching" crossover).
    Observed {
        n: usize,
        factor_patched: u64,
        factor_resweep: u64,
    },
    /// Acknowledges an `observe_batch` *after* the posterior refresh,
    /// reporting the post-batch data size, which ingest path ran
    /// ("incremental", "refit" or "buffered"), and this call's patched vs
    /// re-swept factor-update counts.
    BatchObserved {
        n: usize,
        path: &'static str,
        factor_patched: u64,
        factor_resweep: u64,
    },
    Prediction {
        mu: Vec<f64>,
        svar: Vec<f64>,
        acq: Vec<f64>,
        /// Row-major `[B, D]`; empty when gradients were not requested.
        gacq: Vec<Vec<f64>>,
        /// Which execution path served it: "pjrt" or "native".
        path: &'static str,
    },
    Suggestion {
        x: Vec<f64>,
    },
    /// Acknowledges a `forget`/`forget_batch` (v2): post-forget data size,
    /// how many observations were actually released (a by-value forget that
    /// matches nothing removes zero), and this call's patched vs re-swept
    /// factor-update counts — the downdate mirror of `Observed`.
    Forgotten {
        n: usize,
        removed: usize,
        factor_patched: u64,
        factor_resweep: u64,
    },
    /// A snapshot artifact reply (v3): the model's current mutation
    /// generation and, unless the client already holds it (`have_gen`
    /// matched), the hex-encoded [`crate::gp::persist::encode_snapshot`]
    /// artifact.
    Snapshot {
        gen: u64,
        artifact: Option<String>,
    },
    /// Acknowledges a `subscribe` (v3) with the model's current generation;
    /// `Invalidate` events follow on the same connection.
    Subscribed {
        gen: u64,
    },
    /// Answers a `ping` (v3) with the server's [`PROTOCOL_VERSION`].
    Hello {
        version: u64,
    },
    /// An invalidation push event (v3): the model advanced to `gen`.
    /// Written server→client on subscribed connections only, never as a
    /// direct reply.
    Invalidate {
        model: u64,
        gen: u64,
    },
    /// Result of an on-demand `audit` request: whether every structural
    /// invariant held, how many structures were walked, and (on failure)
    /// the violation rendered as `Structure.field[index]: detail` — empty
    /// string when the audit passed.
    AuditReport {
        passed: bool,
        structures: u64,
        violation: String,
    },
    Stats {
        n: usize,
        d: usize,
        omegas: Vec<f64>,
        cache_hits: u64,
        cache_misses: u64,
        pjrt_batches: u64,
        native_queries: u64,
        /// Cumulative prefix-reuse LU patches across the model's lifetime.
        factor_patches: u64,
        /// Cumulative full LU re-sweeps.
        factor_resweeps: u64,
        /// How many times the `M̃` cache was wholesale-cleared because an
        /// insert exceeded its remap limits (formerly a *silent* truncation
        /// path; refit-driven clears are not counted).
        cache_truncations: u64,
        /// Batched inserts that fell back to a sequential replay + full
        /// rebuild in some dimension (the other formerly-silent path).
        fallback_rebuilds: u64,
        /// Shared worker-pool observability (the pool serves *all* models;
        /// these fields are pool-wide, identical in every model's reply):
        /// fixed worker count, workers currently running a job (occupancy),
        /// jobs queued across all per-worker queues, and cumulative
        /// work-steals.
        pool_workers: u64,
        pool_busy: u64,
        pool_queue_depth: u64,
        pool_steals: u64,
        /// Chunked COW band-storage observability (DESIGN.md "Chunked COW
        /// band storage"): cumulative bytes shifted by mid-matrix band
        /// splices (appends move none), chunks deep-copied by
        /// copy-on-write, and chunks handed to posterior snapshots by
        /// reference instead of deep copy.
        memmove_bytes: u64,
        chunks_copied: u64,
        chunks_shared: u64,
        /// Sliding-window observability (v2): observations evicted by the
        /// rolling-window policy over the model's lifetime, and how many
        /// observations currently sit in the window (equals `n`; reported
        /// separately so dashboards can chart occupancy against the
        /// configured `max_n` without conflating it with non-rolling
        /// models).
        window_evictions: u64,
        window_occupancy: u64,
        /// Fault-tolerance observability (DESIGN.md §Durability). How many
        /// times this model's engine panicked and was resurrected in place
        /// from its mutation journal instead of being quarantined.
        recoveries: u64,
        /// True once journaling for this model has been disabled after an
        /// append/checkpoint failure: the model keeps serving (graceful
        /// degradation) but will not survive a crash beyond its last good
        /// record, and panic resurrection is withheld.
        degraded: bool,
        /// Mutation records appended to this model's journal, bytes written
        /// to it (records + checkpoints), and checkpoint compactions
        /// performed. All zero when the scheduler runs without a journal.
        journal_appends: u64,
        journal_bytes: u64,
        journal_checkpoints: u64,
        /// PCG degradation ladder: warm-start solves that had to be retried
        /// from a cold start, and cold retries that still failed and
        /// escalated to a full refit.
        solve_cold_retries: u64,
        solve_refit_escalations: u64,
        /// Replication observability (v3; DESIGN.md §Replication): snapshot
        /// artifacts exported to replicas, invalidation events pushed to
        /// subscribers, and subscriptions currently attached to this model.
        /// Deliberately *absent* from the flat (v1/v2) serialization — the
        /// legacy shape is golden-pinned — and emitted only inside the v3
        /// `replication` section.
        snapshots_exported: u64,
        invalidations_sent: u64,
        subscribers: u64,
    },
}

impl Response {
    /// Serialize with the echoed request id.
    pub fn to_json(&self, id: Option<f64>) -> Json {
        let mut pairs: Vec<(&str, Json)> = Vec::new();
        if let Some(id) = id {
            pairs.push(("id", Json::Num(id)));
        }
        match self {
            Response::Ok => pairs.push(("ok", Json::Bool(true))),
            Response::Error(e) => {
                pairs.push(("ok", Json::Bool(false)));
                pairs.push(("error", Json::Str(e.clone())));
            }
            Response::ModelCreated { model } => {
                pairs.push(("ok", Json::Bool(true)));
                pairs.push(("model", Json::Num(*model as f64)));
            }
            Response::Observed { n, factor_patched, factor_resweep } => {
                pairs.push(("ok", Json::Bool(true)));
                pairs.push(("n", Json::Num(*n as f64)));
                pairs.push(("factor_patched", Json::Num(*factor_patched as f64)));
                pairs.push(("factor_resweep", Json::Num(*factor_resweep as f64)));
            }
            Response::BatchObserved { n, path, factor_patched, factor_resweep } => {
                pairs.push(("ok", Json::Bool(true)));
                pairs.push(("n", Json::Num(*n as f64)));
                pairs.push(("path", Json::Str(path.to_string())));
                pairs.push(("factor_patched", Json::Num(*factor_patched as f64)));
                pairs.push(("factor_resweep", Json::Num(*factor_resweep as f64)));
            }
            Response::Prediction { mu, svar, acq, gacq, path } => {
                pairs.push(("ok", Json::Bool(true)));
                pairs.push(("mu", Json::arr_f64(mu)));
                pairs.push(("svar", Json::arr_f64(svar)));
                pairs.push(("acq", Json::arr_f64(acq)));
                pairs.push((
                    "gacq",
                    Json::Arr(gacq.iter().map(|row| Json::arr_f64(row)).collect()),
                ));
                pairs.push(("path", Json::Str(path.to_string())));
            }
            Response::Suggestion { x } => {
                pairs.push(("ok", Json::Bool(true)));
                pairs.push(("x", Json::arr_f64(x)));
            }
            Response::Forgotten { n, removed, factor_patched, factor_resweep } => {
                pairs.push(("ok", Json::Bool(true)));
                pairs.push(("n", Json::Num(*n as f64)));
                pairs.push(("removed", Json::Num(*removed as f64)));
                pairs.push(("factor_patched", Json::Num(*factor_patched as f64)));
                pairs.push(("factor_resweep", Json::Num(*factor_resweep as f64)));
            }
            Response::AuditReport { passed, structures, violation } => {
                pairs.push(("ok", Json::Bool(true)));
                pairs.push(("passed", Json::Bool(*passed)));
                pairs.push(("structures", Json::Num(*structures as f64)));
                pairs.push(("violation", Json::Str(violation.clone())));
            }
            Response::Snapshot { gen, artifact } => {
                pairs.push(("ok", Json::Bool(true)));
                pairs.push(("gen", Json::Num(*gen as f64)));
                match artifact {
                    Some(hex) => pairs.push(("snapshot", Json::Str(hex.clone()))),
                    None => pairs.push(("unchanged", Json::Bool(true))),
                }
            }
            Response::Subscribed { gen } => {
                pairs.push(("ok", Json::Bool(true)));
                pairs.push(("subscribed", Json::Bool(true)));
                pairs.push(("gen", Json::Num(*gen as f64)));
            }
            Response::Hello { version } => {
                pairs.push(("ok", Json::Bool(true)));
                pairs.push(("server_version", Json::Num(*version as f64)));
            }
            Response::Invalidate { model, gen } => {
                pairs.push(("ok", Json::Bool(true)));
                pairs.push(("event", Json::Str("invalidate".to_string())));
                pairs.push(("model", Json::Num(*model as f64)));
                pairs.push(("gen", Json::Num(*gen as f64)));
            }
            Response::Stats {
                n,
                d,
                omegas,
                cache_hits,
                cache_misses,
                pjrt_batches,
                native_queries,
                factor_patches,
                factor_resweeps,
                cache_truncations,
                fallback_rebuilds,
                pool_workers,
                pool_busy,
                pool_queue_depth,
                pool_steals,
                memmove_bytes,
                chunks_copied,
                chunks_shared,
                window_evictions,
                window_occupancy,
                recoveries,
                degraded,
                journal_appends,
                journal_bytes,
                journal_checkpoints,
                solve_cold_retries,
                solve_refit_escalations,
                // The replication counters are v3-only: the flat shape
                // below is the v1/v2 wire format, pinned byte-for-byte in
                // tests/protocol_compat.rs, so they must not appear here.
                snapshots_exported: _,
                invalidations_sent: _,
                subscribers: _,
            } => {
                pairs.push(("ok", Json::Bool(true)));
                pairs.push(("n", Json::Num(*n as f64)));
                pairs.push(("d", Json::Num(*d as f64)));
                pairs.push(("omegas", Json::arr_f64(omegas)));
                pairs.push(("cache_hits", Json::Num(*cache_hits as f64)));
                pairs.push(("cache_misses", Json::Num(*cache_misses as f64)));
                pairs.push(("pjrt_batches", Json::Num(*pjrt_batches as f64)));
                pairs.push(("native_queries", Json::Num(*native_queries as f64)));
                pairs.push(("factor_patches", Json::Num(*factor_patches as f64)));
                pairs.push(("factor_resweeps", Json::Num(*factor_resweeps as f64)));
                pairs.push(("cache_truncations", Json::Num(*cache_truncations as f64)));
                pairs.push(("fallback_rebuilds", Json::Num(*fallback_rebuilds as f64)));
                pairs.push(("pool_workers", Json::Num(*pool_workers as f64)));
                pairs.push(("pool_busy", Json::Num(*pool_busy as f64)));
                pairs.push(("pool_queue_depth", Json::Num(*pool_queue_depth as f64)));
                pairs.push(("pool_steals", Json::Num(*pool_steals as f64)));
                pairs.push(("memmove_bytes", Json::Num(*memmove_bytes as f64)));
                pairs.push(("chunks_copied", Json::Num(*chunks_copied as f64)));
                pairs.push(("chunks_shared", Json::Num(*chunks_shared as f64)));
                pairs.push(("window_evictions", Json::Num(*window_evictions as f64)));
                pairs.push(("window_occupancy", Json::Num(*window_occupancy as f64)));
                pairs.push(("recoveries", Json::Num(*recoveries as f64)));
                pairs.push(("degraded", Json::Bool(*degraded)));
                pairs.push(("journal_appends", Json::Num(*journal_appends as f64)));
                pairs.push(("journal_bytes", Json::Num(*journal_bytes as f64)));
                pairs.push(("journal_checkpoints", Json::Num(*journal_checkpoints as f64)));
                pairs.push(("solve_cold_retries", Json::Num(*solve_cold_retries as f64)));
                pairs.push((
                    "solve_refit_escalations",
                    Json::Num(*solve_refit_escalations as f64),
                ));
            }
        }
        Json::obj(pairs)
    }

    /// Serialize honoring the request's declared protocol version: `stats`
    /// replies to v3+ requests carry the counters grouped into nested
    /// `solve`/`storage`/`journal`/`pool`/`window`/`replication` sections;
    /// every other (response, version) pair is identical to [`to_json`].
    /// Both shapes are golden-pinned in `tests/protocol_compat.rs`.
    ///
    /// [`to_json`]: Response::to_json
    pub fn to_json_v(&self, id: Option<f64>, version: u64) -> Json {
        if version < 3 {
            return self.to_json(id);
        }
        match self {
            Response::Stats {
                n,
                d,
                omegas,
                cache_hits,
                cache_misses,
                pjrt_batches,
                native_queries,
                factor_patches,
                factor_resweeps,
                cache_truncations,
                fallback_rebuilds,
                pool_workers,
                pool_busy,
                pool_queue_depth,
                pool_steals,
                memmove_bytes,
                chunks_copied,
                chunks_shared,
                window_evictions,
                window_occupancy,
                recoveries,
                degraded,
                journal_appends,
                journal_bytes,
                journal_checkpoints,
                solve_cold_retries,
                solve_refit_escalations,
                snapshots_exported,
                invalidations_sent,
                subscribers,
            } => {
                let num = |v: u64| Json::Num(v as f64);
                let mut pairs: Vec<(&str, Json)> = Vec::new();
                if let Some(id) = id {
                    pairs.push(("id", Json::Num(id)));
                }
                pairs.push(("ok", Json::Bool(true)));
                pairs.push(("n", Json::Num(*n as f64)));
                pairs.push(("d", Json::Num(*d as f64)));
                pairs.push(("omegas", Json::arr_f64(omegas)));
                pairs.push((
                    "solve",
                    Json::obj(vec![
                        ("cache_hits", num(*cache_hits)),
                        ("cache_misses", num(*cache_misses)),
                        ("pjrt_batches", num(*pjrt_batches)),
                        ("native_queries", num(*native_queries)),
                        ("factor_patches", num(*factor_patches)),
                        ("factor_resweeps", num(*factor_resweeps)),
                        ("cache_truncations", num(*cache_truncations)),
                        ("fallback_rebuilds", num(*fallback_rebuilds)),
                        ("cold_retries", num(*solve_cold_retries)),
                        ("refit_escalations", num(*solve_refit_escalations)),
                    ]),
                ));
                pairs.push((
                    "storage",
                    Json::obj(vec![
                        ("memmove_bytes", num(*memmove_bytes)),
                        ("chunks_copied", num(*chunks_copied)),
                        ("chunks_shared", num(*chunks_shared)),
                    ]),
                ));
                pairs.push((
                    "journal",
                    Json::obj(vec![
                        ("appends", num(*journal_appends)),
                        ("bytes", num(*journal_bytes)),
                        ("checkpoints", num(*journal_checkpoints)),
                        ("recoveries", num(*recoveries)),
                        ("degraded", Json::Bool(*degraded)),
                    ]),
                ));
                pairs.push((
                    "pool",
                    Json::obj(vec![
                        ("workers", num(*pool_workers)),
                        ("busy", num(*pool_busy)),
                        ("queue_depth", num(*pool_queue_depth)),
                        ("steals", num(*pool_steals)),
                    ]),
                ));
                pairs.push((
                    "window",
                    Json::obj(vec![
                        ("evictions", num(*window_evictions)),
                        ("occupancy", num(*window_occupancy)),
                    ]),
                ));
                pairs.push((
                    "replication",
                    Json::obj(vec![
                        ("snapshots_exported", num(*snapshots_exported)),
                        ("invalidations_sent", num(*invalidations_sent)),
                        ("subscribers", num(*subscribers)),
                    ]),
                ));
                Json::obj(pairs)
            }
            other => other.to_json(id),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        let (r, id) = Request::parse(
            r#"{"op":"predict","model":3,"xs":[[1,2],[3,4]],"beta":1.5,"grad":true,"id":9}"#,
        )
        .unwrap();
        assert_eq!(id, Some(9.0));
        match r {
            Request::Predict { model, xs, beta, grad } => {
                assert_eq!(model, 3);
                assert_eq!(xs, vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
                assert_eq!(beta, 1.5);
                assert!(grad);
            }
            _ => panic!("wrong variant"),
        }
    }

    #[test]
    fn parse_create_and_errors() {
        let (r, _) = Request::parse(r#"{"op":"create_model","d":5}"#).unwrap();
        assert_eq!(
            r,
            Request::CreateModel { d: 5, nu2: 1, omega: 1.0, sigma2: 1.0 }
        );
        assert!(Request::parse(r#"{"op":"nope"}"#).is_err());
        assert!(Request::parse("garbage").is_err());
        assert!(Request::parse(r#"{"op":"observe","x":[1],"y":2}"#).is_err());
    }

    #[test]
    fn version_gates_v2_ops() {
        // Legacy frames (no `v`) keep parsing as v1.
        assert!(Request::parse(r#"{"op":"stats","model":1}"#).is_ok());
        // v1 ops still parse under an explicit v2 declaration.
        assert!(Request::parse(r#"{"op":"stats","model":1,"v":2}"#).is_ok());
        // v2 ops require the declaration...
        let e = Request::parse(r#"{"op":"forget","model":1,"x":[1.0]}"#).unwrap_err();
        assert!(e.contains("requires protocol v2"), "got: {e}");
        let e =
            Request::parse(r#"{"op":"rolling_window","model":1,"max_n":10,"v":1}"#).unwrap_err();
        assert!(e.contains("requires protocol v2"), "got: {e}");
        // ...and future versions are rejected loudly.
        let e = Request::parse(r#"{"op":"stats","model":1,"v":4}"#).unwrap_err();
        assert!(e.contains("unsupported protocol version 4"), "got: {e}");
        assert!(Request::parse(r#"{"op":"stats","model":1,"v":0}"#).is_err());
        assert!(Request::parse(r#"{"op":"stats","model":1,"v":1.5}"#).is_err());
    }

    #[test]
    fn version_gates_v3_ops() {
        // v3 ops require the declaration: legacy and v2 frames are refused.
        let e = Request::parse(r#"{"op":"snapshot","model":1}"#).unwrap_err();
        assert!(e.contains("requires protocol v3"), "got: {e}");
        let e = Request::parse(r#"{"op":"subscribe","model":1,"v":2}"#).unwrap_err();
        assert!(e.contains("requires protocol v3"), "got: {e}");
        // Under v3 they parse, and v1/v2 ops still parse under v3 too.
        let (r, _) = Request::parse(r#"{"op":"snapshot","model":5,"v":3}"#).unwrap();
        assert_eq!(r, Request::Snapshot { model: 5, have_gen: None });
        let (r, _) =
            Request::parse(r#"{"op":"snapshot","model":5,"have_gen":17,"v":3}"#).unwrap();
        assert_eq!(r, Request::Snapshot { model: 5, have_gen: Some(17) });
        let (r, _) = Request::parse(r#"{"op":"subscribe","model":5,"v":3}"#).unwrap();
        assert_eq!(r, Request::Subscribe { model: 5 });
        assert!(Request::parse(r#"{"op":"observe","model":1,"x":[1],"y":2,"v":3}"#).is_ok());
    }

    #[test]
    fn ping_is_v3_and_model_free() {
        let e = Request::parse(r#"{"op":"ping"}"#).unwrap_err();
        assert!(e.contains("requires protocol v3"), "got: {e}");
        let (r, _) = Request::parse(r#"{"op":"ping","v":3}"#).unwrap();
        assert_eq!(r, Request::Ping);
        let j = Response::Hello { version: 3 }.to_json(Some(1.0));
        let v = Json::parse(&j.to_string()).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("server_version").unwrap().as_usize(), Some(3));
        assert_eq!(v.get("id").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn parse_wire_reports_the_declared_version() {
        let (_, meta) = Request::parse_wire(r#"{"op":"stats","model":1}"#).unwrap();
        assert_eq!(meta.version, 1, "missing v is the legacy v1 wire format");
        let (_, meta) =
            Request::parse_wire(r#"{"op":"stats","model":1,"v":3,"id":4,"deadline_ms":50}"#)
                .unwrap();
        assert_eq!(meta, RequestMeta { id: Some(4.0), deadline_ms: Some(50), version: 3 });
    }

    #[test]
    fn hex_roundtrips_and_rejects_garbage() {
        assert_eq!(hex_encode(&[]), "");
        assert_eq!(hex_encode(&[0x00, 0xAB, 0xFF]), "00abff");
        assert_eq!(hex_decode("00abff"), Ok(vec![0x00, 0xAB, 0xFF]));
        assert_eq!(hex_decode("00ABFF"), Ok(vec![0x00, 0xAB, 0xFF]));
        let all: Vec<u8> = (0..=255).collect();
        assert_eq!(hex_decode(&hex_encode(&all)), Ok(all));
        assert!(hex_decode("abc").unwrap_err().contains("odd length"));
        assert!(hex_decode("zz").unwrap_err().contains("bad hex"));
    }

    #[test]
    fn snapshot_and_subscription_responses_serialize() {
        let j = Response::Snapshot { gen: 9, artifact: Some("00ff".to_string()) }
            .to_json(Some(2.0));
        let v = Json::parse(&j.to_string()).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("gen").unwrap().as_usize(), Some(9));
        assert_eq!(v.get("snapshot").unwrap().as_str(), Some("00ff"));
        assert!(v.get("unchanged").is_none());

        let j = Response::Snapshot { gen: 9, artifact: None }.to_json(None);
        let v = Json::parse(&j.to_string()).unwrap();
        assert_eq!(v.get("unchanged").unwrap().as_bool(), Some(true));
        assert!(v.get("snapshot").is_none());

        let j = Response::Subscribed { gen: 3 }.to_json(Some(1.0));
        let v = Json::parse(&j.to_string()).unwrap();
        assert_eq!(v.get("subscribed").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("gen").unwrap().as_usize(), Some(3));

        let j = Response::Invalidate { model: 7, gen: 12 }.to_json(None);
        let v = Json::parse(&j.to_string()).unwrap();
        assert_eq!(v.get("event").unwrap().as_str(), Some("invalidate"));
        assert_eq!(v.get("model").unwrap().as_usize(), Some(7));
        assert_eq!(v.get("gen").unwrap().as_usize(), Some(12));
    }

    #[test]
    fn forget_and_rolling_window_parse() {
        let (r, id) =
            Request::parse(r#"{"op":"forget","model":4,"x":[1.5,2.0],"v":2,"id":3}"#).unwrap();
        assert_eq!(id, Some(3.0));
        assert_eq!(r, Request::Forget { model: 4, x: vec![1.5, 2.0] });
        let (r, _) =
            Request::parse(r#"{"op":"forget_batch","model":4,"xs":[[1,2],[3,4]],"v":2}"#).unwrap();
        assert_eq!(
            r,
            Request::ForgetBatch { model: 4, xs: vec![vec![1.0, 2.0], vec![3.0, 4.0]] }
        );
        let (r, _) = Request::parse(
            r#"{"op":"rolling_window","model":4,"max_n":256,"max_age":50,"v":2}"#,
        )
        .unwrap();
        assert_eq!(
            r,
            Request::RollingWindow { model: 4, max_n: 256, max_age: Some(50) }
        );
        let (r, _) =
            Request::parse(r#"{"op":"rolling_window","model":4,"max_n":0,"v":2}"#).unwrap();
        assert_eq!(r, Request::RollingWindow { model: 4, max_n: 0, max_age: None });
        assert!(Request::parse(r#"{"op":"forget","model":4,"v":2}"#).is_err(), "x required");
        assert!(
            Request::parse(r#"{"op":"rolling_window","model":4,"v":2}"#).is_err(),
            "max_n required"
        );
    }

    #[test]
    fn deadline_ms_parses_and_validates() {
        // No deadline → None, on both parse paths.
        let (_, _, dl) = Request::parse_meta(r#"{"op":"stats","model":1}"#).unwrap();
        assert_eq!(dl, None);
        // A positive integer deadline comes through in milliseconds.
        let (r, id, dl) =
            Request::parse_meta(r#"{"op":"suggest","model":2,"deadline_ms":250,"id":7}"#).unwrap();
        assert_eq!(r, Request::Suggest { model: 2, beta: 2.0 });
        assert_eq!(id, Some(7.0));
        assert_eq!(dl, Some(250));
        // Zero, negative and fractional deadlines are structured errors.
        for bad in [
            r#"{"op":"stats","model":1,"deadline_ms":0}"#,
            r#"{"op":"stats","model":1,"deadline_ms":-5}"#,
            r#"{"op":"stats","model":1,"deadline_ms":1.5}"#,
            r#"{"op":"stats","model":1,"deadline_ms":"soon"}"#,
        ] {
            let e = Request::parse_meta(bad).unwrap_err();
            assert!(e.contains("deadline_ms"), "got: {e}");
        }
        // `parse` ignores the field but still accepts the frame.
        assert!(Request::parse(r#"{"op":"stats","model":1,"deadline_ms":250}"#).is_ok());
    }

    #[test]
    fn forgotten_serializes() {
        let j = Response::Forgotten { n: 99, removed: 1, factor_patched: 8, factor_resweep: 0 }
            .to_json(Some(6.0));
        let v = Json::parse(&j.to_string()).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("id").unwrap().as_f64(), Some(6.0));
        assert_eq!(v.get("n").unwrap().as_usize(), Some(99));
        assert_eq!(v.get("removed").unwrap().as_usize(), Some(1));
        assert_eq!(v.get("factor_patched").unwrap().as_usize(), Some(8));
        assert_eq!(v.get("factor_resweep").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn batch_observed_serializes() {
        let j = Response::BatchObserved {
            n: 128,
            path: "incremental",
            factor_patched: 12,
            factor_resweep: 0,
        }
        .to_json(Some(2.0));
        let v = Json::parse(&j.to_string()).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("id").unwrap().as_f64(), Some(2.0));
        assert_eq!(v.get("n").unwrap().as_usize(), Some(128));
        assert_eq!(v.get("path").unwrap().as_str(), Some("incremental"));
        assert_eq!(v.get("factor_patched").unwrap().as_usize(), Some(12));
        assert_eq!(v.get("factor_resweep").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn observed_serializes() {
        let j = Response::Observed { n: 40, factor_patched: 4, factor_resweep: 0 }.to_json(None);
        let v = Json::parse(&j.to_string()).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("n").unwrap().as_usize(), Some(40));
        assert_eq!(v.get("factor_patched").unwrap().as_usize(), Some(4));
        assert_eq!(v.get("factor_resweep").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn audit_parses_and_report_serializes() {
        let (r, id) = Request::parse(r#"{"op":"audit","model":7,"id":11}"#).unwrap();
        assert_eq!(id, Some(11.0));
        assert_eq!(r, Request::Audit { model: 7 });
        assert!(Request::parse(r#"{"op":"audit"}"#).is_err(), "model is required");

        let j = Response::AuditReport {
            passed: false,
            structures: 25,
            violation: "Banded.data[3]: non-finite entry".to_string(),
        }
        .to_json(Some(11.0));
        let v = Json::parse(&j.to_string()).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("passed").unwrap().as_bool(), Some(false));
        assert_eq!(v.get("structures").unwrap().as_usize(), Some(25));
        assert_eq!(
            v.get("violation").unwrap().as_str(),
            Some("Banded.data[3]: non-finite entry")
        );
    }

    fn full_stats() -> Response {
        Response::Stats {
            n: 10,
            d: 2,
            omegas: vec![1.0, 2.0],
            cache_hits: 3,
            cache_misses: 4,
            pjrt_batches: 5,
            native_queries: 6,
            factor_patches: 7,
            factor_resweeps: 8,
            cache_truncations: 9,
            fallback_rebuilds: 10,
            pool_workers: 11,
            pool_busy: 12,
            pool_queue_depth: 13,
            pool_steals: 14,
            memmove_bytes: 15,
            chunks_copied: 16,
            chunks_shared: 17,
            window_evictions: 18,
            window_occupancy: 19,
            recoveries: 20,
            degraded: true,
            journal_appends: 21,
            journal_bytes: 22,
            journal_checkpoints: 23,
            solve_cold_retries: 24,
            solve_refit_escalations: 25,
            snapshots_exported: 26,
            invalidations_sent: 27,
            subscribers: 28,
        }
    }

    #[test]
    fn stats_nests_under_v3_and_stays_flat_below() {
        let resp = full_stats();
        // v1/v2 (and the legacy to_json): flat counters, no sections, and
        // no replication fields at all.
        for flat in [resp.to_json(Some(1.0)), resp.to_json_v(Some(1.0), 2)] {
            let v = Json::parse(&flat.to_string()).unwrap();
            assert_eq!(v.get("cache_hits").unwrap().as_usize(), Some(3));
            assert_eq!(v.get("journal_appends").unwrap().as_usize(), Some(21));
            assert!(v.get("solve").is_none());
            assert!(v.get("replication").is_none());
            assert!(v.get("snapshots_exported").is_none());
        }
        // v3: nested sections, no flat counters.
        let v = Json::parse(&resp.to_json_v(Some(1.0), 3).to_string()).unwrap();
        assert!(v.get("cache_hits").is_none());
        assert!(v.get("journal_appends").is_none());
        assert_eq!(v.get("n").unwrap().as_usize(), Some(10));
        assert_eq!(v.get("solve").unwrap().get("cache_hits").unwrap().as_usize(), Some(3));
        assert_eq!(v.get("solve").unwrap().get("refit_escalations").unwrap().as_usize(), Some(25));
        assert_eq!(v.get("storage").unwrap().get("memmove_bytes").unwrap().as_usize(), Some(15));
        assert_eq!(v.get("journal").unwrap().get("appends").unwrap().as_usize(), Some(21));
        assert_eq!(v.get("journal").unwrap().get("degraded").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("pool").unwrap().get("workers").unwrap().as_usize(), Some(11));
        assert_eq!(v.get("window").unwrap().get("evictions").unwrap().as_usize(), Some(18));
        let rep = v.get("replication").unwrap();
        assert_eq!(rep.get("snapshots_exported").unwrap().as_usize(), Some(26));
        assert_eq!(rep.get("invalidations_sent").unwrap().as_usize(), Some(27));
        assert_eq!(rep.get("subscribers").unwrap().as_usize(), Some(28));
        // Non-stats responses are version-invariant.
        let a = Response::Ok.to_json_v(Some(2.0), 3).to_string();
        let b = Response::Ok.to_json(Some(2.0)).to_string();
        assert_eq!(a, b);
    }

    #[test]
    fn response_serializes() {
        let resp = Response::Prediction {
            mu: vec![1.0],
            svar: vec![0.5],
            acq: vec![0.2],
            gacq: vec![vec![0.1, -0.2]],
            path: "native",
        };
        let j = resp.to_json(Some(4.0));
        let v = Json::parse(&j.to_string()).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("id").unwrap().as_f64(), Some(4.0));
        assert_eq!(v.get("mu").unwrap().as_f64_vec().unwrap(), vec![1.0]);
        assert_eq!(v.get("path").unwrap().as_str(), Some("native"));
    }
}
