//! The shared worker-pool scheduler: one fixed pool of N workers serves
//! every model in the process (DESIGN.md §Coordinator).
//!
//! Replaces the old one-thread-per-model `ModelEngine::run` loop. Commands
//! fall into two classes:
//!
//! * **Mutating** (`Observe`/`ObserveBatch`/`Forget`/`ForgetBatch`/
//!   `RollingWindow`/`Fit`) — enqueued on the model's
//!   FIFO queue and executed under the model's engine mutex by whichever
//!   worker claims the model's drain job. Per-model ordering and mutual
//!   exclusion are exact; different models mutate concurrently across the
//!   pool (cross-model sharding). Each successful mutation bumps the model's
//!   *generation*, invalidating the read snapshot.
//!
//! * **Read** (`Predict`/`Suggest`/`Stats`) — served against an immutable
//!   [`PosteriorSnapshot`] built lazily once per generation, so reads on one
//!   model run concurrently with each other and with other models' work, and
//!   a giant model's ingest overlaps its own predict traffic. Snapshot
//!   construction is *non-perturbing* (the engine's numeric trajectory stays
//!   bit-identical to a read-free replay — pinned by the determinism stress
//!   test in `tests/concurrency.rs`).
//!
//! **Durability & recovery** (DESIGN.md §Durability): when built
//! [`Scheduler::with_journal`], every successful mutation is appended to the
//! model's journal *after* it applied and before its reply, and the journal
//! is compacted into a bit-exact checkpoint on a configurable cadence.
//! [`Scheduler::recover`] rebuilds the whole fleet from those files after a
//! crash. A *panicked* engine is no longer terminal: the drain job rebuilds
//! it in place from its journal (the panicked command was never journaled,
//! so replay lands exactly on the pre-command state), up to a bounded
//! recovery budget. Journal I/O failures *degrade* the model — journaling
//! stops, serving continues, `Stats` reports `degraded: true`.
//!
//! **PJRT affinity**: compiled `window_acq` executables are not `Send`, so
//! each model's executable lives in a thread-local registry on the pool
//! worker that compiled it, and that model's predicts are submitted with a
//! worker-affinity hint ([`WorkerPool::spawn_pinned`]). Dynamic predict
//! batching is preserved per model: the pinned drain job takes the whole
//! queued backlog and fans each same-`(β, grad)` run through one executable
//! call.

use std::cell::RefCell;
use std::collections::{HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Mutex};

use crate::bo::acquisition::Acquisition;
use crate::bo::run::BoEngine;
use crate::bo::search::{search_next, SearchCfg};
use crate::coordinator::engine::{Command, EngineConfig, ModelEngine};
use crate::coordinator::journal::{self, JournalConfig, ModelJournal, MutationOp};
use crate::coordinator::lock_clean;
use crate::coordinator::protocol::{hex_encode, Response};
use crate::gp::fit_state::PosteriorSnapshot;
use crate::gp::persist;
use crate::gp::posterior::MTildeCache;
use crate::runtime::xla;
use crate::runtime::{ArtifactManifest, WindowExecutable};
use crate::util::pool::{Job, PoolStats, WorkerPool};
use crate::util::Rng;

thread_local! {
    /// Per-worker PJRT registry: model id → (client, executable). Entries
    /// are created by the pinned build job at `create_model` time and die
    /// with the worker thread at pool shutdown — handles never migrate.
    static WORKER_EXES: RefCell<HashMap<u64, ExeEntry>> = RefCell::new(HashMap::new());
}

struct ExeEntry {
    /// Keeps the client alive for the executable's lifetime.
    _client: xla::PjRtClient,
    exe: WindowExecutable,
}

/// One queued predict awaiting the model's pinned PJRT drain job.
struct PredictReq {
    xs: Vec<Vec<f64>>,
    beta: f64,
    grad: bool,
    reply: Sender<Response>,
}

/// Per-model scheduling state shared across pool workers.
struct ModelCell {
    id: u64,
    cfg: EngineConfig,
    engine: Mutex<ModelEngine>,
    /// FIFO of pending mutating commands.
    mut_queue: Mutex<VecDeque<Command>>,
    /// Whether a mutation drain job is scheduled/running (at most one).
    mut_active: AtomicBool,
    /// Pending predicts for the PJRT-batched path.
    predict_queue: Mutex<VecDeque<PredictReq>>,
    predict_active: AtomicBool,
    /// Mutation generation; bumped (under the engine lock) by every
    /// successful mutation. Tags the read snapshot.
    gen: AtomicU64,
    snapshot: Mutex<Option<Arc<TaggedSnapshot>>>,
    /// Pool worker owning this model's PJRT executable (`None` → native
    /// reads through the snapshot).
    exe_worker: Option<usize>,
    /// Set when a command panicked: the engine state is suspect, so every
    /// later command is refused (the per-model analogue of the old dead
    /// engine thread).
    dead: AtomicBool,
    /// Per-suggest seed sequence (each suggest owns an independent rng).
    suggest_seq: AtomicU64,
    /// Rows served by the snapshot (native) read path.
    native_reads: AtomicU64,
    /// Cache stats folded in from retired snapshots.
    read_hits: AtomicU64,
    read_misses: AtomicU64,
    /// The scheduler's journal configuration (None → durability off). Kept
    /// per cell so a panic-resurrection can re-read the files without
    /// reaching back into the registry.
    jcfg: Option<JournalConfig>,
    /// The model's open journal. Locked after the engine mutex wherever
    /// both are held (same order as `snapshot`). Stays present after
    /// degradation so `Stats` keeps reporting its counters; `degraded`
    /// gates all further writes.
    journal: Mutex<Option<ModelJournal>>,
    /// Panic resurrections performed on this model (bounded by
    /// [`MAX_RECOVERIES`]).
    recoveries: AtomicU64,
    /// Latched when a journal append/checkpoint failed (or the journal
    /// could not be created): journaling stops, the model keeps serving,
    /// and panic resurrection is withheld — the on-disk history is no
    /// longer complete, so a rebuild from it would silently lose state.
    degraded: AtomicBool,
    /// Push-invalidation subscribers (protocol v3 `subscribe`): each sender
    /// receives one [`Response::Invalidate`] per generation bump, in
    /// generation order, until its receiver hangs up (pruned on the next
    /// failed send). Locked after the engine mutex wherever both are held
    /// (same order as `snapshot` / `journal`).
    subscribers: Mutex<Vec<Sender<Response>>>,
    /// Snapshot artifacts encoded and shipped (v3 `snapshot` op; payload
    /// actually sent — `have_gen` short-circuits are not counted).
    snapshots_exported: AtomicU64,
    /// Invalidation events delivered to subscribers (lifetime total).
    invalidations_sent: AtomicU64,
    /// Counter continuity across panic resurrection: a recovered engine
    /// restarts its cumulative counters at the journal-replay value, which
    /// sits below the live pre-panic value for anything not serialized in
    /// the checkpoint (storage splice/COW counters, read-path tallies). The
    /// shortfall is captured here at each resurrection and added back by
    /// `serve_stats`, so the wire counters stay monotone and the
    /// saturating-delta folding in [`crate::coordinator::metrics`] cannot
    /// under-count after a recovery.
    metric_base: Mutex<CounterBase>,
}

/// The Stats-visible cumulative counters that can regress when a panicked
/// engine is rebuilt from its journal (see `ModelCell::metric_base`).
#[derive(Clone, Copy, Default)]
struct CounterBase {
    cache_hits: u64,
    cache_misses: u64,
    pjrt_batches: u64,
    native_queries: u64,
    factor_patches: u64,
    factor_resweeps: u64,
    cache_truncations: u64,
    fallback_rebuilds: u64,
    memmove_bytes: u64,
    chunks_copied: u64,
    chunks_shared: u64,
    window_evictions: u64,
    solve_cold_retries: u64,
    solve_refit_escalations: u64,
}

impl CounterBase {
    /// Fold in the counter shortfall of one resurrection: whatever the
    /// recovered engine (`post`) restarts below the pre-panic engine
    /// (`pre`) becomes a permanent offset. Counters the replay lands
    /// exactly on contribute zero.
    fn absorb_regression(&mut self, pre: &CounterBase, post: &CounterBase) {
        self.cache_hits += pre.cache_hits.saturating_sub(post.cache_hits);
        self.cache_misses += pre.cache_misses.saturating_sub(post.cache_misses);
        self.pjrt_batches += pre.pjrt_batches.saturating_sub(post.pjrt_batches);
        self.native_queries += pre.native_queries.saturating_sub(post.native_queries);
        self.factor_patches += pre.factor_patches.saturating_sub(post.factor_patches);
        self.factor_resweeps += pre.factor_resweeps.saturating_sub(post.factor_resweeps);
        self.cache_truncations +=
            pre.cache_truncations.saturating_sub(post.cache_truncations);
        self.fallback_rebuilds +=
            pre.fallback_rebuilds.saturating_sub(post.fallback_rebuilds);
        self.memmove_bytes += pre.memmove_bytes.saturating_sub(post.memmove_bytes);
        self.chunks_copied += pre.chunks_copied.saturating_sub(post.chunks_copied);
        self.chunks_shared += pre.chunks_shared.saturating_sub(post.chunks_shared);
        self.window_evictions +=
            pre.window_evictions.saturating_sub(post.window_evictions);
        self.solve_cold_retries +=
            pre.solve_cold_retries.saturating_sub(post.solve_cold_retries);
        self.solve_refit_escalations +=
            pre.solve_refit_escalations.saturating_sub(post.solve_refit_escalations);
    }
}

/// Sample every cumulative counter `serve_stats` reads off the engine — the
/// before/after probe around a resurrection's engine swap.
fn engine_counters(eng: &ModelEngine) -> CounterBase {
    let gp = eng.gp();
    let (hits, misses, _) = gp.cache_stats();
    let (patches, resweeps) = gp.factor_stats();
    let (_, fallbacks, _) = gp.incremental_stats();
    let (memmove, copied, shared) = gp.storage_stats();
    CounterBase {
        cache_hits: hits,
        cache_misses: misses,
        pjrt_batches: eng.pjrt_batches,
        native_queries: eng.native_queries,
        factor_patches: patches,
        factor_resweeps: resweeps,
        cache_truncations: gp.cache_truncations(),
        fallback_rebuilds: fallbacks,
        memmove_bytes: memmove,
        chunks_copied: copied,
        chunks_shared: shared,
        window_evictions: eng.window_evictions,
        solve_cold_retries: gp.solve_cold_retries,
        solve_refit_escalations: gp.solve_refit_escalations,
    }
}

/// How many times a model's engine may be rebuilt from its journal after a
/// panic before the scheduler gives up and quarantines it — a crash-loop
/// guard for nondeterministic panics (deterministic ones cannot recur on
/// replay, because the panicked command is never journaled).
const MAX_RECOVERIES: u64 = 3;

struct TaggedSnapshot {
    gen: u64,
    snap: PosteriorSnapshot,
}

struct SchedInner {
    pool: WorkerPool,
    models: Mutex<HashMap<u64, Arc<ModelCell>>>,
    next_id: AtomicU64,
    /// Durability configuration shared by every model (None → no journal).
    journal: Option<JournalConfig>,
}

/// What [`Scheduler::recover`] rebuilt from a journal directory.
#[derive(Clone, Debug, Default)]
pub struct RecoveryReport {
    /// Models successfully rebuilt and registered.
    pub models: u64,
    /// Op records replayed from journal tails (post-checkpoint).
    pub replayed_ops: u64,
    /// Records dropped at torn/corrupt journal tails, and the bytes
    /// discarded with them (the files were repaired to their valid prefix).
    pub dropped_records: u64,
    pub dropped_bytes: u64,
    /// Models whose files were unrecoverable; one message each in `errors`.
    pub failed: u64,
    pub errors: Vec<String>,
}

/// The process-wide scheduler: model registry + shared worker pool.
#[derive(Clone)]
pub struct Scheduler {
    inner: Arc<SchedInner>,
}

impl Scheduler {
    /// Spawn a scheduler over `workers.max(1)` pool workers, without
    /// durability (mutations live only in memory).
    pub fn new(workers: usize) -> Self {
        Scheduler::build(workers, None)
    }

    /// Spawn a scheduler whose models journal every successful mutation
    /// under `jcfg.dir` (see [`JournalConfig`] for the fsync and compaction
    /// knobs). Pair with [`Scheduler::recover`] on restart.
    pub fn with_journal(workers: usize, jcfg: JournalConfig) -> Self {
        Scheduler::build(workers, Some(jcfg))
    }

    fn build(workers: usize, jcfg: Option<JournalConfig>) -> Self {
        Scheduler {
            inner: Arc::new(SchedInner {
                pool: WorkerPool::new(workers),
                models: Mutex::new(HashMap::new()),
                next_id: AtomicU64::new(1),
                journal: jcfg,
            }),
        }
    }

    /// Rebuild the model fleet from a journal directory: every id with a
    /// `model-<id>.journal` / `model-<id>.ckpt` file is decoded from its
    /// checkpoint and replayed through its journal tail, landing each
    /// engine on a state bit-identical to the pre-crash one (the chaos
    /// suite asserts this per seed). Unrecoverable models are skipped and
    /// reported; the scheduler keeps journaling under the same directory,
    /// and fresh `create_model` ids continue past the highest recovered id.
    pub fn recover(workers: usize, jcfg: JournalConfig) -> (Scheduler, RecoveryReport) {
        let sched = Scheduler::build(workers, Some(jcfg.clone()));
        let mut report = RecoveryReport::default();
        let mut max_id = 0u64;
        for id in journal::list_model_ids(&jcfg.dir) {
            // Even an unrecoverable id holds the floor: reusing it would
            // have `create_model` truncate the very journal someone may
            // want to inspect post-mortem.
            max_id = max_id.max(id);
            match journal::recover_model(&jcfg, id) {
                Ok(rec) => {
                    report.models += 1;
                    report.replayed_ops += rec.replayed_ops;
                    report.dropped_records += rec.dropped_records;
                    report.dropped_bytes += rec.dropped_bytes;
                    sched.register_recovered(id, rec);
                }
                Err(e) => {
                    report.failed += 1;
                    report.errors.push(e);
                }
            }
        }
        // Fresh ids must never collide with recovered journals on disk.
        let floor = max_id + 1;
        sched.inner.next_id.fetch_max(floor, Ordering::SeqCst);
        (sched, report)
    }

    /// Install one recovered engine as a live model cell, reattaching its
    /// journal (repaired to its valid prefix by `recover_model`) and
    /// rebuilding the PJRT executable when the recovered config asks for it.
    fn register_recovered(&self, id: u64, rec: journal::RecoveredModel) {
        let cfg = rec.engine.cfg.clone();
        let exe_worker = self.build_pjrt_worker(id, &cfg);
        let (jnl, degraded) = match self
            .inner
            .journal
            .as_ref()
            .map(|jcfg| ModelJournal::open_recovered(jcfg, id, rec.replayed_ops))
        {
            Some(Ok(j)) => (Some(j), false),
            Some(Err(_)) => (None, true),
            None => (None, false),
        };
        let cell = Arc::new(ModelCell {
            id,
            cfg,
            engine: Mutex::new(rec.engine),
            mut_queue: Mutex::new(VecDeque::new()),
            mut_active: AtomicBool::new(false),
            predict_queue: Mutex::new(VecDeque::new()),
            predict_active: AtomicBool::new(false),
            gen: AtomicU64::new(rec.gen),
            snapshot: Mutex::new(None),
            exe_worker,
            dead: AtomicBool::new(false),
            suggest_seq: AtomicU64::new(0),
            native_reads: AtomicU64::new(0),
            read_hits: AtomicU64::new(0),
            read_misses: AtomicU64::new(0),
            jcfg: self.inner.journal.clone(),
            journal: Mutex::new(jnl),
            recoveries: AtomicU64::new(0),
            degraded: AtomicBool::new(degraded),
            subscribers: Mutex::new(Vec::new()),
            snapshots_exported: AtomicU64::new(0),
            invalidations_sent: AtomicU64::new(0),
            metric_base: Mutex::new(CounterBase::default()),
        });
        lock_clean(&self.inner.models).insert(id, cell);
    }

    pub fn workers(&self) -> usize {
        self.inner.pool.workers()
    }

    pub fn pool_stats(&self) -> PoolStats {
        self.inner.pool.stats()
    }

    /// Register a model. The native engine state is built inline; when
    /// `cfg.use_pjrt`, the `window_acq` artifact is compiled by a job pinned
    /// to the model's designated worker (round-robin) and the model's
    /// predicts keep that affinity for the executable's whole life.
    pub fn create_model(&self, cfg: EngineConfig) -> u64 {
        let id = self.inner.next_id.fetch_add(1, Ordering::SeqCst);
        let engine = ModelEngine::new(cfg.clone());
        let exe_worker = self.build_pjrt_worker(id, &cfg);
        // Start the durable history: a config record at generation 0. If
        // even that fails the model still serves, but flagged degraded —
        // there is no file a post-crash recovery could trust.
        let (jnl, degraded) = match self
            .inner
            .journal
            .as_ref()
            .map(|jcfg| ModelJournal::create(jcfg, id, &cfg))
        {
            Some(Ok(j)) => (Some(j), false),
            Some(Err(_)) => (None, true),
            None => (None, false),
        };
        let cell = Arc::new(ModelCell {
            id,
            cfg,
            engine: Mutex::new(engine),
            mut_queue: Mutex::new(VecDeque::new()),
            mut_active: AtomicBool::new(false),
            predict_queue: Mutex::new(VecDeque::new()),
            predict_active: AtomicBool::new(false),
            gen: AtomicU64::new(0),
            snapshot: Mutex::new(None),
            exe_worker,
            dead: AtomicBool::new(false),
            suggest_seq: AtomicU64::new(0),
            native_reads: AtomicU64::new(0),
            read_hits: AtomicU64::new(0),
            read_misses: AtomicU64::new(0),
            jcfg: self.inner.journal.clone(),
            journal: Mutex::new(jnl),
            recoveries: AtomicU64::new(0),
            degraded: AtomicBool::new(degraded),
            subscribers: Mutex::new(Vec::new()),
            snapshots_exported: AtomicU64::new(0),
            invalidations_sent: AtomicU64::new(0),
            metric_base: Mutex::new(CounterBase::default()),
        });
        lock_clean(&self.inner.models).insert(id, cell);
        id
    }

    /// Compile the model's `window_acq` artifact on its designated worker
    /// (round-robin by id). Shared by `create_model` and recovery.
    fn build_pjrt_worker(&self, id: u64, cfg: &EngineConfig) -> Option<usize> {
        if !cfg.use_pjrt {
            return None;
        }
        let w = (id as usize) % self.inner.pool.workers();
        let (tx, rx) = std::sync::mpsc::channel();
        let build_cfg = cfg.clone();
        let submitted = self.inner.pool.spawn_pinned(
            w,
            Box::new(move |_me| {
                let _ = tx.send(build_worker_exe(id, &build_cfg));
            }),
        );
        if submitted && rx.recv().unwrap_or(false) {
            Some(w)
        } else {
            None
        }
    }

    /// Test/inspection hook: the model's bit-exact serialized engine state
    /// ([`ModelEngine::encode_state`]) — the currency of the chaos suite's
    /// recovered-equals-uninterrupted comparisons. `None` for unknown
    /// models or a poisoned engine lock.
    pub fn engine_state_bytes(&self, model: u64) -> Option<Vec<u8>> {
        let cell = lock_clean(&self.inner.models).get(&model).cloned()?;
        let eng = cell.engine.lock().ok()?;
        Some(eng.encode_state())
    }

    pub fn has_model(&self, model: u64) -> bool {
        lock_clean(&self.inner.models).contains_key(&model)
    }

    pub fn model_count(&self) -> usize {
        lock_clean(&self.inner.models).len()
    }

    /// Whether a model's predicts ride the PJRT pinned path.
    pub fn model_has_pjrt(&self, model: u64) -> bool {
        lock_clean(&self.inner.models)
            .get(&model)
            .map(|c| c.exe_worker.is_some())
            .unwrap_or(false)
    }

    /// Route one command. The reply channel inside the command receives
    /// exactly one [`Response`], possibly from a pool worker.
    pub fn dispatch(&self, model: u64, cmd: Command) {
        let cell = {
            let models = lock_clean(&self.inner.models);
            models.get(&model).cloned()
        };
        let Some(cell) = cell else {
            cmd.fail(format!("unknown model {model}"));
            return;
        };
        if cell.dead.load(Ordering::SeqCst) {
            cmd.fail("engine stopped".into());
            return;
        }
        if matches!(
            cmd,
            Command::Observe { .. }
                | Command::ObserveBatch { .. }
                | Command::Forget { .. }
                | Command::ForgetBatch { .. }
                | Command::RollingWindow { .. }
                | Command::Fit { .. }
        ) {
            lock_clean(&cell.mut_queue).push_back(cmd);
            self.schedule_mutations(cell);
            return;
        }
        match cmd {
            Command::Predict { xs, beta, grad, reply } => {
                if cell.exe_worker.is_some() {
                    lock_clean(&cell.predict_queue)
                        .push_back(PredictReq { xs, beta, grad, reply });
                    self.schedule_predicts(cell);
                } else {
                    let c = Arc::clone(&cell);
                    let job: Job =
                        Box::new(move |_| serve_native_predict(&c, xs, beta, grad, reply));
                    // On a shutting-down pool the job (and its reply sender)
                    // is dropped — the caller sees a disconnect-style error.
                    let _ = self.inner.pool.spawn(job);
                }
            }
            Command::Suggest { beta, reply } => {
                let c = Arc::clone(&cell);
                let job: Job = Box::new(move |_| serve_suggest(&c, beta, reply));
                let _ = self.inner.pool.spawn(job);
            }
            Command::Stats { reply } => {
                let c = Arc::clone(&cell);
                let inner = Arc::clone(&self.inner);
                let job: Job = Box::new(move |_| serve_stats(&c, &inner.pool, reply));
                let _ = self.inner.pool.spawn(job);
            }
            Command::Audit { reply } => {
                let c = Arc::clone(&cell);
                let job: Job = Box::new(move |_| serve_audit(&c, reply));
                let _ = self.inner.pool.spawn(job);
            }
            Command::Snapshot { have_gen, reply } => {
                let c = Arc::clone(&cell);
                let job: Job = Box::new(move |_| serve_snapshot(&c, have_gen, reply));
                let _ = self.inner.pool.spawn(job);
            }
            Command::Subscribe { events, reply } => {
                // Register first, then report the generation: a bump racing
                // this window delivers a duplicate invalidation (harmless —
                // fetches are idempotent by generation) rather than a
                // missed one.
                lock_clean(&cell.subscribers).push(events);
                let gen = cell.gen.load(Ordering::SeqCst);
                let _ = reply.send(Response::Subscribed { gen });
            }
            _ => unreachable!("mutating commands are routed to the queue above"),
        }
    }

    fn schedule_mutations(&self, cell: Arc<ModelCell>) {
        if cell.mut_active.swap(true, Ordering::SeqCst) {
            return; // a drain job already owns the queue
        }
        let c = Arc::clone(&cell);
        let job: Job = Box::new(move |_| drain_mutations(&c));
        if !self.inner.pool.spawn(job) {
            cell.mut_active.store(false, Ordering::SeqCst);
            fail_pending(&cell, "coordinator shutting down");
        }
    }

    fn schedule_predicts(&self, cell: Arc<ModelCell>) {
        if cell.predict_active.swap(true, Ordering::SeqCst) {
            return;
        }
        // Only the PJRT path schedules pinned drains, so `exe_worker` is
        // always set here; fail the queue instead of panicking if not.
        let Some(worker) = cell.exe_worker else {
            cell.predict_active.store(false, Ordering::SeqCst);
            fail_pending(&cell, "pjrt predict path lost its worker");
            return;
        };
        let c = Arc::clone(&cell);
        let job: Job = Box::new(move |_| drain_predicts(&c));
        if !self.inner.pool.spawn_pinned(worker, job) {
            cell.predict_active.store(false, Ordering::SeqCst);
            fail_pending(&cell, "coordinator shutting down");
        }
    }

    /// Join every pool worker (queued work drains first). Returns the
    /// number of workers joined; idempotent.
    pub fn shutdown(&self) -> usize {
        self.inner.pool.shutdown()
    }
}

/// Select and compile the matching `(D, W)` artifact, if any.
fn load_exe(client: &xla::PjRtClient, cfg: &EngineConfig) -> Option<WindowExecutable> {
    let manifest = ArtifactManifest::load(ArtifactManifest::default_dir()).ok()?;
    let w = 2 * (cfg.nu.q() + 1); // window width 2ν+1 (even form)
    let spec = manifest.select("window_acq", cfg.d, w, 64)?;
    WindowExecutable::load(client, spec).ok()
}

/// Compile this model's `window_acq` artifact into the current worker's
/// thread-local registry. Returns whether an executable is now resident.
fn build_worker_exe(id: u64, cfg: &EngineConfig) -> bool {
    let Ok(client) = xla::PjRtClient::cpu() else {
        return false;
    };
    match load_exe(&client, cfg) {
        Some(exe) => {
            WORKER_EXES.with(|m| {
                m.borrow_mut().insert(id, ExeEntry { _client: client, exe })
            });
            true
        }
        None => false,
    }
}

/// Answer every queued command with an error (shutdown / dead engine).
fn fail_pending(cell: &ModelCell, msg: &str) {
    let cmds: Vec<Command> = lock_clean(&cell.mut_queue).drain(..).collect();
    for c in cmds {
        c.fail(msg.to_string());
    }
    let preds: Vec<PredictReq> = lock_clean(&cell.predict_queue).drain(..).collect();
    for p in preds {
        let _ = p.reply.send(Response::Error(msg.to_string()));
    }
}

/// Drain the model's mutation queue FIFO under the engine mutex. At most
/// one of these runs per model (`mut_active`); the standard
/// deschedule-and-recheck handshake closes the race with concurrent
/// submitters.
fn drain_mutations(cell: &ModelCell) {
    loop {
        let next = lock_clean(&cell.mut_queue).pop_front();
        let Some(cmd) = next else {
            cell.mut_active.store(false, Ordering::SeqCst);
            let again = !lock_clean(&cell.mut_queue).is_empty();
            if again && !cell.mut_active.swap(true, Ordering::SeqCst) {
                continue; // new work arrived during deschedule; reclaim
            }
            return;
        };
        if cell.dead.load(Ordering::SeqCst) {
            cmd.fail("engine stopped".into());
            continue;
        }
        // Shear the command down to its journalable op — the same value the
        // drain applies (via `journal::apply_op`), appends, and that replay
        // re-applies after a crash, so live and recovered trajectories
        // cannot drift.
        let (reply, op): (Sender<Response>, MutationOp) = match cmd {
            Command::Observe { x, y, reply } => (reply, MutationOp::Observe { x, y }),
            Command::ObserveBatch { xs, ys, reply } => {
                (reply, MutationOp::ObserveBatch { xs, ys })
            }
            Command::Forget { x, reply } => (reply, MutationOp::Forget { x }),
            Command::ForgetBatch { xs, reply } => (reply, MutationOp::ForgetBatch { xs }),
            Command::RollingWindow { max_n, max_age, reply } => {
                (reply, MutationOp::RollingWindow { max_n, max_age })
            }
            Command::Fit { steps, reply } => (reply, MutationOp::Fit { steps }),
            other => {
                other.fail("non-mutating command on the mutation queue".into());
                continue;
            }
        };
        let mut eng = match cell.engine.lock() {
            Ok(g) => g,
            Err(_) => {
                cell.dead.store(true, Ordering::SeqCst);
                let _ = reply.send(Response::Error("engine stopped".into()));
                continue;
            }
        };
        let outcome = catch_unwind(AssertUnwindSafe(|| journal::apply_op(&mut eng, &op)));
        match outcome {
            Ok(resp) => {
                if !matches!(resp, Response::Error(_)) {
                    // Invalidate the read snapshot (still holding the engine
                    // lock, so readers re-checking under it see a stable gen).
                    let gen = cell.gen.fetch_add(1, Ordering::SeqCst) + 1;
                    // Journal after the apply, before the reply: an
                    // acknowledged mutation is on disk (modulo the fsync
                    // policy), and a panicked one is never written, so
                    // replay cannot re-panic. A journal panic must not
                    // take the model down — contain it and degrade.
                    let journaled = catch_unwind(AssertUnwindSafe(|| {
                        journal_append(cell, &mut eng, gen, &op)
                    }));
                    if journaled.is_err() {
                        cell.degraded.store(true, Ordering::SeqCst);
                    }
                    // Push the invalidation while still holding the engine
                    // lock: gen bumps are serialized under it, so every
                    // subscriber sees generations in order.
                    notify_subscribers(cell, gen);
                }
                drop(eng);
                let _ = reply.send(resp);
            }
            Err(_) => {
                // State is suspect. The journal holds every acknowledged
                // mutation and not the one that just panicked — rebuild the
                // engine from it in place (bounded retries) instead of
                // quarantining on first failure.
                match try_resurrect(cell, &mut eng) {
                    Ok(()) => {
                        drop(eng);
                        let _ = reply.send(Response::Error(
                            "engine panicked; command aborted and model recovered from journal"
                                .into(),
                        ));
                    }
                    Err(msg) => {
                        cell.dead.store(true, Ordering::SeqCst);
                        drop(eng);
                        let _ = reply.send(Response::Error(msg));
                        fail_pending(cell, "engine stopped");
                    }
                }
            }
        }
    }
}

/// Append an applied op at its generation, compacting when due. Runs with
/// the engine lock held (the caller's guard) so the journal order is the
/// apply order. Any I/O failure latches `degraded`: journaling stops but
/// the model keeps serving.
fn journal_append(cell: &ModelCell, eng: &mut ModelEngine, gen: u64, op: &MutationOp) {
    if cell.degraded.load(Ordering::SeqCst) {
        return;
    }
    let mut slot = lock_clean(&cell.journal);
    let Some(j) = slot.as_mut() else { return };
    if j.append_op(gen, op).is_err() {
        cell.degraded.store(true, Ordering::SeqCst);
        return;
    }
    if j.due_for_checkpoint() && j.write_checkpoint(gen, &eng.encode_state()).is_err() {
        cell.degraded.store(true, Ordering::SeqCst);
    }
}

/// Rebuild a panicked engine in place from its journal. Succeeds only when
/// durability is on, the journal is intact (not degraded), the recovery
/// budget has headroom, and the replayed history lands exactly on the
/// cell's generation — any shortfall quarantines the model as before.
fn try_resurrect(cell: &ModelCell, eng: &mut ModelEngine) -> Result<(), String> {
    let Some(jcfg) = cell.jcfg.as_ref() else {
        return Err("engine panicked; model disabled".into());
    };
    if cell.degraded.load(Ordering::SeqCst) {
        return Err("engine panicked; model disabled (journal degraded)".into());
    }
    if cell.recoveries.load(Ordering::SeqCst) >= MAX_RECOVERIES {
        return Err("engine panicked; model disabled (recovery budget exhausted)".into());
    }
    // Replay itself runs engine code and could (via an injected or
    // nondeterministic fault) panic again — contain it.
    let rec = catch_unwind(AssertUnwindSafe(|| journal::recover_model(jcfg, cell.id)))
        .map_err(|_| "engine panicked; journal recovery also panicked — model disabled")??;
    let want = cell.gen.load(Ordering::SeqCst);
    if rec.gen != want {
        return Err(format!(
            "engine panicked; journal replays to generation {} but model is at {} — model disabled",
            rec.gen, want
        ));
    }
    // The replay restarts cumulative counters at the journal's idea of the
    // world — anything not in the checkpoint (storage splice/COW tallies,
    // read-path counts since the last checkpoint) regresses. Capture the
    // shortfall against the live pre-panic engine before discarding it, so
    // `serve_stats` keeps the wire counters monotone (the `ServerMetrics`
    // saturating-delta folding would otherwise silently under-count every
    // post-recovery delta until the counter caught back up).
    let pre = engine_counters(eng);
    *eng = rec.engine;
    let post = engine_counters(eng);
    lock_clean(&cell.metric_base).absorb_regression(&pre, &post);
    cell.recoveries.fetch_add(1, Ordering::SeqCst);
    Ok(())
}

/// Deliver one `Invalidate` event for `gen` to every subscriber, pruning
/// the ones whose receiver is gone. Runs under the engine lock (the
/// mutation drain's guard), so events arrive in generation order.
fn notify_subscribers(cell: &ModelCell, gen: u64) {
    let mut subs = lock_clean(&cell.subscribers);
    if subs.is_empty() {
        return;
    }
    let mut sent = 0u64;
    subs.retain(|s| {
        let ok = s.send(Response::Invalidate { model: cell.id, gen }).is_ok();
        if ok {
            sent += 1;
        }
        ok
    });
    if sent > 0 {
        cell.invalidations_sent.fetch_add(sent, Ordering::Relaxed);
    }
}

/// Pinned PJRT drain: take the whole predict backlog, group consecutive
/// same-`(β, grad)` requests, and serve each group through one executable
/// call (dynamic batching, preserved per model).
fn drain_predicts(cell: &ModelCell) {
    loop {
        let batch: VecDeque<PredictReq> =
            std::mem::take(&mut *lock_clean(&cell.predict_queue));
        if batch.is_empty() {
            cell.predict_active.store(false, Ordering::SeqCst);
            let again = !lock_clean(&cell.predict_queue).is_empty();
            if again && !cell.predict_active.swap(true, Ordering::SeqCst) {
                continue;
            }
            return;
        }
        if cell.dead.load(Ordering::SeqCst) {
            for p in batch {
                let _ = p.reply.send(Response::Error("engine stopped".into()));
            }
            continue;
        }
        let mut eng = match cell.engine.lock() {
            Ok(g) => g,
            Err(_) => {
                cell.dead.store(true, Ordering::SeqCst);
                for p in batch {
                    let _ = p.reply.send(Response::Error("engine stopped".into()));
                }
                continue;
            }
        };
        // Same panic containment as `drain_mutations`: a panicking predict
        // must not latch `predict_active` forever (which would wedge the
        // model's whole predict path and deadlock shutdown). The engine
        // guard lives outside the catch, so the mutex is not poisoned; the
        // panicked group's reply senders are dropped mid-unwind, which
        // surfaces as a disconnect error at the caller.
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            WORKER_EXES.with(|m| {
                let exes = m.borrow();
                let exe = exes.get(&cell.id).map(|e| &e.exe);
                let mut it = batch.into_iter().peekable();
                while let Some(first) = it.next() {
                    let (beta, grad) = (first.beta, first.grad);
                    let mut group = vec![(first.xs, first.reply)];
                    while let Some(nx) = it.peek() {
                        if nx.beta != beta || nx.grad != grad {
                            break;
                        }
                        let Some(nx) = it.next() else { break };
                        group.push((nx.xs, nx.reply));
                    }
                    eng.serve_predicts(exe, group, beta, grad);
                }
            });
        }));
        drop(eng);
        if outcome.is_err() {
            cell.dead.store(true, Ordering::SeqCst);
            fail_pending(cell, "engine stopped");
        }
    }
}

/// Fetch (building lazily, once per generation) the model's read snapshot.
fn read_snapshot(cell: &ModelCell) -> Result<Arc<TaggedSnapshot>, String> {
    let gen = cell.gen.load(Ordering::SeqCst);
    if let Some(s) = lock_clean(&cell.snapshot).as_ref() {
        if s.gen == gen {
            return Ok(Arc::clone(s));
        }
    }
    let mut eng = match cell.engine.lock() {
        Ok(g) => g,
        Err(_) => {
            cell.dead.store(true, Ordering::SeqCst);
            return Err("engine stopped".into());
        }
    };
    // Re-read under the engine lock: mutations bump `gen` while holding it,
    // so this value is stable for the duration of the build. Another reader
    // may have built the snapshot while this one waited for the lock.
    let gen = cell.gen.load(Ordering::SeqCst);
    if let Some(s) = lock_clean(&cell.snapshot).as_ref() {
        if s.gen == gen {
            return Ok(Arc::clone(s));
        }
    }
    let snap = eng.read_snapshot()?;
    let tagged = Arc::new(TaggedSnapshot { gen, snap });
    {
        // Store while still holding the engine lock (gen cannot advance),
        // so a freshly-built snapshot can never clobber a newer one. Lock
        // order engine → snapshot matches `serve_stats`.
        let mut slot = lock_clean(&cell.snapshot);
        if let Some(old) = slot.take() {
            // Fold the retired snapshot's cache stats into the cell totals
            // (readers still holding the old Arc keep working; their later
            // hits are uncounted — observability slack, not correctness).
            let (h, m) = old.snap.cache_stats();
            cell.read_hits.fetch_add(h, Ordering::Relaxed);
            cell.read_misses.fetch_add(m, Ordering::Relaxed);
        }
        *slot = Some(Arc::clone(&tagged));
    }
    drop(eng);
    Ok(tagged)
}

/// Concurrent native predict: one snapshot fetch + read-only window math.
fn serve_native_predict(
    cell: &ModelCell,
    xs: Vec<Vec<f64>>,
    beta: f64,
    grad: bool,
    reply: Sender<Response>,
) {
    let tagged = match read_snapshot(cell) {
        Ok(t) => t,
        Err(e) => {
            let _ = reply.send(Response::Error(e));
            return;
        }
    };
    let d = cell.cfg.d;
    if xs.iter().any(|r| r.len() != d) {
        let _ = reply.send(Response::Error(format!("expected {d}-dim points")));
        return;
    }
    let resp = predict_on_snapshot(&tagged.snap, &xs, beta, grad);
    cell.native_reads.fetch_add(xs.len() as u64, Ordering::Relaxed);
    let _ = reply.send(resp);
}

/// The native read-path math over a posterior snapshot, shared by the home
/// shard ([`serve_native_predict`]) and the replica
/// ([`crate::coordinator::replica`]) — one code path is what makes replica
/// predictions bit-identical to the writer's at the same generation.
pub(crate) fn predict_on_snapshot(
    snap: &PosteriorSnapshot,
    xs: &[Vec<f64>],
    beta: f64,
    grad: bool,
) -> Response {
    let a = Acquisition::LcbMin { beta };
    let mut mu = Vec::with_capacity(xs.len());
    let mut svar = Vec::with_capacity(xs.len());
    let mut acqv = Vec::with_capacity(xs.len());
    let mut gacq = Vec::with_capacity(xs.len());
    for x in xs {
        let out = snap.predict(x, grad);
        let (v, g) = if grad {
            a.value_grad(out.mean, out.var, &out.mean_grad, &out.var_grad)
        } else {
            (a.value(out.mean, out.var), Vec::new())
        };
        mu.push(out.mean);
        svar.push(out.var);
        acqv.push(v);
        gacq.push(g);
    }
    Response::Prediction {
        mu,
        svar,
        acq: acqv,
        gacq: if grad { gacq } else { Vec::new() },
        path: "native",
    }
}

/// Read-only acquisition surface over a snapshot, with a private `M̃` cache
/// so a long gradient-ascent search never contends with concurrent predicts.
struct SnapshotEval<'a> {
    snap: &'a PosteriorSnapshot,
    cache: MTildeCache,
}

impl BoEngine for SnapshotEval<'_> {
    fn observe(&mut self, _x: &[f64], _y: f64) {
        unreachable!("read-only snapshot surface");
    }

    fn posterior(&mut self, x: &[f64]) -> (f64, f64, Vec<f64>, Vec<f64>) {
        let out = self.snap.predict_with_cache(&mut self.cache, x, true);
        (out.mean, out.var, out.mean_grad, out.var_grad)
    }

    fn fit_hypers(&mut self) {
        unreachable!("read-only snapshot surface");
    }

    fn n(&self) -> usize {
        self.snap.n()
    }

    fn name(&self) -> &'static str {
        "snapshot"
    }
}

/// Concurrent suggest: multi-start gradient ascent over the snapshot.
fn serve_suggest(cell: &ModelCell, beta: f64, reply: Sender<Response>) {
    let tagged = match read_snapshot(cell) {
        Ok(t) => t,
        Err(e) => {
            let _ = reply.send(Response::Error(e));
            return;
        }
    };
    let seq = cell.suggest_seq.fetch_add(1, Ordering::SeqCst);
    let x = suggest_on_snapshot(
        &tagged.snap,
        cell.cfg.d,
        cell.cfg.lo,
        cell.cfg.hi,
        cell.cfg.seed,
        seq,
        beta,
    );
    cell.native_reads.fetch_add(1, Ordering::Relaxed);
    let _ = reply.send(Response::Suggestion { x });
}

/// Multi-start LCB gradient ascent over a posterior snapshot — the suggest
/// mirror of [`predict_on_snapshot`], shared with the replica. Each call
/// owns an independent rng derived from `(seed, seq)`, so a replica's
/// suggest sequence is deterministic for its own `(seed, seq)` stream.
pub(crate) fn suggest_on_snapshot(
    snap: &PosteriorSnapshot,
    d: usize,
    lo: f64,
    hi: f64,
    seed: u64,
    seq: u64,
    beta: f64,
) -> Vec<f64> {
    let mut rng = Rng::new(seed ^ 0x9E3779B97F4A7C15u64.wrapping_mul(seq + 1));
    let cache = snap.fresh_cache();
    let mut eval = SnapshotEval { snap, cache };
    let acq = Acquisition::LcbMin { beta };
    let scfg = SearchCfg::default();
    search_next(&mut eval, &acq, d, lo, hi, &scfg, &mut rng)
}

/// Export the model's current read snapshot as a generation-numbered
/// artifact (protocol v3 `snapshot` op). A `have_gen` matching the served
/// generation elides the payload — the cheap "unchanged" delta a replica
/// rides between invalidations. The artifact is self-validating
/// ([`persist::decode_snapshot`] re-audits on import), so a torn or stale
/// ship can never install a mixed-generation posterior on a replica.
fn serve_snapshot(cell: &ModelCell, have_gen: Option<u64>, reply: Sender<Response>) {
    let tagged = match read_snapshot(cell) {
        Ok(t) => t,
        Err(e) => {
            let _ = reply.send(Response::Error(e));
            return;
        }
    };
    if have_gen == Some(tagged.gen) {
        let _ = reply.send(Response::Snapshot { gen: tagged.gen, artifact: None });
        return;
    }
    let bytes = persist::encode_snapshot(&tagged.snap, tagged.gen);
    cell.snapshots_exported.fetch_add(1, Ordering::Relaxed);
    let _ = reply.send(Response::Snapshot {
        gen: tagged.gen,
        artifact: Some(hex_encode(&bytes)),
    });
}

/// Stats: engine counters (brief engine lock) + read-path counters + pool
/// occupancy/queue-depth/steal observability. Counters that can regress
/// across a panic resurrection are lifted by the cell's `metric_base`
/// offsets, so everything on the wire is monotone for the lifetime of the
/// model id.
fn serve_stats(cell: &ModelCell, pool: &WorkerPool, reply: Sender<Response>) {
    let eng = match cell.engine.lock() {
        Ok(g) => g,
        Err(_) => {
            cell.dead.store(true, Ordering::SeqCst);
            let _ = reply.send(Response::Error("engine stopped".into()));
            return;
        }
    };
    let gp = eng.gp();
    let live = engine_counters(&eng);
    let base = *lock_clean(&cell.metric_base);
    let (snap_h, snap_m) = {
        let slot = lock_clean(&cell.snapshot);
        slot.as_ref().map(|s| s.snap.cache_stats()).unwrap_or((0, 0))
    };
    let (j_appends, j_bytes, j_ckpts) = {
        // Lock order engine → journal, same as the mutation drain.
        let slot = lock_clean(&cell.journal);
        slot.as_ref().map(|j| (j.appends, j.bytes, j.checkpoints)).unwrap_or((0, 0, 0))
    };
    let ps = pool.stats();
    let resp = Response::Stats {
        n: gp.n(),
        d: gp.input_dim(),
        omegas: gp.omegas.clone(),
        cache_hits: live.cache_hits
            + base.cache_hits
            + cell.read_hits.load(Ordering::Relaxed)
            + snap_h,
        cache_misses: live.cache_misses
            + base.cache_misses
            + cell.read_misses.load(Ordering::Relaxed)
            + snap_m,
        pjrt_batches: live.pjrt_batches + base.pjrt_batches,
        native_queries: live.native_queries
            + base.native_queries
            + cell.native_reads.load(Ordering::Relaxed),
        factor_patches: live.factor_patches + base.factor_patches,
        factor_resweeps: live.factor_resweeps + base.factor_resweeps,
        cache_truncations: live.cache_truncations + base.cache_truncations,
        fallback_rebuilds: live.fallback_rebuilds + base.fallback_rebuilds,
        pool_workers: ps.workers as u64,
        pool_busy: ps.running,
        pool_queue_depth: ps.queued,
        pool_steals: ps.steals,
        memmove_bytes: live.memmove_bytes + base.memmove_bytes,
        chunks_copied: live.chunks_copied + base.chunks_copied,
        chunks_shared: live.chunks_shared + base.chunks_shared,
        window_evictions: live.window_evictions + base.window_evictions,
        window_occupancy: eng.window_occupancy() as u64,
        recoveries: cell.recoveries.load(Ordering::Relaxed),
        degraded: cell.degraded.load(Ordering::SeqCst),
        journal_appends: j_appends,
        journal_bytes: j_bytes,
        journal_checkpoints: j_ckpts,
        solve_cold_retries: live.solve_cold_retries + base.solve_cold_retries,
        solve_refit_escalations: live.solve_refit_escalations
            + base.solve_refit_escalations,
        snapshots_exported: cell.snapshots_exported.load(Ordering::Relaxed),
        invalidations_sent: cell.invalidations_sent.load(Ordering::Relaxed),
        subscribers: lock_clean(&cell.subscribers).len() as u64,
    };
    drop(eng);
    let _ = reply.send(resp);
}

/// On-demand invariant audit: a *read* job that briefly takes the engine
/// lock (a consistent view across all structures) and walks
/// [`crate::gp::model::AdditiveGP::run_audit`]. Never mutates; never bumps
/// the generation.
fn serve_audit(cell: &ModelCell, reply: Sender<Response>) {
    let eng = match cell.engine.lock() {
        Ok(g) => g,
        Err(_) => {
            cell.dead.store(true, Ordering::SeqCst);
            let _ = reply.send(Response::Error("engine stopped".into()));
            return;
        }
    };
    let resp = eng.audit();
    drop(eng);
    let _ = reply.send(resp);
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn cfg(d: usize) -> EngineConfig {
        EngineConfig { d, use_pjrt: false, lo: 0.0, hi: 4.0, seed: 11, ..Default::default() }
    }

    fn call(
        sched: &Scheduler,
        model: u64,
        make: impl FnOnce(Sender<Response>) -> Command,
    ) -> Response {
        let (tx, rx) = channel();
        sched.dispatch(model, make(tx));
        rx.recv().expect("reply")
    }

    #[test]
    fn mutations_are_fifo_and_reads_concurrent() {
        let sched = Scheduler::new(3);
        let m = sched.create_model(cfg(2));
        assert!(sched.has_model(m));
        assert!(!sched.has_model(m + 99));
        let mut rng = Rng::new(3);
        // Batch-activate, then a few single observes.
        let xs: Vec<Vec<f64>> = (0..40)
            .map(|_| vec![rng.uniform_in(0.0, 4.0), rng.uniform_in(0.0, 4.0)])
            .collect();
        let ys: Vec<f64> = xs.iter().map(|x| x[0].sin() + x[1].cos()).collect();
        let r = call(&sched, m, |reply| Command::ObserveBatch { xs, ys, reply });
        match r {
            Response::BatchObserved { n, .. } => assert_eq!(n, 40),
            other => panic!("unexpected {other:?}"),
        }
        for i in 0..5 {
            let x = vec![0.1 * i as f64 + 0.05, 3.9 - 0.1 * i as f64];
            let y = x[0].sin() + x[1].cos();
            let r = call(&sched, m, |reply| Command::Observe { x, y, reply });
            match r {
                Response::Observed { n, .. } => assert_eq!(n, 41 + i),
                other => panic!("unexpected {other:?}"),
            }
        }
        // Concurrent predicts against the snapshot.
        let mut handles = Vec::new();
        for t in 0..4 {
            let sched = sched.clone();
            handles.push(std::thread::spawn(move || {
                let probe = vec![vec![1.0 + 0.2 * t as f64, 2.0]];
                let r = call(&sched, m, |reply| Command::Predict {
                    xs: probe,
                    beta: 2.0,
                    grad: true,
                    reply,
                });
                match r {
                    Response::Prediction { mu, svar, path, .. } => {
                        assert_eq!(mu.len(), 1);
                        assert!(svar[0].is_finite() && svar[0] >= 0.0);
                        assert_eq!(path, "native");
                    }
                    other => panic!("unexpected {other:?}"),
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // Stats carries the pool fields and the COW storage counters (the
        // predicts above forced a snapshot build → chunks were shared; the
        // 5 mid-matrix observes after activation moved splice bytes).
        let r = call(&sched, m, |reply| Command::Stats { reply });
        match r {
            Response::Stats { n, pool_workers, native_queries, memmove_bytes, chunks_shared, .. } => {
                assert_eq!(n, 45);
                assert_eq!(pool_workers, 3);
                assert!(native_queries >= 4);
                assert!(chunks_shared > 0, "snapshot build must share chunks");
                assert!(memmove_bytes > 0, "mid-matrix splices must account moved bytes");
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(sched.shutdown(), 3);
        assert_eq!(sched.shutdown(), 0);
    }

    /// The `audit` command rides the read path and reports the documented
    /// deterministic structure counts at every model age.
    #[test]
    fn audit_command_reports_structures() {
        let sched = Scheduler::new(2);
        let m = sched.create_model(cfg(2));
        match call(&sched, m, |reply| Command::Audit { reply }) {
            Response::AuditReport { passed, structures, violation } => {
                assert!(passed, "inactive model must pass: {violation}");
                assert_eq!(structures, 2, "façade-only audit before activation");
                assert!(violation.is_empty());
            }
            other => panic!("unexpected {other:?}"),
        }
        let mut rng = Rng::new(5);
        let xs: Vec<Vec<f64>> = (0..40)
            .map(|_| vec![rng.uniform_in(0.0, 4.0), rng.uniform_in(0.0, 4.0)])
            .collect();
        let ys: Vec<f64> = xs.iter().map(|x| x[0].sin() + x[1].cos()).collect();
        let r = call(&sched, m, |reply| Command::ObserveBatch { xs, ys, reply });
        assert!(matches!(r, Response::BatchObserved { .. }), "unexpected {r:?}");
        match call(&sched, m, |reply| Command::Audit { reply }) {
            Response::AuditReport { passed, structures, violation } => {
                assert!(passed, "active model must pass: {violation}");
                assert!(structures >= 2 + 1 + 2 * 11, "got {structures}");
            }
            other => panic!("unexpected {other:?}"),
        }
        sched.shutdown();
    }

    /// The v2 mutating commands ride the same FIFO: enabling a rolling
    /// window evicts the oldest overflow immediately, later observes hold
    /// occupancy at the cap, and forget-by-value retires exactly one row —
    /// all visible through the Stats window counters.
    #[test]
    fn rolling_window_evicts_and_forget_removes() {
        let sched = Scheduler::new(2);
        let m = sched.create_model(cfg(2));
        let mut rng = Rng::new(9);
        let xs: Vec<Vec<f64>> = (0..40)
            .map(|_| vec![rng.uniform_in(0.0, 4.0), rng.uniform_in(0.0, 4.0)])
            .collect();
        let ys: Vec<f64> = xs.iter().map(|x| x[0].sin() + x[1].cos()).collect();
        let r = call(&sched, m, |reply| Command::ObserveBatch { xs, ys, reply });
        assert!(matches!(r, Response::BatchObserved { n: 40, .. }), "unexpected {r:?}");
        // Enabling a 30-point window evicts the 10 oldest immediately.
        let r = call(&sched, m, |reply| Command::RollingWindow {
            max_n: 30,
            max_age: None,
            reply,
        });
        assert!(matches!(r, Response::Ok), "unexpected {r:?}");
        // A fresh observe holds occupancy at the cap (insert + evict oldest).
        let x = vec![1.25, 2.5];
        let y = x[0].sin() + x[1].cos();
        let r = call(&sched, m, |reply| Command::Observe { x, y, reply });
        match r {
            Response::Observed { n, .. } => assert_eq!(n, 30, "window must hold the cap"),
            other => panic!("unexpected {other:?}"),
        }
        // Forget-by-value retires exactly the point observed above; a second
        // attempt matches nothing (idempotent retraction).
        let r = call(&sched, m, |reply| Command::Forget { x: vec![1.25, 2.5], reply });
        match r {
            Response::Forgotten { n, removed, .. } => {
                assert_eq!((n, removed), (29, 1));
            }
            other => panic!("unexpected {other:?}"),
        }
        let r = call(&sched, m, |reply| Command::Forget { x: vec![1.25, 2.5], reply });
        match r {
            Response::Forgotten { n, removed, .. } => {
                assert_eq!((n, removed), (29, 0));
            }
            other => panic!("unexpected {other:?}"),
        }
        let r = call(&sched, m, |reply| Command::Stats { reply });
        match r {
            Response::Stats { n, window_evictions, window_occupancy, .. } => {
                assert_eq!(n, 29);
                assert_eq!(window_evictions, 11, "10 at enable + 1 per-observe");
                assert_eq!(window_occupancy, 29);
            }
            other => panic!("unexpected {other:?}"),
        }
        sched.shutdown();
    }

    /// Full restart drill: a journaled scheduler ingests, is dropped with
    /// no clean handoff, and [`Scheduler::recover`] rebuilds a fleet whose
    /// serialized engine state is bit-identical and keeps serving.
    #[test]
    fn journaled_models_recover_after_restart() {
        let dir = std::env::temp_dir().join(format!(
            "addgp-sched-recover-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let jcfg = JournalConfig::new(&dir);
        let sched = Scheduler::with_journal(2, jcfg.clone());
        let m = sched.create_model(cfg(2));
        let mut rng = Rng::new(17);
        let xs: Vec<Vec<f64>> = (0..30)
            .map(|_| vec![rng.uniform_in(0.0, 4.0), rng.uniform_in(0.0, 4.0)])
            .collect();
        let ys: Vec<f64> = xs.iter().map(|x| x[0].sin() + x[1].cos()).collect();
        let r = call(&sched, m, |reply| Command::ObserveBatch { xs, ys, reply });
        assert!(matches!(r, Response::BatchObserved { .. }), "unexpected {r:?}");
        for i in 0..4 {
            let x = vec![0.3 * i as f64 + 0.1, 1.0];
            let y = x[0].sin() + x[1].cos();
            let r = call(&sched, m, |reply| Command::Observe { x, y, reply });
            assert!(matches!(r, Response::Observed { .. }), "unexpected {r:?}");
        }
        let before = sched.engine_state_bytes(m).expect("state");
        match call(&sched, m, |reply| Command::Stats { reply }) {
            Response::Stats { journal_appends, degraded, recoveries, .. } => {
                assert_eq!(journal_appends, 5, "batch + 4 observes all journaled");
                assert!(!degraded);
                assert_eq!(recoveries, 0);
            }
            other => panic!("unexpected {other:?}"),
        }
        sched.shutdown();
        drop(sched);
        let (sched2, report) = Scheduler::recover(2, jcfg);
        assert_eq!((report.models, report.failed), (1, 0), "{:?}", report.errors);
        assert_eq!(report.replayed_ops, 5);
        assert_eq!((report.dropped_records, report.dropped_bytes), (0, 0));
        assert!(sched2.has_model(m));
        let after = sched2.engine_state_bytes(m).expect("state");
        assert_eq!(before, after, "recovered state is bit-identical");
        // The recovered model serves, and fresh ids continue past it.
        let r = call(&sched2, m, |reply| Command::Predict {
            xs: vec![vec![1.0, 2.0]],
            beta: 2.0,
            grad: false,
            reply,
        });
        assert!(matches!(r, Response::Prediction { .. }), "unexpected {r:?}");
        let m2 = sched2.create_model(cfg(2));
        assert!(m2 > m, "fresh ids must clear the recovered journals");
        sched2.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The v3 replication surface end-to-end in-process: subscribe, export
    /// a snapshot artifact, decode it (audit included) to a posterior that
    /// predicts bit-identically, ride the `have_gen` short-circuit, and see
    /// the invalidation push + replication counters after a mutation.
    #[test]
    fn snapshot_export_and_invalidation_push() {
        let sched = Scheduler::new(2);
        let m = sched.create_model(cfg(2));
        let mut rng = Rng::new(21);
        let xs: Vec<Vec<f64>> = (0..40)
            .map(|_| vec![rng.uniform_in(0.0, 4.0), rng.uniform_in(0.0, 4.0)])
            .collect();
        let ys: Vec<f64> = xs.iter().map(|x| x[0].sin() + x[1].cos()).collect();
        let r = call(&sched, m, |reply| Command::ObserveBatch { xs, ys, reply });
        assert!(matches!(r, Response::BatchObserved { .. }), "unexpected {r:?}");
        let (etx, erx) = channel();
        let gen0 = match call(&sched, m, |reply| Command::Subscribe { events: etx, reply })
        {
            Response::Subscribed { gen } => gen,
            other => panic!("unexpected {other:?}"),
        };
        let (gen, artifact) =
            match call(&sched, m, |reply| Command::Snapshot { have_gen: None, reply }) {
                Response::Snapshot { gen, artifact } => (gen, artifact),
                other => panic!("unexpected {other:?}"),
            };
        assert_eq!(gen, gen0);
        let hex = artifact.expect("first export carries the payload");
        let bytes = crate::coordinator::protocol::hex_decode(&hex).expect("hex");
        let (dec_gen, snap) = persist::decode_snapshot(&bytes).expect("decode + audit");
        assert_eq!(dec_gen, gen);
        // The imported posterior predicts bit-identically to the writer.
        let probe = vec![1.3, 2.6];
        let local = snap.predict(&probe, true);
        match call(&sched, m, |reply| Command::Predict {
            xs: vec![probe.clone()],
            beta: 2.0,
            grad: true,
            reply,
        }) {
            Response::Prediction { mu, svar, .. } => {
                assert_eq!(mu[0].to_bits(), local.mean.to_bits());
                assert_eq!(svar[0].to_bits(), local.var.to_bits());
            }
            other => panic!("unexpected {other:?}"),
        }
        // `have_gen` at the served generation elides the payload.
        match call(&sched, m, |reply| Command::Snapshot { have_gen: Some(gen), reply }) {
            Response::Snapshot { gen: g, artifact } => {
                assert_eq!(g, gen);
                assert!(artifact.is_none(), "unchanged generation must ship no bytes");
            }
            other => panic!("unexpected {other:?}"),
        }
        // A mutation pushes exactly one in-order invalidation.
        let x = vec![0.5, 0.5];
        let y = x[0].sin() + x[1].cos();
        let r = call(&sched, m, |reply| Command::Observe { x, y, reply });
        assert!(matches!(r, Response::Observed { .. }), "unexpected {r:?}");
        match erx.recv().expect("invalidation") {
            Response::Invalidate { model, gen: g } => {
                assert_eq!(model, m);
                assert_eq!(g, gen + 1);
            }
            other => panic!("unexpected {other:?}"),
        }
        match call(&sched, m, |reply| Command::Stats { reply }) {
            Response::Stats { snapshots_exported, invalidations_sent, subscribers, .. } => {
                assert_eq!(snapshots_exported, 1, "have_gen short-circuit not counted");
                assert_eq!(invalidations_sent, 1);
                assert_eq!(subscribers, 1);
            }
            other => panic!("unexpected {other:?}"),
        }
        // Dropping the receiver prunes the subscriber on the next bump.
        drop(erx);
        let x = vec![1.5, 1.5];
        let y = x[0].sin() + x[1].cos();
        let r = call(&sched, m, |reply| Command::Observe { x, y, reply });
        assert!(matches!(r, Response::Observed { .. }), "unexpected {r:?}");
        match call(&sched, m, |reply| Command::Stats { reply }) {
            Response::Stats { invalidations_sent, subscribers, .. } => {
                assert_eq!(invalidations_sent, 1, "dead subscriber gets nothing");
                assert_eq!(subscribers, 0, "pruned on the failed send");
            }
            other => panic!("unexpected {other:?}"),
        }
        sched.shutdown();
    }

    #[test]
    fn unknown_model_and_inactive_model_error() {
        let sched = Scheduler::new(2);
        let (tx, rx) = channel();
        sched.dispatch(7, Command::Stats { reply: tx });
        match rx.recv().unwrap() {
            Response::Error(e) => assert!(e.contains("unknown model"), "{e}"),
            other => panic!("unexpected {other:?}"),
        }
        let m = sched.create_model(cfg(2));
        let r = call(&sched, m, |reply| Command::Predict {
            xs: vec![vec![1.0, 1.0]],
            beta: 2.0,
            grad: false,
            reply,
        });
        match r {
            Response::Error(e) => assert!(e.contains("not enough observations"), "{e}"),
            other => panic!("unexpected {other:?}"),
        }
        sched.shutdown();
    }
}
