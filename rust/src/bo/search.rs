//! Acquisition maximization — paper §6.
//!
//! Multi-start projected gradient ascent inside the box. Each step costs
//! `O(D log n)` for the window lookup and `O(1)` arithmetic given cached
//! `M̃` columns; when the learning rate keeps steps below the data spacing
//! the windows (and hence the cache keys) are reused and a step is `O(1)`
//! amortized — the paper's small-learning-rate claim.

use crate::bo::acquisition::Acquisition;
use crate::bo::run::BoEngine;
use crate::util::Rng;

/// Gradient-ascent controls.
#[derive(Clone, Copy, Debug)]
pub struct SearchCfg {
    pub restarts: usize,
    pub steps: usize,
    /// Initial step length as a fraction of the box width.
    pub step_frac: f64,
    /// Multiplicative backtracking factor when a step does not improve.
    pub shrink: f64,
    /// Stop when the step length falls below this fraction of the box.
    pub min_step_frac: f64,
}

impl Default for SearchCfg {
    fn default() -> Self {
        SearchCfg { restarts: 8, steps: 60, step_frac: 0.05, shrink: 0.5, min_step_frac: 1e-5 }
    }
}

/// Maximize the acquisition by multi-start projected gradient ascent;
/// returns the best point found.
pub fn search_next<E: BoEngine>(
    engine: &mut E,
    acq: &Acquisition,
    d: usize,
    lo: f64,
    hi: f64,
    cfg: &SearchCfg,
    rng: &mut Rng,
) -> Vec<f64> {
    let width = hi - lo;
    let mut best_x = vec![0.5 * (lo + hi); d];
    let mut best_v = f64::NEG_INFINITY;
    for _ in 0..cfg.restarts.max(1) {
        let mut x: Vec<f64> = (0..d).map(|_| rng.uniform_in(lo, hi)).collect();
        let (mu, s, gmu, gs) = engine.posterior(&x);
        let (mut v, mut g) = acq.value_grad(mu, s, &gmu, &gs);
        let mut step = cfg.step_frac * width;
        for _ in 0..cfg.steps {
            let gnorm = g.iter().map(|t| t * t).sum::<f64>().sqrt();
            if gnorm < 1e-14 || step < cfg.min_step_frac * width {
                break;
            }
            // Normalized-gradient trial step, projected into the box.
            let xt: Vec<f64> = x
                .iter()
                .zip(&g)
                .map(|(&xi, &gi)| (xi + step * gi / gnorm).clamp(lo, hi))
                .collect();
            let (mu_t, s_t, gmu_t, gs_t) = engine.posterior(&xt);
            let (vt, gt) = acq.value_grad(mu_t, s_t, &gmu_t, &gs_t);
            if vt > v {
                x = xt;
                v = vt;
                g = gt;
                step *= 1.2; // mild acceleration on success
            } else {
                step *= cfg.shrink;
            }
        }
        if v > best_v {
            best_v = v;
            best_x = x;
        }
    }
    best_x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bo::testfns;
    use crate::bo::run::BoEngine;
    use crate::gp::model::{AdditiveGP, AdditiveGpConfig};

    /// On a model fit to a clean paraboloid-like additive surface, the LCB
    /// searcher should move toward the low region of the surface.
    #[test]
    fn search_moves_downhill() {
        let d = 2;
        let mut cfg = AdditiveGpConfig::default();
        cfg.omega0 = 1.0;
        cfg.sigma2_y = 0.05;
        let mut gp = AdditiveGP::new(cfg, d);
        let mut rng = Rng::new(5);
        // surface: (x0−1)² + (x1+1)² on [−3,3]², minimized at (1,−1).
        let f = |x: &[f64]| (x[0] - 1.0).powi(2) + (x[1] + 1.0).powi(2);
        let x: Vec<Vec<f64>> =
            (0..120).map(|_| vec![rng.uniform_in(-3.0, 3.0), rng.uniform_in(-3.0, 3.0)]).collect();
        for xi in &x {
            gp.observe(xi, f(xi) + 0.05 * rng.normal());
        }
        let acq = crate::bo::acquisition::Acquisition::LcbMin { beta: 0.5 };
        let scfg = SearchCfg { restarts: 6, steps: 80, ..Default::default() };
        let xn = search_next(&mut gp, &acq, d, -3.0, 3.0, &scfg, &mut rng);
        assert!(
            f(&xn) < 2.5,
            "searcher landed at {xn:?} with f={}",
            f(&xn)
        );
    }

    /// Small steps reuse the M̃ cache (the paper's O(1) claim): a short
    /// ascent must incur far fewer misses than queries.
    #[test]
    fn small_steps_hit_cache() {
        let d = 2;
        let mut cfg = AdditiveGpConfig::default();
        cfg.omega0 = 1.0;
        let mut gp = AdditiveGP::new(cfg, d);
        let mut rng = Rng::new(6);
        for _ in 0..100 {
            let x = vec![rng.uniform_in(0.0, 4.0), rng.uniform_in(0.0, 4.0)];
            let y = x[0].sin() + x[1].cos() + 0.1 * rng.normal();
            gp.observe(&x, y);
        }
        // Warm the posterior: visit 1 = single solve, visit 2 materializes
        // the window's M̃ columns.
        let mut x = vec![2.0, 2.0];
        let _ = gp.posterior(&x);
        let _ = gp.posterior(&x);
        let (h0, m0, _) = gp.cache_stats();
        for _ in 0..50 {
            x[0] += 1e-5;
            x[1] -= 1e-5;
            let _ = gp.posterior(&x);
        }
        let (h1, m1, _) = gp.cache_stats();
        assert_eq!(m1, m0, "tiny steps must not add cache misses");
        assert!(h1 > h0);
        let _ = testfns::schwefel(&[0.0]);
    }
}
