//! **Algorithm 1** — the sequential Bayesian-optimization driver, generic
//! over the GP engine so the sparse GKP model and the dense FGP baseline run
//! the identical protocol (paper §7.2).
//!
//! The sparse engine runs **observe-per-sample**: each new evaluation is
//! absorbed through `AdditiveGP::observe`'s incremental fit-state update —
//! `O(log n)`-window KP patching plus an `O(ν²n)` small-constant banded
//! factor sweep and a warm-started Algorithm 4 solve — and a *full* refit
//! happens only at the `hyper_every` boundaries where `fit_hypers`
//! re-learns ω (DESIGN.md §FitState; `benches/incremental.rs` measures the
//! per-sample win over refit-per-sample). The warm-up design goes through
//! `BoEngine::observe_batch` as one batch — one splice/sweep/solve per
//! dimension on the sparse engine, dimensions sharded across threads.

use crate::baselines::full_gp::FullGP;
use crate::bo::acquisition::Acquisition;
use crate::bo::search::{search_next, SearchCfg};
use crate::bo::testfns::NoisyObjective;
use crate::gp::model::AdditiveGP;
use crate::gp::train::TrainCfg;
use crate::util::Rng;

/// A GP engine usable by the BO loop.
pub trait BoEngine {
    fn observe(&mut self, x: &[f64], y: f64);
    /// Absorb a whole batch of evaluations (the warm-up design, parallel
    /// objective evaluations). Defaults to a per-point loop; engines with a
    /// cheaper batch path override it.
    fn observe_batch(&mut self, xs: &[Vec<f64>], ys: &[f64]) {
        for (x, &y) in xs.iter().zip(ys) {
            self.observe(x, y);
        }
    }
    /// `(μ, s, ∇μ, ∇s)` at `x`.
    fn posterior(&mut self, x: &[f64]) -> (f64, f64, Vec<f64>, Vec<f64>);
    /// Re-learn hyperparameters from the current data.
    fn fit_hypers(&mut self);
    fn n(&self) -> usize;
    fn name(&self) -> &'static str;
}

impl BoEngine for AdditiveGP {
    /// Incremental: patches the fit state in place (no refit per sample).
    fn observe(&mut self, x: &[f64], y: f64) {
        AdditiveGP::observe(self, x, y);
    }

    /// Batched incremental ingest: one splice/sweep/solve per dimension for
    /// the whole batch, dimensions sharded across threads.
    fn observe_batch(&mut self, xs: &[Vec<f64>], ys: &[f64]) {
        let _ = AdditiveGP::observe_batch(self, xs, ys);
    }

    fn posterior(&mut self, x: &[f64]) -> (f64, f64, Vec<f64>, Vec<f64>) {
        let out = self.predict(x, true);
        (out.mean, out.var, out.mean_grad, out.var_grad)
    }

    fn fit_hypers(&mut self) {
        let tcfg = TrainCfg { steps: 8, lr: 0.2, ..Default::default() };
        self.optimize_hypers(&tcfg);
    }

    fn n(&self) -> usize {
        AdditiveGP::n(self)
    }

    fn name(&self) -> &'static str {
        "GKP"
    }
}

impl BoEngine for FullGP {
    fn observe(&mut self, x: &[f64], y: f64) {
        FullGP::observe(self, x, y);
    }

    fn posterior(&mut self, x: &[f64]) -> (f64, f64, Vec<f64>, Vec<f64>) {
        let (mu, s) = self.predict(x);
        let (gmu, gs) = self.predict_grad(x);
        (mu, s, gmu, gs)
    }

    fn fit_hypers(&mut self) {
        self.optimize_shared_omega(1e-3, 1e2, 12);
    }

    fn n(&self) -> usize {
        FullGP::n(self)
    }

    fn name(&self) -> &'static str {
        "FGP"
    }
}

/// BO run configuration (paper §7.2 protocol).
#[derive(Clone, Copy, Debug)]
pub struct BoConfig {
    pub budget: usize,
    pub warmup: usize,
    /// Box bounds (same for every dimension, as in the paper).
    pub lo: f64,
    pub hi: f64,
    /// Refit hyperparameters every `hyper_every` samples (0 = never).
    pub hyper_every: usize,
    pub beta: f64,
    pub seed: u64,
    pub search: SearchCfg,
}

impl Default for BoConfig {
    fn default() -> Self {
        BoConfig {
            budget: 200,
            warmup: 100,
            lo: -500.0,
            hi: 500.0,
            hyper_every: 50,
            beta: 2.0,
            seed: 0xB0,
            search: SearchCfg::default(),
        }
    }
}

/// Result of one BO run.
#[derive(Clone, Debug)]
pub struct BoResult {
    /// Best (lowest) observed value after each post-warmup iteration.
    pub best_trace: Vec<f64>,
    /// All sampled points.
    pub samples: Vec<Vec<f64>>,
    /// Final incumbent.
    pub best_x: Vec<f64>,
    pub best_y: f64,
    /// Wall-clock seconds spent (model + search only, excluding f evals).
    pub model_time_s: f64,
}

/// Run Algorithm 1 *minimizing* the noisy objective with GP-LCB.
pub fn run_bo<E: BoEngine>(
    engine: &mut E,
    obj: &NoisyObjective,
    d: usize,
    cfg: &BoConfig,
) -> BoResult {
    let mut rng = Rng::new(cfg.seed);
    let mut best_y = f64::INFINITY;
    let mut best_x = vec![0.0; d];
    let mut best_trace = Vec::with_capacity(cfg.budget);
    let mut samples = Vec::with_capacity(cfg.warmup + cfg.budget);
    let mut model_time = 0.0;

    // Warm-up: uniform random design, absorbed as ONE batch — the sparse
    // engine pays a single splice/sweep/solve per dimension for the whole
    // design instead of per-point work (`BoEngine::observe_batch`).
    let mut wxs: Vec<Vec<f64>> = Vec::with_capacity(cfg.warmup);
    let mut wys: Vec<f64> = Vec::with_capacity(cfg.warmup);
    for _ in 0..cfg.warmup {
        let x: Vec<f64> = (0..d).map(|_| rng.uniform_in(cfg.lo, cfg.hi)).collect();
        let y = obj.sample(&x, &mut rng);
        if y < best_y {
            best_y = y;
            best_x = x.clone();
        }
        wxs.push(x);
        wys.push(y);
    }
    let t0 = std::time::Instant::now();
    engine.observe_batch(&wxs, &wys);
    model_time += t0.elapsed().as_secs_f64();
    samples.extend(wxs);

    for it in 0..cfg.budget {
        let t0 = std::time::Instant::now();
        if cfg.hyper_every > 0 && it % cfg.hyper_every == 0 {
            engine.fit_hypers();
        }
        let acq = Acquisition::LcbMin { beta: cfg.beta };
        let x = search_next(engine, &acq, d, cfg.lo, cfg.hi, &cfg.search, &mut rng);
        model_time += t0.elapsed().as_secs_f64();

        let y = obj.sample(&x, &mut rng);
        if y < best_y {
            best_y = y;
            best_x = x.clone();
        }
        let t1 = std::time::Instant::now();
        engine.observe(&x, y);
        model_time += t1.elapsed().as_secs_f64();
        samples.push(x);
        best_trace.push(best_y);
    }

    BoResult { best_trace, samples, best_x, best_y, model_time_s: model_time }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bo::testfns;
    use crate::gp::model::AdditiveGpConfig;

    /// End-to-end smoke: BO on 2-D Schwefel beats random search.
    #[test]
    fn bo_beats_random_on_schwefel() {
        let d = 2;
        let f = testfns::schwefel;
        let obj = NoisyObjective::new(&f, 1.0);
        let mut cfg = BoConfig {
            budget: 40,
            warmup: 30,
            hyper_every: 0,
            seed: 4,
            ..Default::default()
        };
        cfg.search.restarts = 4;
        cfg.search.steps = 40;
        let mut gpcfg = AdditiveGpConfig::default();
        gpcfg.omega0 = 0.02; // sensible scale for (−500,500)
        let mut engine = AdditiveGP::new(gpcfg, d);
        let res = run_bo(&mut engine, &obj, d, &cfg);

        // Pure random search with the same total evaluations.
        let mut rng = Rng::new(999);
        let mut rand_best = f64::INFINITY;
        for _ in 0..(cfg.warmup + cfg.budget) {
            let x: Vec<f64> = (0..d).map(|_| rng.uniform_in(-500.0, 500.0)).collect();
            rand_best = rand_best.min(obj.sample(&x, &mut rng));
        }
        assert!(res.best_y.is_finite());
        assert_eq!(res.best_trace.len(), 40);
        // BO should not be (much) worse than random at equal budget.
        assert!(
            res.best_y <= rand_best + 50.0,
            "BO best {} vs random {rand_best}",
            res.best_y
        );
        // best_trace must be non-increasing.
        for w in res.best_trace.windows(2) {
            assert!(w[1] <= w[0] + 1e-12);
        }
    }
}
