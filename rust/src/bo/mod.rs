//! Bayesian optimization on additive GPs — paper §2.2, §6 and §7.2.
//!
//! * [`testfns`] — the paper's Schwefel (eq. 31) and Rastrigin (eq. 32)
//!   benchmark functions with the Gaussian noise model.
//! * [`acquisition`] — GP-UCB / GP-LCB / EI values and their sparse-window
//!   gradients (eqs. 27–30).
//! * [`search`] — multi-start projected gradient ascent over the acquisition
//!   with `M̃`-window reuse (the paper's `O(1)`-per-step claim).
//! * [`run`] — Algorithm 1, generic over the GP engine (sparse GKP or the
//!   dense FGP baseline).

pub mod acquisition;
pub mod run;
pub mod search;
pub mod testfns;

pub use acquisition::Acquisition;
pub use run::{BoConfig, BoEngine, BoResult};
