//! The paper's §7 test functions (eqs. 31–32) and noise model.
//!
//! Both are classic multimodal benchmarks; the paper's forms average over
//! dimensions. Observations are corrupted with `ε ~ N(0, 1)` (standard
//! normal), exactly as in §7.

/// Schwefel function (paper eq. 31):
/// `f(x) = 418.9829 − (1/D) Σ_d x_d sin(√|x_d|)`, `x ∈ (−500, 500)^D`.
/// Global minimum at `x_d = 420.9687` (value ≈ 0 per-dimension average).
pub fn schwefel(x: &[f64]) -> f64 {
    let d = x.len() as f64;
    418.9829 - x.iter().map(|&v| v * v.abs().sqrt().sin()).sum::<f64>() / d
}

/// The Schwefel domain.
pub const SCHWEFEL_LO: f64 = -500.0;
pub const SCHWEFEL_HI: f64 = 500.0;
/// Per-coordinate argmin of [`schwefel`].
pub const SCHWEFEL_ARGMIN: f64 = 420.9687;

/// Rastrigin function in the paper's form (eq. 32):
/// `f(x) = 10 − (1/D) Σ_d (x_d² − 10 cos(2π x_d))`, `x ∈ (−5.12, 5.12)^D`.
/// (As printed the paper's form is *maximized* at 0; its global *minimum*
/// over the box is at the corners. We keep the printed form and minimize it,
/// matching the paper's "searching the global minimizer" protocol.)
pub fn rastrigin(x: &[f64]) -> f64 {
    let d = x.len() as f64;
    10.0 - x.iter().map(|&v| v * v - 10.0 * (2.0 * std::f64::consts::PI * v).cos()).sum::<f64>() / d
}

pub const RASTRIGIN_LO: f64 = -5.12;
pub const RASTRIGIN_HI: f64 = 5.12;

/// The classical (minimization) Rastrigin, `Σ_d (x² − 10cos 2πx + 10)/D`,
/// minimized at the origin — used by the prediction benchmark where only
/// the surface shape matters.
pub fn rastrigin_classic(x: &[f64]) -> f64 {
    let d = x.len() as f64;
    x.iter()
        .map(|&v| v * v - 10.0 * (2.0 * std::f64::consts::PI * v).cos() + 10.0)
        .sum::<f64>()
        / d
}

/// A noisy objective: `f(x) + ε`, `ε ~ N(0, noise_sd²)`.
pub struct NoisyObjective<'a> {
    pub f: &'a dyn Fn(&[f64]) -> f64,
    pub noise_sd: f64,
    pub evals: std::cell::Cell<usize>,
}

impl<'a> NoisyObjective<'a> {
    pub fn new(f: &'a dyn Fn(&[f64]) -> f64, noise_sd: f64) -> Self {
        NoisyObjective { f, noise_sd, evals: std::cell::Cell::new(0) }
    }

    pub fn sample(&self, x: &[f64], rng: &mut crate::util::Rng) -> f64 {
        self.evals.set(self.evals.get() + 1);
        (self.f)(x) + self.noise_sd * rng.normal()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schwefel_minimum_location() {
        let d = 5;
        let xstar = vec![SCHWEFEL_ARGMIN; d];
        let fstar = schwefel(&xstar);
        assert!(fstar.abs() < 0.01, "f(x*) = {fstar}");
        // Any random point is worse.
        let mut rng = crate::util::Rng::new(1);
        for _ in 0..100 {
            let x: Vec<f64> = (0..d).map(|_| rng.uniform_in(-500.0, 500.0)).collect();
            assert!(schwefel(&x) >= fstar - 1e-9);
        }
    }

    #[test]
    fn rastrigin_forms() {
        let x0 = vec![0.0; 4];
        assert!((rastrigin(&x0) - 20.0).abs() < 1e-12); // 10 − (−10) = 20
        assert!(rastrigin_classic(&x0).abs() < 1e-12);
        assert!(rastrigin_classic(&[1.0, 1.0]) > 0.0);
    }

    #[test]
    fn noise_model() {
        let f = |_: &[f64]| 1.0;
        let obj = NoisyObjective::new(&f, 1.0);
        let mut rng = crate::util::Rng::new(2);
        let n = 5000;
        let mut sum = 0.0;
        for _ in 0..n {
            sum += obj.sample(&[0.0], &mut rng);
        }
        assert!((sum / n as f64 - 1.0).abs() < 0.05);
        assert_eq!(obj.evals.get(), n);
    }
}
