//! Acquisition functions and their gradients — paper §2.2 and §6.
//!
//! All acquisitions are *maximized* by the searcher. For minimization
//! problems (the paper's Schwefel/Rastrigin experiments) use [`Acquisition::LcbMin`],
//! which maximizes `−μ + β√s` (the lower-confidence-bound rule).
//!
//! Values and gradients are assembled from `(μ, s, ∇μ, ∇s)`, which the
//! sparse engine provides in `O(log n)`→`O(1)` per point (eqs. 28–30); the
//! gradient of any acquisition is then `O(D)` extra (§6's "independent of
//! n" claim).

/// Which acquisition rule to use.
#[derive(Clone, Copy, Debug)]
pub enum Acquisition {
    /// GP-UCB (maximization): `A = μ + β√s` (eq. 27).
    UcbMax { beta: f64 },
    /// GP-LCB for minimization: `A = −μ + β√s`.
    LcbMin { beta: f64 },
    /// Expected improvement for maximization over current best `y⁺`:
    /// `A = (μ−y⁺)Φ(z) + √s φ(z)`, `z = (μ−y⁺)/√s`.
    EiMax { best: f64 },
    /// Expected improvement for minimization (improvement `y⁻ − μ`).
    EiMin { best: f64 },
}

/// Standard normal pdf.
fn phi_pdf(z: f64) -> f64 {
    (-0.5 * z * z).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

/// Standard normal cdf via `erf` (Abramowitz–Stegun 7.1.26, |err| < 1.5e-7
/// — far below the stochastic noise of the surrounding estimators).
fn phi_cdf(z: f64) -> f64 {
    let x = z / std::f64::consts::SQRT_2;
    let t = 1.0 / (1.0 + 0.3275911 * x.abs());
    let poly = t
        * (0.254829592
            + t * (-0.284496736 + t * (1.421413741 + t * (-1.453152027 + t * 1.061405429))));
    let erf = 1.0 - poly * (-x * x).exp();
    let erf = if x >= 0.0 { erf } else { -erf };
    0.5 * (1.0 + erf)
}

impl Acquisition {
    /// Acquisition value from posterior `(μ, s)`.
    pub fn value(&self, mu: f64, s: f64) -> f64 {
        let sd = s.max(1e-300).sqrt();
        match *self {
            Acquisition::UcbMax { beta } => mu + beta * sd,
            Acquisition::LcbMin { beta } => -mu + beta * sd,
            Acquisition::EiMax { best } => {
                let z = (mu - best) / sd;
                (mu - best) * phi_cdf(z) + sd * phi_pdf(z)
            }
            Acquisition::EiMin { best } => {
                let z = (best - mu) / sd;
                (best - mu) * phi_cdf(z) + sd * phi_pdf(z)
            }
        }
    }

    /// Acquisition value and gradient from `(μ, s, ∇μ, ∇s)`.
    pub fn value_grad(
        &self,
        mu: f64,
        s: f64,
        gmu: &[f64],
        gs: &[f64],
    ) -> (f64, Vec<f64>) {
        let sd = s.max(1e-300).sqrt();
        let d = gmu.len();
        let val = self.value(mu, s);
        // ∂A/∂μ and ∂A/∂s, then chain through ∇μ, ∇s.
        let (da_dmu, da_ds) = match *self {
            Acquisition::UcbMax { beta } => (1.0, beta / (2.0 * sd)),
            Acquisition::LcbMin { beta } => (-1.0, beta / (2.0 * sd)),
            Acquisition::EiMax { best } => {
                let z = (mu - best) / sd;
                // dEI/dμ = Φ(z);  dEI/dσ = φ(z);  dσ/ds = 1/(2σ).
                (phi_cdf(z), phi_pdf(z) / (2.0 * sd))
            }
            Acquisition::EiMin { best } => {
                let z = (best - mu) / sd;
                (-phi_cdf(z), phi_pdf(z) / (2.0 * sd))
            }
        };
        let grad = (0..d).map(|i| da_dmu * gmu[i] + da_ds * gs[i]).collect();
        (val, grad)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cdf_pdf_sanity() {
        assert!((phi_cdf(0.0) - 0.5).abs() < 1e-7);
        assert!((phi_cdf(1.96) - 0.975).abs() < 1e-3);
        assert!((phi_cdf(-1.96) - 0.025).abs() < 1e-3);
        assert!((phi_pdf(0.0) - 0.39894228).abs() < 1e-7);
    }

    #[test]
    fn ucb_value_grad() {
        let a = Acquisition::UcbMax { beta: 2.0 };
        let (v, g) = a.value_grad(1.0, 4.0, &[0.5, -0.3], &[0.1, 0.2]);
        assert!((v - (1.0 + 2.0 * 2.0)).abs() < 1e-12);
        // grad = gmu + beta/(2σ) gs = gmu + 0.5 gs
        assert!((g[0] - (0.5 + 0.5 * 0.1)).abs() < 1e-12);
        assert!((g[1] - (-0.3 + 0.5 * 0.2)).abs() < 1e-12);
    }

    /// EI gradient matches finite differences of the value.
    #[test]
    fn ei_grad_matches_fd() {
        let a = Acquisition::EiMin { best: 0.3 };
        let f = |mu: f64, s: f64| a.value(mu, s);
        let (mu, s) = (0.5, 0.8);
        let h = 1e-6;
        let (_, g) = a.value_grad(mu, s, &[1.0, 0.0], &[0.0, 1.0]);
        // g[0] = dA/dμ, g[1] = dA/ds by the chosen unit gradients.
        let fd_mu = (f(mu + h, s) - f(mu - h, s)) / (2.0 * h);
        let fd_s = (f(mu, s + h) - f(mu, s - h)) / (2.0 * h);
        assert!((g[0] - fd_mu).abs() < 1e-5, "{} vs {}", g[0], fd_mu);
        assert!((g[1] - fd_s).abs() < 1e-5, "{} vs {}", g[1], fd_s);
    }

    #[test]
    fn ei_nonnegative_and_monotone_in_s() {
        let a = Acquisition::EiMax { best: 1.0 };
        assert!(a.value(0.0, 0.01) >= 0.0);
        assert!(a.value(0.0, 2.0) > a.value(0.0, 0.5));
    }
}
