//! Sorting permutations — the `P_d` matrices of the paper, stored as index
//! vectors instead of explicit matrices.

/// A permutation `π` of `0..n`, representing the matrix `P` with
/// `P[i, π(i)] = 1`, i.e. `(P^T x)[i] = x[π(i)]` gathers into sorted order
/// when `π` is the argsort of the points.
#[derive(Clone, Debug)]
pub struct Permutation {
    /// `fwd[s]` = original index of the point at sorted position `s`.
    fwd: Vec<usize>,
    /// `inv[o]` = sorted position of original index `o`.
    inv: Vec<usize>,
}

impl Permutation {
    /// Argsort permutation of `points` (increasing). `O(n log n)`.
    pub fn sorting(points: &[f64]) -> Self {
        let mut fwd: Vec<usize> = (0..points.len()).collect();
        fwd.sort_by(|&a, &b| points[a].partial_cmp(&points[b]).unwrap());
        let mut inv = vec![0usize; points.len()];
        for (s, &o) in fwd.iter().enumerate() {
            inv[o] = s;
        }
        Permutation { fwd, inv }
    }

    /// Identity permutation.
    pub fn identity(n: usize) -> Self {
        let fwd: Vec<usize> = (0..n).collect();
        Permutation { inv: fwd.clone(), fwd }
    }

    pub fn len(&self) -> usize {
        self.fwd.len()
    }

    pub fn is_empty(&self) -> bool {
        self.fwd.is_empty()
    }

    /// Original index of sorted position `s`.
    #[inline]
    pub fn orig(&self, s: usize) -> usize {
        self.fwd[s]
    }

    /// Sorted position of original index `o`.
    #[inline]
    pub fn sorted_pos(&self, o: usize) -> usize {
        self.inv[o]
    }

    /// Gather `x` (original order) into sorted order: `y[s] = x[orig(s)]`.
    pub fn to_sorted(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; x.len()];
        self.to_sorted_into(x, &mut y);
        y
    }

    /// [`Permutation::to_sorted`] into a caller-owned buffer — the
    /// allocation-free form used by the hot solve loops (DESIGN.md §Perf).
    pub fn to_sorted_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.fwd.len());
        assert_eq!(y.len(), self.fwd.len());
        for (s, &o) in self.fwd.iter().enumerate() {
            y[s] = x[o];
        }
    }

    /// Scatter `x` (sorted order) back to original order.
    pub fn to_original(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; x.len()];
        self.to_original_into(x, &mut y);
        y
    }

    /// [`Permutation::to_original`] into a caller-owned buffer.
    pub fn to_original_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.fwd.len());
        assert_eq!(y.len(), self.fwd.len());
        for (s, &o) in self.fwd.iter().enumerate() {
            y[o] = x[s];
        }
    }

    /// The sorted copy of `points` (convenience).
    pub fn apply_sort(&self, points: &[f64]) -> Vec<f64> {
        self.to_sorted(points)
    }

    /// Extend the permutation with one new element: the new *original* index
    /// is `len()` (appended in data order) and it lands at `sorted_pos` in
    /// sorted order. `O(n)`.
    pub fn insert(&mut self, sorted_pos: usize) {
        assert!(sorted_pos <= self.fwd.len());
        let o = self.fwd.len();
        self.fwd.insert(sorted_pos, o);
        for v in self.inv.iter_mut() {
            if *v >= sorted_pos {
                *v += 1;
            }
        }
        self.inv.push(sorted_pos);
    }

    /// Extend the permutation with `k` new elements in one `O(n + k)` merge:
    /// the t-th new element gets original index `len() + t` (appended in
    /// data order) and lands at sorted position `final_positions[t]` *in the
    /// grown permutation*. Positions must be distinct (they are final slots,
    /// so they need not be ordered). Equivalent to the corresponding
    /// sequence of [`Permutation::insert`] calls, without the `O(n)` `inv`
    /// rewrite per element.
    pub fn insert_batch(&mut self, final_positions: &[usize]) {
        let k = final_positions.len();
        if k == 0 {
            return;
        }
        let n_old = self.fwd.len();
        let n_new = n_old + k;
        let mut slot = vec![usize::MAX; n_new];
        for (t, &p) in final_positions.iter().enumerate() {
            assert!(p < n_new, "insert_batch: position {p} out of range {n_new}");
            assert!(
                slot[p] == usize::MAX,
                "insert_batch: duplicate final position {p}"
            );
            slot[p] = n_old + t;
        }
        let old = std::mem::take(&mut self.fwd);
        let mut old_iter = old.into_iter();
        let mut fwd = Vec::with_capacity(n_new);
        for s in slot {
            if s != usize::MAX {
                fwd.push(s);
            } else {
                fwd.push(old_iter.next().expect("slot bookkeeping"));
            }
        }
        let mut inv = vec![0usize; n_new];
        for (s, &o) in fwd.iter().enumerate() {
            inv[o] = s;
        }
        self.fwd = fwd;
        self.inv = inv;
    }
}

/// Binary search: largest `i` with `xs[i] <= x` in a sorted slice, or `None`
/// if `x < xs[0]`. This is the `O(log n)` window lookup of §5.2.
pub fn lower_index(xs: &[f64], x: f64) -> Option<usize> {
    if xs.is_empty() || x < xs[0] {
        return None;
    }
    let mut lo = 0usize;
    let mut hi = xs.len(); // invariant: xs[lo] <= x < xs[hi]
    while lo + 1 < hi {
        let mid = (lo + hi) / 2;
        if xs[mid] <= x {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    Some(lo)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sort_roundtrip() {
        let pts = vec![3.0, -1.0, 2.0, 0.5];
        let p = Permutation::sorting(&pts);
        let s = p.to_sorted(&pts);
        assert_eq!(s, vec![-1.0, 0.5, 2.0, 3.0]);
        assert_eq!(p.to_original(&s), pts);
        for o in 0..4 {
            assert_eq!(p.orig(p.sorted_pos(o)), o);
        }
    }

    /// Incremental insert matches the argsort of the extended point set.
    #[test]
    fn insert_matches_fresh_sort() {
        let mut pts = vec![3.0, -1.0, 2.0, 0.5];
        let mut p = Permutation::sorting(&pts);
        for &x in &[1.5, -2.0, 4.0, 0.0] {
            let pos = match lower_index(&p.apply_sort(&pts), x) {
                None => 0,
                Some(i) => i + 1,
            };
            pts.push(x);
            p.insert(pos);
            let fresh = Permutation::sorting(&pts);
            for o in 0..pts.len() {
                assert_eq!(p.sorted_pos(o), fresh.sorted_pos(o), "x={x} o={o}");
                assert_eq!(p.orig(p.sorted_pos(o)), o);
            }
        }
    }

    /// `insert_batch` equals the argsort of the extended point set (and thus
    /// the equivalent sequence of single inserts).
    #[test]
    fn insert_batch_matches_fresh_sort() {
        let mut pts = vec![3.0, -1.0, 2.0, 0.5, 1.0];
        let mut p = Permutation::sorting(&pts);
        let news = [1.5, -2.0, 4.0, 0.7];
        // Final positions of the new values in the fully-merged sort order.
        let mut all = pts.clone();
        all.extend_from_slice(&news);
        let fresh = Permutation::sorting(&all);
        let final_positions: Vec<usize> =
            (0..news.len()).map(|t| fresh.sorted_pos(pts.len() + t)).collect();
        p.insert_batch(&final_positions);
        pts = all;
        assert_eq!(p.len(), pts.len());
        for o in 0..pts.len() {
            assert_eq!(p.sorted_pos(o), fresh.sorted_pos(o), "o={o}");
            assert_eq!(p.orig(p.sorted_pos(o)), o);
        }
        // Round-trip still works.
        let s = p.apply_sort(&pts);
        assert_eq!(p.to_original(&s), pts);
    }

    #[test]
    fn lower_index_edges() {
        let xs = vec![0.0, 1.0, 2.0, 3.0];
        assert_eq!(lower_index(&xs, -0.5), None);
        assert_eq!(lower_index(&xs, 0.0), Some(0));
        assert_eq!(lower_index(&xs, 1.5), Some(1));
        assert_eq!(lower_index(&xs, 3.0), Some(3));
        assert_eq!(lower_index(&xs, 99.0), Some(3));
    }
}
