//! Sorting permutations — the `P_d` matrices of the paper, stored as index
//! vectors instead of explicit matrices.

use crate::check::{enforce, Audit, AuditError};

/// A permutation `π` of `0..n`, representing the matrix `P` with
/// `P[i, π(i)] = 1`, i.e. `(P^T x)[i] = x[π(i)]` gathers into sorted order
/// when `π` is the argsort of the points.
#[derive(Clone, Debug)]
pub struct Permutation {
    /// `fwd[s]` = original index of the point at sorted position `s`.
    fwd: Vec<usize>,
    /// `inv[o]` = sorted position of original index `o`.
    inv: Vec<usize>,
}

impl Permutation {
    /// Argsort permutation of `points` (increasing). `O(n log n)`.
    pub fn sorting(points: &[f64]) -> Self {
        let mut fwd: Vec<usize> = (0..points.len()).collect();
        fwd.sort_by(|&a, &b| points[a].total_cmp(&points[b]));
        let mut inv = vec![0usize; points.len()];
        for (s, &o) in fwd.iter().enumerate() {
            inv[o] = s;
        }
        Permutation { fwd, inv }
    }

    /// Identity permutation.
    pub fn identity(n: usize) -> Self {
        let fwd: Vec<usize> = (0..n).collect();
        Permutation { inv: fwd.clone(), fwd }
    }

    pub fn len(&self) -> usize {
        self.fwd.len()
    }

    pub fn is_empty(&self) -> bool {
        self.fwd.is_empty()
    }

    /// Original index of sorted position `s`.
    #[inline]
    pub fn orig(&self, s: usize) -> usize {
        self.fwd[s]
    }

    /// Sorted position of original index `o`.
    #[inline]
    pub fn sorted_pos(&self, o: usize) -> usize {
        self.inv[o]
    }

    /// Gather `x` (original order) into sorted order: `y[s] = x[orig(s)]`.
    pub fn to_sorted(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; x.len()];
        self.to_sorted_into(x, &mut y);
        y
    }

    /// [`Permutation::to_sorted`] into a caller-owned buffer — the
    /// allocation-free form used by the hot solve loops (DESIGN.md §Perf).
    pub fn to_sorted_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.fwd.len());
        assert_eq!(y.len(), self.fwd.len());
        for (s, &o) in self.fwd.iter().enumerate() {
            y[s] = x[o];
        }
    }

    /// Scatter `x` (sorted order) back to original order.
    pub fn to_original(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; x.len()];
        self.to_original_into(x, &mut y);
        y
    }

    /// [`Permutation::to_original`] into a caller-owned buffer.
    pub fn to_original_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.fwd.len());
        assert_eq!(y.len(), self.fwd.len());
        for (s, &o) in self.fwd.iter().enumerate() {
            y[o] = x[s];
        }
    }

    /// The sorted copy of `points` (convenience).
    pub fn apply_sort(&self, points: &[f64]) -> Vec<f64> {
        self.to_sorted(points)
    }

    /// The forward index vector (`fwd[s]` = original index of sorted
    /// position `s`) — the checkpoint serialization surface: `inv` is
    /// derived, so only `fwd` travels.
    pub fn fwd(&self) -> &[usize] {
        &self.fwd
    }

    /// Rebuild from a serialized forward vector, recomputing the inverse.
    /// Errors (instead of panicking) on a non-bijection, so a corrupt
    /// checkpoint surfaces as a recovery error.
    pub fn from_fwd(fwd: Vec<usize>) -> Result<Self, String> {
        let n = fwd.len();
        let mut inv = vec![usize::MAX; n];
        for (s, &o) in fwd.iter().enumerate() {
            if o >= n || inv[o] != usize::MAX {
                return Err(format!("permutation fwd is not a bijection at sorted pos {s}"));
            }
            inv[o] = s;
        }
        Ok(Permutation { fwd, inv })
    }

    /// Extend the permutation with one new element: the new *original* index
    /// is `len()` (appended in data order) and it lands at `sorted_pos` in
    /// sorted order. `O(n)`.
    pub fn insert(&mut self, sorted_pos: usize) {
        assert!(sorted_pos <= self.fwd.len());
        let o = self.fwd.len();
        self.fwd.insert(sorted_pos, o);
        for v in self.inv.iter_mut() {
            if *v >= sorted_pos {
                *v += 1;
            }
        }
        self.inv.push(sorted_pos);
        enforce(self, "Permutation::insert");
    }

    /// Extend the permutation with `k` new elements in one `O(n + k)` merge:
    /// the t-th new element gets original index `len() + t` (appended in
    /// data order) and lands at sorted position `final_positions[t]` *in the
    /// grown permutation*. Positions must be distinct (they are final slots,
    /// so they need not be ordered). Equivalent to the corresponding
    /// sequence of [`Permutation::insert`] calls, without the `O(n)` `inv`
    /// rewrite per element.
    pub fn insert_batch(&mut self, final_positions: &[usize]) {
        let k = final_positions.len();
        if k == 0 {
            return;
        }
        let n_old = self.fwd.len();
        let n_new = n_old + k;
        let mut slot = vec![usize::MAX; n_new];
        for (t, &p) in final_positions.iter().enumerate() {
            assert!(p < n_new, "insert_batch: position {p} out of range {n_new}");
            assert!(
                slot[p] == usize::MAX,
                "insert_batch: duplicate final position {p}"
            );
            slot[p] = n_old + t;
        }
        let old = std::mem::take(&mut self.fwd);
        let mut old_iter = old.into_iter();
        let mut fwd = Vec::with_capacity(n_new);
        for s in slot {
            if s != usize::MAX {
                fwd.push(s);
            } else {
                fwd.push(old_iter.next().expect("slot bookkeeping"));
            }
        }
        let mut inv = vec![0usize; n_new];
        for (s, &o) in fwd.iter().enumerate() {
            inv[o] = s;
        }
        self.fwd = fwd;
        self.inv = inv;
        enforce(self, "Permutation::insert_batch");
    }

    /// Remove the element at sorted position `sorted_pos` — the deletion
    /// mirror of [`Permutation::insert`]. Returns the *original* (data-order)
    /// index of the removed element; surviving original indices above it
    /// shift down by one (the data arrays compact the same way), as do
    /// sorted positions above `sorted_pos`. `O(n)`.
    pub fn remove(&mut self, sorted_pos: usize) -> usize {
        assert!(sorted_pos < self.fwd.len());
        let o = self.fwd.remove(sorted_pos);
        for v in self.fwd.iter_mut() {
            if *v > o {
                *v -= 1;
            }
        }
        self.inv.remove(o);
        for v in self.inv.iter_mut() {
            if *v > sorted_pos {
                *v -= 1;
            }
        }
        enforce(self, "Permutation::remove");
        o
    }

    /// Remove `k` elements in one `O(n + k log k)` pass. `sorted_positions`
    /// are current sorted positions, strictly increasing. Returns the
    /// removed elements' *original* indices (pre-compaction, in the order of
    /// `sorted_positions`). Equivalent to removing the positions one at a
    /// time in descending order.
    pub fn remove_batch(&mut self, sorted_positions: &[usize]) -> Vec<usize> {
        let k = sorted_positions.len();
        if k == 0 {
            return Vec::new();
        }
        let n_old = self.fwd.len();
        for (t, &s) in sorted_positions.iter().enumerate() {
            assert!(s < n_old, "remove_batch: position {s} out of range {n_old}");
            if t > 0 {
                assert!(
                    s > sorted_positions[t - 1],
                    "remove_batch: positions must be strictly increasing"
                );
            }
        }
        let removed_orig: Vec<usize> =
            sorted_positions.iter().map(|&s| self.fwd[s]).collect();
        // shift[o] = number of removed original indices < o.
        let mut orig_removed = vec![false; n_old];
        for &o in &removed_orig {
            orig_removed[o] = true;
        }
        let mut shift = vec![0usize; n_old];
        let mut acc = 0usize;
        for (o, s) in shift.iter_mut().enumerate() {
            *s = acc;
            if orig_removed[o] {
                acc += 1;
            }
        }
        let mut fwd = Vec::with_capacity(n_old - k);
        let mut t = 0usize;
        for (s, &o) in self.fwd.iter().enumerate() {
            if t < k && sorted_positions[t] == s {
                t += 1;
                continue;
            }
            fwd.push(o - shift[o]);
        }
        let mut inv = vec![0usize; n_old - k];
        for (s, &o) in fwd.iter().enumerate() {
            inv[o] = s;
        }
        self.fwd = fwd;
        self.inv = inv;
        enforce(self, "Permutation::remove_batch");
        removed_orig
    }
}

impl Audit for Permutation {
    /// A permutation must be a bijection of `0..n` with `inv` the exact
    /// inverse of `fwd` — both directions are checked so a failure names the
    /// first sorted position (field `fwd`) or original index (field `inv`)
    /// where the round trip breaks.
    fn audit(&self) -> Result<(), AuditError> {
        let n = self.fwd.len();
        if self.inv.len() != n {
            return Err(AuditError::new(
                "Permutation",
                "inv",
                None,
                format!("inv length {} != fwd length {}", self.inv.len(), n),
            ));
        }
        for (s, &o) in self.fwd.iter().enumerate() {
            if o >= n {
                return Err(AuditError::new(
                    "Permutation",
                    "fwd",
                    Some(s),
                    format!("original index {o} out of range for n = {n}"),
                ));
            }
            if self.inv[o] != s {
                return Err(AuditError::new(
                    "Permutation",
                    "fwd",
                    Some(s),
                    format!("inv[fwd[{s}] = {o}] = {} != {s} (not a bijection)", self.inv[o]),
                ));
            }
        }
        for (o, &s) in self.inv.iter().enumerate() {
            if s >= n || self.fwd[s] != o {
                return Err(AuditError::new(
                    "Permutation",
                    "inv",
                    Some(o),
                    format!("fwd[inv[{o}] = {s}] does not round-trip"),
                ));
            }
        }
        Ok(())
    }
}

/// Binary search: largest `i` with `xs[i] <= x` in a sorted slice, or `None`
/// if `x < xs[0]`. This is the `O(log n)` window lookup of §5.2.
pub fn lower_index(xs: &[f64], x: f64) -> Option<usize> {
    if xs.is_empty() || x < xs[0] {
        return None;
    }
    let mut lo = 0usize;
    let mut hi = xs.len(); // invariant: xs[lo] <= x < xs[hi]
    while lo + 1 < hi {
        let mid = (lo + hi) / 2;
        if xs[mid] <= x {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    Some(lo)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sort_roundtrip() {
        let pts = vec![3.0, -1.0, 2.0, 0.5];
        let p = Permutation::sorting(&pts);
        let s = p.to_sorted(&pts);
        assert_eq!(s, vec![-1.0, 0.5, 2.0, 3.0]);
        assert_eq!(p.to_original(&s), pts);
        for o in 0..4 {
            assert_eq!(p.orig(p.sorted_pos(o)), o);
        }
    }

    /// Incremental insert matches the argsort of the extended point set.
    #[test]
    fn insert_matches_fresh_sort() {
        let mut pts = vec![3.0, -1.0, 2.0, 0.5];
        let mut p = Permutation::sorting(&pts);
        for &x in &[1.5, -2.0, 4.0, 0.0] {
            let pos = match lower_index(&p.apply_sort(&pts), x) {
                None => 0,
                Some(i) => i + 1,
            };
            pts.push(x);
            p.insert(pos);
            let fresh = Permutation::sorting(&pts);
            for o in 0..pts.len() {
                assert_eq!(p.sorted_pos(o), fresh.sorted_pos(o), "x={x} o={o}");
                assert_eq!(p.orig(p.sorted_pos(o)), o);
            }
        }
    }

    /// `insert_batch` equals the argsort of the extended point set (and thus
    /// the equivalent sequence of single inserts).
    #[test]
    fn insert_batch_matches_fresh_sort() {
        let mut pts = vec![3.0, -1.0, 2.0, 0.5, 1.0];
        let mut p = Permutation::sorting(&pts);
        let news = [1.5, -2.0, 4.0, 0.7];
        // Final positions of the new values in the fully-merged sort order.
        let mut all = pts.clone();
        all.extend_from_slice(&news);
        let fresh = Permutation::sorting(&all);
        let final_positions: Vec<usize> =
            (0..news.len()).map(|t| fresh.sorted_pos(pts.len() + t)).collect();
        p.insert_batch(&final_positions);
        pts = all;
        assert_eq!(p.len(), pts.len());
        for o in 0..pts.len() {
            assert_eq!(p.sorted_pos(o), fresh.sorted_pos(o), "o={o}");
            assert_eq!(p.orig(p.sorted_pos(o)), o);
        }
        // Round-trip still works.
        let s = p.apply_sort(&pts);
        assert_eq!(p.to_original(&s), pts);
    }

    /// Incremental remove matches the argsort of the compacted point set.
    #[test]
    fn remove_matches_fresh_sort() {
        let mut pts = vec![3.0, -1.0, 2.0, 0.5, 1.5, -2.0, 4.0, 0.0];
        let mut p = Permutation::sorting(&pts);
        for sorted_pos in [0usize, 5, 2, 4] {
            let o = p.remove(sorted_pos);
            let sorted = {
                let mut s = pts.clone();
                s.sort_by(f64::total_cmp);
                s
            };
            assert_eq!(pts[o], sorted[sorted_pos], "removed the right element");
            pts.remove(o);
            let fresh = Permutation::sorting(&pts);
            for q in 0..pts.len() {
                assert_eq!(p.sorted_pos(q), fresh.sorted_pos(q), "pos={sorted_pos} o={q}");
                assert_eq!(p.orig(p.sorted_pos(q)), q);
            }
        }
    }

    /// `remove_batch` equals the corresponding sequence of single removes
    /// (walked in descending order), and reports the same original indices.
    #[test]
    fn remove_batch_matches_single_removes() {
        let pts = vec![3.0, -1.0, 2.0, 0.5, 1.5, -2.0, 4.0, 0.0, 2.5];
        for positions in [vec![0usize, 1], vec![2, 5, 8], vec![7, 8], vec![4]] {
            let mut batched = Permutation::sorting(&pts);
            let origs = batched.remove_batch(&positions);
            let mut seq = Permutation::sorting(&pts);
            let mut seq_origs = vec![0usize; positions.len()];
            for (t, &s) in positions.iter().enumerate().rev() {
                seq_origs[t] = seq.remove(s);
            }
            // Descending single removes report post-compaction original
            // indices for later positions; map them back for comparison.
            for t in 0..positions.len() {
                let mut o = seq_origs[t];
                for &later in &seq_origs[t + 1..] {
                    if later <= o {
                        o += 1;
                    }
                }
                assert_eq!(origs[t], o, "{positions:?} t={t}");
            }
            assert_eq!(batched.len(), seq.len());
            for q in 0..batched.len() {
                assert_eq!(batched.sorted_pos(q), seq.sorted_pos(q), "{positions:?}");
            }
            assert!(batched.audit().is_ok());
        }
    }

    /// Breaking the bijection is pinpointed at the first bad sorted slot.
    #[test]
    fn audit_flags_broken_bijection() {
        let mut p = Permutation::sorting(&[3.0, -1.0, 2.0, 0.5]);
        assert!(p.audit().is_ok());
        p.fwd[1] = p.fwd[2]; // duplicate original index: no longer a bijection
        let e = p.audit().unwrap_err();
        assert_eq!(e.structure, "Permutation");
        assert_eq!(e.field, "fwd");
        assert!(e.index == Some(1) || e.index == Some(2), "{e}");
    }

    /// A desynchronized inverse is pinpointed at the original index.
    #[test]
    fn audit_flags_desynced_inverse() {
        let mut p = Permutation::sorting(&[3.0, -1.0, 2.0, 0.5]);
        p.inv[0] = 99;
        let e = p.audit().unwrap_err();
        assert_eq!(e.structure, "Permutation");
        assert!(e.to_string().contains("Permutation."), "{e}");
    }

    #[test]
    fn lower_index_edges() {
        let xs = vec![0.0, 1.0, 2.0, 3.0];
        assert_eq!(lower_index(&xs, -0.5), None);
        assert_eq!(lower_index(&xs, 0.0), Some(0));
        assert_eq!(lower_index(&xs, 1.5), Some(1));
        assert_eq!(lower_index(&xs, 3.0), Some(3));
        assert_eq!(lower_index(&xs, 99.0), Some(3));
    }
}
