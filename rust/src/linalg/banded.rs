//! General banded matrices in LAPACK-like band storage, with matrix–vector
//! products and an LU factorization with partial pivoting (the `O(b²n)`
//! "banded matrix solver"/"LU decomposition" primitive the paper leans on
//! throughout Table 1).

use std::sync::Arc;

use crate::check::{enforce, Audit, AuditError};
use crate::linalg::chunks::{ChunkedRows, RowCursor, StorageStats};

/// An `n × n` banded matrix with `kl` sub-diagonals and `ku` super-diagonals.
///
/// Entry `(i, j)` is stored iff `j - i ∈ [-kl, ku]`; reads outside the band
/// return `0.0`, writes outside the band panic. The logical layout is
/// row-major band storage — row `i` is a `kl+ku+1`-wide slice with column
/// `j` at in-row offset `j - i + kl` — physically held in a chunked
/// copy-on-write rope ([`ChunkedRows`]): appends touch only the tail chunk,
/// splices rewrite only straddled chunks, and `clone` is a reference bump
/// (see DESIGN.md §"Chunked COW band storage").
#[derive(Clone, Debug)]
pub struct Banded {
    n: usize,
    kl: usize,
    ku: usize,
    store: ChunkedRows,
}

impl Banded {
    /// Zero matrix of size `n` with bandwidths `kl` (lower), `ku` (upper).
    pub fn zeros(n: usize, kl: usize, ku: usize) -> Self {
        Banded { n, kl, ku, store: ChunkedRows::zeros(kl + ku + 1, n) }
    }

    /// Identity matrix stored with the given bandwidths.
    pub fn eye(n: usize, kl: usize, ku: usize) -> Self {
        let mut m = Self::zeros(n, kl, ku);
        for i in 0..n {
            m.set(i, i, 1.0);
        }
        m
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn kl(&self) -> usize {
        self.kl
    }

    pub fn ku(&self) -> usize {
        self.ku
    }

    /// `true` iff `(i, j)` lies inside the stored band.
    #[inline]
    pub fn in_band(&self, i: usize, j: usize) -> bool {
        j + self.kl >= i && j <= i + self.ku && i < self.n && j < self.n
    }

    /// Row `i` of the band storage as a `kl+ku+1`-wide slice (column `j` at
    /// in-row offset `j - i + kl`).
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        self.store.row(i)
    }

    /// Mutable row `i` — copy-on-write: a chunk shared with a snapshot is
    /// deep-copied first.
    #[inline]
    fn row_mut(&mut self, i: usize) -> &mut [f64] {
        self.store.row_mut(i)
    }

    /// Chunk cursor for amortized-O(1) row lookup in sequential sweeps.
    #[inline]
    pub fn row_cursor(&self) -> RowCursor {
        self.store.cursor()
    }

    /// Row `i` through a cursor (see [`ChunkedRows::row_at`]).
    #[inline]
    pub fn row_at<'a>(&'a self, cur: &mut RowCursor, i: usize) -> &'a [f64] {
        self.store.row_at(cur, i)
    }

    /// Read entry `(i, j)`; zero outside the band.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        if self.in_band(i, j) {
            self.store.row(i)[j + self.kl - i]
        } else {
            0.0
        }
    }

    /// Write entry `(i, j)`. Panics outside the band.
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        assert!(
            self.in_band(i, j),
            "set({i},{j}) outside band kl={} ku={} n={}",
            self.kl,
            self.ku,
            self.n
        );
        let off = j + self.kl - i;
        self.store.row_mut(i)[off] = v;
    }

    /// Add `v` to entry `(i, j)`. Panics outside the band.
    #[inline]
    pub fn add(&mut self, i: usize, j: usize, v: f64) {
        assert!(self.in_band(i, j), "add({i},{j}) outside band");
        let off = j + self.kl - i;
        self.store.row_mut(i)[off] += v;
    }

    /// Column range `[lo, hi)` of stored entries in row `i`.
    #[inline]
    pub fn row_range(&self, i: usize) -> (usize, usize) {
        (i.saturating_sub(self.kl), (i + self.ku + 1).min(self.n))
    }

    /// `y = self * x`.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.n];
        self.matvec_into(x, &mut y);
        y
    }

    /// `y = self * x` into a caller-owned buffer — the allocation-free form
    /// used by the hot solve loops (DESIGN.md §Perf).
    pub fn matvec_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.n);
        assert_eq!(y.len(), self.n);
        for (i, row) in self.store.iter_rows().enumerate() {
            let (lo, hi) = self.row_range(i);
            let mut acc = 0.0;
            for j in lo..hi {
                acc += row[j + self.kl - i] * x[j];
            }
            y[i] = acc;
        }
    }

    /// `y = self^T * x`.
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.n);
        let mut y = vec![0.0; self.n];
        for (i, row) in self.store.iter_rows().enumerate() {
            let (lo, hi) = self.row_range(i);
            let xi = x[i];
            if xi != 0.0 {
                for j in lo..hi {
                    y[j] += row[j + self.kl - i] * xi;
                }
            }
        }
        y
    }

    /// Transposed copy (bandwidths swap).
    pub fn transpose(&self) -> Banded {
        let mut t = Banded::zeros(self.n, self.ku, self.kl);
        for i in 0..self.n {
            let (lo, hi) = self.row_range(i);
            for j in lo..hi {
                t.set(j, i, self.get(i, j));
            }
        }
        t
    }

    /// Banded × banded product. The result has bandwidths
    /// `(kl1 + kl2, ku1 + ku2)` (clipped to the matrix size).
    pub fn matmul(&self, other: &Banded) -> Banded {
        assert_eq!(self.n, other.n);
        let kl = (self.kl + other.kl).min(self.n - 1);
        let ku = (self.ku + other.ku).min(self.n - 1);
        let mut out = Banded::zeros(self.n, kl, ku);
        for i in 0..self.n {
            let (lo, hi) = self.row_range(i);
            for k in lo..hi {
                let a = self.get(i, k);
                if a == 0.0 {
                    continue;
                }
                let (lo2, hi2) = other.row_range(k);
                for j in lo2..hi2 {
                    let v = a * other.get(k, j);
                    if out.in_band(i, j) {
                        out.add(i, j, v);
                    } else if v.abs() > 1e-12 {
                        panic!("matmul fill outside declared band at ({i},{j})");
                    }
                }
            }
        }
        out
    }

    /// `self + alpha * other`, widening the band as needed.
    pub fn add_scaled(&self, other: &Banded, alpha: f64) -> Banded {
        assert_eq!(self.n, other.n);
        let kl = self.kl.max(other.kl);
        let ku = self.ku.max(other.ku);
        let mut out = Banded::zeros(self.n, kl, ku);
        for i in 0..self.n {
            let (lo, hi) = out.row_range(i);
            for j in lo..hi {
                out.set(i, j, self.get(i, j) + alpha * other.get(i, j));
            }
        }
        out
    }

    /// Scale all entries in place (copy-on-write unshares every chunk).
    pub fn scale(&mut self, alpha: f64) {
        self.store.map_in_place(|v| *v *= alpha);
    }

    /// Densify (for tests / tiny problems).
    pub fn to_dense(&self) -> crate::linalg::Dense {
        let mut d = crate::linalg::Dense::zeros(self.n, self.n);
        for i in 0..self.n {
            let (lo, hi) = self.row_range(i);
            for j in lo..hi {
                d.set(i, j, self.get(i, j));
            }
        }
        d
    }

    /// Insert a zero row *and* zero column at index `j`, growing the matrix
    /// to `(n+1) × (n+1)`. Only the row-block chunks the splice straddles
    /// are rewritten — `O((kl+ku)·CHUNK)` bytes moved, independent of `n`;
    /// an append moves nothing.
    ///
    /// Because band storage addresses column `j` at the fixed in-row offset
    /// `j - i + kl`, splicing one zero row-block shifts every later row *and*
    /// its stored columns together, so rows whose stored window lies entirely
    /// on one side of `j` keep exactly their old entries. Only rows whose
    /// window straddles `j` (those with `|i - j| ≤ max(kl, ku)`) end up with
    /// entries that refer to shifted columns — callers performing an
    /// incremental update must rewrite that `O(kl+ku)` row window themselves
    /// (see `KpFactorization::insert`).
    pub fn insert_row_col(&mut self, j: usize) {
        self.insert_rows_cols(&[j]);
    }

    /// Insert `k` zero rows *and* zero columns in one pass, growing the
    /// matrix to `(n+k) × (n+k)`. `positions` are the *final* indices of the
    /// new zero rows in the grown matrix, strictly increasing (so
    /// `positions[t] ≤ n + t`). Only the chunks an insertion straddles are
    /// rewritten; every other row-block chunk keeps its buffer verbatim
    /// (structural sharing with outstanding snapshots survives), so the
    /// bytes moved are `O(k·(kl+ku)·CHUNK)` rather than `O((n+k)·(kl+ku))`.
    ///
    /// The caller's contract is the batched form of the single-splice one:
    /// every row within `max(kl, ku)` of any spliced index must be rewritten
    /// afterwards (see `KpFactorization::insert_batch`); all other rows keep
    /// bit-identical entries.
    pub fn insert_rows_cols(&mut self, positions: &[usize]) {
        let k = positions.len();
        if k == 0 {
            return;
        }
        for (t, &q) in positions.iter().enumerate() {
            assert!(
                q <= self.n + t,
                "insert_rows_cols: position {q} out of range for n={} (t={t})",
                self.n
            );
            if t > 0 {
                assert!(
                    q > positions[t - 1],
                    "insert_rows_cols: positions must be strictly increasing"
                );
            }
        }
        self.store.insert_zero_rows(positions);
        self.n += k;
        enforce(self, "Banded::insert_rows_cols");
    }

    /// Remove the row *and* column at index `j`, shrinking the matrix to
    /// `(n−1) × (n−1)` — the deletion mirror of
    /// [`Banded::insert_row_col`]. Only the row-block chunks the deletion
    /// straddles are rewritten.
    ///
    /// Band storage shifts every later row and its stored columns together,
    /// so (exactly as for the insert) rows whose stored window lies entirely
    /// on one side of `j` keep bit-identical entries; rows with
    /// `|i - j| ≤ max(kl, ku)` (post-removal indices) end up referring to
    /// shifted columns and must be rewritten by the caller (see
    /// `KpFactorization::remove`).
    pub fn remove_row_col(&mut self, j: usize) {
        self.remove_rows_cols(&[j]);
    }

    /// Remove `k` rows *and* columns in one pass, shrinking the matrix to
    /// `(n−k) × (n−k)`. `positions` are current indices, strictly
    /// increasing, all `< n`. Only the chunks a deletion lands in are
    /// rewritten; every other row-block chunk keeps its buffer verbatim.
    /// The caller's rewrite contract is the batched form of the single one:
    /// every surviving row within `max(kl, ku)` of any removed index must be
    /// rewritten afterwards.
    pub fn remove_rows_cols(&mut self, positions: &[usize]) {
        let k = positions.len();
        if k == 0 {
            return;
        }
        for (t, &q) in positions.iter().enumerate() {
            assert!(
                q < self.n,
                "remove_rows_cols: position {q} out of range for n={}",
                self.n
            );
            if t > 0 {
                assert!(
                    q > positions[t - 1],
                    "remove_rows_cols: positions must be strictly increasing"
                );
            }
        }
        assert!(k <= self.n, "remove_rows_cols: removing more rows than exist");
        self.store.remove_rows(positions);
        self.n -= k;
        enforce(self, "Banded::remove_rows_cols");
    }

    /// LU-factorize with threshold partial pivoting (row swaps only past
    /// `PIVOT_THRESHOLD`). `O((kl+ku)² n)`.
    pub fn lu(&self) -> BandedLU {
        BandedLU::factor(self)
    }

    /// Convenience: solve `self * x = b` via a fresh LU factorization.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        self.lu().solve(b)
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.store
            .iter_rows()
            .map(|row| row.iter().map(|v| v * v).sum::<f64>())
            .sum::<f64>()
            .sqrt()
    }

    /// Storage counters of the backing rope (cumulative `memmove_bytes`,
    /// `chunks_copied`, plus the current chunk count).
    pub fn storage_stats(&self) -> StorageStats {
        self.store.stats()
    }

    /// Clear the rope's dirty flags (see [`ChunkedRows::mark_clean`]),
    /// returning `(dirtied, total)` chunk counts. Snapshot builders call
    /// this immediately before cloning so the clone is a pure reference
    /// bump.
    pub fn mark_storage_clean(&mut self) -> (u64, u64) {
        self.store.mark_clean()
    }

    /// The flat row-major band layout this rope replaced — test-only
    /// equivalence surface (the COW lint bans production use).
    pub fn to_flat(&self) -> Vec<f64> {
        // lint: cow-ok (definition site: materialization is the point)
        self.store.to_flat()
    }

    /// Rebuild from a flat row-major band layout (checkpoint decode). The
    /// rope restarts with canonical chunk boundaries; chunk layout is
    /// storage bookkeeping and never affects numeric content (the soak
    /// property pinned in `linalg/chunks.rs`), so a decoded matrix is
    /// bit-identical to the live one row by row.
    pub fn from_flat(n: usize, kl: usize, ku: usize, flat: &[f64]) -> Result<Self, String> {
        let w = kl + ku + 1;
        if flat.len() != n * w {
            return Err(format!(
                "band payload is {} values, want n {n} × width {w}",
                flat.len()
            ));
        }
        let mut m = Banded::zeros(n, kl, ku);
        for i in 0..n {
            m.store.row_mut(i).copy_from_slice(&flat[i * w..(i + 1) * w]);
        }
        Ok(m)
    }

    /// A new matrix reusing factor rows `[0, keep)` of `src` (whole chunks
    /// `Arc`-shared — `src` must be storage-clean, see
    /// [`ChunkedRows::from_prefix`]) padded with zero rows to `n_new`.
    fn from_prefix(src: &Banded, keep: usize, n_new: usize) -> Banded {
        Banded { n: n_new, kl: src.kl, ku: src.ku, store: src.store.from_prefix(keep, n_new) }
    }

    /// Maximum absolute entry strictly outside the `(kl', ku')` band — used
    /// by tests asserting that a product really is banded.
    pub fn max_abs_outside(&self, kl2: usize, ku2: usize) -> f64 {
        let mut m: f64 = 0.0;
        for i in 0..self.n {
            let (lo, hi) = self.row_range(i);
            for j in lo..hi {
                let inside = j + kl2 >= i && j <= i + ku2;
                if !inside {
                    m = m.max(self.get(i, j).abs());
                }
            }
        }
        m
    }
}

/// How [`BandedLU::refactor_from`] is allowed to update an existing
/// factorization after a band splice (DESIGN.md §FitState, "Sublinear LU
/// patching").
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PatchPolicy {
    /// Always re-run the full `O((kl+ku)²n)` sweep — bit-identical to a
    /// from-scratch [`Banded::lu`], kept as a kill switch for the
    /// prefix-reuse machinery and as the bench baseline. (Note: the *sweep
    /// itself* uses threshold pivoting — see `PIVOT_THRESHOLD` — under every
    /// policy; this switch disables only the patching.)
    Resweep,
    /// Reuse the untouched elimination prefix verbatim and re-eliminate only
    /// from the lowest touched row to the end. Bit-identical to a
    /// from-scratch [`Banded::lu`] in every case.
    Exact,
    /// [`PatchPolicy::Exact`]'s prefix reuse, plus a tolerance-gated tail
    /// early-exit for mid-matrix splices: once `kl+1` consecutive
    /// re-eliminated factor rows match the old factors to `rel_tol`
    /// (relative, per row), the remaining old factor tail is spliced in
    /// verbatim. Approximate at the `rel_tol` level; appends are unaffected
    /// (their tail is empty, so they stay bit-exact).
    EarlyExit {
        /// Per-row relative tolerance for the tail match.
        rel_tol: f64,
    },
}

/// What a band splice did to the factored matrix, as seen by
/// [`BandedLU::refactor_from`]. The caller (e.g. `gp::DimFactor`) derives
/// this from the insertion positions and its rewrite windows.
#[derive(Clone, Copy, Debug)]
pub struct SpliceInfo {
    /// Rows `< low` of the new matrix are bit-identical — same values *and*
    /// same column indices — to rows `< low` of the previously factored
    /// matrix. (Band storage guarantees this for rows whose window lies
    /// strictly below every spliced index; see [`Banded::insert_rows_cols`].)
    pub low: usize,
    /// `Some((tail_from, shift))` when rows `≥ tail_from` of the new matrix
    /// are bit-identical to old rows shifted down by `shift` (the splice
    /// moved them verbatim). Enables the early-exit under
    /// [`PatchPolicy::EarlyExit`]; `None` (or an empty tail) for appends.
    pub tail: Option<(usize, usize)>,
}

/// Which path one [`BandedLU::refactor_from`] call took — surfaced through
/// `DimFactor` counters up to the coordinator metrics.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PatchOutcome {
    /// The elimination prefix `[0, resumed_at)` was reused verbatim; steps
    /// `[resumed_at, stopped_at)` were re-run (`stopped_at < n` iff the
    /// early-exit spliced in the old tail).
    Patched { resumed_at: usize, stopped_at: usize },
    /// Full from-scratch sweep: the policy demanded it, the splice touched
    /// row 0's neighborhood, a pivot swap straddled the resume boundary, or
    /// the band layout changed.
    Resweep,
}

/// Early-exit context for [`eliminate`]: the old factorization plus the
/// uniform-shift tail description.
struct TailExit<'a> {
    old_fac: &'a Banded,
    old_piv: &'a [usize],
    /// First row of the new matrix in the uniform-shift region.
    tail_from: usize,
    /// Old row `r - shift` corresponds to new row `r` there.
    shift: usize,
    rel_tol: f64,
}

impl TailExit<'_> {
    /// Does freshly-eliminated factor row `k` (with pivot `piv_k`) match the
    /// old factor row `k - shift` to `rel_tol`, pivot structure included?
    fn row_matches(&self, f: &Banded, piv_k: usize, k: usize) -> bool {
        let old_k = k - self.shift;
        if self.old_piv[old_k] + self.shift != piv_k {
            return false;
        }
        let new_row = f.row(k);
        let old_row = self.old_fac.row(old_k);
        let mut scale = 0.0f64;
        for &v in old_row {
            scale = scale.max(v.abs());
        }
        let tol = self.rel_tol * scale.max(1e-300);
        new_row.iter().zip(old_row).all(|(a, b)| (a - b).abs() <= tol)
    }
}

/// Threshold for the pivot swap: rows are exchanged only when the best
/// sub-diagonal candidate exceeds `PIVOT_THRESHOLD × |diag|` (SuperLU-style
/// threshold pivoting, element growth bounded by `1 + PIVOT_THRESHOLD` per
/// step). Plain partial pivoting swaps on ~half the steps of the KP factor
/// matrices (the packet rows' largest coefficients sit off-diagonal), which
/// would leave `refactor_from` no clean resume boundary to reuse; the
/// threshold keeps swaps to the genuinely ill-conditioned steps — measured
/// solve accuracy on the KP factors matches plain partial pivoting to
/// within 2× across 2ν ∈ {1, 3, 5}, including clustered-point stress sets.
const PIVOT_THRESHOLD: f64 = 8.0;

/// Run elimination steps `[from, n)` of the banded threshold-pivoting LU on
/// the widened working matrix `f` (bandwidths `(kl, kuf)`), recording pivots
/// in `piv`. The single driver behind [`BandedLU::factor`] and
/// [`BandedLU::refactor_from`] — both paths execute bit-identical arithmetic
/// by construction.
///
/// With `tail = Some(..)`, finalized rows inside the uniform-shift region
/// are compared against the old factors; after `kl+1` consecutive matches
/// the old factor tail (rows and pivots, shifted) is spliced in verbatim and
/// the sweep stops. Returns the first step index *not* freshly eliminated
/// (`n` when the sweep ran to the end).
fn eliminate(f: &mut Banded, piv: &mut [usize], from: usize, tail: Option<TailExit<'_>>) -> usize {
    let n = f.n;
    let kl = f.kl;
    let kuf = f.ku;
    let mut matched = 0usize;
    for k in from..n {
        // Pivot search in column k, rows k..=k+kl.
        let last = (k + kl).min(n - 1);
        let mut p = k;
        let mut best = f.get(k, k).abs();
        for r in (k + 1)..=last {
            let v = f.get(r, k).abs();
            if v > best {
                best = v;
                p = r;
            }
        }
        if p != k && best <= PIVOT_THRESHOLD * f.get(k, k).abs() {
            p = k; // diagonal within threshold: keep the structure-friendly pivot
        }
        piv[k] = p;
        if p != k {
            // Swap rows k and p within their shared band columns.
            let hi = (k + kuf + 1).min(n);
            for j in k..hi {
                let a = f.get(k, j);
                let b = if f.in_band(p, j) { f.get(p, j) } else { 0.0 };
                f.set(k, j, b);
                if f.in_band(p, j) {
                    f.set(p, j, a);
                } else {
                    assert!(a == 0.0, "pivot swap lost fill at ({p},{j})");
                }
            }
        }
        let pivot = f.get(k, k);
        if pivot != 0.0 {
            // pivot == 0.0: singular; solve will produce inf/nan, logdet -inf
            for r in (k + 1)..=last {
                let m = f.get(r, k) / pivot;
                f.set(r, k, m); // store multiplier
                if m != 0.0 {
                    let hi = (k + kuf + 1).min(n);
                    for j in (k + 1)..hi {
                        let v = f.get(r, j) - m * f.get(k, j);
                        f.set(r, j, v);
                    }
                }
            }
        }
        if let Some(t) = &tail {
            if k >= t.tail_from {
                if t.row_matches(f, piv[k], k) {
                    matched += 1;
                } else {
                    matched = 0;
                }
                if matched > kl {
                    // Splice in the old factor tail verbatim (rows k+1.. are
                    // still mid-elimination and are fully overwritten).
                    for r in (k + 1)..n {
                        let old_r = r - t.shift;
                        f.row_mut(r).copy_from_slice(t.old_fac.row(old_r));
                        piv[r] = t.old_piv[old_r] + t.shift;
                    }
                    return k + 1;
                }
            }
        }
    }
    n
}

/// Determinant-sign parity of a pivot vector: `(-1)^{#swaps}`.
fn pivot_sign(piv: &[usize]) -> f64 {
    let swaps = piv.iter().enumerate().filter(|&(k, &p)| p != k).count();
    if swaps % 2 == 0 {
        1.0
    } else {
        -1.0
    }
}

/// LU factorization (threshold partial pivoting) of a [`Banded`] matrix.
///
/// LAPACK `gbtrf`-style scheme with SuperLU-style threshold pivoting (see
/// `PIVOT_THRESHOLD`): with row swaps the `U` factor's upper bandwidth grows
/// to `kl + ku`; `L`'s multipliers stay within `kl`. After a band splice the
/// factorization can be *patched in place* by [`BandedLU::refactor_from`]
/// instead of re-swept from scratch. `Clone` supports the coordinator's
/// read snapshots ([`crate::gp::fit_state::PosteriorSnapshot`]).
#[derive(Clone)]
pub struct BandedLU {
    n: usize,
    kl: usize,
    /// Upper bandwidth of U after fill-in (`kl + ku`).
    kuf: usize,
    /// `U` (including diagonal) in band storage with bandwidths `(0, kuf)`
    /// plus the `L` multipliers in the sub-diagonal part `(kl, 0)`.
    fac: Banded,
    /// `piv[k]` = row swapped with row `k` at step `k`. `Arc`-shared so a
    /// snapshot clone bumps a reference instead of copying `O(n)` indices;
    /// both factoring paths build a fresh vector and re-wrap it.
    piv: Arc<Vec<usize>>,
    sign: f64,
}

impl BandedLU {
    fn factor(a: &Banded) -> Self {
        if let Some(act) = crate::util::fault::point!("lu.factor") {
            if act == crate::util::fault::FaultAction::Panic {
                panic!("injected fault: lu.factor");
            }
        }
        let n = a.n;
        let kl = a.kl;
        let kuf = (a.kl + a.ku).min(n.saturating_sub(1));
        // Working copy with widened upper band for fill-in.
        let mut f = Banded::zeros(n, kl, kuf);
        for i in 0..n {
            let (lo, hi) = a.row_range(i);
            for j in lo..hi {
                f.set(i, j, a.get(i, j));
            }
        }
        let mut piv = vec![0usize; n];
        eliminate(&mut f, &mut piv, 0, None);
        let sign = pivot_sign(&piv);
        let lu = BandedLU { n, kl, kuf, fac: f, piv: Arc::new(piv), sign };
        enforce(&lu, "BandedLU::factor");
        lu
    }

    /// Matrix size (rows/cols of the factored matrix).
    pub fn n(&self) -> usize {
        self.n
    }

    /// Lower bandwidth of the factored matrix (the `L` multipliers' reach).
    pub fn kl(&self) -> usize {
        self.kl
    }

    /// Upper bandwidth of `U` after pivoting fill-in (`kl + ku`, clipped).
    pub fn kuf(&self) -> usize {
        self.kuf
    }

    /// The packed factor band (read-only) — exposed for storage diagnostics
    /// and the bench's deep-materialization baseline.
    pub fn fac_band(&self) -> &Banded {
        &self.fac
    }

    /// The pivot vector (`piv[k]` = row swapped with `k` at step `k`) —
    /// checkpoint serialization surface.
    pub fn piv(&self) -> &[usize] {
        &self.piv
    }

    /// Determinant-sign parity of the pivoting (`±1`).
    pub fn sign(&self) -> f64 {
        self.sign
    }

    /// Reassemble a factorization from checkpoint-decoded parts. The parts
    /// must come from `fac_band()`/`piv()`/`sign()` of a live factorization
    /// (journal recovery); structural consistency is re-checked, numeric
    /// content is trusted — re-eliminating here would break the recovery
    /// bit-identity argument for matrices whose incremental factor differs
    /// in rounding from a cold sweep.
    pub fn from_parts(
        n: usize,
        kl: usize,
        kuf: usize,
        fac: Banded,
        piv: Vec<usize>,
        sign: f64,
    ) -> Result<Self, String> {
        if fac.n() != n || fac.kl() != kl || fac.ku() != kuf || piv.len() != n {
            return Err(format!(
                "LU parts disagree: n {n}, fac ({}, kl {}, ku {}), piv len {}",
                fac.n(),
                fac.kl(),
                fac.ku(),
                piv.len()
            ));
        }
        if piv.iter().enumerate().any(|(k, &p)| p < k || p >= n) {
            return Err("LU pivot vector out of range".to_string());
        }
        if sign != 1.0 && sign != -1.0 {
            return Err(format!("LU sign {sign} is not ±1"));
        }
        let lu = BandedLU { n, kl, kuf, fac, piv: Arc::new(piv), sign };
        enforce(&lu, "BandedLU::from_parts");
        Ok(lu)
    }

    /// Storage counters of the packed factor's rope.
    pub fn storage_stats(&self) -> StorageStats {
        self.fac.storage_stats()
    }

    /// Clear the packed factor's dirty flags (snapshot-build protocol; see
    /// [`Banded::mark_storage_clean`]).
    pub fn mark_storage_clean(&mut self) -> (u64, u64) {
        self.fac.mark_storage_clean()
    }

    /// Patch this factorization of the *pre-splice* matrix into the
    /// factorization of `a` (the post-splice matrix, same bandwidths,
    /// `a.n() ≥ self.n`), reusing the untouched elimination prefix.
    ///
    /// Why a prefix is reusable at all: elimination step `k` reads and
    /// writes only rows `[k, k+kl]`, so every step with `k + kl <
    /// splice.low` runs on bit-identical inputs and produces bit-identical
    /// factor rows, pivots and multipliers. The sweep therefore resumes at
    /// `s = low − kl`; the working state of the straddling rows `[s, low)`
    /// is reconstructed exactly from the old multipliers (stored in the
    /// sub-diagonal part of `fac`, never moved by later pivot swaps, which
    /// only touch columns `≥ k`) and the reused `U` prefix. Two conditions
    /// guard the reconstruction at a candidate boundary: no pivot swap from
    /// steps `[s−kl, s)` may have crossed it (swapped content would
    /// invalidate the raw-row reconstruction), and no zero pivot may sit in
    /// that window (its targets store working values, not multipliers).
    /// A dirty boundary is handled by walking `s` down to the nearest clean
    /// one — these matrices pivot on roughly half their steps, so the walk
    /// (geometrically distributed, ~2 rows expected) is what keeps the
    /// patch rate near 100% instead of ~50%; a full re-sweep runs only when
    /// the walk reaches row 0 or the band layout changed.
    ///
    /// Under [`PatchPolicy::Exact`] the result is **bit-identical** to
    /// `a.lu()` — the resumed sweep is the from-scratch sweep, executed by
    /// the same elimination driver on bit-identical working state. Cost is
    /// `O((n − low)·(kl+ku)²)` plus an `O(n·(kl+ku))` band copy, so an
    /// append (`low ≈ n`) costs `O((kl+ku)³)` arithmetic — the sublinear
    /// factor patch of DESIGN.md §FitState. [`PatchPolicy::EarlyExit`]
    /// additionally stops a mid-matrix sweep once the recomputed rows match
    /// the old factors (tolerance-gated; appends are unaffected).
    pub fn refactor_from(
        &mut self,
        a: &Banded,
        splice: &SpliceInfo,
        policy: PatchPolicy,
    ) -> PatchOutcome {
        let n_new = a.n();
        let kl = a.kl();
        let kuf = kl + a.ku();
        let layout_ok = self.kl == kl && self.kuf == kuf && kuf <= n_new.saturating_sub(1);
        let n_old = self.n;
        let low = splice.low.min(n_old);
        // Resume at the highest *clean* boundary at or below low − kl: a
        // pivot swap crossing a candidate boundary (content exchanged across
        // it during steps [s−kl, s)) invalidates the straddling-row
        // reconstruction there, but any lower boundary is just as valid —
        // walking down costs a few extra re-eliminated rows (the matrices
        // here pivot on ~half their steps, so bailing to a full re-sweep
        // instead would forfeit most of the patch wins).
        let mut s = low.saturating_sub(kl);
        if matches!(policy, PatchPolicy::Resweep) || !layout_ok {
            s = 0;
        }
        while s > 0 && !self.resume_state_clean(s) {
            s -= 1;
        }
        if s == 0 {
            *self = BandedLU::factor(a);
            return PatchOutcome::Resweep;
        }
        // Reused prefix: factor rows [0, s) verbatim, whole chunks shared
        // by reference (under the flat layout the prefix copy WAS almost
        // the whole cost of an append patch). Sharing requires the source
        // chunks clean — settle them first (chunk dirt is bookkeeping, not
        // numerics, so this cannot perturb the factorization).
        let _ = self.fac.mark_storage_clean();
        let mut f = Banded::from_prefix(&self.fac, s, n_new);
        // Raw rows of the new matrix from s on.
        for r in s..n_new {
            let (lo, hi) = a.row_range(r);
            for j in lo..hi {
                f.set(r, j, a.get(r, j));
            }
        }
        // Reconstruct the straddling rows' working state: replay the updates
        // steps [s−kl, s) applied to rows [s, s+kl), using the stored old
        // multipliers and the reused U prefix — ascending k, exactly the
        // order the from-scratch sweep applies them, so bit-identical.
        let r_hi = (s + kl).min(n_new);
        for r in s..r_hi {
            for k in r.saturating_sub(kl)..s {
                let m = self.fac.get(r, k);
                f.set(r, k, m);
                if m != 0.0 {
                    let hi = (k + kuf + 1).min(n_new);
                    for j in (k + 1)..hi {
                        let v = f.get(r, j) - m * f.get(k, j);
                        f.set(r, j, v);
                    }
                }
            }
        }
        let mut piv = vec![0usize; n_new];
        piv[..s].copy_from_slice(&self.piv[..s]);
        let tail = match (policy, splice.tail) {
            (PatchPolicy::EarlyExit { rel_tol }, Some((tail_from, shift)))
                if shift > 0 && tail_from < n_new =>
            {
                Some(TailExit {
                    old_fac: &self.fac,
                    old_piv: &self.piv[..],
                    tail_from: tail_from.max(s),
                    shift,
                    rel_tol,
                })
            }
            _ => None,
        };
        let stopped = eliminate(&mut f, &mut piv, s, tail);
        self.n = n_new;
        self.fac = f;
        self.sign = pivot_sign(&piv);
        self.piv = Arc::new(piv);
        enforce(self, "BandedLU::refactor_from");
        PatchOutcome::Patched { resumed_at: s, stopped_at: stopped }
    }

    /// Can the elimination resume at step `s`? Requires that no pivot swap
    /// from steps `[s−kl, s)` reached a slot `≥ s` (earlier steps cannot:
    /// `piv[k] ≤ k + kl`) and that none of those steps hit a zero pivot.
    fn resume_state_clean(&self, s: usize) -> bool {
        (s.saturating_sub(self.kl)..s).all(|k| self.piv[k] < s && self.fac.get(k, k) != 0.0)
    }

    /// Solve `A x = b`.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let mut x = b.to_vec();
        self.solve_in_place(&mut x);
        x
    }

    /// Solve `A x = b` in place. The inner loops walk the band rows through
    /// a chunk cursor (amortized O(1) per row, no per-element bounds logic)
    /// — this is the `O(n)` primitive under every algorithm in the crate,
    /// see DESIGN.md §Perf.
    pub fn solve_in_place(&self, x: &mut [f64]) {
        assert_eq!(x.len(), self.n);
        let n = self.n;
        let kl = self.kl;
        let mut cur = self.fac.row_cursor();
        // Forward: apply P and L^{-1}. fac[r, k] sits at in-row offset
        // k + kl - r of row r.
        for k in 0..n {
            let p = self.piv[k];
            if p != k {
                x.swap(k, p);
            }
            let last = (k + kl).min(n - 1);
            let xk = x[k];
            if xk != 0.0 {
                for r in (k + 1)..=last {
                    x[r] -= self.fac.row_at(&mut cur, r)[k + kl - r] * xk;
                }
            }
        }
        // Backward: U x = y. Row k of U is contiguous from in-row offset kl:
        // fac[k, j] sits at kl + (j - k) for j = k..k+kuf.
        for k in (0..n).rev() {
            let hi = (k + self.kuf + 1).min(n);
            let rk = self.fac.row_at(&mut cur, k);
            let row = &rk[kl..kl + (hi - k)];
            let mut acc = x[k];
            for (off, &f) in row.iter().enumerate().skip(1) {
                acc -= f * x[k + off];
            }
            x[k] = acc / row[0];
        }
    }

    /// `log |det A|` and the determinant sign.
    pub fn logdet(&self) -> (f64, f64) {
        let mut ld = 0.0;
        let mut sign = self.sign;
        for k in 0..self.n {
            let d = self.fac.get(k, k);
            ld += d.abs().ln();
            if d < 0.0 {
                sign = -sign;
            }
        }
        (ld, sign)
    }
}

impl Audit for Banded {
    /// The backing rope must hold exactly `n` rows of width `kl+ku+1` and
    /// satisfy the chunk-table invariants (chunk sizes, starts table,
    /// `Arc` sharing only on clean chunks — see [`ChunkedRows`]'s audit),
    /// and every stored entry must be finite — the raw matrices this type
    /// holds (A, Φ, T, Φᵀ, Gram blocks) are always finite by construction;
    /// NaN/inf here means a splice or rebuild wrote garbage. Failures name
    /// the row.
    fn audit(&self) -> Result<(), AuditError> {
        if self.store.n_rows() != self.n || self.store.width() != self.kl + self.ku + 1 {
            return Err(AuditError::new(
                "Banded",
                "data",
                None,
                format!(
                    "storage shape {} rows × {} != n × (kl+ku+1) = {} × {}",
                    self.store.n_rows(),
                    self.store.width(),
                    self.n,
                    self.kl + self.ku + 1,
                ),
            ));
        }
        self.store.audit()?;
        for i in 0..self.n {
            let (lo, hi) = self.row_range(i);
            for j in lo..hi {
                let v = self.get(i, j);
                if !v.is_finite() {
                    return Err(AuditError::new(
                        "Banded",
                        "data",
                        Some(i),
                        format!("non-finite entry {v} at ({i}, {j})"),
                    ));
                }
            }
        }
        Ok(())
    }
}

impl Audit for BandedLU {
    /// Checks the factorization's structural story: the packed factor has the
    /// `n / kl / kuf` shape the header claims, every pivot row lies inside
    /// the partial-pivoting window `[k, min(k+kl, n-1)]`, the determinant
    /// sign matches the recorded swaps, and the stored `L` multipliers obey
    /// the threshold-pivoting bound `|m| ≤ PIVOT_THRESHOLD` (columns whose
    /// pivot is zero or non-finite are skipped: elimination legitimately
    /// leaves raw working values there on singular input, so `fac` is NOT
    /// required to be finite — that is why this impl does not delegate to
    /// `Banded::audit` on `fac`).
    fn audit(&self) -> Result<(), AuditError> {
        let n = self.n;
        if self.piv.len() != n {
            return Err(AuditError::new(
                "BandedLU",
                "piv",
                None,
                format!("pivot vector length {} != n = {}", self.piv.len(), n),
            ));
        }
        if self.fac.n != n || self.fac.kl != self.kl || self.fac.ku != self.kuf {
            return Err(AuditError::new(
                "BandedLU",
                "fac",
                None,
                format!(
                    "factor shape ({}, kl={}, ku={}) disagrees with header (n={}, kl={}, kuf={})",
                    self.fac.n, self.fac.kl, self.fac.ku, n, self.kl, self.kuf
                ),
            ));
        }
        if self.fac.store.n_rows() != self.fac.n
            || self.fac.store.width() != self.fac.kl + self.fac.ku + 1
        {
            return Err(AuditError::new(
                "BandedLU",
                "fac",
                None,
                format!(
                    "factor storage shape {} rows × {} != {} × {}",
                    self.fac.store.n_rows(),
                    self.fac.store.width(),
                    self.fac.n,
                    self.fac.kl + self.fac.ku + 1,
                ),
            ));
        }
        // Chunk-table invariants of the factor's rope (finiteness is
        // deliberately NOT required here — see the impl docs).
        self.fac.store.audit()?;
        for k in 0..n {
            let hi = (k + self.kl).min(n - 1);
            if self.piv[k] < k || self.piv[k] > hi {
                return Err(AuditError::new(
                    "BandedLU",
                    "piv",
                    Some(k),
                    format!("pivot row {} outside window [{k}, {hi}]", self.piv[k]),
                ));
            }
        }
        // Threshold partial pivoting swaps whenever the best sub-diagonal
        // candidate exceeds PIVOT_THRESHOLD·|diag|, so surviving multipliers
        // are bounded by the threshold (ε slack for the division rounding).
        let bound = PIVOT_THRESHOLD * (1.0 + 1e-9);
        for k in 0..n {
            let pivot = self.fac.get(k, k);
            if !pivot.is_finite() || pivot == 0.0 {
                continue;
            }
            let last = (k + self.kl).min(n - 1);
            for r in (k + 1)..=last {
                let m = self.fac.get(r, k);
                if m.is_finite() && m.abs() > bound {
                    return Err(AuditError::new(
                        "BandedLU",
                        "multiplier",
                        Some(k),
                        format!("|L[{r}, {k}]| = {} exceeds pivot bound {bound}", m.abs()),
                    ));
                }
            }
        }
        if self.sign != pivot_sign(&self.piv) {
            return Err(AuditError::new(
                "BandedLU",
                "sign",
                None,
                format!(
                    "determinant sign {} disagrees with pivot swap parity {}",
                    self.sign,
                    pivot_sign(&self.piv)
                ),
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tridiag(n: usize, lo: f64, di: f64, up: f64) -> Banded {
        let mut m = Banded::zeros(n, 1, 1);
        for i in 0..n {
            if i > 0 {
                m.set(i, i - 1, lo);
            }
            m.set(i, i, di);
            if i + 1 < n {
                m.set(i, i + 1, up);
            }
        }
        m
    }

    #[test]
    fn matvec_matches_dense() {
        let m = tridiag(6, -1.0, 2.5, -0.5);
        let x: Vec<f64> = (0..6).map(|i| (i as f64).sin() + 1.0).collect();
        let y = m.matvec(&x);
        let yd = m.to_dense().matvec(&x);
        for i in 0..6 {
            assert!((y[i] - yd[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn matvec_t_matches_transpose() {
        let m = tridiag(7, 0.3, 1.7, -2.0);
        let x: Vec<f64> = (0..7).map(|i| (i as f64 * 0.7).cos()).collect();
        let a = m.matvec_t(&x);
        let b = m.transpose().matvec(&x);
        for i in 0..7 {
            assert!((a[i] - b[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn lu_solve_roundtrip() {
        let m = tridiag(40, -1.0, 2.0, -1.0); // SPD (discrete Laplacian)
        let x_true: Vec<f64> = (0..40).map(|i| ((i * 7 % 11) as f64) - 5.0).collect();
        let b = m.matvec(&x_true);
        let x = m.solve(&b);
        for i in 0..40 {
            assert!((x[i] - x_true[i]).abs() < 1e-9, "i={i}: {} vs {}", x[i], x_true[i]);
        }
    }

    #[test]
    fn lu_solve_needs_pivoting() {
        // Small diagonal entry forces a pivot swap.
        let mut m = Banded::zeros(4, 1, 1);
        m.set(0, 0, 1e-14);
        m.set(0, 1, 1.0);
        m.set(1, 0, 1.0);
        m.set(1, 1, 1.0);
        m.set(1, 2, 2.0);
        m.set(2, 1, -1.0);
        m.set(2, 2, 3.0);
        m.set(2, 3, 0.5);
        m.set(3, 2, 1.0);
        m.set(3, 3, -2.0);
        let x_true = vec![1.0, -2.0, 3.0, -4.0];
        let b = m.matvec(&x_true);
        let x = m.solve(&b);
        for i in 0..4 {
            assert!((x[i] - x_true[i]).abs() < 1e-8, "{:?}", x);
        }
    }

    /// Just inside the threshold window (off-diagonal up to 7.8×|diag|,
    /// `PIVOT_THRESHOLD = 8`) no swap happens, and the factorization must
    /// stay accurate anyway — the in-repo pin for the threshold-pivoting
    /// stability trade-off, exercising the near-threshold regime where
    /// element growth is largest.
    #[test]
    fn lu_threshold_pivoting_stays_accurate() {
        let n = 30;
        let mut m = Banded::zeros(n, 1, 1);
        for i in 0..n {
            // Diagonal 0.5, neighbors up to ±3.9: ratios reach 7.2–7.8.
            m.set(i, i, 0.5);
            if i > 0 {
                m.set(i, i - 1, 9.0 * ((i * 7 % 5) as f64 / 5.0 - 0.4));
            }
            if i + 1 < n {
                m.set(i, i + 1, -7.0 * ((i * 3 % 7) as f64 / 7.0 - 0.3));
            }
        }
        let x_true: Vec<f64> = (0..n).map(|i| ((i * 11 % 13) as f64) - 6.0).collect();
        let b = m.matvec(&x_true);
        let x = m.solve(&b);
        let scale = x_true.iter().fold(0.0f64, |a, &v| a.max(v.abs()));
        for i in 0..n {
            assert!(
                (x[i] - x_true[i]).abs() < 1e-9 * scale,
                "i={i}: {} vs {}",
                x[i],
                x_true[i]
            );
        }
        // And the log-det still matches the dense oracle.
        let (ld, sign) = m.lu().logdet();
        let (ldd, signd) = m.to_dense().lu_logdet();
        assert!((ld - ldd).abs() < 1e-9 * ldd.abs().max(1.0), "{ld} vs {ldd}");
        assert_eq!(sign, signd);
    }

    #[test]
    fn logdet_matches_dense() {
        let m = tridiag(12, -0.8, 2.2, -0.8);
        let (ld, sign) = m.lu().logdet();
        let (ldd, signd) = m.to_dense().lu_logdet();
        assert!((ld - ldd).abs() < 1e-9);
        assert_eq!(sign, signd);
    }

    /// Inserting a row/col and rewriting the straddling `O(kl+ku)` window
    /// (the caller's contract) reproduces a freshly-built matrix exactly.
    #[test]
    fn insert_row_col_then_window_rewrite_matches_fresh() {
        // Per-row values so any index shift is detectable.
        let row_entries = |i: usize, n: usize, vals: &[f64]| -> Vec<(usize, f64)> {
            let mut e = Vec::new();
            if i > 0 {
                e.push((i - 1, -vals[i]));
            }
            e.push((i, 2.0 + vals[i]));
            if i + 1 < n {
                e.push((i + 1, 0.5 * vals[i]));
            }
            e
        };
        let build = |vals: &[f64]| {
            let n = vals.len();
            let mut m = Banded::zeros(n, 1, 1);
            for i in 0..n {
                for (c, v) in row_entries(i, n, vals) {
                    m.set(i, c, v);
                }
            }
            m
        };
        for j in [0usize, 3, 6] {
            let vals6 = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
            let mut vals7 = vals6.to_vec();
            vals7.insert(j, 9.0);
            let fresh = build(&vals7);

            let mut inc = build(&vals6);
            inc.insert_row_col(j);
            assert_eq!(inc.n(), 7);
            // Rewrite the straddling window |i − j| ≤ max(kl, ku) = 1.
            for i in j.saturating_sub(1)..=(j + 1).min(6) {
                let (lo, hi) = inc.row_range(i);
                for c in lo..hi {
                    inc.set(i, c, 0.0);
                }
                for (c, v) in row_entries(i, 7, &vals7) {
                    inc.set(i, c, v);
                }
            }
            for i in 0..7 {
                for c in 0..7 {
                    assert_eq!(inc.get(i, c), fresh.get(i, c), "j={j} ({i},{c})");
                }
            }
        }
    }

    /// Removing a row/col and rewriting the straddling `O(kl+ku)` window
    /// (the caller's contract, mirror of the insert one) reproduces a
    /// freshly-built matrix exactly.
    #[test]
    fn remove_row_col_then_window_rewrite_matches_fresh() {
        let row_entries = |i: usize, n: usize, vals: &[f64]| -> Vec<(usize, f64)> {
            let mut e = Vec::new();
            if i > 0 {
                e.push((i - 1, -vals[i]));
            }
            e.push((i, 2.0 + vals[i]));
            if i + 1 < n {
                e.push((i + 1, 0.5 * vals[i]));
            }
            e
        };
        let build = |vals: &[f64]| {
            let n = vals.len();
            let mut m = Banded::zeros(n, 1, 1);
            for i in 0..n {
                for (c, v) in row_entries(i, n, vals) {
                    m.set(i, c, v);
                }
            }
            m
        };
        for j in [0usize, 3, 6] {
            let vals7 = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0];
            let mut vals6 = vals7.to_vec();
            vals6.remove(j);
            let fresh = build(&vals6);

            let mut inc = build(&vals7);
            inc.remove_row_col(j);
            assert_eq!(inc.n(), 6);
            // Rewrite the straddling window |i − j| ≤ max(kl, ku) = 1 in
            // post-removal indices.
            for i in j.saturating_sub(1)..=(j + 1).min(5) {
                let (lo, hi) = inc.row_range(i);
                for c in lo..hi {
                    inc.set(i, c, 0.0);
                }
                for (c, v) in row_entries(i, 6, &vals6) {
                    inc.set(i, c, v);
                }
            }
            for i in 0..6 {
                for c in 0..6 {
                    assert_eq!(inc.get(i, c), fresh.get(i, c), "j={j} ({i},{c})");
                }
            }
        }
    }

    /// Batched removal == repeated single removals (positions walked in
    /// descending order so earlier removals don't shift later indices).
    #[test]
    fn remove_rows_cols_matches_repeated_single_removes() {
        let base = tridiag(9, -1.5, 2.0, 0.75);
        for positions in [vec![0usize, 1], vec![2, 5], vec![0, 3, 8], vec![7, 8]] {
            let mut batched = base.clone();
            batched.remove_rows_cols(&positions);
            let mut seq = base.clone();
            for &p in positions.iter().rev() {
                seq.remove_row_col(p);
            }
            assert_eq!(batched.n(), seq.n(), "{positions:?}");
            for i in 0..batched.n() {
                let (lo, hi) = batched.row_range(i);
                for c in lo..hi {
                    assert_eq!(batched.get(i, c), seq.get(i, c), "{positions:?} ({i},{c})");
                }
            }
        }
    }

    /// Batched splice == repeated single splices, for front / interior /
    /// back / adjacent positions.
    #[test]
    fn insert_rows_cols_matches_repeated_single_inserts() {
        let base = tridiag(6, -1.5, 2.0, 0.75);
        for positions in [
            vec![0usize, 1],
            vec![2, 5],
            vec![0, 3, 8],
            vec![6, 7],
            vec![1, 2, 3],
        ] {
            let mut batched = base.clone();
            batched.insert_rows_cols(&positions);

            // Flat-layout oracle: repeated single splices at the same *final*
            // indices on a plain Vec in the row-major band layout (splicing
            // in ascending order keeps each final index exact). Comparing
            // via `to_flat` also pins chunked == flat byte layout.
            let w = base.kl() + base.ku() + 1;
            let mut flat = base.to_flat();
            let mut n_single = base.n();
            for &q in &positions {
                let at = q * w;
                let old_len = flat.len();
                flat.resize(old_len + w, 0.0);
                flat.copy_within(at..old_len, at + w);
                for v in &mut flat[at..at + w] {
                    *v = 0.0;
                }
                n_single += 1;
            }

            assert_eq!(batched.n(), 6 + positions.len(), "{positions:?}");
            assert_eq!(batched.n(), n_single, "{positions:?}");
            assert_eq!(batched.to_flat(), flat, "{positions:?}");
        }
    }

    /// Deterministic band matrix whose entry `(i, j)` depends only on
    /// `vals[i]` and the offset `j - i` — so inserting into `vals` and
    /// rebuilding from scratch is bit-identical to a band splice plus a
    /// window rewrite, which is exactly the contract `refactor_from` sees
    /// from `DimFactor`.
    fn band_from_vals(vals: &[f64], b: usize) -> Banded {
        let n = vals.len();
        let mut m = Banded::zeros(n, b, b);
        for i in 0..n {
            let (lo, hi) = m.row_range(i);
            for j in lo..hi {
                let v = if j == i {
                    3.0 + vals[i]
                } else {
                    let o = j as f64 - i as f64;
                    vals[i] * (0.31 * o).sin() / o
                };
                m.set(i, j, v);
            }
        }
        m
    }

    fn assert_lu_bitwise_equal(a: &BandedLU, b: &BandedLU, label: &str) {
        assert_eq!(a.n, b.n, "{label}: n");
        assert_eq!(a.piv[..], b.piv[..], "{label}: piv");
        assert_eq!(a.sign, b.sign, "{label}: sign");
        for r in 0..a.n {
            for (o, (x, y)) in a.fac.row(r).iter().zip(b.fac.row(r)).enumerate() {
                assert!(
                    x == y || (x.is_nan() && y.is_nan()),
                    "{label}: fac row {r} off {o}: {x} vs {y}"
                );
            }
        }
    }

    /// Prefix-reuse patching after an *append* batch is bit-identical to a
    /// from-scratch factorization, across bandwidths.
    #[test]
    fn refactor_append_matches_scratch_bitwise() {
        for b in [1usize, 2, 3] {
            let vals: Vec<f64> = (0..30).map(|i| ((i * 13 % 17) as f64) * 0.21 - 1.3).collect();
            let old = band_from_vals(&vals, b);
            let n = vals.len();
            for m in [1usize, 3] {
                let mut lu = old.lu();
                let mut vnew = vals.clone();
                for t in 0..m {
                    vnew.push(0.4 + 0.17 * t as f64);
                }
                let fresh_mat = band_from_vals(&vnew, b);
                let splice = SpliceInfo { low: n.saturating_sub(b), tail: None };
                let out = lu.refactor_from(&fresh_mat, &splice, PatchPolicy::Exact);
                match out {
                    PatchOutcome::Patched { resumed_at, stopped_at } => {
                        // The resume point may walk below low − kl when a
                        // pivot swap straddles a candidate boundary.
                        assert!(
                            resumed_at > 0 && resumed_at <= n - b - b,
                            "b={b} m={m}: resumed at {resumed_at}"
                        );
                        assert_eq!(stopped_at, n + m, "b={b} m={m}");
                    }
                    PatchOutcome::Resweep => panic!("b={b} m={m}: append must patch"),
                }
                assert_lu_bitwise_equal(&lu, &fresh_mat.lu(), &format!("b={b} m={m}"));
            }
        }
    }

    /// Mid-matrix splices under `Exact` stay bit-identical to scratch for
    /// every insertion position (front positions legitimately fall back to a
    /// resweep — which is also bit-identical by construction).
    #[test]
    fn refactor_mid_matrix_exact_bitwise_all_positions() {
        for b in [1usize, 2, 3] {
            let vals: Vec<f64> = (0..24).map(|i| ((i * 7 % 11) as f64) * 0.33 - 1.1).collect();
            let old = band_from_vals(&vals, b);
            for p in 0..=vals.len() {
                let mut lu = old.lu();
                let mut vnew = vals.clone();
                vnew.insert(p, 0.77);
                let fresh_mat = band_from_vals(&vnew, b);
                let splice = SpliceInfo { low: p.saturating_sub(b), tail: Some((p + b + 1, 1)) };
                let _ = lu.refactor_from(&fresh_mat, &splice, PatchPolicy::Exact);
                assert_lu_bitwise_equal(&lu, &fresh_mat.lu(), &format!("b={b} p={p}"));
            }
        }
    }

    /// Pivoting-heavy matrices (tiny diagonals forcing swaps near the resume
    /// boundary) either patch exactly or fall back to a resweep — both
    /// bit-identical to scratch under `Exact`.
    #[test]
    fn refactor_exact_with_pivot_swaps_matches_scratch() {
        for b in [1usize, 2] {
            for p in [2usize, 8, 11, 15, 18] {
                // Small diagonal entries every 5th row force pivot swaps.
                let vals: Vec<f64> = (0..20)
                    .map(|i| if i % 5 == 3 { -2.999_999 } else { 0.4 * ((i % 7) as f64) })
                    .collect();
                let old = band_from_vals(&vals, b);
                let mut lu = old.lu();
                let mut vnew = vals.clone();
                vnew.insert(p, -2.999_999);
                let fresh_mat = band_from_vals(&vnew, b);
                let splice = SpliceInfo { low: p.saturating_sub(b), tail: Some((p + b + 1, 1)) };
                let _ = lu.refactor_from(&fresh_mat, &splice, PatchPolicy::Exact);
                assert_lu_bitwise_equal(&lu, &fresh_mat.lu(), &format!("b={b} p={p}"));
            }
        }
    }

    /// The `Resweep` policy reproduces today's full sweep bit-for-bit and
    /// reports itself as such.
    #[test]
    fn refactor_resweep_policy_matches_scratch() {
        let vals: Vec<f64> = (0..18).map(|i| (i as f64 * 0.7).cos()).collect();
        let old = band_from_vals(&vals, 2);
        let mut lu = old.lu();
        let mut vnew = vals.clone();
        vnew.insert(9, 0.5);
        let fresh_mat = band_from_vals(&vnew, 2);
        let splice = SpliceInfo { low: 7, tail: Some((12, 1)) };
        let out = lu.refactor_from(&fresh_mat, &splice, PatchPolicy::Resweep);
        assert_eq!(out, PatchOutcome::Resweep);
        assert_lu_bitwise_equal(&lu, &fresh_mat.lu(), "resweep");
    }

    /// The tolerance-gated early-exit triggers on a mid-matrix insert into a
    /// large well-conditioned matrix, stays within 1e-12 of scratch on
    /// solves, and the `Exact` fallback flag reproduces scratch bit-for-bit
    /// on the identical input.
    #[test]
    fn refactor_early_exit_close_to_scratch_with_exact_fallback() {
        for b in [1usize, 2] {
            let n = 400;
            let vals: Vec<f64> = (0..n).map(|i| 0.3 * ((i * 31 % 23) as f64) / 23.0).collect();
            let old = band_from_vals(&vals, b);
            let p = 60;
            let mut vnew = vals.clone();
            vnew.insert(p, 0.21);
            let fresh_mat = band_from_vals(&vnew, b);
            let splice = SpliceInfo { low: p.saturating_sub(b), tail: Some((p + b + 1, 1)) };

            let mut early = old.lu();
            let out = early.refactor_from(
                &fresh_mat,
                &splice,
                PatchPolicy::EarlyExit { rel_tol: 1e-13 },
            );
            match out {
                PatchOutcome::Patched { stopped_at, .. } => assert!(
                    stopped_at < n / 2,
                    "b={b}: early exit expected well before the tail (stopped {stopped_at})"
                ),
                PatchOutcome::Resweep => panic!("b={b}: must patch"),
            }
            let scratch = fresh_mat.lu();
            // Factor entries: ≤ 1e-12 relative per row — the ISSUE criterion
            // in its directly-assertable form.
            for r in 0..early.n {
                let er = early.fac.row(r);
                let sr = scratch.fac.row(r);
                let scale = sr.iter().fold(0.0f64, |m, &v| m.max(v.abs())).max(1e-300);
                for (o, (x, y)) in er.iter().zip(sr).enumerate() {
                    assert!(
                        (x - y).abs() <= 1e-12 * scale,
                        "b={b} fac row {r} off {o}: {x} vs {y}"
                    );
                }
            }
            let x_true: Vec<f64> = (0..n + 1).map(|i| ((i * 5 % 13) as f64) - 6.0).collect();
            let rhs = fresh_mat.matvec(&x_true);
            let xe = early.solve(&rhs);
            let xs = scratch.solve(&rhs);
            let scale = xs.iter().fold(0.0f64, |m, &v| m.max(v.abs())).max(1.0);
            for i in 0..=n {
                assert!(
                    (xe[i] - xs[i]).abs() <= 1e-12 * scale,
                    "b={b} i={i}: early {} vs scratch {}",
                    xe[i],
                    xs[i]
                );
            }

            // Exact fallback flag: bit-for-bit on the same input.
            let mut exact = old.lu();
            let _ = exact.refactor_from(&fresh_mat, &splice, PatchPolicy::Exact);
            assert_lu_bitwise_equal(&exact, &scratch, &format!("b={b} exact fallback"));
        }
    }

    #[test]
    fn matmul_band_widths() {
        let a = tridiag(10, 1.0, 2.0, 3.0);
        let b = tridiag(10, -0.5, 1.0, 0.25);
        let c = a.matmul(&b);
        assert_eq!(c.kl(), 2);
        assert_eq!(c.ku(), 2);
        let cd = a.to_dense().matmul(&b.to_dense());
        for i in 0..10 {
            for j in 0..10 {
                assert!((c.get(i, j) - cd.get(i, j)).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn audit_passes_on_healthy_band_and_lu() {
        let m = tridiag(12, -1.0, 2.5, -1.0);
        assert!(m.audit().is_ok());
        assert!(m.lu().audit().is_ok());
    }

    /// A clobbered band entry is pinpointed by structure, field and row.
    #[test]
    fn audit_flags_clobbered_band_entry() {
        let mut m = tridiag(8, -1.0, 2.0, -1.0);
        m.set(3, 4, f64::NAN);
        let e = m.audit().unwrap_err();
        assert_eq!(e.structure, "Banded");
        assert_eq!(e.field, "data");
        assert_eq!(e.index, Some(3));
        assert!(e.to_string().contains("Banded.data[3]"), "{e}");
    }

    /// A pivot row outside the partial-pivoting window is pinpointed by
    /// elimination step.
    #[test]
    fn audit_flags_broken_pivot_permutation() {
        let m = tridiag(10, -1.0, 2.0, -1.0);
        let mut lu = m.lu();
        Arc::make_mut(&mut lu.piv)[4] = 9; // far outside [4, 4 + kl]
        let e = lu.audit().unwrap_err();
        assert_eq!(e.structure, "BandedLU");
        assert_eq!(e.field, "piv");
        assert_eq!(e.index, Some(4));
    }

    /// An out-of-bound `L` multiplier (impossible under threshold pivoting)
    /// is pinpointed by column.
    #[test]
    fn audit_flags_out_of_bound_multiplier() {
        let m = tridiag(10, -1.0, 2.0, -1.0);
        let mut lu = m.lu();
        lu.fac.set(5, 4, 100.0);
        let e = lu.audit().unwrap_err();
        assert_eq!(e.structure, "BandedLU");
        assert_eq!(e.field, "multiplier");
        assert_eq!(e.index, Some(4));
    }

    /// Singular input leaves zero pivots (and raw working values below them);
    /// the audit must tolerate that — only *structural* breakage is an error.
    #[test]
    fn audit_tolerates_singular_factorization() {
        let m = Banded::zeros(6, 1, 1); // all-zero matrix: every pivot is 0
        let lu = m.lu();
        assert!(lu.audit().is_ok());
    }
}
