//! General banded matrices in LAPACK-like band storage, with matrix–vector
//! products and an LU factorization with partial pivoting (the `O(b²n)`
//! "banded matrix solver"/"LU decomposition" primitive the paper leans on
//! throughout Table 1).

/// An `n × n` banded matrix with `kl` sub-diagonals and `ku` super-diagonals.
///
/// Entry `(i, j)` is stored iff `j - i ∈ [-kl, ku]`; reads outside the band
/// return `0.0`, writes outside the band panic. Storage is row-major band
/// layout: row `i` occupies `data[i*(kl+ku+1) ..]` with column `j` at offset
/// `j - i + kl`.
#[derive(Clone, Debug)]
pub struct Banded {
    n: usize,
    kl: usize,
    ku: usize,
    data: Vec<f64>,
}

impl Banded {
    /// Zero matrix of size `n` with bandwidths `kl` (lower), `ku` (upper).
    pub fn zeros(n: usize, kl: usize, ku: usize) -> Self {
        Banded { n, kl, ku, data: vec![0.0; n * (kl + ku + 1)] }
    }

    /// Identity matrix stored with the given bandwidths.
    pub fn eye(n: usize, kl: usize, ku: usize) -> Self {
        let mut m = Self::zeros(n, kl, ku);
        for i in 0..n {
            m.set(i, i, 1.0);
        }
        m
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn kl(&self) -> usize {
        self.kl
    }

    pub fn ku(&self) -> usize {
        self.ku
    }

    #[inline]
    fn idx(&self, i: usize, j: usize) -> usize {
        i * (self.kl + self.ku + 1) + (j + self.kl - i)
    }

    /// `true` iff `(i, j)` lies inside the stored band.
    #[inline]
    pub fn in_band(&self, i: usize, j: usize) -> bool {
        j + self.kl >= i && j <= i + self.ku && i < self.n && j < self.n
    }

    /// Read entry `(i, j)`; zero outside the band.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        if self.in_band(i, j) {
            self.data[self.idx(i, j)]
        } else {
            0.0
        }
    }

    /// Write entry `(i, j)`. Panics outside the band.
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        assert!(
            self.in_band(i, j),
            "set({i},{j}) outside band kl={} ku={} n={}",
            self.kl,
            self.ku,
            self.n
        );
        let idx = self.idx(i, j);
        self.data[idx] = v;
    }

    /// Add `v` to entry `(i, j)`. Panics outside the band.
    #[inline]
    pub fn add(&mut self, i: usize, j: usize, v: f64) {
        assert!(self.in_band(i, j), "add({i},{j}) outside band");
        let idx = self.idx(i, j);
        self.data[idx] += v;
    }

    /// Column range `[lo, hi)` of stored entries in row `i`.
    #[inline]
    pub fn row_range(&self, i: usize) -> (usize, usize) {
        (i.saturating_sub(self.kl), (i + self.ku + 1).min(self.n))
    }

    /// `y = self * x`.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.n);
        let mut y = vec![0.0; self.n];
        let w = self.kl + self.ku + 1;
        for i in 0..self.n {
            let (lo, hi) = self.row_range(i);
            let row = &self.data[i * w..(i + 1) * w];
            let mut acc = 0.0;
            for j in lo..hi {
                acc += row[j + self.kl - i] * x[j];
            }
            y[i] = acc;
        }
        y
    }

    /// `y = self^T * x`.
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.n);
        let mut y = vec![0.0; self.n];
        let w = self.kl + self.ku + 1;
        for i in 0..self.n {
            let (lo, hi) = self.row_range(i);
            let row = &self.data[i * w..(i + 1) * w];
            let xi = x[i];
            if xi != 0.0 {
                for j in lo..hi {
                    y[j] += row[j + self.kl - i] * xi;
                }
            }
        }
        y
    }

    /// Transposed copy (bandwidths swap).
    pub fn transpose(&self) -> Banded {
        let mut t = Banded::zeros(self.n, self.ku, self.kl);
        for i in 0..self.n {
            let (lo, hi) = self.row_range(i);
            for j in lo..hi {
                t.set(j, i, self.get(i, j));
            }
        }
        t
    }

    /// Banded × banded product. The result has bandwidths
    /// `(kl1 + kl2, ku1 + ku2)` (clipped to the matrix size).
    pub fn matmul(&self, other: &Banded) -> Banded {
        assert_eq!(self.n, other.n);
        let kl = (self.kl + other.kl).min(self.n - 1);
        let ku = (self.ku + other.ku).min(self.n - 1);
        let mut out = Banded::zeros(self.n, kl, ku);
        for i in 0..self.n {
            let (lo, hi) = self.row_range(i);
            for k in lo..hi {
                let a = self.get(i, k);
                if a == 0.0 {
                    continue;
                }
                let (lo2, hi2) = other.row_range(k);
                for j in lo2..hi2 {
                    let v = a * other.get(k, j);
                    if out.in_band(i, j) {
                        out.add(i, j, v);
                    } else if v.abs() > 1e-12 {
                        panic!("matmul fill outside declared band at ({i},{j})");
                    }
                }
            }
        }
        out
    }

    /// `self + alpha * other`, widening the band as needed.
    pub fn add_scaled(&self, other: &Banded, alpha: f64) -> Banded {
        assert_eq!(self.n, other.n);
        let kl = self.kl.max(other.kl);
        let ku = self.ku.max(other.ku);
        let mut out = Banded::zeros(self.n, kl, ku);
        for i in 0..self.n {
            let (lo, hi) = out.row_range(i);
            for j in lo..hi {
                out.set(i, j, self.get(i, j) + alpha * other.get(i, j));
            }
        }
        out
    }

    /// Scale all entries in place.
    pub fn scale(&mut self, alpha: f64) {
        for v in &mut self.data {
            *v *= alpha;
        }
    }

    /// Densify (for tests / tiny problems).
    pub fn to_dense(&self) -> crate::linalg::Dense {
        let mut d = crate::linalg::Dense::zeros(self.n, self.n);
        for i in 0..self.n {
            let (lo, hi) = self.row_range(i);
            for j in lo..hi {
                d.set(i, j, self.get(i, j));
            }
        }
        d
    }

    /// Insert a zero row *and* zero column at index `j`, growing the matrix
    /// to `(n+1) × (n+1)`. `O(n·(kl+ku))` — one `memmove` of the band
    /// storage.
    ///
    /// Because band storage addresses column `j` at the fixed in-row offset
    /// `j - i + kl`, splicing one zero row-block shifts every later row *and*
    /// its stored columns together, so rows whose stored window lies entirely
    /// on one side of `j` keep exactly their old entries. Only rows whose
    /// window straddles `j` (those with `|i - j| ≤ max(kl, ku)`) end up with
    /// entries that refer to shifted columns — callers performing an
    /// incremental update must rewrite that `O(kl+ku)` row window themselves
    /// (see `KpFactorization::insert`).
    pub fn insert_row_col(&mut self, j: usize) {
        self.insert_rows_cols(&[j]);
    }

    /// Insert `k` zero rows *and* zero columns in one pass, growing the
    /// matrix to `(n+k) × (n+k)`. `positions` are the *final* indices of the
    /// new zero rows in the grown matrix, strictly increasing (so
    /// `positions[t] ≤ n + t`). Total cost is `O((n+k)·(kl+ku))` — each
    /// surviving row block moves exactly once, instead of up to `k` times
    /// under repeated [`Banded::insert_row_col`] calls.
    ///
    /// The caller's contract is the batched form of the single-splice one:
    /// every row within `max(kl, ku)` of any spliced index must be rewritten
    /// afterwards (see `KpFactorization::insert_batch`); all other rows keep
    /// bit-identical entries.
    pub fn insert_rows_cols(&mut self, positions: &[usize]) {
        let k = positions.len();
        if k == 0 {
            return;
        }
        for (t, &q) in positions.iter().enumerate() {
            assert!(
                q <= self.n + t,
                "insert_rows_cols: position {q} out of range for n={} (t={t})",
                self.n
            );
            if t > 0 {
                assert!(
                    q > positions[t - 1],
                    "insert_rows_cols: positions must be strictly increasing"
                );
            }
        }
        let w = self.kl + self.ku + 1;
        let old_rows = self.n;
        self.data.resize((old_rows + k) * w, 0.0);
        // Walk the insertions back-to-front: old rows in [q_t − t, src_hi)
        // end up shifted by exactly t+1 slots, so each chunk moves once.
        let mut src_hi = old_rows;
        for t in (0..k).rev() {
            let q = positions[t];
            let src_lo = q - t; // q ≥ t because positions are strictly increasing
            if src_hi > src_lo {
                self.data.copy_within(src_lo * w..src_hi * w, (src_lo + t + 1) * w);
            }
            for v in &mut self.data[q * w..(q + 1) * w] {
                *v = 0.0;
            }
            src_hi = src_lo;
        }
        self.n = old_rows + k;
    }

    /// LU-factorize with partial pivoting (row swaps). `O((kl+ku)² n)`.
    pub fn lu(&self) -> BandedLU {
        BandedLU::factor(self)
    }

    /// Convenience: solve `self * x = b` via a fresh LU factorization.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        self.lu().solve(b)
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    /// Maximum absolute entry strictly outside the `(kl', ku')` band — used
    /// by tests asserting that a product really is banded.
    pub fn max_abs_outside(&self, kl2: usize, ku2: usize) -> f64 {
        let mut m: f64 = 0.0;
        for i in 0..self.n {
            let (lo, hi) = self.row_range(i);
            for j in lo..hi {
                let inside = j + kl2 >= i && j <= i + ku2;
                if !inside {
                    m = m.max(self.get(i, j).abs());
                }
            }
        }
        m
    }
}

/// LU factorization (partial pivoting) of a [`Banded`] matrix.
///
/// Standard LAPACK `gbtrf`-style scheme: with row swaps the `U` factor's
/// upper bandwidth grows to `kl + ku`; `L`'s multipliers stay within `kl`.
pub struct BandedLU {
    n: usize,
    kl: usize,
    /// Upper bandwidth of U after fill-in (`kl + ku`).
    kuf: usize,
    /// `U` (including diagonal) in band storage with bandwidths `(0, kuf)`
    /// plus the `L` multipliers in the sub-diagonal part `(kl, 0)`.
    fac: Banded,
    /// `piv[k]` = row swapped with row `k` at step `k`.
    piv: Vec<usize>,
    sign: f64,
}

impl BandedLU {
    fn factor(a: &Banded) -> Self {
        let n = a.n;
        let kl = a.kl;
        let kuf = (a.kl + a.ku).min(n.saturating_sub(1));
        // Working copy with widened upper band for fill-in.
        let mut f = Banded::zeros(n, kl, kuf);
        for i in 0..n {
            let (lo, hi) = a.row_range(i);
            for j in lo..hi {
                f.set(i, j, a.get(i, j));
            }
        }
        let mut piv = vec![0usize; n];
        let mut sign = 1.0;
        for k in 0..n {
            // Pivot search in column k, rows k..=k+kl.
            let last = (k + kl).min(n - 1);
            let mut p = k;
            let mut best = f.get(k, k).abs();
            for r in (k + 1)..=last {
                let v = f.get(r, k).abs();
                if v > best {
                    best = v;
                    p = r;
                }
            }
            piv[k] = p;
            if p != k {
                sign = -sign;
                // Swap rows k and p within their shared band columns.
                let hi = (k + kuf + 1).min(n);
                for j in k..hi {
                    let a = f.get(k, j);
                    let b = if f.in_band(p, j) { f.get(p, j) } else { 0.0 };
                    f.set(k, j, b);
                    if f.in_band(p, j) {
                        f.set(p, j, a);
                    } else {
                        assert!(a == 0.0, "pivot swap lost fill at ({p},{j})");
                    }
                }
            }
            let pivot = f.get(k, k);
            if pivot == 0.0 {
                continue; // singular; solve will produce inf/nan, logdet -inf
            }
            for r in (k + 1)..=last {
                let m = f.get(r, k) / pivot;
                f.set(r, k, m); // store multiplier
                if m != 0.0 {
                    let hi = (k + kuf + 1).min(n);
                    for j in (k + 1)..hi {
                        let v = f.get(r, j) - m * f.get(k, j);
                        f.set(r, j, v);
                    }
                }
            }
        }
        BandedLU { n, kl, kuf, fac: f, piv, sign }
    }

    /// Solve `A x = b`.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let mut x = b.to_vec();
        self.solve_in_place(&mut x);
        x
    }

    /// Solve `A x = b` in place. The inner loops index the band storage
    /// directly (no per-element bounds logic) — this is the `O(n)` primitive
    /// under every algorithm in the crate, see DESIGN.md §Perf.
    pub fn solve_in_place(&self, x: &mut [f64]) {
        assert_eq!(x.len(), self.n);
        let n = self.n;
        let w = self.kl + self.kuf + 1;
        let data = &self.fac.data;
        let kl = self.kl;
        // Forward: apply P and L^{-1}. fac[r, k] = data[r*w + k + kl - r].
        for k in 0..n {
            let p = self.piv[k];
            if p != k {
                x.swap(k, p);
            }
            let last = (k + kl).min(n - 1);
            let xk = x[k];
            if xk != 0.0 {
                for r in (k + 1)..=last {
                    x[r] -= data[r * w + k + kl - r] * xk;
                }
            }
        }
        // Backward: U x = y. Row k of U is contiguous: fac[k, j] =
        // data[k*w + kl + (j-k)] for j = k..k+kuf.
        for k in (0..n).rev() {
            let hi = (k + self.kuf + 1).min(n);
            let row = &data[k * w + kl..k * w + kl + (hi - k)];
            let mut acc = x[k];
            for (off, &f) in row.iter().enumerate().skip(1) {
                acc -= f * x[k + off];
            }
            x[k] = acc / row[0];
        }
    }

    /// `log |det A|` and the determinant sign.
    pub fn logdet(&self) -> (f64, f64) {
        let mut ld = 0.0;
        let mut sign = self.sign;
        for k in 0..self.n {
            let d = self.fac.get(k, k);
            ld += d.abs().ln();
            if d < 0.0 {
                sign = -sign;
            }
        }
        (ld, sign)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tridiag(n: usize, lo: f64, di: f64, up: f64) -> Banded {
        let mut m = Banded::zeros(n, 1, 1);
        for i in 0..n {
            if i > 0 {
                m.set(i, i - 1, lo);
            }
            m.set(i, i, di);
            if i + 1 < n {
                m.set(i, i + 1, up);
            }
        }
        m
    }

    #[test]
    fn matvec_matches_dense() {
        let m = tridiag(6, -1.0, 2.5, -0.5);
        let x: Vec<f64> = (0..6).map(|i| (i as f64).sin() + 1.0).collect();
        let y = m.matvec(&x);
        let yd = m.to_dense().matvec(&x);
        for i in 0..6 {
            assert!((y[i] - yd[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn matvec_t_matches_transpose() {
        let m = tridiag(7, 0.3, 1.7, -2.0);
        let x: Vec<f64> = (0..7).map(|i| (i as f64 * 0.7).cos()).collect();
        let a = m.matvec_t(&x);
        let b = m.transpose().matvec(&x);
        for i in 0..7 {
            assert!((a[i] - b[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn lu_solve_roundtrip() {
        let m = tridiag(40, -1.0, 2.0, -1.0); // SPD (discrete Laplacian)
        let x_true: Vec<f64> = (0..40).map(|i| ((i * 7 % 11) as f64) - 5.0).collect();
        let b = m.matvec(&x_true);
        let x = m.solve(&b);
        for i in 0..40 {
            assert!((x[i] - x_true[i]).abs() < 1e-9, "i={i}: {} vs {}", x[i], x_true[i]);
        }
    }

    #[test]
    fn lu_solve_needs_pivoting() {
        // Small diagonal entry forces a pivot swap.
        let mut m = Banded::zeros(4, 1, 1);
        m.set(0, 0, 1e-14);
        m.set(0, 1, 1.0);
        m.set(1, 0, 1.0);
        m.set(1, 1, 1.0);
        m.set(1, 2, 2.0);
        m.set(2, 1, -1.0);
        m.set(2, 2, 3.0);
        m.set(2, 3, 0.5);
        m.set(3, 2, 1.0);
        m.set(3, 3, -2.0);
        let x_true = vec![1.0, -2.0, 3.0, -4.0];
        let b = m.matvec(&x_true);
        let x = m.solve(&b);
        for i in 0..4 {
            assert!((x[i] - x_true[i]).abs() < 1e-8, "{:?}", x);
        }
    }

    #[test]
    fn logdet_matches_dense() {
        let m = tridiag(12, -0.8, 2.2, -0.8);
        let (ld, sign) = m.lu().logdet();
        let (ldd, signd) = m.to_dense().lu_logdet();
        assert!((ld - ldd).abs() < 1e-9);
        assert_eq!(sign, signd);
    }

    /// Inserting a row/col and rewriting the straddling `O(kl+ku)` window
    /// (the caller's contract) reproduces a freshly-built matrix exactly.
    #[test]
    fn insert_row_col_then_window_rewrite_matches_fresh() {
        // Per-row values so any index shift is detectable.
        let row_entries = |i: usize, n: usize, vals: &[f64]| -> Vec<(usize, f64)> {
            let mut e = Vec::new();
            if i > 0 {
                e.push((i - 1, -vals[i]));
            }
            e.push((i, 2.0 + vals[i]));
            if i + 1 < n {
                e.push((i + 1, 0.5 * vals[i]));
            }
            e
        };
        let build = |vals: &[f64]| {
            let n = vals.len();
            let mut m = Banded::zeros(n, 1, 1);
            for i in 0..n {
                for (c, v) in row_entries(i, n, vals) {
                    m.set(i, c, v);
                }
            }
            m
        };
        for j in [0usize, 3, 6] {
            let vals6 = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
            let mut vals7 = vals6.to_vec();
            vals7.insert(j, 9.0);
            let fresh = build(&vals7);

            let mut inc = build(&vals6);
            inc.insert_row_col(j);
            assert_eq!(inc.n(), 7);
            // Rewrite the straddling window |i − j| ≤ max(kl, ku) = 1.
            for i in j.saturating_sub(1)..=(j + 1).min(6) {
                let (lo, hi) = inc.row_range(i);
                for c in lo..hi {
                    inc.set(i, c, 0.0);
                }
                for (c, v) in row_entries(i, 7, &vals7) {
                    inc.set(i, c, v);
                }
            }
            for i in 0..7 {
                for c in 0..7 {
                    assert_eq!(inc.get(i, c), fresh.get(i, c), "j={j} ({i},{c})");
                }
            }
        }
    }

    /// Batched splice == repeated single splices, for front / interior /
    /// back / adjacent positions.
    #[test]
    fn insert_rows_cols_matches_repeated_single_inserts() {
        let base = tridiag(6, -1.5, 2.0, 0.75);
        for positions in [
            vec![0usize, 1],
            vec![2, 5],
            vec![0, 3, 8],
            vec![6, 7],
            vec![1, 2, 3],
        ] {
            let mut batched = base.clone();
            batched.insert_rows_cols(&positions);

            // Repeated single splices at the same *final* indices: splicing
            // in ascending order keeps each final index exact.
            let mut single = base.clone();
            for &q in &positions {
                let w = single.kl + single.ku + 1;
                let at = q * w;
                let old_len = single.data.len();
                single.data.resize(old_len + w, 0.0);
                single.data.copy_within(at..old_len, at + w);
                for v in &mut single.data[at..at + w] {
                    *v = 0.0;
                }
                single.n += 1;
            }

            assert_eq!(batched.n(), 6 + positions.len(), "{positions:?}");
            for i in 0..batched.n() {
                for j in 0..batched.n() {
                    assert_eq!(
                        batched.get(i, j),
                        single.get(i, j),
                        "{positions:?} ({i},{j})"
                    );
                }
            }
        }
    }

    #[test]
    fn matmul_band_widths() {
        let a = tridiag(10, 1.0, 2.0, 3.0);
        let b = tridiag(10, -0.5, 1.0, 0.25);
        let c = a.matmul(&b);
        assert_eq!(c.kl(), 2);
        assert_eq!(c.ku(), 2);
        let cd = a.to_dense().matmul(&b.to_dense());
        for i in 0..10 {
            for j in 0..10 {
                assert!((c.get(i, j) - cd.get(i, j)).abs() < 1e-12);
            }
        }
    }
}
