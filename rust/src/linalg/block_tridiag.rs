//! Paper **Algorithm 5**: the `(ν+1/2)`-band of `Φ_d^{-T} A_d^{-1} =
//! (A_d Φ_d^T)^{-1}` where `H = A_d Φ_d^T = A_d K_d A_d^T` is a *symmetric*
//! `2ν`-banded matrix.
//!
//! Implemented as the classic selected ("block-tridiagonal" / RGF /
//! Takahashi) inverse: partition `H` into `s×s` blocks (`s ≥ bandwidth`),
//! making it block-tridiagonal; a forward Schur-complement sweep followed by
//! a backward recursion yields the block-diagonal and first off-diagonal
//! blocks of `H^{-1}` in `O(s² n)` time — exactly the band the paper needs
//! for the `O(1)` posterior-variance windows of eq. (25).

use crate::linalg::{Banded, Dense};

/// Compute the entries of `H^{-1}` with `|i - j| ≤ out_band` for a symmetric
/// banded matrix `H`, returned as a [`Banded`] with bandwidths
/// `(out_band, out_band)`.
///
/// Requirements: `H` symmetric; the forward Schur complements must be
/// invertible (guaranteed for the SPD `A_d K_d A_d^T` of the paper).
pub fn selected_inverse_band(h: &Banded, out_band: usize) -> Banded {
    let n = h.n();
    let bw = h.kl().max(h.ku());
    let s = bw.max(out_band).max(1);
    if n <= 2 * s {
        // Tiny system: dense fallback.
        let inv = h.to_dense().inverse();
        let mut out = Banded::zeros(n, out_band.min(n - 1), out_band.min(n - 1));
        for i in 0..n {
            let (lo, hi) = out.row_range(i);
            for j in lo..hi {
                out.set(i, j, inv.get(i, j));
            }
        }
        return out;
    }

    let nblocks = n.div_ceil(s);
    let bsize = |i: usize| -> usize {
        if i + 1 == nblocks {
            n - i * s
        } else {
            s
        }
    };
    let block = |bi: usize, bj: usize| -> Dense {
        let (ri, rj) = (bi * s, bj * s);
        let (mi, mj) = (bsize(bi), bsize(bj));
        let mut d = Dense::zeros(mi, mj);
        for i in 0..mi {
            for j in 0..mj {
                d.set(i, j, h.get(ri + i, rj + j));
            }
        }
        d
    };

    // Forward sweep: Λ_0 = D_0, Λ_i = D_i − U_{i-1}^T Λ_{i-1}^{-1} U_{i-1}.
    // Store Λ_i^{-1}.
    let mut lam_inv: Vec<Dense> = Vec::with_capacity(nblocks);
    for i in 0..nblocks {
        let mut d = block(i, i);
        if i > 0 {
            let u_prev = block(i - 1, i); // H_{i-1,i}
            let t = lam_inv[i - 1].matmul(&u_prev); // Λ_{i-1}^{-1} U_{i-1}
            let corr = u_prev.transpose().matmul(&t);
            d = d.add_scaled(&corr, -1.0);
        }
        lam_inv.push(d.inverse());
    }

    // Backward recursion for the selected inverse blocks:
    //   S_{I,I}   = Λ_I^{-1}
    //   S_{i,i+1} = −Λ_i^{-1} U_i S_{i+1,i+1}
    //   S_{i,i}   = Λ_i^{-1} + Λ_i^{-1} U_i S_{i+1,i+1} U_i^T Λ_i^{-1}
    let ob = out_band.min(n - 1);
    let mut out = Banded::zeros(n, ob, ob);
    let write_block = |bi: usize, bj: usize, d: &Dense, out: &mut Banded| {
        let (ri, rj) = (bi * s, bj * s);
        for i in 0..d.rows() {
            for j in 0..d.cols() {
                if out.in_band(ri + i, rj + j) {
                    out.set(ri + i, rj + j, d.get(i, j));
                }
            }
        }
    };

    let mut s_next = lam_inv[nblocks - 1].clone(); // S_{I,I}
    write_block(nblocks - 1, nblocks - 1, &s_next, &mut out);
    for i in (0..nblocks - 1).rev() {
        let u = block(i, i + 1);
        let li = &lam_inv[i];
        let li_u = li.matmul(&u); // Λ_i^{-1} U_i
        let mut s_off = li_u.matmul(&s_next); // Λ_i^{-1} U_i S_{i+1,i+1}
        s_off.scale(-1.0); // S_{i,i+1}
        let corr = s_off.matmul(&li_u.transpose()); // −Λ^{-1}U S U^T Λ^{-T}... sign:
        // S_{i,i} = Λ_i^{-1} + (Λ_i^{-1}U_i) S_{i+1,i+1} (Λ_i^{-1}U_i)^T
        //         = Λ_i^{-1} − S_{i,i+1} (Λ_i^{-1}U_i)^T
        let s_diag = li.add_scaled(&corr, -1.0);
        write_block(i, i + 1, &s_off, &mut out);
        write_block(i + 1, i, &s_off.transpose(), &mut out);
        write_block(i, i, &s_diag, &mut out);
        s_next = s_diag;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Symmetric positive-definite banded test matrix.
    fn spd_banded(n: usize, bw: usize) -> Banded {
        let mut m = Banded::zeros(n, bw, bw);
        for i in 0..n {
            let (lo, hi) = m.row_range(i);
            for j in lo..hi {
                if i == j {
                    m.set(i, j, 4.0 + (i as f64 * 0.1).sin());
                } else {
                    let v = 0.5 / (1.0 + (i as f64 - j as f64).abs());
                    m.set(i, j, v);
                }
            }
        }
        m
    }

    #[test]
    fn selected_inverse_matches_dense_bw1() {
        let h = spd_banded(25, 1);
        let band = selected_inverse_band(&h, 1);
        let inv = h.to_dense().inverse();
        for i in 0usize..25 {
            for j in i.saturating_sub(1)..(i + 2).min(25) {
                assert!(
                    (band.get(i, j) - inv.get(i, j)).abs() < 1e-10,
                    "({i},{j}): {} vs {}",
                    band.get(i, j),
                    inv.get(i, j)
                );
            }
        }
    }

    #[test]
    fn selected_inverse_matches_dense_bw3() {
        let h = spd_banded(40, 3);
        let band = selected_inverse_band(&h, 2);
        let inv = h.to_dense().inverse();
        for i in 0usize..40 {
            for j in i.saturating_sub(2)..(i + 3).min(40) {
                assert!(
                    (band.get(i, j) - inv.get(i, j)).abs() < 1e-10,
                    "({i},{j}): {} vs {}",
                    band.get(i, j),
                    inv.get(i, j)
                );
            }
        }
    }

    #[test]
    fn selected_inverse_ragged_last_block() {
        // n not divisible by block size.
        let h = spd_banded(29, 2);
        let band = selected_inverse_band(&h, 2);
        let inv = h.to_dense().inverse();
        for i in 0usize..29 {
            for j in i.saturating_sub(2)..(i + 3).min(29) {
                assert!((band.get(i, j) - inv.get(i, j)).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn tiny_system_dense_fallback() {
        let h = spd_banded(4, 2);
        let band = selected_inverse_band(&h, 2);
        let inv = h.to_dense().inverse();
        for i in 0usize..4 {
            for j in i.saturating_sub(2)..(i + 3).min(4) {
                assert!((band.get(i, j) - inv.get(i, j)).abs() < 1e-10);
            }
        }
    }
}
