//! Chunked copy-on-write row storage for band matrices (DESIGN.md
//! §"Chunked COW band storage").
//!
//! [`ChunkedRows`] replaces the flat `Vec<f64>` behind [`crate::linalg::Banded`]
//! with a rope of row-block chunks, each an `Arc<Vec<f64>>`:
//!
//! * an **append** touches only the unsealed tail chunk (a chunk is sealed
//!   once it reaches [`CHUNK_ROWS`] rows — appends then start a fresh chunk),
//!   so no existing byte moves;
//! * a **mid-matrix splice** rewrites only the chunks an insertion straddles;
//!   every other chunk keeps its buffer verbatim — structural sharing with
//!   outstanding snapshots survives the splice;
//! * a **clone** is a reference bump: clean chunks are `Arc`-shared, and a
//!   later write copies the touched chunk on demand (`chunks_copied` counts
//!   those), so a [`crate::gp::fit_state::PosteriorSnapshot`] build costs
//!   `O(chunks)` pointer bumps instead of an `O(nν)` deep copy per band.
//!
//! The **dirty** flag tracks chunks written since the last
//! [`ChunkedRows::mark_clean`] and carries the central aliasing invariant:
//! a dirty chunk is always uniquely owned (`Arc` strong count 1), because
//! the only way to write a shared chunk is the COW path, which unshares it
//! first. Snapshot builders call `mark_clean` and then `clone`; audits
//! (`strict-invariants`) verify the invariant plus the chunk-table shape.
//!
//! Everything here is pure layout: the logical row-major contents are
//! bit-identical to the flat storage they replace ([`ChunkedRows::to_flat`]
//! reconstructs it exactly — the equivalence surface `tests/incremental.rs`
//! pins across random observe/splice/snapshot interleavings).

use std::sync::Arc;

use crate::check::{Audit, AuditError};

/// Target rows per chunk. Appends grow the tail chunk to this size before
/// starting a new one; splice rebuilds re-split at this size. The value
/// trades splice cost (`O(CHUNK_ROWS · ν)` bytes shifted per straddled
/// chunk) against per-row lookup/bump overhead (`O(n / CHUNK_ROWS)` chunk
/// handles per matrix); 64 rows keeps a ν = 5/2 band's chunk near 4 KiB.
pub const CHUNK_ROWS: usize = 64;

/// Hard upper bound on a chunk's rows. A splice may grow a straddled chunk
/// past [`CHUNK_ROWS`]; once it would exceed this bound the rebuild splits
/// it. (Truncated partial chunks from [`ChunkedRows::from_prefix`] may be
/// arbitrarily small — only the upper bound is invariant.)
pub const MAX_CHUNK_ROWS: usize = 2 * CHUNK_ROWS;

/// Cumulative storage counters surfaced through `Response::Stats`
/// (`memmove_bytes`, `chunks_copied`) plus the current chunk count used for
/// the per-snapshot `chunks_shared` tally.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StorageStats {
    /// Bytes of pre-existing rows shifted inside chunks by splices
    /// ([`ChunkedRows::insert_zero_rows`]); appends contribute zero.
    pub memmove_bytes: u64,
    /// Chunks deep-copied by the copy-on-write path (a write hitting a
    /// chunk shared with a snapshot).
    pub chunks_copied: u64,
    /// Current number of chunks in the rope.
    pub chunks: u64,
}

impl StorageStats {
    /// Elementwise accumulate (summing over a structure's ropes).
    pub fn accumulate(&mut self, other: StorageStats) {
        self.memmove_bytes += other.memmove_bytes;
        self.chunks_copied += other.chunks_copied;
        self.chunks += other.chunks;
    }
}

/// Amortized-O(1) chunk lookup state for loops whose row index moves mostly
/// sequentially (the banded solve walks rows forward then backward) — pass
/// to [`ChunkedRows::row_at`] instead of paying a binary search per row.
#[derive(Clone, Copy, Debug)]
pub struct RowCursor {
    ci: usize,
}

/// A rope of `Arc`-shared row-block chunks holding `n_rows` rows of
/// `width` contiguous `f64`s each. See the module docs for the COW / dirty
/// lifecycle.
#[derive(Debug)]
pub struct ChunkedRows {
    width: usize,
    n_rows: usize,
    chunks: Vec<Arc<Vec<f64>>>,
    /// Prefix row indices: `starts[c]` is the first row of chunk `c`;
    /// `starts.len() == chunks.len() + 1` with `starts[0] == 0` and
    /// `starts[last] == n_rows`.
    starts: Vec<usize>,
    /// `dirty[c]`: chunk `c` was written since the last `mark_clean`.
    /// Invariant: a dirty chunk is uniquely owned.
    dirty: Vec<bool>,
    memmove_bytes: u64,
    chunks_copied: u64,
}

impl Clone for ChunkedRows {
    /// Reference-bump clone: clean chunks are `Arc`-shared; dirty chunks
    /// (uniquely owned by invariant) are deep-copied so `dirty ⇒ unique`
    /// holds on *both* sides afterwards. Snapshot builders call
    /// [`ChunkedRows::mark_clean`] first, making this a pure pointer bump.
    fn clone(&self) -> Self {
        let chunks = self
            .chunks
            .iter()
            .zip(&self.dirty)
            .map(|(c, &d)| if d { Arc::new(Vec::clone(c)) } else { Arc::clone(c) })
            .collect();
        ChunkedRows {
            width: self.width,
            n_rows: self.n_rows,
            chunks,
            starts: self.starts.clone(),
            dirty: vec![false; self.dirty.len()],
            memmove_bytes: self.memmove_bytes,
            chunks_copied: self.chunks_copied,
        }
    }
}

impl ChunkedRows {
    /// `n_rows` zero rows of `width` values each, chunked at
    /// [`CHUNK_ROWS`]. Fresh chunks start dirty (no snapshot has seen them).
    pub fn zeros(width: usize, n_rows: usize) -> Self {
        assert!(width > 0, "ChunkedRows requires a positive row width");
        let mut s = ChunkedRows {
            width,
            n_rows: 0,
            chunks: Vec::new(),
            starts: vec![0],
            dirty: Vec::new(),
            memmove_bytes: 0,
            chunks_copied: 0,
        };
        s.append_zero_rows(n_rows);
        s
    }

    pub fn width(&self) -> usize {
        self.width
    }

    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    pub fn num_chunks(&self) -> usize {
        self.chunks.len()
    }

    /// Cumulative counters plus the current chunk count.
    pub fn stats(&self) -> StorageStats {
        StorageStats {
            memmove_bytes: self.memmove_bytes,
            chunks_copied: self.chunks_copied,
            chunks: self.chunks.len() as u64,
        }
    }

    /// Clear every dirty flag, returning `(dirtied, total)` chunk counts.
    /// Called by snapshot builders immediately before cloning: the clone is
    /// then a pure reference bump, and the chunks a later engine write
    /// touches are copied on demand (counted in `chunks_copied`).
    pub fn mark_clean(&mut self) -> (u64, u64) {
        let mut dirtied = 0u64;
        for d in &mut self.dirty {
            if *d {
                dirtied += 1;
            }
            *d = false;
        }
        (dirtied, self.chunks.len() as u64)
    }

    /// Index of the chunk holding row `i`.
    #[inline]
    fn chunk_of(&self, i: usize) -> usize {
        debug_assert!(i < self.n_rows, "row {i} out of {} rows", self.n_rows);
        self.starts[1..].partition_point(|&s| s <= i)
    }

    /// Row `i` as a `width`-length slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        let c = self.chunk_of(i);
        let off = (i - self.starts[c]) * self.width;
        &self.chunks[c][off..off + self.width]
    }

    /// Row `i` for writing, copy-on-write: a chunk shared with a snapshot
    /// is deep-copied first; the chunk is marked dirty either way.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        let c = self.chunk_of(i);
        let off = (i - self.starts[c]) * self.width;
        let w = self.width;
        let buf = self.make_unique(c);
        &mut buf[off..off + w]
    }

    /// Make chunk `c` uniquely owned (deep-copying if shared) and dirty.
    fn make_unique(&mut self, c: usize) -> &mut Vec<f64> {
        if Arc::strong_count(&self.chunks[c]) > 1 {
            self.chunks_copied += 1;
        }
        self.dirty[c] = true;
        Arc::make_mut(&mut self.chunks[c])
    }

    /// A fresh cursor for [`ChunkedRows::row_at`].
    pub fn cursor(&self) -> RowCursor {
        RowCursor { ci: 0 }
    }

    /// Row `i` through a cursor: the chunk index is found by walking from
    /// the cursor's last chunk, so mostly-sequential access (ascending or
    /// descending) costs amortized O(1) per row instead of a binary search.
    #[inline]
    pub fn row_at<'a>(&'a self, cur: &mut RowCursor, i: usize) -> &'a [f64] {
        debug_assert!(i < self.n_rows, "row {i} out of {} rows", self.n_rows);
        let mut ci = cur.ci;
        if ci >= self.chunks.len() {
            ci = self.chunks.len() - 1;
        }
        while i < self.starts[ci] {
            ci -= 1;
        }
        while i >= self.starts[ci + 1] {
            ci += 1;
        }
        cur.ci = ci;
        let off = (i - self.starts[ci]) * self.width;
        &self.chunks[ci][off..off + self.width]
    }

    /// All rows in order as `width`-length slices, walked chunk-sequentially
    /// (no per-row lookup) — the hot-loop iteration form.
    pub fn iter_rows(&self) -> impl Iterator<Item = &[f64]> {
        let w = self.width;
        self.chunks.iter().flat_map(move |c| c.chunks_exact(w))
    }

    /// Apply `f` to every stored value in place. Every chunk is unshared
    /// (COW) and marked dirty.
    pub fn map_in_place(&mut self, mut f: impl FnMut(&mut f64)) {
        for c in 0..self.chunks.len() {
            for v in self.make_unique(c).iter_mut() {
                f(v);
            }
        }
    }

    /// Append `m` zero rows. Only the unsealed tail chunk is touched: it
    /// grows until it holds [`CHUNK_ROWS`] rows, then fresh chunks are
    /// pushed. No existing row moves (`memmove_bytes` is untouched).
    pub fn append_zero_rows(&mut self, m: usize) {
        if m == 0 {
            return;
        }
        let w = self.width;
        let mut left = m;
        if let Some(last) = self.chunks.last() {
            let rows = last.len() / w;
            if rows < CHUNK_ROWS {
                let take = left.min(CHUNK_ROWS - rows);
                let li = self.chunks.len() - 1;
                let buf = self.make_unique(li);
                buf.resize((rows + take) * w, 0.0);
                if let Some(top) = self.starts.last_mut() {
                    *top += take;
                }
                left -= take;
            }
        }
        while left > 0 {
            let take = left.min(CHUNK_ROWS);
            self.chunks.push(Arc::new(vec![0.0; take * w]));
            self.dirty.push(true);
            let top = self.starts[self.starts.len() - 1];
            self.starts.push(top + take);
            left -= take;
        }
        self.n_rows += m;
    }

    /// Splice `k` zero rows at the given **final** indices (strictly
    /// increasing, `positions[t] ≤ n_rows + t` — the
    /// [`crate::linalg::Banded::insert_rows_cols`] contract). Only chunks an
    /// insertion lands in are rewritten (COW); all other chunks keep their
    /// buffers verbatim, so structural sharing with snapshots survives.
    /// Trailing insertions at the very end route through
    /// [`ChunkedRows::append_zero_rows`] and move nothing.
    ///
    /// `memmove_bytes` accounts the bytes of pre-existing rows displaced
    /// within each rewritten chunk — bounded by `O(MAX_CHUNK_ROWS · width)`
    /// per straddled chunk, independent of `n_rows`.
    pub fn insert_zero_rows(&mut self, positions: &[usize]) {
        let k = positions.len();
        if k == 0 {
            return;
        }
        let w = self.width;
        let n_old = self.n_rows;
        // Original-coordinate insertion points: final index p_t means
        // "before original row p_t − t" (non-decreasing, ≤ n_old).
        let orig: Vec<usize> =
            positions.iter().enumerate().map(|(t, &p)| p - t).collect();
        debug_assert!(orig.windows(2).all(|p| p[0] <= p[1]));
        debug_assert!(orig.last().is_none_or(|&o| o <= n_old));

        let n_chunks = self.chunks.len();
        let mut new_chunks: Vec<Arc<Vec<f64>>> = Vec::with_capacity(n_chunks + 1);
        let mut new_dirty: Vec<bool> = Vec::with_capacity(n_chunks + 1);
        let mut t = 0usize;
        for c in 0..n_chunks {
            let s0 = self.starts[c];
            let s1 = self.starts[c + 1];
            let t0 = t;
            while t < k && orig[t] < s1 {
                t += 1;
            }
            if t == t0 {
                // No insertion lands here: the buffer survives verbatim.
                new_chunks.push(Arc::clone(&self.chunks[c]));
                new_dirty.push(self.dirty[c]);
                continue;
            }
            // Rebuild this chunk with the zero rows spliced in.
            let ins = &orig[t0..t];
            let old = &self.chunks[c];
            let rows_old = s1 - s0;
            let mut v = Vec::with_capacity((rows_old + ins.len()) * w);
            let mut pos = s0;
            for &o in ins {
                v.extend_from_slice(&old[(pos - s0) * w..(o - s0) * w]);
                v.resize(v.len() + w, 0.0);
                pos = o;
            }
            v.extend_from_slice(&old[(pos - s0) * w..]);
            // Pre-existing rows at or past the first insertion point all
            // shifted within this chunk.
            self.memmove_bytes +=
                ((s1 - ins[0]) * w * std::mem::size_of::<f64>()) as u64;
            split_push(&mut new_chunks, &mut new_dirty, v, w);
        }
        self.chunks = new_chunks;
        self.dirty = new_dirty;
        self.rebuild_starts();
        // Remaining insertions sit at the very end (orig == n_old).
        self.append_zero_rows(k - t);
    }

    /// Remove the rows at the given indices (strictly increasing, all
    /// `< n_rows`) — the deletion mirror of
    /// [`ChunkedRows::insert_zero_rows`]. Only chunks a removal lands in are
    /// rebuilt; every other chunk keeps its buffer verbatim, so structural
    /// sharing with outstanding snapshots survives the deletion exactly as
    /// it survives a splice. A chunk whose rows are all removed is dropped
    /// entirely (empty chunks are structurally illegal).
    ///
    /// `memmove_bytes` accounts the bytes of surviving rows displaced within
    /// each rewritten chunk — bounded by `O(MAX_CHUNK_ROWS · width)` per
    /// straddled chunk, independent of `n_rows`.
    pub fn remove_rows(&mut self, positions: &[usize]) {
        let k = positions.len();
        if k == 0 {
            return;
        }
        let w = self.width;
        debug_assert!(positions.windows(2).all(|p| p[0] < p[1]));
        debug_assert!(positions.last().is_none_or(|&p| p < self.n_rows));
        let n_chunks = self.chunks.len();
        let mut new_chunks: Vec<Arc<Vec<f64>>> = Vec::with_capacity(n_chunks);
        let mut new_dirty: Vec<bool> = Vec::with_capacity(n_chunks);
        let mut t = 0usize;
        for c in 0..n_chunks {
            let s0 = self.starts[c];
            let s1 = self.starts[c + 1];
            let t0 = t;
            while t < k && positions[t] < s1 {
                t += 1;
            }
            if t == t0 {
                // No removal lands here: the buffer survives verbatim.
                new_chunks.push(Arc::clone(&self.chunks[c]));
                new_dirty.push(self.dirty[c]);
                continue;
            }
            let rem = &positions[t0..t];
            let rows_old = s1 - s0;
            if rem.len() == rows_old {
                // Every row of this chunk is removed: drop the chunk.
                continue;
            }
            let old = &self.chunks[c];
            let mut v = Vec::with_capacity((rows_old - rem.len()) * w);
            let mut pos = s0;
            for &r in rem {
                v.extend_from_slice(&old[(pos - s0) * w..(r - s0) * w]);
                pos = r + 1;
            }
            v.extend_from_slice(&old[(pos - s0) * w..]);
            // Surviving rows past the first removed index all shifted within
            // this chunk.
            self.memmove_bytes +=
                ((s1 - rem[0] - rem.len()) * w * std::mem::size_of::<f64>()) as u64;
            new_chunks.push(Arc::new(v));
            new_dirty.push(true);
        }
        debug_assert_eq!(t, k, "remove_rows position out of range");
        self.chunks = new_chunks;
        self.dirty = new_dirty;
        self.rebuild_starts();
    }

    /// A new rope reusing rows `[0, keep)` of `self` plus `new_rows − keep`
    /// fresh zero rows: whole chunks below `keep` are `Arc`-shared (their
    /// bytes are settled prefix both sides agree on — the caller must
    /// [`ChunkedRows::mark_clean`] `self` first so sharing keeps the
    /// `dirty ⇒ unique` invariant), a chunk straddling `keep` is deep-copied
    /// truncated. Cumulative counters carry over so per-structure stats
    /// survive a factor patch replacing its storage.
    pub fn from_prefix(&self, keep: usize, new_rows: usize) -> ChunkedRows {
        assert!(keep <= self.n_rows && keep <= new_rows);
        let w = self.width;
        let mut out = ChunkedRows {
            width: w,
            n_rows: 0,
            chunks: Vec::new(),
            starts: vec![0],
            dirty: Vec::new(),
            memmove_bytes: self.memmove_bytes,
            chunks_copied: self.chunks_copied,
        };
        for c in 0..self.chunks.len() {
            let s0 = self.starts[c];
            let s1 = self.starts[c + 1];
            if s1 <= keep {
                debug_assert!(!self.dirty[c], "from_prefix on a dirty source chunk");
                out.chunks.push(Arc::clone(&self.chunks[c]));
                out.dirty.push(false);
                out.starts.push(s1);
                out.n_rows = s1;
            } else {
                if s0 < keep {
                    out.chunks.push(Arc::new(self.chunks[c][..(keep - s0) * w].to_vec()));
                    out.dirty.push(true);
                    out.starts.push(keep);
                    out.n_rows = keep;
                }
                break;
            }
        }
        out.append_zero_rows(new_rows - keep);
        out
    }

    /// Concatenate all rows into the flat row-major band layout this rope
    /// replaced — the chunked == flat equivalence surface for property
    /// tests. Deliberately an O(nν) copy; production code must not call it
    /// (the `cargo xtask lint` COW scanner enforces that).
    pub fn to_flat(&self) -> Vec<f64> {
        let mut v = Vec::with_capacity(self.n_rows * self.width);
        for c in &self.chunks {
            v.extend_from_slice(c);
        }
        v
    }

    fn rebuild_starts(&mut self) {
        let w = self.width;
        self.starts.clear();
        self.starts.push(0);
        let mut acc = 0usize;
        for c in &self.chunks {
            acc += c.len() / w;
            self.starts.push(acc);
        }
        self.n_rows = acc;
    }
}

/// Push a rebuilt buffer as one chunk, or split it into [`CHUNK_ROWS`]-row
/// pieces once it would exceed [`MAX_CHUNK_ROWS`]. Every pushed chunk is
/// freshly owned, hence dirty.
fn split_push(
    chunks: &mut Vec<Arc<Vec<f64>>>,
    dirty: &mut Vec<bool>,
    v: Vec<f64>,
    w: usize,
) {
    let rows = v.len() / w;
    if rows <= MAX_CHUNK_ROWS {
        chunks.push(Arc::new(v));
        dirty.push(true);
        return;
    }
    let mut done = 0usize;
    while done < rows {
        let take = (rows - done).min(CHUNK_ROWS);
        chunks.push(Arc::new(v[done * w..(done + take) * w].to_vec()));
        dirty.push(true);
        done += take;
    }
}

impl Audit for ChunkedRows {
    /// Chunk-table invariants: the `starts` prefix table is strictly
    /// increasing from 0 to `n_rows` and consistent with every chunk's
    /// buffer length, no chunk exceeds [`MAX_CHUNK_ROWS`] rows (or is
    /// empty), the dirty table is parallel to the chunk table, and — the
    /// aliasing invariant the COW path relies on — every dirty chunk is
    /// uniquely owned (`Arc` sharing only on clean chunks).
    fn audit(&self) -> Result<(), AuditError> {
        if self.width == 0 {
            return Err(AuditError::new(
                "ChunkedRows",
                "width",
                None,
                "zero row width".to_string(),
            ));
        }
        if self.starts.len() != self.chunks.len() + 1 || self.starts[0] != 0 {
            return Err(AuditError::new(
                "ChunkedRows",
                "starts",
                None,
                format!(
                    "starts table length {} inconsistent with {} chunks (first = {})",
                    self.starts.len(),
                    self.chunks.len(),
                    self.starts[0]
                ),
            ));
        }
        if self.dirty.len() != self.chunks.len() {
            return Err(AuditError::new(
                "ChunkedRows",
                "dirty",
                None,
                format!(
                    "dirty table length {} != {} chunks",
                    self.dirty.len(),
                    self.chunks.len()
                ),
            ));
        }
        for c in 0..self.chunks.len() {
            let s0 = self.starts[c];
            let s1 = self.starts[c + 1];
            if s1 <= s0 {
                return Err(AuditError::new(
                    "ChunkedRows",
                    "starts",
                    Some(c),
                    format!("starts not strictly increasing: {s0} -> {s1}"),
                ));
            }
            let rows = s1 - s0;
            if rows > MAX_CHUNK_ROWS {
                return Err(AuditError::new(
                    "ChunkedRows",
                    "chunks",
                    Some(c),
                    format!("chunk holds {rows} rows > MAX_CHUNK_ROWS = {MAX_CHUNK_ROWS}"),
                ));
            }
            if self.chunks[c].len() != rows * self.width {
                return Err(AuditError::new(
                    "ChunkedRows",
                    "chunks",
                    Some(c),
                    format!(
                        "chunk buffer length {} != {rows} rows × width {}",
                        self.chunks[c].len(),
                        self.width
                    ),
                ));
            }
            if self.dirty[c] && Arc::strong_count(&self.chunks[c]) != 1 {
                return Err(AuditError::new(
                    "ChunkedRows",
                    "dirty",
                    Some(c),
                    format!(
                        "dirty chunk shared ({} owners) — COW invariant broken",
                        Arc::strong_count(&self.chunks[c])
                    ),
                ));
            }
        }
        if self.starts[self.chunks.len()] != self.n_rows {
            return Err(AuditError::new(
                "ChunkedRows",
                "starts",
                None,
                format!(
                    "starts table ends at {} but n_rows = {}",
                    self.starts[self.chunks.len()],
                    self.n_rows
                ),
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic rope with row i holding [i·w, i·w+1, ...).
    fn ramp(width: usize, rows: usize) -> ChunkedRows {
        let mut r = ChunkedRows::zeros(width, rows);
        for i in 0..rows {
            for (o, v) in r.row_mut(i).iter_mut().enumerate() {
                *v = (i * width + o) as f64;
            }
        }
        r
    }

    fn flat_ramp(width: usize, rows: usize) -> Vec<f64> {
        (0..rows * width).map(|x| x as f64).collect()
    }

    #[test]
    fn zeros_rows_and_lookup_roundtrip() {
        for rows in [0usize, 1, CHUNK_ROWS - 1, CHUNK_ROWS, CHUNK_ROWS + 1, 300] {
            let r = ramp(3, rows);
            assert_eq!(r.n_rows(), rows);
            assert!(r.audit().is_ok(), "rows={rows}");
            assert_eq!(r.to_flat(), flat_ramp(3, rows), "rows={rows}");
            let mut cur = r.cursor();
            for i in (0..rows).rev() {
                assert_eq!(r.row(i)[0], (i * 3) as f64);
                assert_eq!(r.row_at(&mut cur, i)[2], (i * 3 + 2) as f64);
            }
        }
    }

    #[test]
    fn append_fills_tail_then_seals() {
        let mut r = ramp(2, CHUNK_ROWS - 1);
        assert_eq!(r.num_chunks(), 1);
        // Appending exactly one row fills the tail chunk to the seal point.
        r.append_zero_rows(1);
        assert_eq!(r.num_chunks(), 1);
        assert_eq!(r.n_rows(), CHUNK_ROWS);
        // The next append must open a fresh chunk, not grow the sealed one.
        r.append_zero_rows(1);
        assert_eq!(r.num_chunks(), 2);
        assert_eq!(r.n_rows(), CHUNK_ROWS + 1);
        assert_eq!(r.stats().memmove_bytes, 0, "appends never move rows");
        assert!(r.audit().is_ok());
        let mut want = flat_ramp(2, CHUNK_ROWS - 1);
        want.extend_from_slice(&[0.0; 4]);
        assert_eq!(r.to_flat(), want);
    }

    #[test]
    fn splice_matches_flat_reference_and_touches_one_chunk() {
        let rows = 3 * CHUNK_ROWS;
        let w = 2;
        let r0 = ramp(w, rows);
        // Insert two rows into the middle chunk and one at the very front of
        // the last chunk — a splice straddling a chunk seam.
        for positions in [
            vec![CHUNK_ROWS + 5],
            vec![CHUNK_ROWS, CHUNK_ROWS + 1],
            vec![2 * CHUNK_ROWS],
            vec![0],
            vec![rows, rows + 1], // pure appends
        ] {
            let mut r = r0.clone();
            let before = r.stats();
            r.insert_zero_rows(&positions);
            assert!(r.audit().is_ok(), "{positions:?}");
            // Flat reference: splice into a plain Vec.
            let mut flat = flat_ramp(w, rows);
            for &p in &positions {
                flat.splice(p * w..p * w, std::iter::repeat_n(0.0, w));
            }
            assert_eq!(r.to_flat(), flat, "{positions:?}");
            let delta = r.stats().memmove_bytes - before.memmove_bytes;
            if positions[0] >= rows {
                assert_eq!(delta, 0, "append splice must not move rows");
            } else {
                assert!(
                    delta as usize <= MAX_CHUNK_ROWS * w * 8 * positions.len(),
                    "{positions:?}: moved {delta} bytes"
                );
            }
        }
    }

    #[test]
    fn splice_preserves_untouched_chunk_buffers() {
        let rows = 4 * CHUNK_ROWS;
        let mut r = ramp(2, rows);
        let snap = {
            r.mark_clean();
            r.clone()
        };
        // Splice into chunk 1: chunks 0, 2, 3 must still share buffers with
        // the snapshot (structural sharing), chunk 1 must not.
        r.insert_zero_rows(&[CHUNK_ROWS + 3]);
        let copied_before = r.stats().chunks_copied;
        // Writing a shared chunk COWs it exactly once.
        r.row_mut(0)[0] = -1.0;
        assert_eq!(r.stats().chunks_copied, copied_before + 1);
        r.row_mut(1)[0] = -2.0;
        assert_eq!(r.stats().chunks_copied, copied_before + 1, "second write is free");
        // The snapshot still reads the original bytes.
        assert_eq!(snap.row(0)[0], 0.0);
        assert_eq!(snap.row(CHUNK_ROWS + 3)[0], ((CHUNK_ROWS + 3) * 2) as f64);
        assert!(r.audit().is_ok());
        assert!(snap.audit().is_ok());
    }

    #[test]
    fn remove_matches_flat_reference() {
        let rows = 3 * CHUNK_ROWS;
        let w = 2;
        let r0 = ramp(w, rows);
        for positions in [
            vec![CHUNK_ROWS + 5],
            vec![CHUNK_ROWS, CHUNK_ROWS + 1],
            vec![0],
            vec![rows - 1],
            vec![0, CHUNK_ROWS + 3, rows - 1],
            (CHUNK_ROWS..2 * CHUNK_ROWS).collect::<Vec<_>>(), // whole middle chunk
        ] {
            let mut r = r0.clone();
            r.remove_rows(&positions);
            assert!(r.audit().is_ok(), "{positions:?}");
            // Flat reference: drain the removed rows from a plain Vec.
            let mut flat = flat_ramp(w, rows);
            for &p in positions.iter().rev() {
                flat.drain(p * w..(p + 1) * w);
            }
            assert_eq!(r.to_flat(), flat, "{positions:?}");
            assert_eq!(r.n_rows(), rows - positions.len());
        }
    }

    #[test]
    fn remove_drops_emptied_chunks_and_bounds_memmove() {
        let rows = 3 * CHUNK_ROWS;
        let w = 2;
        let mut r = ramp(w, rows);
        let chunks_before = r.num_chunks();
        let before = r.stats().memmove_bytes;
        // Removing every row of the middle chunk drops it outright: no rows
        // move and no empty chunk is left behind.
        r.remove_rows(&(CHUNK_ROWS..2 * CHUNK_ROWS).collect::<Vec<_>>());
        assert_eq!(r.num_chunks(), chunks_before - 1);
        assert_eq!(r.stats().memmove_bytes, before, "dropping a chunk moves nothing");
        assert!(r.audit().is_ok());
        // A mid-chunk removal moves at most the straddled chunk's tail.
        let before = r.stats().memmove_bytes;
        r.remove_rows(&[3]);
        let delta = (r.stats().memmove_bytes - before) as usize;
        assert!(delta <= MAX_CHUNK_ROWS * w * 8, "moved {delta} bytes");
    }

    #[test]
    fn remove_preserves_untouched_chunk_buffers() {
        let rows = 4 * CHUNK_ROWS;
        let mut r = ramp(2, rows);
        let snap = {
            r.mark_clean();
            r.clone()
        };
        // Remove from chunk 1: chunks 0, 2, 3 must still share buffers with
        // the snapshot; the snapshot keeps reading the original bytes.
        r.remove_rows(&[CHUNK_ROWS + 3]);
        assert_eq!(Arc::strong_count(&r.chunks[0]), 2);
        assert_eq!(Arc::strong_count(&r.chunks[2]), 2);
        assert_eq!(snap.row(CHUNK_ROWS + 3)[0], ((CHUNK_ROWS + 3) * 2) as f64);
        assert_eq!(r.row(CHUNK_ROWS + 3)[0], ((CHUNK_ROWS + 4) * 2) as f64);
        assert!(r.audit().is_ok());
        assert!(snap.audit().is_ok());
    }

    #[test]
    fn insert_then_remove_restores_flat_contents() {
        let rows = 2 * CHUNK_ROWS + 7;
        let r0 = ramp(3, rows);
        let mut r = r0.clone();
        r.insert_zero_rows(&[5, CHUNK_ROWS + 2]);
        r.remove_rows(&[5, CHUNK_ROWS + 2]);
        assert_eq!(r.to_flat(), r0.to_flat());
        assert_eq!(r.n_rows(), rows);
        assert!(r.audit().is_ok());
    }

    #[test]
    fn clone_of_dirty_rope_deep_copies_dirty_chunks_only() {
        let mut r = ramp(2, 3 * CHUNK_ROWS);
        r.mark_clean();
        r.row_mut(5)[0] = 42.0; // dirty chunk 0 (unique, so no COW copy)
        let c = r.clone();
        // Both sides satisfy dirty ⇒ unique.
        assert!(r.audit().is_ok());
        assert!(c.audit().is_ok());
        assert_eq!(c.row(5)[0], 42.0);
        // Writing the original's clean chunks now COWs (shared with clone)…
        let copied = r.stats().chunks_copied;
        r.row_mut(2 * CHUNK_ROWS)[0] = 7.0;
        assert_eq!(r.stats().chunks_copied, copied + 1);
        // …but its dirty chunk stayed unique: writing it is free.
        r.row_mut(5)[1] = 8.0;
        assert_eq!(r.stats().chunks_copied, copied + 1);
        assert_eq!(c.row(2 * CHUNK_ROWS)[0], ((2 * CHUNK_ROWS) * 2) as f64);
    }

    #[test]
    fn mark_clean_counts_and_clears() {
        let mut r = ramp(1, 2 * CHUNK_ROWS);
        let (d, total) = r.mark_clean();
        assert_eq!((d, total), (2, 2), "fresh chunks start dirty");
        let (d, _) = r.mark_clean();
        assert_eq!(d, 0);
        r.row_mut(0)[0] = 1.0;
        let (d, _) = r.mark_clean();
        assert_eq!(d, 1);
    }

    #[test]
    fn from_prefix_shares_whole_chunks_and_truncates_straddler() {
        let rows = 3 * CHUNK_ROWS + 10;
        let mut r = ramp(2, rows);
        r.mark_clean();
        let keep = CHUNK_ROWS + 7; // chunk 0 whole, chunk 1 truncated
        let p = r.from_prefix(keep, rows + 5);
        assert!(p.audit().is_ok());
        assert_eq!(p.n_rows(), rows + 5);
        let flat = p.to_flat();
        let want = flat_ramp(2, keep);
        assert_eq!(&flat[..keep * 2], &want[..], "prefix rows preserved");
        assert!(flat[keep * 2..].iter().all(|&v| v == 0.0), "tail zeroed");
        // Chunk 0 is shared with the source (3 would mean an extra owner).
        assert_eq!(Arc::strong_count(&p.chunks[0]), 2);
        // The truncated straddler is freshly owned.
        assert_eq!(Arc::strong_count(&p.chunks[1]), 1);
        // Counters carried over.
        assert_eq!(p.stats().memmove_bytes, r.stats().memmove_bytes);
        assert_eq!(p.stats().chunks_copied, r.stats().chunks_copied);
    }

    #[test]
    fn from_prefix_keep_zero_rows_of_source() {
        let mut r = ramp(3, 10);
        r.mark_clean();
        let p = r.from_prefix(10, 12);
        assert_eq!(p.n_rows(), 12);
        assert!(p.audit().is_ok());
        assert_eq!(&p.to_flat()[..30], &flat_ramp(3, 10)[..]);
    }

    #[test]
    fn map_in_place_unshares_everything() {
        let mut r = ramp(2, 2 * CHUNK_ROWS);
        r.mark_clean();
        let snap = r.clone();
        r.map_in_place(|v| *v *= 2.0);
        assert!(r.audit().is_ok());
        assert_eq!(r.stats().chunks_copied, 2);
        assert_eq!(snap.row(1)[0], 2.0, "snapshot unscathed");
        assert_eq!(r.row(1)[0], 4.0);
    }

    #[test]
    fn audit_flags_shared_dirty_chunk() {
        let mut r = ramp(1, 4);
        // Manufacture the broken state directly: dirty while shared.
        let extra = Arc::clone(&r.chunks[0]);
        r.dirty[0] = true;
        let e = r.audit().unwrap_err();
        assert_eq!(e.structure, "ChunkedRows");
        assert_eq!(e.field, "dirty");
        assert_eq!(e.index, Some(0));
        drop(extra);
        assert!(r.audit().is_ok());
    }

    #[test]
    fn audit_flags_inconsistent_starts_table() {
        let mut r = ramp(2, CHUNK_ROWS + 4);
        r.starts[1] += 1;
        let e = r.audit().unwrap_err();
        assert_eq!(e.structure, "ChunkedRows");
    }

    #[test]
    fn cursor_handles_random_jumps() {
        let r = ramp(1, 5 * CHUNK_ROWS);
        let mut cur = r.cursor();
        for &i in &[0usize, 4 * CHUNK_ROWS, 1, 5 * CHUNK_ROWS - 1, CHUNK_ROWS, 2] {
            assert_eq!(r.row_at(&mut cur, i)[0], i as f64);
        }
    }
}
