//! Linear-algebra substrate: banded matrices with LU solvers, small dense
//! matrices (LU / Cholesky / nullspace), permutations, and the selected
//! band-of-inverse of a symmetric banded matrix (paper Algorithm 5).

pub mod banded;
pub mod block_tridiag;
pub mod chunks;
pub mod dense;
pub mod perm;

pub use banded::{Banded, BandedLU, PatchOutcome, PatchPolicy, SpliceInfo};
pub use chunks::{ChunkedRows, RowCursor, StorageStats, CHUNK_ROWS, MAX_CHUNK_ROWS};
pub use dense::Dense;
pub use perm::Permutation;
