//! Small dense matrices: LU with partial pivoting, Cholesky, inverse,
//! nullspace. Used for the tiny per-KP coefficient systems (p ≤ 2ν+4), the
//! 2ν×2ν blocks of Algorithm 5, and the dense baselines / test oracles.

/// Row-major dense matrix.
#[derive(Clone, Debug)]
pub struct Dense {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Dense {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Dense { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m.set(i, i, 1.0);
        }
        m
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut m = Self::zeros(r, c);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), c);
            m.data[i * c..(i + 1) * c].copy_from_slice(row);
        }
        m
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[i * self.cols + j] = v;
    }

    #[inline]
    pub fn add(&mut self, i: usize, j: usize, v: f64) {
        self.data[i * self.cols + j] += v;
    }

    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn transpose(&self) -> Dense {
        let mut t = Dense::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t.set(j, i, self.get(i, j));
            }
        }
        t
    }

    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0; self.rows];
        for i in 0..self.rows {
            let row = self.row(i);
            let mut acc = 0.0;
            for j in 0..self.cols {
                acc += row[j] * x[j];
            }
            y[i] = acc;
        }
        y
    }

    pub fn matmul(&self, other: &Dense) -> Dense {
        assert_eq!(self.cols, other.rows);
        let mut out = Dense::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self.get(i, k);
                if a == 0.0 {
                    continue;
                }
                for j in 0..other.cols {
                    out.add(i, j, a * other.get(k, j));
                }
            }
        }
        out
    }

    /// `self + alpha * other`.
    pub fn add_scaled(&self, other: &Dense, alpha: f64) -> Dense {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let mut out = self.clone();
        for i in 0..self.data.len() {
            out.data[i] += alpha * other.data[i];
        }
        out
    }

    pub fn scale(&mut self, alpha: f64) {
        for v in &mut self.data {
            *v *= alpha;
        }
    }

    /// Solve `A x = b` via LU with partial pivoting. Panics if non-square.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let lu = DenseLU::factor(self);
        lu.solve(b)
    }

    /// Solve `A X = B` column-wise.
    pub fn solve_mat(&self, b: &Dense) -> Dense {
        let lu = DenseLU::factor(self);
        let mut out = Dense::zeros(self.rows, b.cols);
        for j in 0..b.cols {
            let col: Vec<f64> = (0..b.rows).map(|i| b.get(i, j)).collect();
            let x = lu.solve(&col);
            for i in 0..self.rows {
                out.set(i, j, x[i]);
            }
        }
        out
    }

    /// Dense inverse (for tests / tiny blocks).
    pub fn inverse(&self) -> Dense {
        self.solve_mat(&Dense::eye(self.rows))
    }

    /// `(log|det|, sign)` via LU.
    pub fn lu_logdet(&self) -> (f64, f64) {
        DenseLU::factor(self).logdet()
    }

    /// Cholesky factor `L` (lower) of an SPD matrix. Returns `None` if a
    /// non-positive pivot is met.
    pub fn cholesky(&self) -> Option<Dense> {
        assert_eq!(self.rows, self.cols);
        let n = self.rows;
        let mut l = Dense::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let mut s = self.get(i, j);
                for k in 0..j {
                    s -= l.get(i, k) * l.get(j, k);
                }
                if i == j {
                    if s <= 0.0 {
                        return None;
                    }
                    l.set(i, j, s.sqrt());
                } else {
                    l.set(i, j, s / l.get(j, j));
                }
            }
        }
        Some(l)
    }

    /// Solve `L y = b` (forward substitution) for lower-triangular `L`.
    pub fn forward_sub(&self, b: &[f64]) -> Vec<f64> {
        let n = self.rows;
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut s = b[i];
            for k in 0..i {
                s -= self.get(i, k) * y[k];
            }
            y[i] = s / self.get(i, i);
        }
        y
    }

    /// Solve `L^T x = b` (backward substitution) for lower-triangular `L`.
    pub fn backward_sub_t(&self, b: &[f64]) -> Vec<f64> {
        let n = self.rows;
        let mut x = vec![0.0; n];
        for i in (0..n).rev() {
            let mut s = b[i];
            for k in (i + 1)..n {
                s -= self.get(k, i) * x[k];
            }
            x[i] = s / self.get(i, i);
        }
        x
    }

    /// A unit-∞-norm vector spanning the (assumed 1-dimensional) nullspace of
    /// a `(m) × (m+1)` (or rank-deficient square) matrix, via Gaussian
    /// elimination with full pivoting. The free variable is back-substituted.
    pub fn nullspace_vector(&self) -> Vec<f64> {
        let m = self.rows;
        let n = self.cols;
        assert!(n >= 1);
        // Work on a copy with column permutation bookkeeping.
        let mut a = self.clone();
        let mut colperm: Vec<usize> = (0..n).collect();
        let rank_max = m.min(n);
        let mut rank = 0;
        for k in 0..rank_max {
            // Full pivot search in the remaining submatrix.
            let (mut pi, mut pj, mut best) = (k, k, 0.0f64);
            for i in k..m {
                for j in k..n {
                    let v = a.get(i, j).abs();
                    if v > best {
                        best = v;
                        pi = i;
                        pj = j;
                    }
                }
            }
            if best < 1e-300 {
                break;
            }
            // Swap rows k<->pi and columns k<->pj.
            if pi != k {
                for j in 0..n {
                    let t = a.get(k, j);
                    a.set(k, j, a.get(pi, j));
                    a.set(pi, j, t);
                }
            }
            if pj != k {
                for i in 0..m {
                    let t = a.get(i, k);
                    a.set(i, k, a.get(i, pj));
                    a.set(i, pj, t);
                }
                colperm.swap(k, pj);
            }
            let piv = a.get(k, k);
            for i in (k + 1)..m {
                let f = a.get(i, k) / piv;
                if f != 0.0 {
                    for j in k..n {
                        let v = a.get(i, j) - f * a.get(k, j);
                        a.set(i, j, v);
                    }
                }
            }
            rank += 1;
        }
        // Free variable: the first non-pivot column (index `rank`).
        assert!(rank < n, "matrix has full column rank; no nullspace");
        let mut x = vec![0.0; n];
        x[rank] = 1.0;
        for k in (0..rank).rev() {
            let mut s = 0.0;
            for j in (k + 1)..n {
                s += a.get(k, j) * x[j];
            }
            x[k] = -s / a.get(k, k);
        }
        // Undo the column permutation.
        let mut out = vec![0.0; n];
        for (pos, &orig) in colperm.iter().enumerate() {
            out[orig] = x[pos];
        }
        // Normalize to unit ∞-norm with a sign convention (first nonzero > 0).
        let mx = out.iter().fold(0.0f64, |m, v| m.max(v.abs()));
        if mx > 0.0 {
            let first = out.iter().find(|v| v.abs() > 1e-300 * mx).copied().unwrap_or(1.0);
            let s = if first < 0.0 { -1.0 / mx } else { 1.0 / mx };
            for v in &mut out {
                *v *= s;
            }
        }
        out
    }
}

/// LU factorization with partial pivoting for [`Dense`] square matrices.
pub struct DenseLU {
    n: usize,
    lu: Dense,
    piv: Vec<usize>,
    sign: f64,
}

impl DenseLU {
    pub fn factor(a: &Dense) -> Self {
        assert_eq!(a.rows, a.cols);
        let n = a.rows;
        let mut lu = a.clone();
        let mut piv = vec![0usize; n];
        let mut sign = 1.0;
        for k in 0..n {
            let mut p = k;
            let mut best = lu.get(k, k).abs();
            for r in (k + 1)..n {
                let v = lu.get(r, k).abs();
                if v > best {
                    best = v;
                    p = r;
                }
            }
            piv[k] = p;
            if p != k {
                sign = -sign;
                // Swap only columns k.. — prior L-multiplier columns stay with
                // their original rows (gbtrf convention), which is what the
                // interleaved swap-then-eliminate replay in `solve` expects.
                for j in k..n {
                    let t = lu.get(k, j);
                    lu.set(k, j, lu.get(p, j));
                    lu.set(p, j, t);
                }
            }
            let pivot = lu.get(k, k);
            if pivot == 0.0 {
                continue;
            }
            for r in (k + 1)..n {
                let m = lu.get(r, k) / pivot;
                lu.set(r, k, m);
                if m != 0.0 {
                    for j in (k + 1)..n {
                        let v = lu.get(r, j) - m * lu.get(k, j);
                        lu.set(r, j, v);
                    }
                }
            }
        }
        DenseLU { n, lu, piv, sign }
    }

    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        assert_eq!(b.len(), self.n);
        let mut x = b.to_vec();
        for k in 0..self.n {
            let p = self.piv[k];
            if p != k {
                x.swap(k, p);
            }
            let xk = x[k];
            if xk != 0.0 {
                for r in (k + 1)..self.n {
                    x[r] -= self.lu.get(r, k) * xk;
                }
            }
        }
        for k in (0..self.n).rev() {
            let mut acc = x[k];
            for j in (k + 1)..self.n {
                acc -= self.lu.get(k, j) * x[j];
            }
            x[k] = acc / self.lu.get(k, k);
        }
        x
    }

    pub fn logdet(&self) -> (f64, f64) {
        let mut ld = 0.0;
        let mut sign = self.sign;
        for k in 0..self.n {
            let d = self.lu.get(k, k);
            ld += d.abs().ln();
            if d < 0.0 {
                sign = -sign;
            }
        }
        (ld, sign)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn solve_roundtrip() {
        let a = Dense::from_rows(&[
            vec![4.0, 1.0, 0.5],
            vec![1.0, 3.0, -1.0],
            vec![0.5, -1.0, 5.0],
        ]);
        let x_true = vec![1.0, -2.0, 0.5];
        let b = a.matvec(&x_true);
        let x = a.solve(&b);
        for i in 0..3 {
            assert!((x[i] - x_true[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn cholesky_reconstructs() {
        let a = Dense::from_rows(&[
            vec![4.0, 1.0, 0.5],
            vec![1.0, 3.0, -1.0],
            vec![0.5, -1.0, 5.0],
        ]);
        let l = a.cholesky().unwrap();
        let llt = l.matmul(&l.transpose());
        for i in 0..3 {
            for j in 0..3 {
                assert!((llt.get(i, j) - a.get(i, j)).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn nullspace_of_wide_matrix() {
        // Rows: [1, 1, 1], [1, 2, 4] — nullspace spanned by (2, -3, 1).
        let a = Dense::from_rows(&[vec![1.0, 1.0, 1.0], vec![1.0, 2.0, 4.0]]);
        let v = a.nullspace_vector();
        let r = a.matvec(&v);
        assert!(r.iter().all(|x| x.abs() < 1e-12), "{v:?} -> {r:?}");
        assert!((v.iter().fold(0.0f64, |m, x| m.max(x.abs())) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn inverse_roundtrip() {
        let a = Dense::from_rows(&[vec![2.0, 1.0], vec![1.0, 3.0]]);
        let inv = a.inverse();
        let id = a.matmul(&inv);
        for i in 0..2 {
            for j in 0..2 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((id.get(i, j) - want).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn logdet_sign() {
        let a = Dense::from_rows(&[vec![0.0, 1.0], vec![1.0, 0.0]]); // det = -1
        let (ld, sign) = a.lu_logdet();
        assert!(ld.abs() < 1e-12);
        assert_eq!(sign, -1.0);
    }
}
