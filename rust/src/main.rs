//! `addgp` CLI — the leader entrypoint.
//!
//! ```text
//! addgp serve [--addr 127.0.0.1:7878] [--no-pjrt] [--lo -500] [--hi 500]
//! addgp bo    [--fn schwefel|rastrigin] [--d 10] [--budget 300] [--warmup 100]
//! addgp selfcheck
//! ```
//!
//! (Hand-rolled argument parsing — clap is unavailable offline.)

use addgp::bo::run::{run_bo, BoConfig};
use addgp::bo::testfns::{self, NoisyObjective};
use addgp::coordinator::server::Server;
use addgp::ensure;
use addgp::gp::model::{AdditiveGP, AdditiveGpConfig};
use addgp::util::error::Result;

fn arg_value(args: &[String], key: &str) -> Option<String> {
    args.iter().position(|a| a == key).and_then(|i| args.get(i + 1).cloned())
}

fn flag(args: &[String], key: &str) -> bool {
    args.iter().any(|a| a == key)
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(|s| s.as_str()) {
        Some("serve") => {
            let addr = arg_value(&args, "--addr").unwrap_or_else(|| "127.0.0.1:7878".into());
            let lo = arg_value(&args, "--lo").and_then(|v| v.parse().ok()).unwrap_or(-500.0);
            let hi = arg_value(&args, "--hi").and_then(|v| v.parse().ok()).unwrap_or(500.0);
            let use_pjrt = !flag(&args, "--no-pjrt");
            let server = Server::bind(&addr, use_pjrt, lo, hi)?;
            println!("addgp coordinator listening on {}", server.local_addr());
            server.serve()?;
        }
        Some("bo") => {
            let d: usize = arg_value(&args, "--d").and_then(|v| v.parse().ok()).unwrap_or(10);
            let budget =
                arg_value(&args, "--budget").and_then(|v| v.parse().ok()).unwrap_or(300);
            let warmup =
                arg_value(&args, "--warmup").and_then(|v| v.parse().ok()).unwrap_or(100);
            let fname = arg_value(&args, "--fn").unwrap_or_else(|| "schwefel".into());
            let (f, lo, hi): (fn(&[f64]) -> f64, f64, f64) = match fname.as_str() {
                "rastrigin" => {
                    (testfns::rastrigin, testfns::RASTRIGIN_LO, testfns::RASTRIGIN_HI)
                }
                _ => (testfns::schwefel, testfns::SCHWEFEL_LO, testfns::SCHWEFEL_HI),
            };
            let obj = NoisyObjective::new(&f, 1.0);
            let mut gpcfg = AdditiveGpConfig::default();
            gpcfg.omega0 = 10.0 / (hi - lo);
            let mut engine = AdditiveGP::new(gpcfg, d);
            let cfg = BoConfig { budget, warmup, lo, hi, ..Default::default() };
            let res = run_bo(&mut engine, &obj, d, &cfg);
            println!(
                "{fname} d={d}: best={:.4} at {:?} (model time {:.2}s)",
                res.best_y, res.best_x, res.model_time_s
            );
        }
        Some("selfcheck") => {
            // Tiny end-to-end: fit + predict.
            let mut gp = AdditiveGP::new(AdditiveGpConfig::default(), 2);
            let mut rng = addgp::util::Rng::new(1);
            for _ in 0..50 {
                let x = vec![rng.uniform_in(0.0, 4.0), rng.uniform_in(0.0, 4.0)];
                let y = x[0].sin() + x[1].cos() + 0.1 * rng.normal();
                gp.observe(&x, y);
            }
            let out = gp.predict(&[2.0, 2.0], true);
            println!("selfcheck: μ={:.4} s={:.4} ∇μ={:?}", out.mean, out.var, out.mean_grad);
            ensure!(out.var.is_finite() && out.var >= 0.0);
            println!("OK");
        }
        _ => {
            eprintln!("usage: addgp <serve|bo|selfcheck> [options]");
            std::process::exit(2);
        }
    }
    Ok(())
}
