//! "IP" — inducing-point baseline (paper §7): subset-of-regressors (SoR)
//! with `m = √n` inducing inputs chosen as a random subset of the training
//! data (the GPML `FITC/SoR` configuration the paper benchmarks against;
//! Burt et al. 2019 motivate `m = O(√n)` for Matérn-1/2).
//!
//! ```text
//! Q_m  = K_mn K_nm + σ² K_mm
//! μ(x) = k_m(x)ᵀ Q_m^{-1} K_mn y
//! s(x) = σ² k_m(x)ᵀ Q_m^{-1} k_m(x)
//! ```
//!
//! Fit is `O(n m²)`, prediction `O(m)` / `O(m²)`.

use crate::kernels::matern::{Matern, Nu};
use crate::linalg::Dense;
use crate::util::Rng;

/// Subset-of-regressors additive GP.
pub struct InducingGP {
    pub nu: Nu,
    pub omegas: Vec<f64>,
    pub sigma2_y: f64,
    /// Inducing inputs, row-major `m × D`.
    z: Vec<Vec<f64>>,
    /// Cholesky of `Q_m`.
    chol: Option<Dense>,
    /// `Q_m^{-1} K_mn y`.
    beta: Option<Vec<f64>>,
    n_train: usize,
    seed: u64,
}

impl InducingGP {
    pub fn new(nu: Nu, omega0: f64, sigma2_y: f64, d: usize, seed: u64) -> Self {
        InducingGP {
            nu,
            omegas: vec![omega0; d],
            sigma2_y,
            z: Vec::new(),
            chol: None,
            beta: None,
            n_train: 0,
            seed,
        }
    }

    fn kernels(&self) -> Vec<Matern> {
        self.omegas.iter().map(|&o| Matern::new(self.nu, o)).collect()
    }

    fn ksum(&self, ks: &[Matern], a: &[f64], b: &[f64]) -> f64 {
        ks.iter().enumerate().map(|(d, k)| k.k(a[d], b[d])).sum()
    }

    /// Fit with `m = ⌈√n⌉` inducing points sampled from the data rows.
    pub fn fit(&mut self, x: &[Vec<f64>], y: &[f64]) {
        let n = x.len();
        self.n_train = n;
        let m = (n as f64).sqrt().ceil() as usize;
        let mut rng = Rng::new(self.seed);
        // Sample m distinct row indices.
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..m {
            let j = i + rng.below(n - i);
            idx.swap(i, j);
        }
        self.z = idx[..m].iter().map(|&i| x[i].clone()).collect();

        let ks = self.kernels();
        // K_mn (m × n) and K_mm.
        let mut kmn = Dense::zeros(m, n);
        for i in 0..m {
            for j in 0..n {
                kmn.set(i, j, self.ksum(&ks, &self.z[i], &x[j]));
            }
        }
        let mut q = Dense::zeros(m, m);
        for i in 0..m {
            for j in 0..m {
                q.set(i, j, self.sigma2_y * self.ksum(&ks, &self.z[i], &self.z[j]));
            }
        }
        // Q += K_mn K_nm
        for i in 0..m {
            for j in 0..m {
                let mut acc = q.get(i, j);
                for t in 0..n {
                    acc += kmn.get(i, t) * kmn.get(j, t);
                }
                q.set(i, j, acc);
            }
        }
        // jitter for safety
        for i in 0..m {
            q.add(i, i, 1e-10 * q.get(i, i).abs().max(1.0));
        }
        let chol = q.cholesky().expect("Q_m must be SPD");
        let kmn_y: Vec<f64> = (0..m)
            .map(|i| (0..n).map(|t| kmn.get(i, t) * y[t]).sum())
            .collect();
        let beta = chol.backward_sub_t(&chol.forward_sub(&kmn_y));
        self.chol = Some(chol);
        self.beta = Some(beta);
    }

    fn km(&self, x: &[f64]) -> Vec<f64> {
        let ks = self.kernels();
        self.z.iter().map(|zi| self.ksum(&ks, zi, x)).collect()
    }

    /// SoR posterior mean and variance.
    pub fn predict(&self, x: &[f64]) -> (f64, f64) {
        let km = self.km(x);
        let beta = self.beta.as_ref().expect("fit first");
        let mu: f64 = km.iter().zip(beta).map(|(a, b)| a * b).sum();
        let chol = self.chol.as_ref().unwrap();
        let w = chol.forward_sub(&km);
        let var = self.sigma2_y * w.iter().map(|v| v * v).sum::<f64>();
        (mu, var.max(0.0))
    }

    pub fn m(&self) -> usize {
        self.z.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn approximates_smooth_function() {
        let mut rng = Rng::new(4);
        let n = 400;
        let x: Vec<Vec<f64>> =
            (0..n).map(|_| vec![rng.uniform_in(0.0, 5.0), rng.uniform_in(0.0, 5.0)]).collect();
        let y: Vec<f64> =
            x.iter().map(|r| r[0].sin() + (0.5 * r[1]).cos() + 0.05 * rng.normal()).collect();
        let mut gp = InducingGP::new(Nu::Half, 1.0, 0.05, 2, 7);
        gp.fit(&x, &y);
        assert_eq!(gp.m(), 20);
        let mut err = 0.0;
        for _ in 0..50 {
            let xt = vec![rng.uniform_in(0.5, 4.5), rng.uniform_in(0.5, 4.5)];
            let (mu, var) = gp.predict(&xt);
            err += (mu - (xt[0].sin() + (0.5 * xt[1]).cos())).abs();
            assert!(var.is_finite());
        }
        err /= 50.0;
        // Low-rank approximation: coarse but sane.
        assert!(err < 0.5, "mean abs err {err}");
    }
}
