//! "FGP" — the naive dense additive GP baseline (paper §7):
//! Cholesky of `Σ = Σ_d K_d + σ²I`, `O(n³)` fit, `O(n)` mean / `O(n²)`
//! variance per prediction. Also the exact oracle used by tests.

use crate::kernels::matern::{Matern, Nu};
use crate::linalg::Dense;

/// Dense additive-Matérn GP.
pub struct FullGP {
    pub nu: Nu,
    pub omegas: Vec<f64>,
    pub sigma2_y: f64,
    x_cols: Vec<Vec<f64>>,
    y: Vec<f64>,
    /// Cholesky factor of Σ.
    chol: Option<Dense>,
    alpha: Option<Vec<f64>>,
}

impl FullGP {
    pub fn new(nu: Nu, omega0: f64, sigma2_y: f64, d: usize) -> Self {
        FullGP {
            nu,
            omegas: vec![omega0; d],
            sigma2_y,
            x_cols: vec![Vec::new(); d],
            y: Vec::new(),
            chol: None,
            alpha: None,
        }
    }

    pub fn input_dim(&self) -> usize {
        self.x_cols.len()
    }

    pub fn n(&self) -> usize {
        self.y.len()
    }

    fn kernels(&self) -> Vec<Matern> {
        self.omegas.iter().map(|&o| Matern::new(self.nu, o)).collect()
    }

    /// Replace the data set (rows) and refit (`O(n³)`).
    pub fn fit(&mut self, x: &[Vec<f64>], y: &[f64]) {
        let d = self.input_dim();
        self.x_cols = vec![Vec::with_capacity(x.len()); d];
        for row in x {
            for (dd, &v) in row.iter().enumerate() {
                self.x_cols[dd].push(v);
            }
        }
        self.y = y.to_vec();
        self.refit();
    }

    /// Append one observation and refit.
    pub fn observe(&mut self, x: &[f64], y: f64) {
        for (d, &v) in x.iter().enumerate() {
            self.x_cols[d].push(v);
        }
        self.y.push(y);
        self.refit();
    }

    /// Rebuild Σ and its Cholesky.
    pub fn refit(&mut self) {
        let n = self.n();
        if n == 0 {
            self.chol = None;
            self.alpha = None;
            return;
        }
        let sig = self.sigma_matrix();
        let chol = sig.cholesky().expect("Σ must be SPD");
        let alpha = chol.backward_sub_t(&chol.forward_sub(&self.y));
        self.chol = Some(chol);
        self.alpha = Some(alpha);
    }

    fn sigma_matrix(&self) -> Dense {
        let n = self.n();
        let mut sig = Dense::zeros(n, n);
        for (d, k) in self.kernels().iter().enumerate() {
            let col = &self.x_cols[d];
            for i in 0..n {
                for j in 0..n {
                    sig.add(i, j, k.k(col[i], col[j]));
                }
            }
        }
        for i in 0..n {
            sig.add(i, i, self.sigma2_y);
        }
        sig
    }

    fn kvec(&self, x: &[f64]) -> Vec<f64> {
        let n = self.n();
        let ks = self.kernels();
        (0..n)
            .map(|i| {
                ks.iter().enumerate().map(|(d, k)| k.k(self.x_cols[d][i], x[d])).sum()
            })
            .collect()
    }

    /// Posterior mean and variance (eq. 1) — `O(n)` / `O(n²)`.
    pub fn predict(&self, x: &[f64]) -> (f64, f64) {
        let kv = self.kvec(x);
        let alpha = self.alpha.as_ref().expect("fit first");
        let mu: f64 = kv.iter().zip(alpha).map(|(a, b)| a * b).sum();
        let chol = self.chol.as_ref().unwrap();
        let w = chol.forward_sub(&kv);
        let kxx: f64 = self.kernels().iter().map(|k| k.k(0.0, 0.0)).sum();
        let var = (kxx - w.iter().map(|v| v * v).sum::<f64>()).max(0.0);
        (mu, var)
    }

    /// Gradient of (μ, s) — `O(n D)` + `O(n²)`.
    pub fn predict_grad(&self, x: &[f64]) -> (Vec<f64>, Vec<f64>) {
        let n = self.n();
        let d_in = self.input_dim();
        let ks = self.kernels();
        let alpha = self.alpha.as_ref().expect("fit first");
        let chol = self.chol.as_ref().unwrap();
        let kv = self.kvec(x);
        // Σ^{-1} k
        let sik = chol.backward_sub_t(&chol.forward_sub(&kv));
        let mut gmu = vec![0.0; d_in];
        let mut gs = vec![0.0; d_in];
        for d in 0..d_in {
            for i in 0..n {
                let dk = ks[d].dk_dx(self.x_cols[d][i], x[d]);
                gmu[d] += dk * alpha[i];
                gs[d] += -2.0 * dk * sik[i];
            }
        }
        (gmu, gs)
    }

    /// Exact NLL (eq. 2 up to constant).
    pub fn nll(&self) -> f64 {
        let chol = self.chol.as_ref().expect("fit first");
        let alpha = self.alpha.as_ref().unwrap();
        let quad: f64 = self.y.iter().zip(alpha).map(|(a, b)| a * b).sum();
        let mut logdet = 0.0;
        for i in 0..self.n() {
            logdet += chol.get(i, i).ln();
        }
        0.5 * (quad + 2.0 * logdet + self.n() as f64 * (2.0 * std::f64::consts::PI).ln())
    }

    /// Shared-ω MLE by golden-section search on `log ω` (the classic
    /// dense-GP training loop; `O(n³)` per evaluation).
    pub fn optimize_shared_omega(&mut self, lo: f64, hi: f64, iters: usize) -> f64 {
        let gr = (5.0f64.sqrt() - 1.0) / 2.0;
        let (mut a, mut b) = (lo.ln(), hi.ln());
        let eval = |s: &mut Self, t: f64| -> f64 {
            s.omegas.iter_mut().for_each(|o| *o = t.exp());
            s.refit();
            s.nll()
        };
        let mut c = b - gr * (b - a);
        let mut d = a + gr * (b - a);
        let mut fc = eval(self, c);
        let mut fd = eval(self, d);
        for _ in 0..iters {
            if fc < fd {
                b = d;
                d = c;
                fd = fc;
                c = b - gr * (b - a);
                fc = eval(self, c);
            } else {
                a = c;
                c = d;
                fc = fd;
                d = a + gr * (b - a);
                fd = eval(self, d);
            }
        }
        let t = 0.5 * (a + b);
        eval(self, t);
        t.exp()
    }

    pub fn data(&self) -> (&[Vec<f64>], &[f64]) {
        (&self.x_cols, &self.y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn interpolates_with_small_noise() {
        let mut rng = Rng::new(1);
        let n = 40;
        let x: Vec<Vec<f64>> =
            (0..n).map(|_| vec![rng.uniform_in(0.0, 5.0), rng.uniform_in(0.0, 5.0)]).collect();
        let y: Vec<f64> = x.iter().map(|r| r[0].sin() + (0.7 * r[1]).cos()).collect();
        let mut gp = FullGP::new(Nu::Half, 1.0, 1e-4, 2);
        gp.fit(&x, &y);
        for i in 0..5 {
            let (mu, var) = gp.predict(&x[i]);
            assert!((mu - y[i]).abs() < 0.05, "{mu} vs {}", y[i]);
            assert!(var < 0.05);
        }
    }

    #[test]
    fn gradient_matches_fd() {
        let mut rng = Rng::new(2);
        let n = 25;
        let x: Vec<Vec<f64>> =
            (0..n).map(|_| vec![rng.uniform_in(0.0, 4.0), rng.uniform_in(0.0, 4.0)]).collect();
        let y: Vec<f64> = x.iter().map(|r| (r[0] - r[1]).sin()).collect();
        let mut gp = FullGP::new(Nu::ThreeHalves, 1.2, 0.1, 2);
        gp.fit(&x, &y);
        let x0 = vec![1.3, 2.1];
        let (gmu, gs) = gp.predict_grad(&x0);
        let h = 1e-6;
        for d in 0..2 {
            let mut xp = x0.clone();
            xp[d] += h;
            let mut xm = x0.clone();
            xm[d] -= h;
            let (mp, sp) = gp.predict(&xp);
            let (mm, sm) = gp.predict(&xm);
            let fdm = (mp - mm) / (2.0 * h);
            let fds = (sp - sm) / (2.0 * h);
            assert!((fdm - gmu[d]).abs() < 1e-5 * fdm.abs().max(1.0));
            assert!((fds - gs[d]).abs() < 1e-4 * fds.abs().max(1.0), "{} vs {}", gs[d], fds);
        }
    }

    #[test]
    fn mle_moves_toward_data_scale() {
        let mut rng = Rng::new(3);
        let n = 35;
        let x: Vec<Vec<f64>> = (0..n).map(|_| vec![rng.uniform_in(0.0, 6.0)]).collect();
        let y: Vec<f64> = x.iter().map(|r| (1.0 * r[0]).sin() + 0.05 * rng.normal()).collect();
        let mut gp = FullGP::new(Nu::Half, 50.0, 0.01, 1);
        gp.fit(&x, &y);
        let nll_before = gp.nll();
        let omega = gp.optimize_shared_omega(1e-2, 1e2, 25);
        assert!(gp.nll() < nll_before);
        assert!(omega < 50.0);
    }
}
