//! The paper's §7 comparison methods, implemented from scratch:
//!
//! * [`full_gp`] — naive dense additive GP ("FGP", GPML-style `O(n³)`).
//! * [`inducing`] — subset-of-regressors inducing points ("IP", `m = √n`
//!   per Burt et al. 2019).
//! * [`statespace`] — per-dimension Matérn SDE Kalman/RTS smoother inside a
//!   back-fitting loop. Stands in for Gilboa et al.'s VBEM (whose reference
//!   implementation is unavailable); it is the same `O(n)`-per-iteration
//!   projected-additive family and exercises the identical back-fitting code
//!   path. Documented in DESIGN.md §4.

pub mod full_gp;
pub mod inducing;
pub mod statespace;

pub use full_gp::FullGP;
pub use inducing::InducingGP;
pub use statespace::StateSpaceBackfit;
