//! State-space back-fitting baseline (VBEM stand-in; see `baselines`
//! module docs and DESIGN.md §4).
//!
//! Each one-dimensional Matérn-1/2 (Ornstein–Uhlenbeck) component is an SDE
//! with exact discrete transition `a_i = e^{-ω Δ_i}`, process noise
//! `q_i = σ_f²(1 − a_i²)`. A Kalman filter + RTS smoother computes the
//! component posterior mean over the sorted inputs in `O(n)`; the classic
//! back-fitting loop (Hastie et al. 2009, Gilboa et al. 2013) cycles the
//! components on partial residuals. Posterior mean at an off-grid point is
//! exact by the OU bridge + Markov property:
//! `E[f(x)|data] = bridge(E[f(x_l)|data], E[f(x_r)|data])`.

use crate::linalg::Permutation;

/// One OU component over sorted inputs.
struct OuComponent {
    perm: Permutation,
    xs: Vec<f64>,
    omega: f64,
    /// Smoothed posterior means at `xs` (sorted order).
    smoothed: Vec<f64>,
}

impl OuComponent {
    fn new(points: &[f64], omega: f64) -> Self {
        let perm = Permutation::sorting(points);
        let xs = perm.apply_sort(points);
        OuComponent { perm, xs, omega, smoothed: vec![0.0; points.len()] }
    }

    /// Kalman filter + RTS smoother for observations `r` (data order) with
    /// noise variance `sigma2`; prior marginal variance `sigma2_f`.
    fn smooth(&mut self, r: &[f64], sigma2: f64, sigma2_f: f64) {
        let n = self.xs.len();
        let rs = self.perm.to_sorted(r);
        // Filter.
        let mut mf = vec![0.0; n]; // filtered means
        let mut pf = vec![0.0; n]; // filtered variances
        let mut mp = vec![0.0; n]; // predicted means
        let mut pp = vec![0.0; n]; // predicted variances
        let mut m_prev = 0.0;
        let mut p_prev = sigma2_f;
        for i in 0..n {
            let (m_pred, p_pred) = if i == 0 {
                (0.0, sigma2_f)
            } else {
                let a = (-self.omega * (self.xs[i] - self.xs[i - 1])).exp();
                (a * m_prev, a * a * p_prev + sigma2_f * (1.0 - a * a))
            };
            mp[i] = m_pred;
            pp[i] = p_pred;
            let s = p_pred + sigma2;
            let k = p_pred / s;
            m_prev = m_pred + k * (rs[i] - m_pred);
            p_prev = (1.0 - k) * p_pred;
            mf[i] = m_prev;
            pf[i] = p_prev;
        }
        // RTS smoother.
        let mut ms = vec![0.0; n];
        ms[n - 1] = mf[n - 1];
        let mut m_next = mf[n - 1];
        for i in (0..n - 1).rev() {
            let a = (-self.omega * (self.xs[i + 1] - self.xs[i])).exp();
            let g = pf[i] * a / pp[i + 1];
            let m_sm = mf[i] + g * (m_next - mp[i + 1]);
            ms[i] = m_sm;
            m_next = m_sm;
        }
        self.smoothed = ms;
    }

    /// Posterior-mean fitted values at the training inputs, data order.
    fn fitted(&self) -> Vec<f64> {
        self.perm.to_original(&self.smoothed)
    }

    /// Posterior mean at an arbitrary point via the OU bridge.
    fn predict(&self, x: f64) -> f64 {
        let n = self.xs.len();
        let j = crate::linalg::perm::lower_index(&self.xs, x);
        match j {
            None => {
                // Left of all data: E[f(x)|f(x_0)] = e^{-ω(x_0 - x)} m_0.
                self.smoothed[0] * (-self.omega * (self.xs[0] - x)).exp()
            }
            Some(j) if j + 1 >= n => {
                self.smoothed[n - 1] * (-self.omega * (x - self.xs[n - 1])).exp()
            }
            Some(j) => {
                // OU bridge between x_j and x_{j+1}:
                // E[f(x)|f_l, f_r] = w_l f_l + w_r f_r with
                // w_l = (e^{-ωδl} − e^{-ω(δl+2δr)}) / (1 − e^{-2ωΔ}) etc.
                let (xl, xr) = (self.xs[j], self.xs[j + 1]);
                let (dl, dr) = (x - xl, xr - x);
                let om = self.omega;
                let denom = 1.0 - (-2.0 * om * (xr - xl)).exp();
                let wl = ((-om * dl).exp() - (-om * (dl + 2.0 * dr)).exp()) / denom;
                let wr = ((-om * dr).exp() - (-om * (dr + 2.0 * dl)).exp()) / denom;
                wl * self.smoothed[j] + wr * self.smoothed[j + 1]
            }
        }
    }
}

/// Back-fitting additive model of OU components (posterior-mean only — the
/// mean is what Figure 5's RMSE measures; see module docs).
pub struct StateSpaceBackfit {
    comps: Vec<OuComponent>,
    pub sigma2_y: f64,
    pub sigma2_f: f64,
    pub sweeps: usize,
}

impl StateSpaceBackfit {
    /// Fit on rows `x` with `sweeps` back-fitting passes.
    pub fn fit(
        x: &[Vec<f64>],
        y: &[f64],
        omegas: &[f64],
        sigma2_y: f64,
        sweeps: usize,
    ) -> Self {
        let d = omegas.len();
        let n = y.len();
        let mut cols: Vec<Vec<f64>> = vec![Vec::with_capacity(n); d];
        for row in x {
            for (dd, &v) in row.iter().enumerate() {
                cols[dd].push(v);
            }
        }
        let mut comps: Vec<OuComponent> =
            cols.iter().zip(omegas).map(|(c, &o)| OuComponent::new(c, o)).collect();
        let sigma2_f = 1.0;
        // Back-fitting: cycle components on partial residuals.
        let mut fitted: Vec<Vec<f64>> = vec![vec![0.0; n]; d];
        for _ in 0..sweeps {
            for dd in 0..d {
                let mut r = vec![0.0; n];
                for i in 0..n {
                    let others: f64 =
                        (0..d).filter(|&o| o != dd).map(|o| fitted[o][i]).sum();
                    r[i] = y[i] - others;
                }
                comps[dd].smooth(&r, sigma2_y, sigma2_f);
                fitted[dd] = comps[dd].fitted();
            }
        }
        StateSpaceBackfit { comps, sigma2_y, sigma2_f, sweeps }
    }

    /// Posterior mean at `x`.
    pub fn predict_mean(&self, x: &[f64]) -> f64 {
        self.comps.iter().zip(x).map(|(c, &xd)| c.predict(xd)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// The OU smoother must match the dense GP posterior mean for D=1.
    #[test]
    fn d1_matches_dense_gp() {
        use crate::kernels::matern::{Matern, Nu};
        let mut rng = Rng::new(5);
        let n = 30;
        let xs: Vec<f64> = rng.uniform_vec(n, 0.0, 5.0);
        let y: Vec<f64> = xs.iter().map(|&v| (1.1 * v).sin() + 0.1 * rng.normal()).collect();
        let x_rows: Vec<Vec<f64>> = xs.iter().map(|&v| vec![v]).collect();
        let omega = 1.3;
        let sigma2 = 0.3;
        let model = StateSpaceBackfit::fit(&x_rows, &y, &[omega], sigma2, 1);

        let kern = Matern::new(Nu::Half, omega);
        let mut sig = kern.gram(&xs);
        for i in 0..n {
            sig.add(i, i, sigma2);
        }
        let alpha = sig.solve(&y);
        for t in 0..10 {
            let xq = 0.3 + 0.45 * t as f64;
            let want: f64 =
                xs.iter().zip(&alpha).map(|(&xi, &a)| kern.k(xi, xq) * a).sum();
            let got = model.predict_mean(&[xq]);
            assert!(
                (got - want).abs() < 1e-8 * want.abs().max(1.0),
                "x={xq}: {got} vs {want}"
            );
        }
    }

    /// Back-fitting recovers an additive signal in 2-D.
    #[test]
    fn backfit_recovers_additive_signal() {
        let mut rng = Rng::new(6);
        let n = 300;
        let x: Vec<Vec<f64>> =
            (0..n).map(|_| vec![rng.uniform_in(0.0, 5.0), rng.uniform_in(0.0, 5.0)]).collect();
        let f = |r: &[f64]| r[0].sin() + 0.7 * (1.3 * r[1]).cos();
        let y: Vec<f64> = x.iter().map(|r| f(r) + 0.1 * rng.normal()).collect();
        let model = StateSpaceBackfit::fit(&x, &y, &[1.0, 1.0], 0.1, 10);
        let mut err = 0.0;
        for _ in 0..50 {
            let xt = vec![rng.uniform_in(0.5, 4.5), rng.uniform_in(0.5, 4.5)];
            err += (model.predict_mean(&xt) - f(&xt)).abs();
        }
        err /= 50.0;
        assert!(err < 0.25, "mean abs err {err}");
    }
}
