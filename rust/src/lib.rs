//! # addgp — Additive Gaussian Processes by Sparse Matrices
//!
//! A production reproduction of Zou, Chen & Ding (2023), *"Representing
//! Additive Gaussian Processes by Sparse Matrices"* (stat.ML).
//!
//! The paper shows that for additive Matérn GPs with half-integer smoothness,
//! every per-dimension covariance matrix factors as a banded matrix times the
//! inverse of a banded matrix (the *Kernel Packet* factorization, Algorithm
//! 2), and so do the ω-derivatives (*generalized* Kernel Packets, Algorithm
//! 3). This reduces the posterior mean, posterior variance, log-likelihood
//! and all their gradients to sparse banded algebra plus a back-fitting
//! iteration (Algorithm 4) — `O(n log n)` training and `O(log n)`→`O(1)`
//! acquisition evaluation inside Bayesian optimization (§6).
//!
//! ## Crate layout
//!
//! * [`linalg`] — banded/dense linear-algebra substrate, including the
//!   selected band-of-inverse (Algorithm 5) and banded row/col insertion.
//! * [`kernels`] — Matérn kernels and the KP / generalized-KP
//!   factorizations, incrementally extendable by one point at a time.
//! * [`gp`] — the additive-GP engine: back-fitting solver (with
//!   warm-started PCG), posterior, likelihood + gradients (Algorithms
//!   6–8), MLE training, the incremental [`gp::FitState`] layer, and the
//!   [`AdditiveGP`] façade.
//! * [`baselines`] — dense full GP ("FGP"), inducing points ("IP"), and a
//!   state-space back-fitting baseline (VBEM stand-in).
//! * [`bo`] — Bayesian optimization: acquisitions with sparse-window
//!   gradients, the `O(1)`-step searcher, the Algorithm 1 loop
//!   (observe-per-sample), and the paper's Schwefel/Rastrigin test
//!   functions.
//! * [`runtime`] — PJRT loader/executor for the AOT-compiled JAX/Pallas
//!   batched acquisition kernel (`artifacts/*.hlo.txt`); offline builds use
//!   the graceful [`runtime::xla`] stub.
//! * [`coordinator`] — the serving layer: JSON-line protocol, model
//!   registry, and a **shared work-stealing worker pool** serving every
//!   model at once — per-model FIFO mutual exclusion for mutating commands,
//!   concurrent snapshot-backed `predict`/`suggest`/`stats` reads, dynamic
//!   PJRT predict batching pinned to the worker that compiled the
//!   executable, and incremental `observe`/`observe_batch` ingest
//!   (quickstart: `rust/src/coordinator/README.md`).
//! * [`check`] — structural invariant audits: every stateful structure
//!   implements [`check::Audit`] and, under the `strict-invariants` cargo
//!   feature, re-audits itself after every mutating operation (DESIGN.md
//!   §Invariants). The feature is on in CI test jobs and **off** in release
//!   builds, where the hooks compile to nothing. Repo-specific source
//!   hygiene (unwrap-free coordinator, hot-loop assertion coverage,
//!   HashMap-iteration determinism, `// SAFETY:` comments) is machine-
//!   checked by `cargo xtask lint`.
//! * [`util`] — offline-build substrates (PRNG, JSON, timing, errors).
//!
//! ## Quick start
//!
//! ```no_run
//! use addgp::{AdditiveGP, AdditiveGpConfig};
//!
//! let mut gp = AdditiveGP::new(AdditiveGpConfig::default(), 2);
//! let x = vec![vec![0.1, 0.2], vec![0.5, 0.9], vec![1.5, 0.3],
//!              vec![2.0, 2.0], vec![0.9, 1.4], vec![2.5, 0.1],
//!              vec![1.1, 2.2]];
//! let y = vec![0.3, 1.2, 0.9, -0.4, 1.0, 0.2, -0.1];
//! gp.fit(&x, &y);
//! let out = gp.predict(&[1.0, 1.0], true);
//! println!("μ = {}, s = {}", out.mean, out.var);
//!
//! // Sequential data is absorbed *incrementally* — a window-local KP patch
//! // plus a warm-started Algorithm 4 solve per point, no refit
//! // (DESIGN.md §FitState):
//! gp.observe(&[0.7, 1.8], 0.4);
//! let out = gp.predict(&[1.0, 1.0], false);
//! println!("updated s = {}", out.var);
//!
//! // Batches are first-class too: one band splice, one window-union KP
//! // re-solve and one prefix-reuse factor patch per dimension for the
//! // whole batch — append-ordered ingest never pays a linear LU sweep
//! // (§FitState "Sublinear LU patching") — with dimensions sharded across
//! // threads (§FitState "Batched inserts"):
//! let new_x = vec![vec![0.3, 0.8], vec![1.9, 1.1], vec![2.2, 0.6]];
//! let new_y = vec![0.7, -0.2, 0.5];
//! let path = gp.observe_batch(&new_x, &new_y);
//! println!("batch path: {}", path.as_str()); // "incremental"
//! ```
//!
//! ## Serving quick start — the typed protocol v3 client
//!
//! Over the wire, the same engine is driven through
//! [`coordinator::Client`] — a typed surface over the JSON-line protocol
//! (connect performs a versioned hello; every op returns
//! `Result<T, ProtocolError>`, never hand-parsed JSON):
//!
//! ```no_run
//! use addgp::coordinator::server::Server;
//! use addgp::coordinator::Client;
//!
//! # fn main() -> addgp::util::error::Result<()> {
//! let server = Server::bind("127.0.0.1:0", false, 0.0, 4.0)?;
//! let addr = server.local_addr();
//! std::thread::spawn(move || server.serve());
//!
//! let mut c = Client::connect(addr)?;
//! let model = c.create_model(2, 1, 1.0, 1.0)?;
//! c.observe_batch(model, &[vec![0.1, 0.2], vec![1.5, 0.9]], &[0.3, 1.2])?;
//! let p = c.predict(model, &[vec![1.0, 1.0]], 2.0, true)?;
//! println!("μ = {}, acq = {}", p.mu[0], p.acq[0]);
//! let s = c.stats(model)?;
//! println!("n = {}, pool workers = {}", s.n, s.pool.workers);
//! c.shutdown()?;
//! # Ok(())
//! # }
//! ```
//!
//! Read scale-out rides the v3 replication surface: a stateless
//! [`coordinator::Replica`] imports the writer's generation-numbered
//! posterior snapshots, subscribes to invalidation pushes, and serves
//! `predict`/`suggest` bit-identically to the home shard at any fan-out
//! (DESIGN.md §Replication; cluster quickstart:
//! `rust/src/coordinator/README.md`, demo: `examples/serve_cluster.rs`).

pub mod baselines;
pub mod bo;
pub mod check;
pub mod coordinator;
pub mod gp;
pub mod kernels;
pub mod linalg;
pub mod runtime;
pub mod util;

pub use gp::model::{AdditiveGP, AdditiveGpConfig};
pub use kernels::matern::{Matern, Nu};
