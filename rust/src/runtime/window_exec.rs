//! The compiled `window_acq` executable: load HLO text, compile on the PJRT
//! CPU client, and execute batches of gathered windows.
//!
//! Follows the `/opt/xla-example/load_hlo` pattern: HLO *text* interchange,
//! `return_tuple=True` on the python side, `to_tuple()` on this side.

use crate::anyhow;
use crate::ensure;
use crate::runtime::artifacts::ArtifactSpec;
use crate::runtime::xla;
use crate::util::error::{Context, Result};

/// A batch of gathered windows, exactly the L2 model's input signature
/// (`python/compile/model.py::batch_acq`). Row-major flattened.
#[derive(Clone, Debug)]
pub struct WindowBatch {
    /// Active rows (≤ spec.b); the rest is zero padding.
    pub rows: usize,
    pub phi: Vec<f32>,   // [B, D, W]
    pub dphi: Vec<f32>,  // [B, D, W]
    pub bwin: Vec<f32>,  // [B, D, W]
    pub cwin: Vec<f32>,  // [B, D, W, W]
    pub mwin: Vec<f32>,  // [B, D, W, D, W]
    pub kdiag: Vec<f32>, // [B]
    pub beta: f32,
}

impl WindowBatch {
    /// Zero-padded batch for a spec.
    pub fn zeros(spec: &ArtifactSpec, beta: f32) -> Self {
        let (b, d, w) = (spec.b, spec.d, spec.w);
        WindowBatch {
            rows: 0,
            phi: vec![0.0; b * d * w],
            dphi: vec![0.0; b * d * w],
            bwin: vec![0.0; b * d * w],
            cwin: vec![0.0; b * d * w * w],
            mwin: vec![0.0; b * d * w * d * w],
            kdiag: vec![0.0; b],
            beta,
        }
    }
}

/// Executable outputs (only the first `rows` entries are meaningful).
#[derive(Clone, Debug)]
pub struct WindowOutputs {
    pub mu: Vec<f32>,   // [B]
    pub svar: Vec<f32>, // [B]
    pub acq: Vec<f32>,  // [B]
    pub gacq: Vec<f32>, // [B, D]
}

/// A compiled PJRT executable for one `(D, W, B)` configuration.
pub struct WindowExecutable {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl WindowExecutable {
    /// Load + compile the artifact on a PJRT client.
    pub fn load(client: &xla::PjRtClient, spec: &ArtifactSpec) -> Result<Self> {
        let path = spec
            .path
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 artifact path"))?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compiling {}", spec.name))?;
        Ok(WindowExecutable { spec: spec.clone(), exe })
    }

    /// Dispatch one batch without waiting for the result. PJRT's `execute`
    /// enqueues the computation and returns device buffers immediately; the
    /// blocking host sync happens in [`PendingWindow::wait`]. This split
    /// lets the caller double-buffer: stage the *next* batch's window
    /// gathers on the host while this one executes on the device.
    pub fn submit(&self, batch: &WindowBatch) -> Result<PendingWindow> {
        let (b, d, w) = (self.spec.b as i64, self.spec.d as i64, self.spec.w as i64);
        let lit = |data: &[f32], dims: &[i64]| -> Result<xla::Literal> {
            let expect: i64 = dims.iter().product();
            ensure!(
                data.len() as i64 == expect,
                "shape mismatch: {} vs {:?}",
                data.len(),
                dims
            );
            Ok(xla::Literal::vec1(data).reshape(dims)?)
        };
        let args = [
            lit(&batch.phi, &[b, d, w])?,
            lit(&batch.dphi, &[b, d, w])?,
            lit(&batch.bwin, &[b, d, w])?,
            lit(&batch.cwin, &[b, d, w, w])?,
            lit(&batch.mwin, &[b, d, w, d, w])?,
            lit(&batch.kdiag, &[b])?,
            xla::Literal::scalar(batch.beta),
        ];
        let mut outer = self.exe.execute::<xla::Literal>(&args)?;
        ensure!(
            !outer.is_empty() && !outer[0].is_empty(),
            "executable returned no result buffers"
        );
        Ok(PendingWindow { result: outer.swap_remove(0).swap_remove(0) })
    }

    /// Execute one batch synchronously (`submit` + `wait`). `batch` tensors
    /// must match the spec's shapes.
    pub fn execute(&self, batch: &WindowBatch) -> Result<WindowOutputs> {
        self.submit(batch)?.wait()
    }
}

/// An in-flight [`WindowExecutable::submit`] dispatch. Dropping it without
/// calling [`PendingWindow::wait`] abandons the result (the device work may
/// still run to completion) — the clean fallback when a later submit in the
/// same predict fails.
pub struct PendingWindow {
    result: xla::Literal,
}

impl PendingWindow {
    /// Block on the device → host transfer and unpack the output tuple.
    pub fn wait(self) -> Result<WindowOutputs> {
        let host = self.result.to_literal_sync()?;
        let parts = host.to_tuple()?;
        ensure!(parts.len() == 4, "expected 4 outputs, got {}", parts.len());
        let mut it = parts.into_iter();
        Ok(WindowOutputs {
            mu: it.next().unwrap().to_vec::<f32>()?,
            svar: it.next().unwrap().to_vec::<f32>()?,
            acq: it.next().unwrap().to_vec::<f32>()?,
            gacq: it.next().unwrap().to_vec::<f32>()?,
        })
    }
}
