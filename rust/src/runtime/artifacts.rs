//! Artifact manifest: which AOT-compiled executables exist and their static
//! shapes. Written by `python/compile/aot.py` as `artifacts/manifest.json`.

use std::path::{Path, PathBuf};

use crate::anyhow;
use crate::util::error::{Context, Result};
use crate::util::Json;

/// One compiled `window_acq` configuration.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub kind: String,
    /// Input dimension D.
    pub d: usize,
    /// KP window width W = 2ν+1.
    pub w: usize,
    /// Batch size B (static).
    pub b: usize,
    pub path: PathBuf,
}

/// The parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct ArtifactManifest {
    pub artifacts: Vec<ArtifactSpec>,
}

impl ArtifactManifest {
    /// Load `manifest.json` from an artifacts directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json", dir.display()))?;
        let json = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        let arts = json
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| anyhow!("manifest missing 'artifacts'"))?;
        let mut artifacts = Vec::with_capacity(arts.len());
        for a in arts {
            let name = a
                .get("name")
                .and_then(|v| v.as_str())
                .ok_or_else(|| anyhow!("artifact missing name"))?
                .to_string();
            artifacts.push(ArtifactSpec {
                kind: a
                    .get("kind")
                    .and_then(|v| v.as_str())
                    .unwrap_or("window_acq")
                    .to_string(),
                d: a.get("d").and_then(|v| v.as_usize()).ok_or_else(|| anyhow!("missing d"))?,
                w: a.get("w").and_then(|v| v.as_usize()).ok_or_else(|| anyhow!("missing w"))?,
                b: a.get("b").and_then(|v| v.as_usize()).ok_or_else(|| anyhow!("missing b"))?,
                path: dir.join(&name),
                name,
            });
        }
        Ok(ArtifactManifest { artifacts })
    }

    /// Find the artifact for `(d, w)` with the smallest batch ≥ `want_b`
    /// (or the largest available batch if none is big enough).
    pub fn select(&self, kind: &str, d: usize, w: usize, want_b: usize) -> Option<&ArtifactSpec> {
        let mut candidates: Vec<&ArtifactSpec> = self
            .artifacts
            .iter()
            .filter(|a| a.kind == kind && a.d == d && a.w == w)
            .collect();
        candidates.sort_by_key(|a| a.b);
        candidates
            .iter()
            .find(|a| a.b >= want_b)
            .copied()
            .or_else(|| candidates.last().copied())
    }

    /// Default artifacts directory: `$ADDGP_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("ADDGP_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_written_manifest() {
        let dir = std::env::temp_dir().join(format!("addgp_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"artifacts":[
                {"name":"window_acq_D2_W2_B64.hlo.txt","kind":"window_acq","d":2,"w":2,"b":64},
                {"name":"window_acq_D2_W2_B16.hlo.txt","kind":"window_acq","d":2,"w":2,"b":16}
            ]}"#,
        )
        .unwrap();
        let m = ArtifactManifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        assert_eq!(m.select("window_acq", 2, 2, 10).unwrap().b, 16);
        assert_eq!(m.select("window_acq", 2, 2, 20).unwrap().b, 64);
        assert_eq!(m.select("window_acq", 2, 2, 100).unwrap().b, 64);
        assert!(m.select("window_acq", 3, 2, 1).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }
}
