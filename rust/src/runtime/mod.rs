//! PJRT runtime — loads the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`, see `python/compile/aot.py`) and executes them
//! from the rust hot path. Python is never on the request path.

pub mod artifacts;
pub mod window_exec;
pub mod xla;

pub use artifacts::{ArtifactManifest, ArtifactSpec};
pub use window_exec::{PendingWindow, WindowBatch, WindowExecutable, WindowOutputs};
