//! Offline stub of the PJRT `xla` binding surface used by this crate.
//!
//! The build image has no crate registry, so the real `xla` bindings cannot
//! be resolved as a Cargo dependency. This module mirrors the exact API
//! subset the runtime uses (`PjRtClient`, `HloModuleProto`, `XlaComputation`,
//! `Literal`, `PjRtLoadedExecutable`) and fails gracefully at *runtime*:
//! [`PjRtClient::cpu`] returns an error, so every caller falls back to the
//! native sparse engine (the coordinator's `use_pjrt` path degrades to
//! native-only, and the PJRT integration test/bench print a SKIP notice).
//!
//! To link the real backend: add the `xla` bindings to `Cargo.toml`, delete
//! this module, and replace `use crate::runtime::xla;` /
//! `use addgp::runtime::xla;` with `use xla;` — no other code changes; the
//! call sites are written against the real API.

use crate::util::error::{Error, Result};

fn unavailable() -> Error {
    Error::msg("PJRT unavailable: built with the offline xla stub (see runtime::xla docs)")
}

/// Stub of the PJRT CPU client. [`PjRtClient::cpu`] always errors.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(unavailable())
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

/// Stub of a parsed HLO module.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(unavailable())
    }
}

/// Stub of an XLA computation handle.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Stub of a host literal.
#[derive(Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn scalar(_v: f32) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }
}

/// Stub of a compiled executable; never constructible through the stub
/// client, so [`PjRtLoadedExecutable::execute`] is unreachable in practice.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<Literal>>> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_degrades_gracefully() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("nope.hlo.txt").is_err());
        let lit = Literal::vec1(&[1.0, 2.0]).reshape(&[2]).unwrap();
        assert!(lit.to_vec::<f32>().is_err());
    }
}
