//! Integration: the AOT-compiled JAX/Pallas `window_acq` executable, loaded
//! and run through the PJRT CPU client, must reproduce the native sparse
//! engine's posterior numbers (f32 tolerance).
//!
//! Requires `make artifacts` (skips with a message otherwise).

use addgp::bo::acquisition::Acquisition;
use addgp::gp::model::{AdditiveGP, AdditiveGpConfig};
use addgp::runtime::xla;
use addgp::runtime::{ArtifactManifest, WindowBatch, WindowExecutable};
use addgp::util::Rng;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = ArtifactManifest::default_dir();
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        None
    }
}

#[test]
fn pjrt_window_acq_matches_native() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        return;
    };
    let manifest = ArtifactManifest::load(&dir).unwrap();
    let Some(spec) = manifest.select("window_acq", 2, 2, 64) else {
        eprintln!("SKIP: no D=2 W=2 artifact");
        return;
    };
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            eprintln!("SKIP: PJRT client unavailable ({e})");
            return;
        }
    };
    let exe = WindowExecutable::load(&client, spec).unwrap();

    // Build a model and some queries.
    let mut cfg = AdditiveGpConfig::default();
    cfg.omega0 = 1.0;
    let mut gp = AdditiveGP::new(cfg, 2);
    let mut rng = Rng::new(42);
    for _ in 0..80 {
        let x = vec![rng.uniform_in(0.0, 4.0), rng.uniform_in(0.0, 4.0)];
        let y = x[0].sin() + (0.7 * x[1]).cos() + 0.1 * rng.normal();
        gp.observe(&x, y);
    }

    let beta = 2.0f64;
    let queries: Vec<Vec<f64>> =
        (0..10).map(|_| vec![rng.uniform_in(0.2, 3.8), rng.uniform_in(0.2, 3.8)]).collect();

    // Pack one PJRT batch.
    let (sd, sw) = (spec.d, spec.w);
    let mut batch = WindowBatch::zeros(spec, beta as f32);
    batch.rows = queries.len();
    for (bi, x) in queries.iter().enumerate() {
        let qw = gp.gather_windows(x);
        assert_eq!(qw.w_max, sw);
        for di in 0..sd {
            for wi in 0..sw {
                let src = di * sw + wi;
                let dst = (bi * sd + di) * sw + wi;
                batch.phi[dst] = qw.phi[src] as f32;
                batch.dphi[dst] = qw.dphi[src] as f32;
                batch.bwin[dst] = qw.bwin[src] as f32;
                for wj in 0..sw {
                    batch.cwin[dst * sw + wj] = qw.cwin[src * sw + wj] as f32;
                }
                for dj in 0..sd {
                    for wj in 0..sw {
                        let srcm = (src * sd + dj) * sw + wj;
                        let dstm =
                            ((bi * sd + di) * sw + wi) * sd * sw + dj * sw + wj;
                        batch.mwin[dstm] = qw.mwin[srcm] as f32;
                    }
                }
            }
        }
        batch.kdiag[bi] = qw.kdiag as f32;
    }
    let out = exe.execute(&batch).unwrap();

    // Native reference.
    let acq = Acquisition::LcbMin { beta };
    for (bi, x) in queries.iter().enumerate() {
        let native = gp.predict(x, true);
        let (aval, agrad) =
            acq.value_grad(native.mean, native.var, &native.mean_grad, &native.var_grad);
        let scale = native.mean.abs().max(1.0);
        assert!(
            (out.mu[bi] as f64 - native.mean).abs() < 1e-4 * scale,
            "row {bi} mu: pjrt {} vs native {}",
            out.mu[bi],
            native.mean
        );
        assert!(
            (out.svar[bi] as f64 - native.var).abs() < 1e-3 * native.var.max(0.1),
            "row {bi} svar: pjrt {} vs native {}",
            out.svar[bi],
            native.var
        );
        assert!(
            (out.acq[bi] as f64 - aval).abs() < 1e-3 * aval.abs().max(1.0),
            "row {bi} acq: pjrt {} vs native {aval}",
            out.acq[bi]
        );
        for d in 0..2 {
            let g = out.gacq[bi * 2 + d] as f64;
            assert!(
                (g - agrad[d]).abs() < 2e-3 * agrad[d].abs().max(0.5),
                "row {bi} gacq[{d}]: pjrt {g} vs native {}",
                agrad[d]
            );
        }
    }
    // Outputs exist for all B rows (padding included).
    assert_eq!(out.mu.len(), spec.b);
}

#[test]
fn manifest_covers_default_dimensions() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("SKIP: artifacts/ not built");
        return;
    };
    let manifest = ArtifactManifest::load(&dir).unwrap();
    for d in [2, 5, 10] {
        assert!(
            manifest.select("window_acq", d, 2, 64).is_some(),
            "missing default artifact for D={d}"
        );
    }
}
