//! Property-style randomized tests (the offline image has no `proptest`, so
//! this is a hand-rolled driver: many seeded random cases per property,
//! shrink-free but reproducible — failures print the seed).
//!
//! Properties cover the core mathematical invariants of the paper:
//! factorization identity, posterior consistency, SPD-ness, cache
//! transparency, protocol round-trips.

use addgp::gp::backfit::{BlockVec, GaussSeidel};
use addgp::gp::dim::DimFactor;
use addgp::gp::model::{AdditiveGP, AdditiveGpConfig};
use addgp::kernels::kp::KpFactorization;
use addgp::kernels::matern::{Matern, Nu};
use addgp::util::{Json, Rng};

const CASES: u64 = 12;

fn random_points(rng: &mut Rng, n: usize, lo: f64, hi: f64) -> Vec<f64> {
    rng.uniform_vec(n, lo, hi)
}

/// ∀ random designs: `A·K_sorted` has no mass outside the `ν−1/2` band.
#[test]
fn prop_kp_band_identity() {
    for seed in 0..CASES {
        let mut rng = Rng::new(0x100 + seed);
        let n = 12 + rng.below(30);
        let omega = 10f64.powf(rng.uniform_in(-1.2, 1.0));
        let nu = [Nu::Half, Nu::ThreeHalves][rng.below(2)];
        let pts = random_points(&mut rng, n, -3.0, 7.0);
        let kernel = Matern::new(nu, omega);
        let f = KpFactorization::new(&pts, kernel);
        let kd = kernel.gram(&f.xs);
        let prod = f.a.to_dense().matmul(&kd);
        let w = f.w();
        let mut max_out: f64 = 0.0;
        let mut max_in: f64 = 0.0;
        for i in 0..n {
            for j in 0..n {
                let v = prod.get(i, j).abs();
                if j + w > i && j < i + w {
                    max_in = max_in.max(v);
                } else {
                    max_out = max_out.max(v);
                }
            }
        }
        assert!(
            max_out < 1e-7 * max_in.max(1.0),
            "seed {seed}: n={n} ω={omega} {nu:?}: out {max_out:.2e} in {max_in:.2e}"
        );
    }
}

/// ∀ random inputs: the Algorithm-4 solve satisfies `M ṽ = v`.
#[test]
fn prop_backfit_solves_system() {
    for seed in 0..CASES {
        let mut rng = Rng::new(0x200 + seed);
        let n = 15 + rng.below(25);
        let dd = 1 + rng.below(4);
        let sigma2 = rng.uniform_in(0.3, 2.0);
        let dims: Vec<DimFactor> = (0..dd)
            .map(|_| {
                let pts = random_points(&mut rng, n, 0.0, 5.0);
                DimFactor::new(&pts, Matern::new(Nu::Half, rng.uniform_in(0.4, 2.5)), sigma2)
            })
            .collect();
        let gs = GaussSeidel::new(&dims, sigma2);
        let v: BlockVec = (0..dd).map(|_| rng.normal_vec(n)).collect();
        let (x, stats) = gs.solve(&v);
        assert!(stats.rel_residual < 1e-8, "seed {seed}: residual {}", stats.rel_residual);
        let back = gs.apply(&x);
        let scale = v
            .iter()
            .flat_map(|b| b.iter())
            .fold(0.0f64, |m, &t| m.max(t.abs()));
        for d in 0..dd {
            for i in 0..n {
                assert!(
                    (back[d][i] - v[d][i]).abs() < 1e-6 * scale,
                    "seed {seed} d={d} i={i}"
                );
            }
        }
    }
}

/// ∀ models and queries: variance ≥ 0 and shrinks when a point is observed
/// exactly at the query.
#[test]
fn prop_variance_positive_and_contracts() {
    for seed in 0..CASES {
        let mut rng = Rng::new(0x300 + seed);
        let d = 1 + rng.below(3);
        let mut cfg = AdditiveGpConfig::default();
        cfg.omega0 = rng.uniform_in(0.5, 2.0);
        cfg.sigma2_y = 0.2;
        let mut gp = AdditiveGP::new(cfg, d);
        let n = 30 + rng.below(30);
        for _ in 0..n {
            let x: Vec<f64> = (0..d).map(|_| rng.uniform_in(0.0, 4.0)).collect();
            let y: f64 = x.iter().map(|v| v.sin()).sum::<f64>() + 0.3 * rng.normal();
            gp.observe(&x, y);
        }
        let q: Vec<f64> = (0..d).map(|_| rng.uniform_in(0.5, 3.5)).collect();
        let before = gp.predict(&q, false).var;
        assert!(before >= 0.0, "seed {seed}: negative variance {before}");
        gp.observe(&q, q.iter().map(|v| v.sin()).sum::<f64>());
        let after = gp.predict(&q, false).var;
        assert!(
            after <= before + 1e-9,
            "seed {seed}: variance grew after observing at query: {before} -> {after}"
        );
    }
}

/// ∀ points: cached O(1) prediction equals the cold-cache prediction.
#[test]
fn prop_cache_transparent() {
    for seed in 0..6u64 {
        let mut rng = Rng::new(0x400 + seed);
        let mut cfg = AdditiveGpConfig::default();
        cfg.omega0 = 1.0;
        let mut gp = AdditiveGP::new(cfg, 2);
        for _ in 0..50 {
            let x = vec![rng.uniform_in(0.0, 4.0), rng.uniform_in(0.0, 4.0)];
            gp.observe(&x, x[0].cos() + x[1].sin());
        }
        let q = vec![rng.uniform_in(0.0, 4.0), rng.uniform_in(0.0, 4.0)];
        // 1st visit = single-solve path, 2nd = M̃ columns, 3rd = cache hits.
        // All three are PCG-based (tol 1e-10), so they agree to solver
        // tolerance, not to the last bit.
        let first = gp.predict(&q, true);
        let second = gp.predict(&q, true);
        let third = gp.predict(&q, true);
        assert!((first.mean - second.mean).abs() < 1e-12);
        assert!((first.var - second.var).abs() < 1e-7 * second.var.max(1e-3));
        for d in 0..2 {
            assert!(
                (first.var_grad[d] - second.var_grad[d]).abs()
                    < 1e-6 * second.var_grad[d].abs().max(1e-3),
                "seed {seed}"
            );
            assert!((second.var_grad[d] - third.var_grad[d]).abs() < 1e-12);
        }
    }
}

/// ∀ JSON values we emit: parse(print(v)) == v.
#[test]
fn prop_json_roundtrip() {
    for seed in 0..50u64 {
        let mut rng = Rng::new(0x500 + seed);
        let v = random_json(&mut rng, 3);
        let s = v.to_string();
        let back = Json::parse(&s).unwrap_or_else(|e| panic!("seed {seed}: {e} in {s}"));
        assert_eq!(v, back, "seed {seed}");
    }
}

fn random_json(rng: &mut Rng, depth: usize) -> Json {
    match if depth == 0 { rng.below(4) } else { rng.below(6) } {
        0 => Json::Null,
        1 => Json::Bool(rng.below(2) == 0),
        2 => Json::Num((rng.normal() * 100.0 * 8.0).round() / 8.0),
        3 => {
            let n = rng.below(8);
            Json::Str((0..n).map(|_| (b'a' + rng.below(26) as u8) as char).collect())
        }
        4 => Json::Arr((0..rng.below(4)).map(|_| random_json(rng, depth - 1)).collect()),
        _ => Json::Obj(
            (0..rng.below(4))
                .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                .collect(),
        ),
    }
}

/// ∀ sorted data and queries: the φ-window has ≤ 2ν+1 entries and matches
/// the dense evaluation (routing invariant behind the batcher).
#[test]
fn prop_window_sparsity() {
    for seed in 0..CASES {
        let mut rng = Rng::new(0x600 + seed);
        let n = 20 + rng.below(40);
        let pts = random_points(&mut rng, n, -2.0, 2.0);
        let nu = [Nu::Half, Nu::ThreeHalves][rng.below(2)];
        let f = KpFactorization::new(&pts, Matern::new(nu, 1.3));
        for _ in 0..5 {
            let x = rng.uniform_in(-2.5, 2.5);
            let (start, vals) = f.phi_window(x);
            assert!(vals.len() <= 2 * f.w(), "seed {seed}: window too wide");
            let dense = f.phi_full(x);
            for (i, &dv) in dense.iter().enumerate() {
                let wv = if i >= start && i < start + vals.len() {
                    vals[i - start]
                } else {
                    0.0
                };
                assert!((dv - wv).abs() < 1e-9, "seed {seed} i={i}");
            }
        }
    }
}
