//! Wire-format compatibility pins for the coordinator protocol (ISSUE 5).
//!
//! Every `Request` variant is parsed from a golden JSON line and every
//! `Response` variant is serialized and compared against a golden JSON
//! object (key-set *and* values, order-insensitive via the canonical
//! `Json::Obj` B-tree), so scheduler refactors cannot silently change what
//! clients see on the wire. When a field is added deliberately (like the
//! `pool_*` stats fields in the shared worker-pool rewrite), the golden
//! here must be updated in the same PR — that is the point.

use addgp::coordinator::protocol::{Request, Response};
use addgp::coordinator::server::{Client, Server, MAX_LINE};
use addgp::util::Json;

/// Serialize `resp` (with optional id echo) and require exact equality with
/// the golden object — same keys, same values, nothing extra or missing.
fn pin_response(resp: Response, id: Option<f64>, golden: &str) {
    let got = resp.to_json(id);
    let want = Json::parse(golden).expect("golden parses");
    assert_eq!(got, want, "wire drift:\n got: {got}\nwant: {want}");
    // And the serialized text round-trips through the parser unchanged.
    let round = Json::parse(&got.to_string()).unwrap();
    assert_eq!(round, want);
}

#[test]
fn request_create_model() {
    let (r, id) =
        Request::parse(r#"{"op":"create_model","d":3,"nu2":3,"omega":0.5,"sigma2":2.0,"id":7}"#)
            .unwrap();
    assert_eq!(id, Some(7.0));
    assert_eq!(r, Request::CreateModel { d: 3, nu2: 3, omega: 0.5, sigma2: 2.0 });
    // Defaults: nu2=1, omega=1, sigma2=1, no id.
    let (r, id) = Request::parse(r#"{"op":"create_model","d":5}"#).unwrap();
    assert_eq!(id, None);
    assert_eq!(r, Request::CreateModel { d: 5, nu2: 1, omega: 1.0, sigma2: 1.0 });
}

#[test]
fn request_observe_and_batch() {
    let (r, _) =
        Request::parse(r#"{"op":"observe","model":2,"x":[0.5,-1.25],"y":3.5}"#).unwrap();
    assert_eq!(r, Request::Observe { model: 2, x: vec![0.5, -1.25], y: 3.5 });
    let (r, _) = Request::parse(
        r#"{"op":"observe_batch","model":9,"xs":[[1,2],[3,4]],"ys":[0.5,-0.5]}"#,
    )
    .unwrap();
    assert_eq!(
        r,
        Request::ObserveBatch {
            model: 9,
            xs: vec![vec![1.0, 2.0], vec![3.0, 4.0]],
            ys: vec![0.5, -0.5],
        }
    );
}

#[test]
fn request_fit_predict_suggest_stats_shutdown() {
    let (r, _) = Request::parse(r#"{"op":"fit","model":4,"steps":25}"#).unwrap();
    assert_eq!(r, Request::Fit { model: 4, steps: 25 });
    let (r, _) = Request::parse(r#"{"op":"fit","model":4}"#).unwrap();
    assert_eq!(r, Request::Fit { model: 4, steps: 10 }, "default steps");

    let (r, _) = Request::parse(
        r#"{"op":"predict","model":3,"xs":[[1,2]],"beta":1.5,"grad":true}"#,
    )
    .unwrap();
    assert_eq!(
        r,
        Request::Predict { model: 3, xs: vec![vec![1.0, 2.0]], beta: 1.5, grad: true }
    );
    let (r, _) = Request::parse(r#"{"op":"predict","model":3,"xs":[[1,2]]}"#).unwrap();
    assert_eq!(
        r,
        Request::Predict { model: 3, xs: vec![vec![1.0, 2.0]], beta: 2.0, grad: false },
        "default beta/grad"
    );

    let (r, _) = Request::parse(r#"{"op":"suggest","model":6,"beta":0.5}"#).unwrap();
    assert_eq!(r, Request::Suggest { model: 6, beta: 0.5 });
    let (r, _) = Request::parse(r#"{"op":"suggest","model":6}"#).unwrap();
    assert_eq!(r, Request::Suggest { model: 6, beta: 2.0 }, "default beta");

    let (r, _) = Request::parse(r#"{"op":"stats","model":1}"#).unwrap();
    assert_eq!(r, Request::Stats { model: 1 });
    let (r, _) = Request::parse(r#"{"op":"audit","model":5}"#).unwrap();
    assert_eq!(r, Request::Audit { model: 5 });
    assert!(Request::parse(r#"{"op":"audit"}"#).is_err(), "audit requires model");
    let (r, _) = Request::parse(r#"{"op":"shutdown"}"#).unwrap();
    assert_eq!(r, Request::Shutdown);
}

#[test]
fn request_errors_are_stable() {
    assert!(Request::parse("garbage").is_err());
    assert!(Request::parse(r#"{"d":2}"#).is_err(), "missing op");
    assert!(Request::parse(r#"{"op":"nope"}"#).is_err(), "unknown op");
    assert!(Request::parse(r#"{"op":"observe","x":[1],"y":2}"#).is_err(), "missing model");
    assert!(Request::parse(r#"{"op":"observe","model":1,"y":2}"#).is_err(), "missing x");
    assert!(Request::parse(r#"{"op":"observe","model":1,"x":[1]}"#).is_err(), "missing y");
    assert!(
        Request::parse(r#"{"op":"observe_batch","model":1,"xs":[3],"ys":[1]}"#).is_err(),
        "bad row"
    );
    assert!(Request::parse(r#"{"op":"create_model"}"#).is_err(), "missing d");
}

#[test]
fn response_ok_error_created() {
    pin_response(Response::Ok, None, r#"{"ok":true}"#);
    pin_response(Response::Ok, Some(3.0), r#"{"id":3,"ok":true}"#);
    pin_response(
        Response::Error("boom \"quoted\"".into()),
        Some(1.0),
        r#"{"id":1,"ok":false,"error":"boom \"quoted\""}"#,
    );
    pin_response(Response::ModelCreated { model: 12 }, None, r#"{"ok":true,"model":12}"#);
}

#[test]
fn response_observed_variants() {
    pin_response(
        Response::Observed { n: 41, factor_patched: 4, factor_resweep: 0 },
        Some(9.0),
        r#"{"id":9,"ok":true,"n":41,"factor_patched":4,"factor_resweep":0}"#,
    );
    pin_response(
        Response::BatchObserved {
            n: 128,
            path: "incremental",
            factor_patched: 12,
            factor_resweep: 1,
        },
        None,
        r#"{"ok":true,"n":128,"path":"incremental","factor_patched":12,"factor_resweep":1}"#,
    );
}

#[test]
fn response_prediction_and_suggestion() {
    pin_response(
        Response::Prediction {
            mu: vec![1.0, -2.5],
            svar: vec![0.5, 0.25],
            acq: vec![0.2, 0.1],
            gacq: vec![vec![0.1, -0.2], vec![0.3, 0.4]],
            path: "pjrt",
        },
        Some(4.0),
        r#"{"id":4,"ok":true,"mu":[1,-2.5],"svar":[0.5,0.25],"acq":[0.2,0.1],
            "gacq":[[0.1,-0.2],[0.3,0.4]],"path":"pjrt"}"#,
    );
    pin_response(
        Response::Prediction {
            mu: vec![1.0],
            svar: vec![0.5],
            acq: vec![0.2],
            gacq: Vec::new(),
            path: "native",
        },
        None,
        r#"{"ok":true,"mu":[1],"svar":[0.5],"acq":[0.2],"gacq":[],"path":"native"}"#,
    );
    pin_response(
        Response::Suggestion { x: vec![0.25, 3.75] },
        None,
        r#"{"ok":true,"x":[0.25,3.75]}"#,
    );
}

/// The full stats surface, including the shared worker-pool fields added by
/// the scheduler rewrite (`pool_workers`/`pool_busy`/`pool_queue_depth`/
/// `pool_steals`), the chunked-COW band-storage counters
/// (`memmove_bytes`/`chunks_copied`/`chunks_shared`), and the durability /
/// degradation fields added with the mutation journal
/// (`recoveries`/`degraded`/`journal_*`/`solve_*` — all additive, so old
/// clients keep parsing). Removing or renaming any of these is a breaking
/// wire change and must fail here.
#[test]
fn response_stats_with_pool_fields() {
    pin_response(
        Response::Stats {
            n: 1000,
            d: 4,
            omegas: vec![1.0, 0.5, 2.0, 1.5],
            cache_hits: 10,
            cache_misses: 3,
            pjrt_batches: 7,
            native_queries: 21,
            factor_patches: 90,
            factor_resweeps: 2,
            cache_truncations: 1,
            fallback_rebuilds: 0,
            pool_workers: 8,
            pool_busy: 3,
            pool_queue_depth: 5,
            pool_steals: 17,
            memmove_bytes: 4096,
            chunks_copied: 6,
            chunks_shared: 44,
            window_evictions: 12,
            window_occupancy: 1000,
            recoveries: 1,
            degraded: false,
            journal_appends: 250,
            journal_bytes: 16384,
            journal_checkpoints: 2,
            solve_cold_retries: 3,
            solve_refit_escalations: 1,
            // v3-only counters: deliberately absent from the flat golden
            // below — the legacy shape must not grow fields.
            snapshots_exported: 5,
            invalidations_sent: 40,
            subscribers: 2,
        },
        Some(2.0),
        r#"{"id":2,"ok":true,"n":1000,"d":4,"omegas":[1,0.5,2,1.5],
            "cache_hits":10,"cache_misses":3,"pjrt_batches":7,"native_queries":21,
            "factor_patches":90,"factor_resweeps":2,
            "cache_truncations":1,"fallback_rebuilds":0,
            "pool_workers":8,"pool_busy":3,"pool_queue_depth":5,"pool_steals":17,
            "memmove_bytes":4096,"chunks_copied":6,"chunks_shared":44,
            "window_evictions":12,"window_occupancy":1000,
            "recoveries":1,"degraded":false,
            "journal_appends":250,"journal_bytes":16384,"journal_checkpoints":2,
            "solve_cold_retries":3,"solve_refit_escalations":1}"#,
    );
}

/// Protocol v2 surface (sliding-window forgetting). A missing `v` is the
/// legacy v1 wire format and must stay parseable forever; the v2 ops parse
/// only under a declared `v: 2`; versions the server does not speak are
/// rejected with a stable, structured error.
#[test]
fn request_v2_forget_and_rolling_window() {
    let (r, id) =
        Request::parse(r#"{"op":"forget","model":2,"x":[0.5,-1.25],"v":2,"id":8}"#).unwrap();
    assert_eq!(id, Some(8.0));
    assert_eq!(r, Request::Forget { model: 2, x: vec![0.5, -1.25] });

    let (r, _) =
        Request::parse(r#"{"op":"forget_batch","model":9,"xs":[[1,2],[3,4]],"v":2}"#).unwrap();
    assert_eq!(
        r,
        Request::ForgetBatch { model: 9, xs: vec![vec![1.0, 2.0], vec![3.0, 4.0]] }
    );

    let (r, _) = Request::parse(
        r#"{"op":"rolling_window","model":5,"max_n":512,"max_age":100,"v":2}"#,
    )
    .unwrap();
    assert_eq!(r, Request::RollingWindow { model: 5, max_n: 512, max_age: Some(100) });
    let (r, _) =
        Request::parse(r#"{"op":"rolling_window","model":5,"max_n":0,"v":2}"#).unwrap();
    assert_eq!(
        r,
        Request::RollingWindow { model: 5, max_n: 0, max_age: None },
        "max_n=0 disables rolling mode; max_age defaults to None"
    );

    assert!(Request::parse(r#"{"op":"forget","model":2,"v":2}"#).is_err(), "missing x");
    assert!(
        Request::parse(r#"{"op":"forget_batch","model":2,"v":2}"#).is_err(),
        "missing xs"
    );
    assert!(
        Request::parse(r#"{"op":"rolling_window","model":2,"v":2}"#).is_err(),
        "missing max_n"
    );
}

/// Version gating is part of the wire contract: the rejection *text* is
/// pinned too, because clients branch on it to decide whether to downgrade.
#[test]
fn request_version_gating_is_stable() {
    // v1 ops parse identically with no `v`, `v: 1`, and `v: 2`.
    for frame in [
        r#"{"op":"stats","model":1}"#,
        r#"{"op":"stats","model":1,"v":1}"#,
        r#"{"op":"stats","model":1,"v":2}"#,
    ] {
        let (r, _) = Request::parse(frame).unwrap();
        assert_eq!(r, Request::Stats { model: 1 });
    }
    // A v2 op on a legacy (missing or explicit v1) frame is refused.
    let e = Request::parse(r#"{"op":"forget","model":1,"x":[1.0]}"#).unwrap_err();
    assert_eq!(e, "op 'forget' requires protocol v2 (request declared v1)");
    let e = Request::parse(r#"{"op":"forget_batch","model":1,"xs":[[1]],"v":1}"#).unwrap_err();
    assert_eq!(e, "op 'forget_batch' requires protocol v2 (request declared v1)");
    // A v3 op on a v2 frame is refused with the same structured shape.
    let e = Request::parse(r#"{"op":"snapshot","model":1,"v":2}"#).unwrap_err();
    assert_eq!(e, "op 'snapshot' requires protocol v3 (request declared v2)");
    let e = Request::parse(r#"{"op":"subscribe","model":1}"#).unwrap_err();
    assert_eq!(e, "op 'subscribe' requires protocol v3 (request declared v1)");
    let e = Request::parse(r#"{"op":"ping","v":2}"#).unwrap_err();
    assert_eq!(e, "op 'ping' requires protocol v3 (request declared v2)");
    // Versions above the server's ceiling fail loudly, naming the ceiling.
    let e = Request::parse(r#"{"op":"stats","model":1,"v":4}"#).unwrap_err();
    assert_eq!(e, "unsupported protocol version 4 (server speaks <= 3)");
    // Malformed versions are rejected before any op dispatch.
    assert!(Request::parse(r#"{"op":"stats","model":1,"v":0}"#).is_err());
    assert!(Request::parse(r#"{"op":"stats","model":1,"v":1.5}"#).is_err());
    assert!(Request::parse(r#"{"op":"stats","model":1,"v":"two"}"#).is_err());
}

/// The downdate mirror of `Observed`: post-forget size, how many
/// observations were actually released, and the factor patch/re-sweep delta.
#[test]
fn response_forgotten() {
    pin_response(
        Response::Forgotten { n: 40, removed: 1, factor_patched: 4, factor_resweep: 0 },
        Some(5.0),
        r#"{"id":5,"ok":true,"n":40,"removed":1,"factor_patched":4,"factor_resweep":0}"#,
    );
    pin_response(
        Response::Forgotten { n: 40, removed: 0, factor_patched: 0, factor_resweep: 0 },
        None,
        r#"{"ok":true,"n":40,"removed":0,"factor_patched":0,"factor_resweep":0}"#,
    );
}

/// The audit report surface (structural invariant audit, ISSUE 6): the
/// pass/fail flag, the deterministic walked-structure count, and the
/// violation rendered as `Structure.field[index]: detail` (empty on pass).
#[test]
fn response_audit_report() {
    pin_response(
        Response::AuditReport { passed: true, structures: 25, violation: String::new() },
        Some(6.0),
        r#"{"id":6,"ok":true,"passed":true,"structures":25,"violation":""}"#,
    );
    pin_response(
        Response::AuditReport {
            passed: false,
            structures: 25,
            violation: "Banded.data[3]: non-finite entry".into(),
        },
        None,
        r#"{"ok":true,"passed":false,"structures":25,
            "violation":"Banded.data[3]: non-finite entry"}"#,
    );
}

/// Protocol v3 request surface (snapshot-shipping read replicas): the
/// `snapshot` fetch with its optional `have_gen` delta marker, the
/// `subscribe` stream conversion, and the model-free `ping` hello.
#[test]
fn request_v3_snapshot_subscribe_ping() {
    let (r, id) =
        Request::parse(r#"{"op":"snapshot","model":7,"v":3,"id":2}"#).unwrap();
    assert_eq!(id, Some(2.0));
    assert_eq!(r, Request::Snapshot { model: 7, have_gen: None });
    let (r, _) =
        Request::parse(r#"{"op":"snapshot","model":7,"have_gen":41,"v":3}"#).unwrap();
    assert_eq!(r, Request::Snapshot { model: 7, have_gen: Some(41) });
    let (r, _) = Request::parse(r#"{"op":"subscribe","model":7,"v":3}"#).unwrap();
    assert_eq!(r, Request::Subscribe { model: 7 });
    let (r, _) = Request::parse(r#"{"op":"ping","v":3}"#).unwrap();
    assert_eq!(r, Request::Ping);
    assert!(Request::parse(r#"{"op":"snapshot","v":3}"#).is_err(), "snapshot needs model");
    assert!(Request::parse(r#"{"op":"subscribe","v":3}"#).is_err(), "subscribe needs model");
}

/// Protocol v3 response surface: the snapshot artifact reply (payload and
/// `unchanged` delta forms), the subscription ack, the invalidation push
/// event, and the `ping` hello.
#[test]
fn response_v3_replication_surface() {
    pin_response(
        Response::Snapshot { gen: 17, artifact: Some("00ff7a".into()) },
        Some(3.0),
        r#"{"id":3,"ok":true,"gen":17,"snapshot":"00ff7a"}"#,
    );
    pin_response(
        Response::Snapshot { gen: 17, artifact: None },
        None,
        r#"{"ok":true,"gen":17,"unchanged":true}"#,
    );
    pin_response(
        Response::Subscribed { gen: 9 },
        Some(1.0),
        r#"{"id":1,"ok":true,"subscribed":true,"gen":9}"#,
    );
    pin_response(
        Response::Invalidate { model: 4, gen: 10 },
        None,
        r#"{"ok":true,"event":"invalidate","model":4,"gen":10}"#,
    );
    pin_response(
        Response::Hello { version: 3 },
        Some(1.0),
        r#"{"id":1,"ok":true,"server_version":3}"#,
    );
}

/// The nested v3 `stats` shape — and the guarantee that the SAME response
/// value still serializes to the flat legacy shape for v1/v2 requests.
/// Both shapes are the wire contract; this is the pin.
#[test]
fn response_stats_v3_nested_sections() {
    let stats = Response::Stats {
        n: 1000,
        d: 4,
        omegas: vec![1.0, 0.5, 2.0, 1.5],
        cache_hits: 10,
        cache_misses: 3,
        pjrt_batches: 7,
        native_queries: 21,
        factor_patches: 90,
        factor_resweeps: 2,
        cache_truncations: 1,
        fallback_rebuilds: 0,
        pool_workers: 8,
        pool_busy: 3,
        pool_queue_depth: 5,
        pool_steals: 17,
        memmove_bytes: 4096,
        chunks_copied: 6,
        chunks_shared: 44,
        window_evictions: 12,
        window_occupancy: 1000,
        recoveries: 1,
        degraded: false,
        journal_appends: 250,
        journal_bytes: 16384,
        journal_checkpoints: 2,
        solve_cold_retries: 3,
        solve_refit_escalations: 1,
        snapshots_exported: 5,
        invalidations_sent: 40,
        subscribers: 2,
    };
    let nested = stats.to_json_v(Some(2.0), 3);
    let want = Json::parse(
        r#"{"id":2,"ok":true,"n":1000,"d":4,"omegas":[1,0.5,2,1.5],
            "solve":{"cache_hits":10,"cache_misses":3,"pjrt_batches":7,
                "native_queries":21,"factor_patches":90,"factor_resweeps":2,
                "cache_truncations":1,"fallback_rebuilds":0,
                "cold_retries":3,"refit_escalations":1},
            "storage":{"memmove_bytes":4096,"chunks_copied":6,"chunks_shared":44},
            "journal":{"appends":250,"bytes":16384,"checkpoints":2,
                "recoveries":1,"degraded":false},
            "pool":{"workers":8,"busy":3,"queue_depth":5,"steals":17},
            "window":{"evictions":12,"occupancy":1000},
            "replication":{"snapshots_exported":5,"invalidations_sent":40,
                "subscribers":2}}"#,
    )
    .unwrap();
    assert_eq!(nested, want, "v3 nested stats drift:\n got: {nested}\nwant: {want}");
    // v1/v2 requests get the flat legacy serialization, byte-for-byte what
    // `to_json` produces (the replication counters never leak into it).
    assert_eq!(stats.to_json_v(Some(2.0), 1), stats.to_json(Some(2.0)));
    assert_eq!(stats.to_json_v(Some(2.0), 2), stats.to_json(Some(2.0)));
    // Non-stats responses are version-invariant.
    let ok = Response::Ok;
    assert_eq!(ok.to_json_v(None, 3), ok.to_json(None));
}

// ---------------------------------------------------------------------------
// Live-server wire hardening (ISSUE 9): malformed input of any shape must
// come back as a structured `{"ok":false,"error":…}` on a connection that
// stays usable — never a panic, never a silent close — and the graceful-
// degradation error strings (`retryable:` deadline + load-shed markers) are
// part of the wire contract, pinned byte-for-byte because clients branch on
// them to decide whether to retry.
// ---------------------------------------------------------------------------

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

/// Boot a native-only server, keeping a handle to it (the `Arc` lets the
/// test reach `set_queue_limit`/`metrics_report` while `serve` runs).
fn boot() -> (Arc<Server>, std::net::SocketAddr) {
    let server = Arc::new(Server::bind("127.0.0.1:0", false, 0.0, 4.0).unwrap());
    let addr = server.local_addr();
    let srv = Arc::clone(&server);
    std::thread::spawn(move || {
        let _ = srv.serve();
    });
    (server, addr)
}

/// Read one reply line off a raw socket and require it to parse as JSON —
/// a torn or absent reply fails here, which is exactly the regression this
/// suite pins against.
fn read_reply(reader: &mut BufReader<TcpStream>) -> Json {
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.ends_with('\n'), "reply not newline-framed: {line:?}");
    Json::parse(&line).expect("reply must be structured JSON")
}

/// Garbage bytes, invalid UTF-8, an absurd-length line, and a bad
/// `deadline_ms` all get structured errors on the SAME connection, which
/// then serves a real request — the reader survives every malformed frame.
#[test]
fn malformed_wire_input_gets_structured_errors_on_a_live_connection() {
    let (_server, addr) = boot();
    let stream = TcpStream::connect(addr).unwrap();
    let mut w = stream.try_clone().unwrap();
    let mut r = BufReader::new(stream);

    // Garbage bytes that are not JSON.
    w.write_all(b"!!definitely not json!!\n").unwrap();
    let resp = read_reply(&mut r);
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false), "{resp}");
    assert!(!resp.get("error").unwrap().as_str().unwrap().is_empty());

    // Invalid UTF-8: decoded lossily, rejected by the parser — not a panic.
    w.write_all(&[0xff, 0xfe, b'{', 0x80, b'}', b'\n']).unwrap();
    let resp = read_reply(&mut r);
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false), "{resp}");

    // Absurd length: one byte over MAX_LINE. The frame is discarded up to
    // its newline and the error names the exact byte count — pinned.
    let n = MAX_LINE + 1;
    let mut big = vec![b'x'; n];
    big.push(b'\n');
    w.write_all(&big).unwrap();
    let resp = read_reply(&mut r);
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false), "{resp}");
    assert_eq!(
        resp.get("error").unwrap().as_str(),
        Some(format!("line too long ({n} bytes; limit {MAX_LINE}) — request discarded").as_str()),
        "{resp}"
    );

    // Non-positive deadline budget: structured parse error, pinned text.
    w.write_all(b"{\"op\":\"stats\",\"model\":0,\"deadline_ms\":0}\n").unwrap();
    let resp = read_reply(&mut r);
    assert_eq!(
        resp.get("error").unwrap().as_str(),
        Some("bad deadline_ms (want positive integer milliseconds)"),
        "{resp}"
    );

    // After all of that the SAME connection still serves a real request,
    // echoing its id — nothing was wedged or silently closed.
    w.write_all(b"{\"op\":\"create_model\",\"d\":2,\"id\":42}\n").unwrap();
    let resp = read_reply(&mut r);
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{resp}");
    assert_eq!(resp.get("id").unwrap().as_f64(), Some(42.0));

    w.write_all(b"{\"op\":\"shutdown\"}\n").unwrap();
    let resp = read_reply(&mut r);
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{resp}");
}

/// An expired `deadline_ms` budget returns the pinned `retryable:` error
/// (the late reply is dropped server-side) and the connection — and the
/// model — keep working afterwards.
#[test]
fn deadline_exceeded_is_a_pinned_retryable_error() {
    let (_server, addr) = boot();
    let mut c = Client::connect(addr).unwrap();
    let r = c.call(r#"{"op":"create_model","d":4,"nu2":5}"#).unwrap();
    let model = r.get("model").unwrap().as_usize().unwrap();

    // A batch big enough (n=2500, d=4, ν=5/2) that its activating refit
    // cannot possibly land inside a 1 ms budget.
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for i in 0..2500usize {
        let a = (i % 50) as f64 * 0.08;
        let b = (i / 50) as f64 * 0.08;
        xs.push(format!("[{a},{b},{},{}]", (a + b) * 0.5, (a * b).fract()));
        ys.push(format!("{}", a.sin() + b.cos()));
    }
    let req = format!(
        r#"{{"op":"observe_batch","model":{model},"xs":[{}],"ys":[{}],"deadline_ms":1}}"#,
        xs.join(","),
        ys.join(",")
    );
    let r = c.call(&req).unwrap();
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(false), "{r}");
    assert_eq!(
        r.get("error").unwrap().as_str(),
        Some("retryable: deadline exceeded after 1ms"),
        "{r}"
    );

    // The timed-out mutation still applies server-side (only the reply was
    // dropped); an undeadlined follow-up sees the ingested batch. Stats
    // serializes behind the batch on the engine lock, but poll in case the
    // probe wins the lock before the drain job starts.
    let mut n = 0;
    for _ in 0..500 {
        let r = c.call(&format!(r#"{{"op":"stats","model":{model}}}"#)).unwrap();
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
        n = r.get("n").unwrap().as_usize().unwrap();
        if n == 2500 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    assert_eq!(n, 2500, "a timed-out mutation must still apply server-side");
    let _ = c.call(r#"{"op":"shutdown"}"#);
}

/// Queue-depth load shedding: with the limit forced to 1, a request issued
/// while another is in flight is refused at the door with the pinned
/// `retryable:` overload error — and the in-flight request still completes.
#[test]
fn overload_sheds_with_a_pinned_retryable_error() {
    let (server, addr) = boot();
    server.set_queue_limit(1);
    let mut c = Client::connect(addr).unwrap();
    let r = c.call(r#"{"op":"create_model","d":3,"nu2":5}"#).unwrap();
    let model = r.get("model").unwrap().as_usize().unwrap();

    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for i in 0..800usize {
        let a = (i % 40) as f64 * 0.1;
        let b = (i / 40) as f64 * 0.2;
        xs.push(format!("[{a},{b},{}]", (a + b) * 0.5));
        ys.push(format!("{}", a.sin() + b.cos()));
    }
    let req = format!(
        r#"{{"op":"observe_batch","model":{model},"xs":[{}],"ys":[{}]}}"#,
        xs.join(","),
        ys.join(",")
    );
    assert_eq!(c.call(&req).unwrap().get("ok").unwrap().as_bool(), Some(true));

    // Occupy the single slot with a slow hyperparameter fit on a raw socket
    // (written but not yet read, so it stays in flight while we probe).
    let a = TcpStream::connect(addr).unwrap();
    let mut aw = a.try_clone().unwrap();
    let mut ar = BufReader::new(a);
    aw.write_all(format!("{{\"op\":\"fit\",\"model\":{model},\"steps\":300}}\n").as_bytes())
        .unwrap();

    // Probe until we overlap the in-flight fit; the shed error is immediate
    // (refused at the door, never queued) so this terminates fast.
    let mut shed = None;
    for _ in 0..10_000 {
        let r = c.call(&format!(r#"{{"op":"stats","model":{model}}}"#)).unwrap();
        if r.get("ok").unwrap().as_bool() == Some(false) {
            shed = r.get("error").unwrap().as_str().map(str::to_owned);
            break;
        }
    }
    let shed = shed.expect("probe never overlapped the in-flight fit");
    assert_eq!(shed, "retryable: server overloaded (2 requests in flight, limit 1)");

    // Shedding refused the probe at the door — it did not cancel the
    // in-flight fit, whose reply arrives intact.
    let fit = read_reply(&mut ar);
    assert_eq!(fit.get("ok").unwrap().as_bool(), Some(true), "{fit}");

    // Fleet idle again: the previously-shed client is served normally.
    let r = c.call(&format!(r#"{{"op":"stats","model":{model}}}"#)).unwrap();
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
    let _ = c.call(r#"{"op":"shutdown"}"#);
}
